"""Optimisers for local client training and server-side updates.

Implemented from scratch (no optax dependency): plain SGD, FedProx's
proximal SGD (Li et al., MLSys'20), Adam for the LLM-scale examples, and
the E-epoch local-training drivers used by the federated round (Eq. 12).

The round loops obtain their client phase from :func:`make_client_solver`,
which returns a BATCHED solver (all clients at once).  For the paper
autoencoder it dispatches to the fused local-train operator
(``kernels/ops.local_train``: the whole E-epoch SGD phase in one
VMEM-resident kernel launch, Pallas on TPU / the ``kernels/ref`` oracle
elsewhere) — the dense per-client ``(E * nb, bs, D)`` batch stream of the
legacy path never materialises.  Non-AE models (anything that is not the
``models/autoencoder`` MLP trained with its MSE loss) automatically fall
back to the legacy per-client ``local_sgd`` scan, which
``LocalTrainConfig(fused=False)`` also forces — kept as the equivalence
baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

Params = Any
LossFn = Callable[[Params, jax.Array], jax.Array]


def sgd(params: Params, grads: Params, lr: float) -> Params:
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


def proximal_grad(params: Params, anchor: Params, grads: Params, mu: float) -> Params:
    """grad + mu (theta - theta_anchor): the FedProx proximal term."""
    return jax.tree_util.tree_map(
        lambda g, p, a: g + mu * (p - a), grads, params, anchor
    )


def local_sgd(
    loss_fn: LossFn,
    params: Params,
    batches: jax.Array,
    lr: float,
) -> tuple[Params, jax.Array]:
    """Run SGD over a (nb, bs, ...) batch stream; returns (params, mean loss)."""
    grad_fn = jax.value_and_grad(loss_fn)

    def step(p, batch):
        loss, g = grad_fn(p, batch)
        return sgd(p, g, lr), loss

    params, losses = jax.lax.scan(step, params, batches)
    return params, jnp.mean(losses)


def proximal_local_sgd(
    loss_fn: LossFn,
    params: Params,
    batches: jax.Array,
    lr: float,
    mu: float,
) -> tuple[Params, jax.Array]:
    """FedProx local solver: SGD on F_i(theta) + mu/2 ||theta - theta^t||^2."""
    anchor = params
    grad_fn = jax.value_and_grad(loss_fn)

    def step(p, batch):
        loss, g = grad_fn(p, batch)
        g = proximal_grad(p, anchor, g, mu)
        return sgd(p, g, lr), loss

    params, losses = jax.lax.scan(step, params, batches)
    return params, jnp.mean(losses)


@dataclasses.dataclass(frozen=True)
class LocalTrainConfig:
    """How the round loops run the client phase (Eq. 12).

    ``fused=True`` routes AE clients through the fused local-train kernel
    (``kernels/fused_local_train``; ``use_pallas``/``interpret`` pick the
    backend exactly like ``CompressorConfig``).  ``fused=False`` is the
    legacy per-client ``local_sgd`` scan over a gathered batch stream —
    the equivalence baseline.  Models the kernel cannot express (anything
    but the paper's MLP autoencoder + MSE loss) fall back automatically.
    """

    fused: bool = True
    use_pallas: bool = False
    interpret: bool = True

    def replace(self, **kw: Any) -> "LocalTrainConfig":
        return dataclasses.replace(self, **kw)


def fusable_params(params: Any) -> bool:
    """True when ``params`` is the AE-style MLP the fused kernel handles:
    a list/tuple of ``{"w", "b"}`` layers with chained 2-D weights and an
    output dimension equal to the input dimension (reconstruction)."""
    if not isinstance(params, (list, tuple)) or not params:
        return False
    prev = None
    for layer in params:
        if not isinstance(layer, dict) or set(layer) != {"w", "b"}:
            return False
        w, b = layer["w"], layer["b"]
        if getattr(w, "ndim", 0) != 2 or getattr(b, "ndim", 0) != 1:
            return False
        if b.shape[0] != w.shape[1]:
            return False
        if prev is not None and w.shape[0] != prev:
            return False
        prev = w.shape[1]
    return params[0]["w"].shape[0] == params[-1]["w"].shape[1]


def make_client_solver(
    loss_fn: LossFn,
    *,
    batch_size: int,
    epochs: int,
    lr: float,
    prox_mu: float = 0.0,
    solver: LocalTrainConfig = LocalTrainConfig(),
) -> Callable[[Params, jax.Array, jax.Array], tuple[jax.Array, jax.Array]]:
    """Build the batched client phase used by the round loops.

    Returns ``clients_fn(params, data (N, window, D), keys (N,)) ->
    (flat_deltas (N, d), mean_losses (N,))`` where the deltas are
    ``ravel_pytree(theta_i^E - theta^t)`` — ready to chain into the fused
    compress-and-aggregate operator.

    Dispatch happens per call: when ``solver.fused`` and the params are
    the paper autoencoder trained with its own loss
    (``models/autoencoder.loss``), the whole phase runs as ONE fused
    operator over all clients; otherwise it falls back to the legacy
    vmapped ``local_sgd`` / ``proximal_local_sgd`` scan.
    """
    from repro.data.pipeline import multi_epoch_batches, multi_epoch_indices
    from repro.kernels import ops as kops
    from repro.models import autoencoder as ae

    # STATIC proximal switch: ``prox_mu`` may be a tracer inside a
    # config-axis sweep, where the proximal term always runs (a runtime mu
    # of 0 contributes an exact zero gradient term); a concrete 0 keeps the
    # plain-SGD solver, bit-identical to the historical path.
    use_prox = not (isinstance(prox_mu, (int, float)) and prox_mu == 0.0)

    def scan_path(params, data, keys):
        def one(dd, kk):
            batches = multi_epoch_batches(kk, dd, batch_size, epochs)
            if use_prox:
                p1, loss = proximal_local_sgd(
                    loss_fn, params, batches, lr, prox_mu
                )
            else:
                p1, loss = local_sgd(loss_fn, params, batches, lr)
            delta = jax.tree_util.tree_map(lambda a, b: a - b, p1, params)
            return ravel_pytree(delta)[0], loss

        return jax.vmap(one)(data, keys)

    def clients_fn(params, data, keys):
        if solver.fused and loss_fn is ae.loss and fusable_params(params):
            window = data.shape[1]
            idx = jax.vmap(
                lambda k: multi_epoch_indices(k, window, batch_size, epochs)
            )(keys)
            return kops.local_train(
                params, data, idx, lr, prox_mu,
                use_pallas=solver.use_pallas, interpret=solver.interpret,
            )
        return scan_path(params, data, keys)

    return clients_fn


class AdamState(NamedTuple):
    mu: Params
    nu: Params
    count: jax.Array


def adam_init(params: Params) -> AdamState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(zeros, zeros, jnp.zeros((), jnp.int32))


def adam(
    params: Params,
    grads: Params,
    state: AdamState,
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> tuple[Params, AdamState]:
    count = state.count + 1
    mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads
    )
    nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads
    )
    c = count.astype(jnp.float32)
    mhat_scale = 1.0 / (1.0 - b1**c)
    vhat_scale = 1.0 / (1.0 - b2**c)

    def upd(p, m, v):
        step = lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p
        return p - step

    return jax.tree_util.tree_map(upd, params, mu, nu), AdamState(mu, nu, count)
