"""Optimisers for local client training and server-side updates.

Implemented from scratch (no optax dependency): plain SGD, FedProx's
proximal SGD (Li et al., MLSys'20), Adam for the LLM-scale examples, and
the E-epoch local-training drivers used by the federated round (Eq. 12).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any
LossFn = Callable[[Params, jax.Array], jax.Array]


def sgd(params: Params, grads: Params, lr: float) -> Params:
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


def proximal_grad(params: Params, anchor: Params, grads: Params, mu: float) -> Params:
    """grad + mu (theta - theta_anchor): the FedProx proximal term."""
    return jax.tree_util.tree_map(
        lambda g, p, a: g + mu * (p - a), grads, params, anchor
    )


def local_sgd(
    loss_fn: LossFn,
    params: Params,
    batches: jax.Array,
    lr: float,
) -> tuple[Params, jax.Array]:
    """Run SGD over a (nb, bs, ...) batch stream; returns (params, mean loss)."""
    grad_fn = jax.value_and_grad(loss_fn)

    def step(p, batch):
        loss, g = grad_fn(p, batch)
        return sgd(p, g, lr), loss

    params, losses = jax.lax.scan(step, params, batches)
    return params, jnp.mean(losses)


def proximal_local_sgd(
    loss_fn: LossFn,
    params: Params,
    batches: jax.Array,
    lr: float,
    mu: float,
) -> tuple[Params, jax.Array]:
    """FedProx local solver: SGD on F_i(theta) + mu/2 ||theta - theta^t||^2."""
    anchor = params
    grad_fn = jax.value_and_grad(loss_fn)

    def step(p, batch):
        loss, g = grad_fn(p, batch)
        g = proximal_grad(p, anchor, g, mu)
        return sgd(p, g, lr), loss

    params, losses = jax.lax.scan(step, params, batches)
    return params, jnp.mean(losses)


class AdamState(NamedTuple):
    mu: Params
    nu: Params
    count: jax.Array


def adam_init(params: Params) -> AdamState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(zeros, zeros, jnp.zeros((), jnp.int32))


def adam(
    params: Params,
    grads: Params,
    state: AdamState,
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> tuple[Params, AdamState]:
    count = state.count + 1
    mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads
    )
    nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads
    )
    c = count.astype(jnp.float32)
    mhat_scale = 1.0 / (1.0 - b1**c)
    vhat_scale = 1.0 / (1.0 - b2**c)

    def upd(p, m, v):
        step = lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p
        return p - step

    return jax.tree_util.tree_map(upd, params, mu, nu), AdamState(mu, nu, count)
