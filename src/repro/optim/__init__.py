from repro.optim.scaffold import ScaffoldState, scaffold_local  # noqa: F401
from repro.optim.sgd import (  # noqa: F401
    LocalTrainConfig,
    adam,
    fusable_params,
    local_sgd,
    make_client_solver,
    proximal_local_sgd,
    sgd,
)
