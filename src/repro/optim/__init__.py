from repro.optim.sgd import (  # noqa: F401
    adam,
    local_sgd,
    proximal_local_sgd,
    sgd,
)
from repro.optim.scaffold import ScaffoldState, scaffold_local  # noqa: F401
