"""Server-side adaptive optimisers for federated aggregation (FedAdam,
Reddi et al., ICLR'21 — the paper's related-work family [34]).

The aggregated client update acts as a pseudo-gradient at the gateway:
    theta_{t+1} = theta_t + server_opt(mean_delta).
Plain FedAvg is the identity server optimiser.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ServerOptState(NamedTuple):
    m: jax.Array       # (d,) first moment
    v: jax.Array       # (d,) second moment
    step: jax.Array    # () int32


def init_state(d: int) -> ServerOptState:
    return ServerOptState(
        m=jnp.zeros((d,), jnp.float32),
        v=jnp.zeros((d,), jnp.float32),
        step=jnp.zeros((), jnp.int32),
    )


def adam_update(
    pseudo_grad: jax.Array,
    state: ServerOptState,
    lr: float = 1e-2,
    b1: float = 0.9,
    b2: float = 0.99,
    eps: float = 1e-8,
) -> tuple[jax.Array, ServerOptState]:
    """One FedAdam step; returns (parameter increment, new state)."""
    step = state.step + 1
    m = b1 * state.m + (1.0 - b1) * pseudo_grad
    v = b2 * state.v + (1.0 - b2) * jnp.square(pseudo_grad)
    mhat = m / (1.0 - b1 ** step.astype(jnp.float32))
    vhat = v / (1.0 - b2 ** step.astype(jnp.float32))
    incr = lr * mhat / (jnp.sqrt(vhat) + eps)
    return incr, ServerOptState(m, v, step)
