"""SCAFFOLD control variates (Karimireddy et al., ICML'20).

The paper reports SCAFFOLD unstable under its severe heterogeneity and
keeps it out of the headline tables; we implement it anyway (deliverable:
"if the paper compares against a baseline, implement the baseline too") so
the released traces can include it.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class ScaffoldState(NamedTuple):
    c_global: Params   # server control variate
    c_local: Params    # per-client control variates (stacked leaves, (N, ...))


def init_state(params: Params, n_clients: int) -> ScaffoldState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    stacked = jax.tree_util.tree_map(
        lambda z: jnp.zeros((n_clients, *z.shape), z.dtype), params
    )
    return ScaffoldState(zeros, stacked)


def scaffold_local(
    loss_fn: Callable[[Params, jax.Array], jax.Array],
    params: Params,
    batches: jax.Array,
    lr: float,
    c_global: Params,
    c_i: Params,
) -> tuple[Params, Params, jax.Array]:
    """Option-II SCAFFOLD local update.

    Returns (new_params, new_c_i, mean_loss).  Local steps use the
    variance-corrected gradient g - c_i + c; the new client control variate
    is c_i - c + (theta^t - theta_i) / (K lr).
    """
    anchor = params
    grad_fn = jax.value_and_grad(loss_fn)

    def step(p, batch):
        loss, g = grad_fn(p, batch)
        g = jax.tree_util.tree_map(
            lambda gg, ci, cg: gg - ci + cg, g, c_i, c_global
        )
        p = jax.tree_util.tree_map(lambda pp, gg: pp - lr * gg, p, g)
        return p, loss

    params, losses = jax.lax.scan(step, params, batches)
    k_steps = jnp.maximum(batches.shape[0], 1)
    new_c_i = jax.tree_util.tree_map(
        lambda ci, cg, a, p: ci - cg + (a - p) / (k_steps * lr),
        c_i, c_global, anchor, params,
    )
    return params, new_c_i, jnp.mean(losses)
