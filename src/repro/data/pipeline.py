"""Minibatch pipeline for local training (pure-JAX, scan/vmap friendly).

Local SGD runs E epochs over the client's window; an epoch is a random
permutation of the window split into fixed-size minibatches.  Everything is
shape-static so the whole federated round jits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def epoch_batches(
    key: jax.Array, data: jax.Array, batch_size: int
) -> jax.Array:
    """Shuffle one client's (n, D) window into (n//bs, bs, D) batches."""
    n = data.shape[0]
    nb = n // batch_size
    perm = jax.random.permutation(key, n)[: nb * batch_size]
    return data[perm].reshape(nb, batch_size, *data.shape[1:])


def multi_epoch_indices(
    key: jax.Array, n: int, batch_size: int, epochs: int
) -> jax.Array:
    """(epochs * n//bs, bs) int32 row indices for E local epochs.

    Epoch e is a fresh permutation of [0, n) truncated to whole minibatches
    — exactly the batch order of :func:`multi_epoch_batches`, without
    gathering the data.  The fused local-train kernel consumes these and
    indexes its VMEM-resident window per step, so the dense
    (E * n//bs, bs, D) batch stream never materialises.
    """
    nb = n // batch_size
    keys = jax.random.split(key, epochs)
    perms = jax.vmap(
        lambda k: jax.random.permutation(k, n)[: nb * batch_size]
    )(keys)
    return perms.reshape(epochs * nb, batch_size).astype(jnp.int32)


def multi_epoch_batches(
    key: jax.Array, data: jax.Array, batch_size: int, epochs: int
) -> jax.Array:
    """(epochs * n//bs, bs, D) batch stream for E local epochs."""
    idx = multi_epoch_indices(key, data.shape[0], batch_size, epochs)
    return data[idx]


def lm_batches(
    key: jax.Array, tokens: jax.Array, batch: int, seq_len: int
) -> jax.Array:
    """Sample (batch, seq_len+1) windows from a token stream (for the LLM
    federated fine-tuning example)."""
    n = tokens.shape[0] - seq_len - 1
    starts = jax.random.randint(key, (batch,), 0, jnp.maximum(n, 1))
    idx = starts[:, None] + jnp.arange(seq_len + 1)[None, :]
    return tokens[idx]
