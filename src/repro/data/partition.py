"""Client data partitioning, including Dirichlet non-IID splits (Fig. 7).

The synthetic generator already supports mode-level Dirichlet heterogeneity
directly; this module adds the classical *pooled-data* partitioner used for
the real benchmarks (split one entity's series across several virtual
sensors) and utilities for mapping entities onto the deployment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dirichlet_proportions(
    key: jax.Array, n_clients: int, n_groups: int, alpha: float
) -> jax.Array:
    """(n_clients, n_groups) Dirichlet(alpha) rows."""
    return jax.random.dirichlet(
        key, jnp.full((n_groups,), alpha), (n_clients,)
    )


def contiguous_split(x: jax.Array, n_clients: int) -> jax.Array:
    """Split a (T, D) series into (n_clients, T // n_clients, D) shards.

    Contiguous (not interleaved) so each client sees a coherent window —
    the realistic federated split for time series.
    """
    t = x.shape[0]
    per = t // n_clients
    return x[: per * n_clients].reshape(n_clients, per, *x.shape[1:])


def entities_to_sensors(
    key: jax.Array, n_entities: int, n_sensors: int
) -> jax.Array:
    """Assign each sensor one source entity (round-robin + shuffle)."""
    base = jnp.arange(n_sensors) % n_entities
    return jax.random.permutation(key, base)


def replicate_entities(
    data: jax.Array, assignment: jax.Array
) -> jax.Array:
    """Gather per-entity arrays (E, ...) into per-sensor arrays (N, ...)."""
    return data[assignment]
