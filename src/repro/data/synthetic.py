"""Synthetic IoUT sensing data (paper Sec. III-E / VI evaluation substrate).

Each sensor produces a multivariate series x in R^D built from a small set
of latent environmental *modes* (water masses / equipment regimes): a mode
is a random linear map from a low-dimensional smooth latent process
(sinusoids + AR(1) drift) to the D observed features.  Sensor-level
heterogeneity comes from Dirichlet-distributed mode proportions — alpha
small => strongly non-IID (each sensor sees mostly one mode), alpha large
=> near-IID — exactly the knob used in the paper's Fig. 7 study.

Anomalies injected into test windows (labels returned):
  - spike: additive heavy-tailed burst on a feature subset,
  - drift: slow additive ramp,
  - stuck: a feature subset frozen at a constant.

Distribution-shift schedules (dynamic world, PR 9): ``covariate_shift``
adds a linear mean ramp across the WHOLE per-sensor series (train -> val
-> test), so models trained on the early window score a drifted test
window; ``label_shift`` confines the anomaly segments to the late
``1 - label_shift`` fraction of the test window, a prevalence schedule.
Both default to 0.0, which generates bit-identical data to the legacy
path (same PRNG draws, same arithmetic).  The IN-TRAINING covariate
schedule (world moving between federated rounds) lives in
``core/drift.DriftConfig`` instead.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    n_sensors: int = 100
    feature_dim: int = 32          # D (paper Table II)
    latent_dim: int = 4
    n_modes: int = 5
    train_len: int = 256           # normal-only training window per sensor
    val_len: int = 64              # normal-only calibration window
    test_len: int = 128            # mixed test window
    dirichlet_alpha: float = 1.0   # mode heterogeneity across sensors
    anomaly_rate: float = 0.15     # fraction of anomalous test points
    noise_std: float = 0.05
    anomaly_scale: float = 1.5
    # Distribution-shift schedules (0.0 = bit-identical legacy data):
    covariate_shift: float = 0.0   # mean ramp magnitude over the series
    label_shift: float = 0.0       # in [0, 1): anomalies pushed this late

    def __post_init__(self) -> None:
        if not 0.0 <= self.label_shift < 1.0:
            raise ValueError(
                f"label_shift must be in [0, 1), got {self.label_shift!r}"
            )


class SensorDataset(NamedTuple):
    """Stacked per-sensor splits. Leading axis = sensor."""

    train: jax.Array        # (N, train_len, D) normal
    val: jax.Array          # (N, val_len, D)   normal
    test: jax.Array         # (N, test_len, D)  mixed
    test_label: jax.Array   # (N, test_len) bool
    n_samples: jax.Array    # (N,) f32 — n_i weights for aggregation


def _latent_process(key: jax.Array, length: int, dim: int) -> jax.Array:
    """Smooth latent: sinusoids with random phase/freq + AR(1) noise."""
    kf, kp, kn = jax.random.split(key, 3)
    t = jnp.arange(length, dtype=jnp.float32)[:, None]
    freq = jax.random.uniform(kf, (dim,), minval=0.01, maxval=0.1)
    phase = jax.random.uniform(kp, (dim,), minval=0.0, maxval=2.0 * jnp.pi)
    sin = jnp.sin(2.0 * jnp.pi * freq * t + phase)
    noise = jax.random.normal(kn, (length, dim)) * 0.3

    def ar(carry, x):
        y = 0.9 * carry + x
        return y, y

    _, ar_noise = jax.lax.scan(ar, jnp.zeros((dim,)), noise)
    return sin + 0.2 * ar_noise


def _inject_anomalies(
    key: jax.Array, x: jax.Array, rate: float, scale: float,
    label_shift: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Inject segment anomalies; returns (x', labels).

    ``label_shift`` in [0, 1) confines segment starts to the late
    ``1 - label_shift`` fraction of the window (a prevalence-timing
    schedule); 0.0 reproduces the legacy draws bit-for-bit.
    """
    length, d = x.shape
    kseg, ktype, kfeat, kmag = jax.random.split(key, 4)
    # ~3 segments whose total expected length matches `rate`.
    n_seg = 3
    seg_len = max(1, int(rate * length / n_seg))
    min_start = int(label_shift * max(1, length - seg_len))
    starts = jax.random.randint(
        kseg, (n_seg,), min_start, max(min_start + 1, length - seg_len)
    )
    pos = jnp.arange(length)
    label = jnp.zeros((length,), bool)
    for s in range(n_seg):
        label = label | ((pos >= starts[s]) & (pos < starts[s] + seg_len))

    feat_mask = jax.random.bernoulli(kfeat, 0.4, (d,))
    kind = jax.random.randint(ktype, (), 0, 3)
    mag = scale * (1.0 + jax.random.uniform(kmag, ()))

    spike = x + mag * feat_mask[None, :] * jnp.sign(
        jax.random.normal(kmag, x.shape)
    )
    ramp = x + mag * feat_mask[None, :] * (
        jnp.linspace(0.0, 1.0, length)[:, None]
    )
    stuck = jnp.where(feat_mask[None, :], jnp.mean(x, 0, keepdims=True) + mag, x)
    anom = jax.lax.switch(kind, [lambda: spike, lambda: ramp, lambda: stuck])
    return jnp.where(label[:, None], anom, x), label


def generate(key: jax.Array, cfg: SyntheticConfig) -> SensorDataset:
    """Generate the full stacked dataset for all sensors."""
    k_modes, k_mix, k_sensors = jax.random.split(key, 3)
    # Mode maps: (n_modes, latent_dim, D)
    mode_maps = (
        jax.random.normal(k_modes, (cfg.n_modes, cfg.latent_dim, cfg.feature_dim))
        / jnp.sqrt(cfg.latent_dim)
    )
    mix = jax.random.dirichlet(
        k_mix, jnp.full((cfg.n_modes,), cfg.dirichlet_alpha), (cfg.n_sensors,)
    )  # (N, n_modes)

    total = cfg.train_len + cfg.val_len + cfg.test_len

    def per_sensor(key, w):
        kl, kn, ka = jax.random.split(key, 3)
        latent = _latent_process(kl, total, cfg.latent_dim)
        # Sensor's observation map = Dirichlet-weighted mixture of modes.
        obs_map = jnp.einsum("m,mld->ld", w, mode_maps)
        x = latent @ obs_map + cfg.noise_std * jax.random.normal(
            kn, (total, cfg.feature_dim)
        )
        if cfg.covariate_shift:
            # Linear mean ramp over the whole series: the world the test
            # window sees is not the world the train window saw.
            ramp = jnp.linspace(0.0, 1.0, total, dtype=x.dtype)[:, None]
            x = x + cfg.covariate_shift * ramp
        train = x[: cfg.train_len]
        val = x[cfg.train_len : cfg.train_len + cfg.val_len]
        test = x[cfg.train_len + cfg.val_len :]
        test, label = _inject_anomalies(
            ka, test, cfg.anomaly_rate, cfg.anomaly_scale, cfg.label_shift
        )
        return train, val, test, label

    keys = jax.random.split(k_sensors, cfg.n_sensors)
    train, val, test, label = jax.vmap(per_sensor)(keys, mix)
    return SensorDataset(
        train=train,
        val=val,
        test=test,
        test_label=label,
        n_samples=jnp.full((cfg.n_sensors,), float(cfg.train_len)),
    )


def normalize(ds: SensorDataset) -> SensorDataset:
    """Per-sensor z-score using train statistics (standard AD protocol)."""
    mean = jnp.mean(ds.train, axis=1, keepdims=True)
    std = jnp.std(ds.train, axis=1, keepdims=True) + 1e-6
    return SensorDataset(
        train=(ds.train - mean) / std,
        val=(ds.val - mean) / std,
        test=(ds.test - mean) / std,
        test_label=ds.test_label,
        n_samples=ds.n_samples,
    )
