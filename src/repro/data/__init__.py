from repro.data import benchmarks, partition, pipeline, synthetic  # noqa: F401
