"""Real anomaly-detection benchmarks: SMD, SMAP, MSL (paper Sec. VI-F).

The container has no network access, so each loader first looks for the
real files under ``data_dir`` (the standard OmniAnomaly / Telemanom npy
layout: ``<name>/<channel>_train.npy``, ``_test.npy``, ``_labels.npy``).
When absent it falls back to a *statistically matched surrogate*: same
entity count, feature dimension, and anomaly base rates as the published
benchmarks, generated from the synthetic IoUT process.  EXPERIMENTS.md
flags which source was used.

Published shapes reproduced:
  SMD : 10 machines  x D=38  (the paper's subset)
  SMAP: 55 channels  x D=25
  MSL : 27 channels  x D=55
"""
from __future__ import annotations

import dataclasses
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import SensorDataset, SyntheticConfig, generate, normalize


@dataclasses.dataclass(frozen=True)
class BenchmarkSpec:
    name: str
    n_entities: int
    feature_dim: int
    anomaly_rate: float   # published approximate test anomaly base rate


SPECS = {
    "smd": BenchmarkSpec("smd", 10, 38, 0.042),
    "smap": BenchmarkSpec("smap", 55, 25, 0.13),
    "msl": BenchmarkSpec("msl", 27, 55, 0.105),
}


class BenchmarkData(NamedTuple):
    dataset: SensorDataset
    source: str  # "real" | "surrogate"


def _try_load_real(
    spec: BenchmarkSpec, data_dir: str, max_len: int
) -> SensorDataset | None:
    root = os.path.join(data_dir, spec.name)
    if not os.path.isdir(root):
        return None
    entities = sorted(
        f[: -len("_train.npy")]
        for f in os.listdir(root)
        if f.endswith("_train.npy")
    )
    if not entities:
        return None
    trains, vals, tests, labels = [], [], [], []
    for e in entities[: spec.n_entities]:
        tr = np.load(os.path.join(root, f"{e}_train.npy"))[:max_len]
        te = np.load(os.path.join(root, f"{e}_test.npy"))[:max_len]
        lb = np.load(os.path.join(root, f"{e}_labels.npy"))[:max_len]
        n_val = max(1, len(tr) // 5)
        trains.append(tr[:-n_val])
        vals.append(tr[-n_val:])
        tests.append(te)
        labels.append(lb.astype(bool))

    def stack(parts):
        m = min(p.shape[0] for p in parts)
        return jnp.asarray(np.stack([p[:m] for p in parts]), jnp.float32)

    train, val, test = stack(trains), stack(vals), stack(tests)
    label = jnp.asarray(
        np.stack([lab[: test.shape[1]] for lab in labels]), bool
    )
    n = jnp.full((train.shape[0],), float(train.shape[1]))
    return SensorDataset(train, val, test, label, n)


def _surrogate(spec: BenchmarkSpec, seed: int, length: int) -> SensorDataset:
    cfg = SyntheticConfig(
        n_sensors=spec.n_entities,
        feature_dim=spec.feature_dim,
        train_len=length,
        val_len=max(32, length // 4),
        test_len=length,
        dirichlet_alpha=0.5,       # benchmark entities are heterogeneous
        anomaly_rate=spec.anomaly_rate,
        n_modes=max(4, spec.n_entities // 8),
    )
    return generate(jax.random.PRNGKey(seed), cfg)


def load(
    name: str,
    data_dir: str = "data",
    seed: int = 0,
    length: int = 512,
) -> BenchmarkData:
    """Load a benchmark by name, real files if present, surrogate otherwise."""
    spec = SPECS[name.lower()]
    real = _try_load_real(spec, data_dir, max_len=4 * length)
    if real is not None:
        return BenchmarkData(dataset=normalize(real), source="real")
    return BenchmarkData(
        dataset=normalize(_surrogate(spec, seed, length)), source="surrogate"
    )
