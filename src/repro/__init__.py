"""repro: hierarchical federated anomaly detection for the IoUT, in JAX."""
__version__ = "0.1.0"
