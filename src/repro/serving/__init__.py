"""Fleet-scale online anomaly-scoring service (the paper's end product).

``score``     — fused AE-forward + error + threshold compare hot path
                (Pallas kernel on TPU, jnp oracle elsewhere);
``calibrate`` — streaming per-fog / global threshold reservoirs;
``service``   — micro-batching request loop with double-buffered param
                hot-swap off a ``checkpoint.CheckpointStore`` that
                ``hfl.train`` / ``Engine.run`` publish rounds into.
"""
from repro.serving.calibrate import (  # noqa: F401
    ReservoirState,
    StreamingCalibrator,
)
from repro.serving.score import (  # noqa: F401
    ScoreResult,
    dequantize_params,
    fleet_tau,
    quantize_params,
    score,
    score_fleet,
    score_q8,
)
from repro.serving.service import (  # noqa: F401
    ScorePrograms,
    ScoringService,
    ServiceStats,
)
from repro.serving.tenancy import MultiTenantService  # noqa: F401
