"""Fleet-scale fused anomaly scoring — the serving front-end.

The paper's end product is a detection *service*: fog nodes score sensor
telemetry against the autoencoder threshold (Sec. V-D, Eq. 32) that
federated training keeps fresh.  :func:`score` is that hot path: AE
forward, squared-L2 reconstruction error, and threshold compare run as ONE
fused operator (``kernels/fused_score``, jnp oracle
``kernels/ref.fused_score_ref``) over a ``(fleet, window, d)`` telemetry
batch — compiled Pallas on TPU, the oracle on CPU/GPU, mirroring the
compressor dispatch.  The dense reconstruction never materialises in HBM
on the kernel path.

``fused=False`` keeps the legacy three-program pipeline
(``core/anomaly.reconstruction_errors`` + ``flag_anomalies``) as the
equivalence baseline, exactly like ``CompressorConfig(fused=False)`` does
for the training hot path.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import anomaly
from repro.kernels import ops as kops
from repro.models import autoencoder as ae


def default_use_pallas() -> bool:
    """Compiled Pallas kernels need a real TPU; elsewhere the serving path
    falls back to the pure-jnp oracle (same rule as ``repro.engine``)."""
    return jax.default_backend() == "tpu"


class ScoreResult(NamedTuple):
    """Per-sample scoring output; both leaves share ``x.shape[:-1]``."""

    error: jax.Array   # squared-L2 reconstruction error (f32)
    flag: jax.Array    # anomaly decision err > tau (bool)


def score(
    params: Any,
    x: jax.Array,
    tau: jax.Array | float,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    fused: bool = True,
) -> ScoreResult:
    """Score telemetry ``x`` of shape (..., d) against threshold(s) ``tau``.

    ``tau`` is a scalar or broadcastable to ``x.shape[:-1]`` (per-row
    thresholds — see :func:`fleet_tau` for the per-fog mapping).  Leading
    axes are flattened into one row axis for the kernel and restored on the
    way out, so (fleet, window, d) batches score as a single sweep.
    """
    if use_pallas is None:
        use_pallas = default_use_pallas()
    if interpret is None:
        interpret = not default_use_pallas()
    lead = x.shape[:-1]
    rows = x.reshape(-1, x.shape[-1])
    tau_rows = jnp.broadcast_to(
        jnp.asarray(tau, jnp.float32), lead
    ).reshape(-1)
    if fused:
        err, flag = kops.fused_score(
            rows, params, tau_rows, use_pallas=use_pallas, interpret=interpret
        )
    else:
        err = anomaly.reconstruction_errors(ae.apply, params, rows)
        flag = anomaly.flag_anomalies(err, tau_rows)
    # Non-finite errors (NaN telemetry, poisoned/diverged model) are
    # anomalous by policy: ``NaN > tau`` is False, which would otherwise
    # silently pass the corrupt rows as normal.
    flag = jnp.where(jnp.isfinite(err), flag, True)
    return ScoreResult(err.reshape(lead), flag.reshape(lead))


def quantize_params(params: Any) -> Any:
    """Per-layer, per-output-channel symmetric int8 weight quantisation.

    Each layer ``{"w", "b"}`` becomes ``{"qw" int8, "sw" (1, d_out) f32,
    "b" f32}`` with ``w ≈ qw * sw`` (``sw = amax(|w|, axis=0) / 127``);
    biases stay f32 (they are a rounding error of the weight bytes).
    Reuses the symmetric-amax scheme of ``kernels/quant8`` at per-column
    granularity, which keeps the reconstruction-error shift within
    ~0.5/127 of each column's dynamic range — tight enough that threshold
    flags survive (parity-tested).  This is the opt-in
    ``weight_dtype="int8"`` serving representation; dequantisation happens
    inside the fused score program (:func:`score_q8`)."""
    q = []
    for layer in params:
        w = jnp.asarray(layer["w"], jnp.float32)
        scale = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0
        safe = jnp.where(scale > 0, scale, 1.0)
        qw = jnp.clip(jnp.round(w / safe), -127, 127).astype(jnp.int8)
        qw = jnp.where(scale > 0, qw, jnp.zeros_like(qw))
        q.append({
            "qw": qw,
            "sw": scale.astype(jnp.float32),
            "b": jnp.asarray(layer["b"], jnp.float32),
        })
    return q


def dequantize_params(qparams: Any) -> Any:
    """Materialise f32 ``{"w", "b"}`` layers from :func:`quantize_params`
    output (the unfused/legacy pipeline and tests use this; the fused
    paths dequantise in-program instead)."""
    return [
        {"w": layer["qw"].astype(jnp.float32) * layer["sw"].reshape(1, -1),
         "b": layer["b"]}
        for layer in qparams
    ]


def score_q8(
    qparams: Any,
    x: jax.Array,
    tau: jax.Array | float,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    fused: bool = True,
) -> ScoreResult:
    """:func:`score` over int8-quantised serving weights.

    ``qparams`` comes from :func:`quantize_params`; the fused paths
    (oracle and Pallas) dequantise per output channel inside the score
    program, so the weight buffers stay int8 end to end.  ``fused=False``
    materialises f32 weights and runs the legacy three-program pipeline —
    the equivalence baseline, exactly like the f32 path's opt-out."""
    if use_pallas is None:
        use_pallas = default_use_pallas()
    if interpret is None:
        interpret = not default_use_pallas()
    lead = x.shape[:-1]
    rows = x.reshape(-1, x.shape[-1])
    tau_rows = jnp.broadcast_to(
        jnp.asarray(tau, jnp.float32), lead
    ).reshape(-1)
    if fused:
        err, flag = kops.fused_score_q8(
            rows, qparams, tau_rows, use_pallas=use_pallas, interpret=interpret
        )
    else:
        params = dequantize_params(qparams)
        err = anomaly.reconstruction_errors(ae.apply, params, rows)
        flag = anomaly.flag_anomalies(err, tau_rows)
    flag = jnp.where(jnp.isfinite(err), flag, True)
    return ScoreResult(err.reshape(lead), flag.reshape(lead))


def fleet_tau(
    fog_tau: jax.Array,       # (n_fog,) per-fog thresholds
    fog_id: jax.Array,        # (fleet,) int32 fog assignment per sensor
    window: int,
) -> jax.Array:
    """Map per-fog thresholds onto a (fleet, window) row-threshold grid."""
    return jnp.broadcast_to(
        fog_tau[fog_id][:, None], (fog_id.shape[0], window)
    )


def score_fleet(
    params: Any,
    telemetry: jax.Array,          # (fleet, window, d)
    *,
    tau: jax.Array | float | None = None,
    fog_tau: jax.Array | None = None,
    fog_id: jax.Array | None = None,
    **kw: Any,
) -> ScoreResult:
    """Score a fleet batch with either a global or a per-fog threshold.

    Exactly one of ``tau`` (global, Eq. 32) or (``fog_tau``, ``fog_id``)
    must be given; the latter resolves each sensor's rows against its fog
    cluster's streaming threshold (``serving/calibrate``).
    """
    if (tau is None) == (fog_tau is None):
        raise ValueError("pass exactly one of tau or (fog_tau, fog_id)")
    if fog_tau is not None:
        if fog_id is None:
            raise ValueError("fog_tau needs the fog_id sensor assignment")
        tau = fleet_tau(fog_tau, fog_id, telemetry.shape[1])
    return score(params, telemetry, tau, **kw)
