"""Streaming threshold calibration for the online serving path.

The offline protocol (``core/anomaly.calibrate_threshold``) takes the 99th
percentile of ONE normal-only validation window (Eq. 32).  A long-running
service instead sees an unbounded validation stream, so thresholds here are
maintained over fixed-capacity uniform reservoirs (Vitter's Algorithm R) —
one per fog cluster plus one global — and read out as linearly-interpolated
percentiles of the reservoir contents.

Exactness contract: while a group has seen at most ``capacity`` errors the
reservoir holds *all* of them, and :func:`threshold` reproduces
``jnp.percentile`` (numpy's default linear interpolation) bit-for-bit —
the one-shot calibration is the ``count <= capacity`` special case.
Beyond that the reservoir is a uniform sample and the threshold converges
to the stream percentile at the usual O(1/sqrt(capacity)) rate.

Drift survival (dynamic world, PR 9): a plain uniform reservoir weights
the whole history equally, so after a distribution shift the threshold
re-tracks at O(count) — effectively never for a long-lived service.  The
optional ``horizon`` caps the effective count in the replacement draw:
each new value replaces a uniform slot with probability at least
``capacity / (horizon + 1)``, turning the reservoir into an
exponentially-decayed sample concentrated on roughly the last ``horizon``
observations.  ``horizon=None`` (the default sentinel) reproduces the
legacy uniform behaviour bit-for-bit.

Everything is functional and jittable (`init` / `update` / `threshold`);
:class:`StreamingCalibrator` is the small stateful wrapper the service
loop uses — it also maintains the host-side PSI drift signal
(:meth:`StreamingCalibrator.psi`) that ``ScoringService`` surfaces in
``ServiceStats``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# "Uniform forever" sentinel for ``horizon``: large enough that
# min(count, LEGACY_HORIZON) == count for any reachable count, small
# enough that +1 arithmetic never overflows int32.
LEGACY_HORIZON = 2**30


class ReservoirState(NamedTuple):
    """Per-group reservoirs; the LAST row is the global (all-errors) group,
    rows [0, n_fog) are the per-fog groups."""

    buffer: jax.Array   # (n_fog + 1, capacity) f32
    count: jax.Array    # (n_fog + 1,) int32 — total errors observed
    key: jax.Array      # PRNG state for the replacement draws
    horizon: jax.Array  # () int32 — decay horizon (LEGACY_HORIZON = uniform)


def init(
    key: jax.Array, capacity: int, n_fog: int = 0,
    horizon: int | None = None,
) -> ReservoirState:
    groups = n_fog + 1
    return ReservoirState(
        buffer=jnp.zeros((groups, capacity), jnp.float32),
        count=jnp.zeros((groups,), jnp.int32),
        key=key,
        horizon=jnp.int32(LEGACY_HORIZON if horizon is None else horizon),
    )


def _row_update(buffer, count, g, v, k, ok, horizon):
    """Algorithm R step for group ``g``: slot ``count[g]`` while filling,
    then replace a uniform slot with probability capacity/(count+1) — with
    the count capped at ``horizon``, so a finite horizon keeps the
    replacement probability bounded below and the reservoir decays toward
    the recent window instead of freezing on ancient history.

    ``ok`` gates the whole step: a rejected value (non-finite error) draws
    its PRNG slot but touches neither the buffer nor the count, so the
    key-split sequence stays identical with and without rejections.
    """
    cap = buffer.shape[1]
    c = count[g]
    c_eff = jnp.minimum(c, horizon)
    j = jax.random.randint(k, (), 0, jnp.maximum(c_eff + 1, 1))
    pos = jnp.where(c < cap, c, j)
    keep = (pos < cap) & ok
    pos_c = jnp.minimum(pos, cap - 1)
    buffer = buffer.at[g, pos_c].set(jnp.where(keep, v, buffer[g, pos_c]))
    return buffer, count.at[g].add(jnp.where(ok, 1, 0))


@jax.jit
def update(
    state: ReservoirState,
    errors: jax.Array,              # (B,) validation reconstruction errors
    fog_id: jax.Array | None = None,  # (B,) int32, optional fog routing
) -> ReservoirState:
    """Fold a batch of validation errors into the reservoirs.

    Every error feeds the global group; with ``fog_id`` it also feeds that
    fog's group.  Non-finite errors (NaN/Inf from corrupt telemetry or a
    poisoned model) never enter a reservoir or advance its count — they
    would otherwise pin every threshold to NaN/inf — though each event
    still draws its per-position PRNG keys.  Scan-sequential
    by construction — reservoir sampling is order-dependent — which is fine
    off the hot path (calibration batches are small next to the scoring
    stream).
    """
    errors = errors.reshape(-1).astype(jnp.float32)
    g_global = state.buffer.shape[0] - 1
    fid = (
        jnp.full(errors.shape, g_global, jnp.int32)
        if fog_id is None
        else fog_id.reshape(-1).astype(jnp.int32)
    )

    def one(carry, ev):
        buffer, count, key = carry
        e, f = ev
        key, k1, k2 = jax.random.split(key, 3)
        ok = jnp.isfinite(e)
        buffer, count = _row_update(
            buffer, count, g_global, e, k1, ok, state.horizon
        )
        if fog_id is not None:
            buffer, count = _row_update(
                buffer, count, f, e, k2, ok, state.horizon
            )
        return (buffer, count, key), None

    (buffer, count, key), _ = jax.lax.scan(
        one, (state.buffer, state.count, state.key), (errors, fid)
    )
    return ReservoirState(buffer, count, key, state.horizon)


@jax.jit
def threshold(state: ReservoirState, percentile: float = 99.0) -> jax.Array:
    """Per-group thresholds: (n_fog + 1,) with the global tau last.

    Linearly-interpolated percentile of each group's valid reservoir
    entries (== ``jnp.percentile`` while ``count <= capacity``).  Groups
    that have seen nothing return +inf, so an uncalibrated fog flags no
    anomalies rather than all of them.
    """
    cap = state.buffer.shape[1]
    n_valid = jnp.minimum(state.count, cap)                    # (G,)
    masked = jnp.where(
        jnp.arange(cap)[None, :] < n_valid[:, None], state.buffer, jnp.inf
    )
    srt = jnp.sort(masked, axis=-1)
    q = (n_valid - 1).astype(jnp.float32) * (percentile / 100.0)
    q = jnp.maximum(q, 0.0)
    lo = jnp.floor(q).astype(jnp.int32)
    hi = jnp.minimum(lo + 1, jnp.maximum(n_valid - 1, 0))
    frac = (q - lo.astype(jnp.float32))[:, None]
    v_lo = jnp.take_along_axis(srt, lo[:, None], axis=-1)
    v_hi = jnp.take_along_axis(srt, hi[:, None], axis=-1)
    out = (v_lo + frac * (v_hi - v_lo))[:, 0]
    return jnp.where(n_valid > 0, out, jnp.inf)


class StreamingCalibrator:
    """Stateful wrapper the service loop drives.

    ``observe`` feeds validation errors (optionally fog-routed); ``taus``
    returns the (n_fog + 1,) thresholds with the global one last, and the
    ``global_tau`` / ``fog_taus`` accessors split that for callers.

    ``horizon`` enables the decayed reservoir mode (see module docstring);
    ``psi`` is a host-side population-stability-index drift signal: the
    first ``psi_window`` finite errors freeze a reference histogram
    (deciles by default), the latest ``psi_window`` errors form the
    comparison window, and ``sum((p - q) ln(p / q))`` over the bins scores
    the shift.  The usual reading: < 0.1 stable, 0.1-0.25 moderate drift,
    > 0.25 the thresholds' world has moved.
    """

    def __init__(
        self,
        capacity: int = 4096,
        n_fog: int = 0,
        percentile: float = 99.0,
        seed: int = 0,
        horizon: int | None = None,
        psi_window: int = 512,
        psi_bins: int = 10,
    ):
        self.percentile = float(percentile)
        self.n_fog = int(n_fog)
        self.state = init(jax.random.key(seed), capacity, n_fog, horizon)
        self.psi_window = int(psi_window)
        self.psi_bins = int(psi_bins)
        self._ref: np.ndarray | None = None     # frozen reference sample
        self._ref_edges: np.ndarray | None = None
        self._recent: np.ndarray = np.zeros((0,), np.float32)

    def observe(self, errors: jax.Array, fog_id: jax.Array | None = None) -> None:
        self.state = update(self.state, errors, fog_id)
        e = np.asarray(errors, np.float32).reshape(-1)
        e = e[np.isfinite(e)]
        if e.size == 0:
            return
        self._recent = np.concatenate([self._recent, e])[-self.psi_window:]
        if self._ref is None and self._recent.size >= self.psi_window:
            self._ref = self._recent.copy()
            qs = np.linspace(0.0, 100.0, self.psi_bins + 1)[1:-1]
            self._ref_edges = np.percentile(self._ref, qs)

    def psi(self) -> float:
        """Population stability index of the recent-error histogram vs the
        frozen reference (0.0 until both windows exist)."""
        if self._ref_edges is None or self._recent.size < self.psi_window:
            return 0.0
        ref_hist = np.histogram(self._ref, bins=np.r_[
            -np.inf, self._ref_edges, np.inf
        ])[0]
        cur_hist = np.histogram(self._recent, bins=np.r_[
            -np.inf, self._ref_edges, np.inf
        ])[0]
        eps = 1e-4
        p = ref_hist / max(ref_hist.sum(), 1) + eps
        q = cur_hist / max(cur_hist.sum(), 1) + eps
        p, q = p / p.sum(), q / q.sum()
        return float(np.sum((p - q) * np.log(p / q)))

    def taus(self) -> jax.Array:
        return threshold(self.state, self.percentile)

    @property
    def global_tau(self) -> jax.Array:
        return self.taus()[-1]

    @property
    def fog_taus(self) -> jax.Array:
        return self.taus()[:-1]

    @property
    def seen(self) -> int:
        """Total errors observed by the global group."""
        return int(self.state.count[-1])
