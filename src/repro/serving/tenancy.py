"""Multi-model tenancy: many deployments, one set of compiled programs.

A fleet operator serves one detector *per deployment* (per basin, per
fleet generation, per customer) — but every deployment uses the same
paper autoencoder architecture, so the compiled score programs are
shape-identical across them.  :class:`MultiTenantService` exploits that:
each tenant gets its own param double-buffer, its own
``checkpoint.CheckpointStore`` to hot-swap from, its own thresholds and
its own :class:`~repro.serving.service.ServiceStats` — while every
tenant scores through ONE shared :class:`~repro.serving.service.
ScorePrograms` cache, i.e. one compiled program per row bucket, NOT per
tenant (pinned by ``tests/test_serving_load.py``).

Batches never mix tenants (different weights cannot share a matmul);
the scheduler instead picks which tenant flushes next: any tenant with a
full largest-bucket batch first, otherwise the tenant whose oldest
request has waited longest — so one chatty tenant cannot starve a quiet
one past its ``max_wait_s`` deadline.
"""
from __future__ import annotations

import time
from typing import Any, Callable

from repro.checkpoint import CheckpointStore
from repro.serving import calibrate as cal
from repro.serving.score import ScoreResult
from repro.serving.service import ScorePrograms, ScoringService


class MultiTenantService:
    """Per-deployment scoring services sharing one compiled-program cache.

    Construction fixes what must be shared for the programs to be shared:
    the param template (treedef/shapes), the row buckets, the weight
    dtype, and the dispatch knobs.  ``add_tenant`` then binds a named
    deployment to its own store/threshold source.
    """

    def __init__(
        self,
        params_like: Any,
        *,
        batch_rows: int = 1024,
        buckets: tuple[int, ...] | None = None,
        max_wait_s: float | None = None,
        weight_dtype: str = "f32",
        clock: Callable[[], float] = time.monotonic,
        use_pallas: bool | None = None,
        interpret: bool | None = None,
        fused: bool = True,
    ):
        self.buckets = tuple(sorted(set(buckets or (int(batch_rows),))))
        self.max_wait_s = max_wait_s
        self._params_like = params_like
        self._clock = clock
        self.programs = ScorePrograms(
            weight_dtype=weight_dtype, use_pallas=use_pallas,
            interpret=interpret, fused=fused,
        )
        self._tenants: dict[str, ScoringService] = {}

    # ------------------------------------------------------------------
    # tenant management
    # ------------------------------------------------------------------

    def add_tenant(
        self,
        name: str,
        store: CheckpointStore,
        *,
        tau: float | None = None,
        calibrator: cal.StreamingCalibrator | None = None,
        poll_every: int = 1,
        poll_interval_s: float | None = None,
    ) -> ScoringService:
        """Register a deployment; its latest published round loads now.
        Returns the tenant's service (submit/poll also work through the
        multi-tenant front door)."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        svc = ScoringService(
            store, self._params_like,
            buckets=self.buckets, max_wait_s=self.max_wait_s,
            tau=tau, calibrator=calibrator,
            poll_every=poll_every, poll_interval_s=poll_interval_s,
            weight_dtype=self.programs.weight_dtype, clock=self._clock,
            programs=self.programs,
        )
        self._tenants[name] = svc
        return svc

    def tenant(self, name: str) -> ScoringService:
        return self._tenants[name]

    @property
    def tenants(self) -> tuple[str, ...]:
        return tuple(self._tenants)

    @property
    def compiles_by_bucket(self) -> dict[int, int]:
        """Shared trace counts — one compiled program per bucket, total,
        no matter how many tenants score through it."""
        return dict(self.programs.compiles)

    # ------------------------------------------------------------------
    # request flow
    # ------------------------------------------------------------------

    def submit(
        self, tenant: str, x: Any, fog: int | None = None
    ) -> tuple[str, int]:
        """Queue telemetry for one deployment; the (tenant, rid) pair is
        the key :func:`drain` delivers the result under."""
        return tenant, self._tenants[tenant].submit(x, fog)

    def pending_rows(self) -> int:
        return sum(s.pending_rows() for s in self._tenants.values())

    def next_deadline(self) -> float | None:
        deadlines = [
            d for s in self._tenants.values()
            if (d := s.next_deadline()) is not None
        ]
        return min(deadlines) if deadlines else None

    def should_flush(self, now: float | None = None) -> bool:
        return any(s.should_flush(now) for s in self._tenants.values())

    def _next_tenant(self, now: float | None) -> ScoringService | None:
        """Full batches first (throughput), then the tenant whose oldest
        request has waited longest (fairness under deadlines)."""
        ready = [s for s in self._tenants.values() if s.should_flush(now)]
        if not ready:
            return None
        full = [s for s in ready if s.pending_rows() >= s.buckets[-1]]
        if full:
            return full[0]
        return max(ready, key=lambda s: s.oldest_wait_s(now))

    def step(self, now: float | None = None) -> int:
        """Flush ONE tenant's micro-batch (scheduler above); 0 when no
        tenant is due."""
        svc = self._next_tenant(now)
        return 0 if svc is None else svc.step()

    def pump(self, now: float | None = None) -> int:
        total = 0
        while self.should_flush(now):
            total += self.step(now)
        return total

    def tick(self, now: float | None = None) -> int:
        """Idle heartbeat: per-tenant wall-clock checkpoint polls plus any
        due deadline flushes."""
        for svc in self._tenants.values():
            svc.tick(now)
        return self.pump(now)

    def drain(self) -> dict[tuple[str, int], ScoreResult]:
        """Force-flush every tenant; results keyed by (tenant, rid)."""
        out: dict[tuple[str, int], ScoreResult] = {}
        for name, svc in self._tenants.items():
            for rid, res in svc.drain().items():
                out[(name, rid)] = res
        return out

    def poll(self) -> dict[str, bool]:
        """Hot-swap every tenant to its own newest published round."""
        return {name: svc.poll() for name, svc in self._tenants.items()}

    def summary(self) -> dict:
        tenants = {name: svc.stats.summary() for name, svc in self._tenants.items()}
        return {
            "tenants": tenants,
            "compiles_by_bucket": self.compiles_by_bucket,
            "compiles": sum(self.programs.compiles.values()),
            "requests": sum(t["requests"] for t in tenants.values()),
            "samples": sum(t["samples"] for t in tenants.values()),
            "steps": sum(t["steps"] for t in tenants.values()),
        }
