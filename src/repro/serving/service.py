"""Micro-batching anomaly-scoring service with train-and-serve hot-swap.

One :class:`ScoringService` turns the trained detector into an online
scorer: telemetry requests queue up, get packed into FIXED-SHAPE
micro-batches (padded to ``batch_rows``, so the jitted score program
traces exactly once and never recompiles), and are scored with the fused
kernel path (``serving/score``).

Hot-swap: the service watches a ``checkpoint.CheckpointStore`` that
``hfl.train`` / ``Engine.run`` publish rounds into.  Parameters are
double-buffered — ``poll()`` restores a newer round into the standby
buffer (same treedef/shapes as the active one, so the compiled program is
reused as-is) and flips the active pointer between micro-batches.  Saves
are atomic (tmp + ``os.replace``), so a poll can never observe a
half-written round; federated training and serving run as one pipeline.

Thresholds come from a fixed global tau (Eq. 32), or live from a
``serving/calibrate.StreamingCalibrator`` fed by ``ingest_validation`` —
per-fog when requests carry a fog id, global otherwise.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.serving import calibrate as cal
# Import the functions, not the submodule: the package __init__ re-exports
# a function named `score`, which shadows the module attribute.
from repro.serving.score import ScoreResult
from repro.serving.score import score as _score


@dataclasses.dataclass
class ServiceStats:
    requests: int = 0
    samples: int = 0          # real (unpadded) telemetry rows scored
    steps: int = 0            # micro-batches executed
    swaps: int = 0            # hot-swaps applied after the initial load
    compiles: int = 0         # traces of the score program (1 after warmup)
    busy_s: float = 0.0       # cumulative scoring wall time (all steps)
    # Bounded window so an indefinitely-running service does not grow
    # per-step history without bound; percentiles are over this window.
    step_latency_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=4096)
    )

    def latency_s(self, pct: float) -> float:
        """Percentile of the per-micro-batch wall latency (recent window)."""
        if not self.step_latency_s:
            return 0.0
        return float(np.percentile(np.asarray(self.step_latency_s), pct))

    def samples_per_s(self) -> float:
        return self.samples / self.busy_s if self.busy_s > 0 else 0.0

    def summary(self) -> dict:
        return {
            "requests": self.requests,
            "samples": self.samples,
            "steps": self.steps,
            "swaps": self.swaps,
            "compiles": self.compiles,
            "p50_ms": self.latency_s(50.0) * 1e3,
            "p99_ms": self.latency_s(99.0) * 1e3,
            "samples_per_s": self.samples_per_s(),
        }


class _Request:
    __slots__ = ("rid", "rows", "fog", "lead", "parts_err", "parts_flag", "taken")

    def __init__(self, rid, rows, fog, lead):
        self.rid = rid
        self.rows = rows          # (n, d) f32 numpy
        self.fog = fog            # int fog id or None
        self.lead = lead          # original leading shape to restore
        self.parts_err: list[np.ndarray] = []
        self.parts_flag: list[np.ndarray] = []
        self.taken = 0            # rows already scheduled


class ScoringService:
    """Online scorer over a checkpoint store (see module docstring).

    ``params_like``: a template param tree (e.g. ``autoencoder.init``
    output) fixing the treedef/shapes every published round must match —
    the double-buffer swap relies on it, and it is what keeps the compiled
    program valid across swaps.
    """

    def __init__(
        self,
        store: CheckpointStore,
        params_like: Any,
        *,
        batch_rows: int = 1024,
        tau: float | None = None,
        calibrator: cal.StreamingCalibrator | None = None,
        poll_every: int = 1,
        use_pallas: bool | None = None,
        interpret: bool | None = None,
        fused: bool = True,
    ):
        if (tau is None) and (calibrator is None):
            raise ValueError("need a fixed tau or a StreamingCalibrator")
        self.store = store
        self.batch_rows = int(batch_rows)
        self.tau = None if tau is None else float(tau)
        self.calibrator = calibrator
        self.poll_every = max(1, int(poll_every))
        self.stats = ServiceStats()
        self._queue: list[_Request] = []
        self._done: dict[int, ScoreResult] = {}
        self._next_rid = 0

        params, step = store.restore(params_like)
        # Double buffer: standby starts as a copy of the active tree; every
        # hot-swap restores into the standby slot and flips the pointer.
        self._buffers = [params, jax.tree_util.tree_map(jnp.array, params)]
        self._active = 0
        self._loaded_step = step
        self.d = int(params_like[0]["w"].shape[0])

        stats = self.stats
        kw = dict(use_pallas=use_pallas, interpret=interpret, fused=fused)

        def traced(p, x, t):
            # Runs once per trace: with the fixed micro-batch shape this
            # counts compilations (pinned to 1 after warmup by the tests).
            stats.compiles += 1
            return _score(p, x, t, **kw)

        self._fn = jax.jit(traced)

    # ------------------------------------------------------------------
    # checkpoint watching / hot-swap
    # ------------------------------------------------------------------

    @property
    def params(self) -> Any:
        return self._buffers[self._active]

    @property
    def loaded_step(self) -> int:
        return self._loaded_step

    def poll(self) -> bool:
        """Hot-swap to the newest published round, if any.  Returns True
        when a swap happened.  Same-treedef restore into the standby
        buffer + pointer flip: no recompilation, no torn reads (saves are
        atomic).  A concurrent trainer's retention pass may delete the
        step between ``latest_step`` and the read — treat that as "nothing
        new" and pick the fresher round up on the next poll."""
        step = self.store.latest_step()
        if step is None or step == self._loaded_step:
            return False
        standby = 1 - self._active
        try:
            self._buffers[standby], self._loaded_step = self.store.restore(
                self._buffers[standby], step=step
            )
        except FileNotFoundError:
            return False
        self._active = standby
        self.stats.swaps += 1
        return True

    # ------------------------------------------------------------------
    # request queue / micro-batching
    # ------------------------------------------------------------------

    def submit(self, x: Any, fog: int | None = None) -> int:
        """Queue telemetry of shape (..., d); returns a request id whose
        result :func:`drain` delivers with the leading shape restored."""
        arr = np.asarray(x, np.float32)
        if arr.shape[-1] != self.d:
            raise ValueError(f"expected feature dim {self.d}, got {arr.shape}")
        lead = arr.shape[:-1]
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_Request(rid, arr.reshape(-1, self.d), fog, lead))
        self.stats.requests += 1
        return rid

    def _taus(self) -> np.ndarray | None:
        """Current (n_fog + 1) thresholds, resolved ONCE per micro-batch —
        the reservoir percentile (sort + host sync) must not run per
        request on the scoring hot path."""
        if self.calibrator is None:
            return None
        return np.asarray(self.calibrator.taus())

    def _row_tau(self, req: _Request, taus: np.ndarray | None) -> float:
        if taus is not None:
            return float(taus[req.fog]) if req.fog is not None else float(taus[-1])
        return self.tau

    def step(self) -> int:
        """Score ONE padded micro-batch off the queue; returns the number
        of real rows scored (0 when idle)."""
        if not self._queue:
            return 0
        taus = self._taus()
        batch = np.zeros((self.batch_rows, self.d), np.float32)
        tau = np.full((self.batch_rows,), np.inf, np.float32)
        taken: list[tuple[_Request, int, int, int]] = []  # req, start, n, off
        fill = 0
        while self._queue and fill < self.batch_rows:
            req = self._queue[0]
            n = min(req.rows.shape[0] - req.taken, self.batch_rows - fill)
            batch[fill : fill + n] = req.rows[req.taken : req.taken + n]
            tau[fill : fill + n] = self._row_tau(req, taus)
            taken.append((req, fill, n, req.taken))
            req.taken += n
            fill += n
            if req.taken == req.rows.shape[0]:
                self._queue.pop(0)

        t0 = time.perf_counter()
        err, flag = self._fn(self.params, jnp.asarray(batch), jnp.asarray(tau))
        err, flag = np.asarray(err), np.asarray(flag)
        lat = time.perf_counter() - t0

        for req, start, n, _ in taken:
            req.parts_err.append(err[start : start + n])
            req.parts_flag.append(flag[start : start + n])
            if req.taken == req.rows.shape[0] and sum(
                p.shape[0] for p in req.parts_err
            ) == req.rows.shape[0]:
                self._done[req.rid] = ScoreResult(
                    np.concatenate(req.parts_err).reshape(req.lead),
                    np.concatenate(req.parts_flag).reshape(req.lead),
                )
        self.stats.steps += 1
        self.stats.samples += fill
        self.stats.step_latency_s.append(lat)
        self.stats.busy_s += lat
        if self.stats.steps % self.poll_every == 0:
            self.poll()
        return fill

    def drain(self) -> dict[int, ScoreResult]:
        """Run micro-batches until the queue is empty; hand back (and
        clear) every completed request's :class:`ScoreResult`."""
        while self._queue:
            self.step()
        done, self._done = self._done, {}
        return done

    # ------------------------------------------------------------------
    # streaming calibration feed
    # ------------------------------------------------------------------

    def ingest_validation(
        self, x: Any, fog_id: Any | None = None
    ) -> jax.Array:
        """Score a normal-only validation batch through the SAME fixed-
        shape program (tau=+inf, flags discarded) and feed the errors to
        the calibrator.  ``fog_id`` must broadcast to ``x.shape[:-1]``
        (e.g. a (fleet, 1) column for (fleet, window, d) telemetry).
        Returns the errors, flattened."""
        if self.calibrator is None:
            raise ValueError("service was built without a calibrator")
        x = np.asarray(x, np.float32)
        fid = None
        if fog_id is not None:
            fid = jnp.asarray(
                np.broadcast_to(np.asarray(fog_id, np.int32), x.shape[:-1])
            ).reshape(-1)
        arr = x.reshape(-1, self.d)
        errs = []
        for start in range(0, arr.shape[0], self.batch_rows):
            chunk = arr[start : start + self.batch_rows]
            batch = np.zeros((self.batch_rows, self.d), np.float32)
            batch[: chunk.shape[0]] = chunk
            tau = np.full((self.batch_rows,), np.inf, np.float32)
            err, _ = self._fn(self.params, jnp.asarray(batch), jnp.asarray(tau))
            errs.append(np.asarray(err)[: chunk.shape[0]])
        err = jnp.asarray(np.concatenate(errs))
        self.calibrator.observe(err, fid)
        return err
