"""Micro-batching anomaly-scoring service with train-and-serve hot-swap.

One :class:`ScoringService` turns the trained detector into an online
scorer: telemetry requests queue up, get packed into FIXED-SHAPE
micro-batches, and are scored with the fused kernel path
(``serving/score``).  The padded batch shapes come from a small set of
row *buckets* (e.g. 128/1024): each bucket traces the score program
exactly once, and every micro-batch picks the smallest bucket that covers
the queue depth — so light traffic stops paying the full-batch padding
tax without ever recompiling.

Batch formation is deadline-driven when ``max_wait_s`` is set: a partial
batch is flushed as soon as the OLDEST queued request has waited that
long, instead of holding telemetry hostage until ``batch_rows`` fill up.
``should_flush``/``pump``/``tick`` expose that policy to open-loop
drivers (``repro.loadgen.harness``); ``drain`` still force-flushes.

Hot-swap: the service watches a ``checkpoint.CheckpointStore`` that
``hfl.train`` / ``Engine.run`` publish rounds into.  Parameters are
double-buffered — ``poll()`` restores a newer round into the standby
buffer (same treedef/shapes as the active one, so the compiled program is
reused as-is) and flips the active pointer between micro-batches.  Saves
are atomic (tmp + ``os.replace``), so a poll can never observe a
half-written round.  Polling runs every ``poll_every`` scoring steps AND
— so an idle service still swaps — every ``poll_interval_s`` seconds of
clock time, checked from ``submit``/``step``/``tick``.

Serving weights are f32 by default; ``weight_dtype="int8"`` opt-in keeps
the double-buffered params as per-output-channel symmetric int8
(``serving/score.quantize_params``), dequantised inside the fused score
program (oracle and Pallas paths) — a 4x cut of resident weight bytes
per tenant, parity-tested against f32 in ``tests/test_serving_load.py``.

The ``clock`` is injectable (anything callable returning seconds; an
object with ``advance(dt)`` is advanced by the measured device time of
each micro-batch).  Production uses ``time.monotonic``; the load harness
drives a virtual clock so queueing delay is simulated while device time
stays real.

Thresholds come from a fixed global tau (Eq. 32), or live from a
``serving/calibrate.StreamingCalibrator`` fed by ``ingest_validation`` —
per-fog when requests carry a fog id, global otherwise.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.serving import calibrate as cal
# Import the functions, not the submodule: the package __init__ re-exports
# a function named `score`, which shadows the module attribute.
from repro.serving.score import ScoreResult, quantize_params
from repro.serving.score import score as _score
from repro.serving.score import score_q8 as _score_q8


class ScorePrograms:
    """The compiled score programs, one per row bucket — shareable.

    Owns the jit cache so several services (the tenants of a
    :class:`repro.serving.tenancy.MultiTenantService`) can score through
    the SAME compiled program per bucket: params trees of identical
    treedef/shapes never retrace.  ``compiles`` maps bucket -> trace
    count; with fixed padded shapes every bucket pins to 1 after warmup.
    """

    def __init__(
        self,
        *,
        weight_dtype: str = "f32",
        use_pallas: bool | None = None,
        interpret: bool | None = None,
        fused: bool = True,
    ):
        if weight_dtype not in ("f32", "int8"):
            raise ValueError(f"weight_dtype must be f32|int8, got {weight_dtype!r}")
        self.weight_dtype = weight_dtype
        self.compiles: dict[int, int] = {}
        self._kw = dict(use_pallas=use_pallas, interpret=interpret, fused=fused)
        self._fns: dict[int, Callable] = {}

    def prepare(self, params: Any) -> Any:
        """Convert a restored f32 param tree to the serving representation."""
        if self.weight_dtype == "int8":
            return quantize_params(params)
        return jax.tree_util.tree_map(jnp.asarray, params)

    def fn(self, bucket: int) -> Callable:
        if bucket not in self._fns:
            compiles, kw = self.compiles, self._kw
            score_fn = _score_q8 if self.weight_dtype == "int8" else _score

            def traced(p, x, t):
                # Runs once per trace of this bucket's program: with the
                # fixed padded shape this counts compilations (pinned to
                # one per bucket by the tests).
                compiles[bucket] = compiles.get(bucket, 0) + 1
                return score_fn(p, x, t, **kw)

            self._fns[bucket] = jax.jit(traced)
        return self._fns[bucket]


@dataclasses.dataclass
class ServiceStats:
    requests: int = 0
    samples: int = 0          # real (unpadded) telemetry rows scored
    steps: int = 0            # micro-batches executed
    swaps: int = 0            # hot-swaps applied after the initial load
    partial_flushes: int = 0  # batches flushed below the chosen bucket fill
    dropped: int = 0          # submissions rejected by the max_queue cap
    psi: float = 0.0          # calibration drift signal (last ingest)
    busy_s: float = 0.0       # cumulative scoring wall time (all steps)
    # Trace counts per row bucket — shared with (and written by) the
    # ScorePrograms cache, so under multi-tenancy every tenant sees the
    # same per-bucket counts (one compiled program per bucket, period).
    compiles_by_bucket: dict[int, int] = dataclasses.field(default_factory=dict)
    # Bounded windows so an indefinitely-running service does not grow
    # per-step history without bound; percentiles are over these windows.
    step_latency_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=4096)
    )
    # True per-request latency: submit timestamp -> result completion,
    # i.e. queue wait + batch formation + device time.
    e2e_latency_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=1 << 17)
    )

    @property
    def compiles(self) -> int:
        """Total traces of the score program across all buckets."""
        return sum(self.compiles_by_bucket.values())

    def _pct(self, window, pct: float) -> float:
        if not window:
            return 0.0
        return float(np.percentile(np.asarray(window), pct))

    def step_latency(self, pct: float) -> float:
        """Percentile of the per-micro-batch DEVICE wall latency (recent
        window) — batch execution time, not what a request experiences."""
        return self._pct(self.step_latency_s, pct)

    def e2e_latency(self, pct: float) -> float:
        """Percentile of the per-request end-to-end latency."""
        return self._pct(self.e2e_latency_s, pct)

    def samples_per_s(self) -> float:
        return self.samples / self.busy_s if self.busy_s > 0 else 0.0

    def summary(self) -> dict:
        return {
            "requests": self.requests,
            "samples": self.samples,
            "steps": self.steps,
            "swaps": self.swaps,
            "compiles": self.compiles,
            "compiles_by_bucket": dict(self.compiles_by_bucket),
            "partial_flushes": self.partial_flushes,
            # Device-step percentiles, named for what they are.  The old
            # "p50_ms"/"p99_ms" keys reported these as request latency.
            "step_p50_ms": self.step_latency(50.0) * 1e3,
            "step_p99_ms": self.step_latency(99.0) * 1e3,
            # What a caller actually waits: submit -> completed result.
            "e2e_p50_ms": self.e2e_latency(50.0) * 1e3,
            "e2e_p99_ms": self.e2e_latency(99.0) * 1e3,
            "samples_per_s": self.samples_per_s(),
            "dropped": self.dropped,
            "psi": self.psi,
        }


class _Request:
    __slots__ = (
        "rid", "rows", "fog", "lead", "t_submit", "parts_err", "parts_flag",
        "taken",
    )

    def __init__(self, rid, rows, fog, lead, t_submit):
        self.rid = rid
        self.rows = rows          # (n, d) f32 numpy
        self.fog = fog            # int fog id or None
        self.lead = lead          # original leading shape to restore
        self.t_submit = t_submit  # clock time at submit (e2e latency base)
        self.parts_err: list[np.ndarray] = []
        self.parts_flag: list[np.ndarray] = []
        self.taken = 0            # rows already scheduled


class ScoringService:
    """Online scorer over a checkpoint store (see module docstring).

    ``params_like``: a template param tree (e.g. ``autoencoder.init``
    output) fixing the treedef/shapes every published round must match —
    the double-buffer swap relies on it, and it is what keeps the compiled
    program valid across swaps.

    ``buckets`` (default ``(batch_rows,)``) are the padded micro-batch row
    shapes; ``max_wait_s=None`` keeps the legacy flush-when-asked
    semantics, a float makes ``pump``/``tick`` flush partial batches once
    the oldest request has waited that long.  ``programs`` injects a
    shared :class:`ScorePrograms` (multi-tenancy); by default the service
    owns one.
    """

    def __init__(
        self,
        store: CheckpointStore,
        params_like: Any,
        *,
        batch_rows: int = 1024,
        buckets: tuple[int, ...] | None = None,
        tau: float | None = None,
        calibrator: cal.StreamingCalibrator | None = None,
        poll_every: int = 1,
        poll_interval_s: float | None = None,
        max_wait_s: float | None = None,
        max_queue: int | None = None,
        weight_dtype: str = "f32",
        clock: Callable[[], float] = time.monotonic,
        programs: ScorePrograms | None = None,
        use_pallas: bool | None = None,
        interpret: bool | None = None,
        fused: bool = True,
    ):
        if (tau is None) and (calibrator is None):
            raise ValueError("need a fixed tau or a StreamingCalibrator")
        self.store = store
        self.buckets = tuple(sorted(set(buckets or (int(batch_rows),))))
        if any(b <= 0 for b in self.buckets):
            raise ValueError(f"buckets must be positive, got {self.buckets}")
        self.batch_rows = self.buckets[-1]
        self.tau = None if tau is None else float(tau)
        self.calibrator = calibrator
        self.poll_every = max(1, int(poll_every))
        self.poll_interval_s = (
            None if poll_interval_s is None else float(poll_interval_s)
        )
        self.max_wait_s = None if max_wait_s is None else float(max_wait_s)
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = None if max_queue is None else int(max_queue)
        self._clock = clock
        if programs is None:
            programs = ScorePrograms(
                weight_dtype=weight_dtype, use_pallas=use_pallas,
                interpret=interpret, fused=fused,
            )
        elif programs.weight_dtype != weight_dtype:
            raise ValueError(
                f"shared programs serve {programs.weight_dtype} weights, "
                f"service asked for {weight_dtype}"
            )
        self.programs = programs
        self.stats = ServiceStats(compiles_by_bucket=programs.compiles)
        # deque: batch formation pops the head per request; a plain list's
        # pop(0) is O(n), i.e. quadratic in queue depth under sustained
        # load.
        self._queue: collections.deque[_Request] = collections.deque()
        self._pending_rows = 0
        self._done: dict[int, ScoreResult] = {}
        self._next_rid = 0
        self._last_poll_t = self._clock()

        self._like = params_like
        params, step = store.restore(params_like)
        # Double buffer: every hot-swap prepares the restored round into
        # the standby slot and flips the pointer.
        self._buffers = [programs.prepare(params), programs.prepare(params)]
        self._active = 0
        self._loaded_step = step
        self.d = int(params_like[0]["w"].shape[0])

    # ------------------------------------------------------------------
    # checkpoint watching / hot-swap
    # ------------------------------------------------------------------

    @property
    def params(self) -> Any:
        return self._buffers[self._active]

    @property
    def loaded_step(self) -> int:
        return self._loaded_step

    def poll(self) -> bool:
        """Hot-swap to the newest published round, if any.  Returns True
        when a swap happened.  Same-treedef restore, prepared (f32 or
        int8-quantised) into the standby buffer + pointer flip: no
        recompilation, no torn reads (saves are atomic).  A concurrent
        trainer's retention pass may delete the step between
        ``latest_step`` and the read — treat that as "nothing new" and
        pick the fresher round up on the next poll."""
        self._last_poll_t = self._clock()
        step = self.store.latest_step()
        if step is None or step == self._loaded_step:
            return False
        standby = 1 - self._active
        try:
            raw, step = self.store.restore(self._like, step=step)
        except FileNotFoundError:
            return False
        self._buffers[standby] = self.programs.prepare(raw)
        self._loaded_step = step
        self._active = standby
        self.stats.swaps += 1
        return True

    def _maybe_poll(self, now: float) -> bool:
        """Wall-clock polling path: swap even when no batches run."""
        if (
            self.poll_interval_s is not None
            and now - self._last_poll_t >= self.poll_interval_s
        ):
            return self.poll()
        return False

    # ------------------------------------------------------------------
    # request queue / micro-batching
    # ------------------------------------------------------------------

    def submit(self, x: Any, fog: int | None = None) -> int | None:
        """Queue telemetry of shape (..., d); returns a request id whose
        result :func:`drain` delivers with the leading shape restored.

        With ``max_queue`` set, submissions arriving while that many
        requests are already queued are REJECTED — admission control, so
        sustained overload sheds load at the door instead of growing the
        queue (and its memory, and every queued request's latency) without
        bound.  A rejected submit returns ``None`` and bumps
        ``stats.dropped``; nothing else changes.
        """
        arr = np.asarray(x, np.float32)
        if arr.shape[-1] != self.d:
            raise ValueError(f"expected feature dim {self.d}, got {arr.shape}")
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self.stats.dropped += 1
            return None
        lead = arr.shape[:-1]
        rid = self._next_rid
        self._next_rid += 1
        now = self._clock()
        req = _Request(rid, arr.reshape(-1, self.d), fog, lead, now)
        self._queue.append(req)
        self._pending_rows += req.rows.shape[0]
        self.stats.requests += 1
        self._maybe_poll(now)
        return rid

    def pending_rows(self) -> int:
        """Telemetry rows queued but not yet scheduled into a batch."""
        return self._pending_rows

    def oldest_wait_s(self, now: float | None = None) -> float:
        """How long the oldest queued request has been waiting."""
        if not self._queue:
            return 0.0
        now = self._clock() if now is None else now
        return now - self._queue[0].t_submit

    def next_deadline(self) -> float | None:
        """Clock time at which the oldest queued request's ``max_wait_s``
        expires (None when idle or when deadlines are disabled)."""
        if self.max_wait_s is None or not self._queue:
            return None
        return self._queue[0].t_submit + self.max_wait_s

    def should_flush(self, now: float | None = None) -> bool:
        """Flush policy: a full largest-bucket batch is ready, or the
        oldest queued request has exceeded its ``max_wait_s`` deadline."""
        if self._pending_rows <= 0:
            return False
        if self._pending_rows >= self.buckets[-1]:
            return True
        if self.max_wait_s is None:
            return False
        return self.oldest_wait_s(now) >= self.max_wait_s

    def _pick_bucket(self) -> int:
        """Smallest bucket covering the queue depth (largest when the
        queue exceeds every bucket)."""
        for b in self.buckets:
            if b >= self._pending_rows:
                return b
        return self.buckets[-1]

    def _taus(self) -> np.ndarray | None:
        """Current (n_fog + 1) thresholds, resolved ONCE per micro-batch —
        the reservoir percentile (sort + host sync) must not run per
        request on the scoring hot path."""
        if self.calibrator is None:
            return None
        return np.asarray(self.calibrator.taus())

    def _row_tau(self, req: _Request, taus: np.ndarray | None) -> float:
        if taus is not None:
            return float(taus[req.fog]) if req.fog is not None else float(taus[-1])
        return self.tau

    def step(self) -> int:
        """Score ONE padded micro-batch off the queue; returns the number
        of real rows scored (0 when idle)."""
        if not self._queue:
            return 0
        taus = self._taus()
        bucket = self._pick_bucket()
        batch = np.zeros((bucket, self.d), np.float32)
        tau = np.full((bucket,), np.inf, np.float32)
        taken: list[tuple[_Request, int, int]] = []  # req, start, n
        fill = 0
        while self._queue and fill < bucket:
            req = self._queue[0]
            n = min(req.rows.shape[0] - req.taken, bucket - fill)
            batch[fill : fill + n] = req.rows[req.taken : req.taken + n]
            tau[fill : fill + n] = self._row_tau(req, taus)
            taken.append((req, fill, n))
            req.taken += n
            fill += n
            if req.taken == req.rows.shape[0]:
                self._queue.popleft()
        self._pending_rows -= fill
        if fill < bucket:
            self.stats.partial_flushes += 1

        fn = self.programs.fn(bucket)
        t0 = time.perf_counter()
        err, flag = fn(self.params, jnp.asarray(batch), jnp.asarray(tau))
        err, flag = np.asarray(err), np.asarray(flag)
        lat = time.perf_counter() - t0
        # A virtual clock (load replay) advances by the measured device
        # time, so completion timestamps — and therefore e2e latency —
        # include it on both the real and the simulated clock.
        advance = getattr(self._clock, "advance", None)
        if advance is not None:
            advance(lat)
        t_done = self._clock()

        for req, start, n in taken:
            req.parts_err.append(err[start : start + n])
            req.parts_flag.append(flag[start : start + n])
            if req.taken == req.rows.shape[0] and sum(
                p.shape[0] for p in req.parts_err
            ) == req.rows.shape[0]:
                self._done[req.rid] = ScoreResult(
                    np.concatenate(req.parts_err).reshape(req.lead),
                    np.concatenate(req.parts_flag).reshape(req.lead),
                )
                self.stats.e2e_latency_s.append(t_done - req.t_submit)
        self.stats.steps += 1
        self.stats.samples += fill
        self.stats.step_latency_s.append(lat)
        self.stats.busy_s += lat
        if self.stats.steps % self.poll_every == 0:
            self.poll()
        else:
            self._maybe_poll(t_done)
        return fill

    def pump(self, now: float | None = None) -> int:
        """Run micro-batches while the flush policy says so (full largest
        bucket, or expired ``max_wait_s`` deadline); returns rows scored."""
        total = 0
        while self.should_flush(now):
            total += self.step()
        return total

    def tick(self, now: float | None = None) -> int:
        """Idle heartbeat: wall-clock checkpoint poll + deadline flushes.
        Call this from a serving loop when no requests are arriving."""
        self._maybe_poll(self._clock() if now is None else now)
        return self.pump(now)

    def drain(self) -> dict[int, ScoreResult]:
        """Run micro-batches until the queue is empty; hand back (and
        clear) every completed request's :class:`ScoreResult`."""
        while self._queue:
            self.step()
        done, self._done = self._done, {}
        return done

    # ------------------------------------------------------------------
    # streaming calibration feed
    # ------------------------------------------------------------------

    def ingest_validation(
        self, x: Any, fog_id: Any | None = None
    ) -> jax.Array:
        """Score a normal-only validation batch through the SAME fixed-
        shape program (largest bucket, tau=+inf, flags discarded) and feed
        the errors to the calibrator.  ``fog_id`` must broadcast to
        ``x.shape[:-1]`` (e.g. a (fleet, 1) column for (fleet, window, d)
        telemetry).  Returns the errors, flattened."""
        if self.calibrator is None:
            raise ValueError("service was built without a calibrator")
        x = np.asarray(x, np.float32)
        fid = None
        if fog_id is not None:
            fid = jnp.asarray(
                np.broadcast_to(np.asarray(fog_id, np.int32), x.shape[:-1])
            ).reshape(-1)
        arr = x.reshape(-1, self.d)
        rows = self.batch_rows
        fn = self.programs.fn(rows)
        errs = []
        for start in range(0, arr.shape[0], rows):
            chunk = arr[start : start + rows]
            batch = np.zeros((rows, self.d), np.float32)
            batch[: chunk.shape[0]] = chunk
            tau = np.full((rows,), np.inf, np.float32)
            err, _ = fn(self.params, jnp.asarray(batch), jnp.asarray(tau))
            errs.append(np.asarray(err)[: chunk.shape[0]])
        err = jnp.asarray(np.concatenate(errs))
        self.calibrator.observe(err, fid)
        # Surface the calibrator's drift signal where operators look.
        self.stats.psi = self.calibrator.psi()
        return err
