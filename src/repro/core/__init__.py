from repro.core import (  # noqa: F401
    aggregation,
    anomaly,
    association,
    channel,
    compression,
    cooperation,
    energy,
    flat_fl,
    hfl,
    participation,
    topology,
)
