"""Fault injection for hostile acoustic deployments (ROADMAP scenario
diversity: lossy links, crashing sensors, Byzantine clients).

:class:`FaultConfig` is a registered pytree whose knobs are traceable
sweep LEAVES — ``Engine.sweep`` grids attack fraction x erasure rate x
trim fraction exactly like the physics knobs.  The static aux data is the
Byzantine behaviour name plus ``active``, the static on/off predicate
(mirroring ``CompressorConfig.sparse``): it is derived from concrete
probabilities, pinned through flatten/unflatten so code can branch
Python-side while the probabilities themselves are tracers, and — the
part that matters for sweeps — can be pinned ``True`` on zero-valued
cells so a robustness grid with a clean corner still co-batches into ONE
shape-class.

Semantics (threaded through all four round-loop families):

* **Crash** — a per-round Bernoulli(``crash_prob``) draw removes a client
  exactly like a dead battery: no training, no transmission, no energy.
* **Byzantine** — the first ``floor(byz_frac * N)`` clients are
  adversarial (a deterministic, traceable mask: the fraction can sweep
  without re-tracing).  Their raw deltas are corrupted BEFORE
  compression: ``sign_flip`` sends ``-byz_scale * delta``, ``gauss``
  sends pure noise ``byz_scale * N(0, I)``, ``inflate`` sends
  ``byz_scale * delta``.
* **Erasure** — applied AFTER SNR feasibility: a feasible, transmitted
  packet is lost with probability ``erasure_prob``.  The transmit energy
  is still charged (real acoustics: the modem spent the joules whether or
  not the fog decoded the frame) and the client's error-feedback buffer
  still advances (the sender cannot know); only the aggregation weight
  vanishes.  Erasures are surfaced per round as ``n_erased``.
* **Adaptive collusion** — ``byz_mode="adaptive"`` is an
  a-little-is-enough style moving adversary: the colluders observe the
  PREVIOUS round's global delta (carried in the round state) and all
  submit the same crafted update ``mu - byz_scale * sigma * dirn``,
  where ``mu``/``sigma`` are the honest batch statistics and ``dirn``
  opposes the model's previous movement.  With ``byz_scale`` around 3
  the crafted point hugs the trimmed-mean band edge: a trim fraction
  covering ``byz_frac`` cuts the colluder clump, while the plain mean
  takes a compounding push and collapses — the contract
  ``benchmarks/check_drift_bench.py`` gates.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

BYZ_MODES = ("none", "sign_flip", "gauss", "inflate", "adaptive")


def _concrete(x: Any) -> bool:
    return isinstance(x, (int, float))


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Fault-injection knobs.  The probabilities and the attack scale are
    pytree LEAVES (traceable/stackable); ``byz_mode`` and the derived
    ``active`` predicate are static aux data."""

    erasure_prob: float | Any = 0.0   # P(uplink packet lost | feasible)
    crash_prob: float | Any = 0.0     # P(client crashes this round)
    byz_frac: float | Any = 0.0       # fraction of adversarial clients
    byz_scale: float | Any = 1.0      # attack magnitude (mode-dependent)
    byz_mode: str = "none"            # none | sign_flip | gauss | inflate
    active: bool | None = None        # static on/off predicate (None = derive)

    def __post_init__(self) -> None:
        if self.byz_mode not in BYZ_MODES:
            raise ValueError(
                f"byz_mode must be one of {BYZ_MODES}, got {self.byz_mode!r}"
            )
        # Range checks only on CONCRETE values: traced/stacked sweep
        # leaves pass through (``__post_init__`` re-runs on every pytree
        # unflatten, including inside jit).
        for name in ("erasure_prob", "crash_prob", "byz_frac"):
            v = getattr(self, name)
            if _concrete(v) and not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"{name} must be a probability in [0, 1], got {v!r}"
                )

    def replace(self, **kw: Any) -> "FaultConfig":
        # Changing a probability leaf re-derives the static predicate
        # unless the caller pins it explicitly (CompressorConfig.sparse
        # pattern — a pytree round-trip pins ``active`` concrete).
        if "active" not in kw and any(
            f in kw for f in ("erasure_prob", "crash_prob", "byz_frac",
                              "byz_mode")
        ):
            kw["active"] = None
        return dataclasses.replace(self, **kw)

    @property
    def is_active(self) -> bool:
        """STATIC fault-layer switch.  A pinned value wins; otherwise any
        non-concrete (traced) probability or any concrete nonzero one
        turns the layer on.  When False, round loops take the exact
        legacy path — same key splits, zero extra ops."""
        if self.active is not None:
            return self.active
        if self.byz_mode != "none":
            return True
        probs = (self.erasure_prob, self.crash_prob, self.byz_frac)
        return any((not _concrete(p)) or p > 0.0 for p in probs)


_FAULT_LEAF_FIELDS = ("erasure_prob", "crash_prob", "byz_frac", "byz_scale")


def _fault_flatten(c: FaultConfig):
    return (
        tuple(getattr(c, f) for f in _FAULT_LEAF_FIELDS),
        (c.byz_mode, c.is_active),
    )


def _fault_unflatten(aux, children) -> FaultConfig:
    kw = dict(zip(_FAULT_LEAF_FIELDS, children))
    return FaultConfig(byz_mode=aux[0], active=aux[1], **kw)


jax.tree_util.register_pytree_node(FaultConfig, _fault_flatten, _fault_unflatten)


def byzantine_mask(n: int, byz_frac: float | jax.Array) -> jax.Array:
    """(N,) bool — the first ``floor(byz_frac * n)`` clients are Byzantine.

    Deterministic and traceable in ``byz_frac``: the client identities are
    fixed (adversaries do not rotate), only the fraction sweeps, so a
    robustness grid batches without re-tracing.
    """
    frac = jnp.asarray(byz_frac, jnp.float32)
    return (jnp.arange(n, dtype=jnp.float32) + 0.5) / n < frac


def corrupt_deltas(
    key: jax.Array,
    deltas: jax.Array,          # (N, d) raw flat client updates
    cfg: FaultConfig,
    prev_delta: jax.Array | None = None,   # (d,) last global delta
) -> jax.Array:
    """Inject the configured Byzantine behaviour into the delta stream
    (BEFORE compression — the attacker controls what leaves the sensor).

    ``byz_mode`` branches statically; the mask/scale are traceable.
    ``prev_delta`` feeds the ``adaptive`` colluders; round loops carry it
    in their state (zeros before the first merge, where ``sign(mu)`` is
    the fallback direction).
    """
    if cfg.byz_mode == "none":
        return deltas
    mask = byzantine_mask(deltas.shape[0], cfg.byz_frac)
    scale = jnp.asarray(cfg.byz_scale, jnp.float32)
    if cfg.byz_mode == "sign_flip":
        attacked = -scale * deltas
    elif cfg.byz_mode == "gauss":
        attacked = scale * jax.random.normal(key, deltas.shape, deltas.dtype)
    elif cfg.byz_mode == "adaptive":
        if prev_delta is None:
            prev_delta = jnp.zeros(deltas.shape[-1], deltas.dtype)
        mu = jnp.mean(deltas, axis=0)
        sigma = jnp.std(deltas, axis=0)
        dirn = jnp.where(prev_delta == 0.0, jnp.sign(mu), jnp.sign(prev_delta))
        attacked = jnp.broadcast_to(
            mu - scale * sigma * dirn, deltas.shape
        )
    else:  # inflate
        attacked = scale * deltas
    return jnp.where(mask[:, None], attacked, deltas)


def draw_crash(
    key: jax.Array, n: int, crash_prob: float | jax.Array
) -> jax.Array:
    """(N,) bool per-round crash/straggler mask (Bernoulli per client)."""
    return jax.random.uniform(key, (n,)) < jnp.asarray(crash_prob, jnp.float32)


def draw_erasure(
    key: jax.Array, n: int, erasure_prob: float | jax.Array
) -> jax.Array:
    """(N,) bool packet-erasure mask, applied after SNR feasibility."""
    return jax.random.uniform(key, (n,)) < jnp.asarray(
        erasure_prob, jnp.float32
    )


def nonfinite_rows(deltas: jax.Array) -> jax.Array:
    """(N,) bool — rows carrying any NaN/Inf coordinate (the graceful-
    degradation counter; the zeroing itself lives in
    ``aggregation.compress_and_accumulate`` so it protects the global
    model even with the fault layer off)."""
    return ~jnp.all(jnp.isfinite(deltas), axis=-1)
