"""SNR-driven energy model (paper Sec. III-D, Eqs. 5-8) and battery dynamics.

All functions are pure JAX and broadcast over link arrays.  Infeasible links
(SL_min > SL_max) get ``inf`` energy so downstream argmin/feasibility masks
compose naturally.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import channel as ch


@dataclasses.dataclass(frozen=True)
class EnergyParams:
    """Energy parameters (paper Table II baseline).

    A pytree with every field a leaf (all knobs are pure arithmetic
    downstream), so energy-model sweeps batch along a config axis exactly
    like :class:`repro.core.channel.ChannelParams`.
    """

    eta_ea: float = 0.25          # electro-acoustic efficiency
    p_circuit_tx_w: float = 0.05  # transmit circuit power (W)
    p_circuit_rx_w: float = 0.03  # receive circuit power (W)
    e_init_j: float = 500.0       # initial per-sensor battery (J)
    e_min_j: float = 0.0          # minimum battery reserve (Eq. 25)
    eps_op_j: float = 1e-9        # energy per FLOP for local compute (Sec. III-D)

    def replace(self, **kw: Any) -> "EnergyParams":
        return dataclasses.replace(self, **kw)


_ENERGY_FIELDS = tuple(f.name for f in dataclasses.fields(EnergyParams))

jax.tree_util.register_pytree_node(
    EnergyParams,
    lambda c: (tuple(getattr(c, f) for f in _ENERGY_FIELDS), None),
    lambda _, ch_: EnergyParams(**dict(zip(_ENERGY_FIELDS, ch_))),
)


def acoustic_power_w(sl_min_db: jax.Array) -> jax.Array:
    """Acoustic transmit power P_ac from source level (Eq. 7)."""
    coef = 4.0 * jnp.pi * ch.P_REF_PA**2 / (ch.RHO_WATER * ch.SOUND_SPEED_M_S)
    return coef * 10.0 ** (sl_min_db / 10.0)


def electrical_tx_power_w(
    sl_min_db: jax.Array, eparams: EnergyParams
) -> jax.Array:
    """Electrical transmit power P_tx = P_ac / eta_ea (Sec. III-D)."""
    return acoustic_power_w(sl_min_db) / eparams.eta_ea


def tx_energy_j(
    bits: jax.Array,
    dist_m: jax.Array,
    cparams: ch.ChannelParams,
    eparams: EnergyParams,
) -> jax.Array:
    """Energy to transmit ``bits`` over distance ``dist_m`` (Eq. 8).

    Power-controls to gamma_tgt; infeasible links return ``inf``.
    """
    sl_min = ch.min_source_level_db(dist_m, cparams)
    p_tx = electrical_tx_power_w(sl_min, eparams)
    rate = ch.shannon_rate_bps(cparams)
    e = (p_tx + eparams.p_circuit_tx_w) * jnp.asarray(bits, jnp.float32) / rate
    return jnp.where(sl_min <= cparams.sl_max_db, e, jnp.inf)


def rx_energy_j(
    bits: jax.Array, cparams: ch.ChannelParams, eparams: EnergyParams
) -> jax.Array:
    """Receive energy E_rx = P_c,rx * L / R (Sec. III-D)."""
    rate = ch.shannon_rate_bps(cparams)
    return eparams.p_circuit_rx_w * jnp.asarray(bits, jnp.float32) / rate


def compute_energy_j(flops: jax.Array, eparams: EnergyParams) -> jax.Array:
    """Local-training compute energy E_comp = eps_op * Phi (Sec. III-D)."""
    return eparams.eps_op_j * jnp.asarray(flops, jnp.float32)


def link_latency_s(
    bits: jax.Array, dist_m: jax.Array, cparams: ch.ChannelParams
) -> jax.Array:
    """Per-link latency tau = d/c_s + L/R (Eq. 21 inner term)."""
    rate = ch.shannon_rate_bps(cparams)
    return ch.propagation_delay_s(dist_m) + jnp.asarray(bits, jnp.float32) / rate


def battery_step(
    residual_j: jax.Array,
    spent_j: jax.Array,
    eparams: EnergyParams,
) -> tuple[jax.Array, jax.Array]:
    """One round of battery depletion (Sec. IV-C).

    Returns (new_residual, alive_mask) where ``alive`` enforces the minimum
    reserve constraint (Eq. 25): a sensor whose spend would dip below
    ``e_min_j`` is marked dead and its residual is floored.
    """
    new = residual_j - spent_j
    alive = new >= eparams.e_min_j
    return jnp.maximum(new, eparams.e_min_j), alive


def autoencoder_flops(d_in: int, hidden: tuple[int, ...], n_samples: int, epochs: int) -> int:
    """FLOPs for E epochs of AE training (fwd+bwd ~= 3x fwd matmul cost).

    The symmetric AE maps d_in -> hidden... -> d_in, so the output layer
    back to ``d_in`` is part of the forward cost.
    """
    dims = (d_in, *hidden, d_in)
    mm = sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    return 3 * mm * n_samples * epochs
