"""Event-driven asynchronous federated rounds with staleness-aware merging.

The fourth round-loop family next to ``core/hfl.py`` (synchronous
hierarchical), ``core/flat_fl.py`` (star topology), and ``core/mesh_fl.py``
(TPU-mesh pods).  The paper's own physics motivates it: Eq. 21 latency
spreads widely across acoustic links, so a synchronous round is paced by
the *slowest* feasible path while fast near-gateway clusters idle.  Here
the loop is event-driven instead — each client's update travels for its
own Eq. 21 path latency, a bounded buffer triggers global aggregation when
``buffer_k`` updates land (or a timeout tick fires), and late updates are
merged with staleness-discounted weights ``w(tau) = (1 + tau)^(-alpha)``
where ``tau`` counts global model versions the update missed.

Simulation model (one jittable scan, vmappable over the Engine's
``(seed, deployment)`` trial grid):

* **Launch** — an idle, round-active client pulls the current global
  params, runs its E-epoch local phase through the SAME fused local-train
  solver as the synchronous loops (:func:`repro.optim.sgd.make_client_solver`),
  compresses through the SAME fused compress-and-aggregate kernel
  (:func:`repro.core.aggregation.compress_and_accumulate` with one segment
  per client, so the error-feedback state is bit-compatible), and puts the
  reconstruction "on the wire": it arrives ``compute + uplink latency``
  simulated seconds later.  Uplink energy and compute energy are charged
  to the battery at launch.
* **Fog tick** — the scan step fires when ``fog_k`` in-flight updates have
  landed (or ``fog_timeout_s`` passes): arrivals fold into persistent
  per-fog accumulators, discounted by their staleness at arrival.  This is
  the fog-local cadence.
* **Global merge** — when the number of buffered updates reaches
  ``buffer_k`` (clamped to what can still arrive) or ``timeout_s`` passes
  since the last merge, fog means are cooperatively mixed (Eq. 15) and
  aggregated at the gateway (Eq. 16, FedAdam optional), the accumulators
  drain, and the global version increments.  Fog cadence (``fog_k``) and
  global cadence (``buffer_k``) are decoupled knobs.

**Sync limit.**  With ``fog_k`` and ``buffer_k`` at the fleet size, no
staleness discount (``alpha = 0``) and infinite timeouts, every event
waits for all launched updates, merges them undiscounted, and relaunches
everyone from the new model — exactly Algorithm 1.  :func:`sync_limit`
builds that config and ``tests/test_async_fl.py`` pins the equivalence
against ``hfl.train`` to float tolerance.

All async knobs are traceable pytree leaves (``AsyncFLConfig`` is a
registered pytree like ``HFLConfig``), so ``Engine.sweep`` grids
``alpha`` x ``buffer_k`` x timeout cells in ONE compiled program per
shape-class, exactly like today's energy/compression sweeps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import aggregation as agg
from repro.core import association as assoc
from repro.core import compression as comp
from repro.core import cooperation as coop
from repro.core import energy as en
from repro.core import faults as flt
from repro.core import hfl
from repro.core import topology as topo
from repro.data.synthetic import SensorDataset
from repro.kernels import ops as kops
from repro.optim import server as srv

Params = Any
LossFn = Callable[[Params, jax.Array], jax.Array]

# "Never" for the timeout knobs: a finite sentinel keeps every arithmetic
# path (stacking, subtraction) inf-free while exceeding any simulated time
# a bounded scan can reach.
NEVER_S = 1e30


@dataclasses.dataclass(frozen=True)
class AsyncFLConfig:
    """Async round-family configuration — a pytree split into swept vs
    static, mirroring :class:`repro.core.hfl.HFLConfig`.

    LEAVES (traceable, stackable along a config axis — see
    ``Engine.sweep``): ``buffer_k``, ``fog_k``, ``alpha``, ``timeout_s``,
    ``fog_timeout_s``, ``tau_max`` plus everything swept inside the nested
    ``base`` config (lr, physics, ``rho_s``, faults, ...).  ``n_events`` —
    the scan length — is static aux data: configs that differ there belong
    to different sweep shape-classes.

    ``base.rounds`` is ignored by this family; ``n_events`` fog ticks are
    simulated instead (in the sync limit one tick == one round).

    Staleness policy: arrivals are discounted by ``(1 + tau)^(-alpha)``,
    and — the clipping policy on top — any update staler than ``tau_max``
    global versions is DROPPED (weight 0) instead of merely discounted.
    The default ``tau_max = NEVER_S`` keeps every update (the pure
    discount path, numerically unchanged).
    """

    base: hfl.HFLConfig = hfl.HFLConfig()
    n_events: int = 40                   # fog ticks to simulate (static)
    buffer_k: float | Any = 8.0          # global merge after this many updates
    fog_k: float | Any = 1.0             # fog tick fires when this many land
    alpha: float | Any = 0.5             # staleness exponent in (1+tau)^(-alpha)
    timeout_s: float | Any = NEVER_S     # global merge timeout (sim seconds)
    fog_timeout_s: float | Any = NEVER_S  # fog tick timeout (sim seconds)
    tau_max: float | Any = NEVER_S       # drop updates staler than this
    # Arrival clock (a LEAF, so it sweeps/stacks like the other knobs).
    # Scalar: extra seconds added to the physics clock (compute + Eq. 21
    # uplink latency); the 0.0 default is bit-identical to the legacy
    # clock.  A (N,) array REPLACES the physics clock with replayed
    # per-client launch->arrival delays — the hook that drives the loop
    # from a recorded :class:`repro.loadgen.traces.ArrivalTrace` instead
    # of the synthetic latency model (energy stays physics-based either
    # way).  The branch is on the leaf's RANK, which is static under jit.
    arrival_delay_s: float | Any = 0.0

    def replace(self, **kw: Any) -> "AsyncFLConfig":
        return dataclasses.replace(self, **kw)


_ASYNC_CHILD_FIELDS = (
    "base", "buffer_k", "fog_k", "alpha", "timeout_s", "fog_timeout_s",
    "tau_max", "arrival_delay_s",
)
_ASYNC_AUX_FIELDS = ("n_events",)


def _async_cfg_flatten(c: AsyncFLConfig):
    return (
        tuple(getattr(c, f) for f in _ASYNC_CHILD_FIELDS),
        tuple(getattr(c, f) for f in _ASYNC_AUX_FIELDS),
    )


def _async_cfg_unflatten(aux, children) -> AsyncFLConfig:
    kw = dict(zip(_ASYNC_CHILD_FIELDS, children))
    kw.update(zip(_ASYNC_AUX_FIELDS, aux))
    return AsyncFLConfig(**kw)


jax.tree_util.register_pytree_node(
    AsyncFLConfig, _async_cfg_flatten, _async_cfg_unflatten
)


def sync_limit(base: hfl.HFLConfig, n_events: int | None = None) -> AsyncFLConfig:
    """The synchronous limiting case of the async family.

    Fog tick and merge buffer both wait for the whole fleet, the
    staleness discount is off, timeouts never fire: every event is one
    Algorithm 1 round (pinned against ``hfl.train`` in the tests).
    """
    n = float(base.deployment.n_sensors)
    return AsyncFLConfig(
        base=base,
        n_events=base.rounds if n_events is None else n_events,
        buffer_k=n,
        fog_k=n,
        alpha=0.0,
        timeout_s=NEVER_S,
        fog_timeout_s=NEVER_S,
    )


class AsyncEventMetrics(NamedTuple):
    """Per-fog-tick record.  The first block mirrors
    :class:`repro.core.hfl.RoundMetrics` (and matches it term-for-term in
    the sync limit); the second block is async-specific."""

    loss: jax.Array           # mean loss over this tick's launches
    e_s2f: jax.Array          # Eq. 17 — charged at launch
    e_f2f: jax.Array          # Eq. 18 — charged at merge
    e_f2g: jax.Array          # Eq. 19 — charged at merge
    e_total: jax.Array        # Eq. 20
    latency_s: jax.Array      # Eq. 21-style per-tick latency metric
    participation: jax.Array
    coop_links: jax.Array     # active fog-to-fog exchanges (merge ticks)
    battery_min: jax.Array
    n_nonfinite: jax.Array    # launched deltas carrying NaN/Inf (zeroed)
    n_erased: jax.Array       # arrivals lost to packet erasure
    global_finite: jax.Array  # bool — global params finite after this tick
    # --- async-specific ---
    merged: jax.Array         # bool — did the gateway merge this tick
    n_launched: jax.Array     # clients that started a job this tick
    n_arrived: jax.Array      # updates that landed this tick
    staleness: jax.Array      # mean tau over this tick's arrivals
    event_s: jax.Array        # simulated duration of this tick
    t_sim: jax.Array          # simulated clock after this tick


class AsyncState(NamedTuple):
    # Shared with the synchronous families:
    params: Params            # global model theta^(v)
    err: jax.Array            # (N, d) error-feedback buffers
    battery: jax.Array        # (N,) residual energy
    dep: topo.Deployment
    key: jax.Array
    server: srv.ServerOptState
    # Event-driven extensions:
    version: jax.Array        # () int32 — global model version v
    t_now: jax.Array          # () f32 — simulated clock
    t_last_merge: jax.Array   # () f32
    pending: jax.Array        # () int32 — updates buffered since last merge
    busy: jax.Array           # (N,) bool — update in flight
    inflight: jax.Array       # (N, d) — compressed reconstruction on the wire
    arrive_t: jax.Array       # (N,) f32 — absolute arrival time (NEVER_S idle)
    base_version: jax.Array   # (N,) int32 — version the job trained from
    uplink_lat: jax.Array     # (N,) f32 — Eq. 21 uplink latency at launch
    launch_fog: jax.Array     # (N,) int32 — fog the update was sent to
    fog_sum: jax.Array        # (M, d) — staleness-weighted delta sums
    fog_w: jax.Array          # (M,) — buffered weight per fog
    fog_n: jax.Array          # (M,) int32 — buffered update count per fog
    # Robust-aggregation buffers (``base.robust != "mean"`` only; degenerate
    # (N, 0) / untouched otherwise): per-CLIENT weighted sums so the merge
    # can reduce addressable per-client means with the trimmed/median
    # statistic instead of the pre-summed fog buffers.
    cli_sum: jax.Array        # (N, d_or_0) — weighted arrival sums
    cli_w: jax.Array          # (N,) — accumulated arrival weight
    cli_fog: jax.Array        # (N,) int32 — fog of the latest arrival
    # Dynamic-world carry (zeros when drift/adaptive attack are off):
    assoc_fog: jax.Array      # (N,) int32 — frozen sensor->fog assignment
    assoc_ok: jax.Array       # (N,) bool — feasible at assignment time
    tick: jax.Array           # () int32 — fog-tick counter
    prev_delta: jax.Array     # (d,) last global delta (adaptive colluders)


def init_state(
    key: jax.Array, params: Params, acfg: AsyncFLConfig
) -> AsyncState:
    """Mirror of ``hfl.init_state`` (same key splits, so the sync limit is
    deployment-for-deployment identical) plus the event-driven extensions."""
    cfg = acfg.base
    kd, kr = jax.random.split(key)
    dep = topo.sample_deployment(kd, cfg.deployment)
    flat, _ = ravel_pytree(params)
    n = cfg.deployment.n_sensors
    m = cfg.deployment.n_fog
    d = flat.shape[0]
    return AsyncState(
        params=params,
        err=jnp.zeros((n, d), flat.dtype),
        battery=jnp.full((n,), cfg.energy.e_init_j),
        dep=dep,
        key=kr,
        server=srv.init_state(d),
        version=jnp.zeros((), jnp.int32),
        t_now=jnp.zeros(()),
        t_last_merge=jnp.zeros(()),
        pending=jnp.zeros((), jnp.int32),
        busy=jnp.zeros((n,), bool),
        inflight=jnp.zeros((n, d), flat.dtype),
        arrive_t=jnp.full((n,), NEVER_S),
        base_version=jnp.zeros((n,), jnp.int32),
        uplink_lat=jnp.zeros((n,)),
        launch_fog=jnp.zeros((n,), jnp.int32),
        fog_sum=jnp.zeros((m, d), flat.dtype),
        fog_w=jnp.zeros((m,)),
        fog_n=jnp.zeros((m,), jnp.int32),
        cli_sum=jnp.zeros(
            (n, d if cfg.robust != "mean" else 0), flat.dtype
        ),
        cli_w=jnp.zeros((n,)),
        cli_fog=jnp.zeros((n,), jnp.int32),
        assoc_fog=jnp.zeros((n,), jnp.int32),
        assoc_ok=jnp.zeros((n,), bool),
        tick=jnp.int32(0),
        prev_delta=jnp.zeros((d,), flat.dtype),
    )


def make_event_fn(
    loss_fn: LossFn,
    ds: SensorDataset,
    acfg: AsyncFLConfig,
) -> Callable[[AsyncState, None], tuple[AsyncState, AsyncEventMetrics]]:
    """Build the jittable single-event function (one fog tick)."""
    cfg = acfg.base
    n_fog = cfg.deployment.n_fog
    clients_fn = hfl._client_train_fn(loss_fn, cfg)
    if cfg.robust not in ("mean", "trimmed", "median"):
        raise ValueError(
            f"robust must be 'mean', 'trimmed' or 'median', got "
            f"{cfg.robust!r}"
        )
    fl = cfg.faults
    fault_on = fl.is_active       # STATIC: off => exact legacy event
    dr = cfg.drift
    drift_on = dr.is_active       # STATIC: off => exact legacy event
    adaptive = fault_on and fl.byz_mode == "adaptive"

    def event_fn(state: AsyncState, _) -> tuple[AsyncState, AsyncEventMetrics]:
        if fault_on:
            key, k_mob, k_train, k_byz, k_crash, k_erase = jax.random.split(
                state.key, 6
            )
        else:
            key, k_mob, k_train = jax.random.split(state.key, 3)
        dep = state.dep
        if cfg.fog_mobility:
            dep = topo.gauss_markov_step(k_mob, dep, cfg.deployment)
        if drift_on:
            dep = topo.current_advection_step(
                dep, cfg.deployment, dr.sensor_current_m_s
            )

        # --- association: who could launch / deliver this tick -----------
        if drift_on:
            # Re-association cadence counts fog ticks (the async round
            # analogue); tick 0 always refreshes.
            t_f = state.tick.astype(jnp.float32)
            cadence = jnp.maximum(
                jnp.asarray(dr.reassoc_every, jnp.float32), 1.0
            )
            refresh = jnp.mod(t_f, cadence) < 0.5
            fresh = assoc.nearest_feasible_fog(dep, cfg.channel)
            assoc_fog = jnp.where(refresh, fresh.fog_id, state.assoc_fog)
            assoc_ok = jnp.where(refresh, fresh.participates, state.assoc_ok)
            fa = assoc.assigned_fog_association(
                dep, cfg.channel, assoc_fog, assoc_ok
            )
        else:
            assoc_fog, assoc_ok = state.assoc_fog, state.assoc_ok
            fa = assoc.nearest_feasible_fog(dep, cfg.channel)
        alive = state.battery > cfg.energy.e_min_j
        active = fa.participates & alive
        if fault_on:
            # A crashed client cannot launch this tick; packets it already
            # has on the wire were sent before the crash and still travel.
            active = active & ~flt.draw_crash(
                k_crash, alive.shape[0], fl.crash_prob
            )
        active_f = active.astype(jnp.float32)

        flat0, unravel = ravel_pytree(state.params)
        d = flat0.shape[0]
        n = ds.train.shape[0]
        keys = jax.random.split(k_train, n)

        # --- launch: idle active clients pull theta^(v) and train --------
        # The fused kernels run for EVERY client (fixed shapes under jit);
        # non-launchers are masked out below, exactly like the inactive-
        # client masking of the synchronous loops.
        launch = active & ~state.busy
        launch_f = launch.astype(jnp.float32)
        train = ds.train
        if drift_on:
            train = train * (1.0 + dr.covariate_shift * t_f)
        deltas, losses = clients_fn(state.params, train, keys)
        if fault_on:
            # Byzantine corruption hits the raw delta before compression —
            # the attacker controls what leaves the sensor.
            deltas = flt.corrupt_deltas(
                k_byz, deltas, fl, prev_delta=state.prev_delta
            )
        n_nonfinite = jnp.sum(
            (launch & flt.nonfinite_rows(deltas)).astype(jnp.int32)
        )
        # One segment per client keeps the same fused compress kernel while
        # leaving each compressed reconstruction addressable for its own
        # in-flight journey (weights fold in at MERGE time, when the
        # staleness discount is known).  ``client_chunk`` bounds the
        # per-chunk kernel footprint exactly as in the synchronous loops.
        recon, new_err = agg.client_compress(
            deltas, state.err, cfg.compressor, chunk=cfg.client_chunk,
        )
        new_err = jnp.where(launch[:, None], new_err, state.err)
        inflight = jnp.where(launch[:, None], recon, state.inflight)

        # Transmission: the update lands after compute + uplink latency.
        l_u = comp.payload_bits(d, cfg.compressor)
        l_full = 32.0 * d
        flops = en.autoencoder_flops(
            ds.train.shape[-1], (16, 8, 16), ds.train.shape[1],
            cfg.local_epochs,
        )
        lat_comp = jnp.float32(flops) / cfg.compute_rate_flops
        up_lat = en.link_latency_s(l_u, fa.dist_m, cfg.channel)
        delay = jnp.asarray(acfg.arrival_delay_s, jnp.float32)
        if delay.ndim > 0:
            # Trace replay: the recorded delay IS the end-to-end
            # launch->arrival time (compute included).
            up_eff = jnp.broadcast_to(delay, (n,))
            arr_t_new = state.t_now + up_eff
        else:
            # Physics clock (+0.0 scalar jitter = exact legacy numerics).
            up_eff = up_lat
            arr_t_new = state.t_now + lat_comp + up_lat + delay
        arrive_t = jnp.where(launch, arr_t_new, state.arrive_t)
        uplink_lat = jnp.where(launch, up_eff, state.uplink_lat)
        base_version = jnp.where(launch, state.version, state.base_version)
        launch_fog = jnp.where(launch, fa.fog_id, state.launch_fog)
        busy = state.busy | launch

        # Uplink + compute energy are spent at launch.
        e_up = en.tx_energy_j(l_u, fa.dist_m, cfg.channel, cfg.energy)
        e_up = jnp.where(launch, e_up, 0.0)
        e_comp = en.compute_energy_j(jnp.float32(flops), cfg.energy)
        spent = e_up + jnp.where(launch, e_comp, 0.0)
        battery, _ = en.battery_step(state.battery, spent, cfg.energy)

        # --- fog tick trigger: fog_k-th arrival or the fog timeout -------
        busy_t = jnp.where(busy, arrive_t, NEVER_S)
        n_busy = jnp.sum(busy.astype(jnp.int32))
        k_fog = jnp.clip(
            jnp.asarray(acfg.fog_k, jnp.float32),
            1.0,
            jnp.maximum(n_busy, 1).astype(jnp.float32),
        ).astype(jnp.int32)
        t_kth = jnp.take(jnp.sort(busy_t), k_fog - 1)
        t_tick = jnp.minimum(t_kth, state.t_now + acfg.fog_timeout_s)
        # Dead network (nothing in flight): the clock holds.
        t_tick = jnp.where(n_busy > 0, t_tick, state.t_now)
        # Merge propagation may have advanced the clock past a pending
        # arrival; time never runs backwards.
        t_tick = jnp.maximum(t_tick, state.t_now)

        arrived = busy & (arrive_t <= t_tick)
        # Erasure strikes at DELIVERY: the packet travelled (launch energy
        # was already charged, the EF buffer already advanced) but the fog
        # never decodes it — the client slot frees up, nothing folds in.
        if fault_on:
            lost = arrived & flt.draw_erasure(k_erase, n, fl.erasure_prob)
        else:
            lost = jnp.zeros_like(arrived)
        ok = arrived & ~lost
        ok_f = ok.astype(jnp.float32)
        n_arrived = jnp.sum(ok.astype(jnp.int32))

        # --- fold arrivals into the fog accumulators ---------------------
        # Staleness tau = versions the global model moved since the job's
        # anchor; w(tau) = (1 + tau)^(-alpha) discounts late updates, and
        # the clipping policy drops anything staler than tau_max outright.
        tau = (state.version - base_version).astype(jnp.float32)
        w_tau = (1.0 + tau) ** (-jnp.asarray(acfg.alpha, jnp.float32))
        w_tau = jnp.where(
            tau <= jnp.asarray(acfg.tau_max, jnp.float32), w_tau, 0.0
        )
        w = ds.n_samples * w_tau * ok_f
        fog_sum = state.fog_sum + jax.ops.segment_sum(
            inflight * w[:, None], launch_fog, num_segments=n_fog
        )
        fog_w = state.fog_w + jax.ops.segment_sum(
            w, launch_fog, num_segments=n_fog
        )
        fog_n = state.fog_n + jax.ops.segment_sum(
            ok.astype(jnp.int32), launch_fog, num_segments=n_fog
        )
        if cfg.robust == "mean":
            cli_sum, cli_w, cli_fog = state.cli_sum, state.cli_w, state.cli_fog
        else:
            # Per-client accumulation (w is zero for non-arrivals, so this
            # is a masked add); summing these over a fog reproduces fog_sum,
            # which is what makes trim 0 the weighted-mean equivalence.
            cli_sum = state.cli_sum + inflight * w[:, None]
            cli_w = state.cli_w + w
            cli_fog = jnp.where(ok, launch_fog, state.cli_fog)
        pending = state.pending + n_arrived
        busy = busy & ~arrived
        arrive_t = jnp.where(arrived, NEVER_S, arrive_t)

        # --- global merge trigger ---------------------------------------
        # buffer_k clamps to what can still arrive, so a depleted fleet
        # (or the sync limit with partial participation) still merges.
        reachable = pending + jnp.sum(busy.astype(jnp.int32))
        k_glob = jnp.minimum(
            jnp.asarray(acfg.buffer_k, jnp.float32),
            jnp.maximum(reachable, 1).astype(jnp.float32),
        )
        merge = (pending.astype(jnp.float32) >= k_glob) | (
            t_tick - state.t_last_merge >= acfg.timeout_s
        )

        # --- merge: fog means -> cooperative mix -> gateway (Eqs. 15-16) -
        # The cooperation decision sees the BUFFERED update counts — the
        # async analogue of the sync loop's round-active cluster sizes.
        decision = coop.decide(cfg.rule, dep.fog_pos, fog_n, cfg.channel)
        fog_has = fog_w > 0
        if cfg.robust == "mean":
            fog_delta = fog_sum / jnp.maximum(fog_w, 1e-12)[:, None]
            merge_w = fog_w
        else:
            # Robust reduce over the addressable per-client means: each
            # client's buffered arrivals collapse to a weighted mean first
            # (identical to its contribution to fog_sum), then the fog
            # reduce is the trimmed/median statistic.
            v_cli = cli_sum / jnp.maximum(cli_w, 1e-12)[:, None]
            fog_delta, merge_w = kops.robust_aggregate(
                v_cli, cli_fog, cli_w, n_fog, cfg.trim_frac, cfg.robust,
                use_pallas=cfg.compressor.use_pallas,
                interpret=cfg.compressor.interpret,
            )
        fog_model = fog_delta + flat0[None, :]
        mixed = agg.cooperative_mix(fog_model, decision)
        merged_flat = agg.global_aggregate(mixed, merge_w, prev=flat0)
        if cfg.server_opt == "adam":
            # FedAdam at the gateway; its state advances only on merges.
            incr, server_m = srv.adam_update(
                merged_flat - flat0, state.server, lr=cfg.server_lr
            )
            merged_flat = flat0 + incr
        else:
            server_m = state.server
        server = jax.tree_util.tree_map(
            lambda a, b: jnp.where(merge, a, b), server_m, state.server
        )
        new_flat = jnp.where(merge, merged_flat, flat0)
        new_params = unravel(new_flat)
        # The version only moves when the model does: a timeout merge over
        # an empty buffer holds theta and must not inflate staleness.
        did_move = merge & (jnp.sum(fog_w) > 0)
        version = state.version + did_move.astype(jnp.int32)

        # --- merge-side energy / latency (Eqs. 18, 19, 21) ---------------
        e_ff = en.tx_energy_j(l_full, decision.dist_m, cfg.channel, cfg.energy)
        e_f2f = jnp.where(
            merge,
            jnp.sum(jnp.where(decision.cooperates & fog_has, e_ff, 0.0)),
            0.0,
        )
        e_fg = en.tx_energy_j(
            l_full, fa.fog_gateway_dist_m, cfg.channel, cfg.energy
        )
        e_f2g = jnp.where(
            merge,
            jnp.sum(jnp.where(fog_has & fa.fog_gateway_feasible, e_fg, 0.0)),
            0.0,
        )
        lat_up = jnp.max(jnp.where(arrived, uplink_lat, 0.0))
        lat_ff = jnp.max(
            jnp.where(
                decision.cooperates & fog_has,
                en.link_latency_s(l_full, decision.dist_m, cfg.channel),
                0.0,
            )
        )
        lat_fg = jnp.max(
            jnp.where(
                fog_has,
                en.link_latency_s(l_full, fa.fog_gateway_dist_m, cfg.channel),
                0.0,
            )
        )
        merge_lat = jnp.where(merge, jnp.maximum(lat_ff, lat_fg), 0.0)
        # Eq. 21-comparable per-tick metric: slowest link among those that
        # carried a payload this tick, plus compute (== hfl.comm_latency_s
        # + compute in the sync limit).
        latency = jnp.maximum(lat_up, merge_lat) + lat_comp

        # The clock advances to the trigger, plus the merge propagation
        # (the new global model is only pullable once the fog exchange and
        # gateway upload complete).
        t_next = t_tick + merge_lat
        event_s = t_next - state.t_now

        # --- drain the buffer on merge -----------------------------------
        fog_sum = jnp.where(merge, 0.0, fog_sum)
        fog_w = jnp.where(merge, 0.0, fog_w)
        fog_n = jnp.where(merge, 0, fog_n)
        if cfg.robust != "mean":
            cli_sum = jnp.where(merge, 0.0, cli_sum)
            cli_w = jnp.where(merge, 0.0, cli_w)
        t_last_merge = jnp.where(merge, t_tick, state.t_last_merge)
        pending = jnp.where(merge, 0, pending)

        metrics = AsyncEventMetrics(
            loss=jnp.sum(losses * launch_f)
            / jnp.maximum(jnp.sum(launch_f), 1.0),
            e_s2f=jnp.sum(e_up),
            e_f2f=e_f2f,
            e_f2g=e_f2g,
            e_total=jnp.sum(e_up) + e_f2f + e_f2g,
            latency_s=latency,
            participation=jnp.mean(active_f),
            coop_links=jnp.where(
                merge, jnp.sum(decision.cooperates.astype(jnp.int32)), 0
            ),
            battery_min=jnp.min(battery),
            n_nonfinite=n_nonfinite,
            n_erased=jnp.sum(lost.astype(jnp.int32)),
            global_finite=jnp.all(jnp.isfinite(new_flat)),
            merged=merge,
            n_launched=jnp.sum(launch.astype(jnp.int32)),
            n_arrived=n_arrived,
            staleness=jnp.sum(tau * ok_f)
            / jnp.maximum(n_arrived.astype(jnp.float32), 1.0),
            event_s=event_s,
            t_sim=t_next,
        )
        new_state = AsyncState(
            params=new_params,
            err=new_err,
            battery=battery,
            dep=dep,
            key=key,
            server=server,
            version=version,
            t_now=t_next,
            t_last_merge=t_last_merge,
            pending=pending,
            busy=busy,
            inflight=inflight,
            arrive_t=arrive_t,
            base_version=base_version,
            uplink_lat=uplink_lat,
            launch_fog=launch_fog,
            fog_sum=fog_sum,
            fog_w=fog_w,
            fog_n=fog_n,
            cli_sum=cli_sum,
            cli_w=cli_w,
            cli_fog=cli_fog,
            assoc_fog=assoc_fog,
            assoc_ok=assoc_ok,
            tick=state.tick + 1,
            # Adaptive colluders observe the realised global movement,
            # which only happens on merge ticks.
            prev_delta=(
                jnp.where(merge, new_flat - flat0, state.prev_delta)
                if adaptive else state.prev_delta
            ),
        )
        return new_state, metrics

    return event_fn


def train(
    key: jax.Array,
    init_params: Params,
    loss_fn: LossFn,
    ds: SensorDataset,
    acfg: AsyncFLConfig,
) -> tuple[Params, AsyncEventMetrics]:
    """Simulate ``acfg.n_events`` fog ticks; returns (final params,
    per-tick metrics stacked along the leading axis)."""
    state = init_state(key, init_params, acfg)
    event_fn = make_event_fn(loss_fn, ds, acfg)
    final, metrics = jax.lax.scan(event_fn, state, None, length=acfg.n_events)
    return final.params, metrics
