"""Participation accounting (paper contribution #1, Sec. VI-C).

The paper's central evaluation point: report *who can train* alongside
accuracy and energy.  These helpers compute, per round and per method
family, the participation fraction and reachability statistics that the
scalability study (Fig. 5, Table III) plots.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import association as assoc
from repro.core import channel as ch
from repro.core.topology import Deployment


class Reachability(NamedTuple):
    direct_gateway: jax.Array   # fraction of sensors with feasible direct link
    fog_assisted: jax.Array     # fraction with >= 1 feasible fog link
    fog_to_gateway: jax.Array   # fraction of fogs that can reach the gateway


def reachability(dep: Deployment, cparams: ch.ChannelParams) -> Reachability:
    flat = assoc.flat_association(dep, cparams)
    fog = assoc.nearest_feasible_fog(dep, cparams)
    return Reachability(
        direct_gateway=jnp.mean(flat.participates.astype(jnp.float32)),
        fog_assisted=jnp.mean(fog.participates.astype(jnp.float32)),
        fog_to_gateway=jnp.mean(fog.fog_gateway_feasible.astype(jnp.float32)),
    )


def participation_fraction(mask: jax.Array) -> jax.Array:
    """Fraction of sensors contributing updates this round."""
    return jnp.mean(mask.astype(jnp.float32))


def energy_per_participant(total_energy_j: jax.Array, mask: jax.Array) -> jax.Array:
    """Energy normalised by the number of *participating* sensors — the
    per-participant metric from the paper's design rule #1 (Sec. VI-G)."""
    return total_energy_j / jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
