"""Fog-level cooperation rules (paper Sec. IV-E / V-B, Eqs. 14, 28-29).

All three rules return a :class:`CoopDecision` with, per fog node m:

  - ``partner``: the single neighbour j it mixes with (K=1 in the paper's
    rule family), or ``m`` itself when it does not cooperate;
  - ``self_weight`` / ``partner_weight``: the mixing coefficients
    (alpha_mm, alpha_mj), rows of a (sub-)stochastic mixing matrix (Eq. 14);
  - ``cooperates``: boolean mask (drives the fog-to-fog energy term, Eq. 18).

Rules are pure functions of the fog geometry + cluster sizes, so the whole
round stays jittable and the same code runs inside `shard_map`.
"""
from __future__ import annotations

import enum
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import channel as ch


class CoopRule(enum.Enum):
    NOCOOP = "nocoop"
    NEAREST = "nearest"
    SELECTIVE = "selective"


class CoopDecision(NamedTuple):
    partner: jax.Array        # (M,) int32
    self_weight: jax.Array    # (M,) f32
    partner_weight: jax.Array  # (M,) f32
    cooperates: jax.Array     # (M,) bool
    dist_m: jax.Array         # (M,) distance to partner (0 when not cooperating)


# Paper's fixed mixing weights.
NEAREST_WEIGHTS = (0.7, 0.3)     # HFL-Nearest (Sec. V-B)
SELECTIVE_WEIGHTS = (0.8, 0.2)   # HFL-Selective (Eq. 29)


def _fog_distance_matrix(fog_pos: jax.Array) -> jax.Array:
    d = ch.pairwise_distances(fog_pos, fog_pos)
    return d + jnp.diag(jnp.full((fog_pos.shape[0],), jnp.inf))


def no_cooperation(fog_pos: jax.Array) -> CoopDecision:
    """HFL-NoCoop: N_m = empty set for every fog."""
    m = fog_pos.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    return CoopDecision(
        partner=idx,
        self_weight=jnp.ones((m,), jnp.float32),
        partner_weight=jnp.zeros((m,), jnp.float32),
        cooperates=jnp.zeros((m,), bool),
        dist_m=jnp.zeros((m,), jnp.float32),
    )


def nearest_cooperation(
    fog_pos: jax.Array,
    cluster_size: jax.Array,
    cparams: ch.ChannelParams,
) -> CoopDecision:
    """HFL-Nearest: always-on cooperation with the nearest feasible fog
    *that serves a nonempty cluster*.

    An empty fog holds no local aggregate — its "model" is just the stale
    broadcast globals — so pairing with it would let Eq. 15 blend stale
    params into a real fog's update while the Eq. 18/21 energy and latency
    masks (``cooperates & fog_active``) count no exchange.  Gating partner
    eligibility on ``cluster_size > 0`` (and requiring the cooperating fog
    itself to be nonempty) keeps mixing, energy, and latency consistent.
    """
    d = _fog_distance_matrix(fog_pos)
    nonempty = cluster_size > 0
    feas = ch.feasible(d, cparams) & nonempty[None, :]
    masked = jnp.where(feas, d, jnp.inf)
    partner = jnp.argmin(masked, axis=-1).astype(jnp.int32)
    has_any = jnp.any(feas, axis=-1) & nonempty
    pdist = jnp.take_along_axis(d, partner[:, None], axis=-1)[:, 0]
    w_self, w_peer = NEAREST_WEIGHTS
    m = fog_pos.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    return CoopDecision(
        partner=jnp.where(has_any, partner, idx),
        self_weight=jnp.where(has_any, w_self, 1.0).astype(jnp.float32),
        partner_weight=jnp.where(has_any, w_peer, 0.0).astype(jnp.float32),
        cooperates=has_any,
        dist_m=jnp.where(has_any, pdist, 0.0),
    )


def selective_cooperation(
    fog_pos: jax.Array,
    cluster_size: jax.Array,
    cparams: ch.ChannelParams,
    eligibility_factor: float | jax.Array = 0.75,
) -> CoopDecision:
    """HFL-Selective (paper Eqs. 28-29).

    A fog m cooperates iff
      1. its cluster is small:  c_m <= max(2, f * mean nonempty c)       (28)
         (``eligibility_factor`` f = 0.75 in the paper; swept in the
         ablations),
      2. a feasible neighbour exists with *larger, nonempty* cluster whose
         distance is below the first quartile of feasible fog-fog distances,
    in which case it mixes 0.8/0.2 with the *nearest* such neighbour (29).
    """
    m = fog_pos.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    d = _fog_distance_matrix(fog_pos)
    feas = ch.feasible(d, cparams)

    c = cluster_size.astype(jnp.float32)
    nonempty = c > 0
    mean_c = jnp.sum(c * nonempty) / jnp.maximum(jnp.sum(nonempty), 1.0)
    eligible = c <= jnp.maximum(2.0, eligibility_factor * mean_c)        # (28)

    # First quartile of feasible fog-fog distances (upper triangle of the
    # symmetric matrix; use all feasible off-diagonal entries — each pair
    # counted twice, which leaves the quantile unchanged).  With ZERO
    # feasible pairs the matrix would be all-NaN and nanquantile would
    # yield NaN plus a RuntimeWarning (noisy under vmap); feed zeros
    # instead — the q1 value is irrelevant then because ``feas`` already
    # kills every candidate, so the rule degrades to no-coop explicitly.
    any_feasible = jnp.any(feas)
    feas_d = jnp.where(feas, d, jnp.nan)
    q1 = jnp.nanquantile(
        jnp.where(any_feasible, feas_d, 0.0), 0.25
    )

    # Partner must hold a strictly larger — hence nonempty — cluster; the
    # explicit nonempty mask keeps that invariant even if the size rule
    # changes (cf. nearest_cooperation: never mix in an empty fog's stale
    # params).
    larger = (c[None, :] > c[:, None]) & nonempty[None, :]
    candidate = feas & larger & (d < q1)
    masked = jnp.where(candidate, d, jnp.inf)
    partner = jnp.argmin(masked, axis=-1).astype(jnp.int32)
    has_candidate = jnp.any(candidate, axis=-1)

    coop = eligible & has_candidate & nonempty
    pdist = jnp.take_along_axis(d, partner[:, None], axis=-1)[:, 0]
    w_self, w_peer = SELECTIVE_WEIGHTS
    return CoopDecision(
        partner=jnp.where(coop, partner, idx),
        self_weight=jnp.where(coop, w_self, 1.0).astype(jnp.float32),
        partner_weight=jnp.where(coop, w_peer, 0.0).astype(jnp.float32),
        cooperates=coop,
        dist_m=jnp.where(coop, pdist, 0.0),
    )


def decide(
    rule: CoopRule,
    fog_pos: jax.Array,
    cluster_size: jax.Array,
    cparams: ch.ChannelParams,
) -> CoopDecision:
    """Dispatch on the cooperation rule (static — rule is a Python enum)."""
    if rule is CoopRule.NOCOOP:
        return no_cooperation(fog_pos)
    if rule is CoopRule.NEAREST:
        return nearest_cooperation(fog_pos, cluster_size, cparams)
    if rule is CoopRule.SELECTIVE:
        return selective_cooperation(fog_pos, cluster_size, cparams)
    raise ValueError(f"unknown cooperation rule: {rule}")
