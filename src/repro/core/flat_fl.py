"""Flat (star-topology) FL baselines: FedAvg, FedProx, SCAFFOLD, and the
centralised oracle (paper Sec. VI-B).

Flat methods are participation-limited: only sensors with a feasible
*direct* sensor->gateway acoustic link upload updates (Sec. IV-E).  The
centralised oracle pools raw data at the gateway — underwater-infeasible,
kept as a reference; its energy is the raw-data upload cost through each
sensor's cheapest feasible path (direct if feasible, else the 2-hop
sensor->fog->gateway relay), which is the assumption that makes Table IV's
finite centralised energies reproducible.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg
from repro.core import association as assoc
from repro.core import compression as comp
from repro.core import energy as en
from repro.core import faults as flt
from repro.core import topology as topo
from repro.core.hfl import (
    HFLConfig, HFLState, RoundMetrics, _client_train_fn, _clients_round,
)
from repro.kernels import ops as kops
from repro.data.pipeline import multi_epoch_batches
from repro.data.synthetic import SensorDataset
from repro.launch.mesh import shard_map_compat
from repro.optim import scaffold as scf
from repro.optim import server as srv
from repro.optim.sgd import local_sgd

Params = Any
LossFn = Callable[[Params, jax.Array], jax.Array]


def make_flat_round_fn(
    loss_fn: LossFn,
    ds: SensorDataset,
    cfg: HFLConfig,
    *,
    client_mesh: Mesh | None = None,
) -> Callable[[HFLState, None], tuple[HFLState, RoundMetrics]]:
    """FedAvg (prox_mu=0) / FedProx (prox_mu>0) direct-to-gateway round.

    The gateway is a single "cluster": local training runs through the
    same fused batched client solver as the hierarchical loop (see
    :func:`repro.optim.sgd.make_client_solver`; ``prox_mu > 0`` = FedProx
    in-kernel), and compression + the weighted FedAvg mean through the
    fused compress-and-aggregate operator, with ``n_fog=1``.
    ``client_mesh`` shards the client axis exactly as in
    :func:`repro.core.hfl.make_round_fn`.
    """
    clients_fn = _client_train_fn(loss_fn, cfg)
    if cfg.robust not in ("mean", "trimmed", "median"):
        raise ValueError(
            f"robust must be 'mean', 'trimmed' or 'median', got "
            f"{cfg.robust!r}"
        )
    fl = cfg.faults
    fault_on = fl.is_active       # STATIC: off => exact legacy round
    dr = cfg.drift
    drift_on = dr.is_active       # STATIC: off => exact legacy round
    adaptive = fault_on and fl.byz_mode == "adaptive"
    if client_mesh is not None and (fault_on or cfg.robust != "mean"):
        raise ValueError(
            "client-sharded rounds do not support fault injection or "
            "robust aggregation (the per-client reconstructions never "
            "leave their shard)"
        )
    if client_mesh is not None and drift_on:
        raise ValueError(
            "client-sharded rounds do not support the drift layer yet"
        )
    if client_mesh is not None and ds.train.shape[0] % client_mesh.size != 0:
        raise ValueError(
            f"client axis ({ds.train.shape[0]} sensors) must divide the "
            f"({client_mesh.size})-device client mesh"
        )

    def round_fn(state: HFLState, _) -> tuple[HFLState, RoundMetrics]:
        if fault_on:
            key, k_mob, k_train, k_byz, k_crash, k_erase = jax.random.split(
                state.key, 6
            )
        else:
            key, k_mob, k_train = jax.random.split(state.key, 3)
        dep = state.dep
        if cfg.fog_mobility:
            dep = topo.gauss_markov_step(k_mob, dep, cfg.deployment)
        if drift_on:
            dep = topo.current_advection_step(
                dep, cfg.deployment, dr.sensor_current_m_s
            )

        if drift_on:
            # Frozen round membership, live gateway physics (see
            # hfl.make_round_fn — identical cadence logic).
            t_f = state.t.astype(jnp.float32)
            cadence = jnp.maximum(
                jnp.asarray(dr.reassoc_every, jnp.float32), 1.0
            )
            refresh = jnp.mod(t_f, cadence) < 0.5
            fresh = assoc.flat_association(dep, cfg.channel)
            assoc_ok = jnp.where(refresh, fresh.participates, state.assoc_ok)
            fa = assoc.assigned_flat_association(dep, cfg.channel, assoc_ok)
        else:
            assoc_ok = state.assoc_ok
            fa = assoc.flat_association(dep, cfg.channel)
        alive = state.battery > cfg.energy.e_min_j
        active = fa.participates & alive
        if fault_on:
            active = active & ~flt.draw_crash(
                k_crash, alive.shape[0], fl.crash_prob
            )

        flat0, unravel = ravel_pytree(state.params)
        d = flat0.shape[0]
        n = ds.train.shape[0]
        keys = jax.random.split(k_train, n)
        train = ds.train
        if drift_on:
            train = train * (1.0 + dr.covariate_shift * t_f)

        active_f = active.astype(jnp.float32)
        # Erasure after feasibility: energy charged, EF advanced, weight 0.
        if fault_on:
            erased = active & flt.draw_erasure(k_erase, n, fl.erasure_prob)
        else:
            erased = jnp.zeros_like(active)
        delivered = active & ~erased
        weights = ds.n_samples * delivered.astype(jnp.float32)
        gateway_id = jnp.zeros((ds.train.shape[0],), jnp.int32)

        if client_mesh is None:
            deltas, losses = clients_fn(state.params, train, keys)
            if fault_on:
                deltas = flt.corrupt_deltas(
                    k_byz, deltas, fl, prev_delta=state.prev_delta
                )
            n_nonfinite = jnp.sum(
                (delivered & flt.nonfinite_rows(deltas)).astype(jnp.int32)
            )
            if cfg.robust == "mean":
                fog_sum, fog_weight, new_err = agg.compress_and_accumulate(
                    deltas, state.err, gateway_id, weights, 1,
                    cfg.compressor, chunk=cfg.client_chunk,
                )
                fog_delta = fog_sum / jnp.maximum(fog_weight, 1e-12)[:, None]
            else:
                fog_delta, _, new_err = agg.robust_compress_and_aggregate(
                    deltas, state.err, gateway_id, weights, 1,
                    cfg.compressor, cfg.trim_frac, cfg.robust,
                    chunk=cfg.client_chunk,
                )
        else:
            sharded = shard_map_compat(
                lambda p, dat, kk, e, w, fid: _clients_round(
                    clients_fn, p, dat, kk, e, w, fid, 1,
                    cfg.compressor, axis="data", chunk=cfg.client_chunk,
                ),
                mesh=client_mesh,
                in_specs=(P(), P("data"), P("data"), P("data"),
                          P("data"), P("data")),
                out_specs=(P(), P(), P("data"), P("data")),
            )
            fog_delta, _, new_err, losses = sharded(
                state.params, train, keys, state.err, weights, gateway_id
            )
            n_nonfinite = jnp.int32(0)
        new_err = jnp.where(active[:, None], new_err, state.err)
        mean_delta = fog_delta[0]
        if cfg.server_opt == "adam":
            # FedAdam [34] at the gateway: delta is the pseudo-gradient.
            incr, server = srv.adam_update(
                mean_delta, state.server, lr=cfg.server_lr
            )
        else:
            incr, server = mean_delta, state.server
        new_params = unravel(flat0 + incr)

        l_u = comp.payload_bits(d, cfg.compressor)
        e_up = en.tx_energy_j(l_u, fa.dist_m, cfg.channel, cfg.energy)
        e_up = jnp.where(active, e_up, 0.0)
        e_total = jnp.sum(e_up)

        lat_up = jnp.max(
            jnp.where(active, en.link_latency_s(l_u, fa.dist_m, cfg.channel), 0.0)
        )
        flops = en.autoencoder_flops(
            ds.train.shape[-1], (16, 8, 16), ds.train.shape[1], cfg.local_epochs
        )
        e_comp = en.compute_energy_j(jnp.float32(flops), cfg.energy)
        spent = e_up + jnp.where(active, e_comp, 0.0)
        battery, _ = en.battery_step(state.battery, spent, cfg.energy)

        metrics = RoundMetrics(
            loss=jnp.sum(losses * active_f) / jnp.maximum(jnp.sum(active_f), 1.0),
            e_s2f=e_total,
            e_f2f=jnp.zeros(()),
            e_f2g=jnp.zeros(()),
            e_total=e_total,
            latency_s=lat_up + flops / cfg.compute_rate_flops,
            participation=jnp.mean(active_f),
            coop_links=jnp.zeros((), jnp.int32),
            battery_min=jnp.min(battery),
            n_nonfinite=n_nonfinite,
            n_erased=jnp.sum(erased.astype(jnp.int32)),
            global_finite=jnp.all(jnp.isfinite(flat0 + incr)),
        )
        prev_delta = incr if adaptive else state.prev_delta
        return (
            HFLState(
                new_params, new_err, battery, dep, key, server,
                state.assoc_fog, assoc_ok, state.t + 1, prev_delta,
            ),
            metrics,
        )

    return round_fn


def train_flat(
    key: jax.Array,
    init_params: Params,
    loss_fn: LossFn,
    ds: SensorDataset,
    cfg: HFLConfig,
    *,
    client_mesh: Mesh | None = None,
) -> tuple[Params, RoundMetrics]:
    from repro.core.hfl import init_state

    state = init_state(key, init_params, cfg)
    round_fn = make_flat_round_fn(loss_fn, ds, cfg, client_mesh=client_mesh)
    final, metrics = jax.lax.scan(round_fn, state, None, length=cfg.rounds)
    return final.params, metrics


# ---------------------------------------------------------------------------
# SCAFFOLD
# ---------------------------------------------------------------------------

class ScaffoldTrainState(NamedTuple):
    fl: HFLState
    ctrl: scf.ScaffoldState


def train_scaffold(
    key: jax.Array,
    init_params: Params,
    loss_fn: LossFn,
    ds: SensorDataset,
    cfg: HFLConfig,
) -> tuple[Params, RoundMetrics]:
    """SCAFFOLD over feasible direct links (released-trace baseline).

    SCAFFOLD's deltas are pytrees averaged without the compress path, so
    the fault layer ravels them to flat rows first: Byzantine corruption /
    the isfinite guard / the robust reduce all act on the flat stream,
    and the mean is unravelled back.  With the fault layer statically
    inactive and ``robust == "mean"`` the legacy tree path runs untouched.
    """
    from repro.core.hfl import init_state

    if cfg.robust not in ("mean", "trimmed", "median"):
        raise ValueError(
            f"robust must be 'mean', 'trimmed' or 'median', got "
            f"{cfg.robust!r}"
        )
    fl_cfg = cfg.faults
    fault_on = fl_cfg.is_active
    fault_path = fault_on or cfg.robust != "mean"
    dr = cfg.drift
    drift_on = dr.is_active
    adaptive = fault_on and fl_cfg.byz_mode == "adaptive"

    n = ds.train.shape[0]
    state = ScaffoldTrainState(
        fl=init_state(key, init_params, cfg),
        ctrl=scf.init_state(init_params, n),
    )

    def round_fn(s: ScaffoldTrainState, _):
        st = s.fl
        if fault_on:
            key, k_mob, k_train, k_byz, k_crash, k_erase = jax.random.split(
                st.key, 6
            )
        else:
            key, k_mob, k_train = jax.random.split(st.key, 3)
        dep = st.dep
        if cfg.fog_mobility:
            dep = topo.gauss_markov_step(k_mob, dep, cfg.deployment)
        if drift_on:
            dep = topo.current_advection_step(
                dep, cfg.deployment, dr.sensor_current_m_s
            )
        if drift_on:
            t_f = st.t.astype(jnp.float32)
            cadence = jnp.maximum(
                jnp.asarray(dr.reassoc_every, jnp.float32), 1.0
            )
            refresh = jnp.mod(t_f, cadence) < 0.5
            fresh = assoc.flat_association(dep, cfg.channel)
            assoc_ok = jnp.where(refresh, fresh.participates, st.assoc_ok)
            fa = assoc.assigned_flat_association(dep, cfg.channel, assoc_ok)
        else:
            assoc_ok = st.assoc_ok
            fa = assoc.flat_association(dep, cfg.channel)
        active = fa.participates & (st.battery > cfg.energy.e_min_j)
        if fault_on:
            active = active & ~flt.draw_crash(k_crash, n, fl_cfg.crash_prob)
        active_f = active.astype(jnp.float32)

        keys = jax.random.split(k_train, n)
        train = ds.train
        if drift_on:
            train = train * (1.0 + dr.covariate_shift * t_f)

        def client_step(data, k, c_i):
            batches = multi_epoch_batches(
                k, data, cfg.batch_size, cfg.local_epochs
            )
            p1, new_ci, loss = scf.scaffold_local(
                loss_fn, st.params, batches, cfg.lr, s.ctrl.c_global, c_i
            )
            delta = jax.tree_util.tree_map(lambda a, b: a - b, p1, st.params)
            dc = jax.tree_util.tree_map(lambda a, b: a - b, new_ci, c_i)
            return delta, new_ci, dc, loss

        deltas, new_ci, dcs, losses = jax.vmap(client_step)(
            train, keys, s.ctrl.c_local
        )
        if fault_on:
            erased = active & flt.draw_erasure(k_erase, n, fl_cfg.erasure_prob)
        else:
            erased = jnp.zeros_like(active)
        delivered = active & ~erased
        delivered_f = delivered.astype(jnp.float32)
        weights = ds.n_samples * delivered_f

        if fault_path:
            flat_deltas = jax.vmap(lambda t: ravel_pytree(t)[0])(deltas)
            if fault_on:
                flat_deltas = flt.corrupt_deltas(
                    k_byz, flat_deltas, fl_cfg, prev_delta=st.prev_delta
                )
            finite = ~flt.nonfinite_rows(flat_deltas)
            n_nonfinite = jnp.sum((delivered & ~finite).astype(jnp.int32))
            w_del = weights * finite.astype(jnp.float32)
            safe = jnp.where(finite[:, None], flat_deltas, 0.0)
            if cfg.robust == "mean":
                mean_flat = agg.weighted_mean(safe, w_del)
            else:
                fog_out, _ = kops.robust_aggregate(
                    safe, jnp.zeros((n,), jnp.int32), w_del, 1,
                    cfg.trim_frac, cfg.robust,
                    use_pallas=cfg.compressor.use_pallas,
                    interpret=cfg.compressor.interpret,
                )
                mean_flat = fog_out[0]
            _, unravel_delta = ravel_pytree(
                jax.tree_util.tree_map(lambda x: x[0], deltas)
            )
            mean_delta = unravel_delta(mean_flat)
        else:
            n_nonfinite = jnp.int32(0)
            mean_delta = agg.weighted_mean(deltas, weights)
        new_params = jax.tree_util.tree_map(
            lambda p, dlt: p + dlt, st.params, mean_delta
        )
        # c <- c + (1/N) sum delivered dc (== active with the faults off)
        frac = jnp.sum(delivered_f) / n
        mean_dc = agg.weighted_mean(dcs, delivered_f)
        new_cg = jax.tree_util.tree_map(
            lambda c, dc: c + frac * dc, s.ctrl.c_global, mean_dc
        )
        keep = active.reshape((-1,) + (1,) * 0)
        new_cl = jax.tree_util.tree_map(
            lambda old, new: jnp.where(
                active.reshape((-1,) + (1,) * (new.ndim - 1)), new, old
            ),
            s.ctrl.c_local,
            new_ci,
        )
        del keep

        flat0, _ = ravel_pytree(st.params)
        l_u = comp.payload_bits(flat0.shape[0], cfg.compressor)
        e_up = jnp.where(
            active, en.tx_energy_j(l_u, fa.dist_m, cfg.channel, cfg.energy), 0.0
        )
        battery, _ = en.battery_step(st.battery, e_up, cfg.energy)
        metrics = RoundMetrics(
            loss=jnp.sum(losses * active_f) / jnp.maximum(jnp.sum(active_f), 1.0),
            e_s2f=jnp.sum(e_up),
            e_f2f=jnp.zeros(()),
            e_f2g=jnp.zeros(()),
            e_total=jnp.sum(e_up),
            latency_s=jnp.zeros(()),
            participation=jnp.mean(active_f),
            coop_links=jnp.zeros((), jnp.int32),
            battery_min=jnp.min(battery),
            n_nonfinite=n_nonfinite,
            n_erased=jnp.sum(erased.astype(jnp.int32)),
            global_finite=jnp.all(
                jnp.isfinite(ravel_pytree(new_params)[0])
            ),
        )
        # Adaptive colluders observe the realised global movement (the
        # flat mean delta; only computed on the fault path).
        prev_delta = mean_flat if adaptive else st.prev_delta
        return (
            ScaffoldTrainState(
                HFLState(
                    new_params, st.err, battery, dep, key, st.server,
                    st.assoc_fog, assoc_ok, st.t + 1, prev_delta,
                ),
                scf.ScaffoldState(new_cg, new_cl),
            ),
            metrics,
        )

    final, metrics = jax.lax.scan(round_fn, state, None, length=cfg.rounds)
    return final.fl.params, metrics


# ---------------------------------------------------------------------------
# Centralised oracle
# ---------------------------------------------------------------------------

def train_centralised(
    key: jax.Array,
    init_params: Params,
    loss_fn: LossFn,
    ds: SensorDataset,
    cfg: HFLConfig,
) -> tuple[Params, jax.Array, jax.Array]:
    """All-data oracle at the gateway.

    Returns (params, losses (T,), upload_energy_j scalar).  Energy is the
    one-time raw-data upload through each sensor's cheapest feasible path.
    """
    kd, kt = jax.random.split(key)
    dep = topo.sample_deployment(kd, cfg.deployment)

    # Raw-data upload energy, cheapest feasible path per sensor.
    raw_bits = ds.train.shape[1] * ds.train.shape[2] * 32.0
    flat = assoc.flat_association(dep, cfg.channel)
    fog = assoc.nearest_feasible_fog(dep, cfg.channel)
    e_direct = en.tx_energy_j(raw_bits, flat.dist_m, cfg.channel, cfg.energy)
    e_relay = en.tx_energy_j(
        raw_bits, fog.dist_m, cfg.channel, cfg.energy
    ) + en.tx_energy_j(
        raw_bits, fog.fog_gateway_dist_m[fog.fog_id], cfg.channel, cfg.energy
    )
    e_path = jnp.minimum(
        jnp.where(flat.participates, e_direct, jnp.inf),
        jnp.where(fog.participates, e_relay, jnp.inf),
    )
    upload_energy = jnp.sum(jnp.where(jnp.isfinite(e_path), e_path, 0.0))

    pooled = ds.train.reshape(-1, ds.train.shape[-1])

    def epoch(carry, k):
        params = carry
        params, loss = local_sgd(
            loss_fn,
            params,
            multi_epoch_batches(k, pooled, cfg.batch_size, 1),
            cfg.lr,
        )
        return params, loss

    keys = jax.random.split(kt, cfg.rounds * cfg.local_epochs)
    params, losses = jax.lax.scan(epoch, init_params, keys)
    return params, losses, upload_energy
