"""Anomaly scoring, threshold calibration, and detection metrics.

Implements the paper's Sec. V-D (99th-percentile global threshold on a
normal-only validation window) plus the two metrics used in evaluation:
point-wise F1 (synthetic study) and point-adjusted F1 (real benchmarks),
the standard segment-generous protocol.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def reconstruction_errors(
    apply_fn: Callable[[Any, jax.Array], jax.Array],
    params: Any,
    x: jax.Array,
) -> jax.Array:
    """Squared-L2 reconstruction error per sample (paper Sec. V-D)."""
    recon = apply_fn(params, x)
    return jnp.sum(jnp.square(x - recon), axis=-1)


def calibrate_threshold(errors: jax.Array, percentile: float = 99.0) -> jax.Array:
    """Global threshold tau_A = p-th percentile of validation errors (Eq. 32)."""
    return jnp.percentile(errors, percentile)


def flag_anomalies(errors: jax.Array, tau: jax.Array) -> jax.Array:
    """Boolean anomaly decisions: e > tau_A."""
    return errors > tau


class F1Result(NamedTuple):
    f1: jax.Array
    precision: jax.Array
    recall: jax.Array


def pointwise_f1(pred: jax.Array, label: jax.Array) -> F1Result:
    """Point-wise F1 over boolean prediction/label arrays."""
    pred = pred.astype(jnp.float32)
    label = label.astype(jnp.float32)
    tp = jnp.sum(pred * label)
    fp = jnp.sum(pred * (1.0 - label))
    fn = jnp.sum((1.0 - pred) * label)
    precision = tp / jnp.maximum(tp + fp, 1e-12)
    recall = tp / jnp.maximum(tp + fn, 1e-12)
    f1 = 2.0 * precision * recall / jnp.maximum(precision + recall, 1e-12)
    return F1Result(f1, precision, recall)


def point_adjust(pred: jax.Array, label: jax.Array) -> jax.Array:
    """Point-adjusted predictions (PA protocol, paper Sec. VI-F).

    If any point inside a contiguous anomalous segment is detected, the
    whole segment is credited.  Implemented with a forward/backward
    segment-id sweep so it stays jittable.
    """
    label = label.astype(bool)
    pred = pred.astype(bool)
    # Segment id: cumulative count of rising edges, 0 outside segments.
    start = label & ~jnp.concatenate([jnp.array([False]), label[:-1]])
    seg_id = jnp.cumsum(start.astype(jnp.int32)) * label.astype(jnp.int32)
    n_seg = jnp.max(seg_id) + 1
    hit_per_seg = jax.ops.segment_sum(
        (pred & label).astype(jnp.int32),
        seg_id,
        num_segments=pred.shape[0] + 1,
    )
    seg_hit = hit_per_seg[seg_id] > 0
    return jnp.where(label, seg_hit, pred)


def point_adjusted_f1(pred: jax.Array, label: jax.Array) -> F1Result:
    """PA-F1: point-wise F1 on point-adjusted predictions."""
    return pointwise_f1(point_adjust(pred, label), label)


def evaluate_detector(
    apply_fn: Callable[[Any, jax.Array], jax.Array],
    params: Any,
    x_val_normal: jax.Array,
    x_test: jax.Array,
    y_test: jax.Array,
    percentile: float = 99.0,
    point_adjusted: bool = False,
) -> F1Result:
    """Full paper protocol: calibrate on normal-only val, score test, F1."""
    val_err = reconstruction_errors(apply_fn, params, x_val_normal)
    tau = calibrate_threshold(val_err, percentile)
    test_err = reconstruction_errors(apply_fn, params, x_test)
    pred = flag_anomalies(test_err, tau)
    if point_adjusted:
        return point_adjusted_f1(pred, y_test)
    return pointwise_f1(pred, y_test)
