"""Feasibility-aware association rules (paper Sec. IV-E / V-B).

Flat FL: only sensors with a feasible direct sensor->gateway link
participate.  Hierarchical FL: each sensor attaches to its *nearest feasible*
fog node; sensors with no feasible fog are inactive that round.

Everything returns dense arrays + masks so the round stays jittable.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import channel as ch
from repro.core.topology import Deployment


class FlatAssociation(NamedTuple):
    """Direct-to-gateway association result."""

    participates: jax.Array   # (N,) bool — feasible direct gateway link
    dist_m: jax.Array         # (N,) sensor->gateway distance


class FogAssociation(NamedTuple):
    """Nearest-feasible-fog association result."""

    fog_id: jax.Array         # (N,) int32 — assigned fog (undefined if inactive)
    participates: jax.Array   # (N,) bool — at least one feasible fog link
    dist_m: jax.Array         # (N,) distance to assigned fog
    cluster_size: jax.Array   # (M,) int32 — |C_m|
    fog_gateway_dist_m: jax.Array  # (M,) fog->gateway distance
    fog_gateway_feasible: jax.Array  # (M,) bool


def flat_association(
    dep: Deployment, cparams: ch.ChannelParams
) -> FlatAssociation:
    """Sensors that can reach the gateway directly under the SL cap."""
    d = jnp.linalg.norm(dep.sensor_pos - dep.gateway_pos[None, :], axis=-1)
    return FlatAssociation(participates=ch.feasible(d, cparams), dist_m=d)


def nearest_feasible_fog(
    dep: Deployment, cparams: ch.ChannelParams
) -> FogAssociation:
    """Attach each sensor to its nearest feasible fog (paper Sec. V-B)."""
    d_sf = ch.pairwise_distances(dep.sensor_pos, dep.fog_pos)   # (N, M)
    feas = ch.feasible(d_sf, cparams)
    masked = jnp.where(feas, d_sf, jnp.inf)
    fog_id = jnp.argmin(masked, axis=-1).astype(jnp.int32)
    participates = jnp.any(feas, axis=-1)
    dist = jnp.take_along_axis(d_sf, fog_id[:, None], axis=-1)[:, 0]

    n_fog = dep.fog_pos.shape[0]
    one_hot = jax.nn.one_hot(fog_id, n_fog, dtype=jnp.int32) * participates[
        :, None
    ].astype(jnp.int32)
    cluster_size = jnp.sum(one_hot, axis=0)

    d_fg = jnp.linalg.norm(dep.fog_pos - dep.gateway_pos[None, :], axis=-1)
    return FogAssociation(
        fog_id=fog_id,
        participates=participates,
        dist_m=dist,
        cluster_size=cluster_size,
        fog_gateway_dist_m=d_fg,
        fog_gateway_feasible=ch.feasible(d_fg, cparams),
    )


def assigned_fog_association(
    dep: Deployment,
    cparams: ch.ChannelParams,
    fog_id: jax.Array,       # (N,) int32 — frozen assignment
    assigned: jax.Array,     # (N,) bool — had a feasible fog at assignment
) -> FogAssociation:
    """Stale assignment, live physics (drift layer, Sec. III-A mobility).

    Recomputes distances, SNR feasibility, cluster sizes and fog-gateway
    links from the CURRENT geometry against a FROZEN sensor->fog
    assignment: a sensor whose assigned fog drifted out of range drops
    out until the next re-association refresh.  When ``fog_id`` /
    ``assigned`` come fresh from :func:`nearest_feasible_fog` on the same
    deployment, the result is bit-identical to it (the per-pair distance
    uses the same ``sqrt(sum(sq) + 1e-12)`` ops as
    ``ch.pairwise_distances``), which is what makes neutral drift cells
    pin against the legacy path.
    """
    diff = dep.sensor_pos - dep.fog_pos[fog_id]
    d = jnp.sqrt(jnp.sum(jnp.square(diff), axis=-1) + 1e-12)
    participates = assigned & ch.feasible(d, cparams)

    n_fog = dep.fog_pos.shape[0]
    one_hot = jax.nn.one_hot(fog_id, n_fog, dtype=jnp.int32) * participates[
        :, None
    ].astype(jnp.int32)
    cluster_size = jnp.sum(one_hot, axis=0)

    d_fg = jnp.linalg.norm(dep.fog_pos - dep.gateway_pos[None, :], axis=-1)
    return FogAssociation(
        fog_id=fog_id,
        participates=participates,
        dist_m=d,
        cluster_size=cluster_size,
        fog_gateway_dist_m=d_fg,
        fog_gateway_feasible=ch.feasible(d_fg, cparams),
    )


def assigned_flat_association(
    dep: Deployment, cparams: ch.ChannelParams, assigned: jax.Array
) -> FlatAssociation:
    """Flat-FL sibling of :func:`assigned_fog_association`: frozen round
    membership, live gateway distance + feasibility."""
    d = jnp.linalg.norm(dep.sensor_pos - dep.gateway_pos[None, :], axis=-1)
    return FlatAssociation(
        participates=assigned & ch.feasible(d, cparams), dist_m=d
    )
