"""3D stratified IoUT deployment and fog mobility (paper Sec. III-A).

Sensors are static and deep; fog nodes are mid-water and quasi-static within
a round, drifting between rounds with a Gauss-Markov mobility model.  The
surface gateway sits at z=0 in the centre of the deployment area.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DeploymentParams:
    """Geometry parameters (paper Table II baseline)."""

    lx_m: float = 2000.0
    ly_m: float = 2000.0
    depth_m: float = 1000.0
    n_sensors: int = 100
    n_fog: int = 10
    sensor_depth: tuple[float, float] = (500.0, 1000.0)
    fog_depth: tuple[float, float] = (100.0, 400.0)
    # Gauss-Markov fog drift
    fog_speed_m_s: float = 0.5
    gm_alpha: float = 0.75       # memory factor
    round_interval_s: float = 60.0

    def replace(self, **kw: Any) -> "DeploymentParams":
        return dataclasses.replace(self, **kw)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Deployment:
    """Dynamic node state: positions and fog velocities."""

    sensor_pos: jax.Array      # (N, 3)
    fog_pos: jax.Array         # (M, 3)
    fog_vel: jax.Array         # (M, 3)
    gateway_pos: jax.Array     # (3,)

    def tree_flatten(self):
        return (self.sensor_pos, self.fog_pos, self.fog_vel, self.gateway_pos), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def _uniform_stratum(
    key: jax.Array, n: int, params: DeploymentParams, depth: tuple[float, float]
) -> jax.Array:
    kx, ky, kz = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n,), minval=0.0, maxval=params.lx_m)
    y = jax.random.uniform(ky, (n,), minval=0.0, maxval=params.ly_m)
    z = jax.random.uniform(kz, (n,), minval=depth[0], maxval=depth[1])
    return jnp.stack([x, y, z], axis=-1)


def sample_deployment(key: jax.Array, params: DeploymentParams) -> Deployment:
    """Sample a fresh deployment: uniform (x, y), uniform depth per stratum."""
    ks, kf = jax.random.split(key)
    sensors = _uniform_stratum(ks, params.n_sensors, params, params.sensor_depth)
    fogs = _uniform_stratum(kf, params.n_fog, params, params.fog_depth)
    gateway = jnp.array([params.lx_m / 2.0, params.ly_m / 2.0, 0.0], jnp.float32)
    return Deployment(
        sensor_pos=sensors,
        fog_pos=fogs,
        fog_vel=jnp.zeros((params.n_fog, 3), jnp.float32),
        gateway_pos=gateway,
    )


def gauss_markov_step(
    key: jax.Array, dep: Deployment, params: DeploymentParams
) -> Deployment:
    """Drift fog nodes one round with a Gauss-Markov mobility model.

    v_{t+1} = a v_t + (1-a) v_mean + sqrt(1-a^2) sigma w,  w ~ N(0, I).
    Mean velocity is zero (station-keeping AUVs); positions are reflected
    into the deployment volume and clamped to the fog stratum depth band.
    """
    a = params.gm_alpha
    sigma = params.fog_speed_m_s
    noise = jax.random.normal(key, dep.fog_vel.shape) * sigma
    vel = a * dep.fog_vel + jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * noise
    pos = dep.fog_pos + vel * params.round_interval_s

    lo = jnp.array([0.0, 0.0, params.fog_depth[0]], jnp.float32)
    hi = jnp.array(
        [params.lx_m, params.ly_m, params.fog_depth[1]], jnp.float32
    )
    # Reflect off the boundaries; flip the corresponding velocity component.
    over_hi = pos > hi
    under_lo = pos < lo
    pos = jnp.where(over_hi, 2.0 * hi - pos, pos)
    pos = jnp.where(under_lo, 2.0 * lo - pos, pos)
    pos = jnp.clip(pos, lo, hi)  # guard pathological double-reflection
    vel = jnp.where(over_hi | under_lo, -vel, vel)
    return Deployment(dep.sensor_pos, pos, vel, dep.gateway_pos)


def current_advection_step(
    dep: Deployment, params: DeploymentParams, speed_m_s: float | jax.Array
) -> Deployment:
    """Advect SENSORS one round interval in a depth-sheared ocean current.

    The current is horizontal and deterministic — direction rotates with
    depth (a crude thermocline shear: ``(cos, sin)(2 pi z / depth_m)``)
    so co-located sensors at different depths separate over time.
    Determinism is load-bearing: the drift layer must not consume PRNG
    keys, keeping drift-off round numerics bit-identical to the legacy
    path.  ``speed_m_s`` is traceable (a ``DriftConfig`` sweep leaf).
    Positions reflect into the sensor stratum exactly like the fog walk.
    """
    s = jnp.asarray(speed_m_s, jnp.float32)
    z = dep.sensor_pos[:, 2]
    phase = 2.0 * jnp.pi * z / params.depth_m
    vel = jnp.stack(
        [s * jnp.cos(phase), s * jnp.sin(phase), jnp.zeros_like(z)], axis=-1
    )
    pos = dep.sensor_pos + vel * params.round_interval_s

    lo = jnp.array([0.0, 0.0, params.sensor_depth[0]], jnp.float32)
    hi = jnp.array(
        [params.lx_m, params.ly_m, params.sensor_depth[1]], jnp.float32
    )
    over_hi = pos > hi
    under_lo = pos < lo
    pos = jnp.where(over_hi, 2.0 * hi - pos, pos)
    pos = jnp.where(under_lo, 2.0 * lo - pos, pos)
    pos = jnp.clip(pos, lo, hi)
    return Deployment(pos, dep.fog_pos, dep.fog_vel, dep.gateway_pos)
