"""Hierarchical federated learning main loop (paper Algorithm 1).

The whole federated round is ONE jitted function; training scans it over T
rounds.  Clients are a vmapped leading axis (their local SGD runs in
parallel), fog clusters are segment-sum groups, and the three cooperation
rules from Sec. V-B drive the mixing step.  Per-round energy (Eqs. 17-20),
latency (Eq. 21), participation, and battery dynamics are all recorded.

The sensor side of a round is TWO fused operators by default.  Local
training (Eq. 12) runs through :func:`repro.optim.sgd.make_client_solver`:
for the paper autoencoder the whole E-epoch SGD phase of every client is
one VMEM-resident kernel launch (``kernels/fused_local_train``, jnp oracle
``kernels/ref.local_train_ref``) that indexes each client's resident
window per minibatch instead of gathering a dense ``(E * nb, bs, D)``
batch stream — set ``HFLConfig.local_solver = LocalTrainConfig(
fused=False)`` for the legacy per-client scan (non-AE models fall back
automatically).  Compression (Eq. 30) and fog aggregation (Eq. 13) then
run as the second fused operator —
:func:`repro.core.aggregation.compress_and_aggregate` — so the dense
per-client reconstructions never materialise either; set
``CompressorConfig.fused=False`` for the legacy two-pass pipeline.

Pass ``client_mesh`` (a 1-D ``("data",)`` mesh, see
``launch/sharding.client_mesh``) to :func:`train` / :func:`make_round_fn`
to shard the client axis over devices: local SGD + compression run
per-shard under ``shard_map`` and the fog buffers are reduced with psum
collectives, the multi-device analogue of the sensor->fog acoustic hop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg
from repro.core import association as assoc
from repro.core import channel as ch
from repro.core import compression as comp
from repro.core import cooperation as coop
from repro.core import drift as drf
from repro.core import energy as en
from repro.core import faults as flt
from repro.core import topology as topo
from repro.data.synthetic import SensorDataset
from repro.launch.mesh import shard_map_compat
from repro.optim import server as srv
from repro.optim.sgd import LocalTrainConfig, make_client_solver

Params = Any
LossFn = Callable[[Params, jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class HFLConfig:
    """Round-loop configuration — a pytree split into swept vs static.

    LEAVES (traceable, stackable along a config axis — see
    ``Engine.sweep``): ``lr``, ``prox_mu``, ``server_lr``,
    ``compute_rate_flops``, ``trim_frac`` and the nested ``compressor``
    (its ``rho_s``), ``channel``, ``energy``, ``faults`` pytrees.
    Everything shape- or structure-bearing — rule enum, round/epoch/batch
    counts, solver and backend flags, deployment geometry, the ``robust``
    aggregation rule — is static aux data: configs that differ there
    belong to different sweep shape-classes and are never co-batched.

    Robustness: ``robust`` selects the fog reduce — ``"mean"`` (Eq. 13
    weighted mean, the default), ``"trimmed"`` (coordinate-wise weighted
    trimmed mean, cutting ``trim_frac`` of the member weight from each
    end), or ``"median"``.  ``faults`` injects crashes / Byzantine deltas /
    packet erasure (see :mod:`repro.core.faults`); when it is statically
    inactive and ``robust == "mean"`` the round loop is bit-identical to
    the legacy path (same PRNG splits).

    Dynamic world: ``drift`` (see :mod:`repro.core.drift`) advects the
    sensors in a deterministic current inside the round scan, freezes the
    sensor->fog assignment between ``reassoc_every``-round re-association
    refreshes (stale assignment, live physics), and applies a per-round
    covariate-shift schedule to the client training windows.  The layer
    is deterministic — it consumes no PRNG keys — so with
    ``drift.is_active`` False the round is bit-identical to the legacy
    path, and a neutral-active cell (zero rates, unit cadence) pins
    bit-identical too.
    """

    rule: coop.CoopRule = coop.CoopRule.SELECTIVE
    rounds: int = 20
    local_epochs: int = 5            # E
    batch_size: int = 32
    lr: float | Any = 0.01           # eta
    prox_mu: float | Any = 0.0       # >0 => FedProx local solver
    server_opt: str = "sgd"          # "sgd" (FedAvg identity) | "adam" (FedAdam [34])
    server_lr: float | Any = 1e-2
    local_solver: LocalTrainConfig = LocalTrainConfig()
    compressor: comp.CompressorConfig = comp.CompressorConfig()
    fog_mobility: bool = True
    compute_rate_flops: float | Any = 1e8  # embedded-DSP local compute rate
    # Fog exchange payloads are full precision in the paper (Sec. VI-A).
    channel: ch.ChannelParams = ch.ChannelParams()
    energy: en.EnergyParams = en.EnergyParams()
    deployment: topo.DeploymentParams = topo.DeploymentParams()
    robust: str = "mean"             # fog reduce: mean | trimmed | median
    trim_frac: float | Any = 0.0     # weight fraction cut per end (trimmed)
    faults: flt.FaultConfig = flt.FaultConfig()
    drift: drf.DriftConfig = drf.DriftConfig()
    # Client-phase memory bound: compress/accumulate scans the client axis
    # in chunks of this many sensors, so transient HBM/VMEM high-water
    # marks scale with the chunk, not the fleet.  None (or >= N) keeps the
    # one-shot path bit-identically; STATIC (it is shape-bearing).  Under
    # ``shard_clients`` the chunk applies within each shard's local slice.
    client_chunk: int | None = None

    def __post_init__(self) -> None:
        if self.robust not in ("mean", "trimmed", "median"):
            raise ValueError(
                f"robust must be 'mean', 'trimmed' or 'median', got "
                f"{self.robust!r}"
            )
        # Concrete values only: trim_frac is a sweep leaf, so traced /
        # stacked values pass (``__post_init__`` re-runs on unflatten).
        tf = self.trim_frac
        if isinstance(tf, (int, float)) and not 0.0 <= tf < 0.5:
            raise ValueError(
                "trim_frac cuts a weight fraction from EACH end and must "
                f"be in [0, 0.5), got {tf!r}"
            )
        cc = self.client_chunk
        if cc is not None and (not isinstance(cc, int) or cc < 1):
            raise ValueError(
                f"client_chunk must be None or a positive int, got {cc!r}"
            )

    def replace(self, **kw: Any) -> "HFLConfig":
        return dataclasses.replace(self, **kw)


_HFL_LEAF_FIELDS = (
    "lr", "prox_mu", "server_lr", "compute_rate_flops",
    "compressor", "channel", "energy", "trim_frac", "faults", "drift",
)
_HFL_AUX_FIELDS = (
    "rule", "rounds", "local_epochs", "batch_size", "server_opt",
    "local_solver", "fog_mobility", "deployment", "robust", "client_chunk",
)


def _hfl_cfg_flatten(c: HFLConfig):
    return (
        tuple(getattr(c, f) for f in _HFL_LEAF_FIELDS),
        tuple(getattr(c, f) for f in _HFL_AUX_FIELDS),
    )


def _hfl_cfg_unflatten(aux, children) -> HFLConfig:
    kw = dict(zip(_HFL_LEAF_FIELDS, children))
    kw.update(zip(_HFL_AUX_FIELDS, aux))
    return HFLConfig(**kw)


jax.tree_util.register_pytree_node(
    HFLConfig, _hfl_cfg_flatten, _hfl_cfg_unflatten
)


class RoundMetrics(NamedTuple):
    loss: jax.Array
    e_s2f: jax.Array          # Eq. 17
    e_f2f: jax.Array          # Eq. 18
    e_f2g: jax.Array          # Eq. 19
    e_total: jax.Array        # Eq. 20
    latency_s: jax.Array      # Eq. 21
    participation: jax.Array
    coop_links: jax.Array     # number of active fog-to-fog exchanges
    battery_min: jax.Array
    # Robustness counters (zero / True on the clean legacy path):
    n_nonfinite: jax.Array    # delivered deltas carrying NaN/Inf (zeroed)
    n_erased: jax.Array       # transmitted packets lost to erasure
    global_finite: jax.Array  # bool — global params finite after the round


class HFLState(NamedTuple):
    params: Params            # global model theta^t
    err: jax.Array            # (N, d) error-feedback buffers
    battery: jax.Array        # (N,) residual energy
    dep: topo.Deployment
    key: jax.Array
    server: srv.ServerOptState  # gateway optimiser state (FedAdam)
    # Dynamic-world carry (zeros when drift/adaptive attack are off; the
    # drift layer refreshes the assignment at round 0 before first use):
    assoc_fog: jax.Array      # (N,) int32 — frozen sensor->fog assignment
    assoc_ok: jax.Array       # (N,) bool — feasible at assignment time
    t: jax.Array              # () int32 — round counter
    prev_delta: jax.Array     # (d,) last global delta (adaptive colluders)


def init_state(
    key: jax.Array, params: Params, cfg: HFLConfig
) -> HFLState:
    kd, kr = jax.random.split(key)
    dep = topo.sample_deployment(kd, cfg.deployment)
    flat, _ = ravel_pytree(params)
    n = cfg.deployment.n_sensors
    return HFLState(
        params=params,
        err=jnp.zeros((n, flat.shape[0]), flat.dtype),
        battery=jnp.full((n,), cfg.energy.e_init_j),
        dep=dep,
        key=kr,
        server=srv.init_state(flat.shape[0]),
        assoc_fog=jnp.zeros((n,), jnp.int32),
        assoc_ok=jnp.zeros((n,), bool),
        t=jnp.int32(0),
        prev_delta=jnp.zeros((flat.shape[0],), flat.dtype),
    )


def _client_train_fn(loss_fn: LossFn, cfg: HFLConfig):
    """Batched client phase: E-epoch local SGD from the broadcast params
    for EVERY client at once, returning flat deltas (fused kernel path by
    default; see :func:`repro.optim.sgd.make_client_solver`)."""
    return make_client_solver(
        loss_fn,
        batch_size=cfg.batch_size,
        epochs=cfg.local_epochs,
        lr=cfg.lr,
        prox_mu=cfg.prox_mu,
        solver=cfg.local_solver,
    )


def _clients_round(
    clients_fn, params, data, keys, err, weights, fog_id, n_fog, cc,
    axis: str | None = None,
    chunk: int | None = None,
):
    """Train every client and fuse compression into the fog reduction.

    The sensor side in two fused operators: ``clients_fn`` (the batched
    local-train solver from :func:`_client_train_fn`) emits the flat
    deltas, which chain straight into the fused compress-and-aggregate.
    With ``axis`` set this is the shard_map body: each shard trains its
    slice of the client axis and contributes partial fog sums; the psum
    pair is the sensor->fog hop (cf. aggregation.hierarchical_mean).
    Returns (fog_delta (n_fog, d) — Eq. 13 cluster means — fog_weight,
    new_err (N_local, d), losses (N_local,)).
    """
    deltas, losses = clients_fn(params, data, keys)
    fog_delta, fog_weight, new_err = agg.compress_and_aggregate(
        deltas, err, fog_id, weights, n_fog, cc, axis=axis, chunk=chunk
    )
    return fog_delta, fog_weight, new_err, losses


def comm_latency_s(
    l_u: jax.Array,
    l_full: jax.Array,
    active: jax.Array,
    sensor_dist_m: jax.Array,
    decision: coop.CoopDecision,
    fog_active: jax.Array,
    fog_gateway_dist_m: jax.Array,
    channel: ch.ChannelParams,
) -> jax.Array:
    """Eq. 21 communication term: the slowest active parallel link per
    tier (sensor->fog uplink, fog<->fog exchange, fog->gateway).

    Every tier masks on the links that actually carry a payload.  In
    particular the fog-to-fog tier masks on ``cooperates & fog_active``,
    matching the Eq. 18 energy term: an EMPTY fog cluster has no model to
    exchange, so a phantom pairing with a distant partner must not set the
    round's latency.
    """
    lat_up = jnp.max(
        jnp.where(
            active, en.link_latency_s(l_u, sensor_dist_m, channel), 0.0
        )
    )
    lat_ff = jnp.max(
        jnp.where(
            decision.cooperates & fog_active,
            en.link_latency_s(l_full, decision.dist_m, channel),
            0.0,
        )
    )
    lat_fg = jnp.max(
        jnp.where(
            fog_active,
            en.link_latency_s(l_full, fog_gateway_dist_m, channel),
            0.0,
        )
    )
    return jnp.maximum(jnp.maximum(lat_up, lat_ff), lat_fg)


def make_round_fn(
    loss_fn: LossFn,
    ds: SensorDataset,
    cfg: HFLConfig,
    *,
    client_mesh: Mesh | None = None,
) -> Callable[[HFLState, None], tuple[HFLState, RoundMetrics]]:
    """Build the jittable single-round function (Algorithm 1).

    ``client_mesh``: optional 1-D ``("data",)`` mesh; when given, the
    client axis (local SGD + fused compression) is sharded over its
    devices with fog reduction via psum collectives.  Requires the sensor
    count to divide the mesh size.
    """

    n_fog = cfg.deployment.n_fog
    clients_fn = _client_train_fn(loss_fn, cfg)
    if cfg.robust not in ("mean", "trimmed", "median"):
        raise ValueError(
            f"robust must be 'mean', 'trimmed' or 'median', got "
            f"{cfg.robust!r}"
        )
    fl = cfg.faults
    fault_on = fl.is_active       # STATIC: off => exact legacy round
    dr = cfg.drift
    drift_on = dr.is_active       # STATIC: off => exact legacy round
    adaptive = fault_on and fl.byz_mode == "adaptive"
    if client_mesh is not None and (fault_on or cfg.robust != "mean"):
        raise ValueError(
            "client-sharded rounds do not support fault injection or "
            "robust aggregation (the per-client reconstructions never "
            "leave their shard)"
        )
    if client_mesh is not None and drift_on:
        raise ValueError(
            "client-sharded rounds do not support the drift layer yet"
        )
    if client_mesh is not None and ds.train.shape[0] % client_mesh.size != 0:
        raise ValueError(
            f"client axis ({ds.train.shape[0]} sensors) must divide the "
            f"({client_mesh.size})-device client mesh"
        )

    def round_fn(state: HFLState, _) -> tuple[HFLState, RoundMetrics]:
        if fault_on:
            key, k_mob, k_train, k_byz, k_crash, k_erase = jax.random.split(
                state.key, 6
            )
        else:
            key, k_mob, k_train = jax.random.split(state.key, 3)
        dep = state.dep
        if cfg.fog_mobility:
            dep = topo.gauss_markov_step(k_mob, dep, cfg.deployment)
        if drift_on:
            dep = topo.current_advection_step(
                dep, cfg.deployment, dr.sensor_current_m_s
            )

        # --- 1. association + cooperation decisions (lines 1-7) ----------
        if drift_on:
            # Stale assignment, live physics: refresh the carried
            # sensor->fog assignment every ``reassoc_every`` rounds (round
            # 0 always refreshes), then recompute distances / feasibility /
            # clusters from CURRENT geometry against the frozen fog id.
            t_f = state.t.astype(jnp.float32)
            cadence = jnp.maximum(
                jnp.asarray(dr.reassoc_every, jnp.float32), 1.0
            )
            refresh = jnp.mod(t_f, cadence) < 0.5
            fresh = assoc.nearest_feasible_fog(dep, cfg.channel)
            assoc_fog = jnp.where(refresh, fresh.fog_id, state.assoc_fog)
            assoc_ok = jnp.where(refresh, fresh.participates, state.assoc_ok)
            fa = assoc.assigned_fog_association(
                dep, cfg.channel, assoc_fog, assoc_ok
            )
        else:
            assoc_fog, assoc_ok = state.assoc_fog, state.assoc_ok
            fa = assoc.nearest_feasible_fog(dep, cfg.channel)
        alive = state.battery > cfg.energy.e_min_j
        active = fa.participates & alive
        if fault_on:
            # Crashed clients drop out like a dead battery: no training,
            # no transmission, no energy spend this round.
            active = active & ~flt.draw_crash(
                k_crash, alive.shape[0], fl.crash_prob
            )
        # Cooperation sees ROUND-ACTIVE cluster sizes (battery included):
        # a cluster whose sensors are all dead this round holds no
        # aggregate to exchange, exactly like an empty one — so the
        # decision, the Eq. 15 mixing, and the Eq. 18/21 masks agree.
        c_active = jax.ops.segment_sum(
            active.astype(jnp.int32), fa.fog_id, num_segments=n_fog
        )
        decision = coop.decide(cfg.rule, dep.fog_pos, c_active, cfg.channel)

        # --- 2+3. local training, fused compression + fog aggregation
        # (lines 8-18, Eqs. 30 + 13 as one operator) -----------------------
        flat0, unravel = ravel_pytree(state.params)
        d = flat0.shape[0]
        n = ds.train.shape[0]
        keys = jax.random.split(k_train, n)
        train = ds.train
        if drift_on:
            # Deterministic covariate-shift schedule: the telemetry scale
            # drifts a fraction per round (zero shift multiplies by 1.0,
            # which is bit-exact).
            train = train * (1.0 + dr.covariate_shift * t_f)

        active_f = active.astype(jnp.float32)
        # Erasure strikes AFTER the SNR feasibility gate: the packet was
        # transmitted (energy still charged below, EF buffer still
        # advances) but the fog never decodes it — only the aggregation
        # weight vanishes.
        if fault_on:
            erased = active & flt.draw_erasure(k_erase, n, fl.erasure_prob)
        else:
            erased = jnp.zeros_like(active)
        delivered = active & ~erased
        weights = ds.n_samples * delivered.astype(jnp.float32)

        if client_mesh is None:
            deltas, losses = clients_fn(state.params, train, keys)
            if fault_on:
                deltas = flt.corrupt_deltas(
                    k_byz, deltas, fl, prev_delta=state.prev_delta
                )
            n_nonfinite = jnp.sum(
                (delivered & flt.nonfinite_rows(deltas)).astype(jnp.int32)
            )
            if cfg.robust == "mean":
                fog_sum, fog_weight, new_err = agg.compress_and_accumulate(
                    deltas, state.err, fa.fog_id, weights, n_fog,
                    cfg.compressor, chunk=cfg.client_chunk,
                )
                fog_delta = fog_sum / jnp.maximum(fog_weight, 1e-12)[:, None]
            else:
                fog_delta, fog_weight, new_err = (
                    agg.robust_compress_and_aggregate(
                        deltas, state.err, fa.fog_id, weights, n_fog,
                        cfg.compressor, cfg.trim_frac, cfg.robust,
                        chunk=cfg.client_chunk,
                    )
                )
        else:
            sharded = shard_map_compat(
                lambda p, dat, kk, e, w, fid: _clients_round(
                    clients_fn, p, dat, kk, e, w, fid, n_fog,
                    cfg.compressor, axis="data", chunk=cfg.client_chunk,
                ),
                mesh=client_mesh,
                in_specs=(P(), P("data"), P("data"), P("data"),
                          P("data"), P("data")),
                out_specs=(P(), P(), P("data"), P("data")),
            )
            fog_delta, fog_weight, new_err, losses = sharded(
                state.params, train, keys, state.err, weights, fa.fog_id
            )
            # Sharded deltas never leave their shard: the isfinite guard
            # inside compress_and_accumulate still protects, only the
            # counter is unavailable there.
            n_nonfinite = jnp.int32(0)
        # Non-participants keep their error buffer and contribute nothing.
        new_err = jnp.where(active[:, None], new_err, state.err)

        fog_model = fog_delta + flat0[None, :]          # theta_m^{t+1/2}
        mixed = agg.cooperative_mix(fog_model, decision)  # Eq. 15

        # --- 4. global aggregation (Eq. 16, lines 19-21) -------------------
        # prev=flat0: a dead-network round (every cluster weightless) holds
        # the global model instead of collapsing it to zeros.
        new_flat = agg.global_aggregate(mixed, fog_weight, prev=flat0)
        if cfg.server_opt == "adam":
            # FedAdam [34]: the aggregated movement is a pseudo-gradient.
            incr, server = srv.adam_update(
                new_flat - flat0, state.server, lr=cfg.server_lr
            )
            new_flat = flat0 + incr
        else:
            server = state.server
        new_params = unravel(new_flat)

        # --- 5. energy / latency / battery accounting ---------------------
        l_u = comp.payload_bits(d, cfg.compressor)     # sensor uplink bits
        l_full = 32.0 * d                               # fog exchanges, dense
        e_up = en.tx_energy_j(l_u, fa.dist_m, cfg.channel, cfg.energy)
        e_up = jnp.where(active, e_up, 0.0)
        e_s2f = jnp.sum(e_up)

        fog_active = fog_weight > 0
        e_ff = en.tx_energy_j(l_full, decision.dist_m, cfg.channel, cfg.energy)
        e_ff = jnp.where(decision.cooperates & fog_active, e_ff, 0.0)
        e_f2f = jnp.sum(e_ff)

        e_fg = en.tx_energy_j(
            l_full, fa.fog_gateway_dist_m, cfg.channel, cfg.energy
        )
        e_fg = jnp.where(fog_active & fa.fog_gateway_feasible, e_fg, 0.0)
        e_f2g = jnp.sum(e_fg)

        # Latency (Eq. 21): slowest parallel link per tier + compute time.
        lat_comm = comm_latency_s(
            l_u, l_full, active, fa.dist_m, decision, fog_active,
            fa.fog_gateway_dist_m, cfg.channel,
        )
        flops = en.autoencoder_flops(
            ds.train.shape[-1], (16, 8, 16), ds.train.shape[1], cfg.local_epochs
        )
        lat_comp = flops / cfg.compute_rate_flops
        latency = lat_comm + lat_comp

        e_comp = en.compute_energy_j(jnp.float32(flops), cfg.energy)
        spent = e_up + jnp.where(active, e_comp, 0.0)
        battery, _ = en.battery_step(state.battery, spent, cfg.energy)

        metrics = RoundMetrics(
            loss=jnp.sum(losses * active_f) / jnp.maximum(jnp.sum(active_f), 1.0),
            e_s2f=e_s2f,
            e_f2f=e_f2f,
            e_f2g=e_f2g,
            e_total=e_s2f + e_f2f + e_f2g,
            latency_s=latency,
            participation=jnp.mean(active_f),
            coop_links=jnp.sum(decision.cooperates.astype(jnp.int32)),
            battery_min=jnp.min(battery),
            n_nonfinite=n_nonfinite,
            n_erased=jnp.sum(erased.astype(jnp.int32)),
            global_finite=jnp.all(jnp.isfinite(new_flat)),
        )
        # Adaptive colluders observe the realised global movement; other
        # modes leave the carried delta untouched (identical graph).
        prev_delta = new_flat - flat0 if adaptive else state.prev_delta
        return (
            HFLState(
                new_params, new_err, battery, dep, key, server,
                assoc_fog, assoc_ok, state.t + 1, prev_delta,
            ),
            metrics,
        )

    return round_fn


def train(
    key: jax.Array,
    init_params: Params,
    loss_fn: LossFn,
    ds: SensorDataset,
    cfg: HFLConfig,
    *,
    client_mesh: Mesh | None = None,
    store: Any | None = None,
    publish_every: int = 1,
    publish_offset: int = 0,
) -> tuple[Params, RoundMetrics]:
    """Run T federated rounds; returns (final params, stacked metrics).

    With ``store`` (a ``checkpoint.CheckpointStore``) the loop publishes
    the global params every ``publish_every`` rounds (step = round index +
    ``publish_offset``; the final round always publishes), which is what
    the serving hot-swap (``serving/service.ScoringService``) watches.
    Publishing runs the rounds as a Python loop over ONE jitted round
    function instead of a ``lax.scan`` — identical numerics, same single
    compilation, but with host-visible params between rounds.
    """
    state = init_state(key, init_params, cfg)
    round_fn = make_round_fn(loss_fn, ds, cfg, client_mesh=client_mesh)
    if store is None or cfg.rounds == 0:
        # scan handles length 0 cleanly (and 0 rounds publish nothing).
        final, metrics = jax.lax.scan(round_fn, state, None, length=cfg.rounds)
        return final.params, metrics

    # Donating the carry lets each round update the HFLState — the (N, d)
    # error buffer included — in place instead of copying it per round.
    # state.params aliases the caller's ``init_params`` buffers, which the
    # first donated call would invalidate, so copy that one leaf up front.
    state = state._replace(
        params=jax.tree_util.tree_map(jnp.copy, state.params)
    )
    step_fn = jax.jit(lambda s: round_fn(s, None), donate_argnums=0)
    rounds_metrics = []
    for t in range(cfg.rounds):
        state, m = step_fn(state)
        rounds_metrics.append(m)
        if (t + 1) % publish_every == 0 or t + 1 == cfg.rounds:
            store.publish(publish_offset + t + 1, state.params)
    metrics = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *rounds_metrics
    )
    return state.params, metrics
