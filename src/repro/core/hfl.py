"""Hierarchical federated learning main loop (paper Algorithm 1).

The whole federated round is ONE jitted function; training scans it over T
rounds.  Clients are a vmapped leading axis (their local SGD runs in
parallel), fog clusters are segment-sum groups, and the three cooperation
rules from Sec. V-B drive the mixing step.  Per-round energy (Eqs. 17-20),
latency (Eq. 21), participation, and battery dynamics are all recorded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import aggregation as agg
from repro.core import association as assoc
from repro.core import channel as ch
from repro.core import compression as comp
from repro.core import cooperation as coop
from repro.core import energy as en
from repro.core import topology as topo
from repro.data.pipeline import multi_epoch_batches
from repro.data.synthetic import SensorDataset
from repro.optim import server as srv
from repro.optim.sgd import local_sgd, proximal_local_sgd

Params = Any
LossFn = Callable[[Params, jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class HFLConfig:
    rule: coop.CoopRule = coop.CoopRule.SELECTIVE
    rounds: int = 20
    local_epochs: int = 5            # E
    batch_size: int = 32
    lr: float = 0.01                 # eta
    prox_mu: float = 0.0             # >0 => FedProx local solver
    server_opt: str = "sgd"          # "sgd" (FedAvg identity) | "adam" (FedAdam [34])
    server_lr: float = 1e-2
    compressor: comp.CompressorConfig = comp.CompressorConfig()
    fog_mobility: bool = True
    compute_rate_flops: float = 1e8  # embedded-DSP local compute rate
    # Fog exchange payloads are full precision in the paper (Sec. VI-A).
    channel: ch.ChannelParams = ch.ChannelParams()
    energy: en.EnergyParams = en.EnergyParams()
    deployment: topo.DeploymentParams = topo.DeploymentParams()

    def replace(self, **kw: Any) -> "HFLConfig":
        return dataclasses.replace(self, **kw)


class RoundMetrics(NamedTuple):
    loss: jax.Array
    e_s2f: jax.Array          # Eq. 17
    e_f2f: jax.Array          # Eq. 18
    e_f2g: jax.Array          # Eq. 19
    e_total: jax.Array        # Eq. 20
    latency_s: jax.Array      # Eq. 21
    participation: jax.Array
    coop_links: jax.Array     # number of active fog-to-fog exchanges
    battery_min: jax.Array


class HFLState(NamedTuple):
    params: Params            # global model theta^t
    err: jax.Array            # (N, d) error-feedback buffers
    battery: jax.Array        # (N,) residual energy
    dep: topo.Deployment
    key: jax.Array
    server: srv.ServerOptState  # gateway optimiser state (FedAdam)


def init_state(
    key: jax.Array, params: Params, cfg: HFLConfig
) -> HFLState:
    kd, kr = jax.random.split(key)
    dep = topo.sample_deployment(kd, cfg.deployment)
    flat, _ = ravel_pytree(params)
    n = cfg.deployment.n_sensors
    return HFLState(
        params=params,
        err=jnp.zeros((n, flat.shape[0]), flat.dtype),
        battery=jnp.full((n,), cfg.energy.e_init_j),
        dep=dep,
        key=kr,
        server=srv.init_state(flat.shape[0]),
    )


def _local_train(
    loss_fn: LossFn,
    params: Params,
    data: jax.Array,
    key: jax.Array,
    cfg: HFLConfig,
) -> tuple[Params, jax.Array]:
    batches = multi_epoch_batches(key, data, cfg.batch_size, cfg.local_epochs)
    if cfg.prox_mu > 0.0:
        return proximal_local_sgd(loss_fn, params, batches, cfg.lr, cfg.prox_mu)
    return local_sgd(loss_fn, params, batches, cfg.lr)


def make_round_fn(
    loss_fn: LossFn, ds: SensorDataset, cfg: HFLConfig
) -> Callable[[HFLState, None], tuple[HFLState, RoundMetrics]]:
    """Build the jittable single-round function (Algorithm 1)."""

    n_fog = cfg.deployment.n_fog
    d_model = None  # resolved at first trace via ravel

    def round_fn(state: HFLState, _) -> tuple[HFLState, RoundMetrics]:
        key, k_mob, k_train = jax.random.split(state.key, 3)
        dep = state.dep
        if cfg.fog_mobility:
            dep = topo.gauss_markov_step(k_mob, dep, cfg.deployment)

        # --- 1. association + cooperation decisions (lines 1-7) ----------
        fa = assoc.nearest_feasible_fog(dep, cfg.channel)
        decision = coop.decide(cfg.rule, dep.fog_pos, fa.cluster_size, cfg.channel)

        alive = state.battery > cfg.energy.e_min_j
        active = fa.participates & alive

        # --- 2. local training & compression (lines 8-13) ----------------
        flat0, unravel = ravel_pytree(state.params)
        d = flat0.shape[0]
        n = ds.train.shape[0]
        keys = jax.random.split(k_train, n)

        def client_step(data, k, err):
            p1, loss = _local_train(loss_fn, state.params, data, k, cfg)
            delta = jax.tree_util.tree_map(
                lambda a, b: a - b, p1, state.params
            )
            recon, new_err = comp.compress_update(delta, err, cfg.compressor)
            return ravel_pytree(recon)[0], new_err, loss

        deltas, new_err, losses = jax.vmap(client_step)(
            ds.train, keys, state.err
        )
        # Non-participants keep their error buffer and contribute nothing.
        active_f = active.astype(jnp.float32)
        new_err = jnp.where(active[:, None], new_err, state.err)
        weights = ds.n_samples * active_f

        # --- 3. fog aggregation (Eq. 13, lines 14-18) ---------------------
        fog_delta, fog_weight = agg.fog_aggregate(
            deltas, fa.fog_id, weights, n_fog
        )
        fog_model = fog_delta + flat0[None, :]          # theta_m^{t+1/2}
        mixed = agg.cooperative_mix(fog_model, decision)  # Eq. 15

        # --- 4. global aggregation (Eq. 16, lines 19-21) -------------------
        new_flat = agg.global_aggregate(mixed, fog_weight)
        if cfg.server_opt == "adam":
            # FedAdam [34]: the aggregated movement is a pseudo-gradient.
            incr, server = srv.adam_update(
                new_flat - flat0, state.server, lr=cfg.server_lr
            )
            new_flat = flat0 + incr
        else:
            server = state.server
        new_params = unravel(new_flat)

        # --- 5. energy / latency / battery accounting ---------------------
        l_u = comp.payload_bits(d, cfg.compressor)     # sensor uplink bits
        l_full = 32.0 * d                               # fog exchanges, dense
        e_up = en.tx_energy_j(l_u, fa.dist_m, cfg.channel, cfg.energy)
        e_up = jnp.where(active, e_up, 0.0)
        e_s2f = jnp.sum(e_up)

        fog_active = fog_weight > 0
        e_ff = en.tx_energy_j(l_full, decision.dist_m, cfg.channel, cfg.energy)
        e_ff = jnp.where(decision.cooperates & fog_active, e_ff, 0.0)
        e_f2f = jnp.sum(e_ff)

        e_fg = en.tx_energy_j(
            l_full, fa.fog_gateway_dist_m, cfg.channel, cfg.energy
        )
        e_fg = jnp.where(fog_active & fa.fog_gateway_feasible, e_fg, 0.0)
        e_f2g = jnp.sum(e_fg)

        # Latency (Eq. 21): slowest parallel link per tier + compute time.
        lat_up = jnp.max(
            jnp.where(active, en.link_latency_s(l_u, fa.dist_m, cfg.channel), 0.0)
        )
        lat_ff = jnp.max(
            jnp.where(
                decision.cooperates,
                en.link_latency_s(l_full, decision.dist_m, cfg.channel),
                0.0,
            )
        )
        lat_fg = jnp.max(
            jnp.where(
                fog_active,
                en.link_latency_s(l_full, fa.fog_gateway_dist_m, cfg.channel),
                0.0,
            )
        )
        flops = en.autoencoder_flops(
            ds.train.shape[-1], (16, 8, 16), ds.train.shape[1], cfg.local_epochs
        )
        lat_comp = flops / cfg.compute_rate_flops
        latency = jnp.maximum(jnp.maximum(lat_up, lat_ff), lat_fg) + lat_comp

        e_comp = en.compute_energy_j(jnp.float32(flops), cfg.energy)
        spent = e_up + jnp.where(active, e_comp, 0.0)
        battery, _ = en.battery_step(state.battery, spent, cfg.energy)

        metrics = RoundMetrics(
            loss=jnp.sum(losses * active_f) / jnp.maximum(jnp.sum(active_f), 1.0),
            e_s2f=e_s2f,
            e_f2f=e_f2f,
            e_f2g=e_f2g,
            e_total=e_s2f + e_f2f + e_f2g,
            latency_s=latency,
            participation=jnp.mean(active_f),
            coop_links=jnp.sum(decision.cooperates.astype(jnp.int32)),
            battery_min=jnp.min(battery),
        )
        return (
            HFLState(new_params, new_err, battery, dep, key, server),
            metrics,
        )

    return round_fn


def train(
    key: jax.Array,
    init_params: Params,
    loss_fn: LossFn,
    ds: SensorDataset,
    cfg: HFLConfig,
) -> tuple[Params, RoundMetrics]:
    """Run T federated rounds; returns (final params, stacked metrics)."""
    state = init_state(key, init_params, cfg)
    round_fn = make_round_fn(loss_fn, ds, cfg)
    final, metrics = jax.lax.scan(round_fn, state, None, length=cfg.rounds)
    return final.params, metrics
