"""Underwater acoustic channel model (paper Sec. III-B/C).

Pure-JAX, fully vectorised: every function accepts scalars or arrays and
broadcasts.  All quantities follow the paper's conventions:

  - transmission loss  TL(d, f) = 10 k log10(d) + alpha(f) d/1000      (Eq. 1)
  - Thorp absorption   alpha(f) in dB/km, f in kHz                     (Eq. 2)
  - Wenz ambient noise PSD, four components combined in linear scale   (Eq. 3)
  - passive-sonar SNR  SNR = SL - TL - NL - IL                         (Eq. 4)

The feasibility graph (Eq. 6) is expressed through ``min_source_level`` in
:mod:`repro.core.energy` plus :func:`feasible` here.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

SOUND_SPEED_M_S = 1500.0
P_REF_PA = 1e-6
RHO_WATER = 1025.0


@dataclasses.dataclass(frozen=True)
class ChannelParams:
    """Acoustic parameters (paper Table II baseline).

    Registered as a pytree whose every field is a leaf: all eight knobs are
    used purely arithmetically downstream, so a sweep can stack several
    parameter sets along a leading config axis and ``vmap`` the physics
    (see ``Engine.sweep``).  Plain Python floats keep the class hashable
    for program-cache keys; traced leaves appear only inside sweeps.
    """

    freq_khz: float = 12.0          # carrier frequency f (kHz)
    bandwidth_hz: float = 4000.0    # receiver bandwidth B (Hz)
    spreading_k: float = 1.5        # spreading factor k
    wind_m_s: float = 5.0           # wind speed w (m/s)
    shipping: float = 0.5           # shipping activity s in [0, 1]
    gamma_tgt_db: float = 10.0      # target operating SNR (dB)
    impl_loss_db: float = 2.0       # implementation loss IL (dB)
    sl_max_db: float = 140.0        # capped source level (dB re 1 uPa @ 1 m)

    def replace(self, **kw: Any) -> "ChannelParams":
        return dataclasses.replace(self, **kw)


_CHANNEL_FIELDS = tuple(f.name for f in dataclasses.fields(ChannelParams))

jax.tree_util.register_pytree_node(
    ChannelParams,
    lambda c: (tuple(getattr(c, f) for f in _CHANNEL_FIELDS), None),
    lambda _, ch_: ChannelParams(**dict(zip(_CHANNEL_FIELDS, ch_))),
)


def thorp_absorption_db_per_km(f_khz: jax.Array | float) -> jax.Array:
    """Thorp absorption coefficient alpha(f) in dB/km, f in kHz (Eq. 2)."""
    f2 = jnp.square(jnp.asarray(f_khz, jnp.float32))
    return (
        0.11 * f2 / (1.0 + f2)
        + 44.0 * f2 / (4100.0 + f2)
        + 2.75e-4 * f2
        + 0.003
    )


def transmission_loss_db(
    dist_m: jax.Array, f_khz: float, spreading_k: float = 1.5
) -> jax.Array:
    """Large-scale transmission loss TL(d, f) in dB (Eq. 1).

    ``dist_m`` is clipped at 1 m (the source-level reference distance) so the
    log never goes negative for co-located nodes.
    """
    d = jnp.maximum(jnp.asarray(dist_m, jnp.float32), 1.0)
    alpha = thorp_absorption_db_per_km(f_khz)
    return 10.0 * spreading_k * jnp.log10(d) + alpha * d / 1000.0


def wenz_noise_psd_db(
    f_khz: float, wind_m_s: float = 5.0, shipping: float = 0.5
) -> jax.Array:
    """Wenz-type ambient-noise PSD N0(f) in dB re 1 uPa^2/Hz (Eq. 3).

    Component formulae follow Stojanovic (WONS'07), the reference the paper
    cites for the expressions:

      turbulence: 17 - 30 log10 f
      shipping:   40 + 20 (s - 0.5) + 26 log10 f - 60 log10(f + 0.03)
      wind:       50 + 7.5 sqrt(w) + 20 log10 f - 40 log10(f + 0.4)
      thermal:    -15 + 20 log10 f
    """
    f = jnp.asarray(f_khz, jnp.float32)
    logf = jnp.log10(f)
    n_turb = 17.0 - 30.0 * logf
    n_ship = 40.0 + 20.0 * (shipping - 0.5) + 26.0 * logf - 60.0 * jnp.log10(f + 0.03)
    n_wind = 50.0 + 7.5 * jnp.sqrt(wind_m_s) + 20.0 * logf - 40.0 * jnp.log10(f + 0.4)
    n_therm = -15.0 + 20.0 * logf
    stacked = jnp.stack([n_turb, n_ship, n_wind, n_therm])
    return 10.0 * jnp.log10(jnp.sum(10.0 ** (stacked / 10.0), axis=0))


def noise_level_db(params: ChannelParams) -> jax.Array:
    """Band noise level NL(f, B) = N0(f) + 10 log10 B (Sec. III-C)."""
    n0 = wenz_noise_psd_db(params.freq_khz, params.wind_m_s, params.shipping)
    return n0 + 10.0 * jnp.log10(jnp.asarray(params.bandwidth_hz, jnp.float32))


def snr_db(
    sl_db: jax.Array, dist_m: jax.Array, params: ChannelParams
) -> jax.Array:
    """Receiver SNR via the passive sonar equation (Eq. 4), DI = 0."""
    tl = transmission_loss_db(dist_m, params.freq_khz, params.spreading_k)
    nl = noise_level_db(params)
    return sl_db - tl - nl - params.impl_loss_db


def min_source_level_db(dist_m: jax.Array, params: ChannelParams) -> jax.Array:
    """Minimum source level to hit gamma_tgt at distance d (Eq. 5)."""
    tl = transmission_loss_db(dist_m, params.freq_khz, params.spreading_k)
    nl = noise_level_db(params)
    return params.gamma_tgt_db + tl + nl + params.impl_loss_db


def feasible(dist_m: jax.Array, params: ChannelParams) -> jax.Array:
    """Capped-source-level feasibility SL_min <= SL_max (Eq. 6). Boolean."""
    return min_source_level_db(dist_m, params) <= params.sl_max_db


def shannon_rate_bps(params: ChannelParams) -> jax.Array:
    """Shannon-type link rate at the target operating SNR (Sec. III-D)."""
    gamma_lin = 10.0 ** (params.gamma_tgt_db / 10.0)
    return params.bandwidth_hz * jnp.log2(1.0 + gamma_lin)


def propagation_delay_s(dist_m: jax.Array) -> jax.Array:
    """Acoustic propagation delay tau = d / c_s (Sec. III-B)."""
    return jnp.asarray(dist_m, jnp.float32) / SOUND_SPEED_M_S


def pairwise_distances(a: jax.Array, b: jax.Array) -> jax.Array:
    """Euclidean distance matrix between position sets a:(N,3) and b:(M,3)."""
    diff = a[:, None, :] - b[None, :, :]
    return jnp.sqrt(jnp.sum(jnp.square(diff), axis=-1) + 1e-12)


def max_feasible_range_m(params: ChannelParams, hi_m: float = 50_000.0) -> jax.Array:
    """Maximum feasible link distance under the SL cap (bisection).

    TL is monotone in d, so feasibility is a threshold in distance; 64
    bisection steps pin it to sub-millimetre accuracy.  Useful for analysis
    and tests, not on the training hot path.
    """

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        ok = feasible(mid, params)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, hi = jax.lax.fori_loop(
        0, 64, body, (jnp.float32(1.0), jnp.float32(hi_m))
    )
    return lo
