"""Dynamic-world knobs: mid-training drift + periodic re-association
(ROADMAP scenario diversity: time-varying topology and distribution
shift).

:class:`DriftConfig` is a registered pytree mirroring
:class:`repro.core.faults.FaultConfig`: the rates and the re-association
cadence are traceable sweep LEAVES, so ``Engine.sweep`` grids drift
cells exactly like the physics knobs, and the static aux datum is the
derived ``active`` on/off predicate, pinned through flatten/unflatten so
round loops can branch Python-side while the rates themselves are
tracers.  Pinning ``active=True`` on a zero-rate cell lets a drift grid
with a static corner co-batch into ONE shape-class.

Semantics (threaded through the round scans of ``core/hfl.py``,
``core/flat_fl.py`` and ``core/async_fl.py``):

* **Sensor current advection** — a deterministic depth-sheared
  horizontal current (``topology.current_advection_step``) moves the
  SENSORS each round/tick; the fogs keep their Gauss-Markov walk
  (``fog_mobility``).  Deterministic on purpose: the drift layer adds NO
  extra PRNG splits, so drift-off numerics are trivially bit-identical
  to the legacy path (the PR 7 fault-off discipline).
* **Periodic re-association** — the sensor->fog assignment is CARRIED in
  the round state and refreshed from the live geometry only every
  ``reassoc_every`` rounds (``1`` = recompute every round, the legacy
  behaviour; ``inf`` = frozen after round 0).  Between refreshes the
  stale assignment meets the LIVE physics: distances, SNR feasibility,
  Eq. 18 energy and Eq. 21 latency are recomputed from current positions
  against the frozen fog id — a sensor whose assigned fog drifted out of
  range silently drops out.  That is the collapse mode periodic
  re-association exists to fix, and what ``benchmarks/drift_bench.py``
  measures.
* **Covariate shift** — client training inputs are scaled by
  ``1 + covariate_shift * round`` inside the loop, a deterministic
  distribution-shift schedule (generation-time schedules live in
  ``data/synthetic.py``; this one moves the world mid-training).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax


def _concrete(x: Any) -> bool:
    return isinstance(x, (int, float))


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Dynamic-world knobs.  All three rates are pytree LEAVES
    (traceable/stackable); the derived ``active`` predicate is static
    aux data."""

    sensor_current_m_s: float | Any = 0.0  # horizontal advection speed
    reassoc_every: float | Any = 1.0       # re-association cadence (rounds)
    covariate_shift: float | Any = 0.0     # per-round input-scale drift
    active: bool | None = None             # static on/off (None = derive)

    def __post_init__(self) -> None:
        if _concrete(self.sensor_current_m_s) and self.sensor_current_m_s < 0:
            raise ValueError(
                "sensor_current_m_s must be >= 0, got "
                f"{self.sensor_current_m_s!r}"
            )
        if _concrete(self.reassoc_every) and self.reassoc_every < 1:
            raise ValueError(
                f"reassoc_every must be >= 1 round, got {self.reassoc_every!r}"
            )

    def replace(self, **kw: Any) -> "DriftConfig":
        # Changing a rate leaf re-derives the static predicate unless the
        # caller pins it explicitly (FaultConfig.replace pattern).
        if "active" not in kw and any(
            f in kw for f in _DRIFT_LEAF_FIELDS
        ):
            kw["active"] = None
        return dataclasses.replace(self, **kw)

    @property
    def is_active(self) -> bool:
        """STATIC drift-layer switch.  A pinned value wins; otherwise any
        non-concrete (traced) rate, a nonzero rate, or a non-unit
        re-association cadence turns the layer on.  When False, round
        loops take the exact legacy path — same key splits, zero extra
        ops."""
        if self.active is not None:
            return self.active
        rates = (self.sensor_current_m_s, self.covariate_shift)
        if any((not _concrete(r)) or r != 0.0 for r in rates):
            return True
        k = self.reassoc_every
        return (not _concrete(k)) or k != 1.0


_DRIFT_LEAF_FIELDS = ("sensor_current_m_s", "reassoc_every", "covariate_shift")


def _drift_flatten(c: DriftConfig):
    return (
        tuple(getattr(c, f) for f in _DRIFT_LEAF_FIELDS),
        (c.is_active,),
    )


def _drift_unflatten(aux, children) -> DriftConfig:
    kw = dict(zip(_DRIFT_LEAF_FIELDS, children))
    return DriftConfig(active=aux[0], **kw)


jax.tree_util.register_pytree_node(DriftConfig, _drift_flatten, _drift_unflatten)
