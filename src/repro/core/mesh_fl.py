"""The paper's hierarchical-FL communication pattern on the TPU mesh
(beyond-paper optimisation — EXPERIMENTS.md §Perf, pair C).

Mapping (DESIGN.md §3): pods = fog clusters; the `data` axis inside a pod
is the cluster's sensors; the cross-pod hop is the expensive fog->gateway /
fog->fog link.  This module implements a *compressed selective-cooperation*
train step in PURE pjit (mixed manual/auto ``shard_map`` CHECK-fails in
this XLA build — see experiments/perf/run_pair_c.py):

  1. per-pod gradients via ``vmap(value_and_grad)`` over a leading pod dim
     that is sharded on the ``pod`` mesh axis (the in-pod `data`/`model`
     collectives stay within the pod — fog aggregation, Eq. 13),
  2. per-leaf blockwise Top-K + error feedback + int8 into COMPACT wire
     buffers (values int8, indices int32, scales f32 — the acoustic
     payload, Eqs. 30-31),
  3. a sharding constraint that REPLICATES the compact buffers across
     pods — the only cross-pod collective is an all-gather of the
     compressed payload (fog-to-fog exchange, Eq. 15),
  4. local decompression of every pod's update + fixed-weight mixing
     (Eq. 29) and the SGD update, identical on all pods.

Cross-pod traffic drops from 4·d bytes (dense f32 gradient all-reduce) to
~rho_s·d·5 bytes per pod — 16x at rho_s = 0.05.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import api
from repro.optim.sgd import local_sgd

BLOCK = 4096


def compress_compact(
    flat: jax.Array, rho_s: float
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blockwise Top-K + int8 into compact wire buffers.

    flat: (n,) f32.  Returns (q int8 (nb, k), idx int32 (nb, k),
    scale f32 (nb, 1)).
    """
    n = flat.shape[0]
    nb = -(-n // BLOCK)
    k = max(1, int(round(rho_s * BLOCK)))
    padded = jnp.zeros((nb * BLOCK,), jnp.float32).at[:n].set(flat)
    blocks = padded.reshape(nb, BLOCK)
    _, idx = jax.lax.top_k(jnp.abs(blocks), k)          # (nb, k)
    vals = jnp.take_along_axis(blocks, idx, axis=1)     # signed survivors
    amax = jnp.max(jnp.abs(vals), axis=1, keepdims=True)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(vals / safe), -127, 127).astype(jnp.int8)
    return q, idx.astype(jnp.int32), scale.astype(jnp.float32)


def decompress_compact(
    q: jax.Array, idx: jax.Array, scale: jax.Array, n: int
) -> jax.Array:
    """Inverse of :func:`compress_compact` -> flat (n,) f32."""
    vals = q.astype(jnp.float32) * scale
    blocks = jnp.zeros((q.shape[0], BLOCK), jnp.float32)
    blocks = jax.vmap(lambda b, i, v: b.at[i].set(v))(blocks, idx, vals)
    return blocks.reshape(-1)[:n]


def wire_bytes(d: int, rho_s: float) -> float:
    """Compact cross-pod payload per pod per exchange (bytes)."""
    nb = -(-d // BLOCK)
    k = max(1, int(round(rho_s * BLOCK)))
    return nb * k * (1 + 4) + nb * 4


def init_err(params: Any, n_pods: int) -> Any:
    """Zero per-pod, per-leaf error-feedback buffers (Eq. 30)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((n_pods, *p.shape), jnp.float32), params
    )


def make_pod_hfl_train_step(
    cfg: Any,
    mesh: jax.sharding.Mesh,
    rho_s: float = 0.05,
    self_weight: float = 0.5,
    mode: str = "int8",
    local_epochs: int = 1,
):
    """Compressed hierarchical train step (pure pjit; see module doc).

    mode="int8": elementwise int8 + per-leaf scale for the cross-pod
    exchange.  This is the TPU-grain adaptation of the paper's compressed
    uplink: blockwise Top-K (mode="topk") needs a flat contiguous view of
    each gradient leaf, which forces DENSE all-gathers of the sharded
    leaves before compression and *increases* cross-pod traffic — the
    refuted-hypothesis measurement in EXPERIMENTS.md §Perf pair C.
    Elementwise int8 commutes with any sharding, cutting the wire format
    4x with zero resharding.

    ``local_epochs`` is the pod analogue of the paper's E (Eq. 12): with
    ``local_epochs > 1`` each pod runs E SGD passes over its batch shard
    through :func:`repro.optim.sgd.local_sgd` (the same local-training
    driver as the sensor round loops — these LLM-scale params auto-fall
    back to its scan path, the AE kernel being the fused fast path) and
    the pods exchange compressed parameter DELTAS instead of gradients.
    Mixing is linear and the compressor is scale-equivariant, so E = 1
    keeps the historical gradient-exchange numerics exactly.

    self_weight=0.5 with 2 pods reproduces the exact mean of the
    compressed pod updates; the paper's selective weights use 0.8.
    Signature: (params, err, batch) -> (params', err', loss) with ``err``
    the (n_pods, ...) per-pod error-feedback pytree.
    """
    lfn = api.loss_fn(cfg)
    lr = cfg.learning_rate
    n_pods = mesh.shape["pod"]

    replicated = NamedSharding(mesh, P())

    def leaf_exchange_int8(g: jax.Array, e: jax.Array):
        """g, e: (n_pods, *leaf_shape) pod-sharded on dim 0."""
        v = g.astype(jnp.float32) + e
        # Per-pod scalar scale: a (n_pods,) f32 reduction, sharding-free.
        red_axes = tuple(range(1, v.ndim))
        amax = jnp.max(jnp.abs(v), axis=red_axes)
        scale = amax / 127.0
        safe = jnp.where(scale > 0, scale, 1.0)
        sb = safe.reshape((n_pods,) + (1,) * (v.ndim - 1))
        q = jnp.clip(jnp.round(v / sb), -127, 127).astype(jnp.int8)
        recon_own = q.astype(jnp.float32) * sb
        new_e = v - recon_own

        # THE cross-pod hop: replicate the int8 buffer (all-gather of
        # 1 byte/param instead of a 4-byte f32 all-reduce).
        q = jax.lax.with_sharding_constraint(q, replicated)
        scale = jax.lax.with_sharding_constraint(scale, replicated)

        recon_all = q.astype(jnp.float32) * scale.reshape(
            (n_pods,) + (1,) * (v.ndim - 1)
        )
        own_w = self_weight
        peer_w = (1.0 - self_weight) / max(n_pods - 1, 1)
        mean_all = jnp.sum(recon_all, axis=0)
        # mixed_p = own_w recon_p + peer_w sum_{j!=p} recon_j  (Eq. 29);
        # gateway aggregation (Eq. 16) = mean over pods.
        mixed = own_w * recon_all + peer_w * (mean_all[None] - recon_all)
        upd = jnp.mean(mixed, axis=0)
        return upd, new_e

    def leaf_exchange_topk(g: jax.Array, e: jax.Array):
        """Blockwise-Top-K compact exchange (kept for the refuted-
        hypothesis measurement; forces dense gathers on sharded leaves)."""
        shape = g.shape[1:]
        n = 1
        for s in shape:
            n *= s
        v = g.astype(jnp.float32).reshape(n_pods, n) + e.reshape(n_pods, n)
        q, idx, scale = jax.vmap(
            functools.partial(compress_compact, rho_s=rho_s)
        )(v)
        recon_own = jax.vmap(
            lambda qq, ii, ss: decompress_compact(qq, ii, ss, n)
        )(q, idx, scale)
        new_e = (v - recon_own).reshape(n_pods, *shape)
        q = jax.lax.with_sharding_constraint(q, replicated)
        idx = jax.lax.with_sharding_constraint(idx, replicated)
        scale = jax.lax.with_sharding_constraint(scale, replicated)
        recon_all = jax.vmap(
            lambda qq, ii, ss: decompress_compact(qq, ii, ss, n)
        )(q, idx, scale)
        own_w = self_weight
        peer_w = (1.0 - self_weight) / max(n_pods - 1, 1)
        mean_all = jnp.sum(recon_all, axis=0)
        mixed = own_w * recon_all + peer_w * (mean_all[None] - recon_all)
        upd = jnp.mean(mixed, axis=0).reshape(shape)
        return upd, new_e

    leaf_exchange = (
        leaf_exchange_int8 if mode == "int8" else leaf_exchange_topk
    )

    def step(params, err, batch):
        pb = jax.tree_util.tree_map(
            lambda x: jax.lax.with_sharding_constraint(
                x.reshape(n_pods, x.shape[0] // n_pods, *x.shape[1:]),
                NamedSharding(
                    mesh, P("pod", "data", *(None,) * (x.ndim - 1))
                ),
            ),
            batch,
        )
        if local_epochs == 1:
            # Historical path: one gradient per pod, exchanged as-is.
            losses, exchanged = jax.vmap(
                jax.value_and_grad(lfn), in_axes=(None, 0)
            )(params, pb)
        else:
            # E local passes per pod via the shared local-training driver;
            # the exchange payload becomes the parameter delta.  The steps
            # run on an f32 copy of the params: in raw bf16, |lr * g| <
            # |p| * 2^-9 rounds the update to zero at production learning
            # rates (the E=1 path upcasts before its update for the same
            # reason), which would silently stall local training.
            def pod_local(pb_p):
                p32 = jax.tree_util.tree_map(
                    lambda p: p.astype(jnp.float32)
                    if jnp.issubdtype(p.dtype, jnp.floating) else p,
                    params,
                )
                batches = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(
                        x[None], (local_epochs,) + x.shape
                    ),
                    pb_p,
                )
                p1, loss = local_sgd(lfn, p32, batches, lr)
                return loss, jax.tree_util.tree_map(
                    lambda a, b: a - b, p1, p32
                )

            losses, exchanged = jax.vmap(pod_local)(pb)

        flat_g, tdef = jax.tree_util.tree_flatten(exchanged)
        flat_e = jax.tree_util.tree_leaves(err)
        upds, new_es = [], []
        for g, e in zip(flat_g, flat_e):
            u, ne = leaf_exchange(g, e)
            upds.append(u)
            new_es.append(ne)
        upd = jax.tree_util.tree_unflatten(tdef, upds)
        new_err = jax.tree_util.tree_unflatten(tdef, new_es)

        # Gradients need the -lr step; deltas already carry it.
        step_scale = -lr if local_epochs == 1 else 1.0
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32) + step_scale * g).astype(p.dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p,
            params, upd,
        )
        return new_params, new_err, jnp.mean(losses)

    return step
