"""Aggregation operators for hierarchical FL (paper Eqs. 13, 15, 16).

Two execution styles, same math:

1. **Vectorised single-program** (the simulator hot path): per-client
   updates are stacked along a leading axis; fog aggregation is a
   ``segment_sum`` over cluster ids, cooperative mixing a gather + convex
   combination, global aggregation a weighted sum.  Everything jits and
   scans.

2. **Mesh-parallel** (the production runtime): clients live on mesh shards;
   fog aggregation is an in-pod reduction over the ``data`` axis and global
   aggregation a cross-pod reduction over the ``pod`` axis — the TPU
   analogue of the sensor->fog (short acoustic hop) vs fog->gateway (long
   hop) split.  See :func:`hierarchical_mean` (used under ``shard_map``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import compression as comp
from repro.core.cooperation import CoopDecision
from repro.kernels import ops as kops


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def fog_aggregate(
    updates: Any,            # pytree, leaves (N, ...) — per-client updates
    fog_id: jax.Array,       # (N,) int32
    weights: jax.Array,      # (N,) f32 — n_i, zeroed for non-participants
    n_fog: int,
) -> tuple[Any, jax.Array]:
    """Intra-cluster weighted aggregation (Eq. 13).

    Returns (fog_updates with leaves (M, ...), fog_weight (M,)) where
    fog_updates[m] = sum_{i in C_m} n_i/sum_C n * update_i and fog_weight is
    the total data weight of the cluster (used again in Eq. 16).
    """
    fog_weight = jax.ops.segment_sum(weights, fog_id, num_segments=n_fog)
    denom = jnp.maximum(fog_weight, 1e-12)

    def agg(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1))
        summed = jax.ops.segment_sum(leaf * w, fog_id, num_segments=n_fog)
        return summed / denom.reshape((-1,) + (1,) * (leaf.ndim - 1))

    return _tree_map(agg, updates), fog_weight


def _chunk_starts(n: int, chunk: int) -> tuple[jax.Array, jax.Array]:
    """(clamped, nominal) chunk-start indices covering a client axis of n.

    Instead of zero-padding N up to a chunk multiple (two full-size input
    copies), the last chunk is CLAMPED to start at ``n - chunk`` and
    re-reads up to ``chunk - 1`` rows of its predecessor.  Re-reading is
    safe because every per-row output (reconstruction, EF update) is a
    deterministic function of that row alone — overlap rows recompute
    bit-identically — while per-fog sums mask the overlap rows' weights to
    zero via the nominal starts.  Requires ``chunk < n`` (the dispatch
    guarantees it).
    """
    n_chunks = -(-n // chunk)
    nominal = jnp.arange(n_chunks, dtype=jnp.int32) * chunk
    return jnp.minimum(nominal, n - chunk), nominal


def _wire_k_frac(d: int, cfg: comp.CompressorConfig):
    """Concrete per-block keep fraction if the sparse wire is usable.

    The wire is shape-bearing (k slots per block), so it needs a concrete
    ``rho_s``; config-axis sweeps trace it and must keep the dense oracle.
    Returns a float, or None when the config doesn't qualify.
    """
    if not (
        cfg.enabled and cfg.is_sparse and cfg.fused
        and cfg.mode == "blockwise"
    ):
        return None
    k_frac = comp.blockwise_k_frac(d, cfg.rho_s)
    if not isinstance(k_frac, (int, float)):
        return None
    comp.validate_blockwise_bits(cfg.quant_bits)
    return k_frac


def _chunked_compress_and_accumulate(
    deltas, err, fog_id, weights, n_fog: int, cfg, chunk: int
):
    """``lax.scan`` over client chunks carrying the (n_fog, d) buffers.

    Each scan step compresses and accumulates one chunk of clients, so the
    transient footprint (blocked tiles, masks, wire slots) is O(chunk * d)
    instead of O(N * d) — the peak high-water mark scales with the chunk
    knob, not the fleet.  EF state is still (N, d) round state: it is
    emitted chunk-at-a-time as stacked scan outputs.

    Inside each chunk, a concrete-``rho_s`` fused blockwise config takes
    the sparse wire (emit + scatter-accumulate, no dense per-chunk
    reconstruction); anything else falls back to the dense per-chunk path
    (still chunk-bounded).  Chunks are addressed with clamped
    ``dynamic_slice`` starts (:func:`_chunk_starts`) and the EF output is
    written in place into a carried (N, d) buffer, so neither padded input
    copies nor a stacked scan-output staging buffer ever materialise.
    Float summation order differs from the unchunked pass, which is why
    the equivalence pins are bitwise only at ``chunk >= N`` (where this
    function is never entered).
    """
    n, d = deltas.shape
    starts, nominal = _chunk_starts(n, chunk)
    k_frac = _wire_k_frac(d, cfg)

    def body(carry, x):
        fog_sum, fog_weight, err_out = carry
        start, nom = x
        dc = jax.lax.dynamic_slice_in_dim(deltas, start, chunk)
        ec = jax.lax.dynamic_slice_in_dim(err, start, chunk)
        fc = jax.lax.dynamic_slice_in_dim(fog_id, start, chunk)
        wc = jax.lax.dynamic_slice_in_dim(weights, start, chunk)
        # Rows the clamped last chunk re-reads were already accumulated;
        # zero their weight so the fog sums count every client once.
        fresh = start + jnp.arange(chunk, dtype=jnp.int32) >= nom
        wc = wc * fresh.astype(wc.dtype)
        if k_frac is not None:
            # Same graceful-degradation guard as the unchunked path.
            finite = jnp.all(jnp.isfinite(dc), axis=-1) & jnp.all(
                jnp.isfinite(ec), axis=-1
            )
            dc = jnp.where(finite[:, None], dc, 0.0)
            ec = jnp.where(finite[:, None], ec, 0.0)
            wc = wc * finite.astype(wc.dtype)
            part_w = jax.ops.segment_sum(wc, fc, num_segments=n_fog)
            part, new_err_c = kops.compress_aggregate_wire(
                dc, ec, fc, wc, n_fog, k_frac,
                quantize=cfg.quant_bits < 32,
                use_pallas=cfg.use_pallas,
                interpret=cfg.interpret,
            )
        else:
            part, part_w, new_err_c = compress_and_accumulate(
                dc, ec, fc, wc, n_fog, cfg
            )
        # Overlap rows rewrite bit-identical values (per-row determinism).
        err_out = jax.lax.dynamic_update_slice_in_dim(
            err_out, new_err_c, start, 0
        )
        return (fog_sum + part, fog_weight + part_w, err_out), None

    carry0 = (
        jnp.zeros((n_fog, d), jnp.float32),
        jnp.zeros((n_fog,), jnp.float32),
        jnp.zeros((n, d), deltas.dtype),
    )
    (fog_sum, fog_weight, new_err), _ = jax.lax.scan(
        body, carry0, (starts, nominal)
    )
    return fog_sum, fog_weight, new_err


def compress_and_accumulate(
    deltas: jax.Array,      # (N, d) raw flat client updates
    err: jax.Array,         # (N, d) error-feedback buffers
    fog_id: jax.Array,      # (N,) int32 cluster assignment
    weights: jax.Array,     # (N,) f32, zeroed for non-participants
    n_fog: int,
    cfg: comp.CompressorConfig,
    chunk: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-client compression + UNNORMALISED weighted fog sums (one pass).

    The shard_map round loop psums these partials over the client axis
    before normalising; :func:`compress_and_aggregate` is the single-shard
    wrapper that divides through directly.

    Returns (fog_sum (n_fog, d) = sum_{i in C_m} w_i recon_i,
    fog_weight (n_fog,) = sum_{i in C_m} w_i, new_err (N, d)).

    Graceful degradation: rows carrying any NaN/Inf (a diverging or
    malicious client) are zeroed — delta, EF buffer AND weight — before
    they touch the fog sums, so one poisoned client can never NaN the
    global model.  Always on, independent of the fault layer; a no-op
    (bit-identical ``where(true, x, _)``) for finite inputs.

    ``chunk`` (the resolved ``HFLConfig.client_chunk``) bounds the
    transient memory: ``None`` or ``chunk >= N`` runs the one-shot path
    below UNCHANGED (bit-identical to the pre-chunking code); a smaller
    chunk scans :func:`_chunked_compress_and_accumulate` over client
    chunks.
    """
    if chunk is not None and 0 < chunk < deltas.shape[0]:
        return _chunked_compress_and_accumulate(
            deltas, err, fog_id, weights, n_fog, cfg, chunk
        )
    finite = jnp.all(jnp.isfinite(deltas), axis=-1) & jnp.all(
        jnp.isfinite(err), axis=-1
    )
    deltas = jnp.where(finite[:, None], deltas, 0.0)
    err = jnp.where(finite[:, None], err, 0.0)
    weights = weights * finite.astype(weights.dtype)
    fog_weight = jax.ops.segment_sum(weights, fog_id, num_segments=n_fog)

    # ``is_sparse`` is the STATIC sparsity predicate: rho_s itself may be a
    # tracer inside a config-axis sweep, where the shape-class guarantees a
    # uniform branch.
    if cfg.enabled and cfg.is_sparse and cfg.fused and cfg.mode == "blockwise":
        # The fused kernel path: EF Top-K + int8 + weighted accumulation
        # directly into the (n_fog, d) buffers — the dense per-client
        # reconstruction never materialises.
        comp.validate_blockwise_bits(cfg.quant_bits)
        fog_sum, new_err = kops.compress_aggregate(
            deltas, err, fog_id, weights, n_fog,
            comp.blockwise_k_frac(deltas.shape[1], cfg.rho_s),
            quantize=cfg.quant_bits < 32,
            use_pallas=cfg.use_pallas,
            interpret=cfg.interpret,
        )
        return fog_sum, fog_weight, new_err

    # Unfused fallback (compression off, dense rho_s == 1 quantise-only,
    # mode="global", or cfg.fused=False): per-client reconstruction then a
    # dense segment-sum — the legacy two-pass pipeline.
    if cfg.enabled:
        recon, new_err = jax.vmap(
            lambda d_, e_: comp.compress_update(d_, e_, cfg)
        )(deltas, err)
    else:
        recon, new_err = deltas, err
    fog_sum = jax.ops.segment_sum(
        recon * weights[:, None], fog_id, num_segments=n_fog
    )
    return fog_sum, fog_weight, new_err


def compress_and_aggregate(
    deltas: jax.Array,
    err: jax.Array,
    fog_id: jax.Array,
    weights: jax.Array,
    n_fog: int,
    cfg: comp.CompressorConfig,
    axis: str | None = None,
    chunk: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused sensor-uplink compression + intra-cluster aggregation.

    Eq. 30 (EF compression) and Eq. 13 (weighted fog aggregation) as ONE
    operator: per (client, block), the update is sparsified/quantised and
    its reconstruction accumulated straight into the fog buffers.  This is
    the round loop's hot path; see :mod:`repro.kernels.fused_agg` for the
    single-HBM-pass kernel it dispatches to.

    Under ``shard_map`` pass the client mesh ``axis``: each shard's partial
    fog sums are psum-reduced before normalising (the sensor->fog hop, cf.
    :func:`hierarchical_mean`).  ``chunk`` applies WITHIN the shard's local
    client slice, so chunking composes with ``shard_clients``.

    Returns (fog_update (n_fog, d) — the Eq. 13 weighted cluster means —
    fog_weight (n_fog,), new_err (N, d)).  Empty clusters get zero updates.
    """
    fog_sum, fog_weight, new_err = compress_and_accumulate(
        deltas, err, fog_id, weights, n_fog, cfg, chunk=chunk
    )
    if axis is not None:
        fog_sum = jax.lax.psum(fog_sum, axis)
        fog_weight = jax.lax.psum(fog_weight, axis)
    denom = jnp.maximum(fog_weight, 1e-12)
    return fog_sum / denom[:, None], fog_weight, new_err


def client_compress(
    deltas: jax.Array,      # (N, d) raw flat client updates
    err: jax.Array,         # (N, d) error-feedback buffers
    cfg: comp.CompressorConfig,
    chunk: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-client compression with identity segments, optionally chunked.

    The robust and async paths need each client's dequantised
    reconstruction to stay addressable (the order statistic / the in-flight
    buffer reads them per client), so the output is necessarily (N, d) —
    but the compression TRANSIENTS (blocked tiles, bisection masks, quant
    scratch) need not be: with ``chunk`` set, a ``lax.scan`` emits the
    reconstructions chunk-at-a-time and only O(chunk * d) of scratch is
    live at once.

    ``chunk=None`` / ``chunk >= N`` is the exact legacy call
    (``fog_id = arange(N)``, unit weights — bit-identical); returns
    (recon (N, d), new_err (N, d)).
    """
    n = deltas.shape[0]
    if chunk is None or chunk <= 0 or chunk >= n:
        recon, _, new_err = compress_and_accumulate(
            deltas, err,
            jnp.arange(n, dtype=jnp.int32), jnp.ones((n,), jnp.float32),
            n, cfg,
        )
        return recon, new_err
    d = deltas.shape[1]
    starts, _ = _chunk_starts(n, chunk)

    def body(carry, start):
        recon_out, err_out = carry
        dc = jax.lax.dynamic_slice_in_dim(deltas, start, chunk)
        ec = jax.lax.dynamic_slice_in_dim(err, start, chunk)
        recon_c, _, new_err_c = compress_and_accumulate(
            dc, ec,
            jnp.arange(chunk, dtype=jnp.int32),
            jnp.ones((chunk,), jnp.float32),
            chunk, cfg,
        )
        # Rows the clamped last chunk re-reads recompute bit-identically
        # (per-row determinism), so overwriting them is harmless.
        recon_out = jax.lax.dynamic_update_slice_in_dim(
            recon_out, recon_c, start, 0
        )
        err_out = jax.lax.dynamic_update_slice_in_dim(
            err_out, new_err_c, start, 0
        )
        return (recon_out, err_out), None

    carry0 = (
        jnp.zeros((n, d), deltas.dtype),
        jnp.zeros((n, d), deltas.dtype),
    )
    (recon, new_err), _ = jax.lax.scan(body, carry0, starts)
    return recon, new_err


def robust_compress_and_aggregate(
    deltas: jax.Array,      # (N, d) raw flat client updates
    err: jax.Array,         # (N, d) error-feedback buffers
    fog_id: jax.Array,      # (N,) int32 cluster assignment
    weights: jax.Array,     # (N,) f32, zeroed for non-participants
    n_fog: int,
    cfg: comp.CompressorConfig,
    trim_frac: float | jax.Array,
    mode: str,              # "trimmed" | "median"
    chunk: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Byzantine-robust variant of :func:`compress_and_aggregate`.

    Runs the SAME fused compress path but with per-client segments
    (``fog_id = arange(N)``, unit weights — the async family's trick), so
    each client's dequantised reconstruction stays addressable and the EF
    buffer math is bit-identical to the mean path; the per-fog reduce is
    then the coordinate-wise trimmed mean / median
    (:func:`repro.kernels.ops.robust_aggregate`) instead of the weighted
    sum.  At ``trim_frac == 0`` this reproduces the weighted mean to float
    tolerance (summation order differs).

    Returns (fog_update (n_fog, d) — NORMALISED robust aggregates —
    fog_weight (n_fog,), new_err (N, d)).  ``chunk`` bounds the compress
    transients (see :func:`client_compress`); the (N, d) reconstructions
    themselves are what the order statistic consumes, so they remain.
    """
    recon, new_err = client_compress(deltas, err, cfg, chunk=chunk)
    # The isfinite guard above zeroed poisoned reconstructions; their
    # aggregation weight must vanish too, or a zeroed row would still pull
    # the order statistic toward zero.
    finite = jnp.all(jnp.isfinite(deltas), axis=-1) & jnp.all(
        jnp.isfinite(err), axis=-1
    )
    fog_out, fog_weight = kops.robust_aggregate(
        recon, fog_id, weights * finite.astype(weights.dtype), n_fog,
        trim_frac, mode,
        use_pallas=cfg.use_pallas, interpret=cfg.interpret,
    )
    return fog_out, fog_weight, new_err


def cooperative_mix(fog_models: Any, decision: CoopDecision) -> Any:
    """Cooperative fog mixing (Eq. 15 with K=1 rule family).

    theta~_m = alpha_mm theta_m + alpha_mj theta_j.  Non-cooperating fogs
    have partner=m and weights (1, 0), so this is the identity for them.
    """

    def mix(leaf):
        peer = leaf[decision.partner]
        ws = decision.self_weight.reshape((-1,) + (1,) * (leaf.ndim - 1))
        wp = decision.partner_weight.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return ws * leaf + wp * peer

    return _tree_map(mix, fog_models)


def global_aggregate(
    fog_models: Any,         # pytree, leaves (M, ...)
    fog_weight: jax.Array,   # (M,) — sum of n_i over the cluster
    prev: Any = None,        # carry-through when the whole round is dead
) -> Any:
    """Surface-gateway aggregation (Eq. 16): data-weighted fog average.

    A dead-network round (no active sensor in any cluster) has total weight
    0; the normalised weights then vanish and the weighted sum would wipe
    the model to zeros.  Pass ``prev`` (the current global model, leaves
    matching ``fog_models`` without the leading fog axis) to carry it
    through instead — the round becomes an explicit no-op.
    """
    total = jnp.sum(fog_weight)
    w = fog_weight / jnp.maximum(total, 1e-12)

    def agg(leaf):
        return jnp.tensordot(w, leaf, axes=(0, 0))

    out = _tree_map(agg, fog_models)
    if prev is None:
        return out
    return _tree_map(lambda o, p: jnp.where(total > 0.0, o, p), out, prev)


def weighted_mean(updates: Any, weights: jax.Array, prev: Any = None) -> Any:
    """Flat weighted average over the leading client axis (FedAvg, Eq. 11).

    Same zero-total-weight semantics as :func:`global_aggregate`: with
    ``prev`` given, an all-zero weight vector returns ``prev`` instead of
    collapsing to zeros.  (The flat round loops average *deltas*, where the
    zero default already means "hold the model" — ``prev`` matters when the
    averaged quantity is the model itself.)
    """
    total = jnp.sum(weights)
    w = weights / jnp.maximum(total, 1e-12)

    def agg(leaf):
        return jnp.tensordot(w, leaf, axes=(0, 0))

    out = _tree_map(agg, updates)
    if prev is None:
        return out
    return _tree_map(lambda o, p: jnp.where(total > 0.0, o, p), out, prev)


# ---------------------------------------------------------------------------
# Mesh-parallel hierarchical aggregation (used under shard_map).
# ---------------------------------------------------------------------------

def hierarchical_mean(
    update: Any,
    weight: jax.Array,
    *,
    intra_axis: str = "data",
    inter_axis: str | None = "pod",
) -> Any:
    """Two-level weighted mean: reduce within the pod, then across pods.

    Called from inside ``shard_map`` with per-shard (client) updates.  The
    in-pod reduction is the cheap hop (fog aggregation); the cross-pod
    reduction is the expensive hop (fog->gateway).  With ``inter_axis=None``
    this degenerates to flat FedAvg over ``intra_axis``.
    """
    wsum_local = jax.lax.psum(weight, intra_axis)

    def intra(leaf):
        return jax.lax.psum(leaf * weight, intra_axis) / jnp.maximum(
            wsum_local, 1e-12
        )

    fog_model = _tree_map(intra, update)
    if inter_axis is None:
        return fog_model

    wsum_global = jax.lax.psum(wsum_local, inter_axis)

    def inter(leaf):
        return jax.lax.psum(leaf * wsum_local, inter_axis) / jnp.maximum(
            wsum_global, 1e-12
        )

    return _tree_map(inter, fog_model)


def ring_mix(update: Any, mix_weight: float, axis: str = "pod") -> Any:
    """Gossip mixing with the ring neighbour over ``axis`` — the mesh
    analogue of fog-to-fog cooperation, lowering to collective_permute."""
    # jax.lax.axis_size is newer-JAX only; psum of the constant 1 over a
    # named axis folds to the same static size on every version.
    axis_size = getattr(jax.lax, "axis_size", None)
    n = int(axis_size(axis) if axis_size else jax.lax.psum(1, axis))
    perm = [(i, (i + 1) % n) for i in range(n)]

    def mix(leaf):
        peer = jax.lax.ppermute(leaf, axis, perm)
        return (1.0 - mix_weight) * leaf + mix_weight * peer

    return _tree_map(mix, update)
