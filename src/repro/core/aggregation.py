"""Aggregation operators for hierarchical FL (paper Eqs. 13, 15, 16).

Two execution styles, same math:

1. **Vectorised single-program** (the simulator hot path): per-client
   updates are stacked along a leading axis; fog aggregation is a
   ``segment_sum`` over cluster ids, cooperative mixing a gather + convex
   combination, global aggregation a weighted sum.  Everything jits and
   scans.

2. **Mesh-parallel** (the production runtime): clients live on mesh shards;
   fog aggregation is an in-pod reduction over the ``data`` axis and global
   aggregation a cross-pod reduction over the ``pod`` axis — the TPU
   analogue of the sensor->fog (short acoustic hop) vs fog->gateway (long
   hop) split.  See :func:`hierarchical_mean` (used under ``shard_map``).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.cooperation import CoopDecision


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def fog_aggregate(
    updates: Any,            # pytree, leaves (N, ...) — per-client updates
    fog_id: jax.Array,       # (N,) int32
    weights: jax.Array,      # (N,) f32 — n_i, zeroed for non-participants
    n_fog: int,
) -> tuple[Any, jax.Array]:
    """Intra-cluster weighted aggregation (Eq. 13).

    Returns (fog_updates with leaves (M, ...), fog_weight (M,)) where
    fog_updates[m] = sum_{i in C_m} n_i/sum_C n * update_i and fog_weight is
    the total data weight of the cluster (used again in Eq. 16).
    """
    fog_weight = jax.ops.segment_sum(weights, fog_id, num_segments=n_fog)
    denom = jnp.maximum(fog_weight, 1e-12)

    def agg(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1))
        summed = jax.ops.segment_sum(leaf * w, fog_id, num_segments=n_fog)
        return summed / denom.reshape((-1,) + (1,) * (leaf.ndim - 1))

    return _tree_map(agg, updates), fog_weight


def cooperative_mix(fog_models: Any, decision: CoopDecision) -> Any:
    """Cooperative fog mixing (Eq. 15 with K=1 rule family).

    theta~_m = alpha_mm theta_m + alpha_mj theta_j.  Non-cooperating fogs
    have partner=m and weights (1, 0), so this is the identity for them.
    """

    def mix(leaf):
        peer = leaf[decision.partner]
        ws = decision.self_weight.reshape((-1,) + (1,) * (leaf.ndim - 1))
        wp = decision.partner_weight.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return ws * leaf + wp * peer

    return _tree_map(mix, fog_models)


def global_aggregate(
    fog_models: Any,         # pytree, leaves (M, ...)
    fog_weight: jax.Array,   # (M,) — sum of n_i over the cluster
) -> Any:
    """Surface-gateway aggregation (Eq. 16): data-weighted fog average."""
    total = jnp.maximum(jnp.sum(fog_weight), 1e-12)
    w = fog_weight / total

    def agg(leaf):
        return jnp.tensordot(w, leaf, axes=(0, 0))

    return _tree_map(agg, fog_models)


def weighted_mean(updates: Any, weights: jax.Array) -> Any:
    """Flat weighted average over the leading client axis (FedAvg, Eq. 11)."""
    total = jnp.maximum(jnp.sum(weights), 1e-12)
    w = weights / total

    def agg(leaf):
        return jnp.tensordot(w, leaf, axes=(0, 0))

    return _tree_map(agg, updates)


# ---------------------------------------------------------------------------
# Mesh-parallel hierarchical aggregation (used under shard_map).
# ---------------------------------------------------------------------------

def hierarchical_mean(
    update: Any,
    weight: jax.Array,
    *,
    intra_axis: str = "data",
    inter_axis: str | None = "pod",
) -> Any:
    """Two-level weighted mean: reduce within the pod, then across pods.

    Called from inside ``shard_map`` with per-shard (client) updates.  The
    in-pod reduction is the cheap hop (fog aggregation); the cross-pod
    reduction is the expensive hop (fog->gateway).  With ``inter_axis=None``
    this degenerates to flat FedAvg over ``intra_axis``.
    """
    wsum_local = jax.lax.psum(weight, intra_axis)

    def intra(leaf):
        return jax.lax.psum(leaf * weight, intra_axis) / jnp.maximum(
            wsum_local, 1e-12
        )

    fog_model = _tree_map(intra, update)
    if inter_axis is None:
        return fog_model

    wsum_global = jax.lax.psum(wsum_local, inter_axis)

    def inter(leaf):
        return jax.lax.psum(leaf * wsum_local, inter_axis) / jnp.maximum(
            wsum_global, 1e-12
        )

    return _tree_map(inter, fog_model)


def ring_mix(update: Any, mix_weight: float, axis: str = "pod") -> Any:
    """Gossip mixing with the ring neighbour over ``axis`` — the mesh
    analogue of fog-to-fog cooperation, lowering to collective_permute."""
    # jax.lax.axis_size is newer-JAX only; psum of the constant 1 over a
    # named axis folds to the same static size on every version.
    axis_size = getattr(jax.lax, "axis_size", None)
    n = int(axis_size(axis) if axis_size else jax.lax.psum(1, axis))
    perm = [(i, (i + 1) % n) for i in range(n)]

    def mix(leaf):
        peer = jax.lax.ppermute(leaf, axis, perm)
        return (1.0 - mix_weight) * leaf + mix_weight * peer

    return _tree_map(mix, update)
