"""Update-compression pipeline at the pytree level (paper Sec. V-C).

Two modes:

- ``global``: exact Top-K over the whole flattened update — the paper's
  semantics for the ~1 352-parameter autoencoder (rho_s = 0.05 -> K ~ 68).
- ``blockwise``: the TPU-native blocked kernel path (Deep-Gradient-
  Compression-style per-block selection) for LLM-scale updates, backed by
  the fused Pallas kernel in :mod:`repro.kernels`.

Both apply error feedback (Eq. 30) — the local error buffer absorbs the
sparsification *and* quantisation residuals — and report the acoustic
payload in bits (Eq. 31):  L_u = K (b_q + b_idx).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    rho_s: float = 0.05          # sparsification ratio (1.0 = dense)
    quant_bits: int = 8          # post-sparsification bit-width (32 = none)
    mode: str = "global"         # "global" | "blockwise"
    use_pallas: bool = False     # blockwise only: route through the kernel
    interpret: bool = True       # pallas interpret mode (CPU)
    fused: bool = True           # fuse compression into fog aggregation
    # (core/aggregation.compress_and_aggregate); False = legacy per-client
    # compress_update + dense segment-sum, kept as the equivalence baseline.

    def replace(self, **kw: Any) -> "CompressorConfig":
        return dataclasses.replace(self, **kw)

    @property
    def enabled(self) -> bool:
        return self.rho_s < 1.0 or self.quant_bits < 32


def payload_bits(d: int, cfg: CompressorConfig) -> float:
    """Uplink payload size in bits (paper Eq. 31 / Sec. IV-B).

    ``d`` must be a static (python int) parameter count.
    """
    if not cfg.enabled:
        return 32.0 * d
    bits = float(cfg.quant_bits)
    if cfg.rho_s >= 1.0:
        return bits * d  # quantise-only: no index overhead
    b_idx = math.ceil(math.log2(max(d, 2)))
    k = max(1.0, round(cfg.rho_s * d))
    return k * (bits + b_idx)


def blockwise_k_frac(d: int, rho_s: float) -> float:
    """Per-tile keep fraction for blockwise mode on a length-``d`` vector.

    rho_s is a fraction of the REAL coordinates.  The kernels pad the flat
    vector to whole (BLOCK_ELEMS) tiles and keep a uniform k per tile, so
    solve for the k that keeps ~rho_s * d coords total: the tail tile can
    contribute at most its real coordinates (padding zeros never pass the
    magnitude threshold), so when the uniform k exceeds the tail, the full
    tiles must absorb the difference.
    """
    block = kops.BLOCK_ELEMS
    nb = max(1, -(-d // block))
    tail = d - (nb - 1) * block      # real coords in the last tile
    target = max(1, round(rho_s * d))
    k = target / nb
    if nb > 1 and k > tail:
        k = (target - tail) / (nb - 1)
    return min(1.0, k / block)


def validate_blockwise_bits(quant_bits: int) -> None:
    """Blockwise kernels are int8-only; reject widths they would silently
    mis-quantise (4/16-bit configs must use mode='global')."""
    if quant_bits not in (8,) and quant_bits < 32:
        raise ValueError(
            f"blockwise mode supports quant_bits 8 or >=32, got "
            f"{quant_bits}; use mode='global' for other widths"
        )


def init_error(params: Any) -> jax.Array:
    """Zero error-feedback buffer matching the flattened parameter count."""
    flat, _ = ravel_pytree(params)
    return jnp.zeros_like(flat)


def _global_topk_ef(
    v: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """Exact global Top-K with EF decomposition on a flat vector."""
    absv = jnp.abs(v)
    kth = jax.lax.top_k(absv, k)[0][-1]
    mask = absv >= kth
    # Tie-break: keep at most k (top_k threshold may admit ties); paper's
    # payload accounting assumes exactly K coords, ties are measure-zero in
    # float updates so a >= mask is the standard implementation.
    sparse = jnp.where(mask, v, 0.0)
    return sparse, v - sparse


def _quantize_global(x: jax.Array, bits: int) -> jax.Array:
    """Symmetric fixed-point quantise/dequantise of nonzeros, global scale."""
    if bits >= 32:
        return x
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x))
    scale = amax / qmax
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -qmax, qmax)
    return jnp.where(scale > 0, q * scale, x)


def compress_update(
    delta: Any, err: jax.Array, cfg: CompressorConfig
) -> tuple[Any, jax.Array]:
    """Compress one client's model update (a pytree).

    Returns (reconstructed_update_tree, new_error_buffer).  The
    reconstruction is what the fog node receives after decode; the error
    buffer stays on the client (Eq. 30).
    """
    flat, unravel = ravel_pytree(delta)
    if not cfg.enabled:
        return delta, err

    if cfg.mode == "global":
        d = flat.shape[0]
        k = max(1, int(round(cfg.rho_s * d)))
        v = flat + err
        if cfg.rho_s < 1.0:
            sparse, _ = _global_topk_ef(v, k)
        else:
            sparse = v
        recon = _quantize_global(sparse, cfg.quant_bits)
        new_err = v - recon
        return unravel(recon), new_err

    if cfg.mode == "blockwise":
        validate_blockwise_bits(cfg.quant_bits)
        k_frac = blockwise_k_frac(flat.shape[0], cfg.rho_s)
        if cfg.quant_bits < 32:
            recon, new_err, _ = kops.compress(
                flat, err, k_frac, cfg.use_pallas, cfg.interpret
            )
        else:
            recon, new_err = kops.topk_ef(
                flat, err, k_frac, cfg.use_pallas, cfg.interpret
            )
        return unravel(recon), new_err

    raise ValueError(f"unknown compression mode: {cfg.mode}")


def compression_ratio(d: int, cfg: CompressorConfig) -> float:
    """Effective ratio rho vs uncompressed 32-bit transmission (Sec. V-C)."""
    return payload_bits(d, cfg) / (32.0 * d)
