"""Update-compression pipeline at the pytree level (paper Sec. V-C).

Two modes:

- ``global``: exact Top-K over the whole flattened update — the paper's
  semantics for the ~1 352-parameter autoencoder (rho_s = 0.05 -> K ~ 68).
- ``blockwise``: the TPU-native blocked kernel path (Deep-Gradient-
  Compression-style per-block selection) for LLM-scale updates, backed by
  the fused Pallas kernel in :mod:`repro.kernels`.

Both apply error feedback (Eq. 30) — the local error buffer absorbs the
sparsification *and* quantisation residuals — and report the acoustic
payload in bits (Eq. 31):  L_u = K (b_q + b_idx).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.kernels import ops as kops


def _concrete(x: Any) -> bool:
    """True when ``x`` is a plain Python number (not a jax value/tracer)."""
    return isinstance(x, (int, float))


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    """Compression knobs.  ``rho_s`` is a pytree LEAF so sweeps can stack
    several ratios along a config axis and trace them through the pipeline
    (the blockwise kernels select by threshold-bisection against a count,
    so a traced keep-count is supported on the jnp-oracle path); everything
    else — bit-width, mode, backend flags — is static aux data that defines
    the sweep shape-class.

    ``sparse`` is the static sparsity predicate (``rho_s < 1``).  It is
    derived automatically from a concrete ``rho_s`` and carried through
    flatten/unflatten, so code can branch Python-side on ``is_sparse`` /
    ``enabled`` even while ``rho_s`` itself is a tracer.
    """

    rho_s: float | Any = 0.05    # sparsification ratio (1.0 = dense)
    quant_bits: int = 8          # post-sparsification bit-width (32 = none)
    mode: str = "global"         # "global" | "blockwise"
    use_pallas: bool = False     # blockwise only: route through the kernel
    interpret: bool = True       # pallas interpret mode (CPU)
    fused: bool = True           # fuse compression into fog aggregation
    # (core/aggregation.compress_and_aggregate); False = legacy per-client
    # compress_update + dense segment-sum, kept as the equivalence baseline.
    sparse: bool | None = None   # static rho_s < 1 predicate (None = derive)

    def replace(self, **kw: Any) -> "CompressorConfig":
        # A pytree round-trip pins ``sparse`` to a concrete bool; changing
        # rho_s afterwards must re-derive it or the static predicate goes
        # stale (pass ``sparse`` explicitly to keep a pinned value).
        if "rho_s" in kw and "sparse" not in kw:
            kw["sparse"] = None
        return dataclasses.replace(self, **kw)

    @property
    def is_sparse(self) -> bool:
        if self.sparse is not None:
            return self.sparse
        return bool(self.rho_s < 1.0)

    @property
    def enabled(self) -> bool:
        return self.is_sparse or self.quant_bits < 32


def _cc_flatten(c: CompressorConfig):
    aux = (c.quant_bits, c.mode, c.use_pallas, c.interpret, c.fused,
           c.is_sparse)
    return (c.rho_s,), aux


def _cc_unflatten(aux, children) -> CompressorConfig:
    quant_bits, mode, use_pallas, interpret, fused, sparse = aux
    return CompressorConfig(
        rho_s=children[0], quant_bits=quant_bits, mode=mode,
        use_pallas=use_pallas, interpret=interpret, fused=fused,
        sparse=sparse,
    )


jax.tree_util.register_pytree_node(CompressorConfig, _cc_flatten, _cc_unflatten)


def payload_bits(d: int, cfg: CompressorConfig) -> float | jax.Array:
    """Uplink payload size in bits (paper Eq. 31 / Sec. IV-B).

    ``d`` must be a static (python int) parameter count.  With a concrete
    ``rho_s`` the result is a Python float (exact back-compat); a traced
    ``rho_s`` (config-axis sweeps) yields the identical value as a jnp
    scalar — the branch structure is static either way (``is_sparse``).
    """
    if not cfg.enabled:
        return 32.0 * d
    bits = float(cfg.quant_bits)
    if not cfg.is_sparse:
        return bits * d  # quantise-only: no index overhead
    b_idx = math.ceil(math.log2(max(d, 2)))
    if _concrete(cfg.rho_s):
        k = max(1.0, round(cfg.rho_s * d))
    else:
        k = jnp.maximum(1.0, jnp.round(jnp.asarray(cfg.rho_s, jnp.float32) * d))
    return k * (bits + b_idx)


def blockwise_k_frac(d: int, rho_s: float | jax.Array) -> float | jax.Array:
    """Per-tile keep fraction for blockwise mode on a length-``d`` vector.

    rho_s is a fraction of the REAL coordinates.  The kernels pad the flat
    vector to whole (BLOCK_ELEMS) tiles and keep a uniform k per tile, so
    solve for the k that keeps ~rho_s * d coords total: the tail tile can
    contribute at most its real coordinates (padding zeros never pass the
    magnitude threshold), so when the uniform k exceeds the tail, the full
    tiles must absorb the difference.

    A traced ``rho_s`` (config-axis sweeps) returns the same value as a
    jnp scalar — tile counts stay static, only the keep target traces.
    """
    block = kops.BLOCK_ELEMS
    nb = max(1, -(-d // block))
    tail = d - (nb - 1) * block      # real coords in the last tile
    if _concrete(rho_s):
        target = max(1, round(rho_s * d))
        k = target / nb
        if nb > 1 and k > tail:
            k = (target - tail) / (nb - 1)
        return min(1.0, k / block)
    target = jnp.maximum(1.0, jnp.round(jnp.asarray(rho_s, jnp.float32) * d))
    k = target / nb
    if nb > 1:
        k = jnp.where(k > tail, (target - tail) / (nb - 1), k)
    return jnp.minimum(1.0, k / block)


def validate_blockwise_bits(quant_bits: int) -> None:
    """Blockwise kernels are int8-only; reject widths they would silently
    mis-quantise (4/16-bit configs must use mode='global')."""
    if quant_bits not in (8,) and quant_bits < 32:
        raise ValueError(
            f"blockwise mode supports quant_bits 8 or >=32, got "
            f"{quant_bits}; use mode='global' for other widths"
        )


def init_error(params: Any) -> jax.Array:
    """Zero error-feedback buffer matching the flattened parameter count."""
    flat, _ = ravel_pytree(params)
    return jnp.zeros_like(flat)


def _global_topk_ef(
    v: jax.Array, k: int | jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Exact global Top-K with EF decomposition on a flat vector.

    ``k`` may be traced (config-axis sweeps): the k-th largest magnitude is
    then read out of a full ascending sort at a dynamic index — identical
    threshold, shape-independent of ``k``.
    """
    absv = jnp.abs(v)
    d = absv.shape[0]
    if _concrete(k) or isinstance(k, int):
        kth = jax.lax.top_k(absv, int(k))[0][-1]
    else:
        srt = jnp.sort(absv)                       # ascending
        idx = jnp.clip(d - k.astype(jnp.int32), 0, d - 1)
        kth = jnp.take(srt, idx)                   # == k-th largest
    mask = absv >= kth
    # Tie-break: keep at most k (top_k threshold may admit ties); paper's
    # payload accounting assumes exactly K coords, ties are measure-zero in
    # float updates so a >= mask is the standard implementation.
    sparse = jnp.where(mask, v, 0.0)
    return sparse, v - sparse


def _quantize_global(x: jax.Array, bits: int) -> jax.Array:
    """Symmetric fixed-point quantise/dequantise of nonzeros, global scale."""
    if bits >= 32:
        return x
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x))
    scale = amax / qmax
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -qmax, qmax)
    return jnp.where(scale > 0, q * scale, x)


def compress_update(
    delta: Any, err: jax.Array, cfg: CompressorConfig
) -> tuple[Any, jax.Array]:
    """Compress one client's model update (a pytree).

    Returns (reconstructed_update_tree, new_error_buffer).  The
    reconstruction is what the fog node receives after decode; the error
    buffer stays on the client (Eq. 30).
    """
    flat, unravel = ravel_pytree(delta)
    if not cfg.enabled:
        return delta, err

    if cfg.mode == "global":
        d = flat.shape[0]
        if _concrete(cfg.rho_s):
            k = max(1, int(round(cfg.rho_s * d)))
        else:
            k = jnp.maximum(
                1.0, jnp.round(jnp.asarray(cfg.rho_s, jnp.float32) * d)
            )
        v = flat + err
        if cfg.is_sparse:
            sparse, _ = _global_topk_ef(v, k)
        else:
            sparse = v
        recon = _quantize_global(sparse, cfg.quant_bits)
        new_err = v - recon
        return unravel(recon), new_err

    if cfg.mode == "blockwise":
        validate_blockwise_bits(cfg.quant_bits)
        k_frac = blockwise_k_frac(flat.shape[0], cfg.rho_s)
        if cfg.quant_bits < 32:
            recon, new_err, _ = kops.compress(
                flat, err, k_frac, cfg.use_pallas, cfg.interpret
            )
        else:
            recon, new_err = kops.topk_ef(
                flat, err, k_frac, cfg.use_pallas, cfg.interpret
            )
        return unravel(recon), new_err

    raise ValueError(f"unknown compression mode: {cfg.mode}")


def compression_ratio(d: int, cfg: CompressorConfig) -> float:
    """Effective ratio rho vs uncompressed 32-bit transmission (Sec. V-C)."""
    return payload_bits(d, cfg) / (32.0 * d)
