"""Batched serving driver (decode loop with KV cache).

Serves a reduced-config model on CPU: prefill a batch of prompts, then
autoregressively decode with the per-family cache (KV / SSM state / RG-LRU
state).  The full-size decode shapes (decode_32k, long_500k) are exercised
via launch/dryrun.py on the 512-device mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \\
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import api


def prefill_into_cache(cfg, params, cache, prompts: jax.Array):
    """Feed prompt tokens one step at a time (teacher-forced prefill).

    Production prefill is the fused full-sequence step (prefill_32k path);
    the token-stepped variant here keeps the serving loop family-agnostic
    on CPU since every family exposes decode_step.
    """
    step = api.make_serve_step(cfg)

    def body(carry, tok):
        cache, _ = carry
        cache, logits = step(params, cache, tok[:, None])
        return (cache, logits), None

    (cache, logits), _ = jax.lax.scan(
        body,
        (cache, jnp.zeros((prompts.shape[0], 1, cfg.vocab_size), jnp.float32)),
        prompts.T,
    )
    return cache, logits


def decode_tokens(cfg, params, cache, last_logits, n_new: int, key):
    """Greedy/temperature sampling decode loop, one token per step."""
    step = api.make_serve_step(cfg)

    def body(carry, k):
        cache, logits = carry
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        cache, logits = step(params, cache, tok[:, None])
        return (cache, logits), tok

    (_, _), toks = jax.lax.scan(
        body, (cache, last_logits), jax.random.split(key, n_new)
    )
    return toks.T  # (batch, n_new)


def main(argv: list[str] | None = None) -> None:
    """Run the serving driver; ``argv`` defaults to ``sys.argv[1:]`` so
    callers (e.g. examples/serve_model.py) can pass args directly instead
    of mutating ``sys.argv``."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, reduced=True)
    key = jax.random.key(args.seed)
    k_p, k_prompt, k_dec = jax.random.split(key, 3)

    params = api.init_params(k_p, cfg)
    max_seq = args.prompt_len + args.new_tokens + 1
    cache = api.init_cache(cfg, args.batch, max_seq)
    prompts = jax.random.randint(
        k_prompt, (args.batch, args.prompt_len), 0, cfg.vocab_size
    )

    t0 = time.time()
    cache, logits = prefill_into_cache(cfg, params, cache, prompts)
    t_prefill = time.time() - t0

    t0 = time.time()
    toks = decode_tokens(cfg, params, cache, logits, args.new_tokens, k_dec)
    toks.block_until_ready()
    t_decode = time.time() - t0

    out = {
        "arch": args.arch,
        "batch": args.batch,
        "prompt_len": args.prompt_len,
        "new_tokens": args.new_tokens,
        "prefill_s": round(t_prefill, 2),
        "decode_s": round(t_decode, 2),
        "tok_per_s": round(args.batch * args.new_tokens / max(t_decode, 1e-9), 1),
        "sample_output": toks[0, :8].tolist(),
    }
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
