import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry run: lower + compile every (architecture x input shape)
against the production mesh, with no device allocation (ShapeDtypeStruct
stand-ins), and dump memory/cost/collective analysis for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro import configs                     # noqa: E402
from repro.configs.base import SHAPES         # noqa: E402
from repro.launch import sharding as shlib    # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import api                  # noqa: E402

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64)\[([\d,]*)\]")

_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "f32": 4, "s32": 4,
    "u32": 4, "f64": 8, "s64": 8,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output-shape bytes of every collective op in optimized HLO.

    Uses the *result* shape of each collective instruction line (the data
    that actually crosses links, up to the usual 2(n-1)/n ring factor which
    the roofline treats as 1 — conservative and mesh-size independent).
    """
    out: dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    out["count"] = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        # HLO lines look like: `%x = bf16[..] all-gather(...)` — take ops only.
        m = re.match(r"%?[\w.\-]+ = (.+?) ([a-z\-]+)\(", s)
        if not m:
            continue
        opname = m.group(2)
        if opname in COLLECTIVE_OPS:
            out[opname] += _shape_bytes(m.group(1))
            out["count"] += 1
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


def _lower_one(cfg, shape, mesh):
    """Lower + compile one (config, shape) on ``mesh``; returns compiled."""
    params_abs = api.abstract_params(cfg)
    params_sh = shlib.tree_shardings(params_abs, api.param_axes(cfg), mesh)
    specs = api.input_specs(cfg, shape)
    specs_sh = shlib.batch_shardings(specs, mesh)
    long_ctx = shape.name == "long_500k"

    # set_mesh (not the legacy `with mesh:`) so the ambient abstract mesh
    # is visible to in-model activation sharding hints (layers.shard_hint).
    with jax.sharding.set_mesh(mesh):
        if shape.kind == "train":
            fn = api.make_train_step(cfg)
            lowered = jax.jit(
                fn,
                in_shardings=(params_sh, specs_sh),
                out_shardings=(params_sh, None),
                donate_argnums=(0,),
            ).lower(params_abs, specs)
        elif shape.kind == "prefill":
            fn = api.make_prefill_step(cfg)
            lowered = jax.jit(
                fn,
                in_shardings=(params_sh, specs_sh),
            ).lower(params_abs, specs)
        else:  # decode
            fn = api.make_serve_step(cfg, long_context=long_ctx)
            cache_abs = api.abstract_cache(
                cfg, shape.global_batch, shape.seq_len, long_ctx
            )
            cache_ax = api.module(cfg).cache_axes(cfg) if hasattr(
                api.module(cfg), "cache_axes"
            ) else None
            if cache_ax is not None:
                cache_sh = shlib.tree_shardings(cache_abs, cache_ax, mesh)
            else:
                cache_sh = jax.tree_util.tree_map(
                    lambda leaf: shlib.NamedSharding(
                        mesh,
                        shlib.resolve_spec(
                            _default_cache_logical(leaf), leaf.shape, mesh
                        ),
                    ),
                    cache_abs,
                )
            lowered = jax.jit(
                fn,
                in_shardings=(params_sh, cache_sh, specs_sh["tokens"]),
                out_shardings=((cache_sh, None)),
                donate_argnums=(1,),
            ).lower(params_abs, cache_abs, specs["tokens"])

        compiled = lowered.compile()
    return compiled


def dryrun_one(arch: str, shape_name: str, multi_pod: bool = False) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    ok, reason = api.supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()

    compiled = _lower_one(cfg.replace(scan_unroll=1), shape, mesh)
    compile_s = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_stats = {"error": str(e)}
    coll = collective_bytes(compiled.as_text())

    # --- while-body correction -------------------------------------------
    # XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    # count, so rolled layer stacks under-report FLOPs/bytes/collective
    # traffic by ~n_layers x.  Recover the per-layer cost from a second
    # lowering with the scan body unrolled 2x and extrapolate linearly:
    #   corrected = c1 + (L - 1) * max(c2 - c1, 0).
    # (Python-looped stacks — recurrentgemma — give c2 == c1 and stay put.)
    L = cfg.n_layers
    corr = {}
    try:
        compiled2 = _lower_one(cfg.replace(scan_unroll=2), shape, mesh)
        cost2 = compiled2.cost_analysis() or {}
        coll2 = collective_bytes(compiled2.as_text())

        def extrap(c1, c2):
            return c1 + (L - 1) * max(c2 - c1, 0.0)

        corr = {
            "flops": extrap(cost.get("flops", 0.0), cost2.get("flops", 0.0)),
            "bytes_accessed": extrap(
                cost.get("bytes accessed", 0.0),
                cost2.get("bytes accessed", 0.0),
            ),
            "collective_total": extrap(coll["total"], coll2["total"]),
            "per_layer_flops": max(
                cost2.get("flops", 0.0) - cost.get("flops", 0.0), 0.0
            ),
        }
    except Exception as e:  # fall back to raw numbers
        corr = {"error": str(e)}

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.shape.values()),
        "axes": list(mesh.axis_names),
        "chips": n_chips,
        "status": "ok",
        "kind": shape.kind,
        "compile_s": round(compile_s, 1),
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
        "collectives": coll,
        "corrected": corr,
        "memory": mem_stats,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    return result


def _default_cache_logical(leaf):
    nd = len(leaf.shape)
    if nd >= 4:
        return ("layers", "batch", "cache_seq", "kv_heads", "head_dim")[:nd]
    if nd == 2:
        return ("layers", "batch")
    return (None,) * nd


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    archs = configs.model_archs() if (args.all or not args.arch) else [
        configs.canonical(args.arch)
    ]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            combos.append((a, s))

    n_fail = 0
    for a, s in combos:
        tag = "multipod" if args.multi_pod else "pod"
        try:
            res = dryrun_one(a, s, multi_pod=args.multi_pod)
        except Exception as e:
            res = {"arch": a, "shape": s, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            n_fail += 1
        path = os.path.join(args.out, f"{a}__{s}__{tag}.json")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        status = res["status"]
        extra = ""
        if status == "ok":
            extra = (
                f"flops={res['flops']:.3e} "
                f"coll={res['collectives']['total']:.3e}B "
                f"compile={res['compile_s']}s"
            )
        elif status == "error":
            extra = res["error"][:160]
        else:
            extra = res.get("reason", "")[:80]
        print(f"[{status:7s}] {a:18s} x {s:12s} {extra}", flush=True)

    if n_fail:
        raise SystemExit(f"{n_fail} dry-run failures")


if __name__ == "__main__":
    main()
