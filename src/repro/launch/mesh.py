"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device; only
dryrun.py sets the 512-placeholder-device XLA flag).
"""
from __future__ import annotations

import jax


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` across JAX versions.

    Newer JAX exposes ``jax.shard_map`` (replication check flag named
    ``check_vma``); the 0.4.x line has it under ``jax.experimental`` with
    the flag named ``check_rep``.  Both checks are disabled — callers here
    mix collectives in ways the static replication checker rejects.
    """
    top_level = getattr(jax, "shard_map", None)
    if top_level is not None:
        return top_level(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_federated_mesh(*, multi_pod: bool = False):
    """Mesh for the hierarchical-FL runtime: the `data` axis carries
    federated clients; pods play the fog-cluster role (DESIGN.md §3)."""
    return make_production_mesh(multi_pod=multi_pod)


# TPU v5e hardware constants (roofline targets; this container is CPU-only).
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
