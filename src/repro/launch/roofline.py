"""Roofline analysis over the dry-run artifacts.

Reads experiments/dryrun/*.json (produced by launch/dryrun.py) and derives
the three roofline terms per (arch x shape x mesh):

    compute    = HLO_FLOPs      / (chips * PEAK_FLOPS_BF16)
    memory     = HLO_bytes      / (chips * HBM_BW)
    collective = coll_bytes     / (chips * ICI_BW)

plus MODEL_FLOPS = 6*N*D (dense; N_active for MoE) and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs.  Dominant term = the bottleneck the perf
loop iterates on.

  PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any

from repro import configs
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def model_flops(arch: str, shape_name: str) -> float:
    """6 * N_active * D for train (fwd+bwd); 2 * N_active * D for fwd-only."""
    cfg = configs.get(arch)
    shape = configs.SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyse(rec: dict[str, Any]) -> dict[str, Any] | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    # Prefer the while-body-corrected totals (see launch/dryrun.py): raw
    # cost_analysis counts rolled layer scans once.
    corr = rec.get("corrected") or {}
    flops = corr.get("flops", rec["flops"])
    nbytes = corr.get("bytes_accessed", rec["bytes_accessed"])
    coll_total = corr.get("collective_total", rec["collectives"]["total"])
    # cost_analysis() of the SPMD-partitioned module reports PER-DEVICE
    # work (per-device op shapes), so the roofline terms divide by the
    # per-chip peaks directly — NOT by chips again.
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = nbytes / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": "x".join(str(x) for x in rec["mesh"]),
        "chips": chips,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "model_flops": mf,
        "hlo_flops": flops,
        # per-device share of MODEL_FLOPS vs per-device compiled FLOPs.
        "useful_ratio": (mf / chips) / flops if flops else 0.0,
        "coll_bytes": coll_total,
        "peak_bytes_per_chip": (rec.get("memory") or {}).get("peak_bytes"),
    }


def load_all(directory: str, tag: str = "pod") -> list[dict[str, Any]]:
    rows = []
    for path in sorted(glob.glob(os.path.join(directory, f"*__{tag}.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyse(rec)
        if row is not None:
            rows.append(row)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:7.2f}s "
    if x >= 1e-3:
        return f"{x * 1e3:7.2f}ms"
    return f"{x * 1e6:7.1f}us"


def table(rows: list[dict[str, Any]]) -> str:
    hdr = (
        f"{'arch':18s} {'shape':12s} {'mesh':8s} "
        f"{'compute':>9s} {'memory':>9s} {'collective':>10s} "
        f"{'dominant':>10s} {'useful':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:8s} "
            f"{fmt_s(r['t_compute_s']):>9s} {fmt_s(r['t_memory_s']):>9s} "
            f"{fmt_s(r['t_collective_s']):>10s} "
            f"{r['dominant']:>10s} {r['useful_ratio']:6.1%}"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="pod")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    rows = load_all(args.dir, args.tag)
    print(table(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
