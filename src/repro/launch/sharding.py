"""Logical-axis -> mesh-axis resolution (MaxText-style rules with
divisibility fallback).

Each model exposes an ``axes(cfg)`` pytree whose leaves are tuples of
logical dimension names (or None for replicated leaves).  This module maps
them onto the physical mesh:

  model axis  <- first divisible logical dim in MODEL_PRIORITY
  data axis   <- "batch" when divisible (jointly with "pod" on multi-pod
                 meshes), else "embed" (FSDP), else "cache_seq"
  pod axis    <- only ever combined with "batch": parameters stay
                 replicated across pods (pure DP over the pod axis — the
                 fog-cluster analogue, DESIGN.md §3)

A dim never gets an axis it is not divisible by; a mesh axis is used at
most once per tensor.  The fallback chain is what lets every assigned
architecture (40 q-heads, 8 kv-heads, 60 experts, ...) lower on the same
16x16 mesh.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Order matters: prefer the big compute dims, fall back to head_dim.
# "seq_shard" is an ACTIVATION-only logical name (sequence-parallel
# attention for indivisible head counts — layers.shard_hint callers).
MODEL_PRIORITY = (
    "ff",
    "vocab",
    "heads",
    "kv_heads",
    "inner",
    "inner_proj",
    "inner_conv",
    "ssm_heads",
    "experts",
    "head_dim",
    "seq_shard",
)

DATA_PRIORITY = ("batch", "embed", "cache_seq", "tokens")


def client_mesh(devices=None) -> Mesh:
    """1-D ``("data",)`` mesh over the local devices — the client-axis
    shard_map mesh for the federated round loop (``core/hfl.train`` /
    ``core/flat_fl.train_flat`` ``client_mesh=`` and the engine's
    ``shard_clients`` mode): sensors shard over ``data``, fog reduction is
    a psum over it."""
    import numpy as np

    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devices), ("data",))


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def resolve_spec(
    logical: tuple[str | None, ...] | None,
    shape: tuple[int, ...],
    mesh: Mesh,
) -> P:
    """Resolve one leaf's logical axes to a PartitionSpec."""
    if logical is None:
        return P()
    assert len(logical) == len(shape), (logical, shape)
    assignment: list[Any] = [None] * len(shape)

    has_pod = "pod" in mesh.axis_names
    model_n = _axis_size(mesh, "model")
    data_n = _axis_size(mesh, "data")
    pod_n = _axis_size(mesh, "pod") if has_pod else 1

    # --- model axis ---
    for name in MODEL_PRIORITY:
        placed = False
        for i, ax in enumerate(logical):
            if ax == name and shape[i] % model_n == 0 and shape[i] > 0:
                assignment[i] = "model"
                placed = True
                break
        if placed:
            break

    # --- data (+pod) axis ---
    for name in DATA_PRIORITY:
        placed = False
        for i, ax in enumerate(logical):
            if ax != name or assignment[i] is not None or shape[i] == 0:
                continue
            if name == "batch" and has_pod and shape[i] % (pod_n * data_n) == 0:
                assignment[i] = ("pod", "data")
                placed = True
            elif shape[i] % data_n == 0:
                assignment[i] = "data"
                placed = True
            if placed:
                break
        if placed:
            break

    return P(*assignment)


def tree_shardings(abstract: Any, axes_tree: Any, mesh: Mesh) -> Any:
    """NamedSharding tree for an abstract (ShapeDtypeStruct) pytree.

    ``axes_tree`` must be none-for-none structurally compatible: leaves of
    ``abstract`` that are None in ``axes_tree`` are replicated.
    """

    def one(leaf, logical):
        return NamedSharding(mesh, resolve_spec(logical, leaf.shape, mesh))

    # axes_tree leaves are tuples (which jax would treat as pytrees), so
    # walk `abstract`'s structure and look the logical tuple up positionally.
    flat_abs, treedef = jax.tree_util.tree_flatten(abstract)
    # Flatten axes_tree treating tuples-of-strings/None as leaves.
    def is_leaf(x):
        # Logical-axes tuples are leaves; bare None stays a (dropped) empty
        # node, matching how None params vanish from `abstract`.
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        )

    flat_axes = jax.tree_util.tree_flatten(axes_tree, is_leaf=is_leaf)[0]
    # None-axes leaves pair with None abstract leaves and are dropped by
    # tree_flatten of `abstract` too, so lengths must match.
    assert len(flat_abs) == len(flat_axes), (
        f"axes tree mismatch: {len(flat_abs)} params vs {len(flat_axes)} axes"
    )
    shardings = [one(a, x) for a, x in zip(flat_abs, flat_axes)]
    return jax.tree_util.tree_unflatten(treedef, shardings)


def batch_shardings(specs: dict[str, jax.ShapeDtypeStruct], mesh: Mesh) -> dict:
    """Input batches: shard the leading (batch) dim over (pod, data)."""
    out = {}
    for k, v in specs.items():
        logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, resolve_spec(logical, v.shape, mesh))
    return out
