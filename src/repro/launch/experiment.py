"""Shared federated-experiment runner (one call = one paper table cell).

Every benchmark module and the training launcher funnel through
:func:`run_method`, so the evaluation protocol (train -> calibrate on
normal-only validation -> score test -> F1 / PA-F1, plus the per-round
energy/participation traces) is identical everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import anomaly, cooperation as coop, flat_fl, hfl
from repro.core import topology as topo
from repro.data.synthetic import SensorDataset
from repro.models import autoencoder as ae

METHODS = (
    "centralised",
    "fedavg",
    "fedprox",
    "fedadam",
    "scaffold",
    "hfl-nocoop",
    "hfl-selective",
    "hfl-nearest",
    "hfl-adam",
)

_RULES = {
    "hfl-nocoop": coop.CoopRule.NOCOOP,
    "hfl-selective": coop.CoopRule.SELECTIVE,
    "hfl-nearest": coop.CoopRule.NEAREST,
    "hfl-adam": coop.CoopRule.SELECTIVE,   # FedAdam server + selective coop
}

# FedProx proximal coefficient (paper uses mu ~ 0.01 scale defaults).
PROX_MU = 0.01


@dataclasses.dataclass(frozen=True)
class ExperimentResult:
    method: str
    f1: float
    precision: float
    recall: float
    participation: float       # mean over rounds
    e_total: float             # sum over rounds (J)
    e_s2f: float
    e_f2f: float
    e_f2g: float
    losses: tuple[float, ...]  # per-round mean training loss
    coop_links: float          # mean active fog-to-fog exchanges per round


def _detector_eval(
    params: Any, ds: SensorDataset, percentile: float, point_adjusted: bool
) -> anomaly.F1Result:
    """Paper protocol with the GLOBAL threshold variant (Sec. V-D)."""
    d = ds.val.shape[-1]
    val = ds.val.reshape(-1, d)
    test = ds.test.reshape(-1, d)
    label = ds.test_label.reshape(-1)
    return anomaly.evaluate_detector(
        lambda p, x: ae.apply(p, x),
        params,
        val,
        test,
        label,
        percentile=percentile,
        point_adjusted=point_adjusted,
    )


def run_method(
    method: str,
    ds: SensorDataset,
    cfg: hfl.HFLConfig,
    seed: int = 0,
    percentile: float = 99.0,
    point_adjusted: bool = False,
    hidden: tuple[int, ...] = (16, 8, 16),
) -> ExperimentResult:
    """Train ``method`` on ``ds`` and evaluate the paper's metrics."""
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; one of {METHODS}")
    key = jax.random.key(seed)
    k_init, k_train = jax.random.split(key)
    dim = ds.train.shape[-1]
    params0 = ae.init(k_init, dim, hidden)

    zeros = dict.fromkeys(
        ("e_s2f", "e_f2f", "e_f2g", "participation", "coop_links"), 0.0
    )
    if method == "centralised":
        params, losses, e_up = flat_fl.train_centralised(
            k_train, params0, ae.loss, ds, cfg
        )
        # Oracle sees everything by construction.
        metrics = dict(zeros, e_total=float(e_up), participation=1.0)
        loss_trace = tuple(float(x) for x in losses)
    else:
        if method in ("fedavg", "fedprox", "fedadam"):
            run_cfg = cfg.replace(
                prox_mu=PROX_MU if method == "fedprox" else 0.0,
                server_opt="adam" if method == "fedadam" else cfg.server_opt,
            )
            params, m = flat_fl.train_flat(k_train, params0, ae.loss, ds, run_cfg)
        elif method == "scaffold":
            params, m = flat_fl.train_scaffold(k_train, params0, ae.loss, ds, cfg)
        else:
            run_cfg = cfg.replace(
                rule=_RULES[method],
                prox_mu=0.0,
                server_opt="adam" if method == "hfl-adam" else cfg.server_opt,
            )
            params, m = hfl.train(k_train, params0, ae.loss, ds, run_cfg)
        metrics = {
            "e_total": float(jnp.sum(m.e_total)),
            "e_s2f": float(jnp.sum(m.e_s2f)),
            "e_f2f": float(jnp.sum(m.e_f2f)),
            "e_f2g": float(jnp.sum(m.e_f2g)),
            "participation": float(jnp.mean(m.participation)),
            "coop_links": float(jnp.mean(m.coop_links)),
        }
        loss_trace = tuple(float(x) for x in m.loss)

    f1 = _detector_eval(params, ds, percentile, point_adjusted)
    return ExperimentResult(
        method=method,
        f1=float(f1.f1),
        precision=float(f1.precision),
        recall=float(f1.recall),
        losses=loss_trace,
        **{k: metrics.get(k, 0.0) for k in (
            "participation", "e_total", "e_s2f", "e_f2f", "e_f2g", "coop_links"
        )},
    )


def audit_method(
    method: str,
    cfg: hfl.HFLConfig,
    d: int = 1352,
    seed: int = 0,
) -> dict:
    """Replay Algorithm 1's decision + energy accounting WITHOUT training.

    Per-round communication energy in the simulator depends only on the
    topology, association/cooperation decisions, and payload sizes — not on
    model values — so the paper's *energy and participation* tables can be
    reproduced at full scale (N=200, T=20) cheaply.  F1 columns still come
    from :func:`run_method` at whatever scale the budget allows.
    """
    from repro.core import association as assoc
    from repro.core import channel as chm
    from repro.core import compression as comp
    from repro.core import cooperation as coop_m
    from repro.core import energy as en
    from repro.core import topology as topo_m

    if method in ("fedavg", "fedprox", "fedadam", "scaffold"):
        kind = "flat"
    elif method in _RULES:
        kind = "hfl"
    else:
        raise ValueError(f"audit unsupported for {method!r}")

    key = jax.random.key(seed)
    dep0 = topo_m.sample_deployment(key, cfg.deployment)
    l_u = comp.payload_bits(d, cfg.compressor)
    l_full = 32.0 * d

    def round_fn(carry, k):
        dep = carry
        dep = topo_m.gauss_markov_step(k, dep, cfg.deployment) if cfg.fog_mobility else dep
        if kind == "flat":
            fa = assoc.flat_association(dep, cfg.channel)
            e_up = en.tx_energy_j(l_u, fa.dist_m, cfg.channel, cfg.energy)
            e_s2f = jnp.sum(jnp.where(fa.participates, e_up, 0.0))
            out = dict(
                e_s2f=e_s2f, e_f2f=jnp.zeros(()), e_f2g=jnp.zeros(()),
                participation=jnp.mean(fa.participates.astype(jnp.float32)),
                coop_links=jnp.zeros(()),
            )
        else:
            fa = assoc.nearest_feasible_fog(dep, cfg.channel)
            decision = coop_m.decide(
                _RULES[method], dep.fog_pos, fa.cluster_size, cfg.channel
            )
            e_up = en.tx_energy_j(l_u, fa.dist_m, cfg.channel, cfg.energy)
            e_s2f = jnp.sum(jnp.where(fa.participates, e_up, 0.0))
            fog_active = fa.cluster_size > 0
            e_ff = en.tx_energy_j(
                l_full, decision.dist_m, cfg.channel, cfg.energy
            )
            e_f2f = jnp.sum(
                jnp.where(decision.cooperates & fog_active, e_ff, 0.0)
            )
            e_fg = en.tx_energy_j(
                l_full, fa.fog_gateway_dist_m, cfg.channel, cfg.energy
            )
            e_f2g = jnp.sum(
                jnp.where(fog_active & fa.fog_gateway_feasible, e_fg, 0.0)
            )
            out = dict(
                e_s2f=e_s2f, e_f2f=e_f2f, e_f2g=e_f2g,
                participation=jnp.mean(fa.participates.astype(jnp.float32)),
                coop_links=jnp.sum(decision.cooperates.astype(jnp.float32)),
            )
        return dep, out

    keys = jax.random.split(jax.random.fold_in(key, 1), cfg.rounds)
    _, m = jax.lax.scan(jax.jit(round_fn), dep0, keys)
    total = {k: float(jnp.sum(v)) for k, v in m.items() if k.startswith("e_")}
    total["e_total"] = total["e_s2f"] + total["e_f2f"] + total["e_f2g"]
    total["participation"] = float(jnp.mean(m["participation"]))
    total["coop_links"] = float(jnp.mean(m["coop_links"]))
    total["method"] = method
    return total


def make_config(
    n_sensors: int,
    n_fog: int,
    rounds: int,
    **overrides: Any,
) -> hfl.HFLConfig:
    """Paper Table II defaults with per-experiment overrides."""
    dep = topo.DeploymentParams(n_sensors=n_sensors, n_fog=n_fog)
    return hfl.HFLConfig(deployment=dep, rounds=rounds).replace(**overrides)


def seed_sweep(
    method: str,
    ds_fn,
    cfg: hfl.HFLConfig,
    seeds: tuple[int, ...] = (0, 1, 2),
    **kw: Any,
) -> tuple[ExperimentResult, ...]:
    """Run ``method`` over seeds; ``ds_fn(seed) -> SensorDataset``."""
    return tuple(
        run_method(method, ds_fn(s), cfg, seed=s, **kw) for s in seeds
    )


def mean_std(values: list[float]) -> tuple[float, float]:
    arr = jnp.asarray(values)
    return float(jnp.mean(arr)), float(jnp.std(arr))
