"""Shared federated-experiment runner (one call = one paper table cell).

Every benchmark module and the training launcher funnel through
:func:`run_method`, so the evaluation protocol (train -> calibrate on
normal-only validation -> score test -> F1 / PA-F1, plus the per-round
energy/participation traces) is identical everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import anomaly, async_fl, flat_fl, hfl
from repro.core import cooperation as coop
from repro.core import topology as topo
from repro.data.synthetic import SensorDataset
from repro.models import autoencoder as ae

METHODS = (
    "centralised",
    "fedavg",
    "fedprox",
    "fedadam",
    "scaffold",
    "hfl-nocoop",
    "hfl-selective",
    "hfl-nearest",
    "hfl-adam",
    "hfl-async",
)

_RULES = {
    "hfl-nocoop": coop.CoopRule.NOCOOP,
    "hfl-selective": coop.CoopRule.SELECTIVE,
    "hfl-nearest": coop.CoopRule.NEAREST,
    "hfl-adam": coop.CoopRule.SELECTIVE,   # FedAdam server + selective coop
}

# FedProx proximal coefficient (paper uses mu ~ 0.01 scale defaults).
PROX_MU = 0.01


@dataclasses.dataclass(frozen=True)
class ExperimentResult:
    method: str
    f1: float
    precision: float
    recall: float
    participation: float       # mean over rounds
    e_total: float             # sum over rounds (J)
    e_s2f: float
    e_f2f: float
    e_f2g: float
    losses: tuple[float, ...]  # per-round mean training loss
    coop_links: float          # mean active fog-to-fog exchanges per round


def _detector_eval(
    params: Any, ds: SensorDataset, percentile: float, point_adjusted: bool
) -> anomaly.F1Result:
    """Paper protocol with the GLOBAL threshold variant (Sec. V-D)."""
    d = ds.val.shape[-1]
    val = ds.val.reshape(-1, d)
    test = ds.test.reshape(-1, d)
    label = ds.test_label.reshape(-1)
    return anomaly.evaluate_detector(
        lambda p, x: ae.apply(p, x),
        params,
        val,
        test,
        label,
        percentile=percentile,
        point_adjusted=point_adjusted,
    )


def trial_metrics(
    method: str,
    key: jax.Array,
    ds: SensorDataset,
    cfg: hfl.HFLConfig | async_fl.AsyncFLConfig,
    *,
    percentile: float = 99.0,
    point_adjusted: bool = False,
    hidden: tuple[int, ...] = (16, 8, 16),
    client_mesh=None,
    return_params: bool = False,
) -> dict[str, jax.Array]:
    """One fully traced trial: train ``method`` from ``key``, evaluate.

    This is the jittable core shared by the sequential :func:`run_method`
    path and the batched :class:`repro.engine.Engine` (which vmaps it over
    a leading trial axis).  Everything returned is a jnp value; only
    ``method``/``cfg``/keyword knobs are static.

    ``client_mesh``: optional 1-D ``("data",)`` mesh — shards the client
    axis of the hfl / flat-FL round loops over devices (scaffold and the
    centralised oracle run unsharded; they bypass the fused pipeline).

    ``return_params``: include the trained model under ``"params"`` (used
    by ``Engine.run(store=...)`` to publish rounds for the serving path).

    ``method="hfl-async"`` runs the event-driven staleness-aware family
    (``core/async_fl``); ``cfg`` may then be an
    :class:`repro.core.async_fl.AsyncFLConfig` (a plain ``HFLConfig`` is
    wrapped with the async defaults).  Every branch also reports
    ``sim_time_s`` — summed Eq. 21 round latency for the synchronous
    loops, the final simulated clock for the async loop — so
    accuracy-vs-simulated-wall-clock comparisons read one key.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; one of {METHODS}")
    k_init, k_train = jax.random.split(key)
    dim = ds.train.shape[-1]
    params0 = ae.init(k_init, dim, hidden)

    zero = jnp.zeros(())
    if method == "centralised":
        params, losses, e_up = flat_fl.train_centralised(
            k_train, params0, ae.loss, ds, cfg
        )
        # Oracle sees everything by construction.
        out = {
            "e_s2f": zero, "e_f2f": zero, "e_f2g": zero,
            "e_total": e_up, "participation": jnp.ones(()),
            "coop_links": zero, "losses": losses, "sim_time_s": zero,
            # No federated uplinks: the robustness counters are trivially 0.
            "nonfinite_total": zero, "erased_total": zero,
            "nonfinite_rounds": zero,
        }
    elif method == "hfl-async":
        acfg = (
            cfg if isinstance(cfg, async_fl.AsyncFLConfig)
            else async_fl.AsyncFLConfig(base=cfg)
        )
        params, m = async_fl.train(k_train, params0, ae.loss, ds, acfg)
        arrived_f = m.n_arrived.astype(jnp.float32)
        out = {
            "e_total": jnp.sum(m.e_total),
            "e_s2f": jnp.sum(m.e_s2f),
            "e_f2f": jnp.sum(m.e_f2f),
            "e_f2g": jnp.sum(m.e_f2g),
            "participation": jnp.mean(m.participation),
            "coop_links": jnp.mean(m.coop_links.astype(jnp.float32)),
            "losses": m.loss,
            "sim_time_s": m.t_sim[-1],
            "merges": jnp.sum(m.merged.astype(jnp.float32)),
            "staleness": jnp.sum(m.staleness * arrived_f)
            / jnp.maximum(jnp.sum(arrived_f), 1.0),
            "nonfinite_total": jnp.sum(m.n_nonfinite.astype(jnp.float32)),
            "erased_total": jnp.sum(m.n_erased.astype(jnp.float32)),
            "nonfinite_rounds": jnp.sum(
                1.0 - m.global_finite.astype(jnp.float32)
            ),
        }
    else:
        if method in ("fedavg", "fedprox", "fedadam"):
            run_cfg = cfg.replace(
                prox_mu=PROX_MU if method == "fedprox" else 0.0,
                server_opt="adam" if method == "fedadam" else cfg.server_opt,
            )
            params, m = flat_fl.train_flat(
                k_train, params0, ae.loss, ds, run_cfg,
                client_mesh=client_mesh,
            )
        elif method == "scaffold":
            params, m = flat_fl.train_scaffold(k_train, params0, ae.loss, ds, cfg)
        else:
            run_cfg = cfg.replace(
                rule=_RULES[method],
                prox_mu=0.0,
                server_opt="adam" if method == "hfl-adam" else cfg.server_opt,
            )
            params, m = hfl.train(
                k_train, params0, ae.loss, ds, run_cfg,
                client_mesh=client_mesh,
            )
        out = {
            "e_total": jnp.sum(m.e_total),
            "e_s2f": jnp.sum(m.e_s2f),
            "e_f2f": jnp.sum(m.e_f2f),
            "e_f2g": jnp.sum(m.e_f2g),
            "participation": jnp.mean(m.participation),
            "coop_links": jnp.mean(m.coop_links.astype(jnp.float32)),
            "losses": m.loss,
            "sim_time_s": jnp.sum(m.latency_s),
            "nonfinite_total": jnp.sum(m.n_nonfinite.astype(jnp.float32)),
            "erased_total": jnp.sum(m.n_erased.astype(jnp.float32)),
            "nonfinite_rounds": jnp.sum(
                1.0 - m.global_finite.astype(jnp.float32)
            ),
        }

    f1 = _detector_eval(params, ds, percentile, point_adjusted)
    out.update(f1=f1.f1, precision=f1.precision, recall=f1.recall)
    if return_params:
        out["params"] = params
    return out


def run_method(
    method: str,
    ds: SensorDataset,
    cfg: hfl.HFLConfig | async_fl.AsyncFLConfig,
    seed: int = 0,
    percentile: float = 99.0,
    point_adjusted: bool = False,
    hidden: tuple[int, ...] = (16, 8, 16),
) -> ExperimentResult:
    """Train ``method`` on ``ds`` and evaluate the paper's metrics."""
    m = trial_metrics(
        method, jax.random.key(seed), ds, cfg,
        percentile=percentile, point_adjusted=point_adjusted, hidden=hidden,
    )
    return ExperimentResult(
        method=method,
        f1=float(m["f1"]),
        precision=float(m["precision"]),
        recall=float(m["recall"]),
        losses=tuple(float(x) for x in m["losses"]),
        participation=float(m["participation"]),
        e_total=float(m["e_total"]),
        e_s2f=float(m["e_s2f"]),
        e_f2f=float(m["e_f2f"]),
        e_f2g=float(m["e_f2g"]),
        coop_links=float(m["coop_links"]),
    )


def audit_trial(
    method: str,
    key: jax.Array,
    cfg: hfl.HFLConfig,
    d: int = 1352,
    l_u: jax.Array | float | None = None,
) -> dict[str, jax.Array]:
    """One fully traced training-free audit trial (see :func:`audit_method`).

    Jittable core shared by the sequential wrapper and the batched engine:
    samples a deployment from ``key``, replays Algorithm 1's association /
    cooperation / energy accounting over ``cfg.rounds`` rounds, and returns
    summed energies + mean participation as jnp scalars.

    ``l_u`` overrides the uplink payload (bits).  The audit touches the
    compressor ONLY through this number, so ``Engine.sweep`` precomputes it
    per config and feeds it as a swept operand — audit cells that differ
    only in compressor settings then share one compiled program.
    """
    from repro.core import association as assoc
    from repro.core import compression as comp
    from repro.core import cooperation as coop_m
    from repro.core import energy as en
    from repro.core import topology as topo_m

    if method in ("fedavg", "fedprox", "fedadam", "scaffold"):
        kind = "flat"
    elif method in _RULES:
        kind = "hfl"
    else:
        raise ValueError(f"audit unsupported for {method!r}")

    dep0 = topo_m.sample_deployment(key, cfg.deployment)
    if l_u is None:
        l_u = comp.payload_bits(d, cfg.compressor)
    l_full = 32.0 * d

    def round_fn(carry, k):
        dep = carry
        dep = topo_m.gauss_markov_step(k, dep, cfg.deployment) if cfg.fog_mobility else dep
        if kind == "flat":
            fa = assoc.flat_association(dep, cfg.channel)
            e_up = en.tx_energy_j(l_u, fa.dist_m, cfg.channel, cfg.energy)
            e_s2f = jnp.sum(jnp.where(fa.participates, e_up, 0.0))
            out = dict(
                e_s2f=e_s2f, e_f2f=jnp.zeros(()), e_f2g=jnp.zeros(()),
                participation=jnp.mean(fa.participates.astype(jnp.float32)),
                coop_links=jnp.zeros(()),
            )
        else:
            fa = assoc.nearest_feasible_fog(dep, cfg.channel)
            decision = coop_m.decide(
                _RULES[method], dep.fog_pos, fa.cluster_size, cfg.channel
            )
            e_up = en.tx_energy_j(l_u, fa.dist_m, cfg.channel, cfg.energy)
            e_s2f = jnp.sum(jnp.where(fa.participates, e_up, 0.0))
            fog_active = fa.cluster_size > 0
            e_ff = en.tx_energy_j(
                l_full, decision.dist_m, cfg.channel, cfg.energy
            )
            e_f2f = jnp.sum(
                jnp.where(decision.cooperates & fog_active, e_ff, 0.0)
            )
            e_fg = en.tx_energy_j(
                l_full, fa.fog_gateway_dist_m, cfg.channel, cfg.energy
            )
            e_f2g = jnp.sum(
                jnp.where(fog_active & fa.fog_gateway_feasible, e_fg, 0.0)
            )
            out = dict(
                e_s2f=e_s2f, e_f2f=e_f2f, e_f2g=e_f2g,
                participation=jnp.mean(fa.participates.astype(jnp.float32)),
                coop_links=jnp.sum(decision.cooperates.astype(jnp.float32)),
            )
        return dep, out

    keys = jax.random.split(jax.random.fold_in(key, 1), cfg.rounds)
    _, m = jax.lax.scan(round_fn, dep0, keys)
    total = {k: jnp.sum(v) for k, v in m.items() if k.startswith("e_")}
    total["e_total"] = total["e_s2f"] + total["e_f2f"] + total["e_f2g"]
    total["participation"] = jnp.mean(m["participation"])
    total["coop_links"] = jnp.mean(m["coop_links"])
    return total


def audit_method(
    method: str,
    cfg: hfl.HFLConfig,
    d: int = 1352,
    seed: int = 0,
) -> dict:
    """Replay Algorithm 1's decision + energy accounting WITHOUT training.

    Per-round communication energy in the simulator depends only on the
    topology, association/cooperation decisions, and payload sizes — not on
    model values — so the paper's *energy and participation* tables can be
    reproduced at full scale (N=200, T=20) cheaply.  F1 columns still come
    from :func:`run_method` at whatever scale the budget allows.
    """
    m = audit_trial(method, jax.random.key(seed), cfg, d)
    out = {k: float(v) for k, v in m.items()}
    out["method"] = method
    return out


def make_config(
    n_sensors: int,
    n_fog: int,
    rounds: int,
    **overrides: Any,
) -> hfl.HFLConfig:
    """Paper Table II defaults with per-experiment overrides."""
    dep = topo.DeploymentParams(n_sensors=n_sensors, n_fog=n_fog)
    return hfl.HFLConfig(deployment=dep, rounds=rounds).replace(**overrides)


def seed_sweep(
    method: str,
    ds_fn,
    cfg: hfl.HFLConfig,
    seeds: tuple[int, ...] = (0, 1, 2),
    **kw: Any,
) -> tuple[ExperimentResult, ...]:
    """Run ``method`` over seeds; ``ds_fn(seed) -> SensorDataset``."""
    return tuple(
        run_method(method, ds_fn(s), cfg, seed=s, **kw) for s in seeds
    )


def mean_std(values: list[float]) -> tuple[float, float]:
    arr = jnp.asarray(values)
    return float(jnp.mean(arr)), float(jnp.std(arr))
