"""Training launcher.

Two entry modes:

  federated  — the paper's pipeline: hierarchical (or flat) federated
               anomaly-detector training over the simulated underwater
               acoustic network, with checkpointing and metric logs.

      PYTHONPATH=src python -m repro.launch.train federated \\
          --method hfl-selective --sensors 100 --fog 10 --rounds 20

  production — data-parallel training of an assigned architecture on the
               local mesh (reduced config on CPU; the full config is
               exercised via launch/dryrun.py on the 512-device mesh).

      PYTHONPATH=src python -m repro.launch.train production \\
          --arch llama3-8b --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.checkpoint import CheckpointStore
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.launch import experiment as exp
from repro.models import api


def run_federated(args: argparse.Namespace) -> dict:
    cfg = exp.make_config(
        n_sensors=args.sensors,
        n_fog=args.fog,
        rounds=args.rounds,
        local_epochs=args.local_epochs,
        lr=args.lr,
    )
    ds = normalize(
        generate(
            jax.random.key(args.seed),
            SyntheticConfig(
                n_sensors=args.sensors, dirichlet_alpha=args.dirichlet_alpha
            ),
        )
    )
    t0 = time.time()
    res = exp.run_method(args.method, ds, cfg, seed=args.seed)
    wall = time.time() - t0
    out = {
        "mode": "federated",
        "method": res.method,
        "f1": res.f1,
        "participation": res.participation,
        "energy_j": {
            "total": res.e_total,
            "s2f": res.e_s2f,
            "f2f": res.e_f2f,
            "f2g": res.e_f2g,
        },
        "final_loss": res.losses[-1] if res.losses else None,
        "wall_s": round(wall, 1),
    }
    return out


def run_production(args: argparse.Namespace) -> dict:
    cfg = configs.get(args.arch, reduced=not args.full)
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    key = jax.random.key(args.seed)
    params = api.init_params(key, cfg)
    step = api.make_train_step(cfg)

    store = CheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if store is not None and store.latest_step() is not None:
        params, start = store.restore(params)
        print(f"restored checkpoint at step {start}")

    batch_sh = NamedSharding(mesh, P("data"))
    jstep = jax.jit(step, in_shardings=(None, {"tokens": batch_sh}),
                    donate_argnums=(0,))

    losses = []
    t0 = time.time()
    with mesh:
        for i in range(start, start + args.steps):
            key, kb = jax.random.split(key)
            batch = {
                "tokens": jax.random.randint(
                    kb, (args.batch, args.seq), 0, cfg.vocab_size
                )
            }
            if cfg.family == "encdec":
                batch["audio_embeds"] = jax.random.normal(
                    kb, (args.batch, cfg.n_audio_frames, cfg.d_model), cfg.dtype
                )
            if cfg.n_visual_tokens > 0:
                batch["visual_embeds"] = jax.random.normal(
                    kb, (args.batch, cfg.n_visual_tokens, cfg.d_model), cfg.dtype
                )
                jstep_v = jax.jit(step, donate_argnums=(0,))
                params, loss = jstep_v(params, batch)
            else:
                params, loss = jstep(params, batch)
            losses.append(float(loss))
            if store is not None and (i + 1) % args.ckpt_every == 0:
                store.save(i + 1, params)
    wall = time.time() - t0
    if store is not None:
        store.save(start + args.steps, params)
    return {
        "mode": "production",
        "arch": args.arch,
        "steps": args.steps,
        "loss_first": losses[0],
        "loss_last": losses[-1],
        "wall_s": round(wall, 1),
        "finite": all(jnp.isfinite(jnp.asarray(losses)).tolist()),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="mode", required=True)

    fed = sub.add_parser("federated")
    fed.add_argument("--method", default="hfl-selective", choices=exp.METHODS)
    fed.add_argument("--sensors", type=int, default=100)
    fed.add_argument("--fog", type=int, default=10)
    fed.add_argument("--rounds", type=int, default=20)
    fed.add_argument("--local-epochs", type=int, default=5)
    fed.add_argument("--lr", type=float, default=0.01)
    fed.add_argument("--dirichlet-alpha", type=float, default=1.0)
    fed.add_argument("--seed", type=int, default=0)

    prod = sub.add_parser("production")
    prod.add_argument("--arch", required=True)
    prod.add_argument("--steps", type=int, default=10)
    prod.add_argument("--batch", type=int, default=4)
    prod.add_argument("--seq", type=int, default=64)
    prod.add_argument("--full", action="store_true",
                      help="full config (dry-run scale; not for CPU)")
    prod.add_argument("--ckpt-dir", default=None)
    prod.add_argument("--ckpt-every", type=int, default=100)
    prod.add_argument("--seed", type=int, default=0)

    args = ap.parse_args()
    out = run_federated(args) if args.mode == "federated" else run_production(args)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
