"""Whisper-style encoder-decoder transformer backbone (arXiv:2212.04356).

Per the assignment, the mel-spectrogram + conv feature extractor is a STUB:
``input_specs`` supplies precomputed frame embeddings (B, n_audio_frames,
d_model).  We implement the transformer backbone faithfully: bidirectional
encoder with sinusoidal positions, causal decoder with self- and
cross-attention.  Deviation recorded in DESIGN.md: RoPE-free absolute
positions use the sinusoidal table on both sides (whisper's decoder uses a
learned table capped at 448 positions; the assigned decode shapes require
32k-token caches, so a fixed sinusoidal table is the faithful-in-spirit
choice that scales).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L


class EncBlock(NamedTuple):
    ln1: jax.Array
    attn: attn.AttnParams
    ln2: jax.Array
    w_gate: jax.Array
    w_up: jax.Array
    w_down: jax.Array


class DecBlock(NamedTuple):
    ln1: jax.Array
    self_attn: attn.AttnParams
    ln_x: jax.Array
    cross_attn: attn.AttnParams
    ln2: jax.Array
    w_gate: jax.Array
    w_up: jax.Array
    w_down: jax.Array


class Params(NamedTuple):
    enc_blocks: EncBlock          # stacked (n_enc_layers, ...)
    enc_final: jax.Array
    embed: jax.Array
    dec_blocks: DecBlock          # stacked (n_layers, ...)
    final_norm: jax.Array


def _init_enc(key: jax.Array, cfg: ModelConfig) -> EncBlock:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, ff = cfg.d_model, cfg.d_ff
    return EncBlock(
        ln1=jnp.zeros((d,), cfg.dtype),
        attn=attn.init(k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                       False, cfg.dtype),
        ln2=jnp.zeros((d,), cfg.dtype),
        w_gate=L.dense_init(k2, (d, ff), cfg.dtype),
        w_up=L.dense_init(k3, (d, ff), cfg.dtype),
        w_down=L.dense_init(k4, (ff, d), cfg.dtype),
    )


def _init_dec(key: jax.Array, cfg: ModelConfig) -> DecBlock:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d, ff = cfg.d_model, cfg.d_ff
    return DecBlock(
        ln1=jnp.zeros((d,), cfg.dtype),
        self_attn=attn.init(k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                            False, cfg.dtype),
        ln_x=jnp.zeros((d,), cfg.dtype),
        cross_attn=attn.init(k2, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                             False, cfg.dtype),
        ln2=jnp.zeros((d,), cfg.dtype),
        w_gate=L.dense_init(k3, (d, ff), cfg.dtype),
        w_up=L.dense_init(k4, (d, ff), cfg.dtype),
        w_down=L.dense_init(k5, (ff, d), cfg.dtype),
    )


def init(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, kb, kd = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: _init_enc(k, cfg))(
        jax.random.split(kb, cfg.n_enc_layers)
    )
    dec = jax.vmap(lambda k: _init_dec(k, cfg))(
        jax.random.split(kd, cfg.n_layers)
    )
    return Params(
        enc_blocks=enc,
        enc_final=jnp.zeros((cfg.d_model,), cfg.dtype),
        embed=L.embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        dec_blocks=dec,
        final_norm=jnp.zeros((cfg.d_model,), cfg.dtype),
    )


def axes(cfg: ModelConfig) -> Params:
    a = attn.AttnParams(
        wq=("layers", "embed", "heads", "head_dim"),
        wk=("layers", "embed", "kv_heads", "head_dim"),
        wv=("layers", "embed", "kv_heads", "head_dim"),
        wo=("layers", "heads", "head_dim", "embed"),
        q_norm=None, k_norm=None,
    )
    return Params(
        enc_blocks=EncBlock(
            ln1=("layers", "embed"), attn=a, ln2=("layers", "embed"),
            w_gate=("layers", "embed", "ff"), w_up=("layers", "embed", "ff"),
            w_down=("layers", "ff", "embed"),
        ),
        enc_final=("embed",),
        embed=("vocab", "embed"),
        dec_blocks=DecBlock(
            ln1=("layers", "embed"), self_attn=a, ln_x=("layers", "embed"),
            cross_attn=a, ln2=("layers", "embed"),
            w_gate=("layers", "embed", "ff"), w_up=("layers", "embed", "ff"),
            w_down=("layers", "ff", "embed"),
        ),
        final_norm=("embed",),
    )


def encode(params: Params, audio_embeds: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Bidirectional encoder over stubbed frame embeddings (b, t_a, d)."""
    b, t_a, d = audio_embeds.shape
    pos = L.sinusoidal_positions(t_a, d).astype(audio_embeds.dtype)
    x = audio_embeds + pos[None]
    positions = jnp.broadcast_to(jnp.arange(t_a), (b, t_a))

    def block(x, bp):
        def fn(bp, x):
            h = attn.full_attention(
                bp.attn, L.rms_norm(x, bp.ln1), positions,
                rope_theta=None, causal=False,
            )
            x = x + h
            return x + L.swiglu(
                L.rms_norm(x, bp.ln2), bp.w_gate, bp.w_up, bp.w_down,
                act=jax.nn.gelu,
            )
        if cfg.remat:
            fn = jax.checkpoint(fn)
        return fn(bp, x), None

    x, _ = jax.lax.scan(block, x, params.enc_blocks, unroll=cfg.scan_unroll)
    return L.rms_norm(x, params.enc_final)


def _dec_block(cfg, bp, x, positions, enc_out):
    h = attn.full_attention(
        bp.self_attn, L.rms_norm(x, bp.ln1), positions, rope_theta=None
    )
    x = x + h
    ekv_k = jnp.einsum("btd,dhk->bthk", enc_out, bp.cross_attn.wk)
    ekv_v = jnp.einsum("btd,dhk->bthk", enc_out, bp.cross_attn.wv)
    h = attn.full_attention(
        bp.cross_attn, L.rms_norm(x, bp.ln_x), positions,
        rope_theta=None, cross_kv=(ekv_k, ekv_v), causal=False,
    )
    x = x + h
    return x + L.swiglu(
        L.rms_norm(x, bp.ln2), bp.w_gate, bp.w_up, bp.w_down, act=jax.nn.gelu
    )


def forward(params: Params, batch: dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    enc_out = encode(params, batch["audio_embeds"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    d = cfg.d_model
    pos_tab = L.sinusoidal_positions(s, d).astype(cfg.dtype)
    x = params.embed[tokens] + pos_tab[None]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def block(x, bp):
        fn = _dec_block
        if cfg.remat:
            fn = jax.checkpoint(fn, static_argnums=(0,))
        return fn(cfg, bp, x, positions, enc_out), None

    x, _ = jax.lax.scan(block, x, params.dec_blocks, unroll=cfg.scan_unroll)
    return L.rms_norm(x, params.final_norm)


def loss(params: Params, batch: dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    h = forward(params, batch, cfg)
    b, s, d = h.shape
    return L.chunked_cross_entropy(
        h[:, :-1].reshape(-1, d),
        params.embed.T,
        batch["tokens"][:, 1:].reshape(-1),
        jnp.ones((b * (s - 1),), jnp.float32),
        n_chunks=cfg.loss_chunks,
    )


class DecodeCache(NamedTuple):
    kv: attn.KVCache            # decoder self-attn cache, stacked (layers,)
    cross_k: jax.Array          # (layers, b, t_a, kv, hd) — frozen
    cross_v: jax.Array


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               long_context: bool = False) -> DecodeCache:
    kv = attn.init_cache(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, cfg.dtype)

    def stack(leaf):
        return jnp.broadcast_to(leaf[None], (cfg.n_layers, *leaf.shape))

    t_a = cfg.n_audio_frames
    return DecodeCache(
        kv=jax.tree_util.tree_map(stack, kv),
        cross_k=jnp.zeros(
            (cfg.n_layers, batch, t_a, cfg.n_kv_heads, cfg.head_dim), cfg.dtype
        ),
        cross_v=jnp.zeros(
            (cfg.n_layers, batch, t_a, cfg.n_kv_heads, cfg.head_dim), cfg.dtype
        ),
    )


def precompute_cross_kv(
    params: Params, enc_out: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    """Cross-attention KV from encoder output, all layers at once."""
    ck = jnp.einsum("btd,ldhk->lbthk", enc_out, params.dec_blocks.cross_attn.wk)
    cv = jnp.einsum("btd,ldhk->lbthk", enc_out, params.dec_blocks.cross_attn.wv)
    return ck, cv


def decode_step(
    params: Params,
    cache: DecodeCache,
    tokens: jax.Array,
    cfg: ModelConfig,
    long_context: bool = False,
) -> tuple[DecodeCache, jax.Array]:
    del long_context
    b = tokens.shape[0]
    d = cfg.d_model
    # Absolute sinusoidal position for the current step.
    step = cache.kv.length[0, 0]
    angle_tab = L.sinusoidal_positions(1, d)  # row 0; shift by step phases
    # For decode we evaluate the sinusoid at `step` directly:
    div = jnp.exp(
        jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d)
    )
    pos_vec = jnp.zeros((d,), jnp.float32)
    pos_vec = pos_vec.at[0::2].set(jnp.sin(step.astype(jnp.float32) * div))
    pos_vec = pos_vec.at[1::2].set(jnp.cos(step.astype(jnp.float32) * div))
    del angle_tab
    x = params.embed[tokens] + pos_vec.astype(cfg.dtype)[None, None, :]

    def block(x, scanned):
        bp, kv, ck, cv = scanned
        new_kv, h = attn.decode_step(
            bp.self_attn, kv, L.rms_norm(x, bp.ln1), rope_theta=None
        )
        x = x + h
        h = attn.full_attention(
            bp.cross_attn, L.rms_norm(x, bp.ln_x),
            jnp.zeros((x.shape[0], 1), jnp.int32),
            rope_theta=None, cross_kv=(ck, cv), causal=False,
        )
        x = x + h
        x = x + L.swiglu(
            L.rms_norm(x, bp.ln2), bp.w_gate, bp.w_up, bp.w_down,
            act=jax.nn.gelu,
        )
        return x, new_kv

    x, new_kv = jax.lax.scan(
        block, x, (params.dec_blocks, cache.kv, cache.cross_k, cache.cross_v),
        unroll=cfg.scan_unroll,
    )
    h = L.rms_norm(x, params.final_norm)
    logits = jnp.einsum("bsd,dv->bsv", h, params.embed.T).astype(jnp.float32)
    return DecodeCache(new_kv, cache.cross_k, cache.cross_v), logits
