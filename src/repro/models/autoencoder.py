"""The paper's anomaly-detection autoencoder (Table II: 32-16-8-16-32).

A symmetric fully-connected AE with tanh activations, ~1 352 parameters at
D=32.  Written as explicit init/apply functions (no flax) so per-client
parameter stacks vmap cleanly in the federated round.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def init(key: jax.Array, feature_dim: int = 32,
         hidden: tuple[int, ...] = (16, 8, 16)) -> Params:
    """Glorot-initialised MLP autoencoder parameters."""
    dims = (feature_dim, *hidden, feature_dim)
    params = []
    keys = jax.random.split(key, len(dims) - 1)
    for k, (a, b) in zip(keys, zip(dims[:-1], dims[1:])):
        scale = jnp.sqrt(2.0 / (a + b))
        params.append(
            {"w": scale * jax.random.normal(k, (a, b)), "b": jnp.zeros((b,))}
        )
    return params


def apply(params: Params, x: jax.Array) -> jax.Array:
    """Forward pass; tanh on hidden layers, linear output."""
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jnp.tanh(h)
    return h


def loss(params: Params, batch: jax.Array) -> jax.Array:
    """Mean squared reconstruction error (paper Eq. 9/10)."""
    recon = apply(params, batch)
    return jnp.mean(jnp.sum(jnp.square(batch - recon), axis=-1))


def param_count(feature_dim: int = 32, hidden: tuple[int, ...] = (16, 8, 16)) -> int:
    dims = (feature_dim, *hidden, feature_dim)
    return sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
