"""Unified model API over the architecture zoo.

Every family exposes: init / axes / loss / decode_step / init_cache.  This
module adds the train/serve step builders the launchers and the federated
runtime consume, plus ShapeDtypeStruct input specs for the dry run (no
device allocation ever happens for the full-size configs).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, moe, rglru, ssm, transformer

Params = Any

_FAMILY = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": rglru,
    "encdec": encdec,
}


def module(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    return module(cfg).init(key, cfg)


def abstract_params(cfg: ModelConfig) -> Params:
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(
        functools.partial(module(cfg).init, cfg=cfg), jax.random.PRNGKey(0)
    )


def param_axes(cfg: ModelConfig) -> Params:
    return module(cfg).axes(cfg)


def loss_fn(cfg: ModelConfig) -> Callable[[Params, dict], jax.Array]:
    mod = module(cfg)
    return lambda params, batch: mod.loss(params, batch, cfg)


def make_train_step(cfg: ModelConfig):
    """Plain-SGD train step (the dry-run/production default; the federated
    runtime wraps its own local-epoch solvers around `loss_fn`)."""
    lfn = loss_fn(cfg)

    def train_step(params: Params, batch: dict) -> tuple[Params, jax.Array]:
        loss, grads = jax.value_and_grad(lfn)(params, batch)
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p - cfg.learning_rate * g.astype(jnp.float32)).astype(
                p.dtype
            )
            if p.dtype != jnp.int32
            else p,
            params,
            grads,
        )
        return new_params, loss

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """Forward-only full-sequence step (prefill_32k): returns last hidden."""
    mod = module(cfg)

    def prefill_step(params: Params, batch: dict) -> jax.Array:
        if cfg.family == "moe":
            h, _ = mod.forward(params, batch, cfg)
        else:
            h = mod.forward(params, batch, cfg)
        return h[:, -1, :]

    return prefill_step


def make_serve_step(cfg: ModelConfig, long_context: bool = False):
    mod = module(cfg)

    def serve_step(params: Params, cache, tokens: jax.Array):
        return mod.decode_step(params, cache, tokens, cfg,
                               long_context=long_context)

    return serve_step


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               long_context: bool = False):
    return module(cfg).init_cache(cfg, batch, max_seq, long_context)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int,
                   long_context: bool = False):
    return jax.eval_shape(
        functools.partial(
            module(cfg).init_cache, cfg, batch, max_seq, long_context
        )
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this shape.

    train/prefill: token batch (+ stubbed modality embeddings).
    decode: ONE new token per sequence (the KV cache is a separate
    argument; see launch/dryrun.py).
    """
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), tok)}
        if cfg.family == "encdec":
            specs["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_audio_frames, cfg.d_model), cfg.dtype
            )
        if cfg.n_visual_tokens > 0:
            specs["visual_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_visual_tokens, cfg.d_model), cfg.dtype
            )
        return specs
    return {"tokens": jax.ShapeDtypeStruct((b, 1), tok)}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is runnable; reason recorded in DESIGN.md."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "full-attention architecture without a sub-quadratic variant; "
            "long_500k decode skipped (DESIGN.md §5)"
        )
    return True, ""
