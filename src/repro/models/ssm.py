"""Mamba-2 (SSD — state-space duality) decoder, attention-free.

Training/prefill use the chunked SSD algorithm (Dao & Gu, 2024): quadratic
attention-like compute *within* chunks (MXU-friendly (Q x Q) blocks), a
linear recurrence *across* chunk states (lax.scan over n_chunks), never
materialising the (L x L) kernel.  Decode is the O(1) recurrent update on
the (H, N, P) state.

Layout notes for TPU: heads H shard over ``model``; the chunk dimension is
batch-like.  Chunk size Q=64 keeps the intra-chunk (Q x Q) matmuls and the
(Q, N) B/C blocks VMEM-resident under the default BlockSpec-free XLA path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


class BlockParams(NamedTuple):
    ln: jax.Array          # (d,)
    in_proj: jax.Array     # (d, 2*d_in + 2*N + H)
    conv_w: jax.Array      # (width, d_in + 2*N) depthwise
    conv_b: jax.Array      # (d_in + 2*N,)
    a_log: jax.Array       # (H,)
    d_skip: jax.Array      # (H,)
    dt_bias: jax.Array     # (H,)
    gate_norm: jax.Array   # (d_in,)
    out_proj: jax.Array    # (d_in, d)


class Params(NamedTuple):
    embed: jax.Array
    blocks: BlockParams
    final_norm: jax.Array


def dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim P, state N)."""
    d_in = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_head_dim
    return d_in, d_in // p, p, cfg.ssm_state


def _init_block(key: jax.Array, cfg: ModelConfig) -> BlockParams:
    d = cfg.d_model
    d_in, h, p, n = dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * n + h
    return BlockParams(
        ln=jnp.zeros((d,), cfg.dtype),
        in_proj=L.dense_init(k1, (d, proj_out), cfg.dtype),
        conv_w=L.dense_init(k2, (cfg.conv_width, d_in + 2 * n), cfg.dtype,
                            scale=cfg.conv_width**-0.5),
        conv_b=jnp.zeros((d_in + 2 * n,), cfg.dtype),
        a_log=jnp.log(
            jax.random.uniform(k3, (h,), jnp.float32, 1.0, 16.0)
        ),
        d_skip=jnp.ones((h,), jnp.float32),
        dt_bias=jnp.log(
            jnp.exp(jax.random.uniform(k4, (h,), jnp.float32, 1e-3, 0.1)) - 1.0
        ),
        gate_norm=jnp.zeros((d_in,), cfg.dtype),
        out_proj=L.dense_init(jax.random.fold_in(k1, 7), (d_in, d), cfg.dtype),
    )


def init(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, kb = jax.random.split(key)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(
        jax.random.split(kb, cfg.n_layers)
    )
    return Params(
        embed=L.embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        blocks=blocks,
        final_norm=jnp.zeros((cfg.d_model,), cfg.dtype),
    )


def axes(cfg: ModelConfig) -> Params:
    return Params(
        embed=("vocab", "embed"),
        blocks=BlockParams(
            ln=("layers", "embed"),
            in_proj=("layers", "embed", "inner_proj"),
            conv_w=("layers", None, "inner_conv"),
            conv_b=("layers", "inner_conv"),
            a_log=("layers", "ssm_heads"),
            d_skip=("layers", "ssm_heads"),
            dt_bias=("layers", "ssm_heads"),
            gate_norm=("layers", "inner"),
            out_proj=("layers", "inner", "embed"),
        ),
        final_norm=("embed",),
    )


def _split_proj(z_xbc_dt: jax.Array, cfg: ModelConfig):
    d_in, h, p, n = dims(cfg)
    z = z_xbc_dt[..., :d_in]
    xbc = z_xbc_dt[..., d_in : 2 * d_in + 2 * n]
    dt = z_xbc_dt[..., 2 * d_in + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (b, l, ch) with (width, ch) weights."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(width):
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def ssd_chunked(
    x: jax.Array,      # (b, l, h, p)
    dt: jax.Array,     # (b, l, h) — post-softplus
    a: jax.Array,      # (h,) negative
    bmat: jax.Array,   # (b, l, n)
    cmat: jax.Array,   # (b, l, n)
    chunk: int,
    h0: jax.Array | None = None,   # (b, h, n, p) initial state
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (b,l,h,p), final_state (b,h,n,p))."""
    b, sl, h, p = x.shape
    n = bmat.shape[-1]
    nc = sl // chunk
    q = chunk

    xr = x.reshape(b, nc, q, h, p)
    dtr = dt.reshape(b, nc, q, h)
    br = bmat.reshape(b, nc, q, n)
    cr = cmat.reshape(b, nc, q, n)

    da = dtr * a  # (b, nc, q, h) log-decay per step
    cum = jnp.cumsum(da, axis=2)                    # (b, nc, q, h)

    # Intra-chunk (quadratic within chunk).
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,q_i,q_j,h)
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(li), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", cr, br)       # (b,nc,q,q)
    m = scores[..., None] * decay                        # (b,nc,q,q,h)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", m, dtr, xr)

    # Chunk summary states.
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (b,nc,q,h)
    s_chunk = jnp.einsum(
        "bcqh,bcqn,bcqhp->bchnp", decay_to_end * dtr, br, xr
    )

    # Inter-chunk linear recurrence over chunk states.
    g = jnp.exp(cum[:, :, -1, :])                        # (b, nc, h)
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), x.dtype)

    def step(hprev, inp):
        gc, sc = inp
        hnew = gc[:, :, None, None] * hprev + sc
        return hnew, hprev  # emit state at chunk START

    hfin, hstart = jax.lax.scan(
        step,
        h0,
        (jnp.moveaxis(g, 1, 0), jnp.moveaxis(s_chunk, 1, 0)),
    )
    hstart = jnp.moveaxis(hstart, 0, 1)                  # (b, nc, h, n, p)

    y_inter = jnp.einsum(
        "bcin,bcih,bchnp->bcihp", cr, jnp.exp(cum), hstart
    )
    y = (y_intra + y_inter).reshape(b, sl, h, p)
    return y, hfin


def _block_apply(
    cfg: ModelConfig, bp: BlockParams, x: jax.Array
) -> jax.Array:
    d_in, h, p, n = dims(cfg)
    res = x
    u = L.rms_norm(x, bp.ln)
    z, xbc, dt = _split_proj(jnp.einsum("bld,dk->blk", u, bp.in_proj), cfg)
    xbc = _causal_conv(xbc, bp.conv_w, bp.conv_b)
    xs = xbc[..., :d_in].reshape(*x.shape[:2], h, p)
    bmat = xbc[..., d_in : d_in + n].astype(jnp.float32)
    cmat = xbc[..., d_in + n :].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + bp.dt_bias)
    a = -jnp.exp(bp.a_log)

    y, _ = ssd_chunked(
        xs.astype(jnp.float32), dt, a, bmat, cmat, cfg.ssm_chunk
    )
    y = y + bp.d_skip[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], d_in).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), bp.gate_norm)
    return res + jnp.einsum("blk,kd->bld", y, bp.out_proj)


def forward(params: Params, batch: dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    x = params.embed[batch["tokens"]]

    def block(x, bp):
        fn = _block_apply
        if cfg.remat:
            fn = jax.checkpoint(fn, static_argnums=(0,))
        return fn(cfg, bp, x), None

    x, _ = jax.lax.scan(block, x, params.blocks, unroll=cfg.scan_unroll)
    return L.rms_norm(x, params.final_norm)


def loss(params: Params, batch: dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    h = forward(params, batch, cfg)
    b, s, d = h.shape
    return L.chunked_cross_entropy(
        h[:, :-1].reshape(-1, d),
        params.embed.T,
        batch["tokens"][:, 1:].reshape(-1),
        jnp.ones((b * (s - 1),), jnp.float32),
        n_chunks=cfg.loss_chunks,
    )


class DecodeCache(NamedTuple):
    ssm_state: jax.Array    # (layers, b, h, n, p)
    conv_state: jax.Array   # (layers, b, width-1, d_in + 2n)
    length: jax.Array       # (b,)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               long_context: bool = False) -> DecodeCache:
    del max_seq, long_context  # O(1) state regardless of context length
    d_in, h, p, n = dims(cfg)
    return DecodeCache(
        ssm_state=jnp.zeros((cfg.n_layers, batch, h, n, p), jnp.float32),
        conv_state=jnp.zeros(
            (cfg.n_layers, batch, cfg.conv_width - 1, d_in + 2 * n), cfg.dtype
        ),
        length=jnp.zeros((batch,), jnp.int32),
    )


def cache_axes(cfg: ModelConfig) -> DecodeCache:
    return DecodeCache(
        ssm_state=("layers", "batch", "ssm_heads", None, None),
        conv_state=("layers", "batch", None, "inner_conv"),
        length=("batch",),
    )


def decode_step(
    params: Params,
    cache: DecodeCache,
    tokens: jax.Array,       # (b, 1)
    cfg: ModelConfig,
    long_context: bool = False,
) -> tuple[DecodeCache, jax.Array]:
    del long_context
    d_in, h, p, n = dims(cfg)
    x = params.embed[tokens][:, 0]                  # (b, d)

    def block(x, scanned):
        bp, hstate, cstate = scanned
        res = x
        u = L.rms_norm(x, bp.ln)
        z, xbc, dt = _split_proj(jnp.einsum("bd,dk->bk", u, bp.in_proj), cfg)
        # Depthwise causal conv from the rolling buffer.
        hist = jnp.concatenate([cstate, xbc[:, None, :]], axis=1)  # (b,w,ch)
        conv = jax.nn.silu(
            jnp.einsum("bwc,wc->bc", hist, bp.conv_w) + bp.conv_b
        )
        new_cstate = hist[:, 1:, :]
        xs = conv[:, :d_in].reshape(-1, h, p).astype(jnp.float32)
        bmat = conv[:, d_in : d_in + n].astype(jnp.float32)
        cmat = conv[:, d_in + n :].astype(jnp.float32)
        dt1 = jax.nn.softplus(dt.astype(jnp.float32) + bp.dt_bias)  # (b,h)
        a = -jnp.exp(bp.a_log)
        decay = jnp.exp(dt1 * a)                                     # (b,h)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt1, bmat, xs)
        hnew = decay[:, :, None, None] * hstate + upd
        y = jnp.einsum("bn,bhnp->bhp", cmat, hnew)
        y = y + bp.d_skip[None, :, None] * xs
        y = y.reshape(-1, d_in).astype(x.dtype)
        y = L.rms_norm(y * jax.nn.silu(z), bp.gate_norm)
        out = res + jnp.einsum("bk,kd->bd", y, bp.out_proj)
        return out, (hnew, new_cstate)

    x, (new_h, new_c) = jax.lax.scan(
        block, x, (params.blocks, cache.ssm_state, cache.conv_state),
        unroll=cfg.scan_unroll,
    )
    hfinal = L.rms_norm(x, params.final_norm)
    logits = jnp.einsum("bd,dv->bv", hfinal, params.embed.T)
    return (
        DecodeCache(new_h, new_c, cache.length + 1),
        logits[:, None, :].astype(jnp.float32),
    )
