from repro.models import autoencoder  # noqa: F401
