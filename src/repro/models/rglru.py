"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local MQA
attention in a (rec, rec, attn) pattern (arXiv:2402.19427).

Temporal-mixing blocks alternate per ``cfg.block_pattern``; every block is
followed by a gated-MLP.  The RG-LRU gated linear recurrence

    r_t = sigmoid(W_r x + b_r);  i_t = sigmoid(W_i x + b_i)
    log a_t = -c * softplus(lambda) * r_t
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

runs as a lax.associative_scan for train/prefill (parallel over L, the
TPU-friendly formulation of the recurrence) and as a single fused update
for decode.  Layers are a Python loop (heterogeneous structure), which is
fine at 26 layers.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L


class RecParams(NamedTuple):
    ln: jax.Array
    w_x: jax.Array        # (d, r) linear branch into the recurrence
    w_gate: jax.Array     # (d, r) gelu gate branch
    conv_w: jax.Array     # (width, r) depthwise temporal conv
    conv_b: jax.Array
    w_rg: jax.Array       # (r, r) recurrence gate
    b_rg: jax.Array
    w_ig: jax.Array       # (r, r) input gate
    b_ig: jax.Array
    lam: jax.Array        # (r,) learnable decay parameter
    w_out: jax.Array      # (r, d)


class AttnBlock(NamedTuple):
    ln: jax.Array
    attn: attn.AttnParams


class MLPParams(NamedTuple):
    ln: jax.Array
    w_gate: jax.Array
    w_up: jax.Array
    w_down: jax.Array


class Params(NamedTuple):
    embed: jax.Array
    temporal: tuple[Any, ...]     # RecParams | AttnBlock per layer
    mlps: tuple[MLPParams, ...]
    final_norm: jax.Array


def pattern(cfg: ModelConfig) -> tuple[str, ...]:
    base = cfg.block_pattern or ("rec", "rec", "attn")
    return tuple(base[i % len(base)] for i in range(cfg.n_layers))


def _init_rec(key: jax.Array, cfg: ModelConfig) -> RecParams:
    d = cfg.d_model
    r = d  # lru width = d_model for recurrentgemma-2b
    ks = jax.random.split(key, 6)
    return RecParams(
        ln=jnp.zeros((d,), cfg.dtype),
        w_x=L.dense_init(ks[0], (d, r), cfg.dtype),
        w_gate=L.dense_init(ks[1], (d, r), cfg.dtype),
        conv_w=L.dense_init(ks[2], (cfg.conv_width, r), cfg.dtype,
                            scale=cfg.conv_width**-0.5),
        conv_b=jnp.zeros((r,), cfg.dtype),
        w_rg=L.dense_init(ks[3], (r, r), cfg.dtype),
        b_rg=jnp.zeros((r,), jnp.float32),
        w_ig=L.dense_init(ks[4], (r, r), cfg.dtype),
        b_ig=jnp.zeros((r,), jnp.float32),
        # softplus(lam) ~ U[...] so a^c starts in a stable range
        lam=jax.random.uniform(ks[5], (r,), jnp.float32, 0.3, 0.8),
        w_out=L.dense_init(jax.random.fold_in(key, 9), (r, d), cfg.dtype),
    )


def _init_attn(key: jax.Array, cfg: ModelConfig) -> AttnBlock:
    return AttnBlock(
        ln=jnp.zeros((cfg.d_model,), cfg.dtype),
        attn=attn.init(
            key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            False, cfg.dtype,
        ),
    )


def _init_mlp(key: jax.Array, cfg: ModelConfig) -> MLPParams:
    k1, k2, k3 = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    return MLPParams(
        ln=jnp.zeros((d,), cfg.dtype),
        w_gate=L.dense_init(k1, (d, ff), cfg.dtype),
        w_up=L.dense_init(k2, (d, ff), cfg.dtype),
        w_down=L.dense_init(k3, (ff, d), cfg.dtype),
    )


def init(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, kt, km = jax.random.split(key, 3)
    pat = pattern(cfg)
    tkeys = jax.random.split(kt, cfg.n_layers)
    mkeys = jax.random.split(km, cfg.n_layers)
    temporal = tuple(
        _init_rec(k, cfg) if p == "rec" else _init_attn(k, cfg)
        for k, p in zip(tkeys, pat)
    )
    mlps = tuple(_init_mlp(k, cfg) for k in mkeys)
    return Params(
        embed=L.embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        temporal=temporal,
        mlps=mlps,
        final_norm=jnp.zeros((cfg.d_model,), cfg.dtype),
    )


def axes(cfg: ModelConfig) -> Params:
    pat = pattern(cfg)
    rec_ax = RecParams(
        ln=("embed",), w_x=("embed", "inner"), w_gate=("embed", "inner"),
        conv_w=(None, "inner"), conv_b=("inner",),
        w_rg=("inner", "inner2"), b_rg=("inner",),
        w_ig=("inner", "inner2"), b_ig=("inner",),
        lam=("inner",), w_out=("inner", "embed"),
    )
    attn_ax = AttnBlock(
        ln=("embed",),
        attn=attn.AttnParams(
            wq=("embed", "heads", "head_dim"),
            wk=("embed", "kv_heads", "head_dim"),
            wv=("embed", "kv_heads", "head_dim"),
            wo=("heads", "head_dim", "embed"),
            q_norm=None, k_norm=None,
        ),
    )
    mlp_ax = MLPParams(
        ln=("embed",), w_gate=("embed", "ff"), w_up=("embed", "ff"),
        w_down=("ff", "embed"),
    )
    return Params(
        embed=("vocab", "embed"),
        temporal=tuple(rec_ax if p == "rec" else attn_ax for p in pat),
        mlps=tuple(mlp_ax for _ in pat),
        final_norm=("embed",),
    )


def _rglru_scan(
    a: jax.Array, bx: jax.Array, h0: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t h_{t-1} + bx_t over axis 1. a, bx: (b, l, r).

    Associative composition of (a, b) pairs; returns (all h, final h).
    """
    if h0 is not None:
        # Fold the initial state into the first element.
        bx = bx.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h, h[:, -1, :]


def _rec_apply(
    p: RecParams, x: jax.Array, cfg: ModelConfig,
    conv_state: jax.Array | None = None,
    h0: jax.Array | None = None,
):
    """Full-sequence RG-LRU block. x: (b, l, d)."""
    u = L.rms_norm(x, p.ln)
    xb = jnp.einsum("bld,dr->blr", u, p.w_x)
    gate = jax.nn.gelu(jnp.einsum("bld,dr->blr", u, p.w_gate))

    # Temporal conv (causal, depthwise).
    width = p.conv_w.shape[0]
    pad = jnp.pad(xb, ((0, 0), (width - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i : i + xb.shape[1], :] * p.conv_w[i] for i in range(width)
    ) + p.conv_b
    xb = conv

    # The square gate maps contract over the model-sharded `inner` dim;
    # anchoring their outputs back to inner-sharded turns the partial-sum
    # all-reduce of a REPLICATED f32 (b, l, r) tensor into a
    # reduce-scatter onto the shard (16x less traffic) and keeps every
    # downstream elementwise op and the associative scan fully sharded
    # (EXPERIMENTS.md §Perf, recurrentgemma iteration).
    r = jax.nn.sigmoid(
        L.shard_hint(
            jnp.einsum("blr,rk->blk", xb, p.w_rg).astype(jnp.float32),
            ("batch", None, "inner"),
        ) + p.b_rg
    )
    i = jax.nn.sigmoid(
        L.shard_hint(
            jnp.einsum("blr,rk->blk", xb, p.w_ig).astype(jnp.float32),
            ("batch", None, "inner"),
        ) + p.b_ig
    )
    log_a = -cfg.rglru_c * jax.nn.softplus(p.lam) * r
    a = jnp.exp(log_a)
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-6))
    bx = scale * (i * xb.astype(jnp.float32))
    h, hlast = _rglru_scan(a, bx, h0)
    y = (h.astype(x.dtype) * gate)
    return x + jnp.einsum("blr,rd->bld", y, p.w_out), hlast


def _mlp_apply(p: MLPParams, x: jax.Array) -> jax.Array:
    return x + L.swiglu(L.rms_norm(x, p.ln), p.w_gate, p.w_up, p.w_down,
                        act=jax.nn.gelu)


def forward(params: Params, batch: dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    x = params.embed[batch["tokens"]]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    b, s = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    for tp, mp in zip(params.temporal, params.mlps):
        if isinstance(tp, RecParams):
            def fn(tpp, xx):
                return _rec_apply(tpp, xx, cfg)[0]
        else:
            def fn(tpp, xx):
                return xx + attn.full_attention(
                    tpp.attn, L.rms_norm(xx, tpp.ln), positions,
                    window=cfg.sliding_window, rope_theta=cfg.rope_theta,
                )
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x = fn(tp, x)
        x = jax.checkpoint(_mlp_apply)(mp, x) if cfg.remat else _mlp_apply(mp, x)
    return L.rms_norm(x, params.final_norm)


def loss(params: Params, batch: dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    h = forward(params, batch, cfg)
    b, s, d = h.shape
    return L.chunked_cross_entropy(
        h[:, :-1].reshape(-1, d),
        params.embed.T,
        batch["tokens"][:, 1:].reshape(-1),
        jnp.ones((b * (s - 1),), jnp.float32),
        n_chunks=cfg.loss_chunks,
        softcap_value=cfg.logit_softcap,
    )


class DecodeCache(NamedTuple):
    kv: tuple[Any, ...]           # per-attn-layer KVCache (window-sized)
    rec_h: tuple[jax.Array, ...]  # per-rec-layer (b, r) hidden states
    rec_conv: tuple[jax.Array, ...]  # per-rec-layer (b, width-1, r)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               long_context: bool = False) -> DecodeCache:
    pat = pattern(cfg)
    window = cfg.sliding_window or 2048
    cache_seq = min(max_seq, window) if long_context else max_seq
    kv, rec_h, rec_conv = [], [], []
    r = cfg.d_model
    for p in pat:
        if p == "attn":
            kv.append(attn.init_cache(
                batch, cache_seq, cfg.n_kv_heads, cfg.head_dim, cfg.dtype
            ))
        else:
            rec_h.append(jnp.zeros((batch, r), jnp.float32))
            rec_conv.append(jnp.zeros((batch, cfg.conv_width - 1, r), cfg.dtype))
    return DecodeCache(kv=tuple(kv), rec_h=tuple(rec_h), rec_conv=tuple(rec_conv))


def decode_step(
    params: Params,
    cache: DecodeCache,
    tokens: jax.Array,
    cfg: ModelConfig,
    long_context: bool = False,
) -> tuple[DecodeCache, jax.Array]:
    del long_context  # window-sized cache handles any context length
    x = params.embed[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    new_kv, new_h, new_conv = [], [], []
    i_kv = i_rec = 0
    for tp, mp in zip(params.temporal, params.mlps):
        if isinstance(tp, RecParams):
            res = x
            u = L.rms_norm(x, tp.ln)[:, 0]
            xb = jnp.einsum("bd,dr->br", u, tp.w_x)
            gate = jax.nn.gelu(jnp.einsum("bd,dr->br", u, tp.w_gate))
            hist = jnp.concatenate(
                [cache.rec_conv[i_rec], xb[:, None, :]], axis=1
            )
            xb = jnp.einsum("bwr,wr->br", hist, tp.conv_w) + tp.conv_b
            new_conv.append(hist[:, 1:, :])
            r_g = jax.nn.sigmoid(
                jnp.einsum("br,rk->bk", xb, tp.w_rg).astype(jnp.float32) + tp.b_rg
            )
            i_g = jax.nn.sigmoid(
                jnp.einsum("br,rk->bk", xb, tp.w_ig).astype(jnp.float32) + tp.b_ig
            )
            a = jnp.exp(-cfg.rglru_c * jax.nn.softplus(tp.lam) * r_g)
            scale = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-6))
            h = a * cache.rec_h[i_rec] + scale * (i_g * xb.astype(jnp.float32))
            new_h.append(h)
            y = h.astype(x.dtype) * gate
            x = res + jnp.einsum("br,rd->bd", y, tp.w_out)[:, None, :]
            i_rec += 1
        else:
            kv, h = attn.decode_step(
                tp.attn, cache.kv[i_kv], L.rms_norm(x, tp.ln),
                window=cfg.sliding_window, rope_theta=cfg.rope_theta,
            )
            new_kv.append(kv)
            x = x + h
            i_kv += 1
        x = _mlp_apply(mp, x)
    h = L.rms_norm(x, params.final_norm)
    logits = jnp.einsum("bsd,dv->bsv", h, params.embed.T).astype(jnp.float32)
    if cfg.logit_softcap is not None:
        logits = L.softcap(logits, cfg.logit_softcap)
    return (
        DecodeCache(kv=tuple(new_kv), rec_h=tuple(new_h), rec_conv=tuple(new_conv)),
        logits,
    )
