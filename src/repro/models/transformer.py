"""Dense decoder-only transformer (llama3 / qwen3 / gemma2 / internvl LM).

Scan-over-layers with stacked per-layer parameters (the MaxText pattern):
compile time is O(1) in depth, and per-layer remat gives the standard
activation-checkpoint memory profile.  Handles:

  - GQA with optional qk-norm (qwen3) and RoPE,
  - gemma2 extras: attn/logit soft-caps, sandwich post-norms, sqrt(d)
    embedding scaling, query_pre_attn scaling, alternating local/global
    attention (per-layer window array scanned with the params),
  - VLM (internvl2): visual patch embeddings scattered into the first
    ``n_visual_tokens`` positions, loss masked to text positions,
  - chunked cross-entropy so 256k-vocab logits never fully materialise.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L


class BlockParams(NamedTuple):
    ln1: jax.Array
    attn: attn.AttnParams
    post_attn: jax.Array | None
    ln2: jax.Array
    w_gate: jax.Array
    w_up: jax.Array
    w_down: jax.Array
    post_mlp: jax.Array | None


class Params(NamedTuple):
    embed: jax.Array
    blocks: BlockParams              # leaves stacked (n_layers, ...)
    final_norm: jax.Array
    unembed: jax.Array | None        # None when tied


def layer_windows(cfg: ModelConfig, long_context: bool = False) -> jax.Array:
    """Per-layer attention window; "global" layers get a huge window."""
    big = jnp.int32(2**30)
    if cfg.sliding_window is None:
        return jnp.full((cfg.n_layers,), big, jnp.int32)
    idx = jnp.arange(cfg.n_layers)
    if cfg.local_global_period > 0:
        is_global = (idx % cfg.local_global_period) == (
            cfg.local_global_period - 1
        )
    else:
        is_global = jnp.zeros((cfg.n_layers,), bool)
    if long_context:
        # Long-context serving mode: every layer windowed (sub-quadratic).
        is_global = jnp.zeros((cfg.n_layers,), bool)
        return jnp.full((cfg.n_layers,), cfg.long_context_window, jnp.int32)
    return jnp.where(is_global, big, jnp.int32(cfg.sliding_window))


def _init_block(key: jax.Array, cfg: ModelConfig) -> BlockParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, ff = cfg.d_model, cfg.d_ff
    return BlockParams(
        ln1=jnp.zeros((d,), cfg.dtype),
        attn=attn.init(
            k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.qk_norm,
            cfg.dtype,
        ),
        post_attn=jnp.zeros((d,), cfg.dtype) if cfg.post_norms else None,
        ln2=jnp.zeros((d,), cfg.dtype),
        w_gate=L.dense_init(k2, (d, ff), cfg.dtype),
        w_up=L.dense_init(k3, (d, ff), cfg.dtype),
        w_down=L.dense_init(k4, (ff, d), cfg.dtype),
        post_mlp=jnp.zeros((d,), cfg.dtype) if cfg.post_norms else None,
    )


def init(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, kb, ku = jax.random.split(key, 3)
    block_keys = jax.random.split(kb, cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(block_keys)
    return Params(
        embed=L.embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        blocks=blocks,
        final_norm=jnp.zeros((cfg.d_model,), cfg.dtype),
        unembed=None
        if cfg.tie_embeddings
        else L.dense_init(ku, (cfg.d_model, cfg.vocab_size), cfg.dtype),
    )


def axes(cfg: ModelConfig) -> Params:
    """Logical sharding axes, same structure as Params."""
    nrm = ("embed",)
    return Params(
        embed=("vocab", "embed"),
        blocks=BlockParams(
            ln1=("layers", "embed"),
            attn=attn.AttnParams(
                wq=("layers", "embed", "heads", "head_dim"),
                wk=("layers", "embed", "kv_heads", "head_dim"),
                wv=("layers", "embed", "kv_heads", "head_dim"),
                wo=("layers", "heads", "head_dim", "embed"),
                q_norm=("layers", "head_dim") if cfg.qk_norm else None,
                k_norm=("layers", "head_dim") if cfg.qk_norm else None,
            ),
            post_attn=("layers", "embed") if cfg.post_norms else None,
            ln2=("layers", "embed"),
            w_gate=("layers", "embed", "ff"),
            w_up=("layers", "embed", "ff"),
            w_down=("layers", "ff", "embed"),
            post_mlp=("layers", "embed") if cfg.post_norms else None,
        ),
        final_norm=nrm,
        unembed=None if cfg.tie_embeddings else ("embed", "vocab"),
    )


def _block_apply(
    cfg: ModelConfig,
    bp: BlockParams,
    window: jax.Array,
    x: jax.Array,
    positions: jax.Array,
) -> jax.Array:
    h = attn.full_attention(
        bp.attn,
        L.rms_norm(x, bp.ln1),
        positions,
        window=window,
        attn_softcap=cfg.attn_softcap,
        rope_theta=cfg.rope_theta,
    )
    if bp.post_attn is not None:
        h = L.rms_norm(h, bp.post_attn)
    x = x + h
    h = L.swiglu(
        L.rms_norm(x, bp.ln2), bp.w_gate, bp.w_up, bp.w_down,
        act=jax.nn.gelu if cfg.post_norms else jax.nn.silu,
    )
    if bp.post_mlp is not None:
        h = L.rms_norm(h, bp.post_mlp)
    return x + h


def _embed_inputs(
    cfg: ModelConfig, params: Params, batch: dict[str, jax.Array]
) -> jax.Array:
    x = params.embed[batch["tokens"]]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if cfg.n_visual_tokens > 0 and "visual_embeds" in batch:
        nv = batch["visual_embeds"].shape[1]
        x = jax.lax.dynamic_update_slice(
            x, batch["visual_embeds"].astype(x.dtype), (0, 0, 0)
        )
        del nv
    return x


def forward(
    params: Params, batch: dict[str, jax.Array], cfg: ModelConfig
) -> jax.Array:
    """Hidden states after the final norm: (b, s, d)."""
    x = _embed_inputs(cfg, params, batch)
    b, s = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    windows = layer_windows(cfg)

    def block(x, scanned):
        bp, window = scanned
        # Pin the residual stream to batch sharding at every layer
        # boundary so the scanned body never round-trips it through a
        # replicated layout (EXPERIMENTS.md §Perf iter 2).
        x = L.shard_hint(x, ("batch", None, None))
        fn = _block_apply
        if cfg.remat:
            fn = jax.checkpoint(fn, static_argnums=(0,))
        return fn(cfg, bp, window, x, positions), None

    x, _ = jax.lax.scan(block, x, (params.blocks, windows),
                        unroll=cfg.scan_unroll)
    return L.rms_norm(x, params.final_norm)


def loss(
    params: Params, batch: dict[str, jax.Array], cfg: ModelConfig
) -> jax.Array:
    """Next-token cross-entropy (text positions only for VLM)."""
    h = forward(params, batch, cfg)
    b, s, d = h.shape
    unembed = (
        params.unembed if params.unembed is not None else params.embed.T
    )
    targets = batch["tokens"][:, 1:]
    hidden = h[:, :-1].reshape(-1, d)
    mask = jnp.ones((b, s - 1), jnp.float32)
    if cfg.n_visual_tokens > 0:
        pos = jnp.arange(s - 1)[None, :]
        mask = (pos >= cfg.n_visual_tokens).astype(jnp.float32) * mask
    return L.chunked_cross_entropy(
        hidden,
        unembed,
        targets.reshape(-1),
        mask.reshape(-1),
        n_chunks=cfg.loss_chunks,
        softcap_value=cfg.logit_softcap,
    )


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    kv: attn.KVCache        # leaves stacked (n_layers, ...)


def init_cache(
    cfg: ModelConfig, batch: int, max_seq: int, long_context: bool = False
) -> DecodeCache:
    if long_context:
        # Sub-quadratic serving: only the window is cached (ring buffer
        # semantics are approximated with a window-sized linear cache for
        # the dry run; positions wrap via modulo in a real server).
        max_seq = min(max_seq, cfg.long_context_window)
    kv = attn.init_cache(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, cfg.dtype)

    def stack(leaf):
        return jnp.broadcast_to(leaf[None], (cfg.n_layers, *leaf.shape))

    return DecodeCache(kv=jax.tree_util.tree_map(stack, kv))


def cache_axes(cfg: ModelConfig) -> DecodeCache:
    return DecodeCache(
        kv=attn.KVCache(
            k=("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
            v=("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
            length=("layers", "batch"),
        )
    )


def decode_step(
    params: Params,
    cache: DecodeCache,
    tokens: jax.Array,           # (b, 1) int32
    cfg: ModelConfig,
    long_context: bool = False,
) -> tuple[DecodeCache, jax.Array]:
    """Serve one token for the whole batch; returns (cache, logits)."""
    x = params.embed[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    windows = layer_windows(cfg, long_context=long_context)

    def block(x, scanned):
        bp, window, kv = scanned
        new_kv, h = attn.decode_step(
            bp.attn,
            kv,
            L.rms_norm(x, bp.ln1),
            window=window,
            attn_softcap=cfg.attn_softcap,
            rope_theta=cfg.rope_theta,
        )
        if bp.post_attn is not None:
            h = L.rms_norm(h, bp.post_attn)
        x = x + h
        h = L.swiglu(
            L.rms_norm(x, bp.ln2), bp.w_gate, bp.w_up, bp.w_down,
            act=jax.nn.gelu if cfg.post_norms else jax.nn.silu,
        )
        if bp.post_mlp is not None:
            h = L.rms_norm(h, bp.post_mlp)
        return x + h, new_kv

    x, new_kv = jax.lax.scan(
        block, x, (params.blocks, windows, cache.kv), unroll=cfg.scan_unroll
    )
    h = L.rms_norm(x, params.final_norm)
    unembed = params.unembed if params.unembed is not None else params.embed.T
    logits = jnp.einsum("bsd,dv->bsv", h, unembed).astype(jnp.float32)
    if cfg.logit_softcap is not None:
        logits = L.softcap(logits, cfg.logit_softcap)
    return DecodeCache(kv=new_kv), logits
