"""Mixture-of-Experts decoder (qwen2-moe / grok-1).

Routing is GShard/Switch-style capacity-based dispatch expressed as
einsums, which shards cleanly under pjit: tokens are processed in groups,
each group dispatches at most ``capacity`` tokens per expert, and the
(group, tokens, experts, capacity) one-hot tensors stay bounded because
capacity scales with the *group* size, not the global token count.  Expert
FFN weights are stacked (E, ...) and shard over the ``model`` axis on the
ff dim (tensor-parallel experts — valid for any expert count; see
EXPERIMENTS.md §Perf for the expert-parallel variant).

Shared experts (qwen2-moe: 4 always-on) are a single fused swiglu with
n_shared * moe_hidden width.  The router aux (load-balance) loss follows
Switch: E * sum_e f_e * P_e.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L

MOE_GROUP = 2048  # dispatch group size (tokens)


class MoEMLP(NamedTuple):
    w_router: jax.Array           # (d, E) f32
    w_gate: jax.Array             # (E, d, ff_e)
    w_up: jax.Array               # (E, d, ff_e)
    w_down: jax.Array             # (E, ff_e, d)
    shared_gate: jax.Array | None  # (d, ff_s)
    shared_up: jax.Array | None
    shared_down: jax.Array | None


class BlockParams(NamedTuple):
    ln1: jax.Array
    attn: attn.AttnParams
    ln2: jax.Array
    mlp: MoEMLP


class Params(NamedTuple):
    embed: jax.Array
    blocks: BlockParams
    final_norm: jax.Array
    unembed: jax.Array


def _init_mlp(key: jax.Array, cfg: ModelConfig) -> MoEMLP:
    kr, kg, ku, kd, ksg, ksu, ksd = jax.random.split(key, 7)
    d, ffe, e = cfg.d_model, cfg.moe_hidden, cfg.n_experts
    shared = cfg.n_shared_experts > 0
    ffs = cfg.moe_hidden * cfg.n_shared_experts
    def init3(k, shape):
        return (
            (shape[1] ** -0.5)
            * jax.random.normal(k, shape, jnp.float32)
        ).astype(cfg.dtype)
    return MoEMLP(
        w_router=(d**-0.5) * jax.random.normal(kr, (d, e), jnp.float32),
        w_gate=init3(kg, (e, d, ffe)),
        w_up=init3(ku, (e, d, ffe)),
        w_down=(
            (ffe**-0.5) * jax.random.normal(kd, (e, ffe, d), jnp.float32)
        ).astype(cfg.dtype),
        shared_gate=L.dense_init(ksg, (d, ffs), cfg.dtype) if shared else None,
        shared_up=L.dense_init(ksu, (d, ffs), cfg.dtype) if shared else None,
        shared_down=L.dense_init(ksd, (ffs, d), cfg.dtype) if shared else None,
    )


def _init_block(key: jax.Array, cfg: ModelConfig) -> BlockParams:
    k1, k2 = jax.random.split(key)
    return BlockParams(
        ln1=jnp.zeros((cfg.d_model,), cfg.dtype),
        attn=attn.init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            cfg.qk_norm, cfg.dtype,
        ),
        ln2=jnp.zeros((cfg.d_model,), cfg.dtype),
        mlp=_init_mlp(k2, cfg),
    )


def init(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, kb, ku = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(
        jax.random.split(kb, cfg.n_layers)
    )
    return Params(
        embed=L.embed_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        blocks=blocks,
        final_norm=jnp.zeros((cfg.d_model,), cfg.dtype),
        unembed=L.dense_init(ku, (cfg.d_model, cfg.vocab_size), cfg.dtype),
    )


def axes(cfg: ModelConfig) -> Params:
    shared = cfg.n_shared_experts > 0
    return Params(
        embed=("vocab", "embed"),
        blocks=BlockParams(
            ln1=("layers", "embed"),
            attn=attn.AttnParams(
                wq=("layers", "embed", "heads", "head_dim"),
                wk=("layers", "embed", "kv_heads", "head_dim"),
                wv=("layers", "embed", "kv_heads", "head_dim"),
                wo=("layers", "heads", "head_dim", "embed"),
                q_norm=("layers", "head_dim") if cfg.qk_norm else None,
                k_norm=("layers", "head_dim") if cfg.qk_norm else None,
            ),
            ln2=("layers", "embed"),
            mlp=MoEMLP(
                w_router=("layers", "embed", "experts"),
                w_gate=("layers", "experts", "embed", "ff"),
                w_up=("layers", "experts", "embed", "ff"),
                w_down=("layers", "experts", "ff", "embed"),
                shared_gate=("layers", "embed", "ff") if shared else None,
                shared_up=("layers", "embed", "ff") if shared else None,
                shared_down=("layers", "ff", "embed") if shared else None,
            ),
        ),
        final_norm=("embed",),
        unembed=("embed", "vocab"),
    )


def moe_apply(
    mlp: MoEMLP, x: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    """Capacity-dispatch MoE over (..., d) tokens; returns (out, aux_loss)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    flat = x.reshape(-1, d)
    t = flat.shape[0]
    g_size = min(MOE_GROUP, t)
    n_groups = t // g_size
    xg = flat.reshape(n_groups, g_size, d)

    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), mlp.w_router
    )
    probs = jax.nn.softmax(logits, axis=-1)                # (g, t, E)
    k = cfg.n_experts_per_tok
    e = cfg.n_experts
    topv, topi = jax.lax.top_k(probs, k)                   # (g, t, k)
    topv = topv / jnp.maximum(
        jnp.sum(topv, axis=-1, keepdims=True), 1e-9
    )

    # Aux load-balance loss (Switch): E * sum_e f_e P_e.
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    onehot_top = jax.nn.one_hot(topi, e)                    # (g, t, k, E)
    fe = jnp.mean(jnp.sum(onehot_top, axis=2), axis=(0, 1)) / k
    aux = e * jnp.sum(fe * me)

    capacity = max(
        1, int(cfg.capacity_factor * k * g_size / e)
    )

    # Slot-major priority positions: slot 0 assignments beat slot 1.
    sel = jnp.transpose(onehot_top, (0, 2, 1, 3))           # (g, k, t, E)
    sel_flat = sel.reshape(n_groups, k * g_size, e)
    pos = jnp.cumsum(sel_flat, axis=1) - sel_flat           # rank in queue
    keep = (pos < capacity) * sel_flat
    pos_oh = jax.nn.one_hot(pos, capacity) * keep[..., None]
    disp = pos_oh.reshape(n_groups, k, g_size, e, capacity)

    gates = jnp.transpose(topv, (0, 2, 1))                  # (g, k, t)
    combine = jnp.einsum("gktec,gkt->gtec", disp, gates)    # (g, t, E, C)
    dispatch = jnp.sum(disp, axis=1)                        # (g, t, E, C)

    expert_in = jnp.einsum(
        "gtec,gtd->gecd", dispatch.astype(x.dtype), xg
    )
    hg = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, mlp.w_gate))
    hu = jnp.einsum("gecd,edf->gecf", expert_in, mlp.w_up)
    expert_out = jnp.einsum("gecf,efd->gecd", hg * hu, mlp.w_down)
    out = jnp.einsum(
        "gtec,gecd->gtd", combine.astype(x.dtype), expert_out
    )

    if mlp.shared_gate is not None:
        out = out + L.swiglu(xg, mlp.shared_gate, mlp.shared_up, mlp.shared_down)
    return out.reshape(orig_shape), aux


def _block_apply(
    cfg: ModelConfig,
    bp: BlockParams,
    x: jax.Array,
    positions: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    h = attn.full_attention(
        bp.attn, L.rms_norm(x, bp.ln1), positions, rope_theta=cfg.rope_theta
    )
    x = x + h
    h, aux = moe_apply(bp.mlp, L.rms_norm(x, bp.ln2), cfg)
    return x + h, aux


def forward(
    params: Params, batch: dict[str, jax.Array], cfg: ModelConfig
) -> tuple[jax.Array, jax.Array]:
    x = params.embed[batch["tokens"]]
    b, s = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def block(x, bp):
        fn = _block_apply
        if cfg.remat:
            fn = jax.checkpoint(fn, static_argnums=(0,))
        x, aux = fn(cfg, bp, x, positions)
        return x, aux

    x, auxes = jax.lax.scan(block, x, params.blocks, unroll=cfg.scan_unroll)
    return L.rms_norm(x, params.final_norm), jnp.sum(auxes)


def loss(
    params: Params, batch: dict[str, jax.Array], cfg: ModelConfig
) -> jax.Array:
    h, aux = forward(params, batch, cfg)
    b, s, d = h.shape
    ce = L.chunked_cross_entropy(
        h[:, :-1].reshape(-1, d),
        params.unembed,
        batch["tokens"][:, 1:].reshape(-1),
        jnp.ones((b * (s - 1),), jnp.float32),
        n_chunks=cfg.loss_chunks,
    )
    return ce + cfg.router_aux_coef * aux


class DecodeCache(NamedTuple):
    kv: attn.KVCache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               long_context: bool = False) -> DecodeCache:
    kv = attn.init_cache(batch, max_seq, cfg.n_kv_heads, cfg.head_dim, cfg.dtype)

    def stack(leaf):
        return jnp.broadcast_to(leaf[None], (cfg.n_layers, *leaf.shape))

    return DecodeCache(kv=jax.tree_util.tree_map(stack, kv))


def decode_step(
    params: Params,
    cache: DecodeCache,
    tokens: jax.Array,
    cfg: ModelConfig,
    long_context: bool = False,
) -> tuple[DecodeCache, jax.Array]:
    x = params.embed[tokens]

    def block(x, scanned):
        bp, kv = scanned
        new_kv, h = attn.decode_step(
            bp.attn, kv, L.rms_norm(x, bp.ln1), rope_theta=cfg.rope_theta
        )
        x = x + h
        h, _ = moe_apply(bp.mlp, L.rms_norm(x, bp.ln2), cfg)
        return x + h, new_kv

    x, new_kv = jax.lax.scan(block, x, (params.blocks, cache.kv),
                             unroll=cfg.scan_unroll)
    h = L.rms_norm(x, params.final_norm)
    logits = jnp.einsum("bsd,dv->bsv", h, params.unembed).astype(jnp.float32)
    return DecodeCache(kv=new_kv), logits
