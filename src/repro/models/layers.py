"""Shared neural-network layers for the architecture zoo.

Explicit init/apply style (dict params, no flax) so the same modules run
under vmap (federated client stacks), scan-over-layers (deep LMs), and
pjit (mesh runtime).  Compute dtype is bf16 with f32 norms/softmax/logits,
the standard TPU recipe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _ambient_mesh():
    """The mesh installed by ``with mesh:``, or None.

    ``jax.sharding.get_abstract_mesh`` only exists on newer JAX; on the
    0.4.x line the ambient mesh lives in the pxla thread resources.  Both
    report axis names/sizes the same way, which is all shard_hint needs.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        return get_abstract()
    from jax.interpreters import pxla

    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def shard_hint(x: jax.Array, logical: tuple[str | None, ...]) -> jax.Array:
    """Soft activation-sharding constraint (perf: EXPERIMENTS.md §Perf).

    Resolves ``logical`` dimension names against the AMBIENT mesh (the one
    the launcher/dry-run installed with ``with mesh:``) using the same
    rules as the parameter shardings, and constrains ``x`` to it.  A
    no-op without a mesh, so CPU tests/vmapped federated clients are
    untouched.

    Why: when a head count is not divisible by the model axis (qwen3-14b's
    40 heads, grok's 8 kv heads on a 16-way axis), the parameter fallback
    shards head_dim; without an activation anchor XLA ping-pongs the
    (b, s, h, d) activations between incompatible shardings inside the
    scanned layer body ("involuntary full rematerialization"), inflating
    the collective and memory roofline terms by >5x.  Anchoring q/k/v to
    batch-only (heads replicated when indivisible) keeps the attention
    math local; the only added traffic is the per-layer weight gather.
    """
    mesh = _ambient_mesh()
    if mesh is None or not mesh.axis_names or mesh.size <= 1:
        return x
    from repro.launch.sharding import resolve_spec  # no circular import

    # Inside a shard_map manual region (e.g. core/mesh_fl's pod-manual
    # step) sharding constraints on the remaining auto axes trip an XLA
    # SPMD-partitioner CHECK (mixed Manual/Auto groups) — let the
    # partitioner choose freely there instead.
    manual = getattr(getattr(jax.sharding, "AxisType", None), "Manual", None)
    if manual is not None and any(
        t == manual for t in getattr(mesh, "axis_types", ())
    ):
        return x
    spec = resolve_spec(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in f32, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    exp = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exp)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding.  x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                    # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    angles = angles[..., None, :]                                 # (..., seq, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Classic transformer sinusoidal table (whisper encoder)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        jnp.arange(0, dim, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / dim)
    )
    tab = jnp.zeros((length, dim), jnp.float32)
    tab = tab.at[:, 0::2].set(jnp.sin(pos * div))
    tab = tab.at[:, 1::2].set(jnp.cos(pos * div))
    return tab


def dense_init(key: jax.Array, shape: tuple[int, ...],
               dtype=jnp.bfloat16, scale: float | None = None) -> jax.Array:
    fan_in = shape[0]
    if scale is None:
        scale = fan_in**-0.5
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def embed_init(key: jax.Array, vocab: int, dim: int, dtype=jnp.bfloat16) -> jax.Array:
    # 1/sqrt(d) scale keeps tied-unembedding logits O(1) at init.
    return (dim**-0.5 * jax.random.normal(key, (vocab, dim), jnp.float32)).astype(dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, act=jax.nn.silu) -> jax.Array:
    """Gated MLP: down( act(x @ gate) * (x @ up) )."""
    g = act(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = g * u
    # Anchor the hidden to (batch, ff): keeps the down-proj a local
    # contraction followed by one model-axis all-reduce of the
    # batch-SHARDED residual shard (EXPERIMENTS.md §Perf iter 2).
    h = shard_hint(h, ("batch",) + (None,) * (h.ndim - 2) + ("ff",))
    out = jnp.einsum("...f,fd->...d", h, w_down)
    return shard_hint(out, ("batch",) + (None,) * (out.ndim - 1))


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
             w_out: jax.Array, b_out: jax.Array) -> jax.Array:
    """Whisper-style biased GELU MLP."""
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in) + b_in)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


def chunked_cross_entropy(
    hidden: jax.Array,          # (tokens, d_model)
    unembed: jax.Array,         # (d_model, vocab)
    targets: jax.Array,         # (tokens,) int32
    mask: jax.Array,            # (tokens,) f32
    n_chunks: int = 8,
    softcap_value: float | None = None,
) -> jax.Array:
    """Cross-entropy without materialising full (tokens, vocab) logits.

    Scans over token chunks; each chunk's logits exist only transiently
    (and are recomputed in the backward pass via jax.checkpoint).  This is
    what keeps the 256k-vocab architectures inside HBM at train_4k scale.
    """
    tokens = hidden.shape[0]
    if tokens % n_chunks != 0:
        n_chunks = 1
    chunk = tokens // n_chunks
    h = hidden.reshape(n_chunks, chunk, -1)
    t = targets.reshape(n_chunks, chunk)
    m = mask.reshape(n_chunks, chunk)

    @jax.checkpoint
    def chunk_loss(args):
        hc, tc, mc = args
        logits = jnp.einsum("sd,dv->sv", hc, unembed).astype(jnp.float32)
        if softcap_value is not None:
            logits = softcap(logits, softcap_value)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[:, None], axis=-1)[:, 0]
        return jnp.sum((logz - gold) * mc)

    def body(carry, args):
        return carry + chunk_loss(args), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (h, t, m))
    return total / jnp.maximum(jnp.sum(mask), 1.0)
