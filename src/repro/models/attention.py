"""Attention for the zoo: GQA with optional qk-norm, soft-capping, and
sliding-window (local) masking; full-sequence (train/prefill) and
single-token decode (KV cache) paths.

Shapes follow the (batch, seq, heads, head_dim) convention.  KV caches are
(batch, max_seq, kv_heads, head_dim) and are updated functionally.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers as L

NEG_INF = -2.3819763e38  # min bf16-representable-ish; standard mask value


class AttnParams(NamedTuple):
    wq: jax.Array        # (d_model, n_heads, head_dim)
    wk: jax.Array        # (d_model, n_kv, head_dim)
    wv: jax.Array        # (d_model, n_kv, head_dim)
    wo: jax.Array        # (n_heads, head_dim, d_model)
    q_norm: jax.Array | None    # (head_dim,) qk-norm scales (qwen3)
    k_norm: jax.Array | None


def init(
    key: jax.Array,
    d_model: int,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    qk_norm: bool = False,
    dtype=jnp.bfloat16,
) -> AttnParams:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return AttnParams(
        wq=L.dense_init(kq, (d_model, n_heads, head_dim), dtype),
        wk=L.dense_init(kk, (d_model, n_kv, head_dim), dtype),
        wv=L.dense_init(kv, (d_model, n_kv, head_dim), dtype),
        wo=L.dense_init(ko, (n_heads, head_dim, d_model), dtype,
                        scale=(n_heads * head_dim) ** -0.5),
        q_norm=jnp.zeros((head_dim,), dtype) if qk_norm else None,
        k_norm=jnp.zeros((head_dim,), dtype) if qk_norm else None,
    )


def axes(qk_norm: bool = False):
    """Logical sharding axes matching AttnParams."""
    return AttnParams(
        wq=("embed", "heads", "head_dim"),
        wk=("embed", "kv_heads", "head_dim"),
        wv=("embed", "kv_heads", "head_dim"),
        wo=("heads", "head_dim", "embed"),
        q_norm=("head_dim",) if qk_norm else None,
        k_norm=("head_dim",) if qk_norm else None,
    )


def _project_qkv(
    p: AttnParams, x: jax.Array, positions: jax.Array,
    rope_theta: float | None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk)
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv)
    if p.q_norm is not None:
        q = L.rms_norm(q, p.q_norm)
        k = L.rms_norm(k, p.k_norm)
    if rope_theta is not None:  # None => absolute-position models (whisper)
        q = L.apply_rope(q, positions, rope_theta)
        k = L.apply_rope(k, positions, rope_theta)
    # Anchor activation shardings AFTER rope: heads when divisible, else
    # the QUERY-SEQUENCE dim (sequence-parallel attention: each device
    # holds a q-block against the full batch-local K/V, so the quadratic
    # scores tensor is 1/16 per device and stays local).  head_dim is
    # deliberately NOT offered — see layers.shard_hint.  Without an
    # anchor, the head_dim-sharded weight layout propagates through rope
    # into the scores einsum, turning the contraction into partial sums +
    # an all-reduce of the full (b, h, g, s, s) f32 scores (343 GB/layer
    # at prefill_32k on qwen3-14b — EXPERIMENTS.md §Perf).
    q = L.shard_hint(q, ("batch", "seq_shard", "heads", None))
    k = L.shard_hint(k, ("batch", None, "kv_heads", None))
    v = L.shard_hint(v, ("batch", None, "kv_heads", None))
    return q, k, v


def full_attention(
    p: AttnParams,
    x: jax.Array,                 # (b, s, d)
    positions: jax.Array,         # (b, s)
    window: jax.Array | int | None = None,   # sliding window (tokens) or None
    attn_softcap: float | None = None,
    rope_theta: float = 10000.0,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,  # enc-dec cross-attn
    causal: bool = True,
) -> jax.Array:
    """Dense (possibly masked) attention for train/prefill."""
    b, s, d = x.shape
    if cross_kv is None:
        q, k, v = _project_qkv(p, x, positions, rope_theta)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
        if p.q_norm is not None:
            q = L.rms_norm(q, p.q_norm)
        k, v = cross_kv
    n_heads, head_dim = q.shape[-2], q.shape[-1]
    n_kv = k.shape[-2]
    g = n_heads // n_kv

    qg = q.reshape(b, s, n_kv, g, head_dim)
    scores = jnp.einsum(
        "bqhgd,bthd->bhgqt", qg, k, preferred_element_type=jnp.float32
    ) * (head_dim**-0.5)                          # (b, n_kv, g, s_q, s_k)
    if attn_softcap is not None:
        scores = L.softcap(scores, attn_softcap)

    s_k = k.shape[1]
    qpos = positions[:, :, None]                   # (b, s_q, 1)
    kpos = jnp.arange(s_k)[None, None, :]          # (1, 1, s_k)
    mask = jnp.ones((b, s, s_k), bool)
    if causal and cross_kv is None:
        mask &= kpos <= qpos
    if window is not None and cross_kv is None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqt,bthk->bqhgk", probs, v)
    out = out.reshape(b, s, n_heads, head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p.wo)


class KVCache(NamedTuple):
    k: jax.Array          # (b, max_seq, n_kv, head_dim)
    v: jax.Array
    length: jax.Array     # (b,) int32 — valid entries


def init_cache(
    batch: int, max_seq: int, n_kv: int, head_dim: int, dtype=jnp.bfloat16
) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_seq, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, max_seq, n_kv, head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def decode_step(
    p: AttnParams,
    cache: KVCache,
    x: jax.Array,                 # (b, 1, d) — the new token's activations
    window: jax.Array | int | None = None,
    attn_softcap: float | None = None,
    rope_theta: float = 10000.0,
    use_pallas_swa: bool = False,
) -> tuple[KVCache, jax.Array]:
    """One decode step: append to cache, attend, return (cache, out)."""
    b = x.shape[0]
    positions = cache.length[:, None]              # (b, 1)
    q, k_new, v_new = _project_qkv(p, x, positions, rope_theta)

    idx = cache.length                              # (b,)
    k = jax.vmap(lambda c, kn, i: jax.lax.dynamic_update_slice(
        c, kn, (i, 0, 0)))(cache.k, k_new, idx)
    v = jax.vmap(lambda c, vn, i: jax.lax.dynamic_update_slice(
        c, vn, (i, 0, 0)))(cache.v, v_new, idx)
    new_len = cache.length + 1

    max_seq = k.shape[1]
    n_heads, head_dim = q.shape[-2], q.shape[-1]
    n_kv = k.shape[-2]
    g = n_heads // n_kv

    if use_pallas_swa and window is not None:
        out = jax.vmap(
            lambda qq, kk, vv, ln: kops.swa_decode_attention(
                qq.reshape(n_heads, head_dim), kk, vv, ln,
                int(window), use_pallas=True,
            )
        )(q[:, 0], k, v, new_len)
        out = out.reshape(b, 1, n_heads, head_dim)
    else:
        qg = q.reshape(b, 1, n_kv, g, head_dim)
        scores = jnp.einsum(
            "bqhgk,bthk->bhgqt", qg, k, preferred_element_type=jnp.float32
        ) * (head_dim**-0.5)
        if attn_softcap is not None:
            scores = L.softcap(scores, attn_softcap)
        kpos = jnp.arange(max_seq)[None, :]
        valid = kpos < new_len[:, None]
        if window is not None:
            valid &= kpos >= (new_len[:, None] - window)
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(x.dtype)
        out = jnp.einsum("bhgqt,bthk->bqhgk", probs, v)
        out = out.reshape(b, 1, n_heads, head_dim)

    y = jnp.einsum("bshk,hkd->bsd", out, p.wo)
    return KVCache(k, v, new_len), y
