"""Pure-jnp oracles for every Pallas kernel in this package.

These define the *semantics* the kernels must match bit-for-bit (or to
float tolerance where reductions reorder).  Tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-oracle.

Semantics notes
---------------
Block Top-K uses *threshold-by-bisection* selection: a per-block magnitude
threshold t is refined for a fixed number of iterations so that the number
of entries with |x| > t is as large as possible while <= k.  This is the
TPU-native replacement for CUDA radix-select (see DESIGN.md §4); the oracle
implements the identical iteration so kernel and oracle agree exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BISECT_ITERS = 32


def bisect_threshold(absx: jax.Array, k: int, iters: int = BISECT_ITERS) -> jax.Array:
    """Magnitude threshold t with |{i : absx_i > t}| <= k, maximal keep.

    ``absx``: (..., block) non-negative.  Returns (..., 1) threshold.
    Invariant maintained: count(> hi) <= k <= count(> lo)  (lo starts at -1
    so every entry passes; hi starts at max so none does).
    """
    lo = jnp.full(absx.shape[:-1] + (1,), -1.0, absx.dtype)
    hi = jnp.max(absx, axis=-1, keepdims=True)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(absx > mid, axis=-1, keepdims=True)
        lo = jnp.where(cnt > k, mid, lo)
        hi = jnp.where(cnt > k, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def blockwise_topk_ef_ref(
    delta: jax.Array, err: jax.Array, k_per_block: int
) -> tuple[jax.Array, jax.Array]:
    """Error-feedback block Top-K (paper Eq. 30, blockwise TPU variant).

    Inputs are (nb, block).  Returns (sparse, new_err) with
    sparse + new_err == delta + err exactly (mask decomposition).
    """
    v = delta + err
    absv = jnp.abs(v)
    t = bisect_threshold(absv, k_per_block)
    mask = absv > t
    sparse = jnp.where(mask, v, 0.0)
    return sparse, v - sparse


def quant8_ref(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantisation.

    x: (nb, block) -> (q int8 (nb, block), scale f32 (nb, 1));
    scale = max|x| / 127, q = round(x / scale).  All-zero blocks get
    scale 0 and q 0.
    """
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    q = jnp.where(scale > 0, q, jnp.zeros_like(q))
    return q, scale


def dequant8_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quant8_ref` (lossy)."""
    return q.astype(jnp.float32) * scale


def compress_ref(
    delta: jax.Array, err: jax.Array, k_per_block: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused EF Top-K + int8 quantisation (the full paper pipeline, Sec. V-C).

    Returns (q int8, scale, new_err).  The error buffer absorbs *both* the
    sparsification residual and the quantisation residual, so no update
    information is permanently lost:
        dequant(q, scale) + new_err == delta + err   (up to f32 rounding)
    """
    v = delta + err
    absv = jnp.abs(v)
    t = bisect_threshold(absv, k_per_block)
    mask = absv > t
    sparse = jnp.where(mask, v, 0.0)
    q, scale = quant8_ref(sparse)
    recon = dequant8_ref(q, scale)
    return q, scale, v - recon


def sliding_window_decode_attention_ref(
    q: jax.Array,          # (Hq, d)
    k_cache: jax.Array,    # (S, Hkv, d)
    v_cache: jax.Array,    # (S, Hkv, d)
    cache_len: jax.Array,  # scalar int — number of valid cache entries
    window: int,           # attend to the last `window` positions
    scale: float | None = None,
) -> jax.Array:
    """One-token GQA decode attention over a sliding window. Returns (Hq, d)."""
    hq, d = q.shape
    s, hkv, _ = k_cache.shape
    g = hq // hkv
    if scale is None:
        scale = d ** -0.5
    qg = q.reshape(hkv, g, d).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    scores = jnp.einsum("hgd,shd->hgs", qg, kf) * scale     # (hkv, g, s)
    pos = jnp.arange(s)
    valid = (pos < cache_len) & (pos >= cache_len - window)
    scores = jnp.where(valid[None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hgs,shd->hgd", p, v_cache.astype(jnp.float32))
    return out.reshape(hq, d).astype(q.dtype)
