"""Pure-jnp oracles for every Pallas kernel in this package.

These define the *semantics* the kernels must match bit-for-bit (or to
float tolerance where reductions reorder).  Tests sweep shapes/dtypes and
``assert_allclose`` kernel-vs-oracle.

Semantics notes
---------------
Block Top-K uses *threshold-by-bisection* selection: a per-block magnitude
threshold t is refined for a fixed number of iterations so that the number
of entries with |x| > t is as large as possible while <= k.  This is the
TPU-native replacement for CUDA radix-select (see DESIGN.md §4); the oracle
implements the identical iteration so kernel and oracle agree exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BISECT_ITERS = 32


def bisect_threshold(
    absx: jax.Array, k: int, iters: int = BISECT_ITERS,
    hi: jax.Array | None = None,
) -> jax.Array:
    """Magnitude threshold t with |{i : absx_i > t}| <= k, maximal keep.

    ``absx``: (..., block) non-negative.  Returns (..., 1) threshold.
    Invariant maintained: count(> hi) <= k <= count(> lo)  (lo starts at -1
    so every entry passes; hi starts at max so none does).  Callers that
    already hold the per-block max can pass it as ``hi`` to skip the
    reduction.
    """
    lo = jnp.full(absx.shape[:-1] + (1,), -1.0, absx.dtype)
    if hi is None:
        hi = jnp.max(absx, axis=-1, keepdims=True)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(absx > mid, axis=-1, keepdims=True)
        lo = jnp.where(cnt > k, mid, lo)
        hi = jnp.where(cnt > k, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def blockwise_topk_ef_ref(
    delta: jax.Array, err: jax.Array, k_per_block: int
) -> tuple[jax.Array, jax.Array]:
    """Error-feedback block Top-K (paper Eq. 30, blockwise TPU variant).

    Inputs are (nb, block).  Returns (sparse, new_err) with
    sparse + new_err == delta + err exactly (mask decomposition).
    """
    v = delta + err
    absv = jnp.abs(v)
    t = bisect_threshold(absv, k_per_block)
    mask = absv > t
    sparse = jnp.where(mask, v, 0.0)
    return sparse, v - sparse


def quant8_ref(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantisation.

    x: (nb, block) -> (q int8 (nb, block), scale f32 (nb, 1));
    scale = max|x| / 127, q = round(x / scale).  All-zero blocks get
    scale 0 and q 0.
    """
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    q = jnp.where(scale > 0, q, jnp.zeros_like(q))
    return q, scale


def dequant8_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quant8_ref` (lossy)."""
    return q.astype(jnp.float32) * scale


def compress_ref(
    delta: jax.Array, err: jax.Array, k_per_block: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused EF Top-K + int8 quantisation (the full paper pipeline, Sec. V-C).

    Returns (q int8, scale, new_err).  The error buffer absorbs *both* the
    sparsification residual and the quantisation residual, so no update
    information is permanently lost:
        dequant(q, scale) + new_err == delta + err   (up to f32 rounding)
    """
    v = delta + err
    absv = jnp.abs(v)
    t = bisect_threshold(absv, k_per_block)
    mask = absv > t
    sparse = jnp.where(mask, v, 0.0)
    q, scale = quant8_ref(sparse)
    recon = dequant8_ref(q, scale)
    return q, scale, v - recon


def compress_aggregate_ref(
    delta: jax.Array,        # (N, nb, block) per-client blocked updates
    err: jax.Array,          # (N, nb, block) EF buffers
    fog_id: jax.Array,       # (N,) int32 cluster id per client
    weights: jax.Array,      # (N,) f32, zeroed for non-participants
    n_fog: int,
    k_per_block: int,
    quantize: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused compress-and-aggregate kernel.

    Per client: EF Top-K (+ optional int8 round-trip), exactly the
    :func:`compress_ref` / :func:`blockwise_topk_ef_ref` semantics; the
    reconstructions are then weight-scaled and segment-summed into per-fog
    accumulators instead of being returned densely.

    Returns (fog_sum (n_fog, nb, block) f32 — the UNNORMALISED weighted
    sums sum_{i in C_m} w_i recon_i — and new_err (N, nb, block)).
    """
    v = delta + err
    absv = jnp.abs(v)
    amax = jnp.max(absv, axis=-1, keepdims=True)
    t = bisect_threshold(absv, k_per_block, hi=amax)
    sparse = jnp.where(absv > t, v, 0.0)
    if quantize:
        # int8 round-trip in f32: round() yields exact integers <= 127, so
        # q * scale is bit-identical to quant8_ref + dequant8_ref without
        # materialising the int8 codes (the fused op never transmits them).
        # The quantisation scale reuses the block max of absv: whenever any
        # coordinate survives the threshold the block max survives too
        # (absv_max > t), so max|sparse| == max(absv); when nothing
        # survives, sparse is all-zero and the scale multiplies only
        # zeros — recon/new_err are identical either way.
        scale = amax / 127.0
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(sparse / safe), -127.0, 127.0)
        recon = jnp.where(scale > 0, q * scale, 0.0)
    else:
        recon = sparse
    # Cluster reduction as a one-hot GEMM with the weights folded into the
    # selector: no dense (N, nb, block) weighted intermediate, no scatter.
    sel = jnp.where(
        fog_id[None, :] == jnp.arange(n_fog)[:, None], weights[None, :], 0.0
    ).astype(jnp.float32)
    fog_sum = jnp.tensordot(sel, recon.astype(jnp.float32), axes=(1, 0))
    return fog_sum, v - recon


def compress_wire_ref(
    delta: jax.Array,        # (N, nb, block) per-client blocked updates
    err: jax.Array,          # (N, nb, block) EF buffers
    k_per_block: int,
    quantize: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Emit the sparse wire format: what actually travels up the acoustic link.

    Selection is the identical bisection-threshold rule as
    :func:`compress_aggregate_ref` (mask = |v| > t), but instead of a dense
    masked array the survivors are packed into ``k_per_block`` fixed slots
    per block.  Returns

    - ``idx``   (N, nb, k) int32 — within-block coordinate of each slot,
    - ``q``     (N, nb, k) int8 (``quantize``) or f32 — slot values; unused
      slots (fewer than k survivors) carry value 0, making them no-ops for
      any consumer that scatter-adds,
    - ``scale`` (N, nb) f32 — per-block dequant scale (block max / 127;
      1.0 when not quantizing so ``q * scale`` is always the recon),
    - ``new_err`` (N, nb, block) — EF state, bit-identical to the dense
      path's (the residual decomposition is the same).

    The wire is the rho_s-sized object: per block it is k indices + k int8
    codes + one f32 scale, the Eq. 31 payload made manifest instead of
    analytic-only.
    """
    v = delta + err
    absv = jnp.abs(v)
    amax = jnp.max(absv, axis=-1, keepdims=True)
    t = bisect_threshold(absv, k_per_block, hi=amax)
    survive = absv > t
    block = v.shape[-1]
    k = min(int(k_per_block), block)
    # Rank survivors first (absv >= 0 > -1 for non-survivors), then take the
    # k best slots.  Bisection guarantees <= k_per_block survivors, so every
    # survivor lands in a slot; surplus slots are masked to exact zeros.
    rank_key = jnp.where(survive, absv, -1.0)
    _, idx = jax.lax.top_k(rank_key, k)
    kept = jnp.take_along_axis(survive, idx, axis=-1)
    vals = jnp.where(kept, jnp.take_along_axis(v, idx, axis=-1), 0.0)
    if quantize:
        # Same scale rule as compress_aggregate_ref: block max of absv (the
        # top survivor IS the block max whenever anything survives).
        scale = (amax / 127.0)[..., 0]                      # (N, nb)
        safe = jnp.where(scale > 0, scale, 1.0)[..., None]
        q = jnp.clip(jnp.round(vals / safe), -127.0, 127.0)
        recon_vals = jnp.where(scale[..., None] > 0, q * scale[..., None], 0.0)
        q = q.astype(jnp.int8)
    else:
        scale = jnp.ones(v.shape[:-1], jnp.float32)
        q = vals
        recon_vals = vals
    n, nb, _ = v.shape
    ii = jnp.arange(n)[:, None, None]
    bb = jnp.arange(nb)[None, :, None]
    new_err = v.at[ii, bb, idx].add(-recon_vals)
    return idx.astype(jnp.int32), q, scale, new_err


def wire_aggregate_ref(
    idx: jax.Array,          # (N, nb, k) int32 within-block coordinates
    q: jax.Array,            # (N, nb, k) int8 codes (or f32 values)
    scale: jax.Array,        # (N, nb) f32 per-block dequant scales
    fog_id: jax.Array,       # (N,) int32 cluster id per client
    weights: jax.Array,      # (N,) f32, zeroed for non-participants
    n_fog: int,
    block: int,
) -> jax.Array:
    """Weighted scatter-accumulate straight off the wire.

    Each slot contributes ``w_i * q * scale`` at its block coordinate of its
    client's fog accumulator.  No dense (N, nb, block) reconstruction ever
    exists — contributions flow (N, nb, k) -> (n_fog, nb, block) directly,
    which is what bounds the memory high-water mark at fleet scale.
    Returns fog_sum (n_fog, nb, block) f32 (unnormalised weighted sums).
    """
    n, nb, _ = idx.shape
    contrib = q.astype(jnp.float32) * scale[..., None] * weights[:, None, None]
    ff = jnp.broadcast_to(fog_id[:, None, None], idx.shape)
    bb = jnp.broadcast_to(jnp.arange(nb)[None, :, None], idx.shape)
    fog_sum = jnp.zeros((n_fog, nb, block), jnp.float32)
    return fog_sum.at[ff, bb, idx].add(contrib)


def compress_aggregate_wire_ref(
    delta: jax.Array,        # (N, nb, block)
    err: jax.Array,          # (N, nb, block)
    fog_id: jax.Array,       # (N,) int32
    weights: jax.Array,      # (N,) f32
    n_fog: int,
    k_per_block: int,
    quantize: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Sparse-wire twin of :func:`compress_aggregate_ref`.

    Emits the wire then consumes it with the scatter-accumulate; equal to
    the dense one-hot-GEMM oracle up to f32 summation order (scatter-add vs
    GEMM reduce) and threshold ties, which is why the chunked round path
    that uses it is pinned to tolerance rather than bitwise.
    """
    idx, q, scale, new_err = compress_wire_ref(delta, err, k_per_block, quantize)
    fog_sum = wire_aggregate_ref(
        idx, q, scale, fog_id, weights, n_fog, delta.shape[-1]
    )
    return fog_sum, new_err


def robust_aggregate_ref(
    recon: jax.Array,        # (N, d) per-client reconstructions
    fog_id: jax.Array,       # (N,) int32 cluster id per client
    weights: jax.Array,      # (N,) f32, zeroed for non-participants
    n_fog: int,
    trim_frac: float | jax.Array = 0.1,
    mode: str = "trimmed",
) -> tuple[jax.Array, jax.Array]:
    """Oracle for coordinate-wise Byzantine-robust fog aggregation.

    ``mode="trimmed"``: weighted trimmed mean — per fog and coordinate,
    the members' values are (conceptually) laid out on a weight axis of
    total mass W, the outer ``trim_frac`` mass is cut from EACH end, and
    the surviving mass is averaged.  Implemented sort-free via tie-group
    interval overlap: member i with value v_i owns the weight interval
    [A_i, A_i + g_i) scaled by w_i/g_i, where A_i is the weight strictly
    below v_i and g_i the weight tied at v_i; its surviving (effective)
    weight is the overlap of that interval with [beta W, (1 - beta) W].
    Order-independent, no data-dependent gathers, and at
    ``trim_frac == 0`` the overlap is exactly g_i — so the result reduces
    to the plain weighted mean bit-for-bit up to summation order (the
    equivalence pin in the tests).

    ``mode="median"``: weighted (lower) median — the tie group whose
    interval contains W/2.

    Returns (fog_out (n_fog, d) f32 — the NORMALISED robust aggregate per
    fog, zeros for empty fogs — and fog_weight (n_fog,) = sum of member
    weights, the Eq. 16 gateway weights).  ``trim_frac`` may be traced
    (config-axis sweeps); it is clamped below 0.5 — trimming half the
    mass from both ends leaves nothing.
    """
    v = recon.astype(jnp.float32)
    w_fog = jnp.where(
        fog_id[None, :] == jnp.arange(n_fog)[:, None],
        weights[None, :].astype(jnp.float32), 0.0,
    )                                                    # (M, N)
    fog_weight = jnp.sum(w_fog, axis=1)
    # Pairwise comparisons, shared across fogs: [i, k, d].
    less = (v[None, :, :] < v[:, None, :]).astype(jnp.float32)
    eq = (v[None, :, :] == v[:, None, :]).astype(jnp.float32)

    def one_fog(w):                                      # (N,) member weights
        big_w = jnp.sum(w)
        a = jnp.einsum("ikd,k->id", less, w)             # weight below v_i
        g = jnp.einsum("ikd,k->id", eq, w)               # weight tied at v_i
        g_safe = jnp.maximum(g, 1e-30)
        if mode == "median":
            half = 0.5 * big_w
            ratio = jnp.where((a < half) & (half <= a + g), 1.0 / g_safe, 0.0)
        else:
            beta = jnp.clip(jnp.asarray(trim_frac, jnp.float32), 0.0, 0.4995)
            lo = jnp.maximum(a, beta * big_w)
            hi = jnp.minimum(a + g, (1.0 - beta) * big_w)
            # overlap == g exactly at beta 0, so ratio == 1.0 exactly and
            # eff_i == w_i — the weighted-mean equivalence.
            ratio = jnp.maximum(hi - lo, 0.0) / g_safe
        eff = w[:, None] * ratio                         # (N, d)
        num = jnp.einsum("id,id->d", eff, v)
        den = jnp.sum(eff, axis=0)
        return num / jnp.maximum(den, 1e-12)

    return jax.vmap(one_fog)(w_fog), fog_weight


def fused_score_ref(
    x: jax.Array,                 # (R, d) telemetry rows
    ws: tuple[jax.Array, ...],    # per-layer weights, (d_in, d_out)
    bs: tuple[jax.Array, ...],    # per-layer biases, (d_out,)
    tau: jax.Array,               # (R,) per-row thresholds
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused anomaly-score kernel (serving hot path).

    AE forward (tanh hidden layers, linear output — exactly
    ``models/autoencoder.apply``), squared-L2 reconstruction error
    (Sec. V-D), and the Eq. 32 threshold compare in one computation.

    Returns (err (R,) f32, flag (R,) bool).  The dense reconstruction is
    an internal intermediate only — the fused kernel never writes it to
    HBM, and neither path returns it.
    """
    h = x.astype(jnp.float32)
    for i, (w, b) in enumerate(zip(ws, bs)):
        h = h @ w.astype(jnp.float32) + b.astype(jnp.float32)
        if i < len(ws) - 1:
            h = jnp.tanh(h)
    err = jnp.sum(jnp.square(x.astype(jnp.float32) - h), axis=-1)
    return err, err > tau


def fused_score_q8_ref(
    x: jax.Array,                  # (R, d) telemetry rows
    qws: tuple[jax.Array, ...],    # per-layer int8 weights, (d_in, d_out)
    sws: tuple[jax.Array, ...],    # per-layer scales, (1, d_out) f32
    bs: tuple[jax.Array, ...],     # per-layer f32 biases, (d_out,)
    tau: jax.Array,                # (R,) per-row thresholds
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the int8-weight fused score kernel: per-output-channel
    symmetric dequantisation (``w = q * scale``) INSIDE the program, then
    exactly :func:`fused_score_ref`.  The f32 weights never exist outside
    the compiled computation — the serving buffers stay int8."""
    ws = tuple(
        q.astype(jnp.float32) * s.astype(jnp.float32).reshape(1, -1)
        for q, s in zip(qws, sws)
    )
    return fused_score_ref(x, ws, bs, tau)


def local_train_ref(
    x: jax.Array,                 # (window, D) one client's resident window
    idx: jax.Array,               # (steps, bsz) int32 minibatch row indices
    ws: tuple[jax.Array, ...],    # per-layer weights, (d_in, d_out)
    bs: tuple[jax.Array, ...],    # per-layer biases, (d_out,)
    lr: float | jax.Array,
    mu: float | jax.Array = 0.0,
    use_prox: bool | None = None,
) -> tuple[tuple[jax.Array, ...], tuple[jax.Array, ...], jax.Array]:
    """Oracle for the fused local-training kernel (the client phase).

    Runs the whole E-epoch local solver of one client — exactly
    ``optim/sgd.local_sgd`` (``mu == 0``) / ``proximal_local_sgd``
    (``mu > 0``, FedProx with the broadcast params as anchor) over the
    ``models/autoencoder.loss`` objective — but assembles each minibatch by
    *indexing* the resident ``(window, D)`` data with ``idx`` instead of
    consuming a pre-gathered ``(steps, bsz, D)`` batch stream.  With
    ``idx = data/pipeline.multi_epoch_indices(key, ...)`` the two
    formulations see identical batches, so they agree to float tolerance.

    Returns (new_ws, new_bs, mean_loss).  ``lr``/``mu`` are traceable
    (pure arithmetic); ``use_prox`` is the STATIC proximal-term switch —
    None derives it from a concrete ``mu`` and defaults to True for a
    traced one (a runtime mu of 0 then contributes an exact zero term).
    """
    if use_prox is None:
        use_prox = not (isinstance(mu, (int, float)) and mu == 0.0)
    n_layers = len(ws)

    def loss_fn(params, batch):
        pw, pb = params
        h = batch
        for li in range(n_layers):
            h = h @ pw[li] + pb[li]
            if li < n_layers - 1:
                h = jnp.tanh(h)
        return jnp.mean(jnp.sum(jnp.square(batch - h), axis=-1))

    anchor = (ws, bs)
    grad_fn = jax.value_and_grad(loss_fn)

    def step(params, ib):
        loss, g = grad_fn(params, x[ib])
        if use_prox:
            g = jax.tree_util.tree_map(
                lambda gg, p, a: gg + mu * (p - a), g, params, anchor
            )
        new = jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)
        return new, loss

    (new_ws, new_bs), losses = jax.lax.scan(step, (ws, bs), idx)
    return new_ws, new_bs, jnp.mean(losses)


def sliding_window_decode_attention_ref(
    q: jax.Array,          # (Hq, d)
    k_cache: jax.Array,    # (S, Hkv, d)
    v_cache: jax.Array,    # (S, Hkv, d)
    cache_len: jax.Array,  # scalar int — number of valid cache entries
    window: int,           # attend to the last `window` positions
    scale: float | None = None,
) -> jax.Array:
    """One-token GQA decode attention over a sliding window. Returns (Hq, d)."""
    hq, d = q.shape
    s, hkv, _ = k_cache.shape
    g = hq // hkv
    if scale is None:
        scale = d ** -0.5
    qg = q.reshape(hkv, g, d).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    scores = jnp.einsum("hgd,shd->hgs", qg, kf) * scale     # (hkv, g, s)
    pos = jnp.arange(s)
    valid = (pos < cache_len) & (pos >= cache_len - window)
    scores = jnp.where(valid[None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hgs,shd->hgd", p, v_cache.astype(jnp.float32))
    return out.reshape(hq, d).astype(q.dtype)
