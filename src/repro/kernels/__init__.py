# Pallas TPU kernels for the compute hot-spots (update compression and the
# long-context sliding-window decode attention) + jnp oracles in ref.py.
