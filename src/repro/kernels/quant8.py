"""Pallas TPU kernels: per-block symmetric int8 (de)quantisation, and the
fused compress kernel (EF add + block Top-K + int8 quantise) used by the
federated update pipeline (paper Sec. V-C).

The fused kernel is the production path: it keeps the whole
sparsify-quantise-residual computation in VMEM, writing each element of the
update exactly once (q) plus the error buffer — versus three separate HBM
round-trips for the unfused pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import BISECT_ITERS
from repro.kernels.topk_ef import BLOCK_LANES, BLOCK_ROWS


def _quant8_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x))
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    q_ref[...] = jnp.where(scale > 0, q, jnp.zeros_like(q))
    scale_ref[...] = jnp.full(scale_ref.shape, scale, jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quant8_blocks(
    x: jax.Array, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Quantise (nb, R, L) blocks -> (q int8, scale (nb, 1, 1))."""
    nb = x.shape[0]
    assert x.shape == (nb, BLOCK_ROWS, BLOCK_LANES), x.shape
    spec = pl.BlockSpec((1, BLOCK_ROWS, BLOCK_LANES), lambda i: (i, 0, 0))
    scale_spec = pl.BlockSpec((1, 1, 1), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _quant8_kernel,
        grid=(nb,),
        in_specs=[spec],
        out_specs=[spec, scale_spec],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, jnp.int8),
            jax.ShapeDtypeStruct((nb, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def _compress_kernel(delta_ref, err_ref, q_ref, scale_ref, new_err_ref, *, k: int):
    v = delta_ref[...] + err_ref[...]
    absv = jnp.abs(v)

    lo = jnp.float32(-1.0)
    hi = jnp.max(absv)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        take = jnp.sum(absv > mid) > k
        return jnp.where(take, mid, lo), jnp.where(take, hi, mid)

    lo, hi = jax.lax.fori_loop(0, BISECT_ITERS, body, (lo, hi))
    sparse = jnp.where(absv > hi, v, 0.0)

    amax = jnp.max(jnp.abs(sparse))
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(sparse / safe), -127, 127).astype(jnp.int8)
    q = jnp.where(scale > 0, q, jnp.zeros_like(q))
    recon = q.astype(jnp.float32) * scale
    q_ref[...] = q
    scale_ref[...] = jnp.full(scale_ref.shape, scale, jnp.float32)
    new_err_ref[...] = v - recon


@functools.partial(jax.jit, static_argnames=("k_per_block", "interpret"))
def compress_blocks(
    delta: jax.Array,
    err: jax.Array,
    k_per_block: int,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused EF + Top-K + int8: (nb, R, L) -> (q, scale, new_err)."""
    nb = delta.shape[0]
    assert delta.shape == (nb, BLOCK_ROWS, BLOCK_LANES), delta.shape
    spec = pl.BlockSpec((1, BLOCK_ROWS, BLOCK_LANES), lambda i: (i, 0, 0))
    scale_spec = pl.BlockSpec((1, 1, 1), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_compress_kernel, k=k_per_block),
        grid=(nb,),
        in_specs=[spec, spec],
        out_specs=[spec, scale_spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(delta.shape, jnp.int8),
            jax.ShapeDtypeStruct((nb, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct(delta.shape, delta.dtype),
        ],
        interpret=interpret,
    )(delta, err)
