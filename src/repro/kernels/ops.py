"""Public jitted wrappers around the Pallas kernels.

These own layout: flat update vectors are zero-padded to a whole number of
(BLOCK_ROWS x BLOCK_LANES) tiles and reshaped for the kernels; outputs are
un-padded back.  ``use_pallas=False`` routes to the pure-jnp oracle (the
default on the CPU dry-run path, so lowered HLO stays clean for roofline
analysis); ``use_pallas=True`` with ``interpret=True`` exercises the kernel
body on CPU, and on a real TPU ``interpret=False`` compiles it.

Scalar knobs (``k_frac``, ``lr``, ``prox_mu``) are TRACEABLE on the oracle
path: the blockwise selection is threshold-by-bisection against a keep
*count* and SGD uses the rates purely arithmetically, so config-axis
sweeps (``Engine.sweep``) can batch different knob values in one compiled
program.  The Pallas kernels bake those scalars into the kernel body, so
the pallas branch still requires concrete Python numbers — the sweep
driver keeps kernel-bound knobs static per shape-class on TPU.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import fused_agg as _fa
from repro.kernels import fused_local_train as _flt
from repro.kernels import fused_score as _fs
from repro.kernels import quant8 as _q8
from repro.kernels import ref as _ref
from repro.kernels import robust_agg as _ra
from repro.kernels import swa_attention as _swa
from repro.kernels import topk_ef as _tk

BLOCK_ELEMS = _tk.BLOCK_ELEMS


def _pad_blocks(x: jax.Array) -> tuple[jax.Array, int]:
    """Zero-pad flat (n,) to (nb, ROWS, LANES); return original length."""
    n = x.shape[0]
    nb = max(1, -(-n // BLOCK_ELEMS))
    padded = jnp.zeros((nb * BLOCK_ELEMS,), x.dtype).at[:n].set(x)
    return padded.reshape(nb, _tk.BLOCK_ROWS, _tk.BLOCK_LANES), n


def _pad_blocks_batch(x: jax.Array) -> tuple[jax.Array, int]:
    """Zero-pad (N, d) rows to (N, nb, ROWS, LANES); return original d."""
    n_rows, d = x.shape
    nb = max(1, -(-d // BLOCK_ELEMS))
    padded = jnp.zeros((n_rows, nb * BLOCK_ELEMS), x.dtype).at[:, :d].set(x)
    return padded.reshape(n_rows, nb, _tk.BLOCK_ROWS, _tk.BLOCK_LANES), d


def _unpad(x: jax.Array, n: int) -> jax.Array:
    return x.reshape(-1)[:n]


def _static_scalar(x, name: str) -> float:
    """Concretise a kernel-bound scalar for the Pallas branch.

    The Pallas kernels bake these into the kernel body, so a traced value
    (a config-axis sweep) cannot reach them — the sweep driver must demote
    the knob to a per-shape-class constant first (it does, on TPU).
    """
    try:
        return float(x)
    except (jax.errors.ConcretizationTypeError, TypeError) as e:
        raise ValueError(
            f"{name} must be a concrete Python number on the Pallas kernel "
            f"path (it is baked into the kernel body); traced values are "
            f"only supported with use_pallas=False"
        ) from e


def _block_k(k_frac) -> jax.Array | int:
    """Per-block keep count from a keep fraction; traced fractions give a
    traced count (used only in bisection comparisons on the oracle path)."""
    if isinstance(k_frac, (int, float)):
        return max(1, int(round(k_frac * BLOCK_ELEMS)))
    return jnp.maximum(
        1.0, jnp.round(jnp.asarray(k_frac, jnp.float32) * BLOCK_ELEMS)
    )


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def _topk_ef_pallas(delta, err, k: int, interpret: bool):
    blocks, n = _pad_blocks(delta)
    err_blocks, _ = _pad_blocks(err)
    sparse, new_err = _tk.topk_ef_blocks(blocks, err_blocks, k, interpret)
    return _unpad(sparse, n), _unpad(new_err, n)


@jax.jit
def _topk_ef_ref(delta, err, k):
    blocks, n = _pad_blocks(delta)
    err_blocks, _ = _pad_blocks(err)
    flat = blocks.reshape(blocks.shape[0], -1)
    eflat = err_blocks.reshape(blocks.shape[0], -1)
    sparse, new_err = _ref.blockwise_topk_ef_ref(flat, eflat, k)
    return _unpad(sparse, n), _unpad(new_err, n)


def topk_ef(
    delta: jax.Array,
    err: jax.Array,
    k_frac: float | jax.Array,
    use_pallas: bool = False,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Blockwise EF Top-K on a flat vector.  Keeps ~k_frac of each block.

    ``k_frac`` may be traced on the oracle path (``use_pallas=False``).
    """
    if use_pallas:
        k = max(1, int(round(_static_scalar(k_frac, "k_frac") * BLOCK_ELEMS)))
        return _topk_ef_pallas(delta, err, k, interpret)
    return _topk_ef_ref(delta, err, _block_k(k_frac))


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def quant8(
    x: jax.Array, use_pallas: bool = False, interpret: bool = True
) -> tuple[jax.Array, jax.Array, int]:
    """Blockwise int8 quantise a flat vector -> (q blocks, scales, n)."""
    blocks, n = _pad_blocks(x)
    if use_pallas:
        q, scale = _q8.quant8_blocks(blocks, interpret)
        scale = scale.reshape(-1, 1)
        q = q.reshape(q.shape[0], -1)
    else:
        q, scale = _ref.quant8_ref(blocks.reshape(blocks.shape[0], -1))
    return q, scale, n


@jax.jit
def dequant8(q: jax.Array, scale: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`quant8`; returns the flat (n,) vector."""
    return _ref.dequant8_ref(q, scale).reshape(-1)[:n]


def _compress_payload(qf, scale, new_err, n):
    recon = _ref.dequant8_ref(qf, scale)
    nnz = jnp.sum(qf != 0)
    d = jnp.maximum(n, 2)
    b_idx = jnp.ceil(jnp.log2(d.astype(jnp.float32)))
    payload_bits = nnz.astype(jnp.float32) * (8.0 + b_idx)
    return _unpad(recon, n), _unpad(new_err, n), payload_bits


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def _compress_pallas(delta, err, k: int, interpret: bool):
    blocks, n = _pad_blocks(delta)
    err_blocks, _ = _pad_blocks(err)
    q, scale, new_err = _q8.compress_blocks(blocks, err_blocks, k, interpret)
    qf = q.reshape(q.shape[0], -1)
    scale = scale.reshape(-1, 1)
    return _compress_payload(qf, scale, new_err, n)


@jax.jit
def _compress_ref(delta, err, k):
    blocks, n = _pad_blocks(delta)
    err_blocks, _ = _pad_blocks(err)
    qf, scale, new_err = _ref.compress_ref(
        blocks.reshape(blocks.shape[0], -1),
        err_blocks.reshape(blocks.shape[0], -1),
        k,
    )
    return _compress_payload(qf, scale, new_err, n)


def compress(
    delta: jax.Array,
    err: jax.Array,
    k_frac: float | jax.Array,
    use_pallas: bool = False,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused EF + blockwise Top-K + int8 for a flat update vector.

    Returns (recon, new_err, payload_bits) where ``recon`` is the
    dequantised sparse update the receiver reconstructs (same length as
    ``delta``) and ``payload_bits`` is the acoustic payload size per the
    paper's accounting (Eq. 31): kept coords * (8 + ceil(log2 d)) bits.
    ``k_frac`` may be traced on the oracle path.
    """
    if use_pallas:
        k = max(1, int(round(_static_scalar(k_frac, "k_frac") * BLOCK_ELEMS)))
        return _compress_pallas(delta, err, k, interpret)
    return _compress_ref(delta, err, _block_k(k_frac))


@functools.partial(
    jax.jit, static_argnames=("n_fog", "k", "quantize", "interpret")
)
def _compress_aggregate_pallas(
    deltas, err, fog_id, weights, n_fog: int, k: int, quantize: bool,
    interpret: bool,
):
    blocks, d = _pad_blocks_batch(deltas)
    err_blocks, _ = _pad_blocks_batch(err)
    fog_blocks, new_err = _fa.compress_aggregate_blocks(
        blocks, err_blocks, fog_id, weights, n_fog, k, quantize, interpret
    )
    fog_sum = fog_blocks.reshape(n_fog, -1)[:, :d]
    return fog_sum, new_err.reshape(deltas.shape[0], -1)[:, :d]


@functools.partial(jax.jit, static_argnames=("n_fog", "quantize"))
def _compress_aggregate_ref(
    deltas, err, fog_id, weights, k, n_fog: int, quantize: bool
):
    blocks, d = _pad_blocks_batch(deltas)
    err_blocks, _ = _pad_blocks_batch(err)
    n_rows = blocks.shape[0]
    fog_blocks, new_err = _ref.compress_aggregate_ref(
        blocks.reshape(n_rows, blocks.shape[1], -1),
        err_blocks.reshape(n_rows, blocks.shape[1], -1),
        fog_id,
        weights,
        n_fog,
        k,
        quantize,
    )
    fog_sum = fog_blocks.reshape(n_fog, -1)[:, :d]
    return fog_sum, new_err.reshape(deltas.shape[0], -1)[:, :d]


def compress_aggregate(
    deltas: jax.Array,    # (N, d) raw per-client flat updates
    err: jax.Array,       # (N, d) error-feedback buffers
    fog_id: jax.Array,    # (N,) int32 cluster assignment
    weights: jax.Array,   # (N,) f32, zeroed for non-participants
    n_fog: int,
    k_frac: float | jax.Array,
    quantize: bool = True,
    use_pallas: bool = False,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused EF Top-K (+ int8) compression and weighted fog accumulation.

    One pass over the (N, d) updates: each client's blockwise
    reconstruction is accumulated directly into its fog cluster's buffer
    instead of being materialised densely and re-read by a segment-sum.

    Returns (fog_sum (n_fog, d) f32 — UNNORMALISED weighted sums
    ``sum_{i in C_m} w_i recon_i``; divide by the per-fog weight totals for
    Eq. 13 — and new_err (N, d)).  ``k_frac`` may be traced on the oracle
    path — the selection is a bisection against the keep count, so swept
    compression ratios batch into one program.
    """
    if use_pallas:
        k = max(1, int(round(_static_scalar(k_frac, "k_frac") * BLOCK_ELEMS)))
        return _compress_aggregate_pallas(
            deltas, err, fog_id, weights, n_fog, k, quantize, interpret
        )
    return _compress_aggregate_ref(
        deltas, err, fog_id, weights, _block_k(k_frac), n_fog, quantize
    )


def wire_k(k_frac) -> int:
    """Concrete per-block slot count for the sparse wire format.

    The wire is shape-bearing (k indices + k codes per block), so unlike
    the bisection keep-count it can NEVER be traced: a swept ``rho_s``
    stays on the dense oracle, a concrete one gets the sparse wire.
    """
    k = max(1, int(round(_static_scalar(k_frac, "k_frac") * BLOCK_ELEMS)))
    return min(k, BLOCK_ELEMS)


@functools.partial(jax.jit, static_argnames=("k", "quantize", "interpret"))
def _compress_wire_pallas(deltas, err, k: int, quantize: bool,
                          interpret: bool):
    blocks, d = _pad_blocks_batch(deltas)
    err_blocks, _ = _pad_blocks_batch(err)
    idx, q, scale, new_err = _fa.compress_wire_blocks(
        blocks, err_blocks, k, quantize, interpret
    )
    return idx, q, scale, new_err.reshape(deltas.shape[0], -1)[:, :d]


@functools.partial(jax.jit, static_argnames=("k", "quantize"))
def _compress_wire_ref(deltas, err, k: int, quantize: bool):
    blocks, d = _pad_blocks_batch(deltas)
    err_blocks, _ = _pad_blocks_batch(err)
    n_rows, nb = blocks.shape[:2]
    idx, q, scale, new_err = _ref.compress_wire_ref(
        blocks.reshape(n_rows, nb, -1),
        err_blocks.reshape(n_rows, nb, -1),
        k,
        quantize,
    )
    return idx, q.astype(jnp.float32), scale, (
        new_err.reshape(n_rows, -1)[:, :d]
    )


def compress_wire(
    deltas: jax.Array,    # (N, d) raw per-client flat updates
    err: jax.Array,       # (N, d) error-feedback buffers
    k_frac: float,
    quantize: bool = True,
    use_pallas: bool = False,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Emit the sparse wire format for a batch of clients.

    Returns (idx (N, nb, k) int32, q (N, nb, k) f32 int8-valued codes,
    scale (N, nb) f32, new_err (N, d)).  Per block the wire is k indices +
    k int8 codes + one f32 scale — the Eq. 31 payload as a real in-memory
    object, ~``rho_s * d`` of the dense row.  ``k_frac`` must be concrete
    (the wire is shape-bearing).
    """
    k = wire_k(k_frac)
    if use_pallas:
        return _compress_wire_pallas(deltas, err, k, quantize, interpret)
    return _compress_wire_ref(deltas, err, k, quantize)


@functools.partial(jax.jit, static_argnames=("n_fog", "d", "interpret"))
def _wire_aggregate_pallas(idx, q, scale, fog_id, weights, n_fog: int,
                           d: int, interpret: bool):
    fog_blocks = _fa.wire_aggregate_blocks(
        idx, q, scale, fog_id, weights, n_fog, interpret
    )
    return fog_blocks.reshape(n_fog, -1)[:, :d]


@functools.partial(jax.jit, static_argnames=("n_fog", "d"))
def _wire_aggregate_ref(idx, q, scale, fog_id, weights, n_fog: int, d: int):
    fog_blocks = _ref.wire_aggregate_ref(
        idx, q, scale, fog_id, weights, n_fog, BLOCK_ELEMS
    )
    return fog_blocks.reshape(n_fog, -1)[:, :d]


def wire_aggregate(
    idx: jax.Array,       # (N, nb, k) int32 wire indices
    q: jax.Array,         # (N, nb, k) codes
    scale: jax.Array,     # (N, nb) f32 per-block scales
    fog_id: jax.Array,    # (N,) int32 cluster assignment
    weights: jax.Array,   # (N,) f32, zeroed for non-participants
    n_fog: int,
    d: int,
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Weighted scatter-accumulate of wire payloads into fog buffers.

    Returns fog_sum (n_fog, d) f32 (unnormalised weighted sums).  The dense
    (N, d) reconstructions never exist — contributions go straight from the
    k-slot wire into the accumulators, so the transient footprint is the
    wire plus O(n_fog * d), independent of N.
    """
    if use_pallas:
        return _wire_aggregate_pallas(
            idx, q, scale, fog_id, weights, n_fog, d, interpret
        )
    return _wire_aggregate_ref(idx, q, scale, fog_id, weights, n_fog, d)


def compress_aggregate_wire(
    deltas: jax.Array,    # (N, d) raw per-client flat updates
    err: jax.Array,       # (N, d) error-feedback buffers
    fog_id: jax.Array,    # (N,) int32 cluster assignment
    weights: jax.Array,   # (N,) f32, zeroed for non-participants
    n_fog: int,
    k_frac: float,
    quantize: bool = True,
    use_pallas: bool = False,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Sparse-wire twin of :func:`compress_aggregate`: emit the wire, then
    scatter-accumulate it, without a dense per-client reconstruction on
    either path.  Same contract — (fog_sum (n_fog, d) unnormalised,
    new_err (N, d)) — equal to the dense path up to f32 summation order.
    ``k_frac`` must be concrete (shape-bearing); traced sweeps keep the
    dense oracle.
    """
    idx, q, scale, new_err = compress_wire(
        deltas, err, k_frac, quantize, use_pallas, interpret
    )
    fog_sum = wire_aggregate(
        idx, q, scale, fog_id, weights, n_fog, deltas.shape[1],
        use_pallas, interpret,
    )
    return fog_sum, new_err


def _fog_weight_totals(fog_id, weights, n_fog: int) -> jax.Array:
    return jnp.sum(
        jnp.where(
            fog_id[None, :] == jnp.arange(n_fog)[:, None],
            weights[None, :].astype(jnp.float32), 0.0,
        ),
        axis=1,
    )


@functools.partial(jax.jit, static_argnames=("n_fog", "mode"))
def _robust_aggregate_ref(recon, fog_id, weights, trim_frac, n_fog, mode):
    return _ref.robust_aggregate_ref(
        recon, fog_id, weights, n_fog, trim_frac, mode
    )


@functools.partial(
    jax.jit, static_argnames=("n_fog", "beta", "mode", "interpret")
)
def _robust_aggregate_pallas(
    recon, fog_id, weights, n_fog: int, beta: float, mode: str,
    interpret: bool,
):
    blocks, d = _pad_blocks_batch(recon)
    out = _ra.robust_aggregate_blocks(
        blocks, fog_id, weights, n_fog, beta, mode, interpret
    )
    return (
        out.reshape(n_fog, -1)[:, :d],
        _fog_weight_totals(fog_id, weights, n_fog),
    )


def robust_aggregate(
    recon: jax.Array,     # (N, d) per-client dequantised reconstructions
    fog_id: jax.Array,    # (N,) int32 cluster assignment
    weights: jax.Array,   # (N,) f32, zeroed for non-participants
    n_fog: int,
    trim_frac: float | jax.Array,
    mode: str = "trimmed",
    use_pallas: bool = False,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Coordinate-wise Byzantine-robust fog aggregation (weighted trimmed
    mean / weighted median) as an alternative to the weighted-sum reduce.

    Returns (fog_out (n_fog, d) f32 — the NORMALISED robust aggregate per
    fog, zeros for empty fogs — and fog_weight (n_fog,), the Eq. 16
    gateway weights).  At ``trim_frac == 0`` this reproduces
    ``fog_sum / max(fog_weight, eps)`` exactly (the equivalence pin).
    ``trim_frac`` may be traced on the oracle path; the Pallas kernel bakes
    it into the kernel body and needs a concrete number.
    """
    if mode not in ("trimmed", "median"):
        raise ValueError(
            f"robust mode must be 'trimmed' or 'median', got {mode!r}"
        )
    if use_pallas:
        beta = min(max(_static_scalar(trim_frac, "trim_frac"), 0.0), 0.4995)
        return _robust_aggregate_pallas(
            recon, fog_id, weights, n_fog, beta, mode, interpret
        )
    return _robust_aggregate_ref(
        recon, fog_id, weights, trim_frac, n_fog, mode
    )


def _pad2(a: jax.Array, rows: int, cols: int) -> jax.Array:
    """Zero-pad a 2-D array up to (rows, cols)."""
    return jnp.zeros((rows, cols), a.dtype).at[: a.shape[0], : a.shape[1]].set(a)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def fused_score(
    x: jax.Array,        # (R, d) telemetry rows
    params: Any,         # autoencoder params: list of {"w", "b"} layers
    tau: jax.Array,      # scalar or (R,) per-row thresholds
    use_pallas: bool = False,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused anomaly scoring: AE forward + squared-L2 reconstruction error
    + threshold compare in one pass over the rows (serving hot path).

    Layout owner for :mod:`repro.kernels.fused_score`: rows are zero-padded
    to whole SCORE_ROWS tiles and every layer dimension to a LANES
    multiple (padded-row thresholds are +inf so their flags stay False).
    Returns (err (R,) f32, flags (R,) bool); the dense reconstruction is
    never materialised in HBM on the kernel path.
    """
    r, d = x.shape
    ws = tuple(layer["w"] for layer in params)
    bs = tuple(layer["b"] for layer in params)
    tau_rows = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (r,))
    if not use_pallas:
        return _ref.fused_score_ref(x, ws, bs, tau_rows)

    rows_pad = max(1, -(-r // _fs.SCORE_ROWS)) * _fs.SCORE_ROWS
    dims = (d,) + tuple(w.shape[1] for w in ws)     # layer output dims
    dims_pad = tuple(max(1, -(-dd // _fs.LANES)) * _fs.LANES for dd in dims)
    x_pad = _pad2(x.astype(jnp.float32), rows_pad, dims_pad[0])
    ws_pad = tuple(
        _pad2(w.astype(jnp.float32), dims_pad[i], dims_pad[i + 1])
        for i, w in enumerate(ws)
    )
    bs_pad = tuple(
        _pad2(b.astype(jnp.float32)[None, :], 1, dims_pad[i + 1])
        for i, b in enumerate(bs)
    )
    tau_pad = jnp.full((rows_pad,), jnp.inf, jnp.float32).at[:r].set(tau_rows)
    err, flag = _fs.score_blocks(
        x_pad, tau_pad.reshape(-1, _fs.SCORE_ROWS), ws_pad, bs_pad, interpret
    )
    return err.reshape(-1)[:r], flag.reshape(-1)[:r] > 0.0


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def fused_score_q8(
    x: jax.Array,        # (R, d) telemetry rows
    qparams: Any,        # quantized AE params: list of {"qw", "sw", "b"}
    tau: jax.Array,      # scalar or (R,) per-row thresholds
    use_pallas: bool = False,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """int8-serving-weight sibling of :func:`fused_score`.

    ``qparams`` holds per-layer int8 weights with per-output-channel f32
    scales (``serving/score.quantize_params``); dequantisation happens
    inside the fused program (jnp oracle and Pallas kernel alike), so the
    resident weight buffers stay int8.  Same padding contract as
    :func:`fused_score` — int8 zero padding dequantises to exact zeros.
    """
    r, d = x.shape
    qws = tuple(layer["qw"] for layer in qparams)
    sws = tuple(layer["sw"] for layer in qparams)
    bs = tuple(layer["b"] for layer in qparams)
    tau_rows = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (r,))
    if not use_pallas:
        return _ref.fused_score_q8_ref(x, qws, sws, bs, tau_rows)

    rows_pad = max(1, -(-r // _fs.SCORE_ROWS)) * _fs.SCORE_ROWS
    dims = (d,) + tuple(q.shape[1] for q in qws)    # layer output dims
    dims_pad = tuple(max(1, -(-dd // _fs.LANES)) * _fs.LANES for dd in dims)
    x_pad = _pad2(x.astype(jnp.float32), rows_pad, dims_pad[0])
    qws_pad = tuple(
        _pad2(q, dims_pad[i], dims_pad[i + 1]) for i, q in enumerate(qws)
    )
    sws_pad = tuple(
        _pad2(s.astype(jnp.float32).reshape(1, -1), 1, dims_pad[i + 1])
        for i, s in enumerate(sws)
    )
    bs_pad = tuple(
        _pad2(b.astype(jnp.float32)[None, :], 1, dims_pad[i + 1])
        for i, b in enumerate(bs)
    )
    tau_pad = jnp.full((rows_pad,), jnp.inf, jnp.float32).at[:r].set(tau_rows)
    err, flag = _fs.score_blocks_q8(
        x_pad, tau_pad.reshape(-1, _fs.SCORE_ROWS), qws_pad, sws_pad, bs_pad,
        interpret,
    )
    return err.reshape(-1)[:r], flag.reshape(-1)[:r] > 0.0


def _ravel_deltas(dws, dbs, n):
    # ravel_pytree order for a list of {"b", "w"} dicts: per layer, bias
    # first (dict keys sort alphabetically), then the row-major weight.
    return jnp.concatenate(
        [part for dw, db in zip(dws, dbs)
         for part in (db.reshape(n, -1), dw.reshape(n, -1))],
        axis=1,
    )


@functools.partial(jax.jit, static_argnames=("use_prox",))
def _local_train_ref(params, data, idx, lr, prox_mu, use_prox: bool):
    ws = tuple(layer["w"] for layer in params)
    bs = tuple(layer["b"] for layer in params)
    n = data.shape[0]
    new_ws, new_bs, losses = jax.vmap(
        lambda xx, ii: _ref.local_train_ref(
            xx, ii, ws, bs, lr, prox_mu, use_prox=use_prox
        )
    )(data, idx)
    dws = [nw - w[None] for nw, w in zip(new_ws, ws)]
    dbs = [nb.reshape(n, 1, -1) - b[None, None] for nb, b in
           zip(new_bs, bs)]
    return _ravel_deltas(dws, dbs, n), losses


@functools.partial(
    jax.jit, static_argnames=("lr", "prox_mu", "interpret")
)
def _local_train_pallas(
    params, data, idx, lr: float, prox_mu: float, interpret: bool
):
    ws = tuple(layer["w"] for layer in params)
    bs = tuple(layer["b"] for layer in params)
    n, _, d = data.shape
    steps, bsz = idx.shape[1], idx.shape[2]
    lanes, sub = _flt.LANES, _flt.SUBLANES
    dims = (d,) + tuple(w.shape[1] for w in ws)
    dims_pad = tuple(max(1, -(-dd // lanes)) * lanes for dd in dims)
    w_pad = max(1, -(-data.shape[1] // lanes)) * lanes
    b_pad = max(1, -(-bsz // sub)) * sub
    s_pad = max(1, -(-steps // lanes)) * lanes
    x_pad = (
        jnp.zeros((n, w_pad, dims_pad[0]), jnp.float32)
        .at[:, : data.shape[1], :d].set(data.astype(jnp.float32))
    )
    idx_t = jnp.swapaxes(idx, 1, 2)                  # (N, bsz, steps)
    idx_pad = (
        jnp.full((n, b_pad, s_pad), -1, jnp.int32)
        .at[:, :bsz, :steps].set(idx_t.astype(jnp.int32))
    )
    ws_pad = tuple(
        _pad2(w.astype(jnp.float32), dims_pad[i], dims_pad[i + 1])
        for i, w in enumerate(ws)
    )
    bs_pad = tuple(
        _pad2(b.astype(jnp.float32)[None, :], 1, dims_pad[i + 1])
        for i, b in enumerate(bs)
    )
    dws_p, dbs_p, loss = _flt.local_train_blocks(
        x_pad, idx_pad, ws_pad, bs_pad, steps, bsz, lr, prox_mu,
        interpret,
    )
    dws = [dw[:, : w.shape[0], : w.shape[1]] for dw, w in zip(dws_p, ws)]
    dbs = [db[:, :, : b.shape[0]] for db, b in zip(dbs_p, bs)]
    return _ravel_deltas(dws, dbs, n), loss[:, 0]


def local_train(
    params: Any,          # autoencoder params: list of {"w", "b"} layers
    data: jax.Array,      # (N, window, D) per-client resident windows
    idx: jax.Array,       # (N, steps, bsz) int32 minibatch row indices
    lr: float,
    prox_mu: float = 0.0,
    use_pallas: bool = False,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused E-epoch local training for a batch of clients (the client
    phase of a federated round in ONE operator).

    Layout owner for :mod:`repro.kernels.fused_local_train`: windows and
    every layer dimension are zero-padded to LANES multiples, batch rows
    to SUBLANES, and the index table is transposed to (bsz, steps) and
    -1-filled so padded rows select nothing.  ``idx`` comes from
    :func:`repro.data.pipeline.multi_epoch_indices`, which makes this
    batch-for-batch identical to ``local_sgd`` over
    ``multi_epoch_batches`` — without the dense (steps, bsz, D) stream.

    ``lr`` / ``prox_mu`` may be traced on the oracle path (config-axis
    sweeps); the Pallas kernel bakes them into the kernel body and needs
    concrete numbers.

    Returns (flat_deltas (N, d) f32 in ``ravel_pytree`` leaf order, i.e.
    exactly ``ravel_pytree(theta_i^E - theta^t)``, and mean_losses (N,)).
    The deltas chain straight into :func:`compress_aggregate`.
    """
    if use_pallas:
        return _local_train_pallas(
            params, data, idx, _static_scalar(lr, "lr"),
            _static_scalar(prox_mu, "prox_mu"), interpret,
        )
    use_prox = not (isinstance(prox_mu, (int, float)) and prox_mu == 0.0)
    return _local_train_ref(params, data, idx, lr, prox_mu, use_prox)


def swa_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    window: int,
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Single-token sliding-window GQA attention (see swa_attention.py)."""
    if use_pallas:
        return _swa.swa_decode_attention(
            q, k_cache, v_cache, cache_len, window, interpret
        )
    return _ref.sliding_window_decode_attention_ref(
        q, k_cache, v_cache, cache_len, window
    )
