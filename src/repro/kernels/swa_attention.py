"""Pallas TPU kernel: sliding-window flash-style decode attention.

Serves the `long_500k` decode path: ONE query token attends to the last
``window`` positions of a KV cache of length up to 524 288.  The kernel
streams KV blocks HBM->VMEM with an online-softmax accumulator so the full
(1 x S) score row never materialises — VMEM holds one (BLOCK_S, Hkv, d) KV
tile plus the (Hq, d) accumulator.

GQA layout: q is (Hkv, G, d); each grid step computes scores for one KV
tile against all query groups.  Grid is 1-D over KV tiles; running max /
denominator / weighted accumulator persist in VMEM scratch across steps
(the standard flash-decoding recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_S = 512

NEG_INF = -1e30


def _swa_decode_kernel(
    cache_len_ref,  # (1,) int32 — replicated to every grid step
    q_ref,          # (hkv, g, d)
    k_ref,          # (BLOCK_S, hkv, d)
    v_ref,          # (BLOCK_S, hkv, d)
    out_ref,        # (hkv, g, d)
    m_ref,          # scratch (hkv, g)   running max
    l_ref,          # scratch (hkv, g)   running denominator
    acc_ref,        # scratch (hkv, g, d) running weighted sum
    *,
    window: int,
    scale: float,
):
    step = pl.program_id(0)
    nsteps = pl.num_programs(0)

    @pl.when(step == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[...].astype(jnp.float32) * scale          # (hkv, g, d)
    k = k_ref[...].astype(jnp.float32)                  # (bs, hkv, d)
    scores = jnp.einsum(
        "hgd,shd->hgs", q, k, preferred_element_type=jnp.float32
    )                                                   # (hkv, g, bs)

    cache_len = cache_len_ref[0]
    pos = step * BLOCK_S + jax.lax.iota(jnp.int32, BLOCK_S)
    valid = (pos < cache_len) & (pos >= cache_len - window)
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    # If every position so far is masked, m stays NEG_INF; clamp the exp
    # arguments so the arithmetic remains finite until real scores arrive.
    alpha = jnp.exp(jnp.clip(m_prev - m_new, -80.0, 0.0))
    p = jnp.exp(jnp.clip(scores - m_new[..., None], -80.0, 0.0))
    p = jnp.where(valid[None, None, :], p, 0.0)

    v = v_ref[...].astype(jnp.float32)                  # (bs, hkv, d)
    pv = jnp.einsum("hgs,shd->hgd", p, v, preferred_element_type=jnp.float32)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new

    @pl.when(step == nsteps - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-20)[..., None]
        out_ref[...] = (acc_ref[...] / denom).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def swa_decode_attention(
    q: jax.Array,           # (hq, d)
    k_cache: jax.Array,     # (s, hkv, d)
    v_cache: jax.Array,     # (s, hkv, d)
    cache_len: jax.Array,   # scalar int32
    window: int,
    interpret: bool = True,
) -> jax.Array:
    """Single-token sliding-window GQA attention; returns (hq, d)."""
    s, hkv, d = k_cache.shape
    hq = q.shape[0]
    g = hq // hkv
    assert hq == g * hkv, (hq, hkv)
    assert s % BLOCK_S == 0, s
    qg = q.reshape(hkv, g, d)
    scale = d ** -0.5
    cache_len = jnp.reshape(cache_len, (1,)).astype(jnp.int32)

    kv_spec = pl.BlockSpec((BLOCK_S, hkv, d), lambda i: (i, 0, 0))
    rep_q = pl.BlockSpec((hkv, g, d), lambda i: (0, 0, 0))
    out_spec = pl.BlockSpec((hkv, g, d), lambda i: (0, 0, 0))
    len_spec = pl.BlockSpec((1,), lambda i: (0,))

    out = pl.pallas_call(
        functools.partial(_swa_decode_kernel, window=window, scale=scale),
        grid=(s // BLOCK_S,),
        in_specs=[len_spec, rep_q, kv_spec, kv_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g, d), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len, qg, k_cache, v_cache)
    return out.reshape(hq, d)
