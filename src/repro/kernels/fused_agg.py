"""Pallas TPU kernel: fused compress-and-aggregate (EF Top-K + int8 +
weighted fog accumulation) — the federated round's hot path in ONE pass.

The unfused pipeline makes three HBM round-trips per round: the compress
kernel writes a dense reconstruction per client, the error buffer, and the
fog segment-sum then re-reads every reconstruction.  This kernel loads each
(client, block) tile once, runs the identical sparsify-quantise-residual
computation in VMEM (bit-for-bit the :func:`repro.kernels.ref.compress_ref`
semantics), and accumulates ``w_i * recon_i`` straight into a per-fog VMEM
accumulator — the dense (N, d) reconstruction never exists in HBM, only the
(n_fog, d) weighted sums and the (N, d) error buffer (which is round state
and has to be written regardless).

Grid layout: ``(nb, N)`` with the client axis INNERMOST, so the fog
accumulator block for column ``j`` stays resident in VMEM across all N
sequential client steps (zeroed at ``i == 0``, flushed when ``j``
advances).  ``fog_id`` / ``weights`` ride in as scalar-prefetch operands
(SMEM), which is what lets the kernel scatter into a dynamic fog row with
``pl.dslice`` — no sorting of clients by cluster required.  The per-fog
block is (n_fog, BLOCK_ROWS, BLOCK_LANES) f32: at the paper's M = N/10
(n_fog <= 20) that is ~640 KiB, comfortably inside VMEM next to the three
32 KiB client tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import BISECT_ITERS
from repro.kernels.topk_ef import BLOCK_LANES, BLOCK_ROWS


def _fused_agg_kernel(
    fog_id_ref,   # (N,) int32  scalar prefetch
    w_ref,        # (N,) f32    scalar prefetch
    delta_ref,    # (1, 1, R, L)
    err_ref,      # (1, 1, R, L)
    fog_ref,      # (n_fog, 1, R, L) accumulator, resident across clients
    new_err_ref,  # (1, 1, R, L)
    *,
    k: int,
    quantize: bool,
):
    i = pl.program_id(1)  # client index (innermost grid axis)

    @pl.when(i == 0)
    def _():
        fog_ref[...] = jnp.zeros_like(fog_ref)

    v = delta_ref[...] + err_ref[...]
    absv = jnp.abs(v)

    # Threshold bisection, identical to ref.bisect_threshold: invariant
    # count(> hi) <= k <= count(> lo).
    lo = jnp.float32(-1.0)
    hi = jnp.max(absv)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        take = jnp.sum(absv > mid) > k
        return jnp.where(take, mid, lo), jnp.where(take, hi, mid)

    lo, hi = jax.lax.fori_loop(0, BISECT_ITERS, body, (lo, hi))
    sparse = jnp.where(absv > hi, v, 0.0)

    if quantize:
        amax = jnp.max(jnp.abs(sparse))
        scale = amax / 127.0
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(sparse / safe), -127, 127).astype(jnp.int8)
        q = jnp.where(scale > 0, q, jnp.zeros_like(q))
        recon = q.astype(jnp.float32) * scale
    else:
        recon = sparse
    new_err_ref[...] = v - recon

    # Scatter-accumulate into this client's fog row (data-dependent index
    # from the prefetched cluster assignment).
    idx = (pl.dslice(fog_id_ref[i], 1), pl.dslice(0, 1),
           slice(None), slice(None))
    acc = pl.load(fog_ref, idx)
    pl.store(fog_ref, idx, acc + w_ref[i] * recon)


def _wire_emit_kernel(
    delta_ref,    # (1, 1, R, L)
    err_ref,      # (1, 1, R, L)
    idx_ref,      # (1, 1, k) int32
    q_ref,        # (1, 1, k) f32 codes (int8-valued when quantizing)
    scale_ref,    # (1, 1) f32
    new_err_ref,  # (1, 1, R, L)
    *,
    k: int,
    quantize: bool,
):
    """Emit the sparse wire for one (client, block) tile.

    Identical selection to :func:`_fused_agg_kernel` (bisection threshold),
    but the survivors are packed into k fixed slots (index + code + one
    per-block scale) instead of a dense masked tile — this is the
    rho_s-sized object the acoustic link actually carries.  Codes are
    emitted as f32 holding exact int8 values: the consumer multiplies by
    the scale either way, and f32 keeps the tile layout trivial.
    """
    v = (delta_ref[...] + err_ref[...]).reshape(-1)
    absv = jnp.abs(v)

    lo = jnp.float32(-1.0)
    hi = jnp.max(absv)
    amax = hi

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        take = jnp.sum(absv > mid) > k
        return jnp.where(take, mid, lo), jnp.where(take, hi, mid)

    lo, hi = jax.lax.fori_loop(0, BISECT_ITERS, body, (lo, hi))
    survive = absv > hi
    rank_key = jnp.where(survive, absv, -1.0)
    _, idx = jax.lax.top_k(rank_key, k)
    kept = jnp.take_along_axis(survive, idx, axis=-1)
    vals = jnp.where(kept, jnp.take_along_axis(v, idx, axis=-1), 0.0)
    if quantize:
        scale = amax / 127.0
        safe = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(vals / safe), -127.0, 127.0)
        recon_vals = jnp.where(scale > 0, q * scale, 0.0)
    else:
        scale = jnp.float32(1.0)
        q = vals
        recon_vals = vals
    idx_ref[...] = idx.reshape(1, 1, k).astype(jnp.int32)
    q_ref[...] = q.reshape(1, 1, k)
    scale_ref[...] = scale.reshape(1, 1)
    # Residual via slot subtraction (one-hot matmul keeps it MXU-friendly):
    # new_err = v - scatter(recon_vals at idx).
    onehot = (idx[:, None] == jnp.arange(v.shape[0])[None, :]).astype(
        jnp.float32
    )
    recon = recon_vals @ onehot
    new_err_ref[...] = (v - recon).reshape(new_err_ref.shape)


def _wire_agg_kernel(
    fog_id_ref,   # (N,) int32  scalar prefetch
    w_ref,        # (N,) f32    scalar prefetch
    idx_ref,      # (1, 1, k) int32
    q_ref,        # (1, 1, k) f32 codes
    scale_ref,    # (1, 1) f32
    fog_ref,      # (n_fog, 1, R, L) accumulator, resident across clients
):
    """Weighted scatter-accumulate straight off the wire.

    Same grid discipline as :func:`_fused_agg_kernel` — ``(nb, N)`` with
    clients innermost so the fog block stays VMEM-resident — but the input
    per step is the k-slot wire, not a dense tile: the dense per-client
    reconstruction never exists even inside the kernel, only the one-hot
    expansion of k slots into the (R, L) accumulator tile.
    """
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _():
        fog_ref[...] = jnp.zeros_like(fog_ref)

    k = idx_ref.shape[-1]
    idx = idx_ref[...].reshape(k)
    contrib_vals = q_ref[...].reshape(k) * scale_ref[0, 0] * w_ref[i]
    onehot = (
        idx[:, None] == jnp.arange(BLOCK_ROWS * BLOCK_LANES)[None, :]
    ).astype(jnp.float32)
    tile = (contrib_vals @ onehot).reshape(1, 1, BLOCK_ROWS, BLOCK_LANES)
    sel = (pl.dslice(fog_id_ref[i], 1), pl.dslice(0, 1),
           slice(None), slice(None))
    acc = pl.load(fog_ref, sel)
    pl.store(fog_ref, sel, acc + tile)


@functools.partial(
    jax.jit, static_argnames=("k_per_block", "quantize", "interpret")
)
def compress_wire_blocks(
    delta: jax.Array,     # (N, nb, BLOCK_ROWS, BLOCK_LANES) f32
    err: jax.Array,       # (N, nb, BLOCK_ROWS, BLOCK_LANES) f32
    k_per_block: int,
    quantize: bool = True,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Emit the sparse wire for every (client, block) tile.

    Returns (idx (N, nb, k) int32, q (N, nb, k) f32 int8-valued codes,
    scale (N, nb) f32, new_err like ``delta``).  The slot axis k is not
    lane-padded — fine under interpret; a compiled-TPU pass would pad it to
    a LANES multiple (hardware gate still pending per ROADMAP).
    """
    n, nb = delta.shape[:2]
    assert delta.shape == (n, nb, BLOCK_ROWS, BLOCK_LANES), delta.shape
    k = min(int(k_per_block), BLOCK_ROWS * BLOCK_LANES)
    tile = pl.BlockSpec((1, 1, BLOCK_ROWS, BLOCK_LANES),
                        lambda i, j: (i, j, 0, 0))
    slot = pl.BlockSpec((1, 1, k), lambda i, j: (i, j, 0))
    sc = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_wire_emit_kernel, k=k, quantize=quantize),
        grid=(n, nb),
        in_specs=[tile, tile],
        out_specs=[slot, slot, sc, tile],
        out_shape=[
            jax.ShapeDtypeStruct((n, nb, k), jnp.int32),
            jax.ShapeDtypeStruct((n, nb, k), jnp.float32),
            jax.ShapeDtypeStruct((n, nb), jnp.float32),
            jax.ShapeDtypeStruct(delta.shape, delta.dtype),
        ],
        interpret=interpret,
    )(delta, err)


@functools.partial(jax.jit, static_argnames=("n_fog", "interpret"))
def wire_aggregate_blocks(
    idx: jax.Array,       # (N, nb, k) int32
    q: jax.Array,         # (N, nb, k) f32 codes
    scale: jax.Array,     # (N, nb) f32
    fog_id: jax.Array,    # (N,) int32
    weights: jax.Array,   # (N,) f32
    n_fog: int,
    interpret: bool = True,
) -> jax.Array:
    """Consume the wire into (n_fog, nb, R, L) weighted sums."""
    n, nb, k = idx.shape
    slot = pl.BlockSpec((1, 1, k), lambda j, i, *_: (i, j, 0))
    sc = pl.BlockSpec((1, 1), lambda j, i, *_: (i, j))
    fog_spec = pl.BlockSpec((n_fog, 1, BLOCK_ROWS, BLOCK_LANES),
                            lambda j, i, *_: (0, j, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb, n),
        in_specs=[slot, slot, sc],
        out_specs=[fog_spec],
    )
    (out,) = pl.pallas_call(
        _wire_agg_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_fog, nb, BLOCK_ROWS, BLOCK_LANES),
                                 jnp.float32),
        ],
        interpret=interpret,
    )(fog_id.astype(jnp.int32), weights.astype(jnp.float32), idx,
      q.astype(jnp.float32), scale)
    return out


@functools.partial(
    jax.jit, static_argnames=("n_fog", "k_per_block", "quantize", "interpret")
)
def compress_aggregate_blocks(
    delta: jax.Array,     # (N, nb, BLOCK_ROWS, BLOCK_LANES) f32
    err: jax.Array,       # (N, nb, BLOCK_ROWS, BLOCK_LANES) f32
    fog_id: jax.Array,    # (N,) int32
    weights: jax.Array,   # (N,) f32
    n_fog: int,
    k_per_block: int,
    quantize: bool = True,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Run the fused kernel over blocked input.

    Returns (fog_sum (n_fog, nb, R, L) f32 — unnormalised weighted sums —
    and new_err, same shape/dtype as ``delta``).
    """
    n, nb = delta.shape[:2]
    assert delta.shape == (n, nb, BLOCK_ROWS, BLOCK_LANES), delta.shape
    tile = pl.BlockSpec((1, 1, BLOCK_ROWS, BLOCK_LANES),
                        lambda j, i, *_: (i, j, 0, 0))
    fog_spec = pl.BlockSpec((n_fog, 1, BLOCK_ROWS, BLOCK_LANES),
                            lambda j, i, *_: (0, j, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb, n),
        in_specs=[tile, tile],
        out_specs=[fog_spec, tile],
    )
    return pl.pallas_call(
        functools.partial(_fused_agg_kernel, k=k_per_block, quantize=quantize),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_fog, nb, BLOCK_ROWS, BLOCK_LANES),
                                 jnp.float32),
            jax.ShapeDtypeStruct(delta.shape, delta.dtype),
        ],
        interpret=interpret,
    )(fog_id.astype(jnp.int32), weights.astype(jnp.float32), delta, err)
