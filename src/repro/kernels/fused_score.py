"""Pallas TPU kernel: fused anomaly scoring (AE forward + reconstruction
error + threshold compare) — the serving hot path in ONE pass.

The unfused serving pipeline makes three HBM round-trips per telemetry
batch: the autoencoder forward writes a dense (R, d) reconstruction, the
error reduction re-reads it (and the input) to produce the per-sample
squared-L2 errors, and the threshold compare re-reads those.  This kernel
loads each row tile once, runs encode -> decode -> error -> compare
entirely in VMEM (bit-compatible with :func:`repro.kernels.ref.
fused_score_ref`, i.e. ``models/autoencoder.apply`` semantics), and writes
only the (R,) errors and flags — the dense reconstruction never exists in
HBM.

Layout: ops.py pads the row count to a multiple of SCORE_ROWS and every
layer dimension (the feature dim included) to a multiple of LANES = 128,
zero-filling weights/biases.  Zero padding is exact: tanh(0) = 0, padded
weight rows/columns contribute nothing, and padded feature columns add
(0 - 0)^2 to the error.  The grid runs one step per row tile; the padded
layer parameters ride along as whole-array blocks (index map pinned to the
origin) so they stay resident in VMEM across the whole sweep — at the
paper's 32-16-8-16-32 autoencoder that is four 128x128 f32 matrices,
~256 KiB next to a 64 KiB row tile.  Each (SCORE_ROWS, 128) @ (128, 128)
layer step is MXU-shaped.  Thresholds arrive pre-broadcast per row (the
serving layer maps per-fog taus onto rows), tiled (1, SCORE_ROWS) like the
outputs so every block keeps the 128-lane minor dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SCORE_ROWS = 128   # telemetry rows per grid step
LANES = 128        # layer-dimension padding unit (VPU lane count)


def _fused_score_kernel(x_ref, tau_ref, *refs, n_layers: int):
    err_ref, flag_ref = refs[-2], refs[-1]
    x = x_ref[...].astype(jnp.float32)            # (SCORE_ROWS, d_pad)
    h = x
    for li in range(n_layers):
        w = refs[2 * li][...]                     # (d_in_pad, d_out_pad)
        b = refs[2 * li + 1][...]                 # (1, d_out_pad)
        h = jnp.dot(h, w, preferred_element_type=jnp.float32) + b
        if li < n_layers - 1:
            h = jnp.tanh(h)
    diff = x - h
    err = jnp.sum(diff * diff, axis=-1)           # (SCORE_ROWS,)
    err_ref[...] = err[None, :]
    flag_ref[...] = (err[None, :] > tau_ref[...]).astype(jnp.float32)


def _fused_score_q8_kernel(x_ref, tau_ref, *refs, n_layers: int):
    """int8-weight variant: each layer ships (q int8, scale (1, d_out),
    bias) and is dequantised per output channel IN VMEM right before its
    matmul — HBM (and the resident weight blocks) only ever hold int8,
    a 4x cut of the weight bytes that stay live across the row sweep."""
    err_ref, flag_ref = refs[-2], refs[-1]
    x = x_ref[...].astype(jnp.float32)            # (SCORE_ROWS, d_pad)
    h = x
    for li in range(n_layers):
        q = refs[3 * li][...]                     # (d_in_pad, d_out_pad) i8
        s = refs[3 * li + 1][...]                 # (1, d_out_pad) f32
        b = refs[3 * li + 2][...]                 # (1, d_out_pad) f32
        w = q.astype(jnp.float32) * s             # per-channel dequant
        h = jnp.dot(h, w, preferred_element_type=jnp.float32) + b
        if li < n_layers - 1:
            h = jnp.tanh(h)
    diff = x - h
    err = jnp.sum(diff * diff, axis=-1)           # (SCORE_ROWS,)
    err_ref[...] = err[None, :]
    flag_ref[...] = (err[None, :] > tau_ref[...]).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def score_blocks_q8(
    x: jax.Array,                  # (R_pad, d_pad) f32, R_pad % SCORE_ROWS == 0
    tau: jax.Array,                # (nb, SCORE_ROWS) f32 (+inf on padded rows)
    qws: tuple[jax.Array, ...],    # padded int8 weights, (d_in_pad, d_out_pad)
    sws: tuple[jax.Array, ...],    # padded scales, (1, d_out_pad) f32
    bs: tuple[jax.Array, ...],     # padded biases, (1, d_out_pad) f32
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused score sweep with int8-resident weights (see the q8 kernel).

    Same grid/layout contract as :func:`score_blocks`; zero-padded int8
    weight rows/columns dequantise to exact zeros (0 * scale), so padding
    stays exact."""
    r_pad, d_pad = x.shape
    assert r_pad % SCORE_ROWS == 0 and d_pad % LANES == 0, x.shape
    nb = r_pad // SCORE_ROWS
    assert tau.shape == (nb, SCORE_ROWS), tau.shape

    x_spec = pl.BlockSpec((SCORE_ROWS, d_pad), lambda i: (i, 0))
    row_spec = pl.BlockSpec((1, SCORE_ROWS), lambda i: (i, 0))
    wb_specs = []
    for q, s, b in zip(qws, sws, bs):
        wb_specs.append(pl.BlockSpec(q.shape, lambda i: (0, 0)))
        wb_specs.append(pl.BlockSpec(s.shape, lambda i: (0, 0)))
        wb_specs.append(pl.BlockSpec(b.shape, lambda i: (0, 0)))
    return pl.pallas_call(
        functools.partial(_fused_score_q8_kernel, n_layers=len(qws)),
        grid=(nb,),
        in_specs=[x_spec, row_spec, *wb_specs],
        out_specs=[row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nb, SCORE_ROWS), jnp.float32),
            jax.ShapeDtypeStruct((nb, SCORE_ROWS), jnp.float32),
        ],
        interpret=interpret,
    )(x, tau, *[a for qsb in zip(qws, sws, bs) for a in qsb])


@functools.partial(jax.jit, static_argnames=("interpret",))
def score_blocks(
    x: jax.Array,                  # (R_pad, d_pad) f32, R_pad % SCORE_ROWS == 0
    tau: jax.Array,                # (nb, SCORE_ROWS) f32 (+inf on padded rows)
    ws: tuple[jax.Array, ...],     # padded weights, (d_in_pad, d_out_pad)
    bs: tuple[jax.Array, ...],     # padded biases, (1, d_out_pad)
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Run the fused score kernel over padded row tiles.

    Returns (err (nb, SCORE_ROWS) f32, flag (nb, SCORE_ROWS) f32 0/1 —
    float so every output block shares the f32 tiling; ops.py casts back
    to bool after unpadding).
    """
    r_pad, d_pad = x.shape
    assert r_pad % SCORE_ROWS == 0 and d_pad % LANES == 0, x.shape
    nb = r_pad // SCORE_ROWS
    assert tau.shape == (nb, SCORE_ROWS), tau.shape

    x_spec = pl.BlockSpec((SCORE_ROWS, d_pad), lambda i: (i, 0))
    row_spec = pl.BlockSpec((1, SCORE_ROWS), lambda i: (i, 0))
    wb_specs = []
    for w, b in zip(ws, bs):
        wb_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        wb_specs.append(pl.BlockSpec(b.shape, lambda i: (0, 0)))
    return pl.pallas_call(
        functools.partial(_fused_score_kernel, n_layers=len(ws)),
        grid=(nb,),
        in_specs=[x_spec, row_spec, *wb_specs],
        out_specs=[row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nb, SCORE_ROWS), jnp.float32),
            jax.ShapeDtypeStruct((nb, SCORE_ROWS), jnp.float32),
        ],
        interpret=interpret,
    )(x, tau, *[a for wb in zip(ws, bs) for a in wb])
