"""Pallas TPU kernel: fused error-feedback block Top-K sparsification.

One HBM round-trip per block: load (delta, err) tile into VMEM, form
v = delta + err, select a magnitude threshold by vectorised bisection
(the TPU-native replacement for CUDA radix-select — compare + reduce only,
VPU-friendly, no data-dependent shuffles), emit the sparsified tile and the
new error tile.

Layout: the flat update vector is reshaped by ops.py to (nb, BLOCK_ROWS,
BLOCK_LANES) so each grid step works on an (8k-element) VREG-aligned tile:
BLOCK_LANES = 128 matches the VPU lane count, BLOCK_ROWS = 64 gives
64x128 = 8192 f32 = 32 KiB per input tile (3 tiles in flight = 96 KiB,
comfortably inside the ~16 MiB VMEM with room for double buffering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import BISECT_ITERS

BLOCK_ROWS = 64
BLOCK_LANES = 128
BLOCK_ELEMS = BLOCK_ROWS * BLOCK_LANES


def _topk_ef_kernel(delta_ref, err_ref, sparse_ref, new_err_ref, *, k: int):
    v = delta_ref[...] + err_ref[...]
    # Bisect in f32 regardless of the storage dtype (bf16 tiles included).
    absv = jnp.abs(v).astype(jnp.float32)

    # Bisection on the keep-threshold over the whole tile.  Invariant:
    # count(> hi) <= k <= count(> lo).
    lo = jnp.float32(-1.0)
    hi = jnp.max(absv)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum(absv > mid)
        take = cnt > k
        return jnp.where(take, mid, lo), jnp.where(take, hi, mid)

    lo, hi = jax.lax.fori_loop(0, BISECT_ITERS, body, (lo, hi))
    mask = absv > hi
    sparse = jnp.where(mask, v, jnp.zeros_like(v))
    sparse_ref[...] = sparse.astype(sparse_ref.dtype)
    new_err_ref[...] = (v - sparse).astype(new_err_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k_per_block", "interpret"))
def topk_ef_blocks(
    delta: jax.Array,
    err: jax.Array,
    k_per_block: int,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Run the kernel over (nb, BLOCK_ROWS, BLOCK_LANES) blocked input."""
    nb = delta.shape[0]
    assert delta.shape == (nb, BLOCK_ROWS, BLOCK_LANES), delta.shape
    spec = pl.BlockSpec((1, BLOCK_ROWS, BLOCK_LANES), lambda i: (i, 0, 0))
    out_shape = [
        jax.ShapeDtypeStruct(delta.shape, delta.dtype),
        jax.ShapeDtypeStruct(delta.shape, delta.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_topk_ef_kernel, k=k_per_block),
        grid=(nb,),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(delta, err)
