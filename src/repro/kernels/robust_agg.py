"""Pallas TPU kernel: coordinate-wise Byzantine-robust fog aggregation
(weighted trimmed mean / weighted median) over per-client reconstructions.

Composes with the fused compress path: when ``robust != "mean"`` the round
loop runs :func:`repro.kernels.ops.compress_aggregate` with per-client
segments (``fog_id = arange(N)``, unit weights — the trick the async family
already uses), which keeps each client's dequantised reconstruction
addressable while the EF buffer math stays bit-identical to the mean path.
This kernel then reduces those (N, d) reconstructions per fog with the
trimmed/median statistic instead of the weighted sum.

The statistic is the sort-free tie-group interval-overlap formulation of
:func:`repro.kernels.ref.robust_aggregate_ref` (the oracle — see its
docstring for the math): per coordinate, member i's effective weight is the
overlap of its weight interval ``[A_i, A_i + g_i)`` with the kept band
``[beta W, (1 - beta) W]``, rescaled by ``w_i / g_i``.  No data-dependent
gathers, no sorting network — only masked reductions, which is exactly what
vectorises on the VPU.  At ``beta == 0`` the overlap ratio is exactly 1, so
the kernel degrades to the plain weighted mean (the equivalence pin).

Grid layout: ``(nb, n_fog)`` with the fog axis INNERMOST, so the full
(N, 1, R, L) column of client reconstructions stays resident in VMEM while
every fog reduces it (at the paper's N = 200 that is ~800 KiB — fine next
to the accumulators).  ``fog_id`` / ``weights`` ride in as scalar-prefetch
operands (SMEM); membership masking is a scalar select per client, so no
one-hot matrix is materialised.  The O(N^2) pairwise rank pass runs as two
nested ``fori_loop``s over (R, L) tiles — each iteration is a full VPU tile
op, and N is the fleet size (tens to low hundreds), not the model dim.

``beta`` and the median flag are baked into the kernel body (static), like
``lr``/``k`` in the other kernels; traced trim fractions are oracle-only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.topk_ef import BLOCK_LANES, BLOCK_ROWS


def _robust_agg_kernel(
    fog_id_ref,   # (N,) int32  scalar prefetch
    w_ref,        # (N,) f32    scalar prefetch
    v_ref,        # (N, 1, R, L) all client reconstructions for this column
    out_ref,      # (1, 1, R, L) this fog's robust aggregate
    *,
    n: int,
    beta: float,
    median: bool,
):
    m = pl.program_id(1)  # fog index (innermost grid axis)

    def member_w(k):
        # Membership-masked weight: scalar select against the prefetched
        # cluster assignment (zero weight excludes non-members entirely).
        return jnp.where(fog_id_ref[k] == m, w_ref[k], jnp.float32(0.0))

    big_w = jax.lax.fori_loop(
        0, n, lambda k, acc: acc + member_w(k), jnp.float32(0.0)
    )

    def client_tile(k):
        return pl.load(
            v_ref,
            (pl.dslice(k, 1), pl.dslice(0, 1), slice(None), slice(None)),
        )

    def outer(i, carry):
        num, den = carry
        w_i = member_w(i)
        v_i = client_tile(i)

        def inner(k, ag):
            a, g = ag
            w_k = member_w(k)
            v_k = client_tile(k)
            a = a + jnp.where(v_k < v_i, w_k, 0.0)   # member weight below v_i
            g = g + jnp.where(v_k == v_i, w_k, 0.0)  # member weight tied at v_i
            return a, g

        zero = jnp.zeros_like(v_i)
        a, g = jax.lax.fori_loop(0, n, inner, (zero, zero))
        g_safe = jnp.maximum(g, 1e-30)
        if median:
            half = 0.5 * big_w
            ratio = jnp.where((a < half) & (half <= a + g), 1.0 / g_safe, 0.0)
        else:
            lo = jnp.maximum(a, beta * big_w)
            hi = jnp.minimum(a + g, (1.0 - beta) * big_w)
            # overlap == g exactly at beta 0 -> ratio == 1.0 -> eff == w_i.
            ratio = jnp.maximum(hi - lo, 0.0) / g_safe
        eff = w_i * ratio
        return num + eff * v_i, den + eff

    zero = jnp.zeros((1, 1, BLOCK_ROWS, BLOCK_LANES), jnp.float32)
    num, den = jax.lax.fori_loop(0, n, outer, (zero, zero))
    out_ref[...] = num / jnp.maximum(den, 1e-12)


@functools.partial(
    jax.jit, static_argnames=("n_fog", "beta", "mode", "interpret")
)
def robust_aggregate_blocks(
    v: jax.Array,         # (N, nb, BLOCK_ROWS, BLOCK_LANES) f32 recons
    fog_id: jax.Array,    # (N,) int32
    weights: jax.Array,   # (N,) f32, zeroed for non-participants
    n_fog: int,
    beta: float,
    mode: str = "trimmed",
    interpret: bool = True,
) -> jax.Array:
    """Run the robust-aggregation kernel over blocked reconstructions.

    Returns the NORMALISED per-fog robust aggregate,
    (n_fog, nb, R, L) f32 — zeros for empty fogs.
    """
    n, nb = v.shape[:2]
    assert v.shape == (n, nb, BLOCK_ROWS, BLOCK_LANES), v.shape
    col = pl.BlockSpec((n, 1, BLOCK_ROWS, BLOCK_LANES),
                       lambda j, m, *_: (0, j, 0, 0))
    out_spec = pl.BlockSpec((1, 1, BLOCK_ROWS, BLOCK_LANES),
                            lambda j, m, *_: (m, j, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb, n_fog),
        in_specs=[col],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        functools.partial(
            _robust_agg_kernel, n=n, beta=beta, median=(mode == "median")
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_fog, nb, BLOCK_ROWS, BLOCK_LANES), jnp.float32
        ),
        interpret=interpret,
    )(fog_id.astype(jnp.int32), weights.astype(jnp.float32),
      v.astype(jnp.float32))
