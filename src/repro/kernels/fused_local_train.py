"""Pallas TPU kernel: fused local training — one client's ENTIRE federated
work item (E epochs of minibatch SGD on the paper autoencoder, Eq. 12) in a
single VMEM-resident launch.

The unfused client phase is the last big HBM spender in the round loop:
``data/pipeline.multi_epoch_batches`` gathers a dense ``(E * nb, bs, D)``
batch stream per client per round (``E * nb * bs`` rows re-read from a
``window``-row buffer), and ``optim/sgd.local_sgd`` then scans one
``value_and_grad`` + tree-update per minibatch over it — on the engine's
``(seed, deployment)`` trial grid that is ``O(S * P * N * E * window * D)``
gather traffic before a single useful FLOP.  This kernel instead keeps ONE
copy of the client's ``(window, D)`` window and the broadcast params
resident in VMEM for the whole local phase: each grid step (= one client)
loads its window once, then for every minibatch *indexes* the resident
rows (a one-hot selector matmul — the TPU-native gather), runs forward +
manual backward + the SGD/FedProx update fused, and finally writes only the
per-layer parameter DELTAS ``theta_i^E - theta^t`` and the mean loss.  The
dense batch stream never exists anywhere; only the tiny ``(steps, bs)``
int32 permutation table (from ``data/pipeline.multi_epoch_indices``) rides
along, so the client phase chains straight into the fused
compress-and-aggregate kernel and the whole sensor side of a round is two
launches with no dense intermediates.

Layout: ops.py pads the window and every layer dimension (feature dim
included) to LANES = 128 and the batch rows to SUBLANES = 8, zero-filling
data/weights/biases and -1-filling index padding.  Zero padding is exact
end to end: padded window rows are never selected (indices only address
real rows), padded batch rows select nothing (all-zero one-hot row) and
are masked out of the loss/gradient, and padded layer lanes stay
identically zero through forward, backward, and the update (tanh(0) = 0,
zero weight rows/columns propagate zeros, so the emitted deltas are zero
there).  The broadcast params ride as whole-array blocks with the index
map pinned to the origin — resident across all N sequential client steps
— and per-client working params live in VMEM scratch, re-seeded from the
broadcast blocks at each grid step.  At the paper's 32-16-8-16-32
autoencoder that is four 128x128 f32 anchor matrices + the same again in
scratch (~512 KiB) next to a (window, 128) data tile.  Every per-step
matmul — the one-hot gather, the four layer GEMMs, and their transposed
backward partners — is MXU-shaped.

FedProx (``mu > 0``) is free here: the anchor ``theta^t`` the proximal
term needs is exactly the resident broadcast block, so the kernel adds
``mu * (theta - anchor)`` to the gradient without any extra traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128      # layer-dimension / window padding unit (VPU lane count)
SUBLANES = 8     # batch-row padding unit (f32 sublane count)


def _local_train_kernel(
    x_ref,        # (1, W_pad, D_pad) this client's data window
    idx_ref,      # (1, B_pad, S_pad) int32 minibatch indices, -1 = padding
    *refs,
    n_layers: int,
    steps: int,
    batch: int,
    lr: float,
    mu: float,
):
    nl = n_layers
    w_refs = [refs[2 * li] for li in range(nl)]          # anchor theta^t
    b_refs = [refs[2 * li + 1] for li in range(nl)]
    outs = refs[2 * nl:]
    dw_refs = [outs[2 * li] for li in range(nl)]
    db_refs = [outs[2 * li + 1] for li in range(nl)]
    loss_ref = outs[2 * nl]
    scratch = outs[2 * nl + 1:]
    sw = [scratch[2 * li] for li in range(nl)]           # working theta
    sb = [scratch[2 * li + 1] for li in range(nl)]

    # Re-seed the working params from the resident broadcast blocks: every
    # client starts its local phase from the same global theta^t.
    for li in range(nl):
        sw[li][...] = w_refs[li][...]
        sb[li][...] = b_refs[li][...]

    x = x_ref[0]                                         # (W_pad, D_pad)
    idx_all = idx_ref[0]                                 # (B_pad, S_pad)
    w_pad = x.shape[0]
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (1, w_pad), 1)
    inv_b = 1.0 / batch

    def step(s, loss_sum):
        idx_col = jax.lax.dynamic_slice(
            idx_all, (0, s), (idx_all.shape[0], 1)
        )                                                # (B_pad, 1) int32
        row_mask = (idx_col >= 0).astype(jnp.float32)    # (B_pad, 1)
        # Gather-as-matmul: one-hot selector rows pick the minibatch out of
        # the resident window (padding rows select nothing).
        sel = (idx_col == iota_w).astype(jnp.float32)    # (B_pad, W_pad)
        xb = jnp.dot(sel, x, preferred_element_type=jnp.float32)

        ws_now = [sw[li][...] for li in range(nl)]
        bs_now = [sb[li][...] for li in range(nl)]
        acts = [xb]
        h = xb
        for li in range(nl):
            h = jnp.dot(h, ws_now[li], preferred_element_type=jnp.float32)
            h = h + bs_now[li]
            if li < nl - 1:
                h = jnp.tanh(h)
            acts.append(h)

        # loss = mean over real rows of sum_j (x - recon)^2; padded batch
        # rows reconstruct the bias stack from a zero input, so mask them.
        diff = (h - xb) * row_mask
        loss = jnp.sum(diff * diff) * inv_b
        g = (2.0 * inv_b) * diff                         # dL/dz_last
        for li in range(nl - 1, -1, -1):
            a_prev = acts[li]
            dw = jax.lax.dot_general(
                a_prev, g, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            db = jnp.sum(g, axis=0, keepdims=True)
            if li > 0:
                # tanh'(z_{l-1}) = 1 - a_prev^2 (a_prev is the tanh output)
                g = jax.lax.dot_general(
                    g, ws_now[li], (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ) * (1.0 - a_prev * a_prev)
            if mu != 0.0:
                dw = dw + mu * (ws_now[li] - w_refs[li][...])
                db = db + mu * (bs_now[li] - b_refs[li][...])
            sw[li][...] = ws_now[li] - lr * dw
            sb[li][...] = bs_now[li] - lr * db
        return loss_sum + loss

    loss_sum = jax.lax.fori_loop(0, steps, step, jnp.float32(0.0))

    for li in range(nl):
        dw_refs[li][0] = sw[li][...] - w_refs[li][...]
        db_refs[li][0] = sb[li][...] - b_refs[li][...]
    loss_ref[0, 0] = loss_sum / steps


@functools.partial(
    jax.jit, static_argnames=("steps", "batch", "lr", "mu", "interpret")
)
def local_train_blocks(
    x: jax.Array,                  # (N, W_pad, D_pad) f32 client windows
    idx: jax.Array,                # (N, B_pad, S_pad) int32, -1 padding
    ws: tuple[jax.Array, ...],     # padded weights, (d_in_pad, d_out_pad)
    bs: tuple[jax.Array, ...],     # padded biases, (1, d_out_pad)
    steps: int,                    # real SGD steps (E * nb), <= S_pad
    batch: int,                    # real minibatch rows, <= B_pad
    lr: float,
    mu: float = 0.0,
    interpret: bool = True,
) -> tuple[list[jax.Array], list[jax.Array], jax.Array]:
    """Run the fused local-train kernel over padded per-client tiles.

    Grid = one step per client; the broadcast params stay resident across
    the sweep.  Returns (dws [(N, d_in_pad, d_out_pad)] per layer,
    dbs [(N, 1, d_out_pad)] per layer, loss (N, 1) f32) — the per-layer
    parameter deltas and mean local loss; ops.py slices off the padding
    and assembles the flat ``ravel_pytree``-ordered delta.
    """
    n, w_pad, d_pad = x.shape
    assert w_pad % LANES == 0 and d_pad % LANES == 0, x.shape
    b_pad, s_pad = idx.shape[1], idx.shape[2]
    assert idx.shape[0] == n and s_pad % LANES == 0, idx.shape
    assert 0 < steps <= s_pad and 0 < batch <= b_pad, (steps, batch)

    x_spec = pl.BlockSpec((1, w_pad, d_pad), lambda i: (i, 0, 0))
    idx_spec = pl.BlockSpec((1, b_pad, s_pad), lambda i: (i, 0, 0))
    wb_specs = []
    for w, b in zip(ws, bs):
        wb_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        wb_specs.append(pl.BlockSpec(b.shape, lambda i: (0, 0)))
    out_specs, out_shape, scratch = [], [], []
    for w, b in zip(ws, bs):
        out_specs.append(pl.BlockSpec((1, *w.shape), lambda i: (i, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((n, *w.shape), jnp.float32))
        out_specs.append(pl.BlockSpec((1, *b.shape), lambda i: (i, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((n, *b.shape), jnp.float32))
        scratch.append(pltpu.VMEM(w.shape, jnp.float32))
        scratch.append(pltpu.VMEM(b.shape, jnp.float32))
    out_specs.append(pl.BlockSpec((1, 1), lambda i: (i, 0)))
    out_shape.append(jax.ShapeDtypeStruct((n, 1), jnp.float32))

    outs = pl.pallas_call(
        functools.partial(
            _local_train_kernel,
            n_layers=len(ws), steps=steps, batch=batch,
            lr=float(lr), mu=float(mu),
        ),
        grid=(n,),
        in_specs=[x_spec, idx_spec, *wb_specs],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(x, idx.astype(jnp.int32), *[a for wb in zip(ws, bs) for a in wb])
    dws = [outs[2 * li] for li in range(len(ws))]
    dbs = [outs[2 * li + 1] for li in range(len(ws))]
    return dws, dbs, outs[-1]
