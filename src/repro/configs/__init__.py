"""Architecture config registry: ``repro.configs.get("<arch>")``.

Each module exports CONFIG (exact published spec, source cited in its
docstring) and REDUCED (<=2 layers, d_model<=512, <=4 experts) for the CPU
smoke tests.
"""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401

ARCHS = (
    "whisper_medium",
    "qwen3_14b",
    "qwen2_moe_a2_7b",
    "grok_1_314b",
    "gemma2_27b",
    "internvl2_26b",
    "llama3_8b",
    "recurrentgemma_2b",
    "mamba2_2_7b",
    "qwen3_32b",
    "paper_ae",
)

_ALIASES = {
    "whisper-medium": "whisper_medium",
    "qwen3-14b": "qwen3_14b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "grok-1-314b": "grok_1_314b",
    "gemma2-27b": "gemma2_27b",
    "internvl2-26b": "internvl2_26b",
    "llama3-8b": "llama3_8b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mamba2-2.7b": "mamba2_2_7b",
    "qwen3-32b": "qwen3_32b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get(name: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.REDUCED if reduced else mod.CONFIG


def model_archs() -> tuple[str, ...]:
    """The ten assigned transformer/SSM architectures (excludes paper_ae)."""
    return tuple(a for a in ARCHS if a != "paper_ae")
