"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — local+global alternating (window 4096), attn softcap 50,
logit softcap 30, sandwich post-norms, sqrt(d) embed scaling
[arXiv:2408.00118].

long_500k: runs with every layer windowed (the beyond-model-card
sub-quadratic serving variant; DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    rope_theta=10000.0,
    logit_softcap=30.0,
    attn_softcap=50.0,
    sliding_window=4096,
    local_global_period=2,       # L, G, L, G, ...
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    query_scale=(4608 / 32) ** -0.5,
    supports_long_context=True,
    long_context_window=4096,
)

REDUCED = CONFIG.replace(
    name="gemma2-27b-reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=512, head_dim=64, sliding_window=64, loss_chunks=1,
    query_scale=(256 / 4) ** -0.5,
)
