"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) per-expert
d_ff=32768, vocab=131072, MoE 8 experts top-2 [hf:xai-org/grok-1]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    moe_d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    n_experts=8,
    n_experts_per_tok=2,
    n_shared_experts=0,
)

REDUCED = CONFIG.replace(
    name="grok-1-reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    moe_d_ff=512, vocab_size=512, head_dim=64,
    n_experts=4, n_experts_per_tok=2, loss_chunks=1,
)
