"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1, head_dim
256) d_ff=7680 vocab=256000 — RG-LRU + local attention (window 2048),
pattern (rec, rec, attn) [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    sliding_window=2048,
    block_pattern=("rec", "rec", "attn"),
    conv_width=4,
    rglru_c=8.0,
    embed_scale=True,
    tie_embeddings=True,
    supports_long_context=True,
    long_context_window=2048,
)

REDUCED = CONFIG.replace(
    name="recurrentgemma-reduced",
    n_layers=3, d_model=256, n_heads=2, n_kv_heads=1, d_ff=512,
    vocab_size=512, head_dim=128, sliding_window=64, loss_chunks=1,
)
