"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 [arXiv:2407.21783]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
)

REDUCED = CONFIG.replace(
    name="llama3-8b-reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=512, head_dim=64, loss_chunks=1,
)
