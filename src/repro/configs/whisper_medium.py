"""whisper-medium [audio enc-dec]: 24 encoder + 24 decoder layers,
d_model=1024 16H (kv=16) d_ff=4096 vocab=51865; conv mel frontend STUBBED
(input_specs supplies (B, 1500, 1024) frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,          # decoder layers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    n_audio_frames=1500,
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    name="whisper-reduced",
    n_layers=2, n_enc_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=512, vocab_size=512, head_dim=64, n_audio_frames=64, loss_chunks=1,
)
