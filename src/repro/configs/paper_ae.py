"""The paper's own anomaly-detection autoencoder (Table II):
32 -> 16 -> 8 -> 16 -> 32, ~1 352 parameters, D=32 features."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class AEConfig:
    name: str = "paper-ae"
    feature_dim: int = 32
    hidden: tuple = (16, 8, 16)
    local_epochs: int = 5
    lr: float = 0.01
    rho_s: float = 0.05
    quant_bits: int = 8


CONFIG = AEConfig()
REDUCED = AEConfig(name="paper-ae-reduced", feature_dim=8, hidden=(4, 2, 4))
