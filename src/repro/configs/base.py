"""Configuration system: architecture + input-shape configs.

Every assigned architecture gets a module ``src/repro/configs/<id>.py``
exporting ``CONFIG`` (exact published spec, source cited) and ``REDUCED``
(the <=2-layer, d_model<=512 smoke variant).  ``repro.configs.get(name)``
resolves either by arch id; ``--arch`` flags on the launchers go through it.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # gemma2-style extras
    logit_softcap: float | None = None
    attn_softcap: float | None = None
    sliding_window: int | None = None   # window size of local layers
    local_global_period: int = 0        # every k-th layer is GLOBAL (0 = all global)
    post_norms: bool = False            # gemma2 sandwich norms
    query_scale: float | None = None    # gemma2 query_pre_attn_scalar
    embed_scale: bool = False           # gemma-style sqrt(d) embedding scaling
    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int | None = None         # per-expert hidden (defaults to d_ff)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_width: int = 4
    # hybrid (recurrentgemma): block pattern, e.g. ("rec", "rec", "attn")
    block_pattern: tuple[str, ...] = ()
    rglru_c: float = 8.0
    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_audio_frames: int = 1500          # conv-frontend output length (stub)
    # vlm
    n_visual_tokens: int = 0            # prefix patch-embedding tokens (stub)
    # numerics
    dtype: Any = jnp.bfloat16
    # layer-stack scan unroll (dry-run cost analysis uses 1 vs 2 to recover
    # true per-layer cost: XLA's cost_analysis counts a while body ONCE,
    # whatever the trip count — see launch/dryrun.py)
    scan_unroll: int = 1
    # long-context: archs that can serve long_500k (sub-quadratic path)
    supports_long_context: bool = False
    long_context_window: int = 4096
    # training
    learning_rate: float = 3e-4
    remat: bool = True
    loss_chunks: int = 8

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def moe_hidden(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    def param_count(self) -> int:
        """Approximate parameter count (reported in EXPERIMENTS.md)."""
        d, hd = self.d_model, self.head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            per = (
                d * (2 * d_in + 2 * self.ssm_state + nh)   # in_proj(z,x,B,C,dt)
                + self.conv_width * (d_in + 2 * self.ssm_state)
                + d_in * d                                  # out_proj
                + d_in + 2 * nh                             # norm, A, D
            )
            return self.n_layers * per + 2 * self.vocab_size * d
        mlp = 3 * d * self.d_ff
        if self.family == "moe":
            mlp = 3 * d * self.moe_hidden * (self.n_experts + self.n_shared_experts)
            mlp += d * self.n_experts                       # router
        per = attn + mlp + 2 * d
        total = self.n_layers * per
        if self.family == "encdec":
            total += self.n_enc_layers * (attn + 3 * d * self.d_ff + 2 * d)
            total += self.n_layers * attn                   # cross-attention
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense_like = self.replace(family="dense", d_ff=0).param_count()
        active_mlp = (
            3 * d * self.moe_hidden
            * (self.n_experts_per_tok + self.n_shared_experts)
        )
        return dense_like + self.n_layers * active_mlp


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
