"""internvl2-26b [vlm]: InternLM2-20B language backbone — 48L d_model=6144
48H (GQA kv=8) d_ff=16384 vocab=92553 — consuming stubbed InternViT patch
embeddings (256 visual tokens scattered into the sequence prefix)
[arXiv:2404.16821].  The ViT-6B vision tower + MLP projector is the
assignment's sanctioned stub: input_specs supplies (B, 256, d_model)
pre-projected patch embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    n_visual_tokens=256,
    rope_theta=1000000.0,
)

REDUCED = CONFIG.replace(
    name="internvl2-reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=512, head_dim=64, n_visual_tokens=16, loss_chunks=1,
)
