"""mamba2-2.7b [ssm]: 64L d_model=2560, attention-free SSD with
ssm_state=128, head_dim P=64 (=> 80 ssm heads at expand=2),
vocab=50280 [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,            # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    conv_width=4,
    tie_embeddings=True,
    supports_long_context=True,
)

REDUCED = CONFIG.replace(
    name="mamba2-reduced",
    n_layers=2, d_model=256, vocab_size=512, ssm_state=32,
    ssm_head_dim=32, ssm_chunk=16, loss_chunks=1,
)
