"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) per-expert
d_ff=1408, vocab=151936, MoE 60 routed top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=151936,
    head_dim=128,
    n_experts=60,
    n_experts_per_tok=4,
    n_shared_experts=4,
    rope_theta=1000000.0,
)

REDUCED = CONFIG.replace(
    name="qwen2-moe-reduced",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=128,
    moe_d_ff=128, vocab_size=512, head_dim=64,
    n_experts=4, n_experts_per_tok=2, n_shared_experts=1, loss_chunks=1,
)
