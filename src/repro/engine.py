"""Batched multi-deployment simulation engine.

One :class:`Engine` call evaluates a whole ablation cell — every seed and
deployment realisation of one configuration — as a single compiled XLA
program, instead of re-tracing ``hfl.train`` / ``flat_fl.train_*`` once
per seed the way the sequential path does.

Batch axes
----------
``Engine.run`` / ``Engine.audit`` take ``seeds`` (length S) and
``n_deployments`` (P) and build an (S, P) grid of trial keys:

* trial ``(s, 0)`` uses ``jax.random.key(seeds[s])`` — bit-identical to a
  sequential ``experiment.run_method(..., seed=seeds[s])`` call, which is
  what the equivalence tests in ``tests/test_engine.py`` pin down;
* trial ``(s, j>0)`` folds the deployment index into the seed key, giving
  an independent deployment realisation (and model init) per column.

The jittable per-trial functions from :mod:`repro.launch.experiment`
(``trial_metrics`` / ``audit_trial``) are nested-``vmap``-ped over the
grid — the inner deployment axis broadcasts each seed's dataset instead
of duplicating it on device — and the whole thing, the ``lax.scan`` over
rounds included, is jitted once per distinct (method, resolved config,
S, P, data shapes) cell.  Results come back with leading (S, P) axes.

Compressor default
------------------
Unless constructed with ``compressor="keep"``, the engine rewrites sparse
(``rho_s < 1``) ``mode="global"`` compressor configs to the blockwise
kernel path: compiled Pallas on TPU, the pure-jnp oracle (``kernels/ref``)
everywhere else — compiled Pallas needs a real TPU and interpret mode is
only a correctness tool, so CPU/GPU fall back automatically.
``Engine.resolve_config`` exposes the rewrite so sequential comparisons
can run the identical numerics.

Inside the round loops, compression and fog aggregation run FUSED by
default: ``core/aggregation.compress_and_aggregate`` dispatches to the
one-HBM-pass compress-and-aggregate kernel (``kernels/fused_agg``, jnp
oracle ``kernels/ref.compress_aggregate_ref``) which accumulates each
client's reconstruction straight into the (n_fog, d) fog buffers instead
of materialising dense (N, d) reconstructions and re-reading them in a
segment-sum.  Opt out per config with
``CompressorConfig(fused=False)`` — the legacy two-pass pipeline, kept as
the equivalence baseline.

Sharding
--------
With more than one device, input leaves are placed with the
``launch/sharding.py`` resolution rules on a 1-D ``("data",)`` mesh: the
trial axis shards when divisible by the device count, otherwise the
client axis of the dataset leaves does.  On one device this is a no-op.

``Engine(shard_clients=True)`` instead shards the CLIENT axis *inside*
the round loop: local SGD + fused compression run per-shard under
``shard_map`` on the ``launch/sharding.client_mesh()`` 1-D ``("data",)``
mesh, and the fog buffers are reduced with psum collectives
(``aggregation.hierarchical_mean``-style) — the multi-host lever for
deployments too large for a single device's memory.  It applies to the
hfl / flat-FL families when the sensor count divides the device count;
other cells silently run the default placement.

Benchmarks
----------
``benchmarks/{ablations,table3_scalability,fig4_convergence,fig7_noniid}``
run every cell through a shared engine (``benchmarks.common.get_engine``)
and record ``Engine.take_log()`` — per-cell wall clock + whether the cell
hit the program cache — into their JSON under ``"engine"``, so compile
counts and wall-clock are tracked from PR 1 onward.  CI smoke-runs the
kernel microbenchmark; the tier-1 suite covers batched-vs-sequential
equivalence and Pallas-vs-ref parity.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import async_fl, hfl
from repro.core import compression as comp
from repro.core import drift as drf
from repro.core import faults as flt
from repro.data.synthetic import SensorDataset
from repro.launch import experiment as exp
from repro.launch import sharding as shard_rules
from repro.optim.sgd import LocalTrainConfig


def default_use_pallas() -> bool:
    """Compiled Pallas kernels need a real TPU; elsewhere the engine falls
    back to the pure-jnp oracle in :mod:`repro.kernels.ref`."""
    return jax.default_backend() == "tpu"


def _base_cfg(cfg) -> hfl.HFLConfig:
    """The nested ``HFLConfig`` of an async config, else the config itself —
    every engine path that reads kernel/compressor/round statics goes
    through here so the four families share one code path."""
    return cfg.base if isinstance(cfg, async_fl.AsyncFLConfig) else cfg


def _cfg_key(cfg) -> tuple:
    """Hashable program-cache fingerprint of a (possibly array-bearing)
    config.  Dataclass configs hash fine while every leaf is a Python
    scalar, but leaves like ``AsyncFLConfig.arrival_delay_s`` may carry a
    trace-replay ARRAY — unhashable, and (because ``Engine.run`` closes
    over the config, baking leaves in as compile-time constants) the key
    must distinguish array CONTENT, not just shape.  Arrays become
    (shape, dtype, digest) triples; everything else passes through."""
    leaves, treedef = jax.tree_util.tree_flatten(cfg)
    out = []
    for x in leaves:
        if isinstance(x, (jax.Array, np.ndarray)):
            arr = np.asarray(x)
            out.append(("arr", arr.shape, str(arr.dtype),
                        hashlib.sha1(arr.tobytes()).hexdigest()))
        else:
            out.append(x)
    return (treedef, tuple(out))


def _describe_compressor(cc: comp.CompressorConfig) -> str:
    """Short human tag recorded per cell so bench JSONs show which
    numerics actually ran (the engine may rewrite ``global`` configs)."""
    if not cc.enabled:
        return "dense"
    backend = (
        ("pallas" if not cc.interpret else "pallas-interpret")
        if cc.use_pallas else "ref"
    ) if cc.mode == "blockwise" else "jnp"
    return f"{cc.mode}[{backend}] rho={cc.rho_s:g} q{cc.quant_bits}"


@dataclasses.dataclass(frozen=True)
class EngineRun:
    """Result of one batched cell.  Metric leaves have leading (S, P)."""

    method: str
    cfg: hfl.HFLConfig
    seeds: tuple[int, ...]
    n_deployments: int
    metrics: dict[str, jax.Array]
    wall_s: float
    fresh_compile: bool

    def __getitem__(self, name: str) -> jax.Array:
        return self.metrics[name]

    @property
    def f1(self) -> jax.Array:
        return self.metrics["f1"]

    @property
    def losses(self) -> jax.Array:
        """(S, P, T) per-round mean training loss."""
        return self.metrics["losses"]

    def seed_mean_std(self, name: str) -> tuple[float, float]:
        """Mean/std of a scalar metric over all (seed, deployment) trials."""
        v = jnp.asarray(self.metrics[name], jnp.float32)
        return float(jnp.mean(v)), float(jnp.std(v))


@dataclasses.dataclass(frozen=True)
class SweepRun:
    """Result of one config-axis sweep.  Metric leaves have leading
    (C, S, P) — config cell x seed x deployment."""

    method: str
    cfgs: tuple[hfl.HFLConfig, ...]   # resolved configs, input order
    seeds: tuple[int, ...]
    n_deployments: int
    metrics: dict[str, jax.Array]
    classes: tuple[dict, ...]         # per-shape-class execution info
    wall_s: float

    def __getitem__(self, name: str) -> jax.Array:
        return self.metrics[name]

    @property
    def compiled_programs(self) -> int:
        """Programs compiled fresh for THIS sweep (cache hits excluded)."""
        return sum(1 for c in self.classes if c["fresh_compile"])

    @property
    def n_classes(self) -> int:
        return len(self.classes)

    def cell(self, i: int) -> dict[str, jax.Array]:
        """Metrics of config cell ``i`` with the (S, P) trial axes kept."""
        return {k: v[i] for k, v in self.metrics.items()}

    def seed_mean_std(self, name: str, i: int) -> tuple[float, float]:
        v = jnp.asarray(self.metrics[name][i], jnp.float32)
        return float(jnp.mean(v)), float(jnp.std(v))


class Engine:
    """Unified batched front-end for the four round-loop families.

    * ``run``   — the trainable families: flat FL (``core/flat_fl``:
      fedavg/fedprox/fedadam/scaffold/centralised), hierarchical FL
      (``core/hfl``: the hfl-* cooperation rules), and the event-driven
      asynchronous family (``core/async_fl``: method ``"hfl-async"`` with
      an :class:`repro.core.async_fl.AsyncFLConfig` — its staleness knobs
      ``alpha`` / ``buffer_k`` / ``fog_k`` / timeouts are swept leaves,
      so ``sweep`` grids them exactly like the physics knobs);
    * ``sweep`` — ``run``/``audit`` over a whole CONFIG GRID: cells are
      grouped into shape-classes (identical static structure — enums,
      shapes, backend flags), each class's swept knobs (channel/energy
      physics, ``rho_s``, ``lr``, ...) are stacked along a new leading
      config axis, and one compiled program evaluates the whole class as
      a ``(C, S, P)`` grid;
    * ``audit`` — the training-free energy/participation replay of either
      family at paper scale;
    * ``pod_train_step`` — the TPU-mesh family (``core/mesh_fl``), returned
      as a cached jitted step for callers that own the mesh/batch loop.
    """

    def __init__(
        self,
        *,
        compressor: str = "auto",
        shard_trials: bool = True,
        shard_clients: bool = False,
        client_chunk: int | None = None,
        hidden: tuple[int, ...] = (16, 8, 16),
        percentile: float = 99.0,
        point_adjusted: bool = False,
    ) -> None:
        if compressor not in ("auto", "keep"):
            raise ValueError(f"compressor must be auto|keep, got {compressor!r}")
        if client_chunk is not None and (
            not isinstance(client_chunk, int) or client_chunk < 1
        ):
            raise ValueError(
                f"client_chunk must be None or a positive int, got "
                f"{client_chunk!r}"
            )
        self.compressor = compressor
        self.shard_trials = shard_trials
        self.shard_clients = shard_clients
        self.client_chunk = client_chunk
        self.hidden = hidden
        self.percentile = percentile
        self.point_adjusted = point_adjusted
        self._programs: dict[Any, Callable] = {}
        self.compile_count = 0
        self.call_log: list[dict] = []

    # ------------------------------------------------------------------
    # config / data resolution
    # ------------------------------------------------------------------

    def resolve_compressor(self, cc: comp.CompressorConfig) -> comp.CompressorConfig:
        """The engine's compressor default: blockwise kernels, Pallas on TPU."""
        if self.compressor == "keep" or not cc.enabled or cc.rho_s >= 1.0:
            return cc
        if cc.quant_bits != 8 and cc.quant_bits < 32:
            return cc  # kernels are int8-only; keep paper global numerics
        use_pallas = default_use_pallas()
        if (cc.mode == "blockwise" and cc.use_pallas == use_pallas
                and cc.interpret == (not use_pallas)):
            return cc
        return cc.replace(
            mode="blockwise",
            use_pallas=use_pallas,
            interpret=not use_pallas,
        )

    def resolve_local_solver(
        self, ls: LocalTrainConfig
    ) -> LocalTrainConfig:
        """The engine's local-train default: the fused kernel, Pallas on
        TPU, the ``kernels/ref`` oracle elsewhere.  ``fused=False`` (the
        legacy per-client scan) is respected as an explicit opt-out."""
        if not ls.fused:
            return ls
        use_pallas = default_use_pallas()
        if ls.use_pallas == use_pallas and ls.interpret == (not use_pallas):
            return ls
        return ls.replace(use_pallas=use_pallas, interpret=not use_pallas)

    def resolve_config(self, cfg):
        """Apply the engine's kernel-backend defaults; an async config
        resolves through its nested ``base`` round-loop config.

        ``Engine(client_chunk=...)`` stamps the fleet-axis chunk size into
        configs that leave it unset (``cfg.client_chunk is None``); an
        explicit per-config value always wins.  The knob is static aux
        (shape-bearing), so differing chunk settings split sweep
        shape-classes — which is why :meth:`_audit_normal` blanks it.
        """
        if isinstance(cfg, async_fl.AsyncFLConfig):
            return cfg.replace(base=self.resolve_config(cfg.base))
        kw: dict[str, Any] = dict(
            compressor=self.resolve_compressor(cfg.compressor),
            local_solver=self.resolve_local_solver(cfg.local_solver),
        )
        if cfg.client_chunk is None and self.client_chunk is not None:
            kw["client_chunk"] = self.client_chunk
        return cfg.replace(**kw)

    @staticmethod
    def stack_datasets(ds_list: Sequence[SensorDataset]) -> SensorDataset:
        """Stack per-seed datasets along a new leading trial axis."""
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ds_list)

    def _as_stacked(self, ds, seeds: Sequence[int]) -> SensorDataset:
        if callable(ds):
            return self.stack_datasets([ds(s) for s in seeds])
        if ds.train.ndim == 3:  # one dataset shared by every seed
            return self.stack_datasets([ds] * len(seeds))
        if ds.train.shape[0] != len(seeds):
            raise ValueError(
                f"stacked dataset has {ds.train.shape[0]} entries for "
                f"{len(seeds)} seeds"
            )
        return ds

    @staticmethod
    def _trial_keys(seeds: Sequence[int], n_deployments: int) -> jax.Array:
        """(S, P) trial keys; column 0 is exactly ``jax.random.key(seed)``."""
        if not seeds or n_deployments < 1:
            raise ValueError(
                f"need >=1 seed and n_deployments >= 1, got "
                f"{len(seeds)} seed(s), n_deployments={n_deployments}"
            )
        rows = []
        for s in seeds:
            base = jax.random.key(s)
            rows.append(jnp.stack([
                base if j == 0 else jax.random.fold_in(base, j)
                for j in range(n_deployments)
            ]))
        return jnp.stack(rows)

    # ------------------------------------------------------------------
    # program cache / sharding / instrumentation
    # ------------------------------------------------------------------

    def _get_program(self, cache_key: Any, build: Callable[[], Callable]):
        fn = self._programs.get(cache_key)
        fresh = fn is None
        if fresh:
            fn = jax.jit(build())
            self._programs[cache_key] = fn
            self.compile_count += 1
        return fn, fresh

    def _client_mesh(self, method: str, stacked: SensorDataset):
        """The in-loop client-axis mesh for a ``run`` cell, or None.

        Client sharding needs >1 device, a round-loop family that routes
        through the fused pipeline (hfl / flat FL), and a sensor count the
        device count divides; every other cell keeps default placement.
        """
        if not self.shard_clients or method in (
            "centralised", "scaffold", "hfl-async"
        ):
            return None
        devices = jax.devices()
        n_clients = stacked.train.shape[1]
        if len(devices) <= 1 or n_clients % len(devices) != 0:
            return None
        return shard_rules.client_mesh(devices)

    def _place(self, tree: Any, n_leading: int) -> Any:
        """Shard inputs over devices with the launch/sharding rules.

        Prefers the leading (seed) axis; falls back to the client axis of
        dataset leaves when the seed count does not divide the device
        count.  Single-device: identity.
        """
        devices = jax.devices()
        if not self.shard_trials or len(devices) <= 1:
            return tree
        import numpy as np

        # resolve_spec expects the production ("data", "model") axis pair;
        # a trivial model axis keeps trials pure data-parallel.
        mesh = jax.sharding.Mesh(
            np.asarray(devices).reshape(-1, 1), ("data", "model")
        )
        trial_ok = n_leading % len(devices) == 0

        def place(x):
            if not hasattr(x, "ndim") or x.ndim == 0:
                return x
            if trial_ok:
                logical = ("batch",) + (None,) * (x.ndim - 1)
            elif x.ndim >= 2:
                logical = (None, "batch") + (None,) * (x.ndim - 2)
            else:
                return x
            spec = shard_rules.resolve_spec(logical, x.shape, mesh)
            return jax.device_put(x, jax.sharding.NamedSharding(mesh, spec))

        return jax.tree_util.tree_map(place, tree)

    def _timed_call(self, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        out = jax.tree_util.tree_map(jax.block_until_ready, out)
        return out, time.perf_counter() - t0

    def _log(self, **entry) -> None:
        self.call_log.append(entry)

    def take_log(self) -> list[dict]:
        """Drain the per-call log (benchmarks snapshot this into JSON)."""
        entries, self.call_log = self.call_log, []
        return entries

    def stats(self) -> dict:
        return {
            "compiled_programs": self.compile_count,
            "cached_programs": len(self._programs),
        }

    # ------------------------------------------------------------------
    # the three families
    # ------------------------------------------------------------------

    def run(
        self,
        method: str,
        cfg: hfl.HFLConfig,
        seeds: Sequence[int],
        ds: SensorDataset | Callable[[int], SensorDataset],
        *,
        n_deployments: int = 1,
        label: str | None = None,
        store: Any | None = None,
        publish_step: int | None = None,
    ) -> EngineRun:
        """Train + evaluate ``method`` for every (seed, deployment) trial.

        ``ds``: a per-seed callable, a single dataset (shared), or a
        dataset stacked along a leading ``len(seeds)`` axis.

        ``store``: optional ``checkpoint.CheckpointStore`` — publishes the
        trained params of trial (seeds[0], deployment 0) as round
        ``publish_step`` (default ``cfg.rounds``), the hand-off point to
        the serving path (``serving/service.ScoringService``).
        """
        cfg = self.resolve_config(cfg)
        seeds = tuple(int(s) for s in seeds)
        stacked = self._as_stacked(ds, seeds)
        s_n, p_n = len(seeds), n_deployments
        keys = self._trial_keys(seeds, p_n)           # (S, P)
        client_mesh = self._client_mesh(method, stacked)
        return_params = store is not None
        shapes = tuple(
            (x.shape, str(x.dtype)) for x in jax.tree_util.tree_leaves(stacked)
        )
        cache_key = ("run", method, _cfg_key(cfg), s_n, p_n, shapes,
                     self.hidden, self.percentile, self.point_adjusted,
                     client_mesh.size if client_mesh is not None else 0,
                     return_params)

        def build():
            def trial(key, one_ds):
                return exp.trial_metrics(
                    method, key, one_ds, cfg,
                    percentile=self.percentile,
                    point_adjusted=self.point_adjusted,
                    hidden=self.hidden,
                    client_mesh=client_mesh,
                    return_params=return_params,
                )

            # Inner vmap broadcasts the seed's dataset over the deployment
            # columns (no device-side duplication); outer vmap pairs each
            # seed with its dataset.  Output leaves lead with (S, P).
            return jax.vmap(jax.vmap(trial, in_axes=(0, None)))

        fn, fresh = self._get_program(cache_key, build)
        if client_mesh is None:
            # client-sharded cells leave placement to the in-loop shard_map
            keys, stacked = self._place(keys, s_n), self._place(stacked, s_n)
        out, wall = self._timed_call(fn, keys, stacked)
        if store is not None:
            params0 = jax.tree_util.tree_map(lambda a: a[0, 0], out.pop("params"))
            store.publish(
                _base_cfg(cfg).rounds if publish_step is None else publish_step,
                params0,
            )
        self._log(kind="run", method=method, label=label or method,
                  n_trials=s_n * p_n, wall_s=wall, fresh_compile=fresh,
                  compressor=_describe_compressor(_base_cfg(cfg).compressor),
                  client_sharded=client_mesh is not None)
        return EngineRun(method, cfg, seeds, p_n, out, wall, fresh)

    def audit(
        self,
        method: str,
        cfg: hfl.HFLConfig,
        seeds: Sequence[int],
        *,
        d: int = 1352,
        n_deployments: int = 1,
        label: str | None = None,
    ) -> dict[str, jax.Array]:
        """Batched training-free energy/participation audit.

        Returns summed energies / mean participation with (S, P) leading
        axes; trial (s, 0) matches ``experiment.audit_method(seed=s)``.
        """
        cfg = self.resolve_config(cfg)
        seeds = tuple(int(s) for s in seeds)
        s_n, p_n = len(seeds), n_deployments
        keys = self._trial_keys(seeds, p_n)           # (S, P)
        cache_key = ("audit", method, _cfg_key(cfg), s_n, p_n, d)

        def build():
            trial = lambda key: exp.audit_trial(method, key, cfg, d)  # noqa: E731
            return jax.vmap(jax.vmap(trial))

        fn, fresh = self._get_program(cache_key, build)
        out, wall = self._timed_call(fn, self._place(keys, s_n))
        self._log(kind="audit", method=method, label=label or method,
                  n_trials=s_n * p_n, wall_s=wall, fresh_compile=fresh,
                  compressor=_describe_compressor(cfg.compressor))
        return out

    # ------------------------------------------------------------------
    # config-axis sweeps
    # ------------------------------------------------------------------

    @staticmethod
    def stack_configs(cfgs: Sequence[hfl.HFLConfig]) -> hfl.HFLConfig:
        """Stack same-shape-class configs: every swept leaf becomes a
        (C,) f32 array, static aux fields come from the first config."""
        return jax.tree_util.tree_map(
            lambda *xs: jnp.stack([jnp.asarray(x, jnp.float32) for x in xs]),
            *cfgs,
        )

    @staticmethod
    def _audit_normal(cfg: hfl.HFLConfig) -> hfl.HFLConfig:
        """Blank out the static fields the audit family never reads.

        The audit touches the compressor only through the uplink payload
        size — which the sweep feeds as a swept operand — so cells that
        differ only in compressor/solver/server statics collapse into one
        shape-class.
        """
        if isinstance(cfg, async_fl.AsyncFLConfig):
            raise ValueError(
                "audit family is training-free and synchronous; it does "
                "not support AsyncFLConfig cells"
            )
        return cfg.replace(
            local_epochs=1,
            batch_size=32,
            server_opt="sgd",
            local_solver=LocalTrainConfig(),
            compressor=comp.CompressorConfig(),
            faults=flt.FaultConfig(),
            drift=drf.DriftConfig(),
            trim_frac=0.0,
            robust="mean",
            client_chunk=None,  # audits never run the client phase
        )

    @staticmethod
    def _kernel_static_knobs(cfg: hfl.HFLConfig) -> tuple:
        """Knobs the Pallas kernels bake into their bodies.

        On the jnp-oracle backend these trace (bisection selection, scalar
        arithmetic) and the sweep batches across their values; a
        pallas-backed config must keep them concrete, so they join the
        shape-class signature and are re-pinned inside the program.
        """
        base = _base_cfg(cfg)
        knobs = {}
        cc = base.compressor
        if cc.enabled and cc.is_sparse and cc.mode == "blockwise" and cc.use_pallas:
            knobs["rho_s"] = float(cc.rho_s)
        if base.local_solver.fused and base.local_solver.use_pallas:
            knobs["lr"] = float(base.lr)
            knobs["prox_mu"] = float(base.prox_mu)
        if base.robust != "mean" and cc.use_pallas:
            knobs["trim_frac"] = float(base.trim_frac)
        return tuple(sorted(knobs.items()))

    def _sweep_classes(
        self, cfgs: Sequence[hfl.HFLConfig], family: str,
        ds_shapes: Sequence[tuple] | None,
    ) -> tuple[list[hfl.HFLConfig], dict]:
        """Group sweep cells into shape-classes.

        The signature is the config's pytree STRUCTURE (every static aux
        field — rule enum, round/epoch counts, compressor mode/bits/flags,
        deployment geometry — lives in the treedef; swept leaves do not),
        plus any kernel-bound knobs and, for per-cell datasets, the data
        shapes.  Mixed enums/static shapes therefore never co-batch.
        """
        norm, groups = [], {}
        for i, rcfg in enumerate(cfgs):
            ncfg = self._audit_normal(rcfg) if family == "audit" else rcfg
            norm.append(ncfg)
            sig = (
                jax.tree_util.tree_structure(ncfg),
                self._kernel_static_knobs(rcfg) if family == "run" else (),
                ds_shapes[i] if ds_shapes is not None else None,
            )
            groups.setdefault(sig, []).append(i)
        return norm, groups

    def sweep(
        self,
        method: str | Sequence[str],
        cfgs: Sequence[hfl.HFLConfig],
        seeds: Sequence[int],
        ds: Any = None,
        *,
        n_deployments: int = 1,
        family: str = "run",
        d: int = 1352,
        label: str | None = None,
    ) -> SweepRun:
        """Evaluate a whole config grid: ONE compiled program per
        shape-class, each running its cells as a leading config axis on
        top of the (seed, deployment) trial grid.

        ``cfgs``: the hyperparameter cells.  Cells may differ in any
        traceable knob (``ChannelParams`` / ``EnergyParams`` physics,
        ``CompressorConfig.rho_s``, ``lr`` / ``prox_mu`` / ``server_lr`` /
        ``compute_rate_flops``) and still share a program; cells that
        differ in static structure — cooperation rule, round/epoch/batch
        counts, compressor mode/bit-width/backend, deployment geometry —
        split into separate shape-classes (and separate programs).

        ``family="run"`` trains and evaluates (``ds`` required: one
        dataset/callable shared by every cell, or a length-C sequence of
        per-cell datasets, each in any form ``Engine.run`` accepts);
        ``family="audit"`` replays the training-free energy accounting
        (``d`` = model size; ``ds`` ignored).

        ``method`` may be a length-C sequence for ``family="audit"``: the
        cells' methods become a ``lax.switch`` branch index — a swept
        operand like the payload size — so audit cells that differ ONLY in
        method (e.g. Table III's four methods at one N) share one compiled
        program instead of one per (cfg, method) pair.  The training
        family keeps one method per sweep (its per-method round loops
        differ structurally).

        Returns a :class:`SweepRun` with metric leaves shaped (C, S, P);
        cell ``i`` matches ``Engine.run(cfgs[i], ...)`` /
        ``Engine.audit`` to float tolerance.
        """
        if family not in ("run", "audit"):
            raise ValueError(f"family must be run|audit, got {family!r}")
        if not cfgs:
            raise ValueError("need at least one config cell")
        if isinstance(method, str):
            methods = (method,) * len(cfgs)
        else:
            methods = tuple(method)
            if len(methods) != len(cfgs):
                raise ValueError(
                    f"got {len(methods)} methods for {len(cfgs)} configs"
                )
            if family == "run" and len(set(methods)) > 1:
                raise ValueError(
                    "per-cell methods are audit-only (the training "
                    "family's round loops differ structurally per method)"
                )
        # Order-preserving unique methods — the lax.switch branch table.
        uniq = tuple(dict.fromkeys(methods))
        method_desc = uniq[0] if len(uniq) == 1 else "+".join(uniq)
        seeds = tuple(int(s) for s in seeds)
        s_n, p_n = len(seeds), n_deployments
        keys = self._trial_keys(seeds, p_n)           # (S, P)
        rcfgs = tuple(self.resolve_config(c) for c in cfgs)

        stacked_ds, ds_shapes = None, None
        if family == "run":
            if ds is None:
                raise ValueError("family='run' sweeps need a dataset")
            shape_of = lambda one: tuple(  # noqa: E731
                (x.shape, str(x.dtype))
                for x in jax.tree_util.tree_leaves(one)
            )
            if isinstance(ds, (list, tuple)):
                if len(ds) != len(rcfgs):
                    raise ValueError(
                        f"got {len(ds)} datasets for {len(rcfgs)} configs"
                    )
                stacked_ds = [self._as_stacked(one, seeds) for one in ds]
                ds_shapes = [shape_of(one) for one in stacked_ds]
            else:
                shared = self._as_stacked(ds, seeds)
                stacked_ds = [shared] * len(rcfgs)
                ds_shapes = [shape_of(shared)] * len(rcfgs)

        norm, groups = self._sweep_classes(rcfgs, family, ds_shapes)

        per_cfg: list[Any] = [None] * len(rcfgs)
        classes, wall_total = [], 0.0
        for sig, idxs in groups.items():
            stacked_cfg = self.stack_configs([norm[i] for i in idxs])
            rep = rcfgs[idxs[0]]
            knobs = dict(self._kernel_static_knobs(rep))
            cache_key = ("sweep", family, uniq, sig, len(idxs), s_n, p_n,
                         d, self.hidden, self.percentile, self.point_adjusted)

            if family == "run":
                shared_cell_ds = all(
                    stacked_ds[i] is stacked_ds[idxs[0]] for i in idxs
                )
                if shared_cell_ds:
                    ds_arg, ds_axis = stacked_ds[idxs[0]], None
                else:
                    ds_arg = jax.tree_util.tree_map(
                        lambda *xs: jnp.stack(xs),
                        *[stacked_ds[i] for i in idxs],
                    )
                    ds_axis = 0
                cache_key += (ds_axis,)

                def build(knobs=knobs, ds_axis=ds_axis):
                    def trial(cfg_, key, one_ds):
                        if knobs:
                            # kernel-bound knobs stay concrete per class;
                            # async cells carry them in the nested base.
                            b = _base_cfg(cfg_)
                            b = b.replace(
                                lr=knobs.get("lr", b.lr),
                                prox_mu=knobs.get("prox_mu", b.prox_mu),
                                trim_frac=knobs.get(
                                    "trim_frac", b.trim_frac
                                ),
                            )
                            if "rho_s" in knobs:
                                b = b.replace(
                                    compressor=b.compressor.replace(
                                        rho_s=knobs["rho_s"]
                                    )
                                )
                            cfg_ = (
                                cfg_.replace(base=b)
                                if isinstance(cfg_, async_fl.AsyncFLConfig)
                                else b
                            )
                        return exp.trial_metrics(
                            uniq[0], key, one_ds, cfg_,
                            percentile=self.percentile,
                            point_adjusted=self.point_adjusted,
                            hidden=self.hidden,
                        )

                    dep_v = jax.vmap(trial, in_axes=(None, 0, None))
                    seed_v = jax.vmap(dep_v, in_axes=(None, 0, 0))
                    return jax.vmap(seed_v, in_axes=(0, None, ds_axis))

                fn, fresh = self._get_program(cache_key, build)
                # Same launch/sharding placement rules as Engine.run:
                # per-cell datasets shard over the config axis, shared
                # ones over the seed axis (no-op on one device).
                placed_keys = self._place(keys, s_n)
                placed_ds = self._place(
                    ds_arg, len(idxs) if ds_axis == 0 else s_n
                )
                out, wall = self._timed_call(
                    fn, stacked_cfg, placed_keys, placed_ds
                )
            else:
                l_u = jnp.asarray(
                    [float(comp.payload_bits(d, rcfgs[i].compressor))
                     for i in idxs],
                    jnp.float32,
                )
                # Per-cell method as a traced branch index: the program
                # carries every unique method's audit as a lax.switch
                # branch, so cells differing only in method co-batch.
                midx = jnp.asarray(
                    [uniq.index(methods[i]) for i in idxs], jnp.int32
                )

                def build():
                    def trial(cfg_, lu, mi, key):
                        if len(uniq) == 1:
                            return exp.audit_trial(
                                uniq[0], key, cfg_, d, l_u=lu
                            )
                        branches = [
                            (lambda k_, c_, l_, m=m: exp.audit_trial(
                                m, k_, c_, d, l_u=l_
                            ))
                            for m in uniq
                        ]
                        return jax.lax.switch(mi, branches, key, cfg_, lu)

                    dep_v = jax.vmap(trial, in_axes=(None, None, None, 0))
                    seed_v = jax.vmap(dep_v, in_axes=(None, None, None, 0))
                    return jax.vmap(seed_v, in_axes=(0, 0, 0, None))

                fn, fresh = self._get_program(cache_key, build)
                out, wall = self._timed_call(
                    fn, stacked_cfg, l_u, midx, self._place(keys, s_n)
                )

            for pos, i in enumerate(idxs):
                per_cfg[i] = jax.tree_util.tree_map(lambda a: a[pos], out)
            info = dict(
                indices=tuple(idxs), n_cells=len(idxs), wall_s=wall,
                fresh_compile=fresh,
                compressor=_describe_compressor(_base_cfg(rep).compressor),
            )
            classes.append(info)
            wall_total += wall
            self._log(kind=f"sweep-{family}", method=method_desc,
                      label=label or f"sweep:{method_desc}",
                      n_cells=len(idxs),
                      n_trials=len(idxs) * s_n * p_n, wall_s=wall,
                      fresh_compile=fresh, compressor=info["compressor"])

        # Stack per metric into (C, S, P, ...) where shapes agree across
        # classes; a metric whose trailing shape differs between classes
        # (e.g. per-round losses under different round counts) stays a
        # C-tuple — cell indexing works identically either way.
        metrics = {}
        for name in per_cfg[0]:
            vals = [m[name] for m in per_cfg]
            if len({v.shape for v in vals}) == 1:
                metrics[name] = jnp.stack(vals)
            else:
                metrics[name] = tuple(vals)
        return SweepRun(method_desc, rcfgs, seeds, p_n, metrics,
                        tuple(classes), wall_total)

    def reachability(
        self,
        cfg: hfl.HFLConfig,
        seeds: Sequence[int],
        *,
        n_deployments: int = 1,
        label: str | None = None,
    ) -> dict[str, jax.Array]:
        """Batched geometry-only reachability study (the Fig. 5 family).

        Training- and model-free: each trial samples a deployment and
        computes the direct-gateway / fog-assisted / fog-to-gateway
        feasibility fractions.  Returns (S, P)-leading arrays; trial
        (s, 0) matches a sequential ``topo.sample_deployment`` +
        ``participation.reachability`` call from ``jax.random.key(s)``.
        """
        from repro.core import participation as part
        from repro.core import topology as topo

        seeds = tuple(int(s) for s in seeds)
        s_n, p_n = len(seeds), n_deployments
        keys = self._trial_keys(seeds, p_n)           # (S, P)
        cache_key = ("reach", cfg.deployment, cfg.channel, s_n, p_n)

        def build():
            def trial(key):
                dep = topo.sample_deployment(key, cfg.deployment)
                r = part.reachability(dep, cfg.channel)
                return {
                    "direct_gateway": r.direct_gateway,
                    "fog_assisted": r.fog_assisted,
                    "fog_to_gateway": r.fog_to_gateway,
                }

            return jax.vmap(jax.vmap(trial))

        fn, fresh = self._get_program(cache_key, build)
        out, wall = self._timed_call(fn, keys)
        self._log(kind="reachability", method="reachability",
                  label=label or "reachability", n_trials=s_n * p_n,
                  wall_s=wall, fresh_compile=fresh, compressor="n/a")
        return out

    def score(
        self,
        params: Any,
        x: jax.Array,
        tau: jax.Array | float,
        *,
        n_trial_axes: int = 0,
        fused: bool = True,
        label: str | None = None,
    ):
        """Batched fused anomaly scoring — the serving family (ISSUE 3).

        ``x``: telemetry ``(..., d)``; the fused score kernel
        (``serving/score``: Pallas on TPU, jnp oracle elsewhere) flattens
        everything below the trial axes into one row sweep.  ``params``
        may carry ``n_trial_axes`` leading axes (e.g. the (S, P) grid of
        a training cell) which are vmapped exactly like ``run``; ``x`` and
        ``tau`` broadcast rows per trial.  With no trial axes the leading
        (fleet) axis of ``x`` shards over devices via the launch/sharding
        rules — the fleet-scale lever.  Returns a ``ScoreResult`` with
        leaves shaped ``x.shape[:-1]``.
        """
        # The serving package re-exports the function under the submodule's
        # name, so import the function itself.
        from repro.serving.score import score as serving_score_fn

        x = jnp.asarray(x)
        tau_b = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), x.shape[:-1])
        use_pallas = default_use_pallas()
        treedef = jax.tree_util.tree_structure(params)
        p_shapes = tuple(
            (tuple(leaf.shape), str(leaf.dtype))
            for leaf in jax.tree_util.tree_leaves(params)
        )
        cache_key = ("score", treedef, p_shapes, x.shape, str(x.dtype),
                     n_trial_axes, fused)

        def build():
            def one(p, xx, tt):
                return serving_score_fn(
                    p, xx, tt, use_pallas=use_pallas,
                    interpret=not use_pallas, fused=fused,
                )

            fn = one
            for _ in range(n_trial_axes):
                fn = jax.vmap(fn)
            return fn

        fn, fresh = self._get_program(cache_key, build)
        n_leading = x.shape[0]
        placed = self._place((x, tau_b), n_leading)
        out, wall = self._timed_call(fn, params, *placed)
        n_rows = math.prod(x.shape[:-1])
        self._log(kind="score", method="score", label=label or "score",
                  n_trials=n_rows, wall_s=wall, fresh_compile=fresh,
                  compressor="fused" if fused else "unfused")
        return out

    def pod_train_step(
        self,
        model_cfg: Any,
        mesh: jax.sharding.Mesh | None = None,
        *,
        rho_s: float = 0.05,
        self_weight: float = 0.5,
        mode: str = "int8",
        local_epochs: int = 1,
    ) -> Callable:
        """Cached jitted TPU-mesh pod step (``core/mesh_fl`` family).

        Defaults to a single-pod host mesh so the same entry point works
        on CPU; pass the production mesh on real hardware.
        ``local_epochs > 1`` runs E local passes per pod through the
        shared ``optim/sgd`` local-training driver (delta exchange).
        """
        from repro.core import mesh_fl

        if mesh is None:
            mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
        cache_key = ("pod", repr(model_cfg), tuple(sorted(mesh.shape.items())),
                     rho_s, self_weight, mode, local_epochs)

        def build():
            return mesh_fl.make_pod_hfl_train_step(
                model_cfg, mesh, rho_s=rho_s, self_weight=self_weight,
                mode=mode, local_epochs=local_epochs,
            )

        fn, _ = self._get_program(cache_key, build)
        return fn
