"""Flat-npz pytree checkpointing.

Keys are the jax.tree_util key-paths, so any pytree of arrays round-trips
without a registry.  ``CheckpointStore`` adds step management (latest,
retention) for the training launcher; save is atomic (tmp + rename) so a
killed run never leaves a truncated checkpoint behind.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree: Any) -> None:
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten(tree))
    # np.savez appends .npz to names without it.
    if not tmp.endswith(".npz"):
        tmp += ".npz"
    os.replace(tmp, path)


def load_pytree(path: str, like: Any) -> Any:
    """Load into the structure of ``like`` (arrays or ShapeDtypeStructs)."""
    with np.load(path) as data:
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path_keys, ref in paths:
            key = jax.tree_util.keystr(path_keys)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch at {key!r}: {arr.shape} vs {ref.shape}"
                )
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointStore:
    """Step-indexed checkpoints under one directory, keeping the last K."""

    _FMT = "step_{:08d}.npz"
    _RE = re.compile(r"step_(\d+)\.npz$")

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, self._FMT.format(step))

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = self._RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any) -> str:
        path = self._path(step)
        save_pytree(path, tree)
        for old in self.steps()[: -self.keep]:
            os.remove(self._path(old))
        return path

    def restore(self, like: Any, step: int | None = None) -> tuple[Any, int]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return load_pytree(self._path(step), like), step
