"""Flat-npz pytree checkpointing.

Keys are the jax.tree_util key-paths, so any pytree of arrays round-trips
without a registry.  ``CheckpointStore`` adds step management (latest,
retention) for the training launcher and the serving hot-swap
(``serving/service.ScoringService`` polls ``latest_step``): ``save`` is
atomic — the payload is staged to a unique temp file in the same directory,
fsynced, and ``os.replace``d into place — so a concurrent reader can never
observe a half-written round and a killed run never leaves a truncated
checkpoint behind.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree: Any) -> None:
    """Atomically write ``tree`` to ``path`` (tmp file + ``os.replace``).

    The temp name is unique per call (no collision between concurrent
    writers of the same step) and lives in the target directory, so the
    final rename stays within one filesystem and is atomic.
    """
    directory = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=".inflight-", suffix=".npz")
    try:
        with os.fdopen(fd, "wb") as f:
            # A file object keeps np.savez from appending ".npz" to the name.
            np.savez(f, **_flatten(tree))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def load_pytree(path: str, like: Any) -> Any:
    """Load into the structure of ``like`` (arrays or ShapeDtypeStructs)."""
    with np.load(path) as data:
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path_keys, ref in paths:
            key = jax.tree_util.keystr(path_keys)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch at {key!r}: {arr.shape} vs {ref.shape}"
                )
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointStore:
    """Step-indexed checkpoints under one directory, keeping the last K."""

    _FMT = "step_{:08d}.npz"
    _RE = re.compile(r"step_(\d+)\.npz$")

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, self._FMT.format(step))

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = self._RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any) -> str:
        path = self._path(step)
        save_pytree(path, tree)
        for old in self.steps()[: -self.keep]:
            try:
                os.remove(self._path(old))
            except FileNotFoundError:
                pass  # a concurrent writer's retention pass got there first
        return path

    def restore(self, like: Any, step: int | None = None) -> tuple[Any, int]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return load_pytree(self._path(step), like), step

    # Serving-facing aliases: the train loop *publishes* rounds, the
    # service reads back the *latest* — see serving/service.ScoringService.
    def publish(self, step: int, tree: Any) -> str:
        return self.save(step, tree)

    def latest(self, like: Any) -> tuple[Any, int]:
        return self.restore(like)
