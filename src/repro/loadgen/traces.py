"""Deterministic arrival-trace generators for the serving load harness.

Underwater telemetry does not arrive back-to-back: acoustic links surface
windows of data in bursts, duty-cycled sensors report on tide/daylight
rhythms, and the IoUT serving constraint is precisely that intermittent,
bursty delivery.  These generators produce *replayable* arrival traces —
seeded, pure numpy, identical arrays for identical arguments — that
``loadgen/harness.replay`` drives against a ``ScoringService`` on a
virtual clock.

A trace is a time-sorted event stream; each event is one sensor
surfacing one telemetry window: ``(t_arrive, sensor_id, fog_id)`` plus
the per-event window row count (``rows``, constant per trace).  The
fleet-aggregate process is sampled directly and events are attributed to
sensors uniformly — the superposition of ``fleet`` independent
per-sensor Poisson processes IS the aggregate Poisson process, so this
is exact for the homogeneous-fleet model while staying O(n_events)
regardless of fleet size.

Three processes:

* :func:`poisson_trace` — constant-rate Poisson: the steady-state
  baseline.
* :func:`mmpp_trace` — a 2-state Markov-modulated Poisson process
  (on/off: exponential sojourns, per-state rates).  ``rate_off_hz=0``
  gives hard silences between bursts — the acoustic-surfacing shape that
  breaks fixed-size batching.
* :func:`diurnal_trace` — sinusoidally modulated rate via Lewis-Shedler
  thinning: slow daily load swings for autoscaling/bucket studies.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """A replayable arrival trace (time-sorted, one window per event)."""

    kind: str                 # "poisson" | "mmpp" | "diurnal"
    t: np.ndarray             # (n_events,) f64 arrival seconds, sorted
    sensor: np.ndarray        # (n_events,) int32 sensor id
    fog: np.ndarray           # (n_events,) int32 fog cluster of the sensor
    rows: int                 # telemetry rows (window length) per event
    duration_s: float         # trace horizon the events were drawn over
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def n_events(self) -> int:
        return int(self.t.shape[0])

    def __len__(self) -> int:
        return self.n_events

    @property
    def total_rows(self) -> int:
        return self.n_events * self.rows

    def mean_rate_hz(self) -> float:
        """Realised event rate over the trace horizon."""
        return self.n_events / self.duration_s if self.duration_s > 0 else 0.0

    def summary(self) -> dict:
        return {
            "kind": self.kind,
            "n_events": self.n_events,
            "rows_per_event": self.rows,
            "total_rows": self.total_rows,
            "duration_s": self.duration_s,
            "mean_rate_hz": self.mean_rate_hz(),
            **self.meta,
        }


def _finish(
    kind: str,
    seed: int,
    times: np.ndarray,
    *,
    fleet: int,
    n_fog: int,
    rows: int,
    duration_s: float,
    meta: dict,
) -> ArrivalTrace:
    """Attribute aggregate arrivals to sensors (uniform, seeded) and fix
    the fog routing the repo uses everywhere (``sensor % n_fog``)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xA551]))
    times = np.asarray(times, np.float64)
    sensor = rng.integers(0, fleet, times.shape[0], dtype=np.int32)
    fog = (sensor % n_fog).astype(np.int32)
    return ArrivalTrace(
        kind=kind, t=times, sensor=sensor, fog=fog, rows=int(rows),
        duration_s=float(duration_s),
        meta={"fleet": int(fleet), "n_fog": int(n_fog), "seed": int(seed), **meta},
    )


def poisson_trace(
    seed: int,
    *,
    rate_hz: float,
    duration_s: float,
    fleet: int,
    n_fog: int,
    rows: int = 16,
) -> ArrivalTrace:
    """Constant-rate Poisson arrivals at ``rate_hz`` events/s aggregate."""
    if rate_hz <= 0 or duration_s <= 0:
        raise ValueError("rate_hz and duration_s must be positive")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x9015]))
    # Draw in chunks until past the horizon: exact, no truncation bias.
    gaps = []
    total = 0.0
    while total < duration_s:
        chunk = rng.exponential(1.0 / rate_hz, size=max(64, int(rate_hz)))
        gaps.append(chunk)
        total += float(chunk.sum())
    times = np.cumsum(np.concatenate(gaps))
    times = times[times < duration_s]
    return _finish(
        "poisson", seed, times, fleet=fleet, n_fog=n_fog, rows=rows,
        duration_s=duration_s, meta={"rate_hz": float(rate_hz)},
    )


def mmpp_trace(
    seed: int,
    *,
    rate_on_hz: float,
    rate_off_hz: float = 0.0,
    mean_on_s: float,
    mean_off_s: float,
    duration_s: float,
    fleet: int,
    n_fog: int,
    rows: int = 16,
    start_on: bool = True,
) -> ArrivalTrace:
    """2-state on/off MMPP: exponential sojourns, Poisson within state.

    ``rate_off_hz=0`` (default) makes the off state silent — bursts of
    acoustic surfacing separated by dead air, the bursty-delivery model
    the IoUT serving literature calls out.
    """
    if rate_on_hz <= 0 or duration_s <= 0:
        raise ValueError("rate_on_hz and duration_s must be positive")
    if mean_on_s <= 0 or mean_off_s <= 0:
        raise ValueError("sojourn means must be positive")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x3399]))
    times = []
    t, on, bursts = 0.0, bool(start_on), 0
    while t < duration_s:
        sojourn = float(rng.exponential(mean_on_s if on else mean_off_s))
        end = min(t + sojourn, duration_s)
        rate = rate_on_hz if on else rate_off_hz
        if rate > 0:
            tick = t + float(rng.exponential(1.0 / rate))
            while tick < end:
                times.append(tick)
                tick += float(rng.exponential(1.0 / rate))
        bursts += int(on)
        t, on = end, not on
    return _finish(
        "mmpp", seed, np.asarray(times), fleet=fleet, n_fog=n_fog, rows=rows,
        duration_s=duration_s,
        meta={
            "rate_on_hz": float(rate_on_hz), "rate_off_hz": float(rate_off_hz),
            "mean_on_s": float(mean_on_s), "mean_off_s": float(mean_off_s),
            "bursts": bursts,
        },
    )


def diurnal_trace(
    seed: int,
    *,
    base_rate_hz: float,
    peak_rate_hz: float,
    period_s: float,
    duration_s: float,
    fleet: int,
    n_fog: int,
    rows: int = 16,
) -> ArrivalTrace:
    """Sinusoidally modulated Poisson arrivals (Lewis-Shedler thinning).

    Instantaneous rate ``base + (peak - base) * (1 + sin(2*pi*t/T)) / 2``
    — swings between ``base_rate_hz`` and ``peak_rate_hz`` once per
    ``period_s``.
    """
    if not 0 < base_rate_hz <= peak_rate_hz or duration_s <= 0:
        raise ValueError("need 0 < base_rate_hz <= peak_rate_hz, duration > 0")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xD1E1]))
    times = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / peak_rate_hz))
        if t >= duration_s:
            break
        rate = base_rate_hz + (peak_rate_hz - base_rate_hz) * 0.5 * (
            1.0 + np.sin(2.0 * np.pi * t / period_s)
        )
        if rng.uniform() * peak_rate_hz < rate:
            times.append(t)
    return _finish(
        "diurnal", seed, np.asarray(times), fleet=fleet, n_fog=n_fog,
        rows=rows, duration_s=duration_s,
        meta={
            "base_rate_hz": float(base_rate_hz),
            "peak_rate_hz": float(peak_rate_hz), "period_s": float(period_s),
        },
    )
