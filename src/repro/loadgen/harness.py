"""Open-loop replay of an arrival trace against a scoring service.

The harness drives a :class:`~repro.serving.ScoringService` (or a
:class:`~repro.serving.MultiTenantService`) on a VIRTUAL clock: arrival
gaps advance simulated time instantly, while every micro-batch advances
it by the batch's *measured* device wall time (the service does this via
``clock.advance``).  Replay is open-loop — arrivals never wait for the
service, exactly like real telemetry — so the recorded per-request
latency is the true end-to-end number: queue wait + batch formation
(deadline policy) + device time.  That is the quantity
``ScoringService.step`` alone cannot see and ``benchmarks/load_bench``
gates in CI.

Between arrivals the harness fires every ``max_wait_s`` deadline at its
exact virtual expiry (``next_deadline`` / ``pump``), so adaptive
micro-batching behaves as it would under a real ticking clock; a final
drain phase flushes whatever the trace left behind (for a fixed-batch
service this is where the tail pain shows up — partial batches sit until
the horizon).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.loadgen.traces import ArrivalTrace


class VirtualClock:
    """Simulated seconds; the service advances it by measured device time.

    Satisfies the ``clock`` protocol of ``serving/service``: calling it
    reads the current time, ``advance`` (duck-typed) adds device seconds.
    """

    def __init__(self, t0: float = 0.0):
        self.now = float(t0)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        self.now += float(dt)

    def advance_to(self, t: float) -> None:
        """Monotonic jump — never rewinds past work already accounted."""
        self.now = max(self.now, float(t))


def gaussian_windows(
    trace: ArrivalTrace, d: int, seed: int = 0, scale: float = 1.0
) -> Callable[[int], np.ndarray]:
    """Deterministic per-event telemetry windows: event ``i`` always gets
    the same (rows, d) f32 draw, so replays are bit-replayable."""

    def window(i: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([seed, i]))
        return (scale * rng.standard_normal((trace.rows, d))).astype(np.float32)

    return window


@dataclasses.dataclass
class ReplayReport:
    """What one open-loop replay measured (see ``summary``)."""

    trace: dict                   # the trace's summary metadata
    n_events: int                 # events submitted
    completed: int                # requests fully scored
    e2e_latency_s: np.ndarray     # per completed request, submit -> result
    virtual_s: float              # simulated clock at the end of replay
    steps: int
    samples: int
    busy_s: float                 # cumulative device time
    partial_flushes: int
    compiles_by_bucket: dict[int, int]

    def _pct(self, pct: float) -> float:
        if self.e2e_latency_s.size == 0:
            return 0.0
        return float(np.percentile(self.e2e_latency_s, pct))

    def summary(self) -> dict:
        return {
            "n_events": self.n_events,
            "completed": self.completed,
            "e2e_p50_ms": self._pct(50.0) * 1e3,
            "e2e_p99_ms": self._pct(99.0) * 1e3,
            "e2e_mean_ms": (
                float(self.e2e_latency_s.mean()) * 1e3
                if self.e2e_latency_s.size else 0.0
            ),
            "e2e_max_ms": (
                float(self.e2e_latency_s.max()) * 1e3
                if self.e2e_latency_s.size else 0.0
            ),
            "virtual_s": self.virtual_s,
            "steps": self.steps,
            "samples": self.samples,
            "busy_s": self.busy_s,
            "samples_per_s": self.samples / self.busy_s if self.busy_s else 0.0,
            "mean_fill": self.samples / self.steps if self.steps else 0.0,
            "partial_flushes": self.partial_flushes,
            "compiles_by_bucket": dict(self.compiles_by_bucket),
        }


def _services(service: Any) -> list[Any]:
    """The underlying per-tenant services (or the service itself)."""
    if hasattr(service, "stats"):
        return [service]
    return [service.tenant(name) for name in service.tenants]


def _stats_totals(service: Any) -> tuple[int, int, float, int]:
    steps = samples = flushes = 0
    busy = 0.0
    for svc in _services(service):
        steps += svc.stats.steps
        samples += svc.stats.samples
        busy += svc.stats.busy_s
        flushes += svc.stats.partial_flushes
    return steps, samples, busy, flushes


def _collect_e2e(service: Any) -> np.ndarray:
    parts = [
        np.asarray(svc.stats.e2e_latency_s, np.float64)
        for svc in _services(service)
    ]
    parts = [p for p in parts if p.size]
    return np.concatenate(parts) if parts else np.zeros((0,), np.float64)


def replay(
    service: Any,
    trace: ArrivalTrace,
    clock: VirtualClock,
    *,
    windows: Callable[[int], np.ndarray] | None = None,
    d: int = 32,
    tenant_of: Callable[[int], str] | None = None,
    drain: bool = True,
) -> ReplayReport:
    """Replay ``trace`` open-loop against ``service`` on ``clock``.

    ``service`` must have been constructed with this ``clock`` (that is
    what timestamps submissions and completions).  ``windows`` maps event
    index -> (rows, d) telemetry (default :func:`gaussian_windows`);
    ``tenant_of`` maps event index -> tenant name for a
    ``MultiTenantService``.  The service should be freshly constructed —
    the report reads its cumulative stats.
    """
    windows = windows or gaussian_windows(trace, d)

    def fire_due_deadlines(horizon: float | None) -> None:
        # Flush every max_wait_s expiry strictly before `horizon` at its
        # exact virtual time (device time may push the clock past further
        # deadlines; the loop re-checks).
        while True:
            deadline = service.next_deadline()
            if deadline is None or (horizon is not None and deadline >= horizon):
                return
            clock.advance_to(deadline)
            if service.pump() == 0:
                return
    for i in range(trace.n_events):
        t_arrive = float(trace.t[i])
        fire_due_deadlines(t_arrive)
        clock.advance_to(t_arrive)
        x = windows(i)
        if tenant_of is None:
            service.submit(x, fog=int(trace.fog[i]))
        else:
            service.submit(tenant_of(i), x, fog=int(trace.fog[i]))
        service.pump()                     # full buckets flush immediately

    if drain:
        fire_due_deadlines(None)           # remaining deadline expiries
        service.drain()                    # fixed-batch leftovers flush NOW

    steps, samples, busy, flushes = _stats_totals(service)
    compiles = (
        dict(service.stats.compiles_by_bucket)
        if hasattr(service, "stats")
        else dict(service.compiles_by_bucket)
    )
    e2e = _collect_e2e(service)
    return ReplayReport(
        trace=trace.summary(),
        n_events=trace.n_events,
        completed=int(e2e.size),
        e2e_latency_s=e2e,
        virtual_s=float(clock.now),
        steps=steps,
        samples=samples,
        busy_s=busy,
        partial_flushes=flushes,
        compiles_by_bucket=compiles,
    )
