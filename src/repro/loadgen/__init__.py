"""Production load generation: seeded arrival traces + open-loop replay.

``traces``  — Poisson / bursty MMPP / diurnal arrival-trace generators
              (deterministic, pure numpy);
``harness`` — virtual-clock open-loop replay driving a ``ScoringService``
              or ``MultiTenantService`` and recording true end-to-end
              per-request latency (queue wait + batch formation + device
              time).
"""
from repro.loadgen.harness import (  # noqa: F401
    ReplayReport,
    VirtualClock,
    gaussian_windows,
    replay,
)
from repro.loadgen.traces import (  # noqa: F401
    ArrivalTrace,
    diurnal_trace,
    mmpp_trace,
    poisson_trace,
)
