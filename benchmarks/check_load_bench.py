"""Gate for the serving-load benchmark: latency/throughput trends plus
exact structural pins.

  python -m benchmarks.check_load_bench FRESH.json BASELINE.json

Four kinds of check against ``experiments/bench/load_bench.json``:

* latency trend — per (trace, config) replay row, ``e2e_p50_ms`` /
  ``e2e_p99_ms`` must not regress by more than THRESHOLD (3x, same noisy-
  runner allowance as the sibling gates); missing rows fail loudly.
* throughput trend — ``samples_per_s`` gated in the INVERSE direction
  (a >3x *drop* fails); reuses the same row index.
* exact pins (immune to runner noise):
  - every replay row's ``compiles_by_bucket`` is exactly one trace per
    configured bucket and matches the baseline row — a retrace under
    load (shape leak, cache split) shows up here;
  - the tenancy section keeps one compiled program per bucket TOTAL
    across tenants, and the per-tenant hot-swap stays isolated;
  - every replay completes every submitted event (``completed ==
    n_events`` — a dropped or duplicated request is a correctness bug,
    not noise).
* structure — on the bursty (mmpp) trace, the deadline+bucket policy
  must actually beat fixed batching: ``adaptive_bucketed`` p99 below
  ``fixed`` p99, computed WITHIN the fresh JSON so the check cannot be
  washed out by cross-run drift.  Int8 flag-mismatch fraction stays
  under INT8_MISMATCH_FRAC.
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks.check_kernel_micro import compare

THRESHOLD = 3.0
INT8_MISMATCH_FRAC = 0.02

LATENCY_CHECKS = (
    ("replays", ("trace", "config"), "e2e_p50_ms"),
    ("replays", ("trace", "config"), "e2e_p99_ms"),
)
THROUGHPUT_CHECKS = (("replays", ("trace", "config"), "samples_per_s"),)
# What bench_summary tracks for this json.
CHECKS = LATENCY_CHECKS + THROUGHPUT_CHECKS


def _index(rows, keys):
    return {tuple(r[k] for k in keys): r for r in rows}


def _norm_buckets(d: dict) -> dict:
    """JSON round-trips int dict keys as strings; compare canonically."""
    return {int(k): int(v) for k, v in (d or {}).items()}


def compare_throughput(fresh: dict, baseline: dict, threshold: float) -> list[str]:
    """Inverse-direction trend: throughput DROPS are regressions."""
    failures = []
    for table, keys, field in THROUGHPUT_CHECKS:
        fresh_rows = _index(fresh.get(table, []), keys)
        for row_key, base_row in _index(baseline.get(table, []), keys).items():
            if field not in base_row:
                continue
            tag = f"{table}[{dict(zip(keys, row_key))}].{field}"
            fresh_row = fresh_rows.get(row_key)
            if fresh_row is None or field not in fresh_row:
                failures.append(f"{tag}: missing from the fresh JSON")
                continue
            ratio = base_row[field] / max(fresh_row[field], 1e-9)
            line = (
                f"{tag}: {base_row[field]:.0f}/s -> {fresh_row[field]:.0f}/s "
                f"({ratio:.2f}x slower)"
            )
            if ratio > threshold:
                failures.append(line)
            else:
                print(f"ok   {line}")
    return failures


def check_exact(fresh: dict, baseline: dict) -> list[str]:
    failures = []
    base_rows = _index(baseline.get("replays", []), ("trace", "config"))
    for row in fresh.get("replays", []):
        tag = f"replays[{row['trace']}/{row['config']}]"
        compiles = _norm_buckets(row.get("compiles_by_bucket"))
        if any(v != 1 for v in compiles.values()) or not compiles:
            failures.append(
                f"{tag}: compiles_by_bucket {compiles} != one trace per bucket"
            )
        base = base_rows.get((row["trace"], row["config"]))
        if base is not None and _norm_buckets(
            base.get("compiles_by_bucket")
        ) != compiles:
            failures.append(
                f"{tag}: compiles_by_bucket {compiles} != baseline "
                f"{_norm_buckets(base.get('compiles_by_bucket'))}"
            )
        if row.get("completed") != row.get("n_events"):
            failures.append(
                f"{tag}: completed {row.get('completed')} != submitted "
                f"{row.get('n_events')} events"
            )
    for row_key, base in base_rows.items():
        if row_key not in _index(fresh.get("replays", []), ("trace", "config")):
            failures.append(f"replays[{row_key}]: missing from the fresh JSON")
    ten = fresh.get("tenancy", {})
    t_compiles = _norm_buckets(ten.get("compiles_by_bucket"))
    if any(v != 1 for v in t_compiles.values()) or not t_compiles:
        failures.append(
            f"tenancy: compiles_by_bucket {t_compiles} != one compiled "
            "program per bucket across all tenants"
        )
    if not ten.get("swap_isolated", False):
        failures.append(
            f"tenancy: per-tenant hot-swap not isolated "
            f"(loaded_step={ten.get('loaded_step')})"
        )
    return failures


def check_structure(fresh: dict) -> list[str]:
    """Fresh-internal invariants: the policies must earn their keep."""
    failures = []
    rows = _index(fresh.get("replays", []), ("trace", "config"))
    fixed = rows.get(("mmpp", "fixed"))
    bucketed = rows.get(("mmpp", "adaptive_bucketed"))
    if fixed is None or bucketed is None:
        failures.append("structure: mmpp fixed/adaptive_bucketed rows missing")
    elif bucketed["e2e_p99_ms"] >= fixed["e2e_p99_ms"]:
        failures.append(
            "structure: adaptive_bucketed p99 "
            f"{bucketed['e2e_p99_ms']:.1f}ms does not beat fixed p99 "
            f"{fixed['e2e_p99_ms']:.1f}ms on the bursty trace"
        )
    else:
        print(
            f"ok   mmpp p99: adaptive_bucketed {bucketed['e2e_p99_ms']:.1f}ms"
            f" < fixed {fixed['e2e_p99_ms']:.1f}ms"
        )
    parity = fresh.get("int8_parity", {})
    frac = parity.get("flag_mismatch_frac")
    if frac is None:
        failures.append("structure: int8_parity section missing")
    elif frac > INT8_MISMATCH_FRAC:
        failures.append(
            f"structure: int8 flag mismatch frac {frac:.4f} > "
            f"{INT8_MISMATCH_FRAC}"
        )
    else:
        print(f"ok   int8 flag mismatch frac {frac:.4f}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly generated load_bench.json")
    ap.add_argument("baseline", help="committed baseline load_bench.json")
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures = compare(fresh, baseline, args.threshold, LATENCY_CHECKS, unit="ms")
    failures += compare_throughput(fresh, baseline, args.threshold)
    failures += check_exact(fresh, baseline)
    failures += check_structure(fresh)
    if failures:
        print(f"LOAD BENCH GATE FAILED ({len(failures)} check(s)):")
        for line in failures:
            print(f"FAIL {line}")
        print(
            "If this PR intentionally changed the load benchmark, regenerate "
            "the baseline: PYTHONPATH=src python -m benchmarks.run "
            "--only load_bench"
        )
        return 1
    print(f"load_bench within {args.threshold}x of the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
