"""Trend gate for the dynamic-world benchmark (sibling of
``check_robustness_bench``).

  python -m benchmarks.check_drift_bench FRESH.json BASELINE.json

Contracts, all on the committed ``drift_bench.json`` quantities:

* frozen association degrades: under drift the ``frozen`` cell must shed
  at least ``--part-margin`` participation vs the ``static`` anchor —
  stale assignments must demonstrably stop covering the moving fleet;
* re-association holds: the ``reassoc`` cell stays within ``--part-tol``
  participation of the anchor AND within ``--f1-tol`` F1 of it (and every
  drift cell keeps F1 at the anchor level — drift must not corrupt the
  model, only the cohort);
* adaptive attack collapses the mean: ``adaptive-mean`` sits at least
  ``--degrade-margin`` F1 below the ``clean-mean`` anchor;
* robust rules survive the adaptive attack: ``adaptive-trimmed`` and
  ``adaptive-median`` stay within ``--f1-tol`` of ``clean-mean``;
* graceful degradation: zero non-finite global-model rounds anywhere;
* one program per shape-class: ``sweep_compiled_programs <= n_classes``
  (the drift trio must co-batch via the ``active=True`` pin).

A vanished row fails loudly, exactly like the other gates.
"""
from __future__ import annotations

import argparse
import json
import sys

F1_TOL = 0.12
DEGRADE_MARGIN = 0.30
PART_MARGIN = 0.08
PART_TOL = 0.06


def _rows(res: dict) -> dict:
    return {r["cell"]: r for r in res.get("rows", [])}


def compare(
    fresh: dict,
    baseline: dict,
    f1_tol: float = F1_TOL,
    degrade_margin: float = DEGRADE_MARGIN,
    part_margin: float = PART_MARGIN,
    part_tol: float = PART_TOL,
) -> list[str]:
    failures = []
    fresh_rows, base_rows = _rows(fresh), _rows(baseline)

    for cell in base_rows:
        if cell not in fresh_rows:
            failures.append(f"rows[{cell}]: missing from the fresh JSON")

    static = fresh_rows.get("static")
    clean = fresh_rows.get("clean-mean")
    if static is None or clean is None:
        failures.append(
            "rows[static] / rows[clean-mean]: anchor row missing — "
            "nothing to compare against"
        )
        return failures

    # Zero NaN rounds everywhere (graceful degradation).
    for cell, row in sorted(fresh_rows.items()):
        if row.get("nonfinite_rounds", 0.0) != 0.0:
            failures.append(
                f"rows[{cell}]: {row['nonfinite_rounds']:g} non-finite "
                "global-model round(s)"
            )

    # --- drift grid: participation carries the degradation story.
    frozen = fresh_rows.get("frozen")
    reassoc = fresh_rows.get("reassoc")
    if frozen is not None:
        line = (f"rows[frozen].participation: {frozen['participation']:.3f} "
                f"vs static {static['participation']:.3f}")
        if static["participation"] - frozen["participation"] < part_margin:
            failures.append(
                f"{line} (frozen association no longer degrades by "
                f"{part_margin} — the drift scenario demonstrates nothing)"
            )
        else:
            print(f"ok   {line} (collapsed, as the benchmark requires)")
    if reassoc is not None:
        line = (f"rows[reassoc].participation: "
                f"{reassoc['participation']:.3f} vs static "
                f"{static['participation']:.3f}")
        if static["participation"] - reassoc["participation"] > part_tol:
            failures.append(f"{line} (re-association lost > {part_tol})")
        else:
            print(f"ok   {line}")
    for cell in ("static", "frozen", "reassoc"):
        row = fresh_rows.get(cell)
        if row is None:
            continue
        line = (f"rows[{cell}].f1_mean: {row['f1_mean']:.3f} vs static "
                f"{static['f1_mean']:.3f}")
        if static["f1_mean"] - row["f1_mean"] > f1_tol:
            failures.append(f"{line} (dropped > {f1_tol})")
        elif cell != "static":
            print(f"ok   {line}")

    # --- attack grid: F1 carries the story (corruption moves the model).
    attacked_mean = fresh_rows.get("adaptive-mean")
    if attacked_mean is not None:
        line = (f"rows[adaptive-mean].f1_mean: "
                f"{attacked_mean['f1_mean']:.3f} vs clean "
                f"{clean['f1_mean']:.3f}")
        if clean["f1_mean"] - attacked_mean["f1_mean"] < degrade_margin:
            failures.append(
                f"{line} (adaptive attack no longer collapses the mean by "
                f"{degrade_margin})"
            )
        else:
            print(f"ok   {line} (collapsed, as the benchmark requires)")
    for cell in ("adaptive-trimmed", "adaptive-median"):
        row = fresh_rows.get(cell)
        if row is None:
            continue
        line = (f"rows[{cell}].f1_mean: {row['f1_mean']:.3f} vs clean "
                f"{clean['f1_mean']:.3f}")
        if clean["f1_mean"] - row["f1_mean"] > f1_tol:
            failures.append(f"{line} (dropped > {f1_tol})")
        else:
            print(f"ok   {line}")

    # --- vs the committed baseline: anchors and robust cells must not
    # drift down (the attacked mean collapsing harder is not a regression).
    for cell, row in sorted(fresh_rows.items()):
        base_row = base_rows.get(cell)
        if base_row is None or cell == "adaptive-mean":
            continue
        line = (f"rows[{cell}].f1_mean: baseline "
                f"{base_row['f1_mean']:.3f} -> {row['f1_mean']:.3f}")
        if base_row["f1_mean"] - row["f1_mean"] > f1_tol:
            failures.append(f"{line} (dropped > {f1_tol})")
        else:
            print(f"ok   {line}")

    # --- one compiled program per shape-class.
    eng = fresh.get("engine") or {}
    n_classes = fresh.get("n_classes")
    if eng and n_classes:
        compiled = eng.get("sweep_compiled_programs")
        cells = eng.get("sweep_cells")
        line = (f"engine: {compiled} compiled program(s) for {cells} cells, "
                f"{n_classes} shape-classes")
        if compiled is None or compiled > n_classes:
            failures.append(f"{line} (config-axis batching regressed)")
        else:
            print(f"ok   {line}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly generated drift_bench.json")
    ap.add_argument("baseline", help="committed baseline drift_bench.json")
    ap.add_argument("--f1-tol", type=float, default=F1_TOL)
    ap.add_argument("--degrade-margin", type=float, default=DEGRADE_MARGIN)
    ap.add_argument("--part-margin", type=float, default=PART_MARGIN)
    ap.add_argument("--part-tol", type=float, default=PART_TOL)
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(
        fresh, baseline, args.f1_tol, args.degrade_margin,
        args.part_margin, args.part_tol,
    )
    if failures:
        print("DRIFT REGRESSION:")
        for line in failures:
            print(f"FAIL {line}")
        print(
            "If this PR intentionally changed the drift model, the "
            "re-association cadence semantics, or the adaptive attack, "
            "regenerate the baseline: "
            "PYTHONPATH=src python -m benchmarks.run --only drift_bench"
        )
        return 1
    print("drift_bench within tolerance of the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
