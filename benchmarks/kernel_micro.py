"""Microbenchmark of the compression kernels (CPU interpret mode): wall
time per call + payload accounting.  On CPU the numbers establish
correctness-path cost only; the TPU roofline for these ops is in
EXPERIMENTS.md (they are HBM-bandwidth-bound single-pass kernels).

Three tables:

* ``rows``      — the per-client compress op at flat-vector sizes;
* ``agg_rows``  — the fused compress-and-aggregate op (one program:
  EF Top-K + int8 + weighted fog accumulation) against the unfused
  compress -> segment-sum baseline (two programs with the dense (N, d)
  reconstruction materialised between them);
* ``local_train_rows`` — the fused local-train solver (the whole E-epoch
  client phase indexing each client's resident window;
  ``optim/sgd.make_client_solver`` default) against the legacy per-client
  ``local_sgd`` scan over a gathered (E * nb, bs, D) batch stream, across
  client counts.  The committed JSON is the perf-trend baseline CI
  compares against (benchmarks/check_kernel_micro).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ops
from repro.models import autoencoder as ae
from repro.optim.sgd import LocalTrainConfig, make_client_solver

SIZES = (1352, 65536, 1048576)

# (n_clients, d) cells for the fused aggregate op; n_fog = n_clients // 4.
# The last cell is the 1M-element size (16 * 65536 = 1 048 576).
AGG_SIZES = ((8, 1352), (16, 65536))
K_FRAC = 0.05

# (n_clients, window) cells for the fused local-train solver; feature dim,
# batch size and epochs stay at the paper's Table II values.
LT_SIZES = ((16, 256), (64, 256), (256, 256))
LT_D, LT_BS, LT_EPOCHS, LT_LR = 32, 32, 5, 0.01


def _time(fn, *args, reps=5):
    """Min over ``reps`` individually blocked calls — the min estimator is
    what the CI perf-trend gate compares, and unlike an async-smeared mean
    it is stable on noisy shared runners."""
    fn(*args)  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x,
            out,
        )
        best = min(best, time.time() - t0)
    return best * 1e6


def _paired_time(pair, args, reps: int = 16) -> dict[str, float]:
    """Paired-ratio timing for two pipelines over the same inputs: warm
    (compile) both, then time INTERLEAVED single blocked calls with
    alternating within-pair order, and report the MIN of each — the same
    estimator as :func:`_time` and the CI perf-trend gate.  On a shared
    runner the min is the uncontended cost; means/medians get corrupted
    by multi-call contention storms that hit whichever pipeline is
    unlucky.  ``pair`` is ((name, fn), (name, fn)); fns return a tuple
    whose first leaf supports ``block_until_ready``.
    """
    for _, fn in pair:
        fn(*args)
    times: dict[str, list[float]] = {name: [] for name, _ in pair}
    for rep in range(reps):
        for name, fn in pair if rep % 2 == 0 else pair[::-1]:
            t0 = time.time()
            out = fn(*args)
            out[0].block_until_ready()
            times[name].append((time.time() - t0) * 1e6)
    return {name: min(ts) for name, ts in times.items()}


def _agg_inputs(n_clients: int, d: int):
    key = jax.random.key(n_clients * d)
    deltas = jax.random.normal(key, (n_clients, d))
    errs = jax.random.normal(jax.random.fold_in(key, 1), (n_clients, d)) * 0.1
    n_fog = max(2, n_clients // 4)
    fog_id = jnp.arange(n_clients, dtype=jnp.int32) % n_fog
    weights = jnp.ones((n_clients,), jnp.float32)
    return deltas, errs, fog_id, weights, n_fog


def _unfused_baseline(n_fog: int):
    """The legacy two-program pipeline: batched compress, then a separate
    jitted weighted segment-sum over the dense reconstructions."""
    compress = jax.jit(
        jax.vmap(lambda dd, ee: ops.compress(dd, ee, K_FRAC, False)[:2])
    )
    aggregate = jax.jit(
        lambda recon, fid, w: jax.ops.segment_sum(
            recon * w[:, None], fid, num_segments=n_fog
        )
    )

    def run(deltas, errs, fog_id, weights):
        recon, new_err = compress(deltas, errs)
        return aggregate(recon, fog_id, weights), new_err

    return run


def run(scale: common.Scale) -> dict:
    rows = []
    for n in SIZES:
        delta = jax.random.normal(jax.random.key(n), (n,))
        err = jnp.zeros((n,))
        us_ref = _time(lambda d, e: ops.compress(d, e, K_FRAC, False), delta, err)
        us_pl = _time(lambda d, e: ops.compress(d, e, K_FRAC, True, True), delta, err)
        _, _, bits = ops.compress(delta, err, K_FRAC, False)
        rows.append(
            dict(n=n, us_ref=us_ref, us_pallas_interpret=us_pl,
                 payload_bits=float(bits), dense_bits=32.0 * n)
        )

    agg_rows = []
    for n_clients, d in AGG_SIZES:
        deltas, errs, fog_id, weights, n_fog = _agg_inputs(n_clients, d)
        args = (deltas, errs, fog_id, weights)
        fused = lambda D, E, F, W: ops.compress_aggregate(  # noqa: E731
            D, E, F, W, n_fog, K_FRAC, use_pallas=False
        )
        # Sparse-wire twin (PR 10): emit (idx, int8, scale) and
        # scatter-accumulate it — no dense per-client reconstruction.
        wire = lambda D, E, F, W: ops.compress_aggregate_wire(  # noqa: E731
            D, E, F, W, n_fog, K_FRAC, use_pallas=False
        )
        unfused = _unfused_baseline(n_fog)
        best = _paired_time((("fused", fused), ("unfused", unfused)), args)
        us_fused, us_unfused = best["fused"], best["unfused"]
        us_wire = _paired_time((("wire", wire), ("fused", fused)), args)["wire"]

        def _temp_bytes(fn):
            """Peak device memory of the compiled program's INTERMEDIATES
            (``memory_analysis().temp_size_in_bytes``) — the column the
            wire format exists to shrink."""
            compiled = jax.jit(fn).lower(*args).compile()
            return int(compiled.memory_analysis().temp_size_in_bytes)

        agg_rows.append(
            dict(n_clients=n_clients, d=d, elems=n_clients * d, n_fog=n_fog,
                 us_fused_ref=us_fused, us_unfused_ref=us_unfused,
                 us_wire_ref=us_wire,
                 speedup=us_unfused / us_fused,
                 temp_fused_bytes=_temp_bytes(fused),
                 temp_wire_bytes=_temp_bytes(wire),
                 temp_unfused_bytes=_temp_bytes(
                     lambda D, E, F, W: unfused(D, E, F, W)
                 ))
        )

    lt_rows = []
    params = ae.init(jax.random.key(1), LT_D, (16, 8, 16))
    for n_clients, window in LT_SIZES:
        data = jax.random.normal(
            jax.random.key(n_clients), (n_clients, window, LT_D)
        )
        keys = jax.random.split(jax.random.key(2), n_clients)
        fused = jax.jit(make_client_solver(
            ae.loss, batch_size=LT_BS, epochs=LT_EPOCHS, lr=LT_LR
        ))
        scan = jax.jit(make_client_solver(
            ae.loss, batch_size=LT_BS, epochs=LT_EPOCHS, lr=LT_LR,
            solver=LocalTrainConfig(fused=False),
        ))
        best = _paired_time(
            (("fused", fused), ("scan", scan)), (params, data, keys)
        )
        us_fused, us_scan = best["fused"], best["scan"]
        nb = window // LT_BS
        lt_rows.append(
            dict(n_clients=n_clients, window=window, d_feat=LT_D,
                 epochs=LT_EPOCHS, batch_size=LT_BS,
                 stream_elems=n_clients * LT_EPOCHS * nb * LT_BS * LT_D,
                 us_fused_ref=us_fused, us_scan_ref=us_scan,
                 speedup=us_scan / us_fused)
        )
    return {"rows": rows, "agg_rows": agg_rows, "local_train_rows": lt_rows}


def report(res: dict) -> str:
    lines = ["kernel_micro (compress = EF + blockwise topk + int8)"]
    lines.append(
        f"{'n':>9} {'jnp-ref us':>12} {'pallas(interp) us':>18} {'ratio':>7} {'payload':>10}"
    )
    for r in res["rows"]:
        lines.append(
            f"{r['n']:>9} {r['us_ref']:>12.0f} {r['us_pallas_interpret']:>18.0f} "
            f"{r['payload_bits'] / r['dense_bits']:>7.3f} "
            f"{r['payload_bits']:>10.0f}"
        )
    lines.append("fused compress-and-aggregate vs sparse-wire vs unfused"
                 " compress->segment-sum (jnp ref path; temp = compiled peak"
                 " intermediate memory)")
    lines.append(
        f"{'NxD':>14} {'elems':>9} {'fused us':>10} {'wire us':>9} "
        f"{'unfused us':>11} {'speedup':>8} {'tmp f MB':>9} {'tmp w MB':>9} "
        f"{'tmp u MB':>9}"
    )
    for r in res["agg_rows"]:
        lines.append(
            f"{r['n_clients']:>5}x{r['d']:<8} {r['elems']:>9} "
            f"{r['us_fused_ref']:>10.0f} {r.get('us_wire_ref', 0):>9.0f} "
            f"{r['us_unfused_ref']:>11.0f} {r['speedup']:>8.2f} "
            f"{r.get('temp_fused_bytes', 0) / 1e6:>9.2f} "
            f"{r.get('temp_wire_bytes', 0) / 1e6:>9.2f} "
            f"{r.get('temp_unfused_bytes', 0) / 1e6:>9.2f}"
        )
    lines.append("fused local-train (resident window) vs per-client scan over"
                 " a gathered batch stream (jnp ref path)")
    lines.append(
        f"{'NxWindow':>14} {'stream':>9} {'fused us':>10} {'scan us':>11} {'speedup':>8}"
    )
    for r in res["local_train_rows"]:
        lines.append(
            f"{r['n_clients']:>5}x{r['window']:<8} {r['stream_elems']:>9} "
            f"{r['us_fused_ref']:>10.0f} {r['us_scan_ref']:>11.0f} "
            f"{r['speedup']:>8.2f}"
        )
    return "\n".join(lines)
