"""Microbenchmark of the compression kernels (CPU interpret mode): wall
time per call + payload accounting.  On CPU the numbers establish
correctness-path cost only; the TPU roofline for these ops is in
EXPERIMENTS.md (they are HBM-bandwidth-bound single-pass kernels)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ops

SIZES = (1352, 65536, 1048576)


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
        out,
    )
    return (time.time() - t0) / reps * 1e6


def run(scale: common.Scale) -> dict:
    rows = []
    for n in SIZES:
        delta = jax.random.normal(jax.random.key(n), (n,))
        err = jnp.zeros((n,))
        us_ref = _time(lambda d, e: ops.compress(d, e, 0.05, False), delta, err)
        us_pl = _time(lambda d, e: ops.compress(d, e, 0.05, True, True), delta, err)
        _, _, bits = ops.compress(delta, err, 0.05, False)
        rows.append(
            dict(n=n, us_ref=us_ref, us_pallas_interpret=us_pl,
                 payload_bits=float(bits), dense_bits=32.0 * n)
        )
    return {"rows": rows}


def report(res: dict) -> str:
    lines = ["kernel_micro (compress = EF + blockwise topk + int8)"]
    lines.append(
        f"{'n':>9} {'jnp-ref us':>12} {'pallas(interp) us':>18} {'ratio':>7} {'payload':>10}"
    )
    for r in res["rows"]:
        lines.append(
            f"{r['n']:>9} {r['us_ref']:>12.0f} {r['us_pallas_interpret']:>18.0f} "
            f"{r['payload_bits'] / r['dense_bits']:>7.3f} "
            f"{r['payload_bits']:>10.0f}"
        )
    return "\n".join(lines)
