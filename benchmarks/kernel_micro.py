"""Microbenchmark of the compression kernels (CPU interpret mode): wall
time per call + payload accounting.  On CPU the numbers establish
correctness-path cost only; the TPU roofline for these ops is in
EXPERIMENTS.md (they are HBM-bandwidth-bound single-pass kernels).

Two tables:

* ``rows``      — the per-client compress op at flat-vector sizes;
* ``agg_rows``  — the fused compress-and-aggregate op (one program:
  EF Top-K + int8 + weighted fog accumulation) against the unfused
  compress -> segment-sum baseline (two programs with the dense (N, d)
  reconstruction materialised between them).  The committed JSON is the
  perf-trend baseline CI compares against (benchmarks/check_kernel_micro).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ops

SIZES = (1352, 65536, 1048576)

# (n_clients, d) cells for the fused aggregate op; n_fog = n_clients // 4.
# The last cell is the 1M-element size (16 * 65536 = 1 048 576).
AGG_SIZES = ((8, 1352), (16, 65536))
K_FRAC = 0.05


def _time(fn, *args, reps=5):
    """Min over ``reps`` individually blocked calls — the min estimator is
    what the CI perf-trend gate compares, and unlike an async-smeared mean
    it is stable on noisy shared runners."""
    fn(*args)  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x,
            out,
        )
        best = min(best, time.time() - t0)
    return best * 1e6


def _agg_inputs(n_clients: int, d: int):
    key = jax.random.key(n_clients * d)
    deltas = jax.random.normal(key, (n_clients, d))
    errs = jax.random.normal(jax.random.fold_in(key, 1), (n_clients, d)) * 0.1
    n_fog = max(2, n_clients // 4)
    fog_id = jnp.arange(n_clients, dtype=jnp.int32) % n_fog
    weights = jnp.ones((n_clients,), jnp.float32)
    return deltas, errs, fog_id, weights, n_fog


def _unfused_baseline(n_fog: int):
    """The legacy two-program pipeline: batched compress, then a separate
    jitted weighted segment-sum over the dense reconstructions."""
    compress = jax.jit(
        jax.vmap(lambda dd, ee: ops.compress(dd, ee, K_FRAC, False)[:2])
    )
    aggregate = jax.jit(
        lambda recon, fid, w: jax.ops.segment_sum(
            recon * w[:, None], fid, num_segments=n_fog
        )
    )

    def run(deltas, errs, fog_id, weights):
        recon, new_err = compress(deltas, errs)
        return aggregate(recon, fog_id, weights), new_err

    return run


def run(scale: common.Scale) -> dict:
    rows = []
    for n in SIZES:
        delta = jax.random.normal(jax.random.key(n), (n,))
        err = jnp.zeros((n,))
        us_ref = _time(lambda d, e: ops.compress(d, e, K_FRAC, False), delta, err)
        us_pl = _time(lambda d, e: ops.compress(d, e, K_FRAC, True, True), delta, err)
        _, _, bits = ops.compress(delta, err, K_FRAC, False)
        rows.append(
            dict(n=n, us_ref=us_ref, us_pallas_interpret=us_pl,
                 payload_bits=float(bits), dense_bits=32.0 * n)
        )

    agg_rows = []
    for n_clients, d in AGG_SIZES:
        deltas, errs, fog_id, weights, n_fog = _agg_inputs(n_clients, d)
        args = (deltas, errs, fog_id, weights)
        fused = lambda D, E, F, W: ops.compress_aggregate(  # noqa: E731
            D, E, F, W, n_fog, K_FRAC, use_pallas=False
        )
        unfused = _unfused_baseline(n_fog)
        # Warm (compile) both, then time INTERLEAVED single blocked calls
        # with alternating within-pair order, and report the MIN of each —
        # the same estimator as _time and the CI perf-trend gate.  On a
        # shared runner the min is the uncontended cost; means/medians get
        # corrupted by multi-call contention storms that hit whichever
        # pipeline is unlucky.
        fused(*args), unfused(*args)
        times = {"fused": [], "unfused": []}
        pair = (("fused", fused), ("unfused", unfused))
        for rep in range(16):
            for name, fn in pair if rep % 2 == 0 else pair[::-1]:
                t0 = time.time()
                out = fn(*args)
                out[0].block_until_ready()
                times[name].append((time.time() - t0) * 1e6)
        us_fused = min(times["fused"])
        us_unfused = min(times["unfused"])
        agg_rows.append(
            dict(n_clients=n_clients, d=d, elems=n_clients * d, n_fog=n_fog,
                 us_fused_ref=us_fused, us_unfused_ref=us_unfused,
                 speedup=us_unfused / us_fused)
        )
    return {"rows": rows, "agg_rows": agg_rows}


def report(res: dict) -> str:
    lines = ["kernel_micro (compress = EF + blockwise topk + int8)"]
    lines.append(
        f"{'n':>9} {'jnp-ref us':>12} {'pallas(interp) us':>18} {'ratio':>7} {'payload':>10}"
    )
    for r in res["rows"]:
        lines.append(
            f"{r['n']:>9} {r['us_ref']:>12.0f} {r['us_pallas_interpret']:>18.0f} "
            f"{r['payload_bits'] / r['dense_bits']:>7.3f} "
            f"{r['payload_bits']:>10.0f}"
        )
    lines.append("fused compress-and-aggregate vs unfused compress->segment-sum"
                 " (jnp ref path)")
    lines.append(
        f"{'NxD':>14} {'elems':>9} {'fused us':>10} {'unfused us':>11} {'speedup':>8}"
    )
    for r in res["agg_rows"]:
        lines.append(
            f"{r['n_clients']:>5}x{r['d']:<8} {r['elems']:>9} "
            f"{r['us_fused_ref']:>10.0f} {r['us_unfused_ref']:>11.0f} "
            f"{r['speedup']:>8.2f}"
        )
    return "\n".join(lines)
