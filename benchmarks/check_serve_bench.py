"""Perf-trend gate for the serving benchmark (sibling of
``check_kernel_micro``, same estimator and threshold semantics).

  python -m benchmarks.check_serve_bench FRESH.json BASELINE.json

Fails on a >3x regression of any fused score-kernel row
(``score_rows[*].us_fused_ref``) against the committed
``experiments/bench/serve_bench.json`` — the structural-regression
tripwire for the serving hot path (an accidentally de-jitted score
program, a dense reconstruction sneaking back into the pipeline, ...).
"""
from __future__ import annotations

import sys

from benchmarks.check_kernel_micro import gate_main

CHECKS = (("score_rows", ("fleet", "window"), "us_fused_ref"),)


def main() -> int:
    return gate_main(CHECKS, name="serve_bench")


if __name__ == "__main__":
    sys.exit(main())
