"""Serving microbenchmark: the fused score kernel against the unfused
scoring pipeline, plus a micro-batching service smoke with hot-swap.

Two sections:

* ``score_rows`` — per (fleet, window) telemetry size: one fused program
  (``serving/score``: AE forward + error + threshold compare, no dense
  reconstruction in HBM) vs the unfused three-program baseline
  (``models/autoencoder.apply`` materialising the (R, d) reconstruction,
  then ``core/anomaly``-style error + flag programs).  Min-estimator,
  interleaved, same protocol as kernel_micro's ``agg_rows``; the committed
  JSON is the perf-trend baseline for ``benchmarks/check_serve_bench``.
* ``service`` — a :class:`repro.serving.ScoringService` driven over a
  request stream with a mid-stream checkpoint publish: samples/sec,
  p50/p99 micro-batch latency, swap and compile counts (the latter pinned
  to 1 — fixed micro-batch shapes never retrace).
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.checkpoint import CheckpointStore
from repro.models import autoencoder as ae
from repro.serving import ScoringService
from repro.serving.score import score as fused_score

D = 32                                   # paper Table II feature dim
HIDDEN = (16, 8, 16)
SIZES = ((16, 32), (64, 64), (256, 256))  # (fleet, window): 512..65536 rows
REPS = 16


def _unfused_pipeline():
    """The legacy three-program serving path: dense reconstruction in HBM
    between separately dispatched forward / error / flag programs."""
    fwd = jax.jit(lambda p, x: ae.apply(p, x))
    errf = jax.jit(lambda x, r: jnp.sum(jnp.square(x - r), axis=-1))
    flagf = jax.jit(lambda e, t: e > t)

    def run(params, x, tau):
        recon = fwd(params, x)
        err = errf(x, recon)
        return err, flagf(err, tau)

    return run


def run(scale: common.Scale) -> dict:
    params = ae.init(jax.random.key(0), D, HIDDEN)
    tau = jnp.float32(1.0)

    score_rows = []
    for fleet, window in SIZES:
        rows = fleet * window
        x = jax.random.normal(jax.random.key(rows), (rows, D))
        fused = jax.jit(
            lambda p, xx, t: fused_score(p, xx, t, use_pallas=False)
        )
        unfused = _unfused_pipeline()
        # Warm both, then interleave single blocked calls with alternating
        # within-pair order and keep the MIN — the kernel_micro estimator.
        fused(params, x, tau)[0].block_until_ready()
        unfused(params, x, tau)[0].block_until_ready()
        times = {"fused": [], "unfused": []}
        pair = (("fused", fused), ("unfused", unfused))
        for rep in range(REPS):
            for name, fn in pair if rep % 2 == 0 else pair[::-1]:
                t0 = time.time()
                err, _ = fn(params, x, tau)
                err.block_until_ready()
                times[name].append((time.time() - t0) * 1e6)
        us_fused = min(times["fused"])
        us_unfused = min(times["unfused"])
        score_rows.append(
            dict(fleet=fleet, window=window, rows=rows, d=D,
                 us_fused_ref=us_fused, us_unfused_ref=us_unfused,
                 speedup=us_unfused / us_fused,
                 samples_per_s=rows / (us_fused * 1e-6))
        )

    # --- service smoke: stream + mid-stream hot-swap ----------------------
    with tempfile.TemporaryDirectory(prefix="serve_bench_") as ckpt_dir:
        store = CheckpointStore(ckpt_dir, keep=2)
        store.publish(1, params)
        svc = ScoringService(store, params, batch_rows=4096, tau=1.0)
        fleet, window = SIZES[-1]
        telemetry = np.asarray(
            jax.random.normal(jax.random.key(7), (fleet, window, D))
        )
        n_requests = 4 if scale.quick else 16
        for _ in range(n_requests // 2):
            svc.submit(telemetry)
        svc.drain()
        store.publish(2, jax.tree_util.tree_map(lambda a: a * 0.9, params))
        svc.poll()
        for _ in range(n_requests // 2):
            svc.submit(telemetry)
        svc.drain()
        service = svc.stats.summary()
        service["hot_swapped"] = svc.loaded_step == 2

    return {"score_rows": score_rows, "service": service}


def report(res: dict) -> str:
    lines = ["serve_bench (fused score = AE fwd + err + threshold, one pass)"]
    lines.append(
        f"{'fleetxwin':>12} {'rows':>7} {'fused us':>10} {'unfused us':>11} "
        f"{'speedup':>8} {'samples/s':>12}"
    )
    for r in res["score_rows"]:
        lines.append(
            f"{r['fleet']:>5}x{r['window']:<6} {r['rows']:>7} "
            f"{r['us_fused_ref']:>10.0f} {r['us_unfused_ref']:>11.0f} "
            f"{r['speedup']:>8.2f} {r['samples_per_s']:>12.0f}"
        )
    s = res["service"]
    lines.append(
        f"service: {s['samples']} samples / {s['steps']} micro-batches, "
        f"device-step p50 {s['step_p50_ms']:.2f} ms p99 {s['step_p99_ms']:.2f} ms, "
        f"{s['samples_per_s']:.0f} samples/s, swaps={s['swaps']} "
        f"compiles={s['compiles']}"
    )
    return "\n".join(lines)
