"""Trend gate for the async-family benchmark (sibling of
``check_kernel_micro`` / ``check_serve_bench`` / ``check_sweep_compile``).

  python -m benchmarks.check_async_bench FRESH.json BASELINE.json

Unlike the kernel gates this one checks SIMULATED time, which is
deterministic for a given seed — so the threshold is tight (default
1.25x), not the 3x wall-clock noise allowance.  Checked against the
committed ``experiments/bench/async_bench.json``:

* per (alpha, buffer_frac) row: ``sim_s_per_merge`` must not exceed the
  baseline by more than the threshold, ``speedup_vs_sync`` must not
  shrink below baseline/threshold, and ``f1_mean`` must not drop by more
  than ``--f1-tol`` (absolute);
* the sync row's ``sim_s_per_round`` gets the same ratio check (a
  latency-model change that slows BOTH paths would otherwise hide in the
  speedup ratio);
* a vanished row fails loudly, exactly like the kernel gates.
"""
from __future__ import annotations

import argparse
import json
import sys

THRESHOLD = 1.25
F1_TOL = 0.08


def _row_key(row: dict) -> tuple:
    # "physics" = the Eq.-21 latency-model clock; "mmpp" = the PR-10
    # trace-replay cell whose arrivals come from a loadgen ArrivalTrace.
    return (row["alpha"], row["buffer_frac"], row.get("arrival", "physics"))


def compare(
    fresh: dict,
    baseline: dict,
    threshold: float = THRESHOLD,
    f1_tol: float = F1_TOL,
) -> list[str]:
    failures = []

    def ratio_check(tag, base_v, fresh_v, *, larger_is_worse):
        if fresh_v is None:
            failures.append(f"{tag}: missing from the fresh JSON")
            return
        ratio = (
            fresh_v / max(base_v, 1e-9)
            if larger_is_worse else base_v / max(fresh_v, 1e-9)
        )
        line = f"{tag}: {base_v:.3f} -> {fresh_v:.3f} ({ratio:.2f}x)"
        if ratio > threshold:
            failures.append(line)
        else:
            print(f"ok   {line}")

    base_sync = baseline.get("sync") or {}
    if "sim_s_per_round" in base_sync:
        ratio_check(
            "sync.sim_s_per_round",
            base_sync["sim_s_per_round"],
            (fresh.get("sync") or {}).get("sim_s_per_round"),
            larger_is_worse=True,
        )

    fresh_rows = {_row_key(r): r for r in fresh.get("rows", [])}
    for base_row in baseline.get("rows", []):
        key = _row_key(base_row)
        tag = f"rows[alpha={key[0]:g},buf={key[1]:g},{key[2]}]"
        fresh_row = fresh_rows.get(key)
        if fresh_row is None:
            failures.append(f"{tag}: missing from the fresh JSON")
            continue
        ratio_check(
            f"{tag}.sim_s_per_merge",
            base_row["sim_s_per_merge"], fresh_row.get("sim_s_per_merge"),
            larger_is_worse=True,
        )
        ratio_check(
            f"{tag}.speedup_vs_sync",
            base_row["speedup_vs_sync"], fresh_row.get("speedup_vs_sync"),
            larger_is_worse=False,
        )
        f1_fresh = fresh_row.get("f1_mean")
        f1_line = (
            f"{tag}.f1_mean: {base_row['f1_mean']:.3f} -> "
            f"{f1_fresh if f1_fresh is None else format(f1_fresh, '.3f')}"
        )
        if f1_fresh is None:
            failures.append(f"{tag}.f1_mean: missing from the fresh JSON")
        elif base_row["f1_mean"] - f1_fresh > f1_tol:
            failures.append(f"{f1_line} (dropped > {f1_tol})")
        else:
            print(f"ok   {f1_line}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly generated async_bench.json")
    ap.add_argument("baseline", help="committed baseline async_bench.json")
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    ap.add_argument("--f1-tol", type=float, default=F1_TOL)
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(fresh, baseline, args.threshold, args.f1_tol)
    if failures:
        print(f"ASYNC THROUGHPUT/ACCURACY REGRESSION (> {args.threshold}x "
              f"or F1 drop > {args.f1_tol}):")
        for line in failures:
            print(f"FAIL {line}")
        print(
            "If this PR intentionally changed the async simulation or its "
            "scales, regenerate the baseline: "
            "PYTHONPATH=src python -m benchmarks.run --only async_bench"
        )
        return 1
    print("async_bench within tolerance of the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
