"""Shared helpers for the benchmark harness.

Scale modes:
  quick — CPU-budget defaults: training studies run at reduced N/T;
          geometry/energy studies always run at PAPER scale (they do not
          need training — see launch/experiment.audit_method).
  full  — the paper's exact N/T for the training studies too (hours on
          CPU; intended for a real accelerator).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax

from repro.data.synthetic import SyntheticConfig, generate, normalize

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")

_ENGINES: dict = {}


def get_engine(**kw):
    """The benchmark-wide shared :class:`repro.engine.Engine`.

    Shared so the program cache spans modules: the same (method, config,
    batch) cell compiled for one table is reused by the next.  Keyword
    overrides (e.g. ``point_adjusted=True`` for the real-benchmark table)
    get their own cached instance, since evaluation knobs change the
    compiled programs anyway.
    """
    key = tuple(sorted(kw.items()))
    if key not in _ENGINES:
        from repro.engine import Engine

        _ENGINES[key] = Engine(**kw)
    return _ENGINES[key]


def engine_snapshot(log: list[dict]) -> dict:
    """Summarise a drained ``Engine.take_log()`` for the bench JSON.

    ``sequential_program_equivalent`` is what the pre-engine harness would
    have traced: one program per (cell, trial), since each sequential
    ``train`` call rebuilt its round closure.

    Sweep entries (``Engine.sweep``) additionally report how many config
    cells each compiled program covered: ``sweep_cells`` vs
    ``sweep_compiled_programs`` is the config-axis batching ratio the CI
    gate (``benchmarks/check_sweep_compile.py``) protects — a silent
    fall-back to per-cell compilation shows up as a program-count
    regression here.
    """
    sweep = [e for e in log if e.get("kind", "").startswith("sweep")]
    return {
        "cells": log,
        "compiled_programs_new": sum(1 for e in log if e["fresh_compile"]),
        "sequential_program_equivalent": sum(e["n_trials"] for e in log),
        "wall_s_total": sum(e["wall_s"] for e in log),
        "sweep_cells": sum(e.get("n_cells", 0) for e in sweep),
        "sweep_compiled_programs": sum(
            1 for e in sweep if e["fresh_compile"]
        ),
        "sweep_wall_s": sum(e["wall_s"] for e in sweep),
    }


@dataclasses.dataclass(frozen=True)
class Scale:
    quick: bool = True

    # training-study knobs
    @property
    def rounds(self) -> int:
        return 6 if self.quick else 20

    @property
    def rounds_real(self) -> int:
        return 8 if self.quick else 30

    @property
    def local_epochs(self) -> int:
        return 2 if self.quick else 5

    @property
    def seeds(self) -> tuple[int, ...]:
        return (0, 1) if self.quick else (0, 1, 2)

    @property
    def train_n(self) -> dict[int, int]:
        """Map paper N -> trainable N for the F1 columns."""
        if self.quick:
            return {50: 24, 100: 32, 150: 40, 200: 48}
        return {n: n for n in (50, 100, 150, 200)}

    @property
    def train_len(self) -> int:
        return 96 if self.quick else 256


def make_dataset(seed: int, n_sensors: int, scale: Scale, alpha: float = 1.0):
    cfg = SyntheticConfig(
        n_sensors=n_sensors,
        train_len=scale.train_len,
        val_len=max(32, scale.train_len // 3),
        test_len=scale.train_len,
        dirichlet_alpha=alpha,
    )
    return normalize(generate(jax.random.key(seed), cfg))


def save_result(name: str, payload: dict) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0


def mean_std(xs):
    import numpy as np

    a = np.asarray(list(xs), dtype=float)
    return float(a.mean()), float(a.std())
