"""Fig. 5(a): direct-gateway vs fog-assisted reachability vs network scale.

Pure geometry + channel feasibility — runs at the paper's exact scale
(N in {50, 100, 150, 200}, M = N/10, 3 seeds) in milliseconds.
Paper targets: direct ~0.48-0.51 across N; fog-assisted 0.96 -> ~1.0.
"""
from __future__ import annotations

import jax

from benchmarks import common
from repro.core import channel as ch
from repro.core import participation as part
from repro.core import topology as topo


def run(scale: common.Scale) -> dict:
    cparams = ch.ChannelParams()
    rows = []
    for n in (50, 100, 150, 200):
        direct, fog = [], []
        for seed in (0, 1, 2):
            dep = topo.sample_deployment(
                jax.random.key(seed),
                topo.DeploymentParams(n_sensors=n, n_fog=max(5, n // 10)),
            )
            r = part.reachability(dep, cparams)
            direct.append(float(r.direct_gateway))
            fog.append(float(r.fog_assisted))
        dm, ds = common.mean_std(direct)
        fm, fs = common.mean_std(fog)
        rows.append(
            dict(n=n, direct_mean=dm, direct_std=ds, fog_mean=fm, fog_std=fs)
        )
    return {"rows": rows}


def report(res: dict) -> str:
    lines = ["fig5_participation: reachability vs N (3 seeds, paper scale)"]
    lines.append(f"{'N':>4} {'direct':>14} {'fog-assisted':>14}")
    for r in res["rows"]:
        lines.append(
            f"{r['n']:>4} {r['direct_mean']:.2f}±{r['direct_std']:.2f}"
            f"{'':>6} {r['fog_mean']:.2f}±{r['fog_std']:.2f}"
        )
    return "\n".join(lines)
