"""Fig. 5(a): direct-gateway vs fog-assisted reachability vs network scale.

Pure geometry + channel feasibility — runs at the paper's exact scale
(N in {50, 100, 150, 200}, M = N/10, 3 seeds) in milliseconds, through the
shared engine's batched reachability family (one compiled program per N,
all seeds vmapped; per-cell wall-clock + compile counts land under
``"engine"`` like the other benchmarks).
Paper targets: direct ~0.48-0.51 across N; fog-assisted 0.96 -> ~1.0.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.launch import experiment as exp

SEEDS = (0, 1, 2)


def run(scale: common.Scale) -> dict:
    eng = common.get_engine()
    eng.take_log()
    rows = []
    for n in (50, 100, 150, 200):
        cfg = exp.make_config(
            n_sensors=n, n_fog=max(5, n // 10), rounds=1
        )
        r = eng.reachability(cfg, SEEDS, label=f"n={n}:reach")
        direct = np.ravel(np.asarray(r["direct_gateway"], np.float64))
        fog = np.ravel(np.asarray(r["fog_assisted"], np.float64))
        rows.append(
            dict(n=n,
                 direct_mean=float(direct.mean()), direct_std=float(direct.std()),
                 fog_mean=float(fog.mean()), fog_std=float(fog.std()))
        )
    return {"rows": rows, "engine": common.engine_snapshot(eng.take_log())}


def report(res: dict) -> str:
    lines = ["fig5_participation: reachability vs N (3 seeds, paper scale)"]
    lines.append(f"{'N':>4} {'direct':>14} {'fog-assisted':>14}")
    for r in res["rows"]:
        lines.append(
            f"{r['n']:>4} {r['direct_mean']:.2f}±{r['direct_std']:.2f}"
            f"{'':>6} {r['fog_mean']:.2f}±{r['fog_std']:.2f}"
        )
    return "\n".join(lines)
