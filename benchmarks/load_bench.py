"""Serving-under-load benchmark: arrival-trace replay on a virtual clock.

``benchmarks/serve_bench`` times the score *kernel* and smoke-tests the
service; this module measures what a caller actually experiences under
production arrival patterns — END-TO-END request latency (queue wait +
batch formation + device time), replayed open-loop from deterministic
``repro.loadgen`` traces:

* ``poisson`` — steady telemetry at a constant aggregate rate;
* ``mmpp``    — bursty on/off delivery (acoustic surfacing), the shape
  that breaks fixed-size batching: leftovers below ``batch_rows`` sit
  through every silence.

Each trace replays against the serving configs under test:

* ``fixed``             — single 1024-row bucket, flush only when full
  (the legacy policy);
* ``adaptive``          — same bucket + ``max_wait_s`` deadline flush;
* ``adaptive_bucketed`` — 128/1024 row buckets, deadline flush, bucket
  picked by queue depth;
* ``adaptive_bucketed_int8`` — ditto with int8-quantised serving weights
  (dequant-in-program).

Programs are warmed per bucket BEFORE replay, so ``compiles_by_bucket``
is exactly one per bucket (an exact CI pin, ``check_load_bench``) and
the latency percentiles measure steady-state serving, not compilation.
A ``tenancy`` section replays the Poisson trace across three tenants of
one :class:`~repro.serving.MultiTenantService` — same pin: one compiled
program per bucket TOTAL, plus an isolated per-tenant hot-swap check.
The committed JSON (``experiments/bench/load_bench.json``) is the
baseline for the ``check_load_bench`` trend + structure gate.
"""
from __future__ import annotations

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.checkpoint import CheckpointStore
from repro.loadgen import (
    VirtualClock,
    gaussian_windows,
    mmpp_trace,
    poisson_trace,
    replay,
)
from repro.models import autoencoder as ae
from repro.serving import MultiTenantService, ScoringService, quantize_params
from repro.serving.score import score, score_q8
from repro.serving.service import ScorePrograms

D = 32                    # paper Table II feature dim
HIDDEN = (16, 8, 16)
FLEET = 64
N_FOG = 4
ROWS = 16                 # telemetry rows per arrival event
BUCKETS = (128, 1024)
MAX_WAIT_S = 0.02

# name -> (buckets, max_wait_s, weight_dtype)
CONFIGS = {
    "fixed": ((1024,), None, "f32"),
    "adaptive": ((1024,), MAX_WAIT_S, "f32"),
    "adaptive_bucketed": (BUCKETS, MAX_WAIT_S, "f32"),
    "adaptive_bucketed_int8": (BUCKETS, MAX_WAIT_S, "int8"),
}


def _traces(scale: common.Scale) -> dict:
    dur = 4.0 if scale.quick else 12.0
    return {
        # ~250 ev/s: deadline flushes stay under the small bucket.
        "poisson": poisson_trace(
            0, rate_hz=250.0, duration_s=dur, fleet=FLEET, n_fog=N_FOG,
            rows=ROWS,
        ),
        # Bursts fill full 1024-row batches; silences strand leftovers.
        "mmpp": mmpp_trace(
            1, rate_on_hz=2000.0, mean_on_s=0.3, mean_off_s=0.5,
            duration_s=dur, fleet=FLEET, n_fog=N_FOG, rows=ROWS,
        ),
    }


def _warm(programs: ScorePrograms, params, buckets) -> None:
    """Trace every bucket's program once, outside the measured replay."""
    prepared = programs.prepare(params)
    for b in buckets:
        err, _ = programs.fn(b)(
            prepared,
            jnp.zeros((b, D), jnp.float32),
            jnp.full((b,), jnp.inf, jnp.float32),
        )
        err.block_until_ready()


def _replay_row(trace_name, trace, cfg_name, cfg, params, store) -> dict:
    buckets, max_wait_s, weight_dtype = cfg
    programs = ScorePrograms(weight_dtype=weight_dtype, use_pallas=False)
    _warm(programs, params, buckets)
    clock = VirtualClock()
    svc = ScoringService(
        store, params, buckets=buckets, max_wait_s=max_wait_s, tau=1.0,
        weight_dtype=weight_dtype, clock=clock, programs=programs,
    )
    rep = replay(svc, trace, clock, d=D)
    row = dict(trace=trace_name, config=cfg_name, **rep.summary())
    row["weight_dtype"] = weight_dtype
    return row


def _int8_parity(params, trace) -> dict:
    """Same telemetry through f32 and int8 score paths; mismatched flags
    at a mid-distribution tau are counted (expected ~0: the quantisation
    error is ~0.5/127 of each column's range)."""
    windows = gaussian_windows(trace, D)
    x = np.concatenate([windows(i) for i in range(64)])
    qparams = quantize_params(params)
    err32 = np.asarray(score(params, x, np.inf).error)
    tau = float(np.median(err32))
    r32 = score(params, x, tau)
    r8 = score_q8(qparams, x, tau)
    mism = int(np.sum(np.asarray(r32.flag) != np.asarray(r8.flag)))
    rel = np.abs(np.asarray(r8.error) - err32) / (np.abs(err32) + 1e-9)
    return {
        "rows": int(x.shape[0]),
        "tau": tau,
        "flag_mismatches": mism,
        "flag_mismatch_frac": mism / x.shape[0],
        "max_rel_err": float(rel.max()),
    }


def _tenancy(trace, params, store_factory) -> dict:
    """Three deployments on one MultiTenantService: shared compiled
    programs (one per bucket TOTAL) and per-tenant isolated hot-swap."""
    clock = VirtualClock()
    mt = MultiTenantService(
        params, buckets=BUCKETS, max_wait_s=MAX_WAIT_S, clock=clock,
        use_pallas=False,
    )
    _warm(mt.programs, params, BUCKETS)
    names = ("basin_a", "basin_b", "basin_c")
    stores = {}
    for name in names:
        stores[name] = store_factory()
        stores[name].publish(1, params)
        mt.add_tenant(name, stores[name], tau=1.0)
    rep = replay(
        mt, trace, clock, d=D, tenant_of=lambda i: names[i % len(names)]
    )
    # Publish a new round for ONE tenant; only that tenant may swap.
    stores["basin_b"].publish(
        2, jax.tree_util.tree_map(lambda a: a * 0.9, params)
    )
    mt.poll()
    loaded = {name: mt.tenant(name).loaded_step for name in names}
    return {
        "n_tenants": len(names),
        "replay": rep.summary(),
        "compiles_by_bucket": mt.compiles_by_bucket,
        "per_tenant_requests": {
            name: mt.tenant(name).stats.requests for name in names
        },
        "loaded_step": loaded,
        "swap_isolated": (
            loaded["basin_b"] == 2
            and loaded["basin_a"] == 1
            and loaded["basin_c"] == 1
        ),
    }


def run(scale: common.Scale) -> dict:
    params = ae.init(jax.random.key(0), D, HIDDEN)
    traces = _traces(scale)

    with tempfile.TemporaryDirectory(prefix="load_bench_") as root:
        dirs = iter(range(64))

        def store_factory():
            d = tempfile.mkdtemp(prefix=f"t{next(dirs)}_", dir=root)
            return CheckpointStore(d, keep=2)

        store = store_factory()
        store.publish(1, params)

        replays = [
            _replay_row(tn, tr, cn, cfg, params, store)
            for tn, tr in traces.items()
            for cn, cfg in CONFIGS.items()
        ]
        tenancy = _tenancy(traces["poisson"], params, store_factory)

    return {
        "traces": {name: tr.summary() for name, tr in traces.items()},
        "replays": replays,
        "int8_parity": _int8_parity(params, traces["poisson"]),
        "tenancy": tenancy,
    }


def report(res: dict) -> str:
    lines = ["load_bench (open-loop trace replay, e2e = queue + batch + device)"]
    lines.append(
        f"{'trace':>8} {'config':>22} {'events':>7} {'p50 ms':>8} "
        f"{'p99 ms':>8} {'fill':>7} {'partial':>8} {'samples/s':>11}"
    )
    for r in res["replays"]:
        lines.append(
            f"{r['trace']:>8} {r['config']:>22} {r['n_events']:>7} "
            f"{r['e2e_p50_ms']:>8.1f} {r['e2e_p99_ms']:>8.1f} "
            f"{r['mean_fill']:>7.1f} {r['partial_flushes']:>8} "
            f"{r['samples_per_s']:>11.0f}"
        )
    p = res["int8_parity"]
    lines.append(
        f"int8 parity: {p['flag_mismatches']}/{p['rows']} flag mismatches "
        f"at tau={p['tau']:.3f}, max rel err {p['max_rel_err']:.2e}"
    )
    t = res["tenancy"]
    lines.append(
        f"tenancy: {t['n_tenants']} tenants, shared compiles "
        f"{t['compiles_by_bucket']}, swap isolated: {t['swap_isolated']}"
    )
    return "\n".join(lines)
