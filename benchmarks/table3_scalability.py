"""Table III: participation / F1 / energy across N in {50, 100, 150, 200}.

Energy + participation columns are computed at the PAPER's exact scale via
the training-free audit (they do not depend on model values).  F1 columns
require training; in quick mode they run at a reduced N (recorded in the
output) — the paper's own finding is that the synthetic F1 gaps are small
relative to seed variance, and that the robust result is the
participation-vs-energy trade-off, which we reproduce at full scale.
"""
from __future__ import annotations

from benchmarks import common
from repro.launch import experiment as exp

METHODS = ("fedprox", "hfl-nocoop", "hfl-selective", "hfl-nearest")


def run(scale: common.Scale) -> dict:
    import jax.numpy as jnp

    eng = common.get_engine()
    eng.take_log()
    rows = []
    for n in (50, 100, 150, 200):
        m_fog = max(5, n // 10)
        # --- full-scale energy / participation audit (paper T=20) ---------
        # ONE compiled program per N: the four methods ride a lax.switch
        # branch index through ``Engine.sweep`` (method is a swept operand,
        # like the payload size), instead of one audit program per
        # (N, method) cell.  ``check_sweep_compile`` gates the count.
        audit_cfg = exp.make_config(n_sensors=n, n_fog=m_fog, rounds=20)
        sw = eng.sweep(
            METHODS, [audit_cfg] * len(METHODS), (0, 1, 2),
            family="audit", label=f"n={n}:audit",
        )
        audits = {meth: sw.cell(i) for i, meth in enumerate(METHODS)}
        # --- F1 from training at budgeted scale ---------------------------
        n_train = scale.train_n[n]
        train_cfg = exp.make_config(
            n_sensors=n_train,
            n_fog=max(4, n_train // 6),
            rounds=scale.rounds,
            local_epochs=scale.local_epochs,
        )
        # One stacked dataset per cell, shared by all four methods.
        ds_stack = eng.stack_datasets(
            [common.make_dataset(100 + s, n_train, scale) for s in scale.seeds]
        )
        f1s = {
            meth: eng.run(
                meth, train_cfg, scale.seeds, ds_stack, label=f"n={n}:train"
            ).seed_mean_std("f1")
            for meth in METHODS
        }

        for meth in METHODS:
            e_m, e_s = common.mean_std(
                jnp.ravel(audits[meth]["e_total"]).tolist()
            )
            p_m, _ = common.mean_std(
                jnp.ravel(audits[meth]["participation"]).tolist()
            )
            epp = e_m / max(p_m * n, 1.0)
            rows.append(
                dict(
                    n=n, method=meth, participation=p_m,
                    f1_mean=f1s[meth][0], f1_std=f1s[meth][1],
                    energy_mean=e_m, energy_std=e_s,
                    energy_per_participant=epp,
                    f1_train_n=n_train,
                )
            )
    return {"rows": rows, "engine": common.engine_snapshot(eng.take_log())}


# ---------------------------------------------------------------------------
# Fleet-scale tier (PR 10): wall-clock + peak-device-memory high-water marks
# of the client-phase delta path (fused compress + fog accumulate) as N grows
# toward 10^4-10^6 sensors, dense vs client-chunked.  Saved as its own JSON
# (``scale_bench.json`` via ``benchmarks/scale_bench.py``) and gated by
# ``benchmarks/check_scale_bench.py``.
# ---------------------------------------------------------------------------

SCALE_D = 1352        # paper model size (flat autoencoder params)
SCALE_N_FOG = 16
SCALE_CHUNK = 512


def scale_cells(quick: bool) -> tuple[tuple[int, int | None], ...]:
    """(N, client_chunk) cells.  The dense N=2k cell is the memory
    reference; the chunked tier grows N with the footprint pinned."""
    cells = [
        (2_000, None),
        (2_000, SCALE_CHUNK),
        (10_000, SCALE_CHUNK),
        (50_000, SCALE_CHUNK),
    ]
    if not quick:
        cells.append((200_000, SCALE_CHUNK))
    return tuple(cells)


def run_scale(scale: common.Scale) -> dict:
    """Measure the delta path exactly as the round loops run it.

    Per cell: jit-lower-compile ``aggregation.compress_and_accumulate``
    under the engine-resolved blockwise compressor, read the compiled
    program's ``memory_analysis()`` — ``temp_size_in_bytes`` is the
    peak-device-memory high-water mark of the path's INTERMEDIATES
    (arguments and outputs are round state, recorded separately, and scale
    with N by definition) — then time the real execution (min over reps).
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import aggregation as agg
    from repro.engine import Engine

    cc = Engine().resolve_compressor(exp.make_config(50, 5, rounds=1).compressor)
    rows = []
    for n, chunk in scale_cells(scale.quick):
        k1, k2 = jax.random.split(jax.random.key(n))
        deltas = jax.random.normal(k1, (n, SCALE_D), jnp.float32)
        err = 0.1 * jax.random.normal(k2, (n, SCALE_D), jnp.float32)
        fog_id = jnp.arange(n, dtype=jnp.int32) % SCALE_N_FOG
        w = jnp.ones((n,), jnp.float32)

        def fn(de, er, fi, ww, chunk=chunk):
            return agg.compress_and_accumulate(
                de, er, fi, ww, SCALE_N_FOG, cc, chunk=chunk
            )

        t0 = time.time()
        compiled = jax.jit(fn).lower(deltas, err, fog_id, w).compile()
        compile_s = time.time() - t0
        ma = compiled.memory_analysis()
        walls = []
        for _ in range(2 if n <= 10_000 else 1):
            t0 = time.time()
            out = compiled(deltas, err, fog_id, w)
            jax.tree_util.tree_map(jax.block_until_ready, out)
            walls.append(time.time() - t0)
        rows.append(dict(
            n=n, chunk=chunk, d=SCALE_D, n_fog=SCALE_N_FOG,
            temp_bytes=int(ma.temp_size_in_bytes),
            argument_bytes=int(ma.argument_size_in_bytes),
            output_bytes=int(ma.output_size_in_bytes),
            wall_s=min(walls), compile_s=compile_s,
        ))
    return {
        "rows": rows,
        "meta": dict(
            memory_metric="compiled memory_analysis().temp_size_in_bytes",
            compressor="engine-resolved blockwise (oracle on CPU)",
            quick=scale.quick,
        ),
    }


def report_scale(res: dict) -> str:
    lines = [
        "scale_bench (delta-path wall-clock + peak temp memory vs fleet N;"
        " chunked cells pin the high-water mark to O(chunk * d))",
        f"{'N':>7} {'chunk':>6} {'temp MB':>8} {'args MB':>8} {'out MB':>7}"
        f" {'wall s':>7}",
    ]
    for r in res["rows"]:
        lines.append(
            f"{r['n']:>7} {str(r['chunk'] or 'dense'):>6} "
            f"{r['temp_bytes'] / 1e6:8.1f} {r['argument_bytes'] / 1e6:8.1f} "
            f"{r['output_bytes'] / 1e6:7.1f} {r['wall_s']:7.2f}"
        )
    return "\n".join(lines)


def report(res: dict) -> str:
    lines = [
        "table3_scalability (energy/participation at paper scale; F1 at the"
        " budgeted training scale shown in the last column)",
        f"{'N':>4} {'method':14} {'part':>5} {'F1':>13} {'E (J)':>14}"
        f" {'J/sensor':>9} {'F1@N':>5}",
    ]
    for r in res["rows"]:
        lines.append(
            f"{r['n']:>4} {r['method']:14} {r['participation']:5.2f} "
            f"{r['f1_mean']:.3f}±{r['f1_std']:.3f} "
            f"{r['energy_mean']:8.1f}±{r['energy_std']:4.1f} "
            f"{r['energy_per_participant']:9.3f} {r['f1_train_n']:>5}"
        )
    return "\n".join(lines)
