"""Table III: participation / F1 / energy across N in {50, 100, 150, 200}.

Energy + participation columns are computed at the PAPER's exact scale via
the training-free audit (they do not depend on model values).  F1 columns
require training; in quick mode they run at a reduced N (recorded in the
output) — the paper's own finding is that the synthetic F1 gaps are small
relative to seed variance, and that the robust result is the
participation-vs-energy trade-off, which we reproduce at full scale.
"""
from __future__ import annotations

from benchmarks import common
from repro.launch import experiment as exp

METHODS = ("fedprox", "hfl-nocoop", "hfl-selective", "hfl-nearest")


def run(scale: common.Scale) -> dict:
    import jax.numpy as jnp

    eng = common.get_engine()
    eng.take_log()
    rows = []
    for n in (50, 100, 150, 200):
        m_fog = max(5, n // 10)
        # --- full-scale energy / participation audit (paper T=20) ---------
        # One compiled program per (N, method) cell, all seeds batched.
        audit_cfg = exp.make_config(n_sensors=n, n_fog=m_fog, rounds=20)
        audits = {
            meth: eng.audit(meth, audit_cfg, (0, 1, 2), label=f"n={n}:audit")
            for meth in METHODS
        }
        # --- F1 from training at budgeted scale ---------------------------
        n_train = scale.train_n[n]
        train_cfg = exp.make_config(
            n_sensors=n_train,
            n_fog=max(4, n_train // 6),
            rounds=scale.rounds,
            local_epochs=scale.local_epochs,
        )
        # One stacked dataset per cell, shared by all four methods.
        ds_stack = eng.stack_datasets(
            [common.make_dataset(100 + s, n_train, scale) for s in scale.seeds]
        )
        f1s = {
            meth: eng.run(
                meth, train_cfg, scale.seeds, ds_stack, label=f"n={n}:train"
            ).seed_mean_std("f1")
            for meth in METHODS
        }

        for meth in METHODS:
            e_m, e_s = common.mean_std(
                jnp.ravel(audits[meth]["e_total"]).tolist()
            )
            p_m, _ = common.mean_std(
                jnp.ravel(audits[meth]["participation"]).tolist()
            )
            epp = e_m / max(p_m * n, 1.0)
            rows.append(
                dict(
                    n=n, method=meth, participation=p_m,
                    f1_mean=f1s[meth][0], f1_std=f1s[meth][1],
                    energy_mean=e_m, energy_std=e_s,
                    energy_per_participant=epp,
                    f1_train_n=n_train,
                )
            )
    return {"rows": rows, "engine": common.engine_snapshot(eng.take_log())}


def report(res: dict) -> str:
    lines = [
        "table3_scalability (energy/participation at paper scale; F1 at the"
        " budgeted training scale shown in the last column)",
        f"{'N':>4} {'method':14} {'part':>5} {'F1':>13} {'E (J)':>14}"
        f" {'J/sensor':>9} {'F1@N':>5}",
    ]
    for r in res["rows"]:
        lines.append(
            f"{r['n']:>4} {r['method']:14} {r['participation']:5.2f} "
            f"{r['f1_mean']:.3f}±{r['f1_std']:.3f} "
            f"{r['energy_mean']:8.1f}±{r['energy_std']:4.1f} "
            f"{r['energy_per_participant']:9.3f} {r['f1_train_n']:>5}"
        )
    return "\n".join(lines)
