"""Bench-delta summary: fresh vs committed JSONs as one markdown table.

  python -m benchmarks.bench_summary --fresh DIR --baseline DIR [--out PATH]

CI's bench-smoke job runs this after the trend gates and appends the
table to ``$GITHUB_STEP_SUMMARY`` (the default ``--out`` when that env
var is set), so every PR shows the per-row movement of the gated metrics
— not just the gates' pass/fail bit.  Row specs are imported from the
gate modules themselves (``check_kernel_micro.CHECKS`` etc.), so the
summary and the gates can never drift apart on what is tracked.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks import check_kernel_micro, check_load_bench, check_serve_bench

# json name -> (table, row-key fields, tracked field) triples.
TABLE_SPECS: dict[str, tuple] = {
    "kernel_micro": check_kernel_micro.CHECKS,
    "serve_bench": check_serve_bench.CHECKS,
    "load_bench": check_load_bench.CHECKS,
    "async_bench": (
        ("rows", ("alpha", "buffer_frac", "arrival"), "sim_s_per_merge"),
        ("rows", ("alpha", "buffer_frac", "arrival"), "speedup_vs_sync"),
        ("rows", ("alpha", "buffer_frac", "arrival"), "f1_mean"),
    ),
    "scale_bench": (
        ("rows", ("n", "chunk"), "temp_bytes"),
        ("rows", ("n", "chunk"), "wall_s"),
    ),
    "robustness_bench": (
        ("rows", ("robust", "byz_frac", "erasure"), "f1_mean"),
        ("rows", ("robust", "byz_frac", "erasure"), "nonfinite_rounds"),
    ),
    "drift_bench": (
        ("rows", ("cell",), "f1_mean"),
        ("rows", ("cell",), "participation"),
    ),
}

# jsons whose ``engine`` block (sweep compile accounting) is summarised.
ENGINE_JSONS = ("fig6_energy", "ablations", "async_bench", "robustness_bench",
                "drift_bench", "table3_scalability")


def _load(path: str) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.3g}"
    return str(v)


def delta_rows(fresh_dir: str, baseline_dir: str) -> list[tuple]:
    """(json, row, metric, baseline, fresh, ratio-or-note) tuples."""
    out = []
    for name, checks in TABLE_SPECS.items():
        fresh = _load(os.path.join(fresh_dir, f"{name}.json"))
        base = _load(os.path.join(baseline_dir, f"{name}.json"))
        if fresh is None or base is None:
            continue
        for table, keys, field in checks:
            # .get: legacy rows may predate a key field (e.g. the async
            # ``arrival`` tag) — they key consistently on None.
            fresh_idx = {
                tuple(r.get(k) for k in keys): r for r in fresh.get(table, [])
            }
            for brow in base.get(table, []):
                if field not in brow:
                    continue
                row_key = tuple(brow.get(k) for k in keys)
                row_tag = ",".join(
                    f"{k}={_fmt(v)}" for k, v in zip(keys, row_key)
                )
                frow = fresh_idx.get(row_key)
                if frow is None or field not in frow:
                    out.append((name, row_tag, field, brow[field], None, "missing"))
                    continue
                ratio = frow[field] / max(abs(brow[field]), 1e-9)
                out.append((name, row_tag, field, brow[field], frow[field],
                            f"{ratio:.2f}x"))
    for name in ENGINE_JSONS:
        fresh = _load(os.path.join(fresh_dir, f"{name}.json"))
        base = _load(os.path.join(baseline_dir, f"{name}.json"))
        if fresh is None or base is None:
            continue
        fe, be = fresh.get("engine") or {}, base.get("engine") or {}
        for field in ("sweep_cells", "sweep_compiled_programs"):
            if field in be:
                out.append((name, "engine", field, be[field],
                            fe.get(field), "exact"))
    return out


def markdown(rows: list[tuple]) -> str:
    lines = [
        "## Bench delta — fresh vs committed baseline",
        "",
        "| json | row | metric | baseline | fresh | ratio |",
        "|---|---|---|---|---|---|",
    ]
    for name, row_tag, field, base_v, fresh_v, note in rows:
        fresh_s = "**MISSING**" if fresh_v is None else _fmt(fresh_v)
        lines.append(
            f"| {name} | {row_tag} | {field} | {_fmt(base_v)} "
            f"| {fresh_s} | {note} |"
        )
    if len(lines) == 4:
        lines.append("| _no overlapping bench JSONs found_ | | | | | |")
    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True, help="dir with fresh JSONs")
    ap.add_argument("--baseline", required=True,
                    help="dir with committed baseline JSONs")
    ap.add_argument("--out", default=os.environ.get("GITHUB_STEP_SUMMARY"),
                    help="append the markdown here (default: "
                         "$GITHUB_STEP_SUMMARY, else stdout only)")
    args = ap.parse_args()
    md = markdown(delta_rows(args.fresh, args.baseline))
    print(md)
    if args.out:
        with open(args.out, "a") as f:
            f.write(md)
    return 0


if __name__ == "__main__":
    sys.exit(main())
