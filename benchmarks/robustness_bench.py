"""Robustness benchmark: Byzantine attacks + lossy links vs aggregation rule.

Measures the claim behind ``core/faults`` + the robust aggregation path:
under a >=20% Byzantine fleet a plain weighted-mean aggregate collapses the
detector, while the coordinate-wise trimmed mean / weighted median hold F1
within tolerance of the clean run — and packet erasure degrades the
detector smoothly (no NaN rounds, no cliff) because lost packets only
withdraw aggregation weight.

The grid is ``robust in (mean, trimmed, median) x byz_frac in (0, ATTACK)
x erasure in (0, EROSION)`` — 12 cells.  Every cell shares the fault-layer
statics (``byz_mode`` pins the layer active even at ``byz_frac=0``), so
the whole grid compiles as ONE program per robust mode (3 shape-classes);
``engine.sweep_compiled_programs`` in the JSON is the proof the CI gate
(``benchmarks/check_robustness_bench``) pins, alongside the F1 contracts
above, against the committed ``experiments/bench/robustness_bench.json``.

The attack is ``gauss`` noise at ``byz_scale=20`` — strong enough that the
mean demonstrably collapses at quick scale, while staying finite (the
non-finite guard is exercised separately by the test suite).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common
from repro.core import faults as flt
from repro.launch import experiment as exp

METHOD = "hfl-selective"
ROBUST = ("mean", "trimmed", "median")
BYZ_FRACS = (0.0, 0.25)          # >= 20% Byzantine clients when attacked
ERASURES = (0.0, 0.3)
BYZ_MODE = "gauss"
BYZ_SCALE = 20.0
TRIM_FRAC = 0.45                 # > per-fog Byzantine weight share, with
                                 # headroom for erasure concentrating it


def _cells(scale: common.Scale):
    n = scale.train_n[50]
    base = exp.make_config(
        n_sensors=n, n_fog=max(4, n // 6),
        rounds=scale.rounds, local_epochs=scale.local_epochs,
    )
    keys, cfgs = [], []
    for robust in ROBUST:
        for byz in BYZ_FRACS:
            for er in ERASURES:
                keys.append((robust, byz, er))
                cfgs.append(base.replace(
                    robust=robust,
                    trim_frac=TRIM_FRAC if robust == "trimmed" else 0.0,
                    faults=flt.FaultConfig(
                        erasure_prob=er, byz_frac=byz,
                        byz_scale=BYZ_SCALE, byz_mode=BYZ_MODE,
                    ),
                ))
    return n, keys, cfgs


def run(scale: common.Scale) -> dict:
    eng = common.get_engine()
    eng.take_log()  # drop entries from earlier modules
    n, keys, cfgs = _cells(scale)

    def ds_fn(s):
        return common.make_dataset(700 + s, n, scale)

    sw = eng.sweep(METHOD, cfgs, scale.seeds, ds_fn,
                   label="robustness:attack-grid")
    rows = []
    for i, (robust, byz, er) in enumerate(keys):
        f1m, f1sd = sw.seed_mean_std("f1", i)
        rows.append(dict(
            robust=robust,
            byz_frac=byz,
            erasure=er,
            byz_mode=BYZ_MODE,
            byz_scale=BYZ_SCALE,
            trim_frac=TRIM_FRAC if robust == "trimmed" else 0.0,
            f1_mean=f1m, f1_std=f1sd,
            nonfinite_rounds=float(jnp.sum(sw["nonfinite_rounds"][i])),
            nonfinite_total=float(jnp.sum(sw["nonfinite_total"][i])),
            erased_total=float(jnp.mean(sw["erased_total"][i])),
            e_total_mean=float(jnp.mean(sw["e_total"][i])),
        ))
    return {
        "method": METHOD,
        "n_sensors": n,
        "seeds": list(scale.seeds),
        "n_classes": sw.n_classes,
        "rows": rows,
        "engine": common.engine_snapshot(eng.take_log()),
    }


def _row(res: dict, robust: str, byz: float, er: float) -> dict | None:
    for r in res["rows"]:
        if (r["robust"], r["byz_frac"], r["erasure"]) == (robust, byz, er):
            return r
    return None


def report(res: dict) -> str:
    clean = _row(res, "mean", 0.0, 0.0)
    lines = [
        "robustness_bench — Byzantine attack x erasure x aggregation rule "
        f"(N={res['n_sensors']}, {len(res['seeds'])} seeds, "
        f"{res['rows'][0]['byz_mode']}@{res['rows'][0]['byz_scale']:g})",
        f"clean mean baseline: F1 {clean['f1_mean']:.3f}"
        f"±{clean['f1_std']:.3f}",
        f"{'robust':>8} {'byz':>5} {'erase':>6} {'F1':>13} "
        f"{'erased':>7} {'nan-rounds':>10}",
    ]
    for r in res["rows"]:
        lines.append(
            f"{r['robust']:>8} {r['byz_frac']:>5g} {r['erasure']:>6g} "
            f"{r['f1_mean']:.3f}±{r['f1_std']:.3f} "
            f"{r['erased_total']:>7.1f} {r['nonfinite_rounds']:>10g}"
        )
    eng = res.get("engine")
    if eng:
        lines.append(
            f"engine: {eng['sweep_compiled_programs']} compiled program(s) "
            f"for {eng['sweep_cells']} grid cells "
            f"({res['n_classes']} robust-mode shape-classes), "
            f"{eng['wall_s_total']:.1f}s batched wall"
        )
    return "\n".join(lines)
