"""Memory + wall-clock gate for the fleet-axis scale bench (PR 10).

  python -m benchmarks.check_scale_bench FRESH.json BASELINE.json

Sibling of ``check_kernel_micro`` / ``check_sweep_compile``, protecting the
client-chunked delta path's scaling contract:

* **Chunk pin** (fresh JSON alone, no baseline needed): the chunked
  N=10k cell's peak temp memory must stay below ``CHUNK_PIN`` (50%) of the
  dense N=2k cell's — the PR's headline acceptance criterion.  A refactor
  that silently rematerialises full-fleet intermediates inside the scan
  trips this even with an up-to-date baseline.
* **Flatness** (fresh JSON alone): across the chunked cells the temp
  high-water mark must not spread by more than ``FLAT_TOL`` — the whole
  point of chunking is that the footprint follows ``chunk``, not N.
* **Memory trend** (vs baseline): per-cell ``temp_bytes`` must not exceed
  the committed baseline by more than ``MEM_TOL`` (compiler-version
  headroom; the quantity is otherwise deterministic).
* **Wall-clock trend** (vs baseline): per-cell ``wall_s`` within the
  ``WALL_TOL`` (3x) runner-noise allowance used by the other timing gates.
* A vanished cell fails loudly.
"""
from __future__ import annotations

import argparse
import json
import sys

CHUNK_PIN = 0.5    # chunked-10k temp vs dense-2k temp
FLAT_TOL = 1.25    # max/min spread across chunked cells
MEM_TOL = 1.10     # fresh vs baseline temp_bytes
WALL_TOL = 3.0     # fresh vs baseline wall_s


def _key(row: dict) -> tuple:
    return (row["n"], row["chunk"])


def compare(
    fresh: dict,
    baseline: dict | None,
    *,
    chunk_pin: float = CHUNK_PIN,
    flat_tol: float = FLAT_TOL,
    mem_tol: float = MEM_TOL,
    wall_tol: float = WALL_TOL,
) -> list[str]:
    failures = []
    rows = {_key(r): r for r in fresh.get("rows", [])}

    # --- chunk pin: chunked 10k < chunk_pin * dense 2k ---------------------
    dense = next((r for r in rows.values() if r["chunk"] is None), None)
    chunked = [r for r in rows.values() if r["chunk"] is not None]
    big = next((r for r in chunked if r["n"] >= 10_000), None)
    if dense is None or big is None:
        failures.append(
            "chunk-pin: fresh JSON lacks the dense reference cell and/or a "
            "chunked cell with n >= 10000"
        )
    else:
        ratio = big["temp_bytes"] / max(dense["temp_bytes"], 1)
        line = (
            f"chunk-pin: chunked n={big['n']} temp "
            f"{big['temp_bytes'] / 1e6:.1f}MB vs dense n={dense['n']} "
            f"{dense['temp_bytes'] / 1e6:.1f}MB ({ratio:.2f}x)"
        )
        if ratio >= chunk_pin:
            failures.append(f"{line}: must stay below {chunk_pin}x")
        else:
            print(f"ok   {line}")

    # --- flatness: chunked temp follows chunk, not N -----------------------
    if len(chunked) >= 2:
        temps = [r["temp_bytes"] for r in chunked]
        spread = max(temps) / max(min(temps), 1)
        line = (
            f"flatness: chunked temp spread over n="
            f"{sorted(r['n'] for r in chunked)} is {spread:.2f}x"
        )
        if spread > flat_tol:
            failures.append(f"{line}: exceeds {flat_tol}x — footprint is "
                            "growing with the fleet again")
        else:
            print(f"ok   {line}")

    # --- trends vs the committed baseline ----------------------------------
    for base_row in (baseline or {}).get("rows", []):
        key = _key(base_row)
        tag = f"rows[n={key[0]},chunk={key[1]}]"
        fresh_row = rows.get(key)
        if fresh_row is None:
            failures.append(f"{tag}: missing from the fresh JSON")
            continue
        mem_ratio = fresh_row["temp_bytes"] / max(base_row["temp_bytes"], 1)
        mem_line = (
            f"{tag}.temp_bytes: {base_row['temp_bytes'] / 1e6:.1f}MB -> "
            f"{fresh_row['temp_bytes'] / 1e6:.1f}MB ({mem_ratio:.2f}x)"
        )
        if mem_ratio > mem_tol:
            failures.append(f"{mem_line}: memory regression > {mem_tol}x")
        else:
            print(f"ok   {mem_line}")
        wall_ratio = fresh_row["wall_s"] / max(base_row["wall_s"], 1e-9)
        wall_line = (
            f"{tag}.wall_s: {base_row['wall_s']:.2f} -> "
            f"{fresh_row['wall_s']:.2f} ({wall_ratio:.2f}x)"
        )
        if wall_ratio > wall_tol:
            failures.append(f"{wall_line}: wall-clock regression > "
                            f"{wall_tol}x")
        else:
            print(f"ok   {wall_line}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly generated scale_bench.json")
    ap.add_argument("baseline", help="committed baseline scale_bench.json")
    ap.add_argument("--chunk-pin", type=float, default=CHUNK_PIN)
    ap.add_argument("--mem-tol", type=float, default=MEM_TOL)
    ap.add_argument("--wall-tol", type=float, default=WALL_TOL)
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(
        fresh, baseline, chunk_pin=args.chunk_pin,
        mem_tol=args.mem_tol, wall_tol=args.wall_tol,
    )
    if failures:
        print("SCALE BENCH REGRESSION:")
        for line in failures:
            print(f"FAIL {line}")
        print(
            "If this PR intentionally changed the delta path's memory "
            "behaviour, regenerate the baseline: PYTHONPATH=src python -m "
            "benchmarks.run --only scale_bench"
        )
        return 1
    print("scale_bench within tolerance of the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
