"""Beyond-paper ablations (design-guidance content, Sec. VI-G style).

(a) Compression-ratio sweep: total energy (paper scale, audit) and F1
    (CPU-budget training) as a function of rho_s — locates the knee the
    paper operates at (rho_s = 0.05).
(b) Selective-eligibility threshold sweep: the 0.75 factor in Eq. 28
    controls how many fog clusters cooperate; we sweep it and report
    active links + f2f energy at paper scale — quantifying the rule's
    sensitivity, which the paper fixes without ablation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import channel as ch
from repro.core import compression as comp
from repro.core import cooperation as coop
from repro.core import energy as en
from repro.core import association as assoc
from repro.core import topology as topo
from repro.launch import experiment as exp

RHOS = (0.01, 0.05, 0.2, 1.0)
THRESHOLDS = (0.25, 0.5, 0.75, 1.0, 1.5)


def _rho_sweep(scale: common.Scale) -> list[dict]:
    eng = common.get_engine()
    rows = []
    n_train = scale.train_n[100]
    for rho in RHOS:
        cc = comp.CompressorConfig(rho_s=rho, quant_bits=8 if rho < 1.0 else 32)
        audit_cfg = exp.make_config(
            n_sensors=200, n_fog=20, rounds=20, compressor=cc
        )
        # One compiled program per cell: all audit seeds batched.
        audit = eng.audit(
            "hfl-nocoop", audit_cfg, (0, 1, 2), label=f"rho={rho}:audit"
        )
        e = float(jnp.mean(audit["e_total"]))
        train_cfg = exp.make_config(
            n_sensors=n_train, n_fog=max(4, n_train // 6),
            rounds=scale.rounds, local_epochs=scale.local_epochs,
            compressor=cc,
        )
        r = eng.run(
            "hfl-nocoop", train_cfg, scale.seeds,
            lambda s: common.make_dataset(400 + s, n_train, scale),
            label=f"rho={rho}:train",
        )
        f1m, f1sd = r.seed_mean_std("f1")
        rows.append(dict(
            rho_s=rho,
            payload_bits=comp.payload_bits(1352, cc),
            energy_j_n200=e,
            f1_mean=f1m, f1_std=f1sd, f1_train_n=n_train,
        ))
    return rows


def _threshold_sweep() -> list[dict]:
    """Eq. 28 factor sweep at N=200: how many links fire, at what cost."""
    cparams = ch.ChannelParams()
    eparams = en.EnergyParams()
    rows = []
    d_bits = 32.0 * 1352
    for factor in THRESHOLDS:
        links, e_f2f = [], []
        for seed in (0, 1, 2):
            dep = topo.sample_deployment(
                jax.random.key(seed),
                topo.DeploymentParams(n_sensors=200, n_fog=20),
            )
            fa = assoc.nearest_feasible_fog(dep, cparams)
            c = fa.cluster_size.astype(jnp.float32)
            nonempty = c > 0
            mean_c = jnp.sum(c * nonempty) / jnp.maximum(jnp.sum(nonempty), 1.0)
            # re-run the selective rule with a swept eligibility factor
            d = ch.pairwise_distances(dep.fog_pos, dep.fog_pos) + jnp.diag(
                jnp.full((20,), jnp.inf)
            )
            feas = ch.feasible(d, cparams)
            eligible = c <= jnp.maximum(2.0, factor * mean_c)
            feas_d = jnp.where(feas, d, jnp.nan)
            q1 = jnp.nanquantile(feas_d, 0.25)
            larger = c[None, :] > c[:, None]
            candidate = feas & larger & (d < q1)
            has = jnp.any(candidate, axis=-1)
            cooperates = eligible & has & nonempty
            partner_d = jnp.min(jnp.where(candidate, d, jnp.inf), axis=-1)
            e = en.tx_energy_j(d_bits, jnp.where(
                cooperates, partner_d, 1.0), cparams, eparams)
            e_f2f.append(float(jnp.sum(jnp.where(cooperates, e, 0.0))) * 20)
            links.append(float(jnp.sum(cooperates)))
        rows.append(dict(
            factor=factor,
            links_mean=common.mean_std(links)[0],
            e_f2f_20rounds_j=common.mean_std(e_f2f)[0],
        ))
    return rows


def run(scale: common.Scale) -> dict:
    eng = common.get_engine()
    eng.take_log()  # drop entries from earlier modules
    res = {"rho_sweep": _rho_sweep(scale),
           "threshold_sweep": _threshold_sweep()}
    res["engine"] = common.engine_snapshot(eng.take_log())
    return res


def report(res: dict) -> str:
    lines = ["ablations"]
    lines.append("(a) compression-ratio sweep (HFL-NoCoop; energy at N=200/T=20)")
    lines.append(f"{'rho_s':>6} {'payload':>9} {'E (J)':>8} {'F1':>13}")
    for r in res["rho_sweep"]:
        lines.append(
            f"{r['rho_s']:>6g} {r['payload_bits']:>8.0f}b "
            f"{r['energy_j_n200']:>8.1f} {r['f1_mean']:.3f}±{r['f1_std']:.3f}"
        )
    lines.append("(b) Eq. 28 eligibility-factor sweep (N=200, 3 seeds)")
    lines.append(f"{'factor':>6} {'coop links':>10} {'f2f E/20r (J)':>14}")
    for r in res["threshold_sweep"]:
        lines.append(
            f"{r['factor']:>6g} {r['links_mean']:>10.1f} "
            f"{r['e_f2f_20rounds_j']:>14.1f}"
        )
    lines.append("  (paper fixes factor=0.75 — the knee where links stay"
                 " few but imbalanced clusters are still served)")
    eng = res.get("engine")
    if eng:
        lines.append(
            f"engine: {eng['compiled_programs_new']} compiled programs vs "
            f"{eng['sequential_program_equivalent']} sequential traces, "
            f"{eng['wall_s_total']:.1f}s batched wall"
        )
    return "\n".join(lines)
