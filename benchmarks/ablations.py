"""Beyond-paper ablations (design-guidance content, Sec. VI-G style).

(a) Compression-ratio sweep: total energy (paper scale, audit) and F1
    (CPU-budget training) as a function of rho_s — locates the knee the
    paper operates at (rho_s = 0.05).
(b) Selective-eligibility threshold sweep: the 0.75 factor in Eq. 28
    controls how many fog clusters cooperate; we sweep it and report
    active links + f2f energy at paper scale — quantifying the rule's
    sensitivity, which the paper fixes without ablation.

Both training and audit rho cells run through ``Engine.sweep`` (PR 5):
the whole rho grid is grouped into shape-classes and each class compiles
ONCE with the swept knobs stacked along a leading config axis — 3
compiled programs for the 8-cell quick grid (audits: 1, sparse trains: 1,
the dense train: 1) vs 8 per-cell programs before.  The threshold sweep
reuses the production ``selective_cooperation`` rule with a swept
``eligibility_factor`` instead of a hand-rolled copy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import association as assoc
from repro.core import channel as ch
from repro.core import compression as comp
from repro.core import cooperation as coop
from repro.core import energy as en
from repro.core import topology as topo
from repro.launch import experiment as exp

RHOS = (0.01, 0.05, 0.2, 1.0)
THRESHOLDS = (0.25, 0.5, 0.75, 1.0, 1.5)


def _rho_sweep(scale: common.Scale) -> list[dict]:
    eng = common.get_engine()
    n_train = scale.train_n[100]
    ccs = [
        comp.CompressorConfig(rho_s=rho, quant_bits=8 if rho < 1.0 else 32)
        for rho in RHOS
    ]
    # One program for the WHOLE audit grid: the audit touches the
    # compressor only through the payload size, which sweeps as an operand.
    audit_cfgs = [
        exp.make_config(n_sensors=200, n_fog=20, rounds=20, compressor=cc)
        for cc in ccs
    ]
    audit = eng.sweep(
        "hfl-nocoop", audit_cfgs, (0, 1, 2), family="audit",
        label="rho:audit-sweep",
    )
    # Training grid: the sparse q8 cells share one program (traced keep
    # count), the dense fp32 cell is its own shape-class.
    train_cfgs = [
        exp.make_config(
            n_sensors=n_train, n_fog=max(4, n_train // 6),
            rounds=scale.rounds, local_epochs=scale.local_epochs,
            compressor=cc,
        )
        for cc in ccs
    ]
    train = eng.sweep(
        "hfl-nocoop", train_cfgs, scale.seeds,
        lambda s: common.make_dataset(400 + s, n_train, scale),
        label="rho:train-sweep",
    )
    rows = []
    for i, rho in enumerate(RHOS):
        f1m, f1sd = train.seed_mean_std("f1", i)
        rows.append(dict(
            rho_s=rho,
            payload_bits=comp.payload_bits(1352, ccs[i]),
            energy_j_n200=float(jnp.mean(audit["e_total"][i])),
            f1_mean=f1m, f1_std=f1sd, f1_train_n=n_train,
        ))
    return rows


def _threshold_sweep() -> list[dict]:
    """Eq. 28 factor sweep at N=200: how many links fire, at what cost.

    Runs the production selective rule with a swept eligibility factor —
    empty-partner gating and the feasibility-quantile guard included.
    """
    cparams = ch.ChannelParams()
    eparams = en.EnergyParams()
    rows = []
    d_bits = 32.0 * 1352
    for factor in THRESHOLDS:
        links, e_f2f = [], []
        for seed in (0, 1, 2):
            dep = topo.sample_deployment(
                jax.random.key(seed),
                topo.DeploymentParams(n_sensors=200, n_fog=20),
            )
            fa = assoc.nearest_feasible_fog(dep, cparams)
            dec = coop.selective_cooperation(
                dep.fog_pos, fa.cluster_size, cparams,
                eligibility_factor=factor,
            )
            e = en.tx_energy_j(
                d_bits, jnp.where(dec.cooperates, dec.dist_m, 1.0),
                cparams, eparams,
            )
            e_f2f.append(float(jnp.sum(jnp.where(dec.cooperates, e, 0.0))) * 20)
            links.append(float(jnp.sum(dec.cooperates)))
        rows.append(dict(
            factor=factor,
            links_mean=common.mean_std(links)[0],
            e_f2f_20rounds_j=common.mean_std(e_f2f)[0],
        ))
    return rows


def run(scale: common.Scale) -> dict:
    eng = common.get_engine()
    eng.take_log()  # drop entries from earlier modules
    res = {"rho_sweep": _rho_sweep(scale),
           "threshold_sweep": _threshold_sweep()}
    res["engine"] = common.engine_snapshot(eng.take_log())
    return res


def report(res: dict) -> str:
    lines = ["ablations"]
    lines.append("(a) compression-ratio sweep (HFL-NoCoop; energy at N=200/T=20)")
    lines.append(f"{'rho_s':>6} {'payload':>9} {'E (J)':>8} {'F1':>13}")
    for r in res["rho_sweep"]:
        lines.append(
            f"{r['rho_s']:>6g} {r['payload_bits']:>8.0f}b "
            f"{r['energy_j_n200']:>8.1f} {r['f1_mean']:.3f}±{r['f1_std']:.3f}"
        )
    lines.append("(b) Eq. 28 eligibility-factor sweep (N=200, 3 seeds)")
    lines.append(f"{'factor':>6} {'coop links':>10} {'f2f E/20r (J)':>14}")
    for r in res["threshold_sweep"]:
        lines.append(
            f"{r['factor']:>6g} {r['links_mean']:>10.1f} "
            f"{r['e_f2f_20rounds_j']:>14.1f}"
        )
    lines.append("  (paper fixes factor=0.75 — the knee where links stay"
                 " few but imbalanced clusters are still served)")
    eng = res.get("engine")
    if eng:
        lines.append(
            f"engine: {eng['sweep_compiled_programs']} compiled programs for "
            f"{eng['sweep_cells']} sweep cells "
            f"(vs {eng['sequential_program_equivalent']} sequential traces), "
            f"{eng['wall_s_total']:.1f}s batched wall"
        )
    return "\n".join(lines)
