"""Fig. 4: training-loss convergence of the synthetic method set.

Training-bound -> quick mode runs a reduced N; the check is the paper's
qualitative claim that the loss flattens well before the round budget.
"""
from __future__ import annotations

from benchmarks import common
from repro.launch import experiment as exp

METHODS = ("fedprox", "hfl-nocoop", "hfl-selective", "hfl-nearest")


def run(scale: common.Scale) -> dict:
    n = scale.train_n[150]
    cfg = exp.make_config(
        n_sensors=n, n_fog=max(4, n // 6), rounds=max(8, scale.rounds),
        local_epochs=scale.local_epochs,
    )
    curves = {}
    for meth in METHODS:
        per_seed = []
        for s in scale.seeds:
            ds = common.make_dataset(200 + s, n, scale)
            per_seed.append(exp.run_method(meth, ds, cfg, seed=s).losses)
        curves[meth] = [
            common.mean_std(vals) for vals in zip(*per_seed)
        ]
    return {"n": n, "curves": curves}


def report(res: dict) -> str:
    lines = [f"fig4_convergence (N={res['n']}, mean±std loss per round)"]
    for meth, curve in res["curves"].items():
        first, last = curve[0][0], curve[-1][0]
        flat = curve[len(curve) // 2][0]
        lines.append(
            f"  {meth:14} round0 {first:8.3f} -> mid {flat:8.3f} -> "
            f"final {last:8.3f}  (decreasing={last < first})"
        )
    return "\n".join(lines)
