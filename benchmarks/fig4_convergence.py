"""Fig. 4: training-loss convergence of the synthetic method set.

Training-bound -> quick mode runs a reduced N; the check is the paper's
qualitative claim that the loss flattens well before the round budget.
"""
from __future__ import annotations

from benchmarks import common
from repro.launch import experiment as exp

METHODS = ("fedprox", "hfl-nocoop", "hfl-selective", "hfl-nearest")


def run(scale: common.Scale) -> dict:
    import numpy as np

    eng = common.get_engine()
    eng.take_log()
    n = scale.train_n[150]
    cfg = exp.make_config(
        n_sensors=n, n_fog=max(4, n // 6), rounds=max(8, scale.rounds),
        local_epochs=scale.local_epochs,
    )
    ds_stack = eng.stack_datasets(
        [common.make_dataset(200 + s, n, scale) for s in scale.seeds]
    )
    curves = {}
    for meth in METHODS:
        r = eng.run(meth, cfg, scale.seeds, ds_stack)
        losses = np.asarray(r.losses).reshape(len(scale.seeds), -1)  # (S, T)
        curves[meth] = [
            (float(m), float(sd))
            for m, sd in zip(losses.mean(axis=0), losses.std(axis=0))
        ]
    return {"n": n, "curves": curves,
            "engine": common.engine_snapshot(eng.take_log())}


def report(res: dict) -> str:
    lines = [f"fig4_convergence (N={res['n']}, mean±std loss per round)"]
    for meth, curve in res["curves"].items():
        first, last = curve[0][0], curve[-1][0]
        flat = curve[len(curve) // 2][0]
        lines.append(
            f"  {meth:14} round0 {first:8.3f} -> mid {flat:8.3f} -> "
            f"final {last:8.3f}  (decreasing={last < first})"
        )
    return "\n".join(lines)
