"""Roofline benchmark: three roofline terms per (arch x shape x mesh) from
the dry-run artifacts (ours — no paper analogue; see EXPERIMENTS.md).

experiments/dryrun     = paper-faithful BASELINE sharding,
experiments/dryrun_opt = after the §Perf activation-anchor iterations —
both reported so the before/after is visible.
"""
from __future__ import annotations

import os

from benchmarks import common
from repro.launch import roofline as rl


def run(scale: common.Scale) -> dict:
    res = {
        "pod": rl.load_all("experiments/dryrun", tag="pod"),
        "multipod": rl.load_all("experiments/dryrun", tag="multipod"),
    }
    if os.path.isdir("experiments/dryrun_opt"):
        res["pod_opt"] = rl.load_all("experiments/dryrun_opt", tag="pod")
        res["multipod_opt"] = rl.load_all(
            "experiments/dryrun_opt", tag="multipod"
        )
    return res


def report(res: dict) -> str:
    out = ["roofline BASELINE (single-pod 16x16 = 256 chips)"]
    out.append(rl.table(res["pod"]))
    out.append("")
    out.append(
        f"multipod (2x16x16 = 512 chips): {len(res['multipod'])} combos lowered OK"
    )
    if "pod_opt" in res:
        out.append("")
        out.append("roofline OPTIMIZED (after EXPERIMENTS.md §Perf iterations)")
        out.append(rl.table(res["pod_opt"]))
        out.append(
            f"multipod optimized: {len(res['multipod_opt'])} combos lowered OK"
        )
        # headline improvements
        base = {(r["arch"], r["shape"]): r for r in res["pod"]}
        out.append("")
        out.append("dominant-term improvement (baseline -> optimized):")
        for r in res["pod_opt"]:
            b = base.get((r["arch"], r["shape"]))
            if b and b["bound_s"] > 0 and r["bound_s"] > 0:
                ratio = b["bound_s"] / r["bound_s"]
                if ratio > 1.3 or ratio < 0.77:
                    out.append(
                        f"  {r['arch']:18s} {r['shape']:12s} "
                        f"{rl.fmt_s(b['bound_s'])} -> {rl.fmt_s(r['bound_s'])}"
                        f"  ({ratio:5.1f}x)"
                    )
    return "\n".join(out)
