"""Benchmark driver: one module per paper table/figure + ours.

  PYTHONPATH=src python -m benchmarks.run [--only NAME[,NAME]] [--full]

quick (default): geometry/energy studies at PAPER scale, training studies
at the CPU budget.  --full: everything at the paper's exact scale.
Results land in experiments/bench/<name>.json; a human table prints per
module.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import common

MODULES = (
    "fig4_convergence",
    "fig5_participation",
    "table3_scalability",
    "fig6_energy",
    "fig7_noniid",
    "table4_real",
    "ablations",
    "kernel_micro",
    "serve_bench",
    "load_bench",
    "roofline",
    "async_bench",
    "robustness_bench",
    "drift_bench",
    "scale_bench",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale training studies (slow on CPU)")
    args = ap.parse_args()

    scale = common.Scale(quick=not args.full)
    names = args.only.split(",") if args.only else list(MODULES)
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        # Fail fast with the valid choices instead of letting __import__
        # raise a raw ModuleNotFoundError mid-suite on a typo'd --only.
        ap.error(
            f"unknown benchmark module(s): {', '.join(unknown)} "
            f"(choose from: {', '.join(MODULES)})"
        )
    failures = []
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run", "report"])
        t0 = time.time()
        try:
            res = mod.run(scale)
        except Exception as e:  # keep the suite going; report at the end
            failures.append((name, repr(e)))
            print(f"[FAIL] {name}: {e!r}", flush=True)
            continue
        wall = time.time() - t0
        path = common.save_result(name, res)
        print("=" * 72)
        print(mod.report(res))
        print(f"[{name}: {wall:.1f}s -> {path}]", flush=True)

    print("=" * 72)
    if failures:
        print(f"{len(failures)} benchmark module(s) failed: {failures}")
        sys.exit(1)
    print(f"all {len(names)} benchmark modules completed")


if __name__ == "__main__":
    main()
