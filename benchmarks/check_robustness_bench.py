"""Trend gate for the robustness benchmark (sibling of
``check_async_bench`` / ``check_sweep_compile``).

  python -m benchmarks.check_robustness_bench FRESH.json BASELINE.json

Four contracts, all on DETERMINISTIC simulated quantities:

* robust holds: every zero-erasure trimmed/median row stays within
  ``--f1-tol`` of the clean-mean baseline row (fresh-internal AND vs the
  committed baseline);
* mean collapses: the attacked plain-mean row must sit at least
  ``--degrade-margin`` below the clean row — if the attack stops hurting
  the mean, the benchmark no longer demonstrates anything;
* graceful degradation: every row reports ZERO non-finite global-model
  rounds, and every erased row (except the attacked mean, which is
  already collapsed by design) stays within ``--erasure-tol`` of its
  zero-erasure sibling (smooth, no cliff);
* one program per shape-class: the sweep compiled at most ``n_classes``
  programs for the whole grid (the config-axis batching contract).

A vanished row fails loudly, exactly like the other gates.
"""
from __future__ import annotations

import argparse
import json
import sys

F1_TOL = 0.12
DEGRADE_MARGIN = 0.25
ERASURE_TOL = 0.15


def _key(row: dict) -> tuple:
    return (row["robust"], row["byz_frac"], row["erasure"])


def _rows(res: dict) -> dict:
    return {_key(r): r for r in res.get("rows", [])}


def compare(
    fresh: dict,
    baseline: dict,
    f1_tol: float = F1_TOL,
    degrade_margin: float = DEGRADE_MARGIN,
    erasure_tol: float = ERASURE_TOL,
) -> list[str]:
    failures = []
    fresh_rows, base_rows = _rows(fresh), _rows(baseline)

    def tag(key):
        return f"rows[{key[0]},byz={key[1]:g},er={key[2]:g}]"

    # Every baseline row must still exist.
    for key in base_rows:
        if key not in fresh_rows:
            failures.append(f"{tag(key)}: missing from the fresh JSON")
    clean_key = ("mean", 0.0, 0.0)
    clean = fresh_rows.get(clean_key)
    if clean is None:
        failures.append(f"{tag(clean_key)}: missing — nothing to anchor on")
        return failures

    attacked = [k for k in fresh_rows if k[1] > 0.0]
    if not attacked:
        failures.append("no attacked (byz_frac > 0) rows in the fresh JSON")

    for key, row in sorted(fresh_rows.items()):
        robust, byz, er = key
        f1 = row["f1_mean"]
        # Zero NaN rounds, everywhere — the graceful-degradation contract.
        if row.get("nonfinite_rounds", 0.0) != 0.0:
            failures.append(
                f"{tag(key)}: {row['nonfinite_rounds']:g} non-finite "
                "global-model round(s)"
            )
        if robust in ("trimmed", "median") and er == 0.0:
            # Robust rules hold F1 under attack.
            line = (f"{tag(key)}.f1_mean: {f1:.3f} vs clean "
                    f"{clean['f1_mean']:.3f}")
            if clean["f1_mean"] - f1 > f1_tol:
                failures.append(f"{line} (dropped > {f1_tol})")
            else:
                print(f"ok   {line}")
        elif robust == "mean" and byz > 0.0 and er == 0.0:
            # The attack must demonstrably collapse the plain mean.
            line = (f"{tag(key)}.f1_mean: {f1:.3f} vs clean "
                    f"{clean['f1_mean']:.3f}")
            if clean["f1_mean"] - f1 < degrade_margin:
                failures.append(
                    f"{line} (mean no longer degrades by {degrade_margin})"
                )
            else:
                print(f"ok   {line} (collapsed, as the benchmark requires)")
        elif er > 0.0 and not (robust == "mean" and byz > 0.0):
            # Erasure degrades smoothly vs the zero-erasure sibling.  With
            # BOTH faults on, erasure can leave a fog majority-Byzantine
            # among delivered packets — beyond any trim's breakdown point —
            # so the contract there is bounded degradation, not immunity.
            sib = fresh_rows.get((robust, byz, 0.0))
            if sib is not None:
                line = (f"{tag(key)}.f1_mean: {f1:.3f} vs er=0 "
                        f"{sib['f1_mean']:.3f}")
                if sib["f1_mean"] - f1 > erasure_tol:
                    failures.append(f"{line} (erasure cliff > {erasure_tol})")
                else:
                    print(f"ok   {line}")
        # vs the committed baseline: robust + clean rows must not drift
        # down (a lower attacked-mean F1 is not a regression — collapsing
        # harder is fine, the margin check above owns that direction).
        base_row = base_rows.get(key)
        if base_row is not None and not (robust == "mean" and byz > 0.0):
            line = (f"{tag(key)}.f1_mean: baseline "
                    f"{base_row['f1_mean']:.3f} -> {f1:.3f}")
            if base_row["f1_mean"] - f1 > f1_tol:
                failures.append(f"{line} (dropped > {f1_tol})")
            else:
                print(f"ok   {line}")

    # One compiled program per robust-mode shape-class.
    eng = fresh.get("engine") or {}
    n_classes = fresh.get("n_classes")
    if eng and n_classes:
        compiled = eng.get("sweep_compiled_programs")
        cells = eng.get("sweep_cells")
        line = (f"engine: {compiled} compiled program(s) for {cells} cells, "
                f"{n_classes} shape-classes")
        if compiled is None or compiled > n_classes:
            failures.append(f"{line} (config-axis batching regressed)")
        else:
            print(f"ok   {line}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly generated robustness_bench.json")
    ap.add_argument("baseline",
                    help="committed baseline robustness_bench.json")
    ap.add_argument("--f1-tol", type=float, default=F1_TOL)
    ap.add_argument("--degrade-margin", type=float, default=DEGRADE_MARGIN)
    ap.add_argument("--erasure-tol", type=float, default=ERASURE_TOL)
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(
        fresh, baseline, args.f1_tol, args.degrade_margin, args.erasure_tol
    )
    if failures:
        print("ROBUSTNESS REGRESSION:")
        for line in failures:
            print(f"FAIL {line}")
        print(
            "If this PR intentionally changed the fault model, the robust "
            "aggregators, or their scales, regenerate the baseline: "
            "PYTHONPATH=src python -m benchmarks.run --only robustness_bench"
        )
        return 1
    print("robustness_bench within tolerance of the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
