"""Perf-trend gate for the kernel microbenchmark.

  python -m benchmarks.check_kernel_micro FRESH.json BASELINE.json

Compares a freshly generated ``kernel_micro`` JSON against the committed
baseline (``experiments/bench/kernel_micro.json``) and exits non-zero when
any jnp-ref row regressed by more than THRESHOLD (default 3x — generous on
purpose: shared CI runners are noisy, and the gate exists to catch
*structural* regressions such as an accidentally de-jitted hot path, not
scheduling jitter).  Checked per matching row: ``us_ref`` in the compress
table and ``us_fused_ref`` in the fused-aggregate table.

``compare``/``gate_main`` are table-agnostic so sibling gates (e.g.
``benchmarks/check_serve_bench``) reuse them with their own row specs.
"""
from __future__ import annotations

import argparse
import json
import sys

THRESHOLD = 3.0

# (table name, row-key fields, timed field) triples this gate checks.
CHECKS = (
    ("rows", ("n",), "us_ref"),
    ("agg_rows", ("n_clients", "d"), "us_fused_ref"),
    ("agg_rows", ("n_clients", "d"), "us_wire_ref"),
    ("local_train_rows", ("n_clients", "window"), "us_fused_ref"),
)


def _index(rows: list[dict], keys: tuple[str, ...]) -> dict:
    return {tuple(r[k] for k in keys): r for r in rows}


def compare(
    fresh: dict, baseline: dict, threshold: float, checks=CHECKS, unit="us"
) -> list[str]:
    failures = []
    for table, keys, field in checks:
        fresh_rows = _index(fresh.get(table, []), keys)
        for row_key, base_row in _index(baseline.get(table, []), keys).items():
            if field not in base_row:
                continue  # baseline predates this metric: no trend yet
            tag = f"{table}[{dict(zip(keys, row_key))}].{field}"
            fresh_row = fresh_rows.get(row_key)
            if fresh_row is None or field not in fresh_row:
                # A vanished cell must fail loudly, or a benchmark refactor
                # that drops rows silently disables the very gate meant to
                # catch structural regressions.
                failures.append(f"{tag}: missing from the fresh JSON")
                continue
            ratio = fresh_row[field] / max(base_row[field], 1e-9)
            line = (
                f"{tag}: {base_row[field]:.2f}{unit} -> "
                f"{fresh_row[field]:.2f}{unit} ({ratio:.2f}x)"
            )
            if ratio > threshold:
                failures.append(line)
            else:
                print(f"ok   {line}")
    return failures


def gate_main(checks=CHECKS, name: str = "kernel_micro") -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help=f"freshly generated {name}.json")
    ap.add_argument("baseline", help=f"committed baseline {name}.json")
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(fresh, baseline, args.threshold, checks)
    if failures:
        print(f"PERF REGRESSION (> {args.threshold}x):")
        for line in failures:
            print(f"FAIL {line}")
        print(
            "If this PR intentionally changed the benchmark or the runner "
            "hardware class changed, regenerate the baseline: "
            f"PYTHONPATH=src python -m benchmarks.run --only {name}"
        )
        return 1
    print(f"{name} within {args.threshold}x of the committed baseline")
    return 0


def main() -> int:
    return gate_main()


if __name__ == "__main__":
    sys.exit(main())
