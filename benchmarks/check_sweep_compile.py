"""Compile-count gate for config-axis sweeps (Engine.sweep, PR 5).

  python -m benchmarks.check_sweep_compile FRESH.json BASELINE.json

Sibling of ``benchmarks/check_kernel_micro`` for the sweep batching
contract instead of kernel timings: a sweep that silently falls back to
per-cell compilation (a knob accidentally promoted to a static field, a
shape-class signature that fragments, a benchmark rewired off
``Engine.sweep``) shows up as a ``sweep_compiled_programs`` regression in
the bench JSON's ``"engine"`` block — which, unlike wall-clock, is exact
and runner-independent, so the threshold is equality, not a noise
multiplier.  Checked per JSON:

* ``engine.sweep_compiled_programs`` must not exceed the committed
  baseline (program-count regression);
* ``engine.sweep_cells`` must not shrink (a benchmark refactor that stops
  routing cells through the sweep would otherwise disable the gate).
"""
from __future__ import annotations

import argparse
import json
import sys


def compare(fresh: dict, baseline: dict, name: str = "") -> list[str]:
    failures = []
    fe = fresh.get("engine") or {}
    be = baseline.get("engine") or {}
    if "sweep_compiled_programs" not in be:
        print(f"ok   {name}: baseline predates sweep accounting; no trend yet")
        return failures
    tag = f"{name}engine.sweep_compiled_programs"
    fresh_programs = fe.get("sweep_compiled_programs")
    if fresh_programs is None:
        failures.append(f"{tag}: missing from the fresh JSON")
        return failures
    line = (
        f"{tag}: {be['sweep_compiled_programs']} -> {fresh_programs} "
        f"(cells {be.get('sweep_cells')} -> {fe.get('sweep_cells')})"
    )
    if fresh_programs > be["sweep_compiled_programs"]:
        failures.append(f"{line}: per-cell compilation fallback")
    elif fe.get("sweep_cells", 0) < be.get("sweep_cells", 0):
        failures.append(f"{line}: sweep coverage shrank")
    else:
        print(f"ok   {line}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly generated bench JSON")
    ap.add_argument("baseline", help="committed baseline bench JSON")
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(fresh, baseline)
    if failures:
        print("SWEEP COMPILE-COUNT REGRESSION:")
        for line in failures:
            print(f"FAIL {line}")
        print(
            "If this PR intentionally changed the sweep structure, "
            "regenerate the baseline: PYTHONPATH=src python -m "
            "benchmarks.run --only <module>"
        )
        return 1
    print("sweep compile counts match the committed baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
