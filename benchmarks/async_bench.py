"""Async-family benchmark: round throughput + accuracy vs simulated time.

Measures the claim behind ``core/async_fl``: an event-driven loop paced by
the median acoustic path produces global model updates in fewer simulated
seconds than the synchronous loop paced by the slowest feasible path —
without giving the detection F1 back.  Compared head-to-head, on the SAME
event-driven clock (compute + uplink wait, then merge propagation):

* the sync baseline: ``async_fl.sync_limit`` — every merge waits for the
  whole fleet's slowest uplink (pinned equivalent to ``hfl.train`` by
  ``tests/test_async_fl.py``, which is what makes it the fair baseline:
  identical numerics, identical clock semantics);
* a small (alpha, buffer) staleness grid of async cells, all run as ONE
  compiled ``Engine.sweep`` program: each merge waits only for the
  ``buffer_k`` fastest paths.  Reported per cell: simulated seconds per
  global merge, F1, and mean staleness at merge.

``speedup_vs_sync`` (sync s/round over async s/merge) is the headline
number; ``benchmarks/check_async_bench`` gates it (and the per-cell F1)
against the committed ``experiments/bench/async_bench.json`` — simulated
time is deterministic for a given seed, so unlike wall-clock the gate can
run tight.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common
from repro.core import async_fl
from repro.launch import experiment as exp
from repro.loadgen import traces

# (staleness exponent, merge buffer as a fraction of the fleet) cells.
CELLS = ((0.0, 0.5), (0.5, 0.25), (1.0, 0.25))
EVENTS_PER_ROUND = 3  # fog ticks simulated per sync-round equivalent


def _configs(scale: common.Scale):
    n = scale.train_n[50]
    base = exp.make_config(
        n_sensors=n, n_fog=max(4, n // 6),
        rounds=scale.rounds, local_epochs=scale.local_epochs,
    )
    cfgs = [
        async_fl.AsyncFLConfig(
            base=base,
            n_events=scale.rounds * EVENTS_PER_ROUND,
            buffer_k=max(2.0, frac * n),
            fog_k=2.0,
            alpha=alpha,
        )
        for alpha, frac in CELLS
    ]
    return n, base, cfgs


def run(scale: common.Scale) -> dict:
    eng = common.get_engine()
    eng.take_log()  # drop entries from earlier modules
    n, base, cfgs = _configs(scale)

    def ds_fn(s):
        return common.make_dataset(700 + s, n, scale)

    sync = eng.run(
        "hfl-async", async_fl.sync_limit(base), scale.seeds, ds_fn,
        label="async:sync-baseline",
    )
    sync_time = float(jnp.mean(sync["sim_time_s"]))
    sync_merges = float(jnp.mean(sync["merges"]))
    sync_row = dict(
        f1_mean=sync.seed_mean_std("f1")[0],
        f1_std=sync.seed_mean_std("f1")[1],
        sim_time_s=sync_time,
        rounds=base.rounds,
        merges=sync_merges,
        sim_s_per_round=sync_time / max(sync_merges, 1.0),
    )

    sw = eng.sweep("hfl-async", cfgs, scale.seeds, ds_fn,
                   label="async:staleness-sweep")
    rows = []
    for i, (alpha, frac) in enumerate(CELLS):
        f1m, f1sd = sw.seed_mean_std("f1", i)
        sim_time = float(jnp.mean(sw["sim_time_s"][i]))
        merges = float(jnp.mean(sw["merges"][i]))
        s_per_merge = sim_time / max(merges, 1.0)
        rows.append(dict(
            alpha=alpha,
            buffer_frac=frac,
            arrival="physics",
            n_events=cfgs[i].n_events,
            f1_mean=f1m, f1_std=f1sd,
            sim_time_s=sim_time,
            merges=merges,
            staleness_mean=float(jnp.mean(sw["staleness"][i])),
            sim_s_per_merge=s_per_merge,
            speedup_vs_sync=sync_row["sim_s_per_round"] / max(s_per_merge, 1e-9),
        ))

    # --- trace-replay cell (PR 10): an MMPP ``ArrivalTrace`` replaces the
    # synthetic (Eq.-21 latency-model) arrival clock.  Per-client
    # launch->arrival delay = that sensor's mean inter-event gap in the
    # trace, fed through the ``arrival_delay_s`` leaf — a (N,) array
    # switches ``core/async_fl`` to replayed delays.  The leaf's shape
    # differs from the scalar cells', so it compiles as its own cell
    # rather than joining the staleness sweep.
    trace = traces.mmpp_trace(
        1047, rate_on_hz=0.5 * n, mean_on_s=10.0, mean_off_s=20.0,
        duration_s=120.0, fleet=n, n_fog=max(4, n // 6),
    )
    counts = jnp.zeros((n,), jnp.float32).at[
        jnp.asarray(trace.sensor)
    ].add(1.0)
    delays = jnp.float32(trace.duration_s) / jnp.maximum(counts, 1.0)
    alpha_mm, frac_mm = CELLS[1]
    mm_cfg = cfgs[1].replace(arrival_delay_s=delays)
    mm = eng.run("hfl-async", mm_cfg, scale.seeds, ds_fn,
                 label="async:mmpp-replay")
    mm_time = float(jnp.mean(mm["sim_time_s"]))
    mm_merges = float(jnp.mean(mm["merges"]))
    mm_s_per_merge = mm_time / max(mm_merges, 1.0)
    rows.append(dict(
        alpha=alpha_mm,
        buffer_frac=frac_mm,
        arrival="mmpp",
        n_events=mm_cfg.n_events,
        f1_mean=mm.seed_mean_std("f1")[0],
        f1_std=mm.seed_mean_std("f1")[1],
        sim_time_s=mm_time,
        merges=mm_merges,
        staleness_mean=float(jnp.mean(mm["staleness"])),
        sim_s_per_merge=mm_s_per_merge,
        speedup_vs_sync=sync_row["sim_s_per_round"] / max(mm_s_per_merge, 1e-9),
        trace=dict(
            kind=trace.kind, n_events=int(trace.n_events),
            mean_rate_hz=float(trace.mean_rate_hz()),
            duration_s=float(trace.duration_s),
        ),
    ))
    return {
        "n_sensors": n,
        "seeds": list(scale.seeds),
        "sync": sync_row,
        "rows": rows,
        "engine": common.engine_snapshot(eng.take_log()),
    }


def report(res: dict) -> str:
    s = res["sync"]
    lines = [
        "async_bench — event-driven vs synchronous round throughput "
        f"(N={res['n_sensors']}, {len(res['seeds'])} seeds)",
        f"sync baseline: {s['sim_s_per_round']:.2f} sim-s/round, "
        f"F1 {s['f1_mean']:.3f}±{s['f1_std']:.3f} "
        f"({s['rounds']} rounds in {s['sim_time_s']:.1f} sim-s)",
        f"{'alpha':>6} {'buf':>5} {'s/merge':>8} {'speedup':>8} "
        f"{'stale':>6} {'F1':>13}",
    ]
    for r in res["rows"]:
        lines.append(
            f"{r['alpha']:>6g} {r['buffer_frac']:>5g} "
            f"{r['sim_s_per_merge']:>8.2f} {r['speedup_vs_sync']:>7.2f}x "
            f"{r['staleness_mean']:>6.2f} {r['f1_mean']:.3f}±{r['f1_std']:.3f}"
            + (f"  [{r['arrival']}]" if r.get("arrival") else "")
        )
    eng = res.get("engine")
    if eng:
        lines.append(
            f"engine: {eng['sweep_compiled_programs']} compiled program(s) "
            f"for {eng['sweep_cells']} staleness cells, "
            f"{eng['wall_s_total']:.1f}s batched wall"
        )
    return "\n".join(lines)
