"""Table IV: real benchmarks (SMD / SMAP / MSL), PA-F1 + energy.

Entity counts are the published ones (10 / 55 / 27) so these run at true
scale; real files are used when present under ``data/``, otherwise the
statistically matched surrogates (source recorded in the output).

Each (dataset, method) cell runs through the shared engine (the
point-adjusted evaluation variant): one compiled program with all seeds
vmapped, per-cell wall-clock + compile counts under ``"engine"``.
"""
from __future__ import annotations

from benchmarks import common
from repro.data import benchmarks as bench_data
from repro.launch import experiment as exp

METHODS = (
    "centralised", "fedavg", "fedprox",
    "hfl-nocoop", "hfl-selective", "hfl-nearest",
)


def run(scale: common.Scale) -> dict:
    eng = common.get_engine(point_adjusted=True)
    eng.take_log()
    rows = []
    for name in ("smd", "smap", "msl"):
        spec = bench_data.SPECS[name]
        n = spec.n_entities
        cfg = exp.make_config(
            n_sensors=n, n_fog=max(3, n // 8), rounds=scale.rounds_real,
            local_epochs=scale.local_epochs,
        )
        loaded = {
            s: bench_data.load(name, seed=s, length=scale.train_len)
            for s in scale.seeds
        }
        src = loaded[scale.seeds[0]].source
        ds_stack = eng.stack_datasets(
            [loaded[s].dataset for s in scale.seeds]
        )
        for meth in METHODS:
            r = eng.run(
                meth, cfg, scale.seeds, ds_stack, label=f"{name}:{meth}"
            )
            f1m, f1sd = r.seed_mean_std("f1")
            em, esd = r.seed_mean_std("e_total")
            rows.append(
                dict(dataset=name, source=src, method=meth,
                     pa_f1_mean=f1m, pa_f1_std=f1sd,
                     energy_mean=em, energy_std=esd)
            )
    return {"rows": rows, "engine": common.engine_snapshot(eng.take_log())}


def report(res: dict) -> str:
    lines = ["table4_real (PA-F1; source=real files if present, else surrogate)"]
    lines.append(f"{'dataset':8} {'method':14} {'PA-F1':>13} {'E (J)':>14} {'src':>10}")
    for r in res["rows"]:
        lines.append(
            f"{r['dataset']:8} {r['method']:14} "
            f"{r['pa_f1_mean']:.3f}±{r['pa_f1_std']:.3f} "
            f"{r['energy_mean']:8.2f}±{r['energy_std']:5.2f} {r['source']:>10}"
        )
    eng = res.get("engine")
    if eng:
        lines.append(
            f"engine: {eng['compiled_programs_new']} compiled programs vs "
            f"{eng['sequential_program_equivalent']} sequential traces"
        )
    return "\n".join(lines)
