"""Table IV: real benchmarks (SMD / SMAP / MSL), PA-F1 + energy.

Entity counts are the published ones (10 / 55 / 27) so these run at true
scale; real files are used when present under ``data/``, otherwise the
statistically matched surrogates (source recorded in the output).
"""
from __future__ import annotations

from benchmarks import common
from repro.data import benchmarks as bench_data
from repro.launch import experiment as exp

METHODS = (
    "centralised", "fedavg", "fedprox",
    "hfl-nocoop", "hfl-selective", "hfl-nearest",
)


def run(scale: common.Scale) -> dict:
    rows = []
    for name in ("smd", "smap", "msl"):
        spec = bench_data.SPECS[name]
        n = spec.n_entities
        cfg = exp.make_config(
            n_sensors=n, n_fog=max(3, n // 8), rounds=scale.rounds_real,
            local_epochs=scale.local_epochs,
        )
        for meth in METHODS:
            f1s, es, src = [], [], None
            for s in scale.seeds:
                bd = bench_data.load(name, seed=s, length=scale.train_len)
                src = bd.source
                r = exp.run_method(
                    meth, bd.dataset, cfg, seed=s, point_adjusted=True,
                )
                f1s.append(r.f1)
                es.append(r.e_total)
            f1m, f1sd = common.mean_std(f1s)
            em, esd = common.mean_std(es)
            rows.append(
                dict(dataset=name, source=src, method=meth,
                     pa_f1_mean=f1m, pa_f1_std=f1sd,
                     energy_mean=em, energy_std=esd)
            )
    return {"rows": rows}


def report(res: dict) -> str:
    lines = ["table4_real (PA-F1; source=real files if present, else surrogate)"]
    lines.append(f"{'dataset':8} {'method':14} {'PA-F1':>13} {'E (J)':>14} {'src':>10}")
    for r in res["rows"]:
        lines.append(
            f"{r['dataset']:8} {r['method']:14} "
            f"{r['pa_f1_mean']:.3f}±{r['pa_f1_std']:.3f} "
            f"{r['energy_mean']:8.2f}±{r['energy_std']:5.2f} {r['source']:>10}"
        )
    return "\n".join(lines)
