"""Fleet-axis scale bench (PR 10): the Table III module's scale tier as its
own runner entry, so ``experiments/bench/scale_bench.json`` gets the
wall-clock + peak-device-memory high-water marks per (N, client_chunk) cell
and ``benchmarks/check_scale_bench.py`` can gate them in CI.

The measurement itself lives next to the Table III scalability study
(:func:`benchmarks.table3_scalability.run_scale`): both walk the fleet axis,
this one past the paper's N=200 toward 10^4-10^6 sensors.
"""
from __future__ import annotations

from benchmarks import common
from benchmarks import table3_scalability as t3


def run(scale: common.Scale) -> dict:
    return t3.run_scale(scale)


def report(res: dict) -> str:
    return t3.report_scale(res)
