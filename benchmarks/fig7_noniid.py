"""Fig. 7: non-IID sensitivity (Dirichlet alpha in {0.1, 1e4}).

Training-bound; quick mode runs the budgeted N.  Checks the structural
claims: all methods remain functional under strong heterogeneity, and
HFL-Selective stays within the hierarchical family's accuracy band while
spending less f2f energy than HFL-Nearest.

Per method, BOTH alpha cells run as one ``Engine.sweep`` with the
per-alpha datasets stacked along the config axis — one compiled program
and one device launch per method (4 programs for the 8 cells), recorded
under ``"engine"``.
"""
from __future__ import annotations

from benchmarks import common
from repro.launch import experiment as exp

METHODS = ("fedprox", "hfl-nocoop", "hfl-selective", "hfl-nearest")
ALPHAS = (0.1, 1e4)


def run(scale: common.Scale) -> dict:
    eng = common.get_engine()
    eng.take_log()
    n = scale.train_n[100]
    cfg = exp.make_config(
        n_sensors=n, n_fog=max(4, n // 6), rounds=scale.rounds,
        local_epochs=scale.local_epochs,
    )
    ds_by_alpha = [
        eng.stack_datasets(
            [common.make_dataset(300 + s, n, scale, alpha=alpha)
             for s in scale.seeds]
        )
        for alpha in ALPHAS
    ]
    rows = []
    for meth in METHODS:
        sw = eng.sweep(
            meth, [cfg] * len(ALPHAS), scale.seeds, ds_by_alpha,
            label=f"{meth}:alpha-sweep",
        )
        for i, alpha in enumerate(ALPHAS):
            f1m, f1s_ = sw.seed_mean_std("f1", i)
            em, _ = sw.seed_mean_std("e_total", i)
            rows.append(
                dict(alpha=alpha, method=meth, f1_mean=f1m, f1_std=f1s_,
                     energy=em)
            )
    rows.sort(key=lambda r: (r["alpha"], METHODS.index(r["method"])))
    return {"n": n, "rows": rows,
            "engine": common.engine_snapshot(eng.take_log())}


def report(res: dict) -> str:
    lines = [f"fig7_noniid (N={res['n']})"]
    lines.append(f"{'alpha':>8} {'method':14} {'F1':>13} {'E (J)':>8}")
    for r in res["rows"]:
        lines.append(
            f"{r['alpha']:>8g} {r['method']:14} "
            f"{r['f1_mean']:.3f}±{r['f1_std']:.3f} {r['energy']:8.2f}"
        )
    return "\n".join(lines)
