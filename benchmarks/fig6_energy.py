"""Fig. 6: (a) selective-vs-nearest energy at N in {150, 200}; (b)
compression savings in matched low-vs-full upload tests.

Both panels are pure energy accounting -> run at the paper's exact scale
through ``Engine.sweep(family="audit")`` (PR 5): per method the N=200
default-compressor cell and panel (b)'s matched dense cell share ONE
compiled program (the audit reads the compressor only through the swept
payload-bits operand), so the 12 table entries run as 10 sweep cells in
7 compiled programs — recorded under ``"engine"`` with per-class
wall-clock.
Paper targets: selective cuts always-on cooperation energy by 31-33%; the
tier breakdown shows the gap is almost entirely fog-to-fog; compression
saves 94.8% (flat), 81.3% (HFL-NoCoop), 71.1% (HFL-Nearest) total energy.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common
from repro.core import compression as comp
from repro.launch import experiment as exp

SEEDS = (0, 1, 2)
HFL_METHODS = ("hfl-nocoop", "hfl-selective", "hfl-nearest")

COMPRESSED = comp.CompressorConfig(rho_s=0.05, quant_bits=8)  # Table II
DENSE = comp.CompressorConfig(rho_s=1.0, quant_bits=32)


def _stats(sweep, cell: int) -> dict:
    """mean/std summaries of one sweep cell's (S, P) metric grids."""
    return {
        k: common.mean_std(jnp.ravel(v[cell]).tolist())
        for k, v in sweep.metrics.items()
    }


def _tier_row(a: dict) -> dict:
    return {
        "e_total": a["e_total"][0],
        "e_std": a["e_total"][1],
        "e_s2f": a["e_s2f"][0],
        "e_f2f": a["e_f2f"][0],
        "e_f2g": a["e_f2g"][0],
    }


def run(scale: common.Scale) -> dict:
    eng = common.get_engine()
    eng.take_log()

    # N=200 grid: per method ONE audit sweep; panel (b)'s methods add the
    # matched dense cell to the same program (hfl-selective only feeds
    # panel (a), so it sweeps the compressed cell alone).  Cell 0 feeds
    # panel (a)'s N=200 row; panel (b) reads both cells.
    panel_b_methods = ("fedprox", "hfl-nocoop", "hfl-nearest")
    sweeps200 = {
        meth: eng.sweep(
            meth,
            [
                exp.make_config(n_sensors=200, n_fog=20, rounds=20,
                                compressor=COMPRESSED),
            ] + ([
                exp.make_config(n_sensors=200, n_fog=20, rounds=20,
                                compressor=DENSE),
            ] if meth in panel_b_methods else []),
            SEEDS, family="audit", label=f"n=200:{meth}:audit-sweep",
        )
        for meth in HFL_METHODS + ("fedprox",)
    }

    panel_a = []
    for n in (150, 200):
        row = {"n": n}
        for meth in HFL_METHODS:
            if n == 200:
                row[meth] = _tier_row(_stats(sweeps200[meth], 0))
            else:
                cfg = exp.make_config(n_sensors=n, n_fog=n // 10, rounds=20)
                sw = eng.sweep(
                    meth, [cfg], SEEDS, family="audit",
                    label=f"n={n}:{meth}:audit",
                )
                row[meth] = _tier_row(_stats(sw, 0))
        sel, near = row["hfl-selective"]["e_total"], row["hfl-nearest"]["e_total"]
        row["selective_saving_vs_nearest"] = 1.0 - sel / near
        panel_a.append(row)

    # Panel (b): matched compressed (rho_s=0.05+int8) vs full-precision.
    panel_b = []
    for meth in panel_b_methods:
        sw = sweeps200[meth]
        e_c = _stats(sw, 0)["e_total"][0]
        e_d = _stats(sw, 1)["e_total"][0]
        panel_b.append(
            dict(method=meth, compressed_j=e_c, dense_j=e_d,
                 saving=1.0 - e_c / e_d)
        )
    return {"panel_a": panel_a, "panel_b": panel_b,
            "engine": common.engine_snapshot(eng.take_log())}


def report(res: dict) -> str:
    lines = ["fig6_energy (paper scale, 3 seeds)"]
    lines.append("(a) hierarchical-method energy + tier breakdown")
    for row in res["panel_a"]:
        lines.append(f"  N={row['n']}:")
        for meth in HFL_METHODS:
            e = row[meth]
            lines.append(
                f"    {meth:14} total {e['e_total']:7.1f} J "
                f"(s2f {e['e_s2f']:6.1f} | f2f {e['e_f2f']:6.1f} | "
                f"f2g {e['e_f2g']:6.1f})"
            )
        lines.append(
            f"    selective saves {row['selective_saving_vs_nearest']:.1%}"
            " of always-on energy   [paper: 31-33%]"
        )
    lines.append("(b) compression savings (rho_s=0.05+int8 vs 32-bit dense)")
    for r in res["panel_b"]:
        lines.append(
            f"    {r['method']:14} {r['dense_j']:8.1f} J -> "
            f"{r['compressed_j']:7.1f} J   saving {r['saving']:.1%}"
        )
    lines.append("    [paper: 94.8% flat, 81.3% NoCoop, 71.1% Nearest]")
    eng = res.get("engine")
    if eng:
        lines.append(
            f"engine: {eng['sweep_compiled_programs']} compiled programs for "
            f"{eng['sweep_cells']} sweep cells "
            f"(vs {eng['sequential_program_equivalent']} sequential traces)"
        )
    return "\n".join(lines)
