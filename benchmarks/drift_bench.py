"""Dynamic-world benchmark: topology drift + re-association, adaptive attack.

Two sub-grids, one JSON, gated by ``benchmarks/check_drift_bench.py``:

**Drift trio** (``hfl-selective``): a compact deployment with a tight
acoustic budget (``sl_max_db=135`` => ~580 m feasible range) where sensors
ride a depth-sheared current (``core/drift.current_advection_step``) while
fogs wander under Gauss-Markov mobility.  Three cells share ONE compiled
program (``active=True`` pins the drift shape-class):

  static   — no drift (rates zero, cadence 1), the anchor;
  frozen   — drift with ``reassoc_every=inf``: round-0 association kept
             forever, so links stretch past feasibility and participation
             collapses ("stale assignment, live physics");
  reassoc  — same drift with re-association every 2 rounds: sensors
             re-attach to the nearest feasible fog and participation (and
             with it F1) holds near the static anchor.

The degradation observable is PARTICIPATION, not F1: at quick scale the
synthetic detector sits at its random-projection floor (an untrained AE
already separates the additive anomalies), so shrinking the training
cohort cannot move F1 — the gate instead pins that frozen association
sheds clients where re-association does not, and that F1 stays at the
anchor level throughout (drift must not corrupt the model).

**Adaptive-attack quartet** (``fedavg``): colluding clients run the
ALIE-style ``byz_mode="adaptive"`` attack (identical crafted updates that
track the previous global delta — see ``core/faults``) at
``byz_frac=0.25``.  Flat aggregation puts all clients in one robust
aggregation, so the trimmed mean's breakdown point applies cleanly:
``trim_frac=0.45 > byz_frac`` and the weighted median both hold F1 at the
clean anchor while the plain mean collapses.  (Per-fog hierarchical
aggregation can be hijacked by a colluder-majority cluster — that
sharper finding is documented in the README, not gated here.)

Cells: 3 + 4 = 7; compiled programs: 1 (drift trio) + 3 (one per robust
mode — the clean anchor shares the attacked mean's class because
``byz_mode`` pins the fault layer active even at ``byz_frac=0``) = 4.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import channel as ch
from repro.core import drift as drf
from repro.core import faults as flt
from repro.core import topology as topo
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.launch import experiment as exp

DRIFT_METHOD = "hfl-selective"
ATTACK_METHOD = "fedavg"
CURRENT_M_S = 3.0        # ~180 m/round: stale links die within the run
REASSOC_EVERY = 2.0
SL_MAX_DB = 135.0        # ~580 m feasible range (vs 1090 m at the default)
BYZ_FRAC = 0.25
BYZ_SCALE = 100.0        # sigma-proportional; collapses the mean in 6 rounds
TRIM_FRAC = 0.45


def _deployment(n: int) -> topo.DeploymentParams:
    """Compact basin: nearest-fog links stay well inside the tight
    acoustic range, a drifted-away frozen fog does not."""
    return topo.DeploymentParams(
        lx_m=1200.0, ly_m=1200.0, depth_m=400.0,
        n_sensors=n, n_fog=4,
        sensor_depth=(200.0, 350.0), fog_depth=(50.0, 150.0),
    )


def _base(scale: common.Scale, n: int):
    return exp.make_config(
        n_sensors=n, n_fog=4,
        rounds=scale.rounds, local_epochs=scale.local_epochs,
        deployment=_deployment(n),
        channel=dataclasses.replace(ch.ChannelParams(), sl_max_db=SL_MAX_DB),
    )


def _make_ds_fn(n: int, scale: common.Scale):
    def ds_fn(s):
        # One observation map per sensor (n_modes=n, tiny alpha): the
        # strongest heterogeneity the generator offers, so association
        # decisions move real data in and out of the cohort.
        cfg = SyntheticConfig(
            n_sensors=n, n_modes=n, dirichlet_alpha=0.05,
            train_len=scale.train_len,
            val_len=max(32, scale.train_len // 3),
            test_len=scale.train_len,
        )
        return normalize(generate(jax.random.key(800 + s), cfg))

    return ds_fn


def _drift_cells(base):
    return [
        ("static", base.replace(drift=drf.DriftConfig(active=True))),
        ("frozen", base.replace(drift=drf.DriftConfig(
            sensor_current_m_s=CURRENT_M_S, reassoc_every=float("inf")))),
        ("reassoc", base.replace(drift=drf.DriftConfig(
            sensor_current_m_s=CURRENT_M_S, reassoc_every=REASSOC_EVERY))),
    ]


def _attack_cells(base):
    cells = [("clean-mean", base.replace(faults=flt.FaultConfig(
        byz_frac=0.0, byz_scale=BYZ_SCALE, byz_mode="adaptive")))]
    for robust in ("mean", "trimmed", "median"):
        cells.append((f"adaptive-{robust}", base.replace(
            robust=robust,
            trim_frac=TRIM_FRAC if robust == "trimmed" else 0.0,
            faults=flt.FaultConfig(
                byz_frac=BYZ_FRAC, byz_scale=BYZ_SCALE,
                byz_mode="adaptive"),
        )))
    return cells


def run(scale: common.Scale) -> dict:
    eng = common.get_engine()
    eng.take_log()
    n = scale.train_n[50]
    base = _base(scale, n)
    ds_fn = _make_ds_fn(n, scale)

    rows = []
    n_classes = 0
    for method, cells, grid in (
        (DRIFT_METHOD, _drift_cells(base), "drift"),
        (ATTACK_METHOD, _attack_cells(base), "attack"),
    ):
        sw = eng.sweep(method, [c for _, c in cells], scale.seeds, ds_fn,
                       label=f"drift:{grid}-grid")
        n_classes += sw.n_classes
        for i, (cell, cfg) in enumerate(cells):
            f1m, f1sd = sw.seed_mean_std("f1", i)
            rows.append(dict(
                cell=cell,
                grid=grid,
                method=method,
                robust=cfg.robust,
                byz_frac=float(cfg.faults.byz_frac),
                current_m_s=float(cfg.drift.sensor_current_m_s),
                reassoc_every=(
                    None if cfg.drift.reassoc_every == float("inf")
                    else float(cfg.drift.reassoc_every)
                ),
                f1_mean=f1m, f1_std=f1sd,
                participation=float(jnp.mean(sw["participation"][i])),
                nonfinite_rounds=float(jnp.sum(sw["nonfinite_rounds"][i])),
                e_total_mean=float(jnp.mean(sw["e_total"][i])),
            ))
    return {
        "n_sensors": n,
        "seeds": list(scale.seeds),
        "n_classes": n_classes,
        "current_m_s": CURRENT_M_S,
        "byz_scale": BYZ_SCALE,
        "trim_frac": TRIM_FRAC,
        "rows": rows,
        "engine": common.engine_snapshot(eng.take_log()),
    }


def _row(res: dict, cell: str) -> dict | None:
    for r in res["rows"]:
        if r["cell"] == cell:
            return r
    return None


def report(res: dict) -> str:
    lines = [
        "drift_bench — topology drift x re-association + adaptive attack "
        f"(N={res['n_sensors']}, {len(res['seeds'])} seeds, "
        f"current {res['current_m_s']:g} m/s, "
        f"ALIE z={res['byz_scale']:g})",
        f"{'cell':>16} {'method':>14} {'F1':>13} {'particip':>9} "
        f"{'energy-J':>9}",
    ]
    for r in res["rows"]:
        lines.append(
            f"{r['cell']:>16} {r['method']:>14} "
            f"{r['f1_mean']:.3f}±{r['f1_std']:.3f} "
            f"{r['participation']:>9.3f} {r['e_total_mean']:>9.2f}"
        )
    eng = res.get("engine")
    if eng:
        lines.append(
            f"engine: {eng['sweep_compiled_programs']} compiled program(s) "
            f"for {eng['sweep_cells']} grid cells "
            f"({res['n_classes']} shape-classes), "
            f"{eng['wall_s_total']:.1f}s batched wall"
        )
    return "\n".join(lines)
