"""Replay a bursty arrival trace against the scoring service, two ways.

The IoUT serving problem in one runnable file: telemetry surfaces in
bursts (on/off MMPP), and a fixed-size micro-batcher strands every
burst's leftover rows through the following silence.  This example
replays the SAME deterministic trace on a virtual clock against

  * the legacy fixed 1024-row batcher, and
  * deadline-driven adaptive micro-batching with 128/1024 shape buckets
    (optionally int8 serving weights via ``--int8``),

then prints a JSON comparison of true end-to-end request latency (queue
wait + batch formation + device time).  Expect the adaptive p99 to be
~max_wait_s while the fixed p99 rides the silence lengths.

  PYTHONPATH=src python examples/load_replay.py [--duration 4] [--int8]
"""
import argparse
import json
import tempfile

import jax

from repro.checkpoint import CheckpointStore
from repro.loadgen import VirtualClock, mmpp_trace, replay
from repro.models import autoencoder as ae
from repro.serving import ScoringService

D = 32


def run_config(name, trace, params, store, *, buckets, max_wait_s,
               weight_dtype="f32"):
    clock = VirtualClock()
    svc = ScoringService(
        store, params, buckets=buckets, max_wait_s=max_wait_s, tau=1.0,
        weight_dtype=weight_dtype, clock=clock, use_pallas=False,
    )
    rep = replay(svc, trace, clock, d=D)
    s = rep.summary()
    print(
        f"{name:>18}: p50 {s['e2e_p50_ms']:8.1f} ms   "
        f"p99 {s['e2e_p99_ms']:8.1f} ms   mean fill {s['mean_fill']:6.1f}   "
        f"compiles {s['compiles_by_bucket']}"
    )
    return s


def main(argv: list[str] | None = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=4.0)
    ap.add_argument("--rate-on", type=float, default=2000.0,
                    help="burst arrival rate, events/s")
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument("--int8", action="store_true",
                    help="also replay with int8-quantised serving weights")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    trace = mmpp_trace(
        args.seed, rate_on_hz=args.rate_on, mean_on_s=0.3, mean_off_s=0.5,
        duration_s=args.duration, fleet=64, n_fog=4, rows=16,
    )
    print(f"trace: {trace.n_events} events / {trace.total_rows} rows, "
          f"{trace.meta['bursts']} bursts over {trace.duration_s}s")

    params = ae.init(jax.random.key(args.seed + 1), D, (16, 8, 16))
    store = CheckpointStore(tempfile.mkdtemp(prefix="load_replay_"), keep=2)
    store.publish(1, params)

    wait = args.max_wait_ms / 1e3
    out = {
        "trace": trace.summary(),
        "fixed": run_config(
            "fixed", trace, params, store, buckets=(1024,), max_wait_s=None
        ),
        "adaptive_bucketed": run_config(
            "adaptive_bucketed", trace, params, store,
            buckets=(128, 1024), max_wait_s=wait,
        ),
    }
    if args.int8:
        out["adaptive_bucketed_int8"] = run_config(
            "int8", trace, params, store,
            buckets=(128, 1024), max_wait_s=wait, weight_dtype="int8",
        )
    out["p99_speedup"] = (
        out["fixed"]["e2e_p99_ms"] / out["adaptive_bucketed"]["e2e_p99_ms"]
    )
    print(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
