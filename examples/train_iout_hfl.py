"""End-to-end driver: full federated training of the underwater anomaly
detector, with checkpointing, per-round metric logs, and final evaluation
on a real benchmark (SMD; surrogate fallback when files are absent).

This is the paper's pipeline end-to-end:
  deployment -> feasibility graph -> nearest-feasible-fog association ->
  E local epochs -> Top-K+EF+int8 compressed uplinks -> fog aggregation ->
  selective fog mixing -> surface aggregation -> threshold calibration ->
  PA-F1 evaluation.

  PYTHONPATH=src python examples/train_iout_hfl.py [--rounds 10]
"""
import argparse
import os

import jax

from repro.checkpoint import CheckpointStore
from repro.core import hfl
from repro.core.cooperation import CoopRule
from repro.data import benchmarks as bench
from repro.launch import experiment as exp
from repro.models import autoencoder as ae


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/iout_hfl_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # SMD: 10 machines x 38 features (real files when present under data/).
    bd = bench.load("smd", seed=args.seed, length=128)
    ds = bd.dataset
    n = ds.train.shape[0]
    print(f"dataset: SMD ({bd.source}), {n} entities, D={ds.train.shape[-1]}")

    cfg = exp.make_config(
        n_sensors=n, n_fog=3, rounds=args.rounds,
        local_epochs=args.local_epochs, rule=CoopRule.SELECTIVE,
    )

    key = jax.random.key(args.seed)
    params = ae.init(key, ds.train.shape[-1], (16, 8, 16))
    state = hfl.init_state(key, params, cfg)
    round_fn = hfl.make_round_fn(ae.loss, ds, cfg)
    store = CheckpointStore(args.ckpt_dir, keep=2)

    print(f"{'round':>5} {'loss':>9} {'part':>5} {'E (J)':>8} {'coop':>4} {'batt':>7}")
    jitted = jax.jit(round_fn)
    for t in range(args.rounds):
        state, m = jitted(state, None)
        print(
            f"{t:>5} {float(m.loss):>9.4f} {float(m.participation):>5.2f} "
            f"{float(m.e_total):>8.4f} {int(m.coop_links):>4} "
            f"{float(m.battery_min):>7.2f}"
        )
        store.save(t + 1, state.params)

    # Threshold calibration + PA-F1 (paper Sec. V-D / VI-F protocol).
    from repro.core import anomaly

    d = ds.val.shape[-1]
    r = anomaly.evaluate_detector(
        lambda p, x: ae.apply(p, x),
        state.params,
        ds.val.reshape(-1, d),
        ds.test.reshape(-1, d),
        ds.test_label.reshape(-1),
        point_adjusted=True,
    )
    print(f"\nPA-F1 {float(r.f1):.4f}  (P {float(r.precision):.4f} / "
          f"R {float(r.recall):.4f})")
    print(f"checkpoints: {sorted(os.listdir(args.ckpt_dir))}")


if __name__ == "__main__":
    main()
