"""The paper's technique at LLM scale on the TPU mesh (DESIGN.md §3).

Hierarchical federated fine-tuning of a (reduced) llama3 on the production
mesh layout: clients live on the `data` axis, pods play the fog-cluster
role, and the three paper components map onto mesh collectives:

  sensor->fog upload        -> in-pod weighted psum over `data`
  fog->gateway uplink       -> cross-pod psum over `pod`
  Top-K+EF+int8 compression -> per-client update compression BEFORE the
                               expensive cross-pod hop (kernels/)
  selective fog cooperation -> ring collective_permute mixing over `pod`

On CPU this runs with a 1x1 mesh (the collectives are identities) — the
same program lowers unchanged to the 2x16x16 production mesh, which is
exactly what launch/dryrun.py proves.

  PYTHONPATH=src python examples/federated_llm.py
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core import aggregation as agg
from repro.core import compression as comp
from repro.data.pipeline import lm_batches
from repro.launch.mesh import shard_map_compat
from repro.models import api


def main() -> None:
    cfg = configs.get("llama3-8b", reduced=True)
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    key = jax.random.key(0)
    params = api.init_params(key, cfg)
    lfn = api.loss_fn(cfg)
    compressor = comp.CompressorConfig(rho_s=0.05, quant_bits=8,
                                       mode="blockwise")

    # Synthetic token stream per client shard.
    stream = jax.random.randint(jax.random.key(1), (4096,), 0, cfg.vocab_size)

    from jax.flatten_util import ravel_pytree
    flat0, unravel = ravel_pytree(params)
    err0 = jnp.zeros_like(flat0)

    def local_round(params, err, key):
        """One client's local step + compressed update (per data shard)."""
        batch = {"tokens": lm_batches(key, stream, 2, 32)}
        loss, grads = jax.value_and_grad(lfn)(params, batch)
        delta = jax.tree_util.tree_map(lambda g: -1e-3 * g, grads)
        recon, new_err = comp.compress_update(delta, err, compressor)
        return recon, new_err, loss

    def fed_step(params, err, key):
        recon, new_err, loss = local_round(params, err, key)
        # Hierarchical aggregation: cheap in-pod hop, expensive cross-pod
        # hop on the ALREADY-COMPRESSED update (beyond-paper optimisation).
        update = agg.hierarchical_mean(
            recon, jnp.float32(1.0), intra_axis="data", inter_axis="pod"
        )
        # Selective-cooperation analogue: light gossip over the pod ring.
        update = agg.ring_mix(update, 0.2, axis="pod")
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            params, update,
        )
        return new_params, new_err, jax.lax.pmean(loss, "data")

    sharded = jax.jit(
        shard_map_compat(
            fed_step,
            mesh=mesh,
            in_specs=(P(), P(), P()),
            out_specs=(P(), P(), P()),
        )
    )

    err = err0
    d = flat0.shape[0]
    bits = comp.payload_bits(d, compressor)
    print(f"model: reduced llama3 ({d:,} params)")
    print(f"compressed cross-pod payload: {bits / 8 / 1024:.1f} KiB "
          f"(vs {32 * d / 8 / 1024:.1f} KiB dense, "
          f"{comp.compression_ratio(d, compressor):.1%})")
    for step in range(5):
        key, k = jax.random.split(key)
        params, err, loss = sharded(params, err, k)
        print(f"step {step}: loss {float(loss):.4f}")
    print("same program lowers to the 2x16x16 mesh — see launch/dryrun.py")


if __name__ == "__main__":
    main()
