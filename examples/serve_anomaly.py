"""End-to-end train-and-serve: federated training publishes rounds into a
CheckpointStore while the online scoring service consumes them.

The paper's deployment shape (Sec. V-D) as one pipeline:

  1. a short ``hfl.train`` run publishes its first rounds into the store;
  2. a :class:`repro.serving.ScoringService` comes up on the latest round,
     calibrates per-fog + global thresholds from a validation stream
     (streaming reservoirs, ``serving/calibrate``), and scores a first
     wave of telemetry with the fused score kernel path;
  3. training CONTINUES (publishing with a round offset) and the service
     hot-swaps the fresh params mid-stream — double-buffered, same
     treedef, zero recompiles — before scoring the second wave.

Prints a JSON summary (swaps, compile count, throughput, detection F1);
tests/test_serving.py parses it and pins swaps >= 1 and compiles == 1.

  PYTHONPATH=src python examples/serve_anomaly.py [--rounds 6]
"""
import argparse
import json
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.core import anomaly, hfl
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.launch import experiment as exp
from repro.models import autoencoder as ae
from repro.serving import ScoringService, StreamingCalibrator


def main(argv: list[str] | None = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--n-sensors", type=int, default=10)
    ap.add_argument("--n-fog", type=int, default=3)
    ap.add_argument("--train-len", type=int, default=64)
    ap.add_argument("--batch-rows", type=int, default=512)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    dcfg = SyntheticConfig(
        n_sensors=args.n_sensors,
        train_len=args.train_len,
        val_len=max(24, args.train_len // 2),
        test_len=args.train_len,
    )
    ds = normalize(generate(jax.random.key(args.seed), dcfg))
    d = ds.train.shape[-1]
    params0 = ae.init(jax.random.key(args.seed + 1), d, (16, 8, 16))
    cfg = exp.make_config(
        n_sensors=args.n_sensors, n_fog=args.n_fog,
        rounds=args.rounds, local_epochs=1,
    )
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="serve_anomaly_")
    store = CheckpointStore(ckpt_dir, keep=3)

    # --- phase 1: first half of training, publishing every round ---------
    half = max(1, args.rounds // 2)
    params, _ = hfl.train(
        jax.random.key(args.seed + 2), params0, ae.loss, ds,
        cfg.replace(rounds=half), store=store,
    )
    print(f"phase 1: published rounds {store.steps()} -> {ckpt_dir}")

    # --- serve: calibrate from the validation stream, score wave A -------
    calib = StreamingCalibrator(capacity=2048, n_fog=args.n_fog)
    svc = ScoringService(
        store, params0, batch_rows=args.batch_rows, calibrator=calib,
    )
    fog_id = np.arange(args.n_sensors) % args.n_fog     # serving-side routing
    svc.ingest_validation(np.asarray(ds.val), fog_id[:, None])
    print(f"serving round {svc.loaded_step}; "
          f"global tau = {float(calib.global_tau):.3f}")

    wave_a = {
        s: svc.submit(np.asarray(ds.test[s]), fog=int(fog_id[s]))
        for s in range(args.n_sensors)
    }
    res_a = svc.drain()

    # --- phase 2: training continues; the service hot-swaps mid-stream ---
    hfl.train(
        jax.random.key(args.seed + 3), params, ae.loss, ds,
        cfg.replace(rounds=args.rounds - half), store=store,
        publish_offset=half,
    )
    swapped = svc.poll()
    svc.ingest_validation(np.asarray(ds.val), fog_id[:, None])
    print(f"phase 2: published rounds {store.steps()}, "
          f"hot-swapped to round {svc.loaded_step} (swapped={swapped})")

    wave_b = {
        s: svc.submit(np.asarray(ds.test[s]), fog=int(fog_id[s]))
        for s in range(args.n_sensors)
    }
    res_b = svc.drain()

    # --- detection quality of the served model (wave B flags) ------------
    flags = jnp.stack([jnp.asarray(res_b[wave_b[s]].flag)
                       for s in range(args.n_sensors)])
    f1 = anomaly.pointwise_f1(flags.reshape(-1), ds.test_label.reshape(-1))
    moved = float(
        np.mean(np.abs(
            np.stack([res_b[wave_b[s]].error for s in range(args.n_sensors)])
            - np.stack([res_a[wave_a[s]].error for s in range(args.n_sensors)])
        ))
    )

    summary = {
        "rounds_published": store.steps(),
        "served_round": svc.loaded_step,
        "swapped": bool(swapped),
        "mean_abs_error_shift": moved,    # params really changed mid-stream
        "f1": float(f1.f1),
        "precision": float(f1.precision),
        "recall": float(f1.recall),
        "service": svc.stats.summary(),
    }
    print(json.dumps(summary, indent=1))
    return summary


if __name__ == "__main__":
    main()
