"""Quickstart: train an underwater hierarchical-FL anomaly detector in ~1 min.

Builds a 24-sensor / 5-fog synthetic IoUT deployment, trains the paper's
autoencoder with HFL-Selective (compressed uplinks), and prints detection
quality, participation, and the three-tier energy breakdown.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.launch import experiment as exp


def main() -> None:
    n_sensors, n_fog = 24, 5

    ds = normalize(
        generate(
            jax.random.key(0),
            SyntheticConfig(n_sensors=n_sensors, train_len=96, val_len=32,
                            test_len=96),
        )
    )
    cfg = exp.make_config(
        n_sensors=n_sensors, n_fog=n_fog, rounds=6, local_epochs=2,
        batch_size=16,
    )

    print("method            F1     part   E_total  (s2f / f2f / f2g) J")
    for method in ("fedavg", "hfl-nocoop", "hfl-selective", "hfl-nearest"):
        r = exp.run_method(method, ds, cfg, seed=0)
        print(
            f"{method:14} {r.f1:6.3f} {r.participation:6.2f} "
            f"{r.e_total:8.3f}  ({r.e_s2f:.3f} / {r.e_f2f:.3f} / {r.e_f2g:.3f})"
        )

    print(
        "\nExpected pattern (paper Sec. VI): flat FL is cheapest but only a"
        "\nsubset of sensors participates; hierarchy restores participation;"
        "\nselective cooperation costs less than always-on (f2f column)."
    )


if __name__ == "__main__":
    main()
