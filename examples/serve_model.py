"""Batched serving example: prefill + autoregressive decode with the
per-family cache (KV / SSM state / RG-LRU state) via the serving launcher.

  PYTHONPATH=src python examples/serve_model.py [--arch mamba2-2.7b]
"""
import argparse

from repro.launch import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    args = ap.parse_args()

    serve.main([
        "--arch", args.arch,
        "--batch", "4", "--prompt-len", "16", "--new-tokens", "8",
    ])


if __name__ == "__main__":
    main()
