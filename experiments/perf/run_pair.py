"""Perf-iteration driver: dry-run ONE (arch, shape) pair and log the
roofline terms under a tag, appending to experiments/perf/log.jsonl.

  PYTHONPATH=src python experiments/perf/run_pair.py qwen3_14b prefill_32k TAG [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
import json  # noqa: E402
import sys   # noqa: E402

from repro.launch import dryrun, roofline  # noqa: E402


def main() -> None:
    arch, shape, tag = sys.argv[1], sys.argv[2], sys.argv[3]
    multi = "--multi-pod" in sys.argv
    rec = dryrun.dryrun_one(arch, shape, multi_pod=multi)
    row = roofline.analyse(rec) if rec.get("status") == "ok" else rec
    out = {"tag": tag, "multi_pod": multi, **{k: v for k, v in row.items()}}
    os.makedirs("experiments/perf", exist_ok=True)
    with open("experiments/perf/log.jsonl", "a") as f:
        f.write(json.dumps(out) + "\n")
    print(json.dumps(out, indent=1, default=str))


if __name__ == "__main__":
    main()
