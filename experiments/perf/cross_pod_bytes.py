"""Classify HLO collectives by whether their replica groups cross the pod
boundary, and sum bytes per class.  Pod axis is the leading mesh dim, so
on a (2, 4, 4) mesh devices 0-15 are pod 0 and 16-31 pod 1.

  PYTHONPATH=src python experiments/perf/cross_pod_bytes.py [baseline|hfl] [rho] [mode]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=32 "
    + os.environ.get("XLA_FLAGS", "")
)
import json  # noqa: E402
import re    # noqa: E402
import sys   # noqa: E402

import jax   # noqa: E402

sys.path.insert(0, os.path.dirname(__file__))
import run_pair_c as rpc  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch import dryrun  # noqa: E402

OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
       "collective-permute")


def _iota_groups(spec: str):
    """Parse XLA's iota replica-group format: [G,S]<=[d0,...]T(perm)."""
    import numpy as np

    m = re.match(r"\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", spec)
    if not m:
        return None
    g, size = int(m.group(1)), int(m.group(2))
    dims = [int(x) for x in m.group(3).split(",")]
    n = 1
    for d in dims:
        n *= d
    arr = np.arange(n).reshape(dims)
    if m.group(4):
        arr = arr.transpose([int(x) for x in m.group(4).split(",")])
    return arr.reshape(g, size)


def classify(hlo: str, pod_size: int = 16) -> dict:
    out = {
        "cross_pod": dict.fromkeys(OPS, 0.0),
        "intra_pod": dict.fromkeys(OPS, 0.0),
    }
    for line in hlo.splitlines():
        s = line.strip()
        m = re.match(
            r"%?[\w.\-]+ = (.+?) (" + "|".join(OPS) + r")\(", s
        )
        if not m:
            continue
        nbytes = dryrun._shape_bytes(m.group(1))
        op = m.group(2)
        crossing = False
        groups = None
        # iota format: replica_groups=[G,S]<=[dims]T(perm)
        gi = re.search(
            r"replica_groups=(\[\d+,\d+\]<=\[[\d,]+\](?:T\([\d,]+\))?)", s
        )
        if gi:
            groups = _iota_groups(gi.group(1))
        else:
            gm = re.search(r"replica_groups=\{(.*?)\}\}", s)
            if gm:
                groups = [
                    [int(x) for x in grp.split(",")]
                    for grp in re.findall(r"\{([\d,]+)\}", gm.group(0))
                ]
        if groups is not None:
            for ids in groups:
                pods = {int(i) // pod_size for i in ids}
                if len(pods) > 1:
                    crossing = True
                    break
        else:
            sm = re.search(r"source_target_pairs=\{(.*)\}", s)
            if sm:
                for pair in re.findall(r"\{(\d+),(\d+)\}", sm.group(0)):
                    a, b = int(pair[0]), int(pair[1])
                    if a // pod_size != b // pod_size:
                        crossing = True
                        break
        key = "cross_pod" if crossing else "intra_pod"
        out[key][op] += nbytes
    for k in out:
        out[k]["total"] = sum(out[k][o] for o in OPS)
    return out


def main() -> None:
    mode = sys.argv[1] if len(sys.argv) > 1 else "hfl"
    rho = float(sys.argv[2]) if len(sys.argv) > 2 else 0.05
    comp = sys.argv[3] if len(sys.argv) > 3 else "int8"
    mesh = rpc.make_small_multipod()
    base = configs.get(rpc.ARCH)
    if mode == "baseline":
        c1 = rpc._lower_plain(base.replace(scan_unroll=1), mesh)
        c2 = rpc._lower_plain(base.replace(scan_unroll=2), mesh)
    else:
        c1 = rpc.lower_hfl(base.replace(scan_unroll=1), mesh, rho, comp)
        c2 = rpc.lower_hfl(base.replace(scan_unroll=2), mesh, rho, comp)
    r1, r2 = classify(c1.as_text()), classify(c2.as_text())
    L = base.n_layers
    corrected = {}
    for k in r1:
        corrected[k] = {
            op: r1[k][op] + (L - 1) * max(r2[k][op] - r1[k][op], 0.0)
            for op in list(OPS) + ["total"]
        }
    out = {"tag": f"crosspod_{mode}_{comp}_rho{rho}", "raw": r1,
           "corrected": corrected}
    with open("experiments/perf/log.jsonl", "a") as f:
        f.write(json.dumps(out) + "\n")
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
