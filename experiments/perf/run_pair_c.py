"""Pair C measurement: llama3-8b train_4k on a multipod mesh — standard
data-parallel train_step (baseline) vs the compressed selective cross-pod
HFL step (core/mesh_fl.py).

NOTE: XLA's SPMD partitioner CHECK-fails on mixed manual/auto shard_map
at the full 2x16x16 mesh (spmd_partitioner_util.cc:504, device-group
mismatch — a compiler limitation, not a model property), so this A/B runs
on a reduced 2x4x4 multipod mesh for BOTH arms; the comparison metric is
the relative cross-pod collective traffic.

  PYTHONPATH=src python experiments/perf/run_pair_c.py [baseline|hfl] [rho]
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=32 "
    + os.environ.get("XLA_FLAGS", "")
)
import json  # noqa: E402
import sys   # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.core import mesh_fl  # noqa: E402
from repro.launch import dryrun, roofline, sharding as shlib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import api  # noqa: E402

ARCH, SHAPE = "llama3_8b", "train_4k"


def make_small_multipod():
    return jax.make_mesh((2, 4, 4), ("pod", "data", "model"))


def lower_hfl(cfg, mesh, rho, comp_mode="int8"):
    params_abs = api.abstract_params(cfg)
    params_sh = shlib.tree_shardings(params_abs, api.param_axes(cfg), mesh)
    n_pods = mesh.shape["pod"]
    err_abs = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((n_pods, *l.shape), jnp.float32),
        params_abs,
    )
    from jax.sharding import NamedSharding, PartitionSpec as P
    # err must mirror the gradient leaf shardings (pod + the param spec),
    # otherwise v = g + err forces dense f32 regathers of every leaf.
    err_sh = jax.tree_util.tree_map(
        lambda psh: NamedSharding(mesh, P("pod", *psh.spec)), params_sh
    )
    specs = api.input_specs(cfg, SHAPES[SHAPE])
    specs_sh = shlib.batch_shardings(specs, mesh)
    step = mesh_fl.make_pod_hfl_train_step(cfg, mesh, rho_s=rho, mode=comp_mode)
    with jax.sharding.set_mesh(mesh):
        lowered = jax.jit(
            step,
            in_shardings=(params_sh, err_sh, specs_sh),
            out_shardings=(params_sh, err_sh, None),
            donate_argnums=(0, 1),
        ).lower(params_abs, err_abs, specs)
        return lowered.compile()




def _lower_plain(cfg, mesh):
    params_abs = api.abstract_params(cfg)
    params_sh = shlib.tree_shardings(params_abs, api.param_axes(cfg), mesh)
    specs = api.input_specs(cfg, SHAPES[SHAPE])
    specs_sh = shlib.batch_shardings(specs, mesh)
    fn = api.make_train_step(cfg)
    with jax.sharding.set_mesh(mesh):
        lowered = jax.jit(
            fn,
            in_shardings=(params_sh, specs_sh),
            out_shardings=(params_sh, None),
            donate_argnums=(0,),
        ).lower(params_abs, specs)
        return lowered.compile()


def _to_rec(base, c1, c2):
    cost1, cost2 = c1.cost_analysis(), c2.cost_analysis()
    coll1 = dryrun.collective_bytes(c1.as_text())
    coll2 = dryrun.collective_bytes(c2.as_text())
    L = base.n_layers

    def extrap(a, b):
        return a + (L - 1) * max(b - a, 0.0)

    return {
        "arch": ARCH, "shape": SHAPE, "status": "ok", "kind": "train",
        "mesh": [2, 4, 4], "axes": ["pod", "data", "model"],
        "chips": 32,
        "flops": cost1.get("flops"),
        "bytes_accessed": cost1.get("bytes accessed"),
        "collectives": coll1,
        "corrected": {
            "flops": extrap(cost1["flops"], cost2["flops"]),
            "bytes_accessed": extrap(
                cost1["bytes accessed"], cost2["bytes accessed"]
            ),
            "collective_total": extrap(coll1["total"], coll2["total"]),
        },
        "coll_by_type_raw": {k: v for k, v in coll1.items() if k != "count"},
        "memory": {},
    }

def main() -> None:
    mode = sys.argv[1] if len(sys.argv) > 1 else "hfl"
    comp_mode = sys.argv[3] if len(sys.argv) > 3 else "int8"
    rho = float(sys.argv[2]) if len(sys.argv) > 2 else 0.05
    mesh = make_small_multipod()

    if mode == "baseline":
        base = configs.get(ARCH)
        c1 = _lower_plain(base.replace(scan_unroll=1), mesh)
        c2 = _lower_plain(base.replace(scan_unroll=2), mesh)
        rec = _to_rec(base, c1, c2)
    else:
        base = configs.get(ARCH)
        c1 = lower_hfl(base.replace(scan_unroll=1), mesh, rho, comp_mode)
        c2 = lower_hfl(base.replace(scan_unroll=2), mesh, rho, comp_mode)
        rec = _to_rec(base, c1, c2)

    row = roofline.analyse(rec)
    out = {"tag": f"pairC_{mode}_{comp_mode}_rho{rho}", **row}
    out["coll_by_type_raw"] = rec["coll_by_type_raw"]
    if mode != "baseline":
        d = sum(
            int(jnp.prod(jnp.asarray(l.shape)))
            for l in jax.tree_util.tree_leaves(
                api.abstract_params(configs.get(ARCH))
            )
        )
        out["wire_bytes_compact"] = mesh_fl.wire_bytes(d, rho)
        out["wire_bytes_dense_f32"] = 4.0 * d
    os.makedirs("experiments/perf", exist_ok=True)
    with open("experiments/perf/log.jsonl", "a") as f:
        f.write(json.dumps(out, default=str) + "\n")
    print(json.dumps(out, indent=1, default=str))


if __name__ == "__main__":
    main()
