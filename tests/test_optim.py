"""Tests for local solvers: SGD, proximal SGD (FedProx), SCAFFOLD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import scaffold as scf
from repro.optim.sgd import local_sgd, proximal_local_sgd


def quad_loss(params, batch):
    # ||params - mean(batch)||^2 per batch; optimum at the data mean.
    target = jnp.mean(batch, axis=0)
    return jnp.sum(jnp.square(params - target))


@pytest.fixture
def batches():
    key = jax.random.key(0)
    return jax.random.normal(key, (10, 4, 3)) + 2.0


def test_local_sgd_moves_toward_optimum(batches):
    p0 = jnp.zeros((3,))
    p1, loss = local_sgd(quad_loss, p0, batches, lr=0.05)
    assert float(quad_loss(p1, batches.reshape(-1, 3))) < float(
        quad_loss(p0, batches.reshape(-1, 3))
    )


def test_proximal_term_shrinks_update(batches):
    """FedProx with large mu stays closer to the anchor (Eq. in Sec. V-A)."""
    p0 = jnp.zeros((3,))
    p_plain, _ = local_sgd(quad_loss, p0, batches, lr=0.05)
    p_prox, _ = proximal_local_sgd(quad_loss, p0, batches, lr=0.05, mu=10.0)
    assert float(jnp.linalg.norm(p_prox - p0)) < float(
        jnp.linalg.norm(p_plain - p0)
    )


def test_proximal_zero_mu_equals_sgd(batches):
    p0 = jnp.ones((3,))
    p_a, _ = local_sgd(quad_loss, p0, batches, lr=0.03)
    p_b, _ = proximal_local_sgd(quad_loss, p0, batches, lr=0.03, mu=0.0)
    np.testing.assert_allclose(np.asarray(p_a), np.asarray(p_b), atol=1e-6)


def test_scaffold_state_init():
    params = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    st = scf.init_state(params, n_clients=5)
    assert jax.tree_util.tree_structure(st.c_global) == jax.tree_util.tree_structure(params)
    for leaf in jax.tree_util.tree_leaves(st.c_local):
        assert leaf.shape[0] == 5


def test_scaffold_local_runs(batches):
    p0 = jnp.zeros((3,))
    c_g = jnp.zeros((3,))
    c_i = jnp.zeros((3,))
    p1, new_ci, loss = scf.scaffold_local(
        quad_loss, p0, batches, 0.05, c_g, c_i
    )
    assert p1.shape == (3,)
    assert bool(jnp.all(jnp.isfinite(p1)))
    assert bool(jnp.all(jnp.isfinite(new_ci)))


def test_server_adam_moves_toward_pseudo_gradient():
    from repro.optim import server as srv

    st = srv.init_state(4)
    g = jnp.array([1.0, -1.0, 0.5, 0.0])
    incr, st = srv.adam_update(g, st, lr=0.1)
    # first step: mhat = g, vhat = g^2 -> incr ~ lr * sign(g)
    np.testing.assert_allclose(
        np.asarray(jnp.sign(incr)), np.asarray(jnp.sign(g)), atol=0
    )
    assert int(st.step) == 1
    incr2, st = srv.adam_update(g, st, lr=0.1)
    assert int(st.step) == 2
    assert bool(jnp.all(jnp.isfinite(incr2)))


def test_fedadam_method_runs_and_learns():
    import jax as _jax
    from repro.data.synthetic import SyntheticConfig, generate, normalize
    from repro.launch import experiment as exp

    ds = normalize(generate(_jax.random.key(5), SyntheticConfig(
        n_sensors=12, train_len=48, val_len=16, test_len=48)))
    cfg = exp.make_config(n_sensors=12, n_fog=3, rounds=3, local_epochs=1,
                          batch_size=16)
    for method in ("fedadam", "hfl-adam"):
        r = exp.run_method(method, ds, cfg, seed=0)
        assert r.losses[-1] < r.losses[0], (method, r.losses)
        assert 0.0 <= r.f1 <= 1.0
