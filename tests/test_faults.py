"""Tests for the fault-injection layer + Byzantine-robust aggregation.

Covers the ISSUE-7 acceptance pins: the ``FaultConfig`` pytree contract
(swept-leaf probabilities, static byz_mode, ``active`` predicate pinning),
fault semantics (crash vs erasure vs Byzantine corruption), the robust
aggregation operators (trimmed mean / median oracle properties +
Pallas-interpret parity), the trim-0 + no-faults == weighted-mean
equivalence in all four Engine families, graceful degradation (non-finite
deltas can never NaN the global model), and the one-compiled-program
robustness grid under ``Engine.sweep``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as eng_mod
from repro.core import async_fl, faults as flt, hfl
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.kernels import ops as kops
from repro.launch import experiment as exp
from repro.models import autoencoder as ae

N_SENSORS = 12
N_FOG = 3


def _make_ds(seed: int = 0):
    cfg = SyntheticConfig(
        n_sensors=N_SENSORS, train_len=48, val_len=24, test_len=48
    )
    return normalize(generate(jax.random.key(seed), cfg))


def _small_cfg(**kw):
    kw.setdefault("rounds", 2)
    kw.setdefault("local_epochs", 1)
    return exp.make_config(n_sensors=N_SENSORS, n_fog=N_FOG, **kw)


@pytest.fixture(scope="module")
def ds():
    return _make_ds(0)


@pytest.fixture(scope="module")
def params0(ds):
    return ae.init(jax.random.key(1), ds.train.shape[-1], (16, 8, 16))


# ---------------------------------------------------------------------------
# FaultConfig pytree contract.
# ---------------------------------------------------------------------------

def test_fault_config_activity_predicate_and_pinning():
    off = flt.FaultConfig()
    assert not off.is_active
    on = flt.FaultConfig(erasure_prob=0.2)
    assert on.is_active
    # byz_mode alone activates the layer (byz_frac may be a tracer).
    assert flt.FaultConfig(byz_mode="sign_flip").is_active
    # Pinning lets a zero-fault cell share the active shape-class.
    pinned = flt.FaultConfig(active=True)
    assert pinned.is_active
    assert jax.tree_util.tree_structure(pinned) == (
        jax.tree_util.tree_structure(on)
    )
    # ...and active vs inactive are DIFFERENT shape-classes.
    assert jax.tree_util.tree_structure(off) != (
        jax.tree_util.tree_structure(on)
    )


def test_fault_config_roundtrip_and_replace_rederivation():
    on = flt.FaultConfig(erasure_prob=0.3, byz_frac=0.2, byz_mode="gauss")
    leaves, treedef = jax.tree_util.tree_flatten(on)
    assert all(isinstance(x, (int, float)) for x in leaves)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.byz_mode == "gauss" and back.is_active
    # replace() re-derives the predicate from the new values...
    assert not on.replace(
        erasure_prob=0.0, byz_frac=0.0, byz_mode="none"
    ).is_active
    # ...unless the caller re-pins it in the same call.
    assert flt.FaultConfig(active=True).replace(
        erasure_prob=0.0, active=True
    ).is_active
    # A pytree round-trip pins the derived value concrete.
    rt = jax.tree_util.tree_unflatten(
        *reversed(jax.tree_util.tree_flatten(flt.FaultConfig(active=True)))
    )
    assert rt.active is True
    with pytest.raises(ValueError, match="byz_mode"):
        flt.FaultConfig(byz_mode="teleport")


def test_hfl_config_carries_faults_as_swept_leaves():
    base = _small_cfg()
    a = base.replace(faults=flt.FaultConfig(erasure_prob=0.1, active=True))
    b = base.replace(faults=flt.FaultConfig(erasure_prob=0.4, active=True))
    _, ta = jax.tree_util.tree_flatten(a)
    _, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    stacked = eng_mod.Engine.stack_configs([a, b])
    assert np.asarray(stacked.faults.erasure_prob).shape == (2,)
    assert stacked.faults.is_active
    # robust mode is STATIC: it changes the treedef.
    _, tr = jax.tree_util.tree_flatten(a.replace(robust="trimmed"))
    assert tr != ta


# ---------------------------------------------------------------------------
# Fault primitives.
# ---------------------------------------------------------------------------

def test_byzantine_mask_is_deterministic_prefix():
    m = np.asarray(flt.byzantine_mask(10, 0.3))
    np.testing.assert_array_equal(m[:3], True)
    np.testing.assert_array_equal(m[3:], False)
    assert not np.any(np.asarray(flt.byzantine_mask(10, 0.0)))
    assert np.all(np.asarray(flt.byzantine_mask(10, 1.0)))
    # Traceable fraction (swept leaf) under jit.
    mt = jax.jit(lambda f: flt.byzantine_mask(10, f))(jnp.float32(0.3))
    np.testing.assert_array_equal(np.asarray(mt), m)


def test_corrupt_deltas_modes():
    key = jax.random.key(0)
    deltas = jnp.ones((6, 4))
    cfg = flt.FaultConfig(byz_frac=0.5, byz_scale=3.0, byz_mode="sign_flip")
    out = np.asarray(flt.corrupt_deltas(key, deltas, cfg))
    np.testing.assert_allclose(out[:3], -3.0)        # attacked prefix
    np.testing.assert_allclose(out[3:], 1.0)         # honest rows untouched
    infl = np.asarray(flt.corrupt_deltas(
        key, deltas, cfg.replace(byz_mode="inflate")
    ))
    np.testing.assert_allclose(infl[:3], 3.0)
    g = np.asarray(flt.corrupt_deltas(
        key, deltas, cfg.replace(byz_mode="gauss")
    ))
    np.testing.assert_allclose(g[3:], 1.0)
    assert not np.allclose(g[:3], 1.0)
    # mode "none" is the identity.
    none = flt.corrupt_deltas(key, deltas, flt.FaultConfig(byz_frac=0.5))
    np.testing.assert_array_equal(np.asarray(none), np.asarray(deltas))


# ---------------------------------------------------------------------------
# Robust aggregation operators: oracle properties + kernel parity.
# ---------------------------------------------------------------------------

def _cluster(seed=0, n=12, d=40, n_fog=3):
    k1, k2 = jax.random.split(jax.random.key(seed))
    v = jax.random.normal(k1, (n, d))
    fog_id = jnp.arange(n, dtype=jnp.int32) % n_fog
    w = jax.random.uniform(k2, (n,)) + 0.5
    return v, fog_id, w


def test_robust_trim0_equals_weighted_mean():
    v, fog_id, w = _cluster()
    out, fw = kops.robust_aggregate(v, fog_id, w, N_FOG, 0.0, "trimmed")
    w_fog = jnp.where(
        fog_id[None, :] == jnp.arange(N_FOG)[:, None], w[None, :], 0.0
    )
    ref = (w_fog @ v) / jnp.maximum(w_fog.sum(-1), 1e-12)[:, None]
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(fw), np.asarray(w_fog.sum(-1)), rtol=1e-6
    )


def test_robust_rejects_outliers_mean_does_not():
    # Unit weights: one outlier in a 4-member fog holds 25% of the mass,
    # so beta=0.3 trims it entirely (the trim bound is by WEIGHT — a
    # heavier adversary needs a wider trim).
    v, fog_id, _ = _cluster(seed=3)
    w = jnp.ones((v.shape[0],))
    poisoned = v.at[0].set(1e4).at[1].set(-1e4)
    mean_out, _ = kops.robust_aggregate(
        poisoned, fog_id, w, N_FOG, 0.0, "trimmed"
    )
    trim_out, _ = kops.robust_aggregate(
        poisoned, fog_id, w, N_FOG, 0.3, "trimmed"
    )
    med_out, _ = kops.robust_aggregate(
        poisoned, fog_id, w, N_FOG, 0.0, "median"
    )
    assert float(jnp.max(jnp.abs(mean_out))) > 100.0
    assert float(jnp.max(jnp.abs(trim_out))) < 10.0
    assert float(jnp.max(jnp.abs(med_out))) < 10.0


def test_weighted_median_small_case():
    # One fog, three clients: weighted lower median sits on the middle
    # value once its cumulative weight crosses W/2.
    v = jnp.asarray([[1.0], [5.0], [9.0]])
    fid = jnp.zeros((3,), jnp.int32)
    out, _ = kops.robust_aggregate(
        v, fid, jnp.asarray([1.0, 1.0, 1.0]), 1, 0.0, "median"
    )
    np.testing.assert_allclose(float(out[0, 0]), 5.0)
    # A dominant weight drags the median onto its value.
    out2, _ = kops.robust_aggregate(
        v, fid, jnp.asarray([10.0, 1.0, 1.0]), 1, 0.0, "median"
    )
    np.testing.assert_allclose(float(out2[0, 0]), 1.0)


def test_robust_empty_fog_and_bad_mode():
    v, _, w = _cluster()
    fog_id = jnp.zeros((v.shape[0],), jnp.int32)     # fog 1, 2 empty
    out, fw = kops.robust_aggregate(v, fog_id, w, N_FOG, 0.2, "trimmed")
    np.testing.assert_allclose(np.asarray(out[1:]), 0.0)
    np.testing.assert_allclose(np.asarray(fw[1:]), 0.0)
    with pytest.raises(ValueError, match="mode"):
        kops.robust_aggregate(v, fog_id, w, N_FOG, 0.2, "krum")


@pytest.mark.parametrize("mode", ["trimmed", "median"])
@pytest.mark.parametrize("beta", [0.0, 0.2])
def test_robust_pallas_interpret_matches_ref(mode, beta):
    v, fog_id, w = _cluster(seed=7, n=14, d=300)     # multi-block padding
    ref_out, ref_w = kops.robust_aggregate(
        v, fog_id, w, N_FOG, beta, mode, use_pallas=False
    )
    pal_out, pal_w = kops.robust_aggregate(
        v, fog_id, w, N_FOG, beta, mode, use_pallas=True, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(pal_out), np.asarray(ref_out), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(pal_w), np.asarray(ref_w), rtol=1e-6
    )


# ---------------------------------------------------------------------------
# The equivalence pin: trim 0 + zero faults == weighted mean, per family.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["hfl-selective", "fedavg", "scaffold"])
def test_trim0_no_faults_matches_mean_sync_families(method, ds):
    key = jax.random.key(11)
    cfg = _small_cfg(rounds=3)
    m_mean = exp.trial_metrics(method, key, ds, cfg)
    m_trim = exp.trial_metrics(
        method, key, ds, cfg.replace(robust="trimmed", trim_frac=0.0)
    )
    for k in m_mean:
        np.testing.assert_allclose(
            np.asarray(m_trim[k]), np.asarray(m_mean[k]),
            rtol=1e-4, atol=1e-6, err_msg=k,
        )
    assert float(jnp.sum(m_mean["nonfinite_rounds"])) == 0.0
    assert float(jnp.sum(m_mean["erased_total"])) == 0.0


def test_trim0_no_faults_matches_mean_async(ds):
    key = jax.random.key(12)
    base = _small_cfg(rounds=2)
    acfg = async_fl.AsyncFLConfig(
        base=base, n_events=8, buffer_k=4.0, fog_k=1.0, alpha=0.5
    )
    m_mean = exp.trial_metrics("hfl-async", key, ds, acfg)
    m_trim = exp.trial_metrics(
        "hfl-async", key, ds,
        acfg.replace(base=base.replace(robust="trimmed", trim_frac=0.0)),
    )
    for k in m_mean:
        np.testing.assert_allclose(
            np.asarray(m_trim[k]), np.asarray(m_mean[k]),
            rtol=1e-4, atol=1e-6, err_msg=k,
        )


# ---------------------------------------------------------------------------
# Fault semantics through the round loops.
# ---------------------------------------------------------------------------

def test_erasure_charges_energy_but_drops_weight(ds, params0):
    """A packet lost AFTER the SNR feasibility check still cost its uplink
    energy and still counts as a participant — only its aggregation weight
    (and hence the model update) vanishes."""
    key = jax.random.key(21)
    cfg = _small_cfg(rounds=3)
    clean = cfg.replace(faults=flt.FaultConfig(active=True))
    lossy = cfg.replace(faults=flt.FaultConfig(erasure_prob=0.7))
    _, m0 = hfl.train(key, params0, ae.loss, ds, clean)
    _, m1 = hfl.train(key, params0, ae.loss, ds, lossy)
    # Same active set (same key split): identical sensor-uplink energy —
    # the lost packets were transmitted — and identical participation.
    # Fog-tier energy may only DROP (a fully-erased cluster holds no
    # aggregate to forward).
    np.testing.assert_allclose(
        np.asarray(m1.e_s2f), np.asarray(m0.e_s2f), rtol=1e-6
    )
    assert np.all(
        np.asarray(m1.e_total) <= np.asarray(m0.e_total) * (1 + 1e-6)
    )
    np.testing.assert_allclose(
        np.asarray(m1.participation), np.asarray(m0.participation)
    )
    assert int(jnp.sum(m1.n_erased)) > 0
    assert int(jnp.sum(m0.n_erased)) == 0
    assert bool(jnp.all(m1.global_finite))


def test_full_crash_holds_model(ds, params0):
    """crash_prob=1 is a dead network: no energy spent, no model movement —
    the zero-weight round handling from PR 5 must absorb it."""
    cfg = _small_cfg(rounds=2).replace(
        faults=flt.FaultConfig(crash_prob=1.0)
    )
    params, m = hfl.train(jax.random.key(3), params0, ae.loss, ds, cfg)
    assert float(jnp.max(m.participation)) == 0.0
    assert float(jnp.max(m.e_total)) == 0.0
    for p, p0 in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(params0)
    ):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(p0))
    assert bool(jnp.all(m.global_finite))


def test_nonfinite_deltas_counted_and_zeroed(ds, params0):
    """byz_scale=inf inflation turns attacked deltas non-finite: the guard
    must count AND zero them, keeping the global model finite while honest
    clients keep training."""
    cfg = _small_cfg(rounds=3).replace(
        faults=flt.FaultConfig(
            byz_frac=0.3, byz_scale=float("inf"), byz_mode="inflate"
        )
    )
    params, m = hfl.train(jax.random.key(4), params0, ae.loss, ds, cfg)
    assert int(jnp.sum(m.n_nonfinite)) > 0
    assert bool(jnp.all(m.global_finite))
    for p in jax.tree_util.tree_leaves(params):
        assert bool(jnp.all(jnp.isfinite(p)))


def test_fault_inactive_is_bit_identical_to_legacy(ds, params0):
    """The fault layer off (default) must not perturb the PRNG stream:
    committed baselines stay bit-identical."""
    key = jax.random.key(6)
    cfg = _small_cfg(rounds=2)
    p1, m1 = hfl.train(key, params0, ae.loss, ds, cfg)
    p2, m2 = hfl.train(key, params0, ae.loss, ds, cfg)
    for a, b in zip(
        jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(m1.loss), np.asarray(m2.loss))


# ---------------------------------------------------------------------------
# Engine integration: the robustness grid is ONE compiled program.
# ---------------------------------------------------------------------------

def test_robustness_grid_compiles_one_program():
    """attack-fraction x trim x erasure cells share byz_mode and robust
    statics, so the whole grid (clean corner included, via the always-on
    byz_mode="sign_flip" activity pin) runs as ONE compiled program, each
    cell matching its own Engine.run."""
    eng = eng_mod.Engine()
    base = _small_cfg().replace(robust="trimmed")
    cfgs = [
        base.replace(
            trim_frac=t,
            faults=flt.FaultConfig(
                erasure_prob=e, byz_frac=b, byz_scale=5.0,
                byz_mode="sign_flip",
            ),
        )
        for b in (0.0, 0.25)
        for t in (0.0, 0.25)
        for e in (0.0, 0.3)
    ]
    assert len(cfgs) == 8
    sw = eng.sweep("hfl-selective", cfgs, (0,), _make_ds)
    assert sw.n_classes == 1
    assert sw.compiled_programs == 1
    assert not np.any(np.asarray(sw["nonfinite_rounds"]))
    for i in (0, 7):
        r = eng.run("hfl-selective", cfgs[i], (0,), _make_ds)
        np.testing.assert_allclose(
            np.asarray(sw["losses"][i]), np.asarray(r.losses),
            rtol=1e-4, atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(sw["f1"][i]), np.asarray(r.f1), atol=1e-3
        )
        np.testing.assert_allclose(
            np.asarray(sw["erased_total"][i]), np.asarray(r["erased_total"])
        )


def test_robust_static_splits_shape_class():
    """mean vs trimmed vs median are different programs — robust mode is a
    static branch, not a swept knob."""
    eng = eng_mod.Engine()
    base = _small_cfg()
    cfgs = [
        base,
        base.replace(robust="trimmed", trim_frac=0.2),
        base.replace(robust="median"),
    ]
    sw = eng.sweep("hfl-nocoop", cfgs, (0,), _make_ds)
    assert sw.n_classes == 3


# ---------------------------------------------------------------------------
# Validation (ISSUE 9 satellite): out-of-range knobs fail loudly.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("field", ["erasure_prob", "crash_prob", "byz_frac"])
@pytest.mark.parametrize("bad", [-0.1, 1.5])
def test_fault_config_rejects_out_of_range_probs(field, bad):
    with pytest.raises(ValueError, match=field):
        flt.FaultConfig(**{field: bad})
    # ...including via replace() on a valid config.
    with pytest.raises(ValueError, match=field):
        flt.FaultConfig().replace(**{field: bad})


def test_fault_config_accepts_boundaries_and_tracers():
    flt.FaultConfig(erasure_prob=0.0, crash_prob=1.0, byz_frac=1.0)
    # Traced/stacked leaves must pass the concrete-only check (unflatten
    # runs __post_init__ inside jit and under Engine.stack_configs).
    jax.jit(lambda c: c.erasure_prob)(flt.FaultConfig(erasure_prob=0.5))


@pytest.mark.parametrize("bad", [-0.1, 0.5, 0.7])
def test_hfl_config_rejects_bad_trim_frac(bad):
    with pytest.raises(ValueError, match="trim_frac"):
        _small_cfg(robust="trimmed", trim_frac=bad)


def test_hfl_config_rejects_unknown_robust():
    with pytest.raises(ValueError, match="robust"):
        _small_cfg(robust="krum")


# ---------------------------------------------------------------------------
# Adaptive (colluding) Byzantine mode — ISSUE 9 tentpole part 3.
# ---------------------------------------------------------------------------

def test_adaptive_mode_is_valid_and_activates():
    cfg = flt.FaultConfig(byz_mode="adaptive")
    assert cfg.is_active
    rt = jax.tree_util.tree_unflatten(
        *reversed(jax.tree_util.tree_flatten(cfg))
    )
    assert rt.byz_mode == "adaptive"


def test_adaptive_colluders_submit_identical_crafted_update():
    key = jax.random.key(0)
    deltas = jax.random.normal(jax.random.key(1), (8, 6))
    cfg = flt.FaultConfig(byz_frac=0.25, byz_scale=3.0, byz_mode="adaptive")
    out = flt.corrupt_deltas(key, deltas, cfg, prev_delta=jnp.ones(6))
    mask = np.asarray(flt.byzantine_mask(8, 0.25))
    assert mask.sum() == 2
    atk = np.asarray(out)[mask]
    # Collusion: every Byzantine row is the SAME crafted vector...
    np.testing.assert_array_equal(atk[0], atk[1])
    # ...and honest rows pass through untouched.
    np.testing.assert_array_equal(np.asarray(out)[~mask],
                                  np.asarray(deltas)[~mask])
    # The craft: mu - scale * sigma * sign(prev_delta).
    mu = np.asarray(jnp.mean(deltas, 0))
    sd = np.asarray(jnp.std(deltas, 0))
    np.testing.assert_allclose(atk[0], mu - 3.0 * sd, rtol=1e-5)


def test_adaptive_direction_follows_prev_delta_sign():
    deltas = jnp.ones((4, 3))
    cfg = flt.FaultConfig(byz_frac=0.5, byz_scale=2.0, byz_mode="adaptive")
    # sigma = 0 here, so the attack reduces to mu regardless of direction;
    # use heterogeneous deltas instead.
    deltas = deltas.at[0].set(3.0)
    prev = jnp.array([1.0, -1.0, 0.0])
    out = np.asarray(
        flt.corrupt_deltas(jax.random.key(0), deltas, cfg, prev_delta=prev)
    )
    mu = np.asarray(jnp.mean(deltas, 0))
    sd = np.asarray(jnp.std(deltas, 0))
    # dirn: sign(prev) where prev != 0, else sign(mu) (mu > 0 here).
    expect = mu - 2.0 * sd * np.array([1.0, -1.0, 1.0])
    np.testing.assert_allclose(out[0], expect, rtol=1e-5)


def test_adaptive_without_prev_delta_falls_back_to_mean_sign():
    deltas = jax.random.normal(jax.random.key(2), (6, 4))
    cfg = flt.FaultConfig(byz_frac=0.5, byz_scale=1.0, byz_mode="adaptive")
    out = np.asarray(flt.corrupt_deltas(jax.random.key(0), deltas, cfg))
    mu = np.asarray(jnp.mean(deltas, 0))
    sd = np.asarray(jnp.std(deltas, 0))
    np.testing.assert_allclose(out[0], mu - sd * np.sign(mu), rtol=1e-5)


def test_adaptive_hugs_trimmed_band_at_small_scale():
    """The z=3 craft sits inside the honest spread: with trim_frac above
    the Byzantine weight share the trimmed mean stays within the honest
    min/max envelope per coordinate."""
    deltas = jax.random.normal(jax.random.key(3), (12, 5))
    cfg = flt.FaultConfig(byz_frac=0.25, byz_scale=3.0, byz_mode="adaptive")
    out = flt.corrupt_deltas(jax.random.key(0), deltas, cfg,
                             prev_delta=jnp.ones(5))
    fog_id = jnp.zeros(12, jnp.int32)
    tm, _ = kops.robust_aggregate(
        out, fog_id, jnp.ones(12), n_fog=1, trim_frac=0.3, mode="trimmed"
    )
    tm = np.asarray(tm)[0]
    honest = np.asarray(deltas)[3:]
    assert (tm >= honest.min(0) - 1e-5).all()
    assert (tm <= honest.max(0) + 1e-5).all()
