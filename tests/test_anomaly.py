"""Tests for anomaly scoring, calibration (Eq. 32), F1 and PA-F1."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import anomaly


def test_reconstruction_error_is_squared_l2():
    x = jnp.array([[1.0, 2.0], [0.0, 0.0]])
    err = anomaly.reconstruction_errors(lambda p, a: a * 0.0, None, x)
    np.testing.assert_allclose(np.asarray(err), [5.0, 0.0])


def test_threshold_is_percentile():
    errors = jnp.arange(100.0)
    tau = anomaly.calibrate_threshold(errors, 99.0)
    assert float(tau) == pytest.approx(98.01, abs=0.1)


def test_flagging():
    pred = anomaly.flag_anomalies(jnp.array([0.5, 2.0]), jnp.float32(1.0))
    np.testing.assert_array_equal(np.asarray(pred), [False, True])


def test_pointwise_f1_hand_case():
    pred = jnp.array([1, 0, 1, 1, 0], bool)
    label = jnp.array([1, 1, 0, 1, 0], bool)
    r = anomaly.pointwise_f1(pred, label)
    # tp=2 fp=1 fn=1 -> P=2/3 R=2/3 F1=2/3
    assert float(r.f1) == pytest.approx(2 / 3, abs=1e-6)


def test_point_adjust_credits_whole_segment():
    label = jnp.array([0, 1, 1, 1, 0, 1, 1, 0], bool)
    pred = jnp.array([0, 0, 1, 0, 0, 0, 0, 0], bool)
    adj = anomaly.point_adjust(pred, label)
    # first segment fully credited, second untouched, outside unchanged
    np.testing.assert_array_equal(
        np.asarray(adj), [0, 1, 1, 1, 0, 0, 0, 0]
    )


def test_point_adjust_keeps_false_positives():
    label = jnp.array([0, 0, 1, 1], bool)
    pred = jnp.array([1, 0, 0, 1], bool)
    adj = anomaly.point_adjust(pred, label)
    np.testing.assert_array_equal(np.asarray(adj), [1, 0, 1, 1])


def test_pa_f1_at_least_pointwise():
    """PA is strictly more generous than point-wise (paper Sec. VI-F)."""
    rng = np.random.default_rng(0)
    label = jnp.asarray(rng.random(200) < 0.2)
    pred = jnp.asarray(rng.random(200) < 0.3)
    pw = anomaly.pointwise_f1(pred, label)
    pa = anomaly.point_adjusted_f1(pred, label)
    assert float(pa.f1) >= float(pw.f1) - 1e-9


def test_evaluate_detector_perfect_separation():
    """An oracle reconstruction separates anomalies exactly -> F1 == 1."""
    val = jnp.zeros((64, 4))
    test = jnp.concatenate([jnp.zeros((32, 4)), jnp.ones((8, 4)) * 10], axis=0)
    label = jnp.concatenate([jnp.zeros((32,), bool), jnp.ones((8,), bool)])
    r = anomaly.evaluate_detector(
        lambda p, x: jnp.zeros_like(x), None, val, test, label
    )
    assert float(r.f1) == pytest.approx(1.0)
