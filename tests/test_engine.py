"""Tests for the batched multi-deployment engine (repro.engine)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as eng_mod
from repro.core import compression as comp
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.launch import experiment as exp


def _make_ds(seed: int):
    cfg = SyntheticConfig(n_sensors=12, train_len=48, val_len=24, test_len=48)
    return normalize(generate(jax.random.key(seed), cfg))


def _small_cfg(**kw):
    kw.setdefault("rounds", 3)
    kw.setdefault("local_epochs", 1)
    return exp.make_config(n_sensors=12, n_fog=3, **kw)


SEEDS = (0, 1, 2)


def test_batched_run_matches_sequential():
    """Engine.run over 3 seeds == three sequential hfl.train pipelines.

    Column 0 of the trial grid uses exactly ``jax.random.key(seed)``, so
    the batched program must reproduce ``experiment.run_method`` on the
    engine-resolved config to float tolerance (vmap only reassociates)."""
    eng = eng_mod.Engine()
    cfg = _small_cfg()
    run = eng.run("hfl-selective", cfg, SEEDS, _make_ds)
    assert np.asarray(run.f1).shape == (3, 1)

    rcfg = eng.resolve_config(cfg)
    for i, s in enumerate(SEEDS):
        ref = exp.run_method("hfl-selective", _make_ds(s), rcfg, seed=s)
        np.testing.assert_allclose(
            float(run["e_total"][i, 0]), ref.e_total, rtol=1e-5
        )
        np.testing.assert_allclose(float(run.f1[i, 0]), ref.f1, atol=1e-3)
        np.testing.assert_allclose(
            np.asarray(run.losses[i, 0]), np.asarray(ref.losses), rtol=1e-4
        )


def test_batched_run_flat_family_matches_sequential():
    eng = eng_mod.Engine()
    cfg = _small_cfg()
    run = eng.run("fedprox", cfg, SEEDS, _make_ds)
    rcfg = eng.resolve_config(cfg)
    for i, s in enumerate(SEEDS):
        ref = exp.run_method("fedprox", _make_ds(s), rcfg, seed=s)
        np.testing.assert_allclose(
            float(run["e_total"][i, 0]), ref.e_total, rtol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(run.losses[i, 0]), np.asarray(ref.losses), rtol=1e-4
        )


def test_batched_audit_matches_sequential():
    eng = eng_mod.Engine()
    cfg = _small_cfg(rounds=4)
    audit = eng.audit("hfl-nearest", cfg, SEEDS)
    rcfg = eng.resolve_config(cfg)
    for i, s in enumerate(SEEDS):
        ref = exp.audit_method("hfl-nearest", rcfg, seed=s)
        for k in ("e_s2f", "e_f2f", "e_f2g", "e_total", "participation"):
            np.testing.assert_allclose(
                float(audit[k][i, 0]), ref[k], rtol=1e-5, atol=1e-7
            )


def test_program_cache_reuses_compilations():
    eng = eng_mod.Engine()
    cfg = _small_cfg()
    r1 = eng.run("hfl-nocoop", cfg, (0, 1), _make_ds)
    r2 = eng.run("hfl-nocoop", cfg, (0, 1), _make_ds)
    assert r1.fresh_compile and not r2.fresh_compile
    assert eng.compile_count == 1
    np.testing.assert_array_equal(np.asarray(r1.f1), np.asarray(r2.f1))
    log = eng.take_log()
    assert [e["fresh_compile"] for e in log] == [True, False]
    assert eng.take_log() == []


def test_deployment_axis_varies_topology():
    """n_deployments adds an independent-deployment column per seed."""
    eng = eng_mod.Engine()
    cfg = _small_cfg(rounds=2)
    audit = eng.audit("hfl-selective", cfg, (0,), n_deployments=3)
    e = np.ravel(np.asarray(audit["e_total"]))
    assert e.shape == (3,)
    assert len(np.unique(e)) == 3  # distinct deployment realisations


def test_engine_resolves_global_compressor_to_blockwise_kernels():
    eng = eng_mod.Engine()
    cc = eng.resolve_compressor(comp.CompressorConfig(rho_s=0.05, quant_bits=8))
    assert cc.mode == "blockwise"
    assert cc.use_pallas == eng_mod.default_use_pallas()
    # Dense / disabled configs are left alone.
    dense = comp.CompressorConfig(rho_s=1.0, quant_bits=32)
    assert eng.resolve_compressor(dense) == dense
    keep = eng_mod.Engine(compressor="keep")
    g = comp.CompressorConfig(rho_s=0.05, quant_bits=8)
    assert keep.resolve_compressor(g) == g


def test_pallas_vs_ref_parity_inside_batched_round():
    """A batched round with the Pallas (interpret) compressor must match
    the kernels/ref.py oracle path — threshold bisection and int8 rules
    are specified to agree exactly."""
    eng = eng_mod.Engine(compressor="keep")
    base = _small_cfg(rounds=2)
    cc_pallas = comp.CompressorConfig(
        rho_s=0.05, quant_bits=8, mode="blockwise",
        use_pallas=True, interpret=True,
    )
    cc_ref = cc_pallas.replace(use_pallas=False)
    rp = eng.run("hfl-selective", base.replace(compressor=cc_pallas),
                 (0, 1), _make_ds)
    rr = eng.run("hfl-selective", base.replace(compressor=cc_ref),
                 (0, 1), _make_ds)
    np.testing.assert_allclose(
        np.asarray(rp.losses), np.asarray(rr.losses), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(rp["e_total"]), np.asarray(rr["e_total"]), rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(rp.f1), np.asarray(rr.f1), atol=1e-6
    )


@pytest.mark.parametrize(
    "d,rho",
    [
        (1352, 0.05),    # single padded tile (the paper's autoencoder)
        (9000, 0.9),     # two tiles, short tail, high rho: the uniform
                         # per-tile k would exceed the tail's real coords
        (20000, 0.2),    # three tiles, moderate rho
    ],
)
def test_blockwise_rho_matches_global_keep_count(d, rho):
    """The engine's blockwise default keeps ~rho_s * d coordinates of the
    real (unpadded) update — same K as the paper's global semantics, even
    when the flat vector spans multiple kernel tiles with a partial tail."""
    delta = jax.random.normal(jax.random.key(0), (d,))
    err = jnp.zeros((d,))
    cc = comp.CompressorConfig(rho_s=rho, quant_bits=32, mode="blockwise")
    recon, _ = comp.compress_update(delta, err, cc)
    kept = int(jnp.sum(recon != 0))
    target = round(rho * d)
    # Uniform per-tile k cannot hit the target exactly when it doesn't
    # divide evenly across tiles; a couple coords per tile of slack.
    assert abs(kept - target) <= 2 * (-(-d // 8192)), (kept, target)


@pytest.mark.tpu
def test_compiled_pallas_compressor_on_tpu():
    """Compiled (non-interpret) Pallas path — only meaningful on TPU."""
    eng = eng_mod.Engine()
    assert eng_mod.default_use_pallas()
    cfg = _small_cfg(rounds=2)
    run = eng.run("hfl-selective", cfg, (0,), _make_ds)
    assert bool(jnp.all(jnp.isfinite(run.losses)))
