"""Unit + property tests for the SNR-driven energy model (Sec. III-D)."""
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401
from _hypothesis_compat import given, settings, st  # noqa: F401

from repro.core import channel as ch
from repro.core import energy as en


def test_acoustic_power_formula():
    # Eq. 7 at SL = 140 dB.
    sl = 140.0
    coef = 4 * np.pi * 1e-12 / (1025.0 * 1500.0)
    np.testing.assert_allclose(
        float(en.acoustic_power_w(jnp.float32(sl))),
        coef * 10 ** (sl / 10),
        rtol=1e-5,
    )


def test_electrical_power_scales_with_efficiency(eparams):
    p1 = float(en.electrical_tx_power_w(jnp.float32(120.0), eparams))
    p2 = float(
        en.electrical_tx_power_w(
            jnp.float32(120.0), eparams.replace(eta_ea=0.5)
        )
    )
    np.testing.assert_allclose(p1, 2.0 * p2, rtol=1e-6)


def test_tx_energy_monotone_in_distance(cparams, eparams):
    d = jnp.array([10.0, 100.0, 500.0, 1000.0, 2000.0])
    e = en.tx_energy_j(1000.0, d, cparams, eparams)
    assert bool(jnp.all(jnp.diff(e) > 0))


def test_tx_energy_linear_in_bits(cparams, eparams):
    e1 = float(en.tx_energy_j(1000.0, 500.0, cparams, eparams))
    e2 = float(en.tx_energy_j(2000.0, 500.0, cparams, eparams))
    np.testing.assert_allclose(e2, 2.0 * e1, rtol=1e-6)


def test_infeasible_link_energy_is_inf(cparams, eparams):
    rmax = float(ch.max_feasible_range_m(cparams))
    assert np.isinf(
        float(en.tx_energy_j(1000.0, rmax * 1.01, cparams, eparams))
    )
    assert np.isfinite(
        float(en.tx_energy_j(1000.0, rmax * 0.99, cparams, eparams))
    )


def test_rx_energy(cparams, eparams):
    rate = float(ch.shannon_rate_bps(cparams))
    np.testing.assert_allclose(
        float(en.rx_energy_j(1000.0, cparams, eparams)),
        0.03 * 1000.0 / rate,
        rtol=1e-6,
    )


def test_compute_energy(eparams):
    np.testing.assert_allclose(
        float(en.compute_energy_j(jnp.float32(1e9), eparams)), 1.0, rtol=1e-6
    )


def test_battery_floors_at_reserve(eparams):
    res = jnp.array([10.0, 0.5])
    new, alive = en.battery_step(res, jnp.array([1.0, 1.0]), eparams)
    np.testing.assert_allclose(np.asarray(new), [9.0, 0.0])
    assert bool(alive[0]) and not bool(alive[1])


def test_link_latency_decomposition(cparams):
    rate = float(ch.shannon_rate_bps(cparams))
    got = float(en.link_latency_s(1000.0, 1500.0, cparams))
    np.testing.assert_allclose(got, 1.0 + 1000.0 / rate, rtol=1e-6)


def test_autoencoder_flops_counts_matmuls():
    # 32->16->8->16->32, 1 sample, 1 epoch: 3x forward matmul cost.
    mm = 2 * (32 * 16 + 16 * 8 + 8 * 16 + 16 * 32)
    assert en.autoencoder_flops(32, (16, 8, 16), 1, 1) == 3 * mm


@settings(max_examples=25, deadline=None)
@given(
    bits=st.floats(min_value=1.0, max_value=1e7),
    d=st.floats(min_value=1.0, max_value=3000.0),
)
def test_property_energy_positive_and_finite_in_range(bits, d, cparams, eparams):
    e = float(en.tx_energy_j(bits, d, cparams, eparams))
    assert e > 0
    if bool(ch.feasible(jnp.float32(d), cparams)):
        assert np.isfinite(e)
