"""Tests for the load-generation subsystem (repro.loadgen, ISSUE 8).

Trace generators: determinism (same seed/args -> identical arrays),
sortedness, horizon clipping, fog routing (``sensor % n_fog``), realised
rates near the configured ones, MMPP silences and diurnal modulation
actually present.  Harness: virtual-clock semantics, open-loop replay
completing every event with true e2e latency recorded, and the
structural point of the whole subsystem — deadline batching beating
fixed batching at the tail on a bursty trace.
"""
import numpy as np
import pytest

from repro.loadgen import (
    VirtualClock,
    diurnal_trace,
    gaussian_windows,
    mmpp_trace,
    poisson_trace,
    replay,
)


def _poisson(seed=0, **kw):
    args = dict(rate_hz=200.0, duration_s=2.0, fleet=16, n_fog=4, rows=8)
    args.update(kw)
    return poisson_trace(seed, **args)


def _mmpp(seed=1, **kw):
    args = dict(rate_on_hz=1500.0, mean_on_s=0.2, mean_off_s=0.6,
                duration_s=3.0, fleet=16, n_fog=4, rows=8)
    args.update(kw)
    return mmpp_trace(seed, **args)


# ---------------------------------------------------------------------------
# trace generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("maker", [_poisson, _mmpp])
def test_traces_are_deterministic_and_seed_sensitive(maker):
    a, b, c = maker(seed=3), maker(seed=3), maker(seed=4)
    np.testing.assert_array_equal(a.t, b.t)
    np.testing.assert_array_equal(a.sensor, b.sensor)
    np.testing.assert_array_equal(a.fog, b.fog)
    assert a.t.shape != c.t.shape or not np.array_equal(a.t, c.t)


@pytest.mark.parametrize("maker", [_poisson, _mmpp])
def test_trace_invariants(maker):
    tr = maker()
    assert np.all(np.diff(tr.t) >= 0), "arrivals must be time-sorted"
    assert tr.t[0] >= 0 and tr.t[-1] < tr.duration_s
    assert np.all((tr.sensor >= 0) & (tr.sensor < tr.meta["fleet"]))
    np.testing.assert_array_equal(tr.fog, tr.sensor % tr.meta["n_fog"])
    assert tr.total_rows == tr.n_events * tr.rows
    s = tr.summary()
    assert s["n_events"] == len(tr) and s["kind"] == tr.kind


def test_poisson_realised_rate_near_configured():
    tr = _poisson(rate_hz=500.0, duration_s=8.0)
    # Poisson count has sd sqrt(n) ~ 63 on n=4000: 10% is a loose 6-sigma.
    assert abs(tr.mean_rate_hz() - 500.0) / 500.0 < 0.10


def test_mmpp_has_real_silences():
    """rate_off=0 must produce inter-arrival gaps on the order of the off
    sojourn — the burstiness fixed-size batching chokes on."""
    tr = _mmpp()
    gaps = np.diff(tr.t)
    assert gaps.max() > 0.2, "no silence in an on/off trace"
    # And bursts are dense: median gap is the on-state spacing.
    assert np.median(gaps) < 0.005
    assert tr.meta["bursts"] >= 1


def test_diurnal_modulation_present():
    tr = diurnal_trace(
        5, base_rate_hz=50.0, peak_rate_hz=500.0, period_s=2.0,
        duration_s=2.0, fleet=8, n_fog=2,
    )
    # sin peaks in the first half-period, troughs in the second.
    first = int(np.sum(tr.t < 1.0))
    second = tr.n_events - first
    assert first > 2 * second


def test_trace_argument_validation():
    with pytest.raises(ValueError):
        _poisson(rate_hz=0.0)
    with pytest.raises(ValueError):
        _mmpp(mean_off_s=0.0)
    with pytest.raises(ValueError):
        diurnal_trace(0, base_rate_hz=10.0, peak_rate_hz=5.0, period_s=1.0,
                      duration_s=1.0, fleet=4, n_fog=2)


def test_gaussian_windows_deterministic_per_event():
    tr = _poisson()
    w = gaussian_windows(tr, d=12, seed=7)
    np.testing.assert_array_equal(w(3), w(3))
    assert w(3).shape == (tr.rows, 12) and w(3).dtype == np.float32
    assert not np.array_equal(w(3), w(4))


# ---------------------------------------------------------------------------
# virtual clock + replay harness
# ---------------------------------------------------------------------------

def test_virtual_clock_semantics():
    c = VirtualClock()
    assert c() == 0.0
    c.advance(0.5)
    c.advance_to(0.3)          # never rewinds
    assert c() == 0.5
    c.advance_to(1.0)
    assert c() == 1.0


def _service(store_dir, clock, **kw):
    import jax

    from repro.checkpoint import CheckpointStore
    from repro.models import autoencoder as ae
    from repro.serving import ScoringService

    params = ae.init(jax.random.key(0), 12, (8, 4, 8))
    store = CheckpointStore(str(store_dir))
    store.publish(1, params)
    return ScoringService(store, params, tau=1.0, clock=clock, **kw)


def test_replay_completes_every_event_with_e2e_latency(tmp_path):
    tr = _poisson(rate_hz=300.0, duration_s=1.0)
    clock = VirtualClock()
    svc = _service(tmp_path, clock, buckets=(64, 256), max_wait_s=0.05)
    rep = replay(svc, tr, clock, d=12)
    assert rep.completed == rep.n_events == tr.n_events
    assert rep.samples == tr.total_rows
    assert rep.e2e_latency_s.shape == (tr.n_events,)
    assert np.all(rep.e2e_latency_s >= 0)
    # Deadline policy: no completed request waited forever.
    assert rep.e2e_latency_s.max() < 1.0
    assert rep.virtual_s >= tr.t[-1]
    s = rep.summary()
    assert s["e2e_p99_ms"] >= s["e2e_p50_ms"] > 0
    assert set(s["compiles_by_bucket"]) <= {64, 256}


def test_replay_adaptive_beats_fixed_tail_on_bursty_trace(tmp_path):
    """The tentpole claim, in miniature: on an on/off trace, deadline
    flushing bounds the tail while fixed batching strands burst leftovers
    through every silence."""
    tr = _mmpp(duration_s=2.0)
    clock_f = VirtualClock()
    fixed = _service(tmp_path / "f", clock_f, batch_rows=256)
    rep_f = replay(fixed, tr, clock_f, d=12)
    clock_a = VirtualClock()
    adaptive = _service(
        tmp_path / "a", clock_a, buckets=(64, 256), max_wait_s=0.02
    )
    rep_a = replay(adaptive, tr, clock_a, d=12)
    assert rep_f.completed == rep_a.completed == tr.n_events
    p99_f = np.percentile(rep_f.e2e_latency_s, 99.0)
    p99_a = np.percentile(rep_a.e2e_latency_s, 99.0)
    assert p99_a < p99_f, (p99_a, p99_f)
    # And the adaptive config paid for it with partial flushes.
    assert rep_a.partial_flushes > 0


def test_replay_without_drain_leaves_leftovers_queued(tmp_path):
    tr = _poisson(rate_hz=100.0, duration_s=0.5)
    clock = VirtualClock()
    svc = _service(tmp_path, clock, batch_rows=1 << 14)  # never fills
    rep = replay(svc, tr, clock, d=12, drain=False)
    assert rep.completed == 0
    assert svc.pending_rows() == tr.total_rows
