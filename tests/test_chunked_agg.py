"""Chunk-equivalence contract of the client-chunked delta path (PR 10).

``HFLConfig.client_chunk`` bounds the client-phase memory high-water mark
by scanning the fleet axis in chunks.  The contract pinned here:

* ``chunk is None`` or ``chunk >= N`` is the one-shot path, BIT-identical
  by construction (the dispatch in ``aggregation.compress_and_accumulate``
  only engages for ``0 < chunk < N``);
* ``chunk < N`` re-associates the weighted fog accumulation, so the mean
  path matches within float-accumulation tolerance — including chunk
  sizes that do NOT divide N (the clamped last chunk re-reads rows of its
  predecessor with their weights masked to zero) and ``chunk=1``;
* ``client_compress`` (per-row reconstruction, no cross-row sums — the
  robust/trimmed and async launch paths) is BIT-identical at EVERY chunk;
* the equivalence holds end-to-end through all four round families
  (hfl, flat-FL, scaffold-free robust/trimmed, async), with faults and
  drift active — chunking happens inside the aggregation call, so the
  round loops' PRNG split discipline is untouched.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core import compression as comp
from repro.core import drift as drf
from repro.core import faults as flt
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.engine import Engine
from repro.launch import experiment as exp

CFG = comp.CompressorConfig(rho_s=0.25, quant_bits=8, mode="blockwise")


def _agg_inputs(n=23, d=40, n_fog=4, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    deltas = jax.random.normal(k1, (n, d))
    err = 0.1 * jax.random.normal(k2, (n, d))
    fog_id = jax.random.randint(k3, (n,), 0, n_fog)
    return deltas, err, fog_id, jnp.ones((n,)), n_fog


# ---------------------------------------------------------------------------
# Aggregation level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [None, 23, 64])
def test_chunk_ge_n_is_bitwise_passthrough(chunk):
    deltas, err, fog_id, w, n_fog = _agg_inputs()
    ref = agg.compress_and_accumulate(deltas, err, fog_id, w, n_fog, CFG)
    out = agg.compress_and_accumulate(
        deltas, err, fog_id, w, n_fog, CFG, chunk=chunk
    )
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("chunk", [1, 5, 7, 16])
def test_chunked_matches_dense_within_accumulation_tol(chunk):
    """Non-divisor chunks included: N=23 exercises the clamped last chunk
    (overlap rows recomputed, weights masked) for every size here."""
    deltas, err, fog_id, w, n_fog = _agg_inputs()
    ref = agg.compress_and_accumulate(deltas, err, fog_id, w, n_fog, CFG)
    out = agg.compress_and_accumulate(
        deltas, err, fog_id, w, n_fog, CFG, chunk=chunk
    )
    # fog sums: re-associated adds -> float tolerance; fog weights: exact
    # (masked integers); EF buffer: per-client but the chunked path runs
    # the wire kernel, whose FMA order differs from dense by ~1 ulp.
    np.testing.assert_allclose(
        np.asarray(out[0]), np.asarray(ref[0]), rtol=0, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(ref[1]))
    np.testing.assert_allclose(
        np.asarray(out[2]), np.asarray(ref[2]), rtol=0, atol=1e-6
    )


@pytest.mark.parametrize("chunk", [1, 5, 23, 64])
def test_client_compress_bitwise_at_every_chunk(chunk):
    deltas, err, *_ = _agg_inputs()
    ref = agg.client_compress(deltas, err, CFG)
    out = agg.client_compress(deltas, err, CFG, chunk=chunk)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_robust_trimmed_chunked_bitwise():
    deltas, err, fog_id, w, n_fog = _agg_inputs()
    ref = agg.robust_compress_and_aggregate(
        deltas, err, fog_id, w, n_fog, CFG, 0.2, "trimmed"
    )
    out = agg.robust_compress_and_aggregate(
        deltas, err, fog_id, w, n_fog, CFG, 0.2, "trimmed", chunk=5
    )
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nonfinite_guard_survives_chunking():
    deltas, err, fog_id, w, n_fog = _agg_inputs()
    poisoned = deltas.at[3, 1].set(jnp.inf).at[11, 0].set(jnp.nan)
    fog_sum, fog_w, new_err = agg.compress_and_accumulate(
        poisoned, err, fog_id, w, n_fog, CFG, chunk=5
    )
    assert bool(jnp.all(jnp.isfinite(fog_sum)))
    assert bool(jnp.all(jnp.isfinite(new_err)))
    assert float(fog_w.sum()) == deltas.shape[0] - 2


# ---------------------------------------------------------------------------
# Round families, end to end
# ---------------------------------------------------------------------------

_N = 12


def _ds():
    return normalize(generate(
        jax.random.key(0),
        SyntheticConfig(n_sensors=_N, train_len=32, val_len=16, test_len=32),
    ))


def _cfg(**kw):
    return exp.make_config(
        n_sensors=_N, n_fog=3, rounds=2, local_epochs=1, **kw
    )


def _trial(method, cfg):
    return exp.trial_metrics(method, jax.random.key(3), _ds(), cfg)


@pytest.mark.parametrize("method", ["hfl-selective", "fedprox", "hfl-async"])
def test_family_chunk_ge_n_bit_identical(method):
    ref = _trial(method, _cfg())
    out = _trial(method, _cfg().replace(client_chunk=_N))
    for k in ref:
        np.testing.assert_array_equal(
            np.asarray(ref[k]), np.asarray(out[k]), err_msg=k
        )


@pytest.mark.parametrize(
    "method,chunk", [("hfl-selective", 5), ("fedprox", 5), ("hfl-selective", 1)]
)
def test_family_small_chunk_tolerance(method, chunk):
    """chunk=5 does not divide N=12; chunk=1 is the degenerate extreme."""
    ref = _trial(method, _cfg())
    out = _trial(method, _cfg().replace(client_chunk=chunk))
    np.testing.assert_allclose(
        np.asarray(out["losses"]), np.asarray(ref["losses"]),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(out["e_total"]), np.asarray(ref["e_total"]), rtol=1e-5
    )
    assert abs(float(out["f1"]) - float(ref["f1"])) < 0.02


def test_async_small_chunk_bitwise():
    """The async launch path compresses via ``client_compress`` (per-row,
    no cross-row sums), so ANY chunk is bit-identical, not just >= N."""
    ref = _trial("hfl-async", _cfg())
    out = _trial("hfl-async", _cfg().replace(client_chunk=5))
    for k in ref:
        np.testing.assert_array_equal(
            np.asarray(ref[k]), np.asarray(out[k]), err_msg=k
        )


def test_faults_trimmed_drift_chunked():
    """The adversarial configuration: crashes + Byzantine sign-flips +
    erasure, trimmed fog reduce, and an active drift schedule — chunking
    must not perturb the PRNG split discipline (fault draws identical) and
    the trimmed path is per-row, so the whole round stays bit-identical."""
    cfg = _cfg(
        faults=flt.FaultConfig(
            erasure_prob=0.2, crash_prob=0.1, byz_frac=0.25,
            byz_scale=3.0, byz_mode="sign_flip",
        ),
        drift=drf.DriftConfig(
            sensor_current_m_s=0.5, reassoc_every=2.0, covariate_shift=0.01
        ),
    ).replace(robust="trimmed", trim_frac=0.2)
    ref = _trial("hfl-selective", cfg)
    out = _trial("hfl-selective", cfg.replace(client_chunk=5))
    for k in ref:
        np.testing.assert_array_equal(
            np.asarray(ref[k]), np.asarray(out[k]), err_msg=k
        )


# ---------------------------------------------------------------------------
# Engine resolution of the knob
# ---------------------------------------------------------------------------

def test_engine_stamps_client_chunk():
    eng = Engine(client_chunk=8)
    cfg = _cfg()
    assert eng.resolve_config(cfg).client_chunk == 8
    # an explicit per-config value wins
    assert eng.resolve_config(cfg.replace(client_chunk=4)).client_chunk == 4
    # default engine leaves the config untouched
    assert Engine().resolve_config(cfg).client_chunk is None


def test_engine_rejects_bad_client_chunk():
    with pytest.raises(ValueError):
        Engine(client_chunk=0)
    with pytest.raises(ValueError):
        _cfg().replace(client_chunk=-2)
