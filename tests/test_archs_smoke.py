"""Per-architecture smoke tests: REDUCED config (<=2 layers, d_model<=512,
<=4 experts), one forward/train step on CPU, shapes + finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import api

ARCHS = configs.model_archs()
DECODE_ARCHS = ARCHS  # every assigned arch has a decoder path


def _batch(key, cfg, b=2, s=16):
    if cfg.n_visual_tokens > 0:
        # Visual embeddings occupy the first n_visual_tokens positions;
        # keep at least `s` text positions carrying loss.
        s = s + cfg.n_visual_tokens
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jax.random.normal(
            key, (b, cfg.n_audio_frames, cfg.d_model), cfg.dtype
        )
    if cfg.n_visual_tokens > 0:
        batch["visual_embeds"] = jax.random.normal(
            key, (b, cfg.n_visual_tokens, cfg.d_model), cfg.dtype
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_limits(arch):
    cfg = configs.get(arch, reduced=True)
    # recurrentgemma keeps 3 layers to preserve the 1:2 local-attn:RG-LRU
    # block pattern; everything else is <= 2.
    assert cfg.n_layers <= (3 if cfg.family == "hybrid" else 2)
    assert cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config must carry the exact published spec."""
    spec = {
        "whisper_medium": dict(n_layers=24, d_model=1024, n_heads=16, vocab_size=51865),
        "qwen3_14b": dict(n_layers=40, d_model=5120, n_heads=40, vocab_size=151936),
        "qwen2_moe_a2_7b": dict(n_layers=24, d_model=2048, n_heads=16,
                                vocab_size=151936, n_experts=60),
        "grok_1_314b": dict(n_layers=64, d_model=6144, n_heads=48, vocab_size=131072, n_experts=8),
        "gemma2_27b": dict(n_layers=46, d_model=4608, n_heads=32, vocab_size=256000),
        "internvl2_26b": dict(n_layers=48, d_model=6144, n_heads=48, vocab_size=92553),
        "llama3_8b": dict(n_layers=32, d_model=4096, n_heads=32, vocab_size=128256),
        "recurrentgemma_2b": dict(n_layers=26, d_model=2560, n_heads=10, vocab_size=256000),
        "mamba2_2_7b": dict(n_layers=64, d_model=2560, vocab_size=50280),
        "qwen3_32b": dict(n_layers=64, d_model=5120, n_heads=64, vocab_size=151936),
    }[arch]
    cfg = configs.get(arch)
    for k, v in spec.items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get(arch, reduced=True)
    key = jax.random.key(0)
    params = api.init_params(key, cfg)
    batch = _batch(jax.random.fold_in(key, 1), cfg)
    step = api.make_train_step(cfg)
    new_params, loss = jax.jit(step)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    # params updated in place structurally
    assert jax.tree_util.tree_structure(new_params) == jax.tree_util.tree_structure(params)
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases_over_steps(arch):
    """Steps on a repeated batch must reduce the loss (learnability).

    MoE losses oscillate step-to-step at the reduced scale (router noise),
    so compare the best of the last 3 steps against the first instead of
    demanding monotonicity at a fixed step count."""
    cfg = configs.get(arch, reduced=True)
    key = jax.random.key(1)
    params = api.init_params(key, cfg)
    batch = _batch(jax.random.fold_in(key, 2), cfg, b=2, s=16)
    step = jax.jit(api.make_train_step(cfg))
    losses = []
    for _ in range(8):
        params, loss = step(params, batch)
        losses.append(float(loss))
    assert min(losses[-3:]) < losses[0], f"{arch}: {losses}"


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_step_smoke(arch):
    cfg = configs.get(arch, reduced=True)
    key = jax.random.key(2)
    params = api.init_params(key, cfg)
    cache = api.init_cache(cfg, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    step = jax.jit(api.make_serve_step(cfg))
    cache2, logits = step(params, cache, tok)
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    assert jax.tree_util.tree_structure(cache2) == jax.tree_util.tree_structure(cache)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma2_27b", "recurrentgemma_2b", "mamba2_2_7b"])
def test_long_context_decode_smoke(arch):
    """Sub-quadratic archs must also run the long-context decode path."""
    cfg = configs.get(arch, reduced=True)
    key = jax.random.key(3)
    params = api.init_params(key, cfg)
    cache = api.init_cache(cfg, 1, 64, long_context=True)
    step = jax.jit(api.make_serve_step(cfg, long_context=True))
    cache2, logits = step(params, cache, jnp.zeros((1, 1), jnp.int32))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_shapes(arch):
    cfg = configs.get(arch)
    for shape in configs.SHAPES.values():
        ok, reason = api.supports_shape(cfg, shape)
        if not ok:
            assert shape.name == "long_500k" and reason
            continue
        specs = api.input_specs(cfg, shape)
        assert "tokens" in specs
        b = shape.global_batch
        if shape.kind in ("train", "prefill"):
            assert specs["tokens"].shape == (b, shape.seq_len)
        else:
            assert specs["tokens"].shape == (b, 1)


def test_param_counts_in_published_ballpark():
    """Sanity: total parameter counts should be near the model names."""
    expect = {
        "llama3_8b": (7e9, 9.5e9),
        "qwen3_14b": (13e9, 16e9),
        "qwen3_32b": (30e9, 35e9),
        "gemma2_27b": (25e9, 30e9),
        "grok_1_314b": (280e9, 340e9),
        "mamba2_2_7b": (2.2e9, 3.2e9),
        "recurrentgemma_2b": (2e9, 3.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).param_count()
        assert lo < n < hi, f"{arch}: {n:.3e} not in ({lo:.1e}, {hi:.1e})"
