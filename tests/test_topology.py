"""Tests for the stratified 3D deployment + Gauss-Markov fog mobility."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology as topo


def _in_stratum(pos, params, depth):
    ok_xy = (
        bool(jnp.all(pos[:, 0] >= 0))
        and bool(jnp.all(pos[:, 0] <= params.lx_m))
        and bool(jnp.all(pos[:, 1] >= 0))
        and bool(jnp.all(pos[:, 1] <= params.ly_m))
    )
    ok_z = bool(jnp.all(pos[:, 2] >= depth[0])) and bool(
        jnp.all(pos[:, 2] <= depth[1])
    )
    return ok_xy and ok_z


def test_deployment_respects_strata(small_deployment):
    dep, params = small_deployment
    assert dep.sensor_pos.shape == (params.n_sensors, 3)
    assert dep.fog_pos.shape == (params.n_fog, 3)
    assert _in_stratum(dep.sensor_pos, params, params.sensor_depth)
    assert _in_stratum(dep.fog_pos, params, params.fog_depth)
    np.testing.assert_allclose(
        np.asarray(dep.gateway_pos), [1000.0, 1000.0, 0.0]
    )


def test_gauss_markov_keeps_fogs_in_bounds(small_deployment):
    dep, params = small_deployment
    key = jax.random.key(3)
    for _ in range(50):
        key, k = jax.random.split(key)
        dep = topo.gauss_markov_step(k, dep, params)
    assert _in_stratum(dep.fog_pos, params, params.fog_depth)


def test_gauss_markov_moves_fogs_but_not_sensors(small_deployment):
    dep, params = small_deployment
    dep2 = topo.gauss_markov_step(jax.random.key(0), dep, params)
    assert bool(jnp.all(dep2.sensor_pos == dep.sensor_pos))
    assert not bool(jnp.all(dep2.fog_pos == dep.fog_pos))


def test_gauss_markov_speed_scale(small_deployment):
    """Expected per-round displacement ~ speed * interval; check the order."""
    dep, params = small_deployment
    dep2 = topo.gauss_markov_step(jax.random.key(1), dep, params)
    disp = jnp.linalg.norm(dep2.fog_pos - dep.fog_pos, axis=-1)
    # sigma=0.5 m/s, 60 s round => tens of metres, not km.
    assert float(jnp.max(disp)) < 10.0 * params.fog_speed_m_s * params.round_interval_s


def test_deployment_is_pytree(small_deployment):
    dep, _ = small_deployment
    leaves = jax.tree_util.tree_leaves(dep)
    assert len(leaves) == 4
    dep2 = jax.tree_util.tree_map(lambda x: x + 0.0, dep)
    assert isinstance(dep2, topo.Deployment)
