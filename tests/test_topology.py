"""Tests for the stratified 3D deployment + Gauss-Markov fog mobility."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology as topo


def _in_stratum(pos, params, depth):
    ok_xy = (
        bool(jnp.all(pos[:, 0] >= 0))
        and bool(jnp.all(pos[:, 0] <= params.lx_m))
        and bool(jnp.all(pos[:, 1] >= 0))
        and bool(jnp.all(pos[:, 1] <= params.ly_m))
    )
    ok_z = bool(jnp.all(pos[:, 2] >= depth[0])) and bool(
        jnp.all(pos[:, 2] <= depth[1])
    )
    return ok_xy and ok_z


def test_deployment_respects_strata(small_deployment):
    dep, params = small_deployment
    assert dep.sensor_pos.shape == (params.n_sensors, 3)
    assert dep.fog_pos.shape == (params.n_fog, 3)
    assert _in_stratum(dep.sensor_pos, params, params.sensor_depth)
    assert _in_stratum(dep.fog_pos, params, params.fog_depth)
    np.testing.assert_allclose(
        np.asarray(dep.gateway_pos), [1000.0, 1000.0, 0.0]
    )


def test_gauss_markov_keeps_fogs_in_bounds(small_deployment):
    dep, params = small_deployment
    key = jax.random.key(3)
    for _ in range(50):
        key, k = jax.random.split(key)
        dep = topo.gauss_markov_step(k, dep, params)
    assert _in_stratum(dep.fog_pos, params, params.fog_depth)


def test_gauss_markov_moves_fogs_but_not_sensors(small_deployment):
    dep, params = small_deployment
    dep2 = topo.gauss_markov_step(jax.random.key(0), dep, params)
    assert bool(jnp.all(dep2.sensor_pos == dep.sensor_pos))
    assert not bool(jnp.all(dep2.fog_pos == dep.fog_pos))


def test_gauss_markov_speed_scale(small_deployment):
    """Expected per-round displacement ~ speed * interval; check the order."""
    dep, params = small_deployment
    dep2 = topo.gauss_markov_step(jax.random.key(1), dep, params)
    disp = jnp.linalg.norm(dep2.fog_pos - dep.fog_pos, axis=-1)
    # sigma=0.5 m/s, 60 s round => tens of metres, not km.
    assert float(jnp.max(disp)) < 10.0 * params.fog_speed_m_s * params.round_interval_s


def test_deployment_is_pytree(small_deployment):
    dep, _ = small_deployment
    leaves = jax.tree_util.tree_leaves(dep)
    assert len(leaves) == 4
    dep2 = jax.tree_util.tree_map(lambda x: x + 0.0, dep)
    assert isinstance(dep2, topo.Deployment)


# ---------------------------------------------------------------------------
# Gauss-Markov statistics (ISSUE 9 satellite: the walk was exported but
# never statistically tested).
# ---------------------------------------------------------------------------

def _gm_velocity_trace(dep, params, steps: int, seed: int = 7):
    key = jax.random.key(seed)
    vels = []
    for _ in range(steps):
        key, k = jax.random.split(key)
        dep = topo.gauss_markov_step(k, dep, params)
        vels.append(np.asarray(dep.fog_vel))
    return dep, np.stack(vels)  # (T, M, 3)


def test_gauss_markov_speed_stays_bounded(small_deployment):
    """The stationary per-component std is sigma = fog_speed_m_s; with
    zero mean velocity the speed should live within a few sigma of
    sqrt(3) * sigma and never run away over a long trace."""
    dep, params = small_deployment
    _, vels = _gm_velocity_trace(dep, params, steps=200)
    speeds = np.linalg.norm(vels, axis=-1)  # (T, M)
    sigma = params.fog_speed_m_s
    # 6-sigma bound on the per-component Gaussian => generous speed cap.
    assert speeds.max() < 6.0 * np.sqrt(3.0) * sigma
    # ...and the empirical per-component std matches sigma within 20%.
    emp = vels[50:].std()  # post burn-in, pooled over (T, M, 3)
    assert 0.8 * sigma < emp < 1.2 * sigma


def test_gauss_markov_alpha_memory_honoured(small_deployment):
    """Lag-1 autocorrelation of each velocity component ~= gm_alpha; the
    reflection flip makes the position-limited walk slightly less
    correlated, so compare with a loose band and against a low-alpha
    control."""
    dep, params = small_deployment
    hi = params.replace(gm_alpha=0.9)
    lo = params.replace(gm_alpha=0.1)

    def lag1(params_):
        _, vels = _gm_velocity_trace(dep, params_, steps=300)
        v = vels[50:].reshape(vels[50:].shape[0], -1)  # (T, M*3)
        a, b = v[:-1], v[1:]
        num = ((a - a.mean(0)) * (b - b.mean(0))).sum()
        den = np.sqrt(((a - a.mean(0)) ** 2).sum() * ((b - b.mean(0)) ** 2).sum())
        return num / den

    r_hi, r_lo = lag1(hi), lag1(lo)
    assert r_hi > r_lo + 0.3          # memory factor orders the processes
    assert r_hi > 0.6                 # alpha=0.9 keeps strong memory
    assert abs(r_lo) < 0.35           # alpha=0.1 is near-white


def test_gauss_markov_reflection_no_escape_aggressive(small_deployment):
    """A walk fast enough to overshoot the volume every step must still
    stay inside lx_m x ly_m x fog_depth (reflection + clip guard)."""
    dep, params = small_deployment
    fast = params.replace(fog_speed_m_s=50.0)  # ~3 km/step vs 2 km box
    key = jax.random.key(11)
    for _ in range(100):
        key, k = jax.random.split(key)
        dep = topo.gauss_markov_step(k, dep, fast)
        assert _in_stratum(dep.fog_pos, fast, fast.fog_depth)


# ---------------------------------------------------------------------------
# Sensor current advection (dynamic world, PR 9).
# ---------------------------------------------------------------------------

def test_advection_moves_sensors_not_fogs(small_deployment):
    dep, params = small_deployment
    dep2 = topo.current_advection_step(dep, params, 2.0)
    assert not bool(jnp.all(dep2.sensor_pos == dep.sensor_pos))
    assert bool(jnp.all(dep2.fog_pos == dep.fog_pos))
    assert bool(jnp.all(dep2.fog_vel == dep.fog_vel))
    # The current is horizontal: depth must be untouched.
    assert bool(jnp.all(dep2.sensor_pos[:, 2] == dep.sensor_pos[:, 2]))


def test_advection_zero_speed_is_identity(small_deployment):
    dep, params = small_deployment
    dep2 = topo.current_advection_step(dep, params, 0.0)
    assert bool(jnp.all(dep2.sensor_pos == dep.sensor_pos))


def test_advection_deterministic_and_speed_scaled(small_deployment):
    dep, params = small_deployment
    a = topo.current_advection_step(dep, params, 1.5)
    b = topo.current_advection_step(dep, params, 1.5)
    assert bool(jnp.all(a.sensor_pos == b.sensor_pos))  # no PRNG consumed
    disp = jnp.linalg.norm(
        (a.sensor_pos - dep.sensor_pos)[:, :2], axis=-1
    )
    # Interior sensors move exactly speed * interval; reflection can only
    # shorten the net displacement.
    expect = 1.5 * params.round_interval_s
    assert float(jnp.max(disp)) <= expect + 1e-3
    assert float(jnp.median(disp)) > 0.5 * expect


def test_advection_stays_in_sensor_stratum(small_deployment):
    dep, params = small_deployment
    for _ in range(60):
        dep = topo.current_advection_step(dep, params, 25.0)
        assert _in_stratum(dep.sensor_pos, params, params.sensor_depth)


def test_advection_traceable_speed(small_deployment):
    """speed is a DriftConfig sweep leaf: the step must jit with a traced
    scalar operand."""
    dep, params = small_deployment
    stepped = jax.jit(
        lambda s: topo.current_advection_step(dep, params, s)
    )(jnp.asarray(3.0))
    ref = topo.current_advection_step(dep, params, 3.0)
    np.testing.assert_allclose(
        np.asarray(stepped.sensor_pos), np.asarray(ref.sensor_pos),
        rtol=1e-5, atol=1e-4,
    )
