"""Unit tests for the CI bench gates (satellites of PR 6).

The gates run in CI against freshly generated JSONs; these tests pin the
``compare`` contracts themselves on synthetic fixtures — a passing pair,
a regressed pair, and the vanished-row case — so a gate refactor cannot
silently stop failing.  Also covers the ``benchmarks.run --only``
typo handling and the step-summary delta table.
"""
import pytest

from benchmarks import (
    bench_summary,
    check_async_bench,
    check_drift_bench,
    check_kernel_micro,
    check_load_bench,
    check_robustness_bench,
    check_scale_bench,
    check_sweep_compile,
)
from benchmarks import run as bench_run


# ---------------------------------------------------------------------------
# check_kernel_micro.compare (shared by check_serve_bench)
# ---------------------------------------------------------------------------

def _kernel_json(us_ref: float) -> dict:
    return {"rows": [{"n": 1024, "us_ref": us_ref}]}


def test_kernel_gate_passes_within_threshold():
    failures = check_kernel_micro.compare(
        _kernel_json(120.0), _kernel_json(100.0), threshold=3.0
    )
    assert failures == []


def test_kernel_gate_trips_on_regression():
    failures = check_kernel_micro.compare(
        _kernel_json(400.0), _kernel_json(100.0), threshold=3.0
    )
    assert len(failures) == 1
    assert "us_ref" in failures[0]


def test_kernel_gate_fails_loudly_on_missing_row():
    fresh = {"rows": []}  # the refactor dropped the cell
    failures = check_kernel_micro.compare(
        fresh, _kernel_json(100.0), threshold=3.0
    )
    assert failures and "missing" in failures[0]


def test_kernel_gate_skips_baseline_without_metric():
    """A baseline predating the metric is 'no trend yet', not a failure."""
    failures = check_kernel_micro.compare(
        _kernel_json(100.0), {"rows": [{"n": 1024}]}, threshold=3.0
    )
    assert failures == []


# ---------------------------------------------------------------------------
# check_sweep_compile.compare
# ---------------------------------------------------------------------------

def _sweep_json(programs: int, cells: int = 8) -> dict:
    return {"engine": {
        "sweep_compiled_programs": programs, "sweep_cells": cells,
    }}


def test_sweep_gate_passes_on_equal_counts():
    assert check_sweep_compile.compare(_sweep_json(1), _sweep_json(1)) == []


def test_sweep_gate_trips_on_per_cell_fallback():
    failures = check_sweep_compile.compare(_sweep_json(8), _sweep_json(1))
    assert failures and "fallback" in failures[0]


def test_sweep_gate_trips_on_shrunk_coverage():
    failures = check_sweep_compile.compare(
        _sweep_json(1, cells=2), _sweep_json(1, cells=8)
    )
    assert failures and "shrank" in failures[0]


def test_sweep_gate_fails_loudly_on_missing_engine_block():
    failures = check_sweep_compile.compare({}, _sweep_json(1))
    assert failures and "missing" in failures[0]


# ---------------------------------------------------------------------------
# check_async_bench.compare
# ---------------------------------------------------------------------------

def _async_json(
    s_per_merge: float = 4.0,
    speedup: float = 1.1,
    f1: float = 0.9,
    sync_s: float = 4.5,
) -> dict:
    return {
        "sync": {"sim_s_per_round": sync_s},
        "rows": [{
            "alpha": 0.5, "buffer_frac": 0.25,
            "sim_s_per_merge": s_per_merge,
            "speedup_vs_sync": speedup,
            "f1_mean": f1,
        }],
    }


def test_async_gate_passes_within_threshold():
    failures = check_async_bench.compare(_async_json(), _async_json())
    assert failures == []


def test_async_gate_trips_on_throughput_regression():
    failures = check_async_bench.compare(
        _async_json(s_per_merge=8.0), _async_json(), threshold=1.25
    )
    assert any("sim_s_per_merge" in f for f in failures)


def test_async_gate_trips_on_shrunk_speedup():
    failures = check_async_bench.compare(
        _async_json(speedup=0.7), _async_json(speedup=1.1), threshold=1.25
    )
    assert any("speedup_vs_sync" in f for f in failures)


def test_async_gate_trips_on_f1_drop():
    failures = check_async_bench.compare(
        _async_json(f1=0.7), _async_json(f1=0.9), f1_tol=0.08
    )
    assert any("f1_mean" in f for f in failures)


def test_async_gate_trips_on_sync_baseline_regression():
    """A latency-model slowdown that hits BOTH paths hides in the speedup
    ratio — the sync row's own ratio check is what catches it."""
    failures = check_async_bench.compare(
        _async_json(sync_s=9.0), _async_json(sync_s=4.5)
    )
    assert any("sync.sim_s_per_round" in f for f in failures)


def test_async_gate_fails_loudly_on_missing_row():
    fresh = {"sync": {"sim_s_per_round": 4.5}, "rows": []}
    failures = check_async_bench.compare(fresh, _async_json())
    assert any("missing" in f for f in failures)


# ---------------------------------------------------------------------------
# check_scale_bench.compare (fleet-axis memory + wall-clock, PR 10)
# ---------------------------------------------------------------------------

def _scale_row(n, chunk, temp=65e6, wall=1.0):
    return {"n": n, "chunk": chunk, "temp_bytes": temp, "wall_s": wall}


def _scale_json(dense_temp=260e6, big_temp=65e6, far_temp=65e6, wall=1.0):
    return {"rows": [
        _scale_row(2000, None, temp=dense_temp),
        _scale_row(2000, 512),
        _scale_row(10000, 512, temp=big_temp, wall=wall),
        _scale_row(50000, 512, temp=far_temp),
    ]}


def test_scale_gate_passes_on_healthy_json():
    assert check_scale_bench.compare(_scale_json(), _scale_json()) == []


def test_scale_gate_trips_on_chunk_pin():
    """The headline acceptance pin: chunked 10k temp creeping back toward
    the dense footprint fails even with a matching baseline — and the pin
    needs no baseline at all."""
    failures = check_scale_bench.compare(
        _scale_json(big_temp=200e6), _scale_json(big_temp=200e6)
    )
    assert any("chunk-pin" in f for f in failures)
    failures = check_scale_bench.compare(_scale_json(big_temp=200e6), None)
    assert any("chunk-pin" in f for f in failures)


def test_scale_gate_trips_on_growing_footprint():
    """Chunked temp spreading with N means the footprint follows the fleet
    again — flatness is fresh-internal, no baseline involved."""
    failures = check_scale_bench.compare(
        _scale_json(far_temp=100e6), _scale_json(far_temp=100e6)
    )
    assert any("growing with the fleet" in f for f in failures)


def test_scale_gate_trips_on_memory_regression_vs_baseline():
    failures = check_scale_bench.compare(
        _scale_json(big_temp=80e6), _scale_json(big_temp=65e6)
    )
    assert any("memory regression" in f for f in failures)


def test_scale_gate_trips_on_wall_clock_regression():
    failures = check_scale_bench.compare(
        _scale_json(wall=4.0), _scale_json(wall=1.0)
    )
    assert any("wall-clock regression" in f for f in failures)


def test_scale_gate_fails_loudly_on_missing_cell():
    fresh = _scale_json()
    fresh["rows"] = [r for r in fresh["rows"] if r["n"] != 50000]
    failures = check_scale_bench.compare(fresh, _scale_json())
    assert any("missing" in f for f in failures)


# ---------------------------------------------------------------------------
# check_robustness_bench.compare
# ---------------------------------------------------------------------------

def _robust_row(robust, byz, er, f1, nonfinite=0.0):
    return {
        "robust": robust, "byz_frac": byz, "erasure": er,
        "f1_mean": f1, "nonfinite_rounds": nonfinite,
    }


def _robust_json(
    clean_f1=0.91,
    mean_byz_f1=0.2,
    trim_f1=0.9,
    med_f1=0.9,
    erased_f1=0.88,
    nonfinite=0.0,
    programs=3,
) -> dict:
    return {
        "n_classes": 3,
        "rows": [
            _robust_row("mean", 0.0, 0.0, clean_f1),
            _robust_row("mean", 0.0, 0.3, erased_f1, nonfinite=nonfinite),
            _robust_row("mean", 0.25, 0.0, mean_byz_f1),
            _robust_row("trimmed", 0.25, 0.0, trim_f1),
            _robust_row("trimmed", 0.25, 0.3, erased_f1),
            _robust_row("median", 0.25, 0.0, med_f1),
        ],
        "engine": {"sweep_compiled_programs": programs, "sweep_cells": 6},
    }


def test_robust_gate_passes_on_healthy_grid():
    failures = check_robustness_bench.compare(_robust_json(), _robust_json())
    assert failures == []


def test_robust_gate_trips_when_robust_rule_drops():
    failures = check_robustness_bench.compare(
        _robust_json(trim_f1=0.5), _robust_json(), f1_tol=0.12
    )
    assert any("trimmed" in f and "dropped" in f for f in failures)
    # ...both fresh-internal and vs the committed baseline.
    failures = check_robustness_bench.compare(
        _robust_json(med_f1=0.7), _robust_json(med_f1=0.9), f1_tol=0.12
    )
    assert any("median" in f for f in failures)


def test_robust_gate_trips_when_mean_stops_collapsing():
    """If the attack no longer hurts the plain mean, the benchmark proves
    nothing — that's a failure, not a success."""
    failures = check_robustness_bench.compare(
        _robust_json(mean_byz_f1=0.85), _robust_json(), degrade_margin=0.25
    )
    assert any("no longer degrades" in f for f in failures)


def test_robust_gate_trips_on_nonfinite_rounds():
    failures = check_robustness_bench.compare(
        _robust_json(nonfinite=2.0), _robust_json()
    )
    assert any("non-finite" in f for f in failures)


def test_robust_gate_trips_on_erasure_cliff():
    failures = check_robustness_bench.compare(
        _robust_json(erased_f1=0.3), _robust_json(), erasure_tol=0.15
    )
    assert any("cliff" in f for f in failures)


def test_robust_gate_trips_on_compile_fallback():
    failures = check_robustness_bench.compare(
        _robust_json(programs=6), _robust_json()
    )
    assert any("batching regressed" in f for f in failures)


def test_robust_gate_fails_loudly_on_missing_row():
    fresh = _robust_json()
    fresh["rows"] = [r for r in fresh["rows"] if r["robust"] != "median"]
    failures = check_robustness_bench.compare(fresh, _robust_json())
    assert any("missing" in f for f in failures)
    # No clean anchor row at all: nothing else is checkable.
    failures = check_robustness_bench.compare(
        {"rows": []}, _robust_json()
    )
    assert any("anchor" in f for f in failures)


# ---------------------------------------------------------------------------
# check_drift_bench.compare
# ---------------------------------------------------------------------------

def _drift_row(cell, f1, part, nonfinite=0.0):
    return {
        "cell": cell, "f1_mean": f1, "participation": part,
        "nonfinite_rounds": nonfinite,
    }


def _drift_json(
    static_part=0.89,
    frozen_part=0.71,
    reassoc_part=0.85,
    reassoc_f1=0.84,
    mean_byz_f1=0.23,
    trim_f1=0.84,
    nonfinite=0.0,
    programs=4,
) -> dict:
    return {
        "n_classes": 4,
        "rows": [
            _drift_row("static", 0.84, static_part),
            _drift_row("frozen", 0.84, frozen_part, nonfinite=nonfinite),
            _drift_row("reassoc", reassoc_f1, reassoc_part),
            _drift_row("clean-mean", 0.84, 1.0),
            _drift_row("adaptive-mean", mean_byz_f1, 1.0),
            _drift_row("adaptive-trimmed", trim_f1, 1.0),
            _drift_row("adaptive-median", 0.84, 1.0),
        ],
        "engine": {"sweep_compiled_programs": programs, "sweep_cells": 7},
    }


def test_drift_gate_passes_on_healthy_grid():
    failures = check_drift_bench.compare(_drift_json(), _drift_json())
    assert failures == []


def test_drift_gate_trips_when_frozen_stops_degrading():
    """If stale association no longer sheds participation under drift,
    the scenario demonstrates nothing — that's a failure."""
    failures = check_drift_bench.compare(
        _drift_json(frozen_part=0.88), _drift_json(), part_margin=0.08
    )
    assert any("no longer degrades" in f for f in failures)


def test_drift_gate_trips_when_reassoc_loses_participation():
    failures = check_drift_bench.compare(
        _drift_json(reassoc_part=0.7), _drift_json(), part_tol=0.06
    )
    assert any("re-association lost" in f for f in failures)


def test_drift_gate_trips_when_drift_corrupts_f1():
    failures = check_drift_bench.compare(
        _drift_json(reassoc_f1=0.6), _drift_json(reassoc_f1=0.6), f1_tol=0.12
    )
    assert any("reassoc" in f and "dropped" in f for f in failures)


def test_drift_gate_trips_when_adaptive_mean_stops_collapsing():
    failures = check_drift_bench.compare(
        _drift_json(mean_byz_f1=0.8), _drift_json(), degrade_margin=0.30
    )
    assert any("no longer collapses" in f for f in failures)


def test_drift_gate_trips_when_robust_rule_drops():
    # ...fresh-internal (vs the clean anchor) and vs the committed baseline.
    failures = check_drift_bench.compare(
        _drift_json(trim_f1=0.5), _drift_json(trim_f1=0.5), f1_tol=0.12
    )
    assert any("adaptive-trimmed" in f for f in failures)
    failures = check_drift_bench.compare(
        _drift_json(reassoc_f1=0.75), _drift_json(reassoc_f1=0.9), f1_tol=0.12
    )
    assert any("baseline" in f for f in failures)


def test_drift_gate_trips_on_nonfinite_rounds():
    failures = check_drift_bench.compare(
        _drift_json(nonfinite=1.0), _drift_json()
    )
    assert any("non-finite" in f for f in failures)


def test_drift_gate_trips_on_compile_fallback():
    failures = check_drift_bench.compare(
        _drift_json(programs=7), _drift_json()
    )
    assert any("batching regressed" in f for f in failures)


def test_drift_gate_fails_loudly_on_missing_row():
    fresh = _drift_json()
    fresh["rows"] = [r for r in fresh["rows"] if r["cell"] != "frozen"]
    failures = check_drift_bench.compare(fresh, _drift_json())
    assert any("missing" in f for f in failures)
    # No anchors at all: nothing else is checkable.
    failures = check_drift_bench.compare({"rows": []}, _drift_json())
    assert any("anchor" in f for f in failures)


# ---------------------------------------------------------------------------
# check_load_bench (latency/throughput trends + exact pins + structure)
# ---------------------------------------------------------------------------

def _load_row(trace, config, p50=10.0, p99=20.0, sps=1e6, buckets=(128, 1024),
              completed=100):
    return {
        "trace": trace, "config": config, "n_events": 100,
        "completed": completed, "e2e_p50_ms": p50, "e2e_p99_ms": p99,
        "samples_per_s": sps,
        # json round-trips int keys as strings: model that worst case.
        "compiles_by_bucket": {str(b): 1 for b in buckets},
    }


def _load_json(
    fixed_p99=400.0,
    bucketed_p99=20.0,
    bucketed_sps=1e6,
    compiles=1,
    completed=100,
    mismatch_frac=0.001,
    swap_isolated=True,
) -> dict:
    rows = [
        _load_row("mmpp", "fixed", p99=fixed_p99, buckets=(1024,)),
        _load_row("mmpp", "adaptive_bucketed", p99=bucketed_p99,
                  sps=bucketed_sps, completed=completed),
    ]
    rows[1]["compiles_by_bucket"] = {"128": compiles, "1024": compiles}
    return {
        "replays": rows,
        "int8_parity": {"flag_mismatch_frac": mismatch_frac},
        "tenancy": {
            "compiles_by_bucket": {"128": 1, "1024": 1},
            "swap_isolated": swap_isolated,
            "loaded_step": {"a": 1, "b": 2},
        },
    }


def test_load_gate_passes_on_healthy_json(capsys):
    base = _load_json()
    failures = check_load_bench.compare(
        base, base, 3.0, check_load_bench.LATENCY_CHECKS, unit="ms"
    )
    failures += check_load_bench.compare_throughput(base, base, 3.0)
    failures += check_load_bench.check_exact(base, base)
    failures += check_load_bench.check_structure(base)
    assert failures == []


def test_load_gate_trips_on_latency_regression():
    failures = check_load_bench.compare(
        _load_json(bucketed_p99=90.0), _load_json(), 3.0,
        check_load_bench.LATENCY_CHECKS,
    )
    assert any("e2e_p99_ms" in f for f in failures)


def test_load_gate_trips_on_throughput_drop_inverse_direction():
    """samples_per_s gates the INVERSE ratio: a drop fails, a gain never."""
    failures = check_load_bench.compare_throughput(
        _load_json(bucketed_sps=1e5), _load_json(bucketed_sps=1e6), 3.0
    )
    assert any("samples_per_s" in f for f in failures)
    assert check_load_bench.compare_throughput(
        _load_json(bucketed_sps=1e7), _load_json(bucketed_sps=1e6), 3.0
    ) == []


def test_load_gate_fails_loudly_on_missing_row():
    fresh = _load_json()
    fresh["replays"] = fresh["replays"][:1]    # dropped adaptive_bucketed
    failures = check_load_bench.compare(
        fresh, _load_json(), 3.0, check_load_bench.LATENCY_CHECKS
    )
    assert any("missing" in f for f in failures)
    failures = check_load_bench.check_exact(fresh, _load_json())
    assert any("missing" in f for f in failures)


def test_load_gate_trips_on_retrace_and_dropped_requests():
    failures = check_load_bench.check_exact(
        _load_json(compiles=2), _load_json()
    )
    assert any("compiles_by_bucket" in f for f in failures)
    failures = check_load_bench.check_exact(
        _load_json(completed=99), _load_json(completed=99)
    )
    assert any("completed" in f for f in failures)


def test_load_gate_structure_checks():
    # Adaptive batching failing to beat fixed p99 on the bursty trace is a
    # failure even when every trend ratio looks fine.
    failures = check_load_bench.check_structure(
        _load_json(fixed_p99=15.0, bucketed_p99=20.0)
    )
    assert any("does not beat" in f for f in failures)
    failures = check_load_bench.check_structure(_load_json(mismatch_frac=0.5))
    assert any("int8" in f for f in failures)
    failures = check_load_bench.check_structure({"replays": []})
    assert any("missing" in f for f in failures)


def test_load_gate_trips_on_tenancy_violations():
    bad = _load_json()
    bad["tenancy"]["compiles_by_bucket"] = {"128": 2, "1024": 1}
    failures = check_load_bench.check_exact(bad, _load_json())
    assert any("per bucket" in f for f in failures)
    failures = check_load_bench.check_exact(
        _load_json(swap_isolated=False), _load_json()
    )
    assert any("hot-swap" in f for f in failures)


# ---------------------------------------------------------------------------
# benchmarks.run --only validation + step-summary table
# ---------------------------------------------------------------------------

def test_run_only_rejects_typo_with_usage(monkeypatch, capsys):
    monkeypatch.setattr(
        "sys.argv", ["run.py", "--only", "async_bnech"]
    )
    with pytest.raises(SystemExit) as exc:
        bench_run.main()
    assert exc.value.code == 2  # argparse usage error, not a traceback
    err = capsys.readouterr().err
    assert "unknown benchmark module" in err
    assert "async_bench" in err  # the valid choices are listed


def test_bench_summary_builds_delta_rows(tmp_path):
    import json

    fresh_dir = tmp_path / "fresh"
    base_dir = tmp_path / "base"
    fresh_dir.mkdir()
    base_dir.mkdir()
    (base_dir / "async_bench.json").write_text(json.dumps(_async_json()))
    (fresh_dir / "async_bench.json").write_text(
        json.dumps(_async_json(s_per_merge=4.4))
    )
    rows = bench_summary.delta_rows(str(fresh_dir), str(base_dir))
    tagged = [r for r in rows if r[0] == "async_bench" and r[2] == "sim_s_per_merge"]
    assert tagged, f"no async delta rows in {rows}"
    md = bench_summary.markdown(rows)
    assert "|" in md and "sim_s_per_merge" in md
