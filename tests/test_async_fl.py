"""Tests for the event-driven async family (core/async_fl, PR 6).

The acceptance pin lives here: ``async_fl.sync_limit`` must reproduce
``hfl.train`` round-for-round to float tolerance — that equivalence is
what lets the async loop share the fused local-train and compress kernels
with the synchronous families without a parallel numerics audit.  The
rest covers the genuinely-async semantics (staleness discounting, version
counting, decoupled fog/global cadence) and the Engine integration
(fourth family, one compiled program per sweep shape-class).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro import engine as eng_mod
from repro.core import async_fl, hfl
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.launch import experiment as exp
from repro.models import autoencoder as ae

N_SENSORS = 12
N_FOG = 3


def _make_ds(seed: int = 0):
    cfg = SyntheticConfig(
        n_sensors=N_SENSORS, train_len=48, val_len=24, test_len=48
    )
    return normalize(generate(jax.random.key(seed), cfg))


def _base_cfg(**kw):
    kw.setdefault("rounds", 3)
    kw.setdefault("local_epochs", 1)
    return exp.make_config(n_sensors=N_SENSORS, n_fog=N_FOG, **kw)


def _async_cfg(**kw):
    kw.setdefault("base", _base_cfg())
    kw.setdefault("n_events", 8)
    kw.setdefault("buffer_k", 4.0)
    kw.setdefault("fog_k", 1.0)
    kw.setdefault("alpha", 0.5)
    return async_fl.AsyncFLConfig(**kw)


@pytest.fixture(scope="module")
def ds():
    return _make_ds(0)


@pytest.fixture(scope="module")
def params0(ds):
    return ae.init(jax.random.key(1), ds.train.shape[-1], (16, 8, 16))


# ---------------------------------------------------------------------------
# The acceptance pin: sync limiting case == Algorithm 1.
# ---------------------------------------------------------------------------

def test_sync_limit_reproduces_hfl_train(ds, params0):
    """fog_k = buffer_k = N, alpha = 0, timeouts never: every event is one
    synchronous round, bit-comparable to ``hfl.train``."""
    cfg = _base_cfg(rounds=3)
    key = jax.random.key(5)

    p_sync, m_sync = hfl.train(key, params0, ae.loss, ds, cfg)
    p_async, m_async = async_fl.train(
        key, params0, ae.loss, ds, async_fl.sync_limit(cfg)
    )

    flat_s, _ = ravel_pytree(p_sync)
    flat_a, _ = ravel_pytree(p_async)
    np.testing.assert_allclose(
        np.asarray(flat_a), np.asarray(flat_s), rtol=1e-5, atol=1e-6
    )
    # The shared metric block matches RoundMetrics term for term.
    for field in hfl.RoundMetrics._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(m_async, field)),
            np.asarray(getattr(m_sync, field)),
            rtol=1e-4, atol=1e-6, err_msg=field,
        )
    # Every tick is a round: all merge, none are stale.
    assert bool(jnp.all(m_async.merged))
    np.testing.assert_array_equal(np.asarray(m_async.staleness), 0.0)


def test_sync_limit_through_run_method(ds):
    """Engine-facing equivalence: the async family in its sync limit
    reports the same detector quality as ``hfl-selective``."""
    cfg = _base_cfg(rounds=2)
    r_sync = exp.run_method("hfl-selective", ds, cfg, seed=3)
    r_async = exp.run_method(
        "hfl-async", ds, async_fl.sync_limit(cfg), seed=3
    )
    assert r_async.f1 == pytest.approx(r_sync.f1, abs=1e-6)
    assert r_async.e_total == pytest.approx(r_sync.e_total, rel=1e-4)


# ---------------------------------------------------------------------------
# Genuinely asynchronous semantics.
# ---------------------------------------------------------------------------

def test_async_run_produces_staleness_and_merges(ds, params0):
    acfg = _async_cfg(n_events=10, alpha=1.0)
    _, m = async_fl.train(jax.random.key(2), params0, ae.loss, ds, acfg)

    assert bool(jnp.any(m.merged)), "no global merge in 10 events"
    # With fog_k=1 and a small buffer some updates must arrive late.
    assert float(jnp.max(m.staleness)) > 0.0
    # The simulated clock is monotone and finite.
    t = np.asarray(m.t_sim)
    assert np.all(np.isfinite(t)) and np.all(np.diff(t) >= 0.0)
    assert np.all(np.isfinite(np.asarray(m.loss)))


def test_version_advances_only_on_effective_merges(ds, params0):
    """The global version counts model *movements*: it can never exceed
    the number of merge ticks that actually carried weight."""
    acfg = _async_cfg(n_events=12)
    state = async_fl.init_state(jax.random.key(4), params0, acfg)
    event_fn = async_fl.make_event_fn(ae.loss, ds, acfg)
    final, m = jax.lax.scan(event_fn, state, None, length=acfg.n_events)

    n_merges = int(jnp.sum(m.merged.astype(jnp.int32)))
    assert int(final.version) <= n_merges
    assert int(final.version) > 0
    # Staleness tau is bounded by the version distance.
    assert float(jnp.max(m.staleness)) <= float(final.version)


def test_fog_cadence_decoupled_from_global(ds, params0):
    """fog_k only paces the fog ticks — the same buffer_k merges either
    way, but waiting for more arrivals per tick changes WHEN."""
    fast = _async_cfg(n_events=8, fog_k=1.0)
    slow = _async_cfg(n_events=8, fog_k=6.0)
    _, m_fast = async_fl.train(jax.random.key(6), params0, ae.loss, ds, fast)
    _, m_slow = async_fl.train(jax.random.key(6), params0, ae.loss, ds, slow)
    # Waiting for the 6th arrival folds more updates per typical tick
    # (a merge-propagation clock jump can batch arrivals even at fog_k=1,
    # so compare the mean, not the max).
    assert float(jnp.mean(m_slow.n_arrived.astype(jnp.float32))) > float(
        jnp.mean(m_fast.n_arrived.astype(jnp.float32))
    )
    # ...and both remain valid simulations.
    assert bool(jnp.any(m_fast.merged)) and bool(jnp.any(m_slow.merged))


def test_async_beats_sync_limit_on_event_time(ds, params0):
    """The family's reason to exist: merging on the buffer_k fastest
    paths advances the clock less per merge than waiting for the fleet."""
    base = _base_cfg(rounds=3)
    sync = async_fl.sync_limit(base)
    acfg = async_fl.AsyncFLConfig(
        base=base, n_events=9, buffer_k=4.0, fog_k=1.0, alpha=0.5
    )
    _, m_sync = async_fl.train(jax.random.key(8), params0, ae.loss, ds, sync)
    _, m_async = async_fl.train(jax.random.key(8), params0, ae.loss, ds, acfg)

    per_merge_sync = float(m_sync.t_sim[-1]) / max(
        float(jnp.sum(m_sync.merged.astype(jnp.float32))), 1.0
    )
    per_merge_async = float(m_async.t_sim[-1]) / max(
        float(jnp.sum(m_async.merged.astype(jnp.float32))), 1.0
    )
    assert per_merge_async < per_merge_sync


def test_tau_max_drops_stale_updates(ds, params0):
    """tau_max is a hard staleness clip on top of the (1+tau)^(-alpha)
    discount: updates staler than the bound get weight ZERO instead of a
    small positive one (drop vs discount, ISSUE 7)."""
    acfg = _async_cfg(n_events=10, alpha=1.0)
    key = jax.random.key(13)

    _, m_disc = async_fl.train(key, params0, ae.loss, ds, acfg)
    assert float(jnp.max(m_disc.staleness)) > 0.0   # stale arrivals exist
    # The default bound (NEVER) is bit-identical to no bound at all.
    _, m_never = async_fl.train(
        key, params0, ae.loss, ds, acfg.replace(tau_max=1e20)
    )
    np.testing.assert_array_equal(
        np.asarray(m_disc.loss), np.asarray(m_never.loss)
    )
    # tau_max=0 admits only perfectly-fresh updates; the run stays finite
    # but merges move the model differently than the discounted run.
    _, m_drop = async_fl.train(
        key, params0, ae.loss, ds, acfg.replace(tau_max=0.0)
    )
    assert bool(jnp.all(m_drop.global_finite))
    assert not np.allclose(
        np.asarray(m_drop.staleness), np.asarray(m_disc.staleness)
    ) or not np.allclose(
        np.asarray(m_drop.loss), np.asarray(m_disc.loss)
    )
    # tau_max is a swept LEAF: same treedef, stackable along a config axis.
    _, t0 = jax.tree_util.tree_flatten(acfg)
    _, t1 = jax.tree_util.tree_flatten(acfg.replace(tau_max=2.0))
    assert t0 == t1


def test_timeout_forces_merge(ds, params0):
    """A tiny global timeout merges every tick even when the buffer never
    fills."""
    acfg = _async_cfg(n_events=6, buffer_k=1e6, timeout_s=1e-3)
    _, m = async_fl.train(jax.random.key(9), params0, ae.loss, ds, acfg)
    assert bool(jnp.all(m.merged))


# ---------------------------------------------------------------------------
# Pytree / sweep contract.
# ---------------------------------------------------------------------------

def test_config_is_registered_pytree_with_static_n_events():
    a = _async_cfg(alpha=0.25, n_events=8)
    b = _async_cfg(alpha=0.75, n_events=8)
    # Same treedef (n_events is aux) -> stackable along a config axis.
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x, jnp.float32) for x in xs]), a, b
    )
    assert float(jnp.asarray(stacked.alpha)[1]) == 0.75
    # A different n_events is a different shape-class.
    c = _async_cfg(alpha=0.25, n_events=9)
    _, tc = jax.tree_util.tree_flatten(c)
    assert tc != ta


def test_engine_sweep_one_program_for_alpha_grid():
    """alpha x buffer_k cells share one treedef -> ONE compiled program,
    each cell matching its own Engine.run to float tolerance."""
    eng = eng_mod.Engine()
    base = _base_cfg(rounds=2)
    cfgs = [
        _async_cfg(base=base, n_events=6, alpha=a, buffer_k=k)
        for a in (0.0, 0.5) for k in (3.0, 6.0)
    ]
    sw = eng.sweep("hfl-async", cfgs, (0, 1), _make_ds)
    assert sw.n_classes == 1
    assert sw.compiled_programs == 1
    for i in (0, 3):
        r = eng.run("hfl-async", cfgs[i], (0, 1), _make_ds)
        np.testing.assert_allclose(
            np.asarray(sw["f1"][i]), np.asarray(r["f1"]), rtol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(sw["sim_time_s"][i]), np.asarray(r["sim_time_s"]),
            rtol=1e-5,
        )


def test_audit_family_rejects_async_config():
    eng = eng_mod.Engine()
    with pytest.raises(ValueError, match="audit"):
        eng.run("audit", _async_cfg(), (0,), _make_ds)
