import jax
import pytest

from repro.core import channel as ch
from repro.core import energy as en
from repro.core import topology as topo


@pytest.fixture(scope="session")
def cparams() -> ch.ChannelParams:
    return ch.ChannelParams()


@pytest.fixture(scope="session")
def eparams() -> en.EnergyParams:
    return en.EnergyParams()


@pytest.fixture(scope="session")
def small_deployment():
    params = topo.DeploymentParams(n_sensors=24, n_fog=5)
    dep = topo.sample_deployment(jax.random.key(7), params)
    return dep, params
