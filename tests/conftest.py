import jax
import pytest

from repro.core import channel as ch
from repro.core import energy as en
from repro.core import topology as topo


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked slow (the full local tier)",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: heavy test, skipped unless --runslow is given"
    )
    config.addinivalue_line(
        "markers", "tpu: needs a real TPU backend (skipped elsewhere)"
    )


def pytest_collection_modifyitems(config, items):
    run_slow = config.getoption("--runslow")
    skip_slow = pytest.mark.skip(reason="slow: needs --runslow")
    skip_tpu = pytest.mark.skip(
        reason=f"tpu: backend is {jax.default_backend()}"
    )
    on_tpu = jax.default_backend() == "tpu"
    for item in items:
        if "slow" in item.keywords and not run_slow:
            item.add_marker(skip_slow)
        if "tpu" in item.keywords and not on_tpu:
            item.add_marker(skip_tpu)


@pytest.fixture(scope="session")
def cparams() -> ch.ChannelParams:
    return ch.ChannelParams()


@pytest.fixture(scope="session")
def eparams() -> en.EnergyParams:
    return en.EnergyParams()


@pytest.fixture(scope="session")
def small_deployment():
    params = topo.DeploymentParams(n_sensors=24, n_fog=5)
    dep = topo.sample_deployment(jax.random.key(7), params)
    return dep, params
