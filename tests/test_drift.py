"""Tests for the dynamic-world layer (ISSUE 9 tentpole).

Covers: the ``DriftConfig`` pytree contract (traceable rate leaves,
static derived ``active`` predicate, pinning), the drift-off /
neutral-active bit-identity pins for all four round families, the
``reassoc_every=inf`` static-world no-op pin, the frozen-vs-reassoc
participation behaviour under a strong current, the one-compiled-program
drift grid under ``Engine.sweep``, the generation-time shift schedules
in ``data/synthetic``, and the serving-side drift survival pieces
(decayed reservoir + PSI signal) in ``serving/calibrate``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as eng_mod
from repro.core import async_fl, drift as drf, flat_fl, hfl
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.launch import experiment as exp
from repro.models import autoencoder as ae
from repro.serving import calibrate as cal

N_SENSORS = 12
N_FOG = 3


def _make_ds(seed: int = 0):
    cfg = SyntheticConfig(
        n_sensors=N_SENSORS, train_len=48, val_len=24, test_len=48
    )
    return normalize(generate(jax.random.key(seed), cfg))


def _small_cfg(**kw):
    kw.setdefault("rounds", 3)
    kw.setdefault("local_epochs", 1)
    return exp.make_config(n_sensors=N_SENSORS, n_fog=N_FOG, **kw)


@pytest.fixture(scope="module")
def ds():
    return _make_ds(0)


@pytest.fixture(scope="module")
def params0(ds):
    return ae.init(jax.random.key(1), ds.train.shape[-1], (16, 8, 16))


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# DriftConfig pytree contract (mirrors the FaultConfig contract).
# ---------------------------------------------------------------------------

def test_drift_config_activity_predicate_and_pinning():
    off = drf.DriftConfig()
    assert not off.is_active
    assert drf.DriftConfig(sensor_current_m_s=1.0).is_active
    assert drf.DriftConfig(covariate_shift=0.01).is_active
    # A non-unit cadence alone activates the layer (frozen association
    # is itself a dynamic-world behaviour).
    assert drf.DriftConfig(reassoc_every=4.0).is_active
    # Pinning lets a zero-rate cell share the active shape-class.
    pinned = drf.DriftConfig(active=True)
    assert pinned.is_active
    assert jax.tree_util.tree_structure(pinned) == (
        jax.tree_util.tree_structure(drf.DriftConfig(sensor_current_m_s=2.0))
    )
    assert jax.tree_util.tree_structure(off) != (
        jax.tree_util.tree_structure(pinned)
    )


def test_drift_config_roundtrip_replace_and_validation():
    on = drf.DriftConfig(sensor_current_m_s=2.0, reassoc_every=3.0)
    leaves, treedef = jax.tree_util.tree_flatten(on)
    assert all(isinstance(x, (int, float)) for x in leaves)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.is_active and back.reassoc_every == 3.0
    # replace() re-derives the predicate from the new rates...
    assert not on.replace(sensor_current_m_s=0.0, reassoc_every=1.0).is_active
    # ...unless re-pinned in the same call.
    assert drf.DriftConfig(active=True).replace(
        sensor_current_m_s=0.0, active=True
    ).is_active
    with pytest.raises(ValueError, match="sensor_current_m_s"):
        drf.DriftConfig(sensor_current_m_s=-1.0)
    with pytest.raises(ValueError, match="reassoc_every"):
        drf.DriftConfig(reassoc_every=0.5)


def test_hfl_config_carries_drift_as_swept_leaves():
    base = _small_cfg()
    a = base.replace(drift=drf.DriftConfig(sensor_current_m_s=1.0, active=True))
    b = base.replace(drift=drf.DriftConfig(sensor_current_m_s=3.0, active=True))
    _, ta = jax.tree_util.tree_flatten(a)
    _, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    stacked = eng_mod.Engine.stack_configs([a, b])
    assert np.asarray(stacked.drift.sensor_current_m_s).shape == (2,)
    assert stacked.drift.is_active


# ---------------------------------------------------------------------------
# Bit-identity pins: drift off == neutral-active == legacy, all families.
# ---------------------------------------------------------------------------

def _run_family(family, key, params0, ds, cfg):
    if family == "hfl":
        return hfl.train(key, params0, ae.loss, ds, cfg)
    if family == "flat":
        return flat_fl.train_flat(key, params0, ae.loss, ds, cfg)
    if family == "scaffold":
        return flat_fl.train_scaffold(key, params0, ae.loss, ds, cfg)
    acfg = async_fl.AsyncFLConfig(base=cfg, n_events=6)
    return async_fl.train(key, params0, ae.loss, ds, acfg)


FAMILIES = ("hfl", "flat", "scaffold", "async")


@pytest.mark.parametrize("family", FAMILIES)
def test_neutral_active_drift_is_bit_identical(family, ds, params0):
    """active=True with zero rates and unit cadence takes the drift code
    path but must reproduce the drift-off run BITWISE — params and every
    metric (the shape-class pinning correctness pin)."""
    key = jax.random.key(5)
    cfg = _small_cfg()
    p_off, m_off = _run_family(family, key, params0, ds, cfg)
    p_on, m_on = _run_family(
        family, key, params0, ds, cfg.replace(drift=drf.DriftConfig(active=True))
    )
    _assert_trees_equal(p_off, p_on)
    _assert_trees_equal(m_off, m_on)


@pytest.mark.parametrize("family", FAMILIES)
def test_drift_changes_metrics_when_on(family, ds, params0):
    key = jax.random.key(5)
    cfg = _small_cfg()
    _, m_off = _run_family(family, key, params0, ds, cfg)
    _, m_on = _run_family(
        family, key, params0, ds,
        cfg.replace(drift=drf.DriftConfig(
            sensor_current_m_s=5.0, reassoc_every=2.0
        )),
    )
    la = jax.tree_util.tree_leaves(m_off)
    lb = jax.tree_util.tree_leaves(m_on)
    assert any(
        not np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb)
    )


@pytest.mark.parametrize("family", FAMILIES)
def test_reassoc_alone_is_noop_in_static_world(family, ds, params0):
    """reassoc_every=inf freezes the round-0 association; with fog
    mobility off and zero drift rates the geometry never moves, so the
    frozen assignment equals the per-round recompute BITWISE."""
    key = jax.random.key(6)
    cfg = _small_cfg(fog_mobility=False)
    p_off, m_off = _run_family(family, key, params0, ds, cfg)
    p_frozen, m_frozen = _run_family(
        family, key, params0, ds,
        cfg.replace(drift=drf.DriftConfig(reassoc_every=float("inf"))),
    )
    _assert_trees_equal(p_off, p_frozen)
    _assert_trees_equal(m_off, m_frozen)


def test_covariate_shift_schedule_changes_training(ds, params0):
    key = jax.random.key(7)
    cfg = _small_cfg()
    _, m_off = _run_family("hfl", key, params0, ds, cfg)
    _, m_on = _run_family(
        "hfl", key, params0, ds,
        cfg.replace(drift=drf.DriftConfig(covariate_shift=0.1)),
    )
    assert not np.array_equal(np.asarray(m_off.loss), np.asarray(m_on.loss))
    # Geometry-only metrics stay identical: the shift moves data, not nodes.
    np.testing.assert_array_equal(
        np.asarray(m_off.participation), np.asarray(m_on.participation)
    )


def test_drift_rejects_client_mesh(ds):
    cfg = _small_cfg().replace(
        drift=drf.DriftConfig(sensor_current_m_s=1.0)
    )
    with pytest.raises(ValueError, match="client-sharded"):
        hfl.make_round_fn(ae.loss, ds, cfg, client_mesh=object())


# ---------------------------------------------------------------------------
# Frozen vs re-associated behaviour under a strong current.
# ---------------------------------------------------------------------------

def test_frozen_association_sheds_participation_reassoc_recovers(
    ds, params0
):
    key = jax.random.key(8)
    cfg = _small_cfg(rounds=6)
    cur = 20.0  # ~1.2 km/round in a 2 km box: stale links die fast
    _, m_static = _run_family(
        "hfl", key, params0, ds,
        cfg.replace(drift=drf.DriftConfig(active=True)),
    )
    _, m_frozen = _run_family(
        "hfl", key, params0, ds,
        cfg.replace(drift=drf.DriftConfig(
            sensor_current_m_s=cur, reassoc_every=float("inf")
        )),
    )
    _, m_reassoc = _run_family(
        "hfl", key, params0, ds,
        cfg.replace(drift=drf.DriftConfig(
            sensor_current_m_s=cur, reassoc_every=1.0
        )),
    )
    p_static = float(jnp.mean(m_static.participation))
    p_frozen = float(jnp.mean(m_frozen.participation))
    p_reassoc = float(jnp.mean(m_reassoc.participation))
    assert p_frozen < p_static            # stale assignment drops clients
    assert p_reassoc > p_frozen           # re-association recovers them
    # Round 0 always refreshes: frozen matches the fresh association there.
    np.testing.assert_array_equal(
        np.asarray(m_frozen.participation[0]),
        np.asarray(m_reassoc.participation[0]),
    )


def test_cadence_one_equals_per_round_reassociation(ds, params0):
    """reassoc_every=1 recomputes every round — bitwise the same as the
    legacy live association even while sensors drift."""
    key = jax.random.key(9)
    cfg = _small_cfg()
    drift = drf.DriftConfig(sensor_current_m_s=4.0, reassoc_every=1.0)
    p1, m1 = _run_family("hfl", key, params0, ds, cfg.replace(drift=drift))
    p2, m2 = _run_family("hfl", key, params0, ds, cfg.replace(drift=drift))
    _assert_trees_equal(p1, p2)
    _assert_trees_equal(m1, m2)


# ---------------------------------------------------------------------------
# Engine integration: drift grid is ONE compiled program.
# ---------------------------------------------------------------------------

def test_drift_grid_compiles_one_program():
    eng = eng_mod.Engine()
    base = _small_cfg()
    cfgs = [
        base.replace(drift=drf.DriftConfig(active=True)),
        base.replace(drift=drf.DriftConfig(
            sensor_current_m_s=3.0, reassoc_every=float("inf"))),
        base.replace(drift=drf.DriftConfig(
            sensor_current_m_s=3.0, reassoc_every=2.0)),
        base.replace(drift=drf.DriftConfig(
            sensor_current_m_s=1.0, covariate_shift=0.05)),
    ]
    sw = eng.sweep("hfl-selective", cfgs, (0,), _make_ds)
    assert sw.n_classes == 1
    assert sw.compiled_programs == 1
    assert not np.any(np.asarray(sw["nonfinite_rounds"]))
    # Each batched cell matches its own sequential Engine.run.
    for i in (0, 2):
        r = eng.run("hfl-selective", cfgs[i], (0,), _make_ds)
        np.testing.assert_allclose(
            np.asarray(sw["losses"][i]), np.asarray(r.losses),
            rtol=1e-4, atol=1e-6,
        )


def test_drift_on_off_are_different_shape_classes():
    eng = eng_mod.Engine()
    base = _small_cfg()
    sw = eng.sweep(
        "hfl-selective",
        [base, base.replace(drift=drf.DriftConfig(sensor_current_m_s=2.0))],
        (0,), _make_ds,
    )
    assert sw.n_classes == 2


# ---------------------------------------------------------------------------
# Generation-time shift schedules (data/synthetic).
# ---------------------------------------------------------------------------

def test_synthetic_zero_shift_is_bit_identical():
    base = SyntheticConfig(n_sensors=6, train_len=32, val_len=16, test_len=32)
    withz = SyntheticConfig(
        n_sensors=6, train_len=32, val_len=16, test_len=32,
        covariate_shift=0.0, label_shift=0.0,
    )
    a = generate(jax.random.key(3), base)
    b = generate(jax.random.key(3), withz)
    _assert_trees_equal(a, b)


def test_synthetic_covariate_shift_ramps_the_series():
    cfg = SyntheticConfig(
        n_sensors=6, train_len=64, val_len=16, test_len=32,
        covariate_shift=5.0,
    )
    base = generate(jax.random.key(4), cfg.__class__(
        n_sensors=6, train_len=64, val_len=16, test_len=32))
    shifted = generate(jax.random.key(4), cfg)
    # The ramp is monotone over the whole series: the test window sits
    # higher above its unshifted twin than the train window does.
    d_train = float(jnp.mean(shifted.train - base.train))
    d_test = float(jnp.mean(shifted.test - base.test))
    assert d_test > d_train > 0.0


def test_synthetic_label_shift_pushes_anomalies_late():
    mk = lambda ls: SyntheticConfig(  # noqa: E731
        n_sensors=16, train_len=32, val_len=16, test_len=64,
        label_shift=ls,
    )
    early = generate(jax.random.key(5), mk(0.0))
    late = generate(jax.random.key(5), mk(0.8))
    t = jnp.arange(64, dtype=jnp.float32)[None, :]

    def mean_pos(labels):
        w = labels.astype(jnp.float32)
        return float(jnp.sum(t * w) / jnp.maximum(jnp.sum(w), 1.0))

    assert mean_pos(late.test_label) > mean_pos(early.test_label)
    # All anomalous points live in the late 1 - label_shift fraction
    # (segment starts are confined there; allow segment length overhang).
    first_anom = int(jnp.argmax(jnp.any(late.test_label, axis=0)))
    assert first_anom >= int(0.8 * (64 - 64 // 3)) - 1


def test_synthetic_label_shift_validated():
    with pytest.raises(ValueError, match="label_shift"):
        SyntheticConfig(label_shift=1.0)


# ---------------------------------------------------------------------------
# Serving-side drift survival: decayed reservoir + PSI.
# ---------------------------------------------------------------------------

def test_reservoir_default_horizon_is_bit_identical_legacy():
    """horizon=None keeps the exact uniform Algorithm R draws (the
    sentinel caps nothing reachable)."""
    key = jax.random.key(10)
    errs = jax.random.uniform(jax.random.key(11), (300,))
    s_none = cal.update(cal.init(key, capacity=64), errs)
    s_sent = cal.update(
        cal.init(key, capacity=64, horizon=cal.LEGACY_HORIZON), errs
    )
    np.testing.assert_array_equal(
        np.asarray(s_none.buffer), np.asarray(s_sent.buffer)
    )
    np.testing.assert_array_equal(
        np.asarray(s_none.count), np.asarray(s_sent.count)
    )


def test_decayed_reservoir_tracks_distribution_shift():
    """After a mean shift, the finite-horizon threshold lands near the
    NEW p99 while the uniform reservoir stays anchored on history."""
    rng = np.random.default_rng(0)
    uni = cal.StreamingCalibrator(capacity=256, seed=0)
    dec = cal.StreamingCalibrator(capacity=256, seed=0, horizon=512)
    for mu in (0.0, 5.0):
        for _ in range(20):
            e = jnp.asarray(rng.normal(mu, 1.0, 128).astype(np.float32))
            uni.observe(e)
            dec.observe(e)
    new_p99 = 5.0 + 2.33
    assert abs(float(dec.global_tau) - new_p99) < (
        abs(float(uni.global_tau) - new_p99)
    )
    assert float(dec.global_tau) > 6.5


def test_psi_flags_distribution_shift():
    rng = np.random.default_rng(1)
    c = cal.StreamingCalibrator(capacity=128, seed=0, psi_window=512)
    # Before the reference window fills: no signal.
    c.observe(jnp.asarray(rng.normal(0, 1, 100).astype(np.float32)))
    assert c.psi() == 0.0
    # Stationary stream: PSI stays below the 'stable' reading.
    for _ in range(5):
        c.observe(jnp.asarray(rng.normal(0, 1, 512).astype(np.float32)))
    assert c.psi() < 0.1
    # Shifted stream: PSI crosses the 'drifted' reading.
    for _ in range(3):
        c.observe(jnp.asarray(rng.normal(3, 1, 512).astype(np.float32)))
    assert c.psi() > 0.25


def test_psi_ignores_nonfinite_errors():
    c = cal.StreamingCalibrator(capacity=64, seed=0, psi_window=32)
    c.observe(jnp.asarray([np.nan, np.inf, 1.0, 2.0], np.float32))
    assert c._recent.size == 2
