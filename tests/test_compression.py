"""Tests for the Top-K + error-feedback + int8 compression pipeline (Sec. V-C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401
from _hypothesis_compat import given, settings, st  # noqa: F401

from repro.core import compression as comp


def _rand_tree(key, scale=1.0):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (32, 16)) * scale,
        "b1": jax.random.normal(k2, (16,)) * scale,
        "w2": jax.random.normal(k3, (16, 32)) * scale,
    }


def test_payload_bits_matches_paper_example():
    """Paper Sec. V-C: d~1350, b_idx=11, rho_s=0.05 -> ~1.3 kbit payload."""
    d = 1350
    cfg = comp.CompressorConfig(rho_s=0.05, quant_bits=8)
    bits = comp.payload_bits(d, cfg)
    k = round(0.05 * d)
    assert bits == k * (8 + 11)
    assert 1200 < bits < 1400          # ~1.3 kbit
    dense = comp.payload_bits(d, comp.CompressorConfig(rho_s=1.0, quant_bits=32))
    assert dense == 32 * d             # ~43 kbit
    assert 0.025 < bits / dense < 0.035  # effective rho ~ 0.03


def test_disabled_compressor_is_identity():
    cfg = comp.CompressorConfig(rho_s=1.0, quant_bits=32)
    tree = _rand_tree(jax.random.key(0))
    err = comp.init_error(tree)
    recon, new_err = comp.compress_update(tree, err, cfg)
    for a, b in zip(jax.tree_util.tree_leaves(recon), jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(new_err), 0.0)


def test_error_feedback_invariant_sparsify_only():
    """Eq. 30 with no quantisation: recon + err' == delta + err exactly."""
    cfg = comp.CompressorConfig(rho_s=0.1, quant_bits=32)
    tree = _rand_tree(jax.random.key(1))
    err = comp.init_error(tree) + 0.05
    recon, new_err = comp.compress_update(tree, err, cfg)
    flat_recon = jax.flatten_util.ravel_pytree(recon)[0]
    flat_delta = jax.flatten_util.ravel_pytree(tree)[0]
    np.testing.assert_allclose(
        np.asarray(flat_recon + new_err),
        np.asarray(flat_delta + err),
        atol=1e-6,
    )


def test_error_feedback_absorbs_quantisation_residual():
    cfg = comp.CompressorConfig(rho_s=0.1, quant_bits=8)
    tree = _rand_tree(jax.random.key(2))
    err = comp.init_error(tree)
    recon, new_err = comp.compress_update(tree, err, cfg)
    flat_recon = jax.flatten_util.ravel_pytree(recon)[0]
    flat_delta = jax.flatten_util.ravel_pytree(tree)[0]
    np.testing.assert_allclose(
        np.asarray(flat_recon + new_err), np.asarray(flat_delta), atol=1e-5
    )


def test_topk_keeps_largest():
    v = jnp.array([0.1, -5.0, 0.3, 4.0, -0.2, 0.05])
    sparse, err = comp._global_topk_ef(v, 2)
    np.testing.assert_allclose(
        np.asarray(sparse), [0, -5.0, 0, 4.0, 0, 0], atol=1e-7
    )
    np.testing.assert_allclose(np.asarray(sparse + err), np.asarray(v), atol=1e-7)


def test_quantise_bounds_relative_error():
    x = jax.random.normal(jax.random.key(3), (512,))
    q = comp._quantize_global(x, 8)
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(q - x))) <= amax / 127.0 * 0.5 + 1e-6


def test_compression_ratio_example():
    cfg = comp.CompressorConfig(rho_s=0.05, quant_bits=8)
    rho = comp.compression_ratio(1350, cfg)
    assert 0.025 < rho < 0.035


def test_blockwise_mode_matches_ef_semantics():
    cfg = comp.CompressorConfig(rho_s=0.05, quant_bits=8, mode="blockwise")
    tree = _rand_tree(jax.random.key(4))
    err = comp.init_error(tree)
    recon, new_err = comp.compress_update(tree, err, cfg)
    flat_recon = jax.flatten_util.ravel_pytree(recon)[0]
    flat_delta = jax.flatten_util.ravel_pytree(tree)[0]
    np.testing.assert_allclose(
        np.asarray(flat_recon + new_err), np.asarray(flat_delta), atol=1e-5
    )


def test_ef_conserves_information_over_rounds():
    """Telescoping EF invariant: after T rounds of compressing the same
    update, sum(reconstructions) + final_err == T * delta exactly — no
    gradient information is ever lost (Sec. V-C / [48])."""
    cfg = comp.CompressorConfig(rho_s=0.34, quant_bits=32)
    delta = jnp.array([1.0, 0.01, 0.5])  # rho*3 ~ 1 coord per round
    err = jnp.zeros((3,))
    total_recon = jnp.zeros((3,))
    for _ in range(60):
        recon, err = comp.compress_update(delta, err, cfg)
        total_recon = total_recon + jax.flatten_util.ravel_pytree(recon)[0]
    np.testing.assert_allclose(
        np.asarray(total_recon + err), np.asarray(delta) * 60, rtol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    rho=st.sampled_from([0.02, 0.05, 0.2, 0.5]),
    bits=st.sampled_from([8, 32]),
)
def test_property_ef_invariant(seed, rho, bits):
    """recon + err' == delta + err for every (rho, bits) configuration."""
    cfg = comp.CompressorConfig(rho_s=rho, quant_bits=bits)
    key = jax.random.key(seed)
    delta = jax.random.normal(key, (257,))
    err = jax.random.normal(jax.random.fold_in(key, 1), (257,)) * 0.1
    recon, new_err = comp.compress_update(delta, err, cfg)
    flat = jax.flatten_util.ravel_pytree(recon)[0]
    np.testing.assert_allclose(
        np.asarray(flat + new_err), np.asarray(delta + err), atol=2e-5
    )


@settings(max_examples=20, deadline=None)
@given(d=st.integers(min_value=2, max_value=100_000))
def test_property_payload_monotone_in_d(d):
    cfg = comp.CompressorConfig(rho_s=0.05, quant_bits=8)
    assert comp.payload_bits(d, cfg) <= comp.payload_bits(d, comp.CompressorConfig())
    assert comp.payload_bits(d, cfg) < 32.0 * d
