"""Per-kernel Pallas (interpret=True) vs pure-jnp oracle sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

SIZES = [64, 1024, 1352, 4096, 8192 + 17, 65536]
KFRACS = [0.02, 0.05, 0.25]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("k_frac", KFRACS)
def test_topk_ef_pallas_matches_ref(n, k_frac):
    key = jax.random.key(n)
    delta = jax.random.normal(key, (n,))
    err = jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 0.1
    s_p, e_p = ops.topk_ef(delta, err, k_frac, use_pallas=True, interpret=True)
    s_r, e_r = ops.topk_ef(delta, err, k_frac, use_pallas=False)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(e_p), np.asarray(e_r), atol=1e-5)


@pytest.mark.parametrize("n", SIZES)
def test_quant8_pallas_matches_ref(n):
    x = jax.random.normal(jax.random.key(n + 1), (n,))
    q_p, s_p, _ = ops.quant8(x, use_pallas=True, interpret=True)
    q_r, s_r, _ = ops.quant8(x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(q_p), np.asarray(q_r), atol=1)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=1e-5)


@pytest.mark.parametrize("n", [1024, 4096, 8192 + 17])
@pytest.mark.parametrize("k_frac", KFRACS)
def test_fused_compress_pallas_matches_ref(n, k_frac):
    key = jax.random.key(2 * n)
    delta = jax.random.normal(key, (n,))
    err = jax.random.normal(jax.random.fold_in(key, 3), (n,)) * 0.1
    r_p, e_p, b_p = ops.compress(delta, err, k_frac, use_pallas=True)
    r_r, e_r, b_r = ops.compress(delta, err, k_frac, use_pallas=False)
    np.testing.assert_allclose(np.asarray(r_p), np.asarray(r_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(e_p), np.asarray(e_r), atol=1e-5)
    assert float(b_p) == pytest.approx(float(b_r))


def test_compress_ef_identity():
    """recon + err' == delta + err up to int8 rounding (absorbed in err')."""
    n = 4096
    delta = jax.random.normal(jax.random.key(0), (n,))
    err = jnp.zeros((n,))
    recon, new_err, _ = ops.compress(delta, err, 0.05, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(recon + new_err), np.asarray(delta), atol=1e-5
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_ef_dtypes(dtype):
    n = 2048
    delta = jax.random.normal(jax.random.key(5), (n,)).astype(dtype)
    err = jnp.zeros((n,), dtype)
    s_p, e_p = ops.topk_ef(delta, err, 0.05, use_pallas=True)
    s_r, e_r = ops.topk_ef(delta, err, 0.05, use_pallas=False)
    atol = 1e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(s_p, np.float32), np.asarray(s_r, np.float32), atol=atol
    )


def _swa_batched(q, k_cache, v_cache, cache_len, window, **kw):
    """The kernel is per-sequence (hq, d) x (s, hkv, d); batch via vmap,
    exactly how models/attention.py drives it."""
    return jax.vmap(
        lambda qq, kk, vv, ln: ops.swa_decode_attention(
            qq, kk, vv, ln, window, **kw
        )
    )(q, k_cache, v_cache, cache_len)


@pytest.mark.parametrize("heads,kv_heads,head_dim", [(8, 8, 64), (8, 2, 64), (4, 1, 128)])
@pytest.mark.parametrize("window", [64, 256])
def test_swa_decode_attention_matches_ref(heads, kv_heads, head_dim, window):
    batch, max_seq = 2, 512
    key = jax.random.key(heads * window)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (batch, heads, head_dim))
    k_cache = jax.random.normal(ks[1], (batch, max_seq, kv_heads, head_dim))
    v_cache = jax.random.normal(ks[2], (batch, max_seq, kv_heads, head_dim))
    cache_len = jnp.array([300, 77], jnp.int32)
    out_p = _swa_batched(
        q, k_cache, v_cache, cache_len, window, use_pallas=True, interpret=True
    )
    out_r = _swa_batched(
        q, k_cache, v_cache, cache_len, window, use_pallas=False
    )
    np.testing.assert_allclose(
        np.asarray(out_p), np.asarray(out_r), atol=2e-5, rtol=1e-4
    )


def test_swa_attention_respects_window():
    """Tokens outside the sliding window must not affect the output."""
    batch, heads, kv_heads, head_dim, max_seq, window = 1, 4, 4, 32, 512, 64
    key = jax.random.key(9)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (batch, heads, head_dim))
    k_cache = jax.random.normal(ks[1], (batch, max_seq, kv_heads, head_dim))
    v_cache = jax.random.normal(ks[2], (batch, max_seq, kv_heads, head_dim))
    cache_len = jnp.array([200], jnp.int32)
    out1 = _swa_batched(q, k_cache, v_cache, cache_len, window)
    # Corrupt everything outside [cache_len - window, cache_len)
    k2 = k_cache.at[:, : 200 - window].set(99.0)
    v2 = v_cache.at[:, : 200 - window].set(-99.0)
    k2 = k2.at[:, 200:].set(99.0)
    v2 = v2.at[:, 200:].set(-99.0)
    out2 = _swa_batched(q, k2, v2, cache_len, window)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)
