"""Tests for the online anomaly-scoring subsystem (repro.serving).

Covers the ISSUE-3 acceptance points: fused-vs-unfused equivalence of the
score path against the ``core/anomaly`` oracle (all-normal / all-anomalous
windows and sub-block padding included), ref-vs-Pallas(interpret) kernel
parity, streaming-vs-one-shot calibration (exact below capacity,
convergent beyond it), the micro-batching service's hot-swap with a PINNED
compile count, ``Engine.score`` trial-vmapped equivalence, and the
train->publish->serve example end to end (subprocess).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.core import anomaly
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.kernels import ops
from repro.models import autoencoder as ae
from repro.serving import ScoringService, StreamingCalibrator
from repro.serving import calibrate as cal
from repro.serving import score as serving_score_fn
from repro.serving.score import score_fleet


def _params(d=32, hidden=(16, 8, 16), seed=1):
    return ae.init(jax.random.key(seed), d, hidden)


def _oracle(params, x, tau):
    err = anomaly.reconstruction_errors(
        ae.apply, params, x.reshape(-1, x.shape[-1])
    ).reshape(x.shape[:-1])
    return err, anomaly.flag_anomalies(err, tau)


@pytest.mark.parametrize(
    "shape",
    [
        (37, 32),          # sub-block row padding (37 < SCORE_ROWS)
        (4, 48, 32),       # (fleet, window, d) telemetry batch
        (300, 32),         # multiple row tiles with a partial tail
    ],
)
def test_fused_score_matches_unfused_anomaly_oracle(shape):
    """serving.score(fused=True) == reconstruction_errors + flag_anomalies
    to float tolerance, flags exactly."""
    params = _params()
    x = jax.random.normal(jax.random.key(3), shape)
    err_o, _ = _oracle(params, x, jnp.inf)
    tau = jnp.percentile(err_o, 60.0)
    flag_o = err_o > tau
    res = serving_score_fn(params, x, tau, use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(res.error), np.asarray(err_o), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(res.flag), np.asarray(flag_o))
    assert res.error.shape == shape[:-1]


@pytest.mark.parametrize("tau,expect", [(np.inf, 0.0), (-1.0, 1.0)])
def test_all_normal_and_all_anomalous_windows(tau, expect):
    """Degenerate thresholds: tau=+inf flags nothing (all-normal), a
    negative tau flags everything (errors are squared norms >= 0)."""
    params = _params()
    x = jax.random.normal(jax.random.key(5), (3, 40, 32))
    for use_pallas in (False, True):
        res = serving_score_fn(
            params, x, tau, use_pallas=use_pallas, interpret=True
        )
        assert float(jnp.mean(res.flag.astype(jnp.float32))) == expect


@pytest.mark.parametrize(
    "r,d,hidden",
    [
        (37, 32, (16, 8, 16)),     # sub-block padding on rows AND features
        (256, 32, (16, 8, 16)),    # exact row tiles
        (130, 130, (64, 8, 64)),   # feature dim > LANES: two-lane padding
    ],
)
def test_fused_score_pallas_interpret_matches_ref(r, d, hidden):
    """The kernel body (interpret mode) must agree with the jnp oracle."""
    params = _params(d, hidden)
    x = jax.random.normal(jax.random.key(r), (r, d))
    err_r, flag_r = ops.fused_score(x, params, 1.0, use_pallas=False)
    err_p, flag_p = ops.fused_score(
        x, params, 1.0, use_pallas=True, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(err_p), np.asarray(err_r), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(flag_p), np.asarray(flag_r))


def test_score_fleet_per_fog_thresholds():
    """Per-fog taus route to each sensor's rows via fog_id."""
    params = _params()
    x = jax.random.normal(jax.random.key(11), (4, 16, 32))
    fog_id = jnp.asarray([0, 1, 0, 1])
    fog_tau = jnp.asarray([jnp.inf, -1.0])   # fog 0 never, fog 1 always
    res = score_fleet(params, x, fog_tau=fog_tau, fog_id=fog_id,
                      use_pallas=False)
    np.testing.assert_array_equal(
        np.asarray(jnp.any(res.flag, axis=1)), [False, True, False, True]
    )
    with pytest.raises(ValueError):
        score_fleet(params, x, tau=1.0, fog_tau=fog_tau, fog_id=fog_id)


def test_streaming_calibration_matches_one_shot_below_capacity():
    """While count <= capacity the reservoir holds every error, so the
    streaming tau equals jnp.percentile (Eq. 32) bit-for-bit."""
    errs = jax.random.uniform(jax.random.key(0), (500,)) * 3.0
    c = StreamingCalibrator(capacity=1024, percentile=99.0)
    for i in range(5):                      # five streaming batches
        c.observe(errs[i * 100 : (i + 1) * 100])
    np.testing.assert_allclose(
        float(c.global_tau), float(jnp.percentile(errs, 99.0)), rtol=1e-6
    )
    assert c.seen == 500


def test_streaming_calibration_per_fog_routing():
    errs = jnp.concatenate([jnp.full((50,), 1.0), jnp.full((50,), 10.0)])
    fog = jnp.concatenate([jnp.zeros((50,), jnp.int32),
                           jnp.ones((50,), jnp.int32)])
    c = StreamingCalibrator(capacity=256, n_fog=3, percentile=50.0)
    c.observe(errs, fog)
    taus = np.asarray(c.taus())
    np.testing.assert_allclose(taus[0], 1.0)
    np.testing.assert_allclose(taus[1], 10.0)
    assert np.isinf(taus[2])                # uncalibrated fog flags nothing
    np.testing.assert_allclose(float(c.global_tau), 5.5)  # median of union


def test_streaming_calibration_converges_beyond_capacity():
    """Past capacity the reservoir is a uniform sample; the streaming tau
    must converge to the one-shot percentile of the WHOLE stream."""
    big = jax.random.uniform(jax.random.key(1), (20000,))
    c = StreamingCalibrator(capacity=2048, percentile=99.0, seed=1)
    for i in range(20):
        c.observe(big[i * 1000 : (i + 1) * 1000])
    assert c.seen == 20000
    t_stream = float(c.global_tau)
    t_oneshot = float(jnp.percentile(big, 99.0))
    assert abs(t_stream - t_oneshot) / t_oneshot < 0.05


def test_reservoir_empty_state_is_inf():
    state = cal.init(jax.random.key(0), capacity=16, n_fog=2)
    assert np.all(np.isinf(np.asarray(cal.threshold(state))))


# ---------------------------------------------------------------------------
# Non-finite telemetry (graceful degradation, ISSUE 7).
# ---------------------------------------------------------------------------

def test_score_flags_nonfinite_errors_as_anomalous():
    """NaN telemetry produces a NaN reconstruction error; ``err > tau`` is
    False for NaN, so without the policy override corrupt rows would pass
    as normal.  They must flag True on both the fused and legacy paths."""
    params = _params()
    x = jax.random.normal(jax.random.key(33), (5, 32))
    x = x.at[1].set(jnp.nan).at[3, 0].set(jnp.inf)
    for fused in (True, False):
        res = serving_score_fn(
            params, x, jnp.inf, use_pallas=False, fused=fused
        )
        flag = np.asarray(res.flag)
        assert flag[1] and flag[3], f"fused={fused}"
        # Finite rows keep the tau=inf verdict: not anomalous.
        np.testing.assert_array_equal(flag[[0, 2, 4]], False)


def test_calibrator_excludes_nonfinite_errors():
    """Algorithm-R insertion skips NaN/Inf errors: they never enter a
    reservoir or advance its count, so thresholds stay finite and match
    the percentile of the finite subset (below capacity)."""
    finite = jax.random.uniform(jax.random.key(2), (80,)) * 3.0
    errs = jnp.concatenate(
        [finite[:40], jnp.asarray([jnp.nan, jnp.inf, -jnp.inf]), finite[40:]]
    )
    c = StreamingCalibrator(capacity=256, percentile=99.0)
    c.observe(errs)
    assert c.seen == 80                      # the 3 corrupt ones never count
    np.testing.assert_allclose(
        float(c.global_tau), float(jnp.percentile(finite, 99.0)), rtol=1e-6
    )
    # Per-fog routing excludes on both the global and the fog row.
    c2 = StreamingCalibrator(capacity=64, n_fog=2, percentile=50.0)
    c2.observe(
        jnp.asarray([1.0, jnp.nan, 3.0]), jnp.asarray([0, 0, 1], jnp.int32)
    )
    taus = np.asarray(c2.taus())
    np.testing.assert_allclose(taus[0], 1.0)
    np.testing.assert_allclose(taus[1], 3.0)
    np.testing.assert_allclose(taus[2], 2.0)
    np.testing.assert_array_equal(np.asarray(c2.state.count), [1, 1, 2])


def _train_tiny(store=None, rounds=3, **kw):
    from repro.core import hfl
    from repro.launch import experiment as exp

    dcfg = SyntheticConfig(n_sensors=8, train_len=48, val_len=24, test_len=48)
    ds = normalize(generate(jax.random.key(0), dcfg))
    p0 = ae.init(jax.random.key(1), ds.train.shape[-1], (16, 8, 16))
    cfg = exp.make_config(n_sensors=8, n_fog=3, rounds=rounds, local_epochs=1)
    params, metrics = hfl.train(
        jax.random.key(2), p0, ae.loss, ds, cfg, store=store, **kw
    )
    return params, metrics, p0, ds, cfg


def test_service_hot_swap_pinned_compile_count(tmp_path):
    """The acceptance pin: mixed-size requests over many micro-batches,
    a mid-stream hot-swap — exactly ONE trace of the score program."""
    store = CheckpointStore(str(tmp_path), keep=3)
    params, _, p0, ds, _ = _train_tiny(store=store)
    svc = ScoringService(store, p0, batch_rows=128, tau=1.0)
    assert svc.loaded_step == 3

    telemetry = np.asarray(ds.test[:4])                 # 192 rows > batch
    r1 = svc.submit(telemetry)
    r2 = svc.submit(np.asarray(ds.test[4, :10]))        # 10 rows
    res = svc.drain()
    assert res[r1].error.shape == (4, 48)
    assert res[r2].flag.shape == (10,)
    err_o, _ = _oracle(params, jnp.asarray(telemetry), 1.0)
    np.testing.assert_allclose(
        res[r1].error, np.asarray(err_o), rtol=1e-5, atol=1e-5
    )

    # Publish new params; the swap is double-buffered (no reload of the
    # active tree) and must not retrace.
    store.publish(9, jax.tree_util.tree_map(lambda a: a * 0.5, params))
    assert svc.poll() is True
    assert svc.loaded_step == 9
    r3 = svc.submit(telemetry)
    res2 = svc.drain()
    err_new, _ = _oracle(
        jax.tree_util.tree_map(lambda a: a * 0.5, params),
        jnp.asarray(telemetry), 1.0,
    )
    np.testing.assert_allclose(
        res2[r3].error, np.asarray(err_new), rtol=1e-5, atol=1e-5
    )
    assert svc.stats.swaps == 1
    assert svc.stats.compiles == 1, svc.stats.summary()
    assert svc.stats.samples == 2 * telemetry.size // 32 + 10
    assert svc.poll() is False                          # nothing newer


def test_service_calibrator_feed(tmp_path):
    """ingest_validation drives the streaming thresholds the service then
    scores against (per-fog routing included) — still one compile."""
    store = CheckpointStore(str(tmp_path), keep=3)
    params, _, p0, ds, cfg = _train_tiny(store=store)
    calib = StreamingCalibrator(capacity=1024, n_fog=3, percentile=99.0)
    svc = ScoringService(store, p0, batch_rows=128, calibrator=calib)
    fog_id = np.arange(8) % 3
    errs = svc.ingest_validation(np.asarray(ds.val), fog_id[:, None])
    # Calibration errors match the oracle on the served params.
    err_o, _ = _oracle(params, jnp.asarray(ds.val), np.inf)
    np.testing.assert_allclose(
        np.asarray(errs), np.asarray(err_o).reshape(-1), rtol=1e-5, atol=1e-5
    )
    # Global tau == one-shot Eq. 32 calibration (below reservoir capacity).
    np.testing.assert_allclose(
        float(calib.global_tau),
        float(anomaly.calibrate_threshold(err_o.reshape(-1), 99.0)),
        rtol=1e-5,
    )
    rid = svc.submit(np.asarray(ds.test[0]), fog=0)
    flag = svc.drain()[rid].flag
    tau0 = float(calib.fog_taus[0])
    err_t, _ = _oracle(params, jnp.asarray(ds.test[0]), tau0)
    np.testing.assert_array_equal(flag, np.asarray(err_t > tau0))
    assert svc.stats.compiles == 1


def test_engine_score_matches_oracle_and_vmaps_trials():
    from repro.engine import Engine

    params = _params()
    x = jax.random.normal(jax.random.key(21), (6, 20, 32))
    err_o, _ = _oracle(params, x, jnp.inf)
    tau = jnp.percentile(err_o, 80.0)
    eng = Engine()
    out = eng.score(params, x, tau)
    np.testing.assert_allclose(
        np.asarray(out.error), np.asarray(err_o), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(out.flag), np.asarray(err_o > tau))
    log = eng.take_log()
    assert log[-1]["kind"] == "score" and log[-1]["fresh_compile"]

    # (S, P) trial grid: distinct params per trial, shared telemetry.
    scales = jnp.asarray([[1.0, 0.5]])
    pstack = jax.tree_util.tree_map(
        lambda a: scales.reshape((1, 2) + (1,) * a.ndim) * a[None, None],
        params,
    )
    xt = jnp.broadcast_to(x, (1, 2) + x.shape)
    out2 = eng.score(pstack, xt, tau, n_trial_axes=2)
    assert out2.error.shape == (1, 2, 6, 20)
    np.testing.assert_allclose(
        np.asarray(out2.error[0, 0]), np.asarray(err_o), rtol=1e-5, atol=1e-5
    )
    half = jax.tree_util.tree_map(lambda a: 0.5 * a, params)
    err_half, _ = _oracle(half, x, tau)
    np.testing.assert_allclose(
        np.asarray(out2.error[0, 1]), np.asarray(err_half), rtol=1e-5,
        atol=1e-5,
    )


def test_engine_run_publishes_to_store(tmp_path):
    """Engine.run(store=...) publishes trial (0,0)'s trained params: the
    restored tree must score identically to the sequential train."""
    from repro.engine import Engine
    from repro.launch import experiment as exp

    dcfg = SyntheticConfig(n_sensors=8, train_len=48, val_len=24, test_len=48)
    ds = normalize(generate(jax.random.key(0), dcfg))
    cfg = exp.make_config(n_sensors=8, n_fog=3, rounds=2, local_epochs=1)
    store = CheckpointStore(str(tmp_path), keep=3)
    eng = Engine()
    run = eng.run("hfl-selective", cfg, (0,), ds, store=store)
    assert "params" not in run.metrics          # popped before EngineRun
    like = ae.init(jax.random.key(9), ds.train.shape[-1], (16, 8, 16))
    restored, step = store.latest(like)
    assert step == cfg.rounds
    # Published params reproduce the cell's own F1 under the paper protocol.
    d = ds.val.shape[-1]
    f1 = anomaly.evaluate_detector(
        ae.apply, restored, ds.val.reshape(-1, d), ds.test.reshape(-1, d),
        ds.test_label.reshape(-1),
    )
    np.testing.assert_allclose(float(f1.f1), float(run.f1[0, 0]), atol=1e-6)


def test_serve_anomaly_example_end_to_end():
    """The acceptance pin, end to end: train -> publish -> serve with a
    mid-stream hot-swap and ZERO recompiles after warmup (compiles == 1).
    Subprocess keeps the example honest as a CLI."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = (
        os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    )
    env["JAX_PLATFORMS"] = "cpu"
    script = os.path.join(
        os.path.dirname(__file__), "..", "examples", "serve_anomaly.py"
    )
    proc = subprocess.run(
        [sys.executable, script, "--rounds", "4", "--n-sensors", "8",
         "--train-len", "48", "--batch-rows", "256"],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    summary = json.loads(proc.stdout[proc.stdout.index("{"):])
    assert summary["swapped"] is True
    assert summary["service"]["swaps"] >= 1
    assert summary["service"]["compiles"] == 1      # zero recompiles pin
    assert summary["mean_abs_error_shift"] > 0.0    # params really moved
    assert summary["service"]["samples"] > 0
    assert 0.0 <= summary["f1"] <= 1.0
