"""End-to-end system behaviour tests validating the paper's structural
claims at CPU scale (small N, few rounds — directions, not magnitudes)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import compression as comp
from repro.core import hfl
from repro.core import topology as topo
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.launch import experiment as exp

N_SENSORS = 24
N_FOG = 5
ROUNDS = 4


@pytest.fixture(scope="module")
def ds():
    cfg = SyntheticConfig(
        n_sensors=N_SENSORS, train_len=64, val_len=32, test_len=64
    )
    return normalize(generate(jax.random.key(42), cfg))


@pytest.fixture(scope="module")
def cfg():
    return exp.make_config(
        n_sensors=N_SENSORS, n_fog=N_FOG, rounds=ROUNDS, local_epochs=1,
        batch_size=16,
    )


@pytest.fixture(scope="module")
def results(ds, cfg):
    out = {}
    for method in ("fedavg", "fedprox", "hfl-nocoop", "hfl-selective",
                   "hfl-nearest"):
        out[method] = exp.run_method(method, ds, cfg, seed=0)
    return out


def test_all_methods_learn(results):
    for method, r in results.items():
        assert r.losses[-1] < r.losses[0], (
            f"{method}: loss {r.losses[0]} -> {r.losses[-1]}"
        )


def test_all_methods_detect(results):
    for method, r in results.items():
        assert r.f1 > 0.3, f"{method}: F1 {r.f1}"


def test_hierarchy_preserves_participation(results):
    """Paper Fig. 5: fog-assisted participation >= direct-to-gateway."""
    for h in ("hfl-nocoop", "hfl-selective", "hfl-nearest"):
        assert results[h].participation >= results["fedavg"].participation


def test_flat_is_cheapest(results):
    """Paper design rule: flat FL defines the minimum-energy point."""
    assert results["fedavg"].e_total <= min(
        results[h].e_total
        for h in ("hfl-nocoop", "hfl-selective", "hfl-nearest")
    )


def test_energy_ordering_nocoop_selective_nearest(results):
    """Selective adds f2f energy over NoCoop but less than always-on."""
    assert results["hfl-nocoop"].e_f2f == 0.0
    assert results["hfl-selective"].e_f2f <= results["hfl-nearest"].e_f2f
    # base terms (s2f, f2g) follow the same clustering path
    assert results["hfl-selective"].e_s2f == pytest.approx(
        results["hfl-nocoop"].e_s2f, rel=0.2
    )


def test_selective_activates_fewer_links(results):
    assert results["hfl-selective"].coop_links <= results["hfl-nearest"].coop_links


def test_compression_reduces_energy(ds, cfg):
    """Paper Sec. VI-D: compressed uploads cut total energy dramatically."""
    compressed = exp.run_method("hfl-nocoop", ds, cfg, seed=0)
    dense_cfg = cfg.replace(
        compressor=comp.CompressorConfig(rho_s=1.0, quant_bits=32)
    )
    dense = exp.run_method("hfl-nocoop", ds, dense_cfg, seed=0)
    assert compressed.e_s2f < 0.3 * dense.e_s2f
    # detection quality preserved within a loose band
    assert compressed.f1 > dense.f1 - 0.25


def test_centralised_oracle_runs(ds, cfg):
    r = exp.run_method("centralised", ds, cfg, seed=0)
    assert r.f1 > 0.3
    assert r.participation == 1.0


def test_scaffold_runs(ds, cfg):
    r = exp.run_method("scaffold", ds, cfg, seed=0)
    assert jnp.isfinite(jnp.float32(r.losses[-1]))


def test_battery_depletes_monotonically(ds, cfg):
    from repro.models import autoencoder as ae
    key = jax.random.key(0)
    params = ae.init(key, ds.train.shape[-1], (16, 8, 16))
    state = hfl.init_state(key, params, cfg)
    round_fn = hfl.make_round_fn(ae.loss, ds, cfg)
    _, metrics = jax.lax.scan(round_fn, state, None, length=ROUNDS)
    assert bool(jnp.all(jnp.diff(metrics.battery_min) <= 1e-6))
    assert float(metrics.battery_min[-1]) < cfg.energy.e_init_j


def test_latency_positive(ds, cfg):
    from repro.models import autoencoder as ae
    key = jax.random.key(0)
    params = ae.init(key, ds.train.shape[-1], (16, 8, 16))
    state = hfl.init_state(key, params, cfg)
    round_fn = hfl.make_round_fn(ae.loss, ds, cfg)
    _, metrics = jax.lax.scan(round_fn, state, None, length=2)
    assert float(jnp.min(metrics.latency_s)) > 0.0


def test_seed_sweep_and_stats(ds, cfg):
    def ds_fn(seed):
        return ds  # same data; the sweep varies init/topology seeds

    rs = exp.seed_sweep("hfl-nocoop", ds_fn, cfg, seeds=(0, 1))
    assert len(rs) == 2
    mean, std = exp.mean_std([r.f1 for r in rs])
    assert 0.0 <= mean <= 1.0 and std >= 0.0


def test_fog_mobility_changes_topology(cfg):
    key = jax.random.key(0)
    dep = topo.sample_deployment(key, cfg.deployment)
    dep2 = topo.gauss_markov_step(jax.random.key(1), dep, cfg.deployment)
    assert float(jnp.max(jnp.abs(dep2.fog_pos - dep.fog_pos))) > 0.0
