"""Tests for the fused compress-and-aggregate path.

Covers the ISSUE-2 acceptance points: ref-oracle parity of the fused op
against the unfused compress -> fog_aggregate pipeline (random cluster
assignments, zero-weight non-participants, the n < BLOCK_ELEMS padding
edge), Pallas-interpret vs jnp-oracle parity, the round-loop dispatch
(fused vs ``CompressorConfig(fused=False)``), and shard_map-vs-single-
device equivalence on a forced multi-device CPU mesh (subprocess, since
XLA device flags must be set before jax initialises).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core import compression as comp
from repro.kernels import ops

N_FOG = 4


def _inputs(n_clients, d, seed=0, zero_weight_every=3):
    key = jax.random.key(seed)
    deltas = jax.random.normal(key, (n_clients, d))
    err = jax.random.normal(jax.random.fold_in(key, 1), (n_clients, d)) * 0.1
    fog_id = jax.random.randint(
        jax.random.fold_in(key, 2), (n_clients,), 0, N_FOG
    ).astype(jnp.int32)
    weights = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (n_clients,)))
    # zero-weight non-participants must not contribute to the fog sums
    weights = jnp.where(jnp.arange(n_clients) % zero_weight_every == 0, 0.0, weights)
    return deltas, err, fog_id, weights


def _unfused(deltas, err, fog_id, weights, cfg):
    recon, new_err = jax.vmap(
        lambda d_, e_: comp.compress_update(d_, e_, cfg)
    )(deltas, err)
    fog_up, fog_w = agg.fog_aggregate(recon, fog_id, weights, N_FOG)
    return fog_up, fog_w, new_err


@pytest.mark.parametrize(
    "d",
    [
        1352,        # n < BLOCK_ELEMS: single padded tile (paper autoencoder)
        8192,        # exactly one tile
        20000,       # three tiles with a partial tail
    ],
)
def test_fused_blockwise_matches_unfused_pipeline(d):
    """compress_and_aggregate == per-client compress_update + fog_aggregate
    to float tolerance on random cluster assignments."""
    deltas, err, fog_id, weights = _inputs(11, d)
    cfg = comp.CompressorConfig(rho_s=0.05, quant_bits=8, mode="blockwise")
    fog_up, fog_w, new_err = agg.compress_and_aggregate(
        deltas, err, fog_id, weights, N_FOG, cfg
    )
    ref_up, ref_w, ref_err = _unfused(
        deltas, err, fog_id, weights, cfg.replace(fused=False)
    )
    np.testing.assert_allclose(np.asarray(fog_w), np.asarray(ref_w), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(fog_up), np.asarray(ref_up), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(new_err), np.asarray(ref_err), atol=1e-6
    )


def test_fused_global_matches_unfused_pipeline():
    """mode='global' routes through the same entry point with identical
    numerics (exact global Top-K + global-scale quantisation)."""
    deltas, err, fog_id, weights = _inputs(9, 1352, seed=4)
    cfg = comp.CompressorConfig(rho_s=0.05, quant_bits=8, mode="global")
    fog_up, fog_w, new_err = agg.compress_and_aggregate(
        deltas, err, fog_id, weights, N_FOG, cfg
    )
    ref_up, ref_w, ref_err = _unfused(deltas, err, fog_id, weights, cfg)
    np.testing.assert_allclose(
        np.asarray(fog_up), np.asarray(ref_up), rtol=1e-5, atol=1e-7
    )
    np.testing.assert_allclose(np.asarray(new_err), np.asarray(ref_err), atol=1e-7)


def test_fused_topk_only_matches_unfused_pipeline():
    """quant_bits=32 (sparsify-only) dispatches without the int8 round-trip."""
    deltas, err, fog_id, weights = _inputs(7, 9000, seed=5)
    cfg = comp.CompressorConfig(rho_s=0.2, quant_bits=32, mode="blockwise")
    fog_up, _, new_err = agg.compress_and_aggregate(
        deltas, err, fog_id, weights, N_FOG, cfg
    )
    ref_up, _, ref_err = _unfused(
        deltas, err, fog_id, weights, cfg.replace(fused=False)
    )
    np.testing.assert_allclose(
        np.asarray(fog_up), np.asarray(ref_up), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(np.asarray(new_err), np.asarray(ref_err), atol=1e-6)


def test_zero_weight_clients_do_not_contribute():
    """Non-participants (weight 0) leave the fog sums unchanged but still
    get their error buffers advanced (the round loop masks those)."""
    deltas, err, fog_id, weights = _inputs(8, 1352, zero_weight_every=2)
    cfg = comp.CompressorConfig(rho_s=0.05, quant_bits=8, mode="blockwise")
    fog_up, fog_w, new_err = agg.compress_and_aggregate(
        deltas, err, fog_id, weights, N_FOG, cfg
    )
    keep = np.asarray(weights) > 0
    # removing zero-weight clients entirely gives the same aggregates
    fog_up2, fog_w2, _ = agg.compress_and_aggregate(
        deltas[keep], err[keep], fog_id[keep], weights[keep], N_FOG, cfg
    )
    np.testing.assert_allclose(np.asarray(fog_w), np.asarray(fog_w2), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(fog_up), np.asarray(fog_up2), rtol=1e-5, atol=1e-6
    )
    # but the EF buffers of zero-weight clients still advanced
    assert not np.allclose(np.asarray(new_err[~keep]), np.asarray(err[~keep]))


def test_empty_fog_gets_zero_update():
    deltas, err, _, weights = _inputs(6, 1352)
    fog_id = jnp.zeros((6,), jnp.int32)  # everyone in cluster 0
    cfg = comp.CompressorConfig(rho_s=0.05, quant_bits=8, mode="blockwise")
    fog_up, fog_w, _ = agg.compress_and_aggregate(
        deltas, err, fog_id, jnp.abs(weights) + 0.1, N_FOG, cfg
    )
    np.testing.assert_array_equal(np.asarray(fog_w[1:]), 0.0)
    np.testing.assert_array_equal(np.asarray(fog_up[1:]), 0.0)


@pytest.mark.parametrize("d", [1352, 8192 + 17, 65536])
@pytest.mark.parametrize("quantize", [True, False])
def test_pallas_interpret_matches_ref(d, quantize):
    """The fused kernel body (interpret mode) must agree with the jnp
    oracle — same bisection threshold and int8 rules."""
    deltas, err, fog_id, weights = _inputs(6, d, seed=d)
    fs_r, ne_r = ops.compress_aggregate(
        deltas, err, fog_id, weights, N_FOG, 0.05, quantize=quantize,
        use_pallas=False,
    )
    fs_p, ne_p = ops.compress_aggregate(
        deltas, err, fog_id, weights, N_FOG, 0.05, quantize=quantize,
        use_pallas=True, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(fs_p), np.asarray(fs_r), rtol=1e-5, atol=1e-4
    )
    np.testing.assert_allclose(np.asarray(ne_p), np.asarray(ne_r), atol=1e-5)


def test_round_loop_fused_matches_unfused():
    """End-to-end: hfl.train with the fused default == the legacy
    per-client pipeline (CompressorConfig(fused=False))."""
    from repro.data.synthetic import SyntheticConfig, generate, normalize
    from repro.launch import experiment as exp
    from repro.models import autoencoder as ae
    from repro.core import hfl

    dcfg = SyntheticConfig(n_sensors=10, train_len=48, val_len=24, test_len=48)
    ds = normalize(generate(jax.random.key(0), dcfg))
    params0 = ae.init(jax.random.key(1), ds.train.shape[-1], (16, 8, 16))
    cc = comp.CompressorConfig(rho_s=0.05, quant_bits=8, mode="blockwise")
    cfg = exp.make_config(n_sensors=10, n_fog=3, rounds=2, local_epochs=1,
                          compressor=cc)
    p1, m1 = hfl.train(jax.random.key(2), params0, ae.loss, ds, cfg)
    p2, m2 = hfl.train(
        jax.random.key(2), params0, ae.loss, ds,
        cfg.replace(compressor=cc.replace(fused=False)),
    )
    np.testing.assert_allclose(
        np.asarray(m1.loss), np.asarray(m2.loss), rtol=1e-5
    )
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


_SHMAP_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import hfl, flat_fl
    from repro.data.synthetic import SyntheticConfig, generate, normalize
    from repro.launch import experiment as exp
    from repro.launch import sharding
    from repro.models import autoencoder as ae
    from repro import engine as eng_mod

    assert len(jax.devices()) == 4, jax.devices()
    mesh = sharding.client_mesh()
    assert mesh.axis_names == ("data",) and mesh.size == 4

    cfg = exp.make_config(n_sensors=8, n_fog=3, rounds=2, local_epochs=1)
    dcfg = SyntheticConfig(n_sensors=8, train_len=48, val_len=24, test_len=48)
    ds = normalize(generate(jax.random.key(0), dcfg))
    params0 = ae.init(jax.random.key(1), ds.train.shape[-1], (16, 8, 16))

    for fn in (hfl.train, flat_fl.train_flat):
        p1, m1 = jax.jit(lambda: fn(jax.random.key(2), params0, ae.loss, ds, cfg))()
        p2, m2 = jax.jit(
            lambda: fn(jax.random.key(2), params0, ae.loss, ds, cfg,
                       client_mesh=mesh)
        )()
        np.testing.assert_allclose(
            np.asarray(m1.loss), np.asarray(m2.loss), rtol=1e-4
        )
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    # engine opt-in: shard_clients cells must match default placement
    def make_ds(seed):
        return normalize(generate(jax.random.key(seed), dcfg))

    r1 = eng_mod.Engine().run("hfl-selective", cfg, (0, 1), make_ds)
    sh_eng = eng_mod.Engine(shard_clients=True)
    r2 = sh_eng.run("hfl-selective", cfg, (0, 1), make_ds)
    assert sh_eng.take_log()[0]["client_sharded"] is True
    np.testing.assert_allclose(
        np.asarray(r1.losses), np.asarray(r2.losses), rtol=1e-4
    )
    np.testing.assert_allclose(np.asarray(r1.f1), np.asarray(r2.f1), atol=1e-6)
    print("SHARD_MAP_EQUIVALENCE_OK")
""")


def test_shard_map_matches_single_device():
    """Client-sharded round loop == single-device, on a forced 4-device
    CPU mesh.  Runs in a subprocess because the XLA device-count flag must
    be set before jax initialises."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 "
        + env.get("XLA_FLAGS", "")
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SHMAP_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "SHARD_MAP_EQUIVALENCE_OK" in proc.stdout
