"""Tests for serving-under-load upgrades (ISSUE 8 satellites + tentpole).

Deadline-driven partial flush, shape-bucket selection at the boundaries,
wall-clock checkpoint polling on an idle service, int8-quantised serving
weights (parity against f32 — flags identical at the calibrated tau on
the quick-tier dataset, Pallas-interpret vs oracle agreement), the
multi-tenant pin (one compiled program per bucket TOTAL, per-tenant
hot-swap round-tripping from a real ``hfl.train(store=...)`` publish),
and randomized submit/step/tick/drain interleavings where every request
must complete exactly once with its leading shape restored.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.core import anomaly
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.kernels import ops
from repro.loadgen import VirtualClock
from repro.models import autoencoder as ae
from repro.serving import (
    MultiTenantService,
    ScoringService,
    dequantize_params,
    quantize_params,
    score,
    score_q8,
)
from repro.serving.service import ScorePrograms
from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

D = 12


def _params(seed=0, d=D, hidden=(8, 4, 8)):
    return ae.init(jax.random.key(seed), d, hidden)


def _store(path, params, step=1):
    store = CheckpointStore(str(path))
    store.publish(step, params)
    return store


def _svc(path, clock, params=None, **kw):
    params = _params() if params is None else params
    store = _store(path, params)
    kw.setdefault("tau", 1.0)
    return ScoringService(store, params, clock=clock, **kw)


def _rows(n, seed=0, d=D):
    return np.random.default_rng(seed).standard_normal((n, d)).astype(np.float32)


# ---------------------------------------------------------------------------
# deadline-driven partial flush
# ---------------------------------------------------------------------------

def test_partial_batch_flushes_at_deadline(tmp_path):
    clock = VirtualClock()
    svc = _svc(tmp_path, clock, buckets=(64,), max_wait_s=0.5)
    rid = svc.submit(_rows(10))
    assert svc.pending_rows() == 10
    assert not svc.should_flush()              # neither full nor expired
    assert svc.pump() == 0
    assert svc.next_deadline() == pytest.approx(0.5)
    clock.advance_to(0.49)
    assert svc.tick() == 0                     # still inside the window
    clock.advance_to(0.5)
    assert svc.should_flush()
    assert svc.pump() == 10                    # partial batch went out
    assert svc.stats.partial_flushes == 1
    res = svc.drain()
    assert res[rid].error.shape == (10,)
    # e2e latency = wait-to-deadline + device time: at least the wait.
    assert svc.stats.e2e_latency_s[0] >= 0.5


def test_no_deadline_means_legacy_flush_semantics(tmp_path):
    clock = VirtualClock()
    svc = _svc(tmp_path, clock, buckets=(64,))   # max_wait_s=None
    svc.submit(_rows(10))
    clock.advance(1e6)
    assert svc.next_deadline() is None
    assert not svc.should_flush()
    assert svc.pump() == 0                     # only drain() forces it
    assert len(svc.drain()) == 1


def test_full_bucket_flushes_without_deadline(tmp_path):
    clock = VirtualClock()
    svc = _svc(tmp_path, clock, buckets=(8, 64), max_wait_s=100.0)
    svc.submit(_rows(64))
    assert svc.should_flush()                  # full largest bucket
    assert svc.pump() == 64
    assert svc.stats.partial_flushes == 0


# ---------------------------------------------------------------------------
# shape buckets
# ---------------------------------------------------------------------------

def test_bucket_selection_boundaries(tmp_path):
    clock = VirtualClock()
    svc = _svc(tmp_path, clock, buckets=(128, 1024), max_wait_s=1.0)

    svc.submit(_rows(128))                     # exactly the small bucket
    clock.advance(1.0)
    svc.pump()
    assert svc.stats.compiles_by_bucket == {128: 1}
    assert svc.stats.partial_flushes == 0      # 128 rows fill bucket 128

    svc.submit(_rows(129))                     # one over: big bucket
    clock.advance(1.0)
    svc.pump()
    assert svc.stats.compiles_by_bucket == {128: 1, 1024: 1}
    assert svc.stats.partial_flushes == 1      # 129 rows pad into 1024

    steps = svc.stats.steps
    svc.submit(_rows(1500))                    # over the largest bucket
    clock.advance(1.0)
    svc.pump()
    # 1500 rows = one full 1024 batch + a 476-row partial (the remainder
    # exceeds the 128 bucket, so it pads into 1024) — and REUSING buckets
    # never retraces: the per-bucket compile counts are unchanged.
    assert svc.stats.steps == steps + 2
    assert svc.stats.partial_flushes == 2
    assert svc.stats.compiles_by_bucket == {128: 1, 1024: 1}
    assert svc.pending_rows() == 0
    assert len(svc.drain()) == 3


def test_buckets_sorted_deduped_and_validated(tmp_path):
    clock = VirtualClock()
    svc = _svc(tmp_path, clock, buckets=(256, 64, 256))
    assert svc.buckets == (64, 256)
    assert svc.batch_rows == 256
    with pytest.raises(ValueError):
        _svc(tmp_path / "bad", clock, buckets=(0, 64))


def test_single_bucket_back_compat_batch_rows(tmp_path):
    clock = VirtualClock()
    svc = _svc(tmp_path, clock, batch_rows=96)
    assert svc.buckets == (96,)
    rid = svc.submit(_rows(200))
    res = svc.drain()
    assert res[rid].error.shape == (200,)
    assert svc.stats.compiles_by_bucket == {96: 1}
    assert svc.stats.compiles == 1             # legacy pin still holds


# ---------------------------------------------------------------------------
# wall-clock checkpoint polling (idle hot-swap)
# ---------------------------------------------------------------------------

def test_idle_service_hot_swaps_on_poll_interval(tmp_path):
    params = _params()
    clock = VirtualClock()
    store = _store(tmp_path, params)
    svc = ScoringService(
        store, params, tau=1.0, clock=clock,
        poll_every=10**9, poll_interval_s=5.0,
    )
    store.publish(2, jax.tree_util.tree_map(lambda a: a * 0.5, params))
    clock.advance(4.9)
    svc.tick()
    assert svc.loaded_step == 1                # interval not reached
    clock.advance(0.2)
    svc.tick()                                 # NO scoring steps ran
    assert svc.loaded_step == 2
    assert svc.stats.swaps == 1


def test_submit_also_triggers_interval_poll(tmp_path):
    params = _params()
    clock = VirtualClock()
    store = _store(tmp_path, params)
    svc = ScoringService(
        store, params, tau=1.0, clock=clock,
        poll_every=10**9, poll_interval_s=1.0,
    )
    store.publish(3, params)
    clock.advance(1.5)
    svc.submit(_rows(4))
    assert svc.loaded_step == 3


# ---------------------------------------------------------------------------
# honest stats naming + e2e latency in summary()
# ---------------------------------------------------------------------------

def test_summary_reports_step_and_e2e_latency_separately(tmp_path):
    clock = VirtualClock()
    svc = _svc(tmp_path, clock, buckets=(32,), max_wait_s=2.0)
    svc.submit(_rows(8))
    clock.advance(2.0)
    svc.pump()
    s = svc.stats.summary()
    for key in ("step_p50_ms", "step_p99_ms", "e2e_p50_ms", "e2e_p99_ms",
                "partial_flushes", "compiles_by_bucket"):
        assert key in s, key
    # The old keys misreported device-step time as request latency.
    assert "p50_ms" not in s and "p99_ms" not in s
    # e2e includes the 2s queue wait; the device step does not.
    assert s["e2e_p50_ms"] >= 2000.0
    assert s["step_p50_ms"] < 2000.0


# ---------------------------------------------------------------------------
# int8 serving weights
# ---------------------------------------------------------------------------

def test_int8_off_by_default(tmp_path):
    svc = _svc(tmp_path, VirtualClock())
    assert svc.programs.weight_dtype == "f32"
    assert "qw" not in svc.params[0] and "w" in svc.params[0]


def test_quantize_dequantize_roundtrip_error_bounded():
    params = _params(seed=2, d=32, hidden=(16, 8, 16))
    deq = dequantize_params(quantize_params(params))
    for layer, dlayer in zip(params, deq):
        w = np.asarray(layer["w"])
        err = np.abs(np.asarray(dlayer["w"]) - w)
        # Symmetric per-column int8: error <= half a quantisation step.
        step = np.abs(w).max(axis=0, keepdims=True) / 127.0
        assert np.all(err <= 0.5 * step + 1e-7)
        np.testing.assert_array_equal(dlayer["b"], layer["b"])


def test_score_q8_fused_matches_dequantized_unfused():
    params = _params(seed=3, d=32, hidden=(16, 8, 16))
    qp = quantize_params(params)
    x = jnp.asarray(_rows(300, seed=3, d=32))
    fused = score_q8(qp, x, 1.0, use_pallas=False, fused=True)
    legacy = score_q8(qp, x, 1.0, use_pallas=False, fused=False)
    np.testing.assert_allclose(
        np.asarray(fused.error), np.asarray(legacy.error),
        rtol=1e-5, atol=1e-5,
    )
    np.testing.assert_array_equal(
        np.asarray(fused.flag), np.asarray(legacy.flag)
    )


@pytest.mark.parametrize(
    "r,d,hidden",
    [
        (37, 32, (16, 8, 16)),     # sub-block padding on rows AND features
        (256, 32, (16, 8, 16)),    # exact row tiles
        (130, 130, (64, 8, 64)),   # feature dim > LANES: two-lane padding
    ],
)
def test_fused_score_q8_pallas_interpret_matches_ref(r, d, hidden):
    params = _params(seed=r, d=d, hidden=hidden)
    qp = quantize_params(params)
    x = jax.random.normal(jax.random.key(r), (r, d))
    err_r, flag_r = ops.fused_score_q8(x, qp, 1.0, use_pallas=False)
    err_p, flag_p = ops.fused_score_q8(
        x, qp, 1.0, use_pallas=True, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(err_p), np.asarray(err_r), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(flag_p), np.asarray(flag_r))


def test_score_q8_flags_nonfinite_as_anomalous():
    qp = quantize_params(_params())
    x = np.zeros((4, D), np.float32)
    x[2] = np.nan
    res = score_q8(qp, jnp.asarray(x), jnp.inf, use_pallas=False)
    assert np.asarray(res.flag)[2]
    np.testing.assert_array_equal(np.asarray(res.flag)[[0, 1, 3]], False)


def _train_tiny(store=None, rounds=3, **kw):
    from repro.core import hfl
    from repro.launch import experiment as exp

    dcfg = SyntheticConfig(n_sensors=8, train_len=48, val_len=24, test_len=48)
    ds = normalize(generate(jax.random.key(0), dcfg))
    p0 = ae.init(jax.random.key(1), ds.train.shape[-1], (16, 8, 16))
    cfg = exp.make_config(n_sensors=8, n_fog=3, rounds=rounds, local_epochs=1)
    params, metrics = hfl.train(
        jax.random.key(2), p0, ae.loss, ds, cfg, store=store, **kw
    )
    return params, metrics, p0, ds, cfg


def test_int8_flags_identical_to_f32_at_calibrated_tau():
    """The acceptance criterion: on the quick-tier dataset with TRAINED
    params and the Eq. 32 calibrated tau, int8 serving must flag exactly
    the same windows as f32 (the quantisation shift stays inside the
    threshold margin)."""
    params, _, _, ds, _ = _train_tiny()
    d = ds.val.shape[-1]
    val = jnp.asarray(ds.val).reshape(-1, d)
    test = jnp.asarray(ds.test).reshape(-1, d)
    err_val = anomaly.reconstruction_errors(ae.apply, params, val)
    tau = anomaly.calibrate_threshold(err_val, 99.0)
    r32 = score(params, test, tau, use_pallas=False)
    r8 = score_q8(quantize_params(params), test, tau, use_pallas=False)
    np.testing.assert_array_equal(
        np.asarray(r8.flag), np.asarray(r32.flag)
    )
    # Errors shift by at most the int8 tolerance, and both verdict sets
    # are non-trivial (some anomalies flagged, not all).
    rel = np.abs(np.asarray(r8.error) - np.asarray(r32.error))
    rel /= np.abs(np.asarray(r32.error)) + 1e-9
    assert rel.max() < 0.05
    n_flag = int(np.asarray(r32.flag).sum())
    assert 0 < n_flag < test.shape[0]


def test_int8_service_end_to_end_matches_f32_service(tmp_path):
    params, _, p0, ds, _ = _train_tiny(
        store=CheckpointStore(str(tmp_path / "a"))
    )
    clock32, clock8 = VirtualClock(), VirtualClock()
    store_a = CheckpointStore(str(tmp_path / "a"))
    svc32 = ScoringService(store_a, p0, tau=1.0, batch_rows=128, clock=clock32)
    svc8 = ScoringService(
        store_a, p0, tau=1.0, batch_rows=128, clock=clock8,
        weight_dtype="int8",
    )
    telemetry = np.asarray(ds.test[:4])
    rid32 = svc32.submit(telemetry)
    rid8 = svc8.submit(telemetry)
    e32 = svc32.drain()[rid32]
    e8 = svc8.drain()[rid8]
    np.testing.assert_allclose(e8.error, e32.error, rtol=0.05, atol=1e-4)
    assert e8.error.shape == e32.error.shape == (4, 48)


def test_programs_weight_dtype_mismatch_rejected(tmp_path):
    params = _params()
    store = _store(tmp_path, params)
    programs = ScorePrograms(weight_dtype="int8", use_pallas=False)
    with pytest.raises(ValueError, match="int8"):
        ScoringService(store, params, tau=1.0, programs=programs)
    with pytest.raises(ValueError):
        ScorePrograms(weight_dtype="fp4")


# ---------------------------------------------------------------------------
# multi-tenant serving
# ---------------------------------------------------------------------------

def test_multi_tenant_shares_programs_and_isolates_swaps(tmp_path):
    """The acceptance pin: N tenants, real train->publish stores, one
    compiled program per bucket TOTAL; hot-swap stays per-tenant."""
    store_a = CheckpointStore(str(tmp_path / "a"), keep=3)
    params_a, _, p0, ds, _ = _train_tiny(store=store_a)
    store_b = CheckpointStore(str(tmp_path / "b"), keep=3)
    params_b, _, _, _, _ = _train_tiny(store=store_b, rounds=2)

    clock = VirtualClock()
    mt = MultiTenantService(
        p0, buckets=(64, 256), max_wait_s=0.05, clock=clock, use_pallas=False
    )
    svc_a = mt.add_tenant("a", store_a, tau=1.0)
    svc_b = mt.add_tenant("b", store_b, tau=1.0)
    assert svc_a.loaded_step == 3 and svc_b.loaded_step == 2
    assert mt.tenants == ("a", "b")
    with pytest.raises(ValueError):
        mt.add_tenant("a", store_b, tau=1.0)

    # Interleaved submits; batches never mix tenants, so each result must
    # match ITS tenant's params oracle.
    telemetry = np.asarray(ds.test[:4])        # (4, 48, d): 192 rows
    keys = [mt.submit("a", telemetry), mt.submit("b", telemetry),
            mt.submit("a", telemetry[0])]
    clock.advance(1.0)
    mt.pump()
    res = mt.drain()
    assert set(res) == set(keys)

    def oracle(params):
        err = anomaly.reconstruction_errors(
            ae.apply, params, jnp.asarray(telemetry).reshape(-1, ds.val.shape[-1])
        ).reshape(4, 48)
        return np.asarray(err)

    np.testing.assert_allclose(
        res[("a", keys[0][1])].error, oracle(params_a), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        res[("b", keys[1][1])].error, oracle(params_b), rtol=1e-5, atol=1e-5
    )

    # ONE compiled program per bucket, not per tenant.
    used = mt.compiles_by_bucket
    assert used and all(v == 1 for v in used.values()), used
    # Every tenant's stats view IS the shared per-bucket counter.
    assert svc_a.stats.compiles == svc_b.stats.compiles == sum(used.values())

    # Per-tenant hot-swap: publish a new round for tenant b only.
    store_b.publish(9, jax.tree_util.tree_map(lambda a: a * 0.5, params_b))
    swapped = mt.poll()
    assert swapped == {"a": False, "b": True}
    assert svc_b.loaded_step == 9 and svc_a.loaded_step == 3
    # Swap reuses the compiled programs: still one per bucket.
    k = mt.submit("b", telemetry[0])
    clock.advance(1.0)
    mt.pump()
    res2 = mt.drain()
    assert all(v == 1 for v in mt.compiles_by_bucket.values())
    half_err = anomaly.reconstruction_errors(
        ae.apply, jax.tree_util.tree_map(lambda a: 0.5 * a, params_b),
        jnp.asarray(telemetry[0]),
    )
    np.testing.assert_allclose(
        res2[k].error, np.asarray(half_err), rtol=1e-5, atol=1e-5
    )

    summ = mt.summary()
    # The 48-row submit above used the 64 bucket for the first time; the
    # invariant is one trace per bucket EVER, not a frozen bucket set.
    final = mt.compiles_by_bucket
    assert all(v == 1 for v in final.values()), final
    assert summ["compiles"] == sum(final.values())
    assert summ["requests"] == 4
    assert set(summ["tenants"]) == {"a", "b"}


def test_multi_tenant_deadline_fairness(tmp_path):
    """A quiet tenant's expired deadline flushes even while a chatty
    tenant keeps a deeper (but younger) queue."""
    params = _params()
    clock = VirtualClock()
    mt = MultiTenantService(
        params, buckets=(256,), max_wait_s=0.1, clock=clock, use_pallas=False
    )
    mt.add_tenant("quiet", _store(tmp_path / "q", params), tau=1.0)
    mt.add_tenant("chatty", _store(tmp_path / "c", params), tau=1.0)
    # Warm the 256 program first: its COMPILE time would otherwise advance
    # the virtual clock far past every deadline on the first flush.
    mt.submit("quiet", _rows(256))
    assert mt.pump() == 256
    mt.drain()

    mt.submit("quiet", _rows(4))
    clock.advance(0.09)
    t_chatty = clock()
    mt.submit("chatty", _rows(100, seed=1))
    clock.advance(0.02)                        # quiet expired, chatty not
    assert mt.tenant("quiet").should_flush()
    assert not mt.tenant("chatty").should_flush()
    mt.pump()
    assert mt.tenant("quiet").pending_rows() == 0
    assert mt.tenant("chatty").pending_rows() == 100
    assert mt.next_deadline() == pytest.approx(t_chatty + 0.1)
    assert mt.tenant("quiet").stats.e2e_latency_s[-1] >= 0.11 - 1e-9


# ---------------------------------------------------------------------------
# randomized interleavings: every request completes exactly once
# ---------------------------------------------------------------------------

def _run_interleaving(tmp_path, ops_seq, buckets=(16, 64), max_wait_s=0.05):
    clock = VirtualClock()
    svc = _svc(tmp_path, clock, buckets=buckets, max_wait_s=max_wait_s)
    expected: dict[int, tuple] = {}
    results: dict[int, object] = {}
    for op, arg in ops_seq:
        if op == "submit":
            lead, seed = arg
            n = int(np.prod(lead))
            x = _rows(n, seed=seed).reshape(*lead, D)
            expected[svc.submit(x)] = tuple(lead)
        elif op == "advance":
            clock.advance(arg)
        elif op == "step":
            svc.step()
        elif op == "tick":
            svc.tick()
        elif op == "pump":
            svc.pump()
        elif op == "drain":
            results.update(svc.drain())
    results.update(svc.drain())
    return svc, expected, results


def _check_interleaving(svc, expected, results):
    assert set(results) == set(expected), "every request completes exactly once"
    for rid, lead in expected.items():
        assert results[rid].error.shape == lead, (rid, lead)
        assert results[rid].flag.shape == lead
    assert svc.pending_rows() == 0
    assert len(svc.stats.e2e_latency_s) == len(expected)


LEADS = ((3,), (17,), (2, 5), (40,), (1, 1, 4), (70,))


def test_random_interleavings_seeded(tmp_path):
    """Seeded generator variant that always runs (hypothesis is optional
    in this container): random op soups, exact completion accounting."""
    rng = np.random.default_rng(0)
    for case in range(8):
        ops_seq = []
        for i in range(rng.integers(1, 25)):
            k = rng.integers(0, 6)
            if k <= 2:
                ops_seq.append(
                    ("submit", (LEADS[rng.integers(len(LEADS))], int(i)))
                )
            elif k == 3:
                ops_seq.append(("advance", float(rng.uniform(0, 0.1))))
            else:
                ops_seq.append(
                    (("step", "tick", "pump", "drain")[rng.integers(4)], None)
                )
        svc, expected, results = _run_interleaving(
            tmp_path / f"case{case}", ops_seq
        )
        _check_interleaving(svc, expected, results)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="needs hypothesis")
@settings(max_examples=25, deadline=None)
@given(
    st.lists(
        st.one_of(
            st.tuples(
                st.just("submit"),
                st.tuples(st.sampled_from(LEADS), st.integers(0, 99)),
            ),
            st.tuples(st.just("advance"), st.floats(0.0, 0.2)),
            st.tuples(st.sampled_from(("step", "tick", "pump", "drain")),
                      st.none()),
        ),
        max_size=30,
    )
)
def test_random_interleavings_property(tmp_path_factory, ops_seq):
    svc, expected, results = _run_interleaving(
        tmp_path_factory.mktemp("interleave"), ops_seq
    )
    _check_interleaving(svc, expected, results)


# ---------------------------------------------------------------------------
# max_queue admission control (ISSUE 9 satellite): overload sheds load at
# the door instead of growing the deque without bound.
# ---------------------------------------------------------------------------

def test_max_queue_validated(tmp_path):
    with pytest.raises(ValueError, match="max_queue"):
        _svc(tmp_path, VirtualClock(), max_queue=0)


def test_submit_drops_over_cap_and_counts(tmp_path):
    clock = VirtualClock()
    svc = _svc(tmp_path, clock, buckets=(64,), max_queue=2)
    r1 = svc.submit(_rows(4))
    r2 = svc.submit(_rows(4))
    assert r1 is not None and r2 is not None
    # Queue full, nothing flushable (bucket 64, no deadline): reject.
    assert svc.submit(_rows(4)) is None
    assert svc.submit(_rows(4)) is None
    assert svc.stats.dropped == 2
    assert svc.stats.requests == 2             # rejected != admitted
    assert svc.pending_rows() == 8             # queue unchanged by drops
    # Admitted requests still complete exactly once.
    res = svc.drain()
    assert sorted(res) == [r1, r2]
    # ...and a post-flush submit is admitted again.
    assert svc.submit(_rows(4)) is not None
    assert svc.stats.summary()["dropped"] == 2


def test_no_cap_keeps_legacy_unbounded_queue(tmp_path):
    clock = VirtualClock()
    svc = _svc(tmp_path, clock, buckets=(64,))
    rids = [svc.submit(_rows(1)) for _ in range(50)]
    assert all(r is not None for r in rids)
    assert svc.stats.dropped == 0


def test_loadgen_overload_trace_sheds_and_completes(tmp_path):
    """An overload trace against a capped service: drops happen, the
    queue stays bounded, every ADMITTED request completes exactly once,
    and the replay report only counts completions."""
    from repro.loadgen import harness, poisson_trace

    clock = VirtualClock()
    cap = 4
    # Bucket far above what the trace delivers and a deadline beyond its
    # horizon: nothing flushes mid-trace, so the queue fills to the cap
    # and every later arrival is shed at the door.
    svc = _svc(
        tmp_path, clock, buckets=(512,), max_wait_s=100.0, max_queue=cap,
    )
    trace = poisson_trace(
        0, rate_hz=20.0, duration_s=4.0, fleet=8, n_fog=2, rows=4
    )
    report = harness.replay(svc, trace, clock, d=D)
    assert svc.stats.requests == cap
    assert svc.stats.dropped == trace.n_events - cap
    assert report.completed == svc.stats.requests
    assert len(svc.drain()) == 0               # nothing stranded
    assert svc.pending_rows() == 0
