"""Tests for logical-axis -> mesh-axis resolution (launch/sharding.py).

These run on a fake Mesh built from a 1-device CPU backend via
jax.sharding.Mesh over a reshaped device array is impossible here, so we
exercise resolve_spec through a lightweight stand-in mesh object with the
production shapes (the function only reads .shape and .axis_names).
"""
import jax
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as sh


class FakeMesh:
    def __init__(self, shape_map):
        self.shape = dict(shape_map)
        self.axis_names = tuple(shape_map)


POD_MESH = FakeMesh({"data": 16, "model": 16})
MULTI_MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_ff_gets_model_axis():
    spec = sh.resolve_spec(("embed", "ff"), (4096, 14336), POD_MESH)
    assert spec == P("data", "model")  # embed FSDP fallback + ff model


def test_batch_gets_data_axis():
    spec = sh.resolve_spec(("batch", None), (256, 4096), POD_MESH)
    assert spec == P("data", None)


def test_batch_gets_pod_and_data_on_multipod():
    spec = sh.resolve_spec(("batch", None), (256, 4096), MULTI_MESH)
    assert spec == P(("pod", "data"), None)


def test_indivisible_dim_not_sharded():
    # 40 heads % 16 != 0 -> heads cannot take the model axis; head_dim 128 can.
    spec = sh.resolve_spec(
        ("embed", "heads", "head_dim"), (5120, 40, 128), POD_MESH
    )
    assert spec[1] is None
    assert spec[2] == "model"


def test_mesh_axis_used_at_most_once():
    spec = sh.resolve_spec(("ff", "vocab"), (65536, 65536), POD_MESH)
    axes = [s for s in spec if s is not None]
    assert len(axes) == len(set(axes))
    assert "model" in axes


def test_priority_prefers_ff_over_vocab():
    spec = sh.resolve_spec(("vocab", "ff"), (151936, 17408), POD_MESH)
    assert spec == P(None, "model") or spec == P("data", "model")
    assert spec[1] == "model"


def test_none_logical_is_replicated():
    assert sh.resolve_spec(None, (7, 3), POD_MESH) == P()


def test_parameters_never_take_pod_axis():
    """Params are replicated across pods (pure DP over `pod`)."""
    for logical, shape in [
        (("embed", "ff"), (4096, 14336)),
        (("vocab", "embed"), (128256, 4096)),
        (("kv_heads", "head_dim"), (8, 128)),
    ]:
        spec = sh.resolve_spec(logical, shape, MULTI_MESH)
        flat = [a for s in spec if s is not None for a in (s if isinstance(s, tuple) else (s,))]
        assert "pod" not in flat, (logical, spec)


def test_experts_shardable():
    spec = sh.resolve_spec(("experts", "embed", "ff"), (64, 2048, 1408), POD_MESH)
    # ff=1408=16*88 divisible -> model on ff; experts stays unsharded then.
    assert spec[2] == "model" or spec[0] == "model"


def test_batch_shardings_on_real_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = {"tokens": jax.ShapeDtypeStruct((8, 128), jax.numpy.int32)}
    out = sh.batch_shardings(specs, mesh)
    assert out["tokens"].spec == P("data", None)
