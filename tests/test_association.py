"""Tests for feasibility-aware association (Sec. IV-E / V-B)."""
import jax.numpy as jnp
import numpy as np

from repro.core import association as assoc
from repro.core import channel as ch
from repro.core import participation as part


def test_flat_association_matches_manual_feasibility(small_deployment, cparams):
    dep, _ = small_deployment
    fa = assoc.flat_association(dep, cparams)
    d = np.linalg.norm(
        np.asarray(dep.sensor_pos) - np.asarray(dep.gateway_pos)[None], axis=-1
    )
    rmax = float(ch.max_feasible_range_m(cparams))
    np.testing.assert_array_equal(np.asarray(fa.participates), d <= rmax)
    np.testing.assert_allclose(np.asarray(fa.dist_m), d, rtol=1e-5)


def test_nearest_fog_is_nearest_among_feasible(small_deployment, cparams):
    dep, _ = small_deployment
    fa = assoc.nearest_feasible_fog(dep, cparams)
    d_sf = np.asarray(ch.pairwise_distances(dep.sensor_pos, dep.fog_pos))
    feas = np.asarray(ch.feasible(jnp.asarray(d_sf), cparams))
    for i in range(d_sf.shape[0]):
        if not feas[i].any():
            assert not bool(fa.participates[i])
            continue
        masked = np.where(feas[i], d_sf[i], np.inf)
        assert int(fa.fog_id[i]) == int(np.argmin(masked))
        assert float(fa.dist_m[i]) == float(d_sf[i, int(fa.fog_id[i])])


def test_cluster_sizes_count_participants_only(small_deployment, cparams):
    dep, _ = small_deployment
    fa = assoc.nearest_feasible_fog(dep, cparams)
    assert int(jnp.sum(fa.cluster_size)) == int(jnp.sum(fa.participates))


def test_fog_reachability_dominates_direct(small_deployment, cparams):
    """The paper's Fig. 5 structural claim: fog-assisted reachability >=
    direct gateway reachability (fogs are mid-water, strictly closer)."""
    dep, _ = small_deployment
    r = part.reachability(dep, cparams)
    assert float(r.fog_assisted) >= float(r.direct_gateway)


def test_participation_fraction():
    mask = jnp.array([True, False, True, True])
    assert float(part.participation_fraction(mask)) == 0.75


def test_energy_per_participant():
    mask = jnp.array([True, False, True, False])
    assert float(part.energy_per_participant(jnp.float32(10.0), mask)) == 5.0
