"""Tests for the pod-level compressed exchange (core/mesh_fl.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mesh_fl


def test_compact_roundtrip_ef_invariant():
    n = 10_000
    flat = jax.random.normal(jax.random.key(0), (n,))
    q, idx, scale = mesh_fl.compress_compact(flat, rho_s=0.05)
    recon = mesh_fl.decompress_compact(q, idx, scale, n)
    # survivors reconstruct within int8 tolerance; dropped coords are zero
    nnz = np.flatnonzero(np.asarray(recon))
    amax = float(jnp.max(jnp.abs(flat)))
    np.testing.assert_allclose(
        np.asarray(recon)[nnz], np.asarray(flat)[nnz], atol=amax / 127.0
    )
    k = max(1, round(0.05 * mesh_fl.BLOCK))
    nb = -(-n // mesh_fl.BLOCK)
    assert len(nnz) <= nb * k


def test_compact_keeps_largest_per_block():
    flat = jnp.zeros((mesh_fl.BLOCK,)).at[7].set(5.0).at[100].set(-3.0)
    q, idx, scale = mesh_fl.compress_compact(flat, rho_s=2 / mesh_fl.BLOCK)
    recon = mesh_fl.decompress_compact(q, idx, scale, mesh_fl.BLOCK)
    assert float(recon[7]) == pytest.approx(5.0, rel=0.02)
    assert float(recon[100]) == pytest.approx(-3.0, rel=0.02)


def test_wire_bytes_much_smaller_than_dense():
    d = 8_030_261_248  # llama3-8b
    wire = mesh_fl.wire_bytes(d, 0.05)
    assert wire < 0.08 * 4 * d  # >12x smaller than dense f32


def test_pod_hfl_step_single_pod_mesh():
    """On a 1-pod mesh the step must run and decrease loss like plain SGD
    with a quantised gradient (mix degenerates to the identity)."""
    from repro import configs
    from repro.models import api

    cfg = configs.get("llama3_8b", reduced=True).replace(learning_rate=1e-2)
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    step = mesh_fl.make_pod_hfl_train_step(cfg, mesh, mode="int8")
    key = jax.random.key(0)
    params = api.init_params(key, cfg)
    err = mesh_fl.init_err(params, n_pods=1)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    with mesh:
        jstep = jax.jit(step)
        losses = []
        for _ in range(3):
            params, err, loss = jstep(params, err, batch)
            losses.append(float(loss))
    assert losses[-1] < losses[0]
    for leaf in jax.tree_util.tree_leaves(params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
