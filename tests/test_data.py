"""Tests for the data substrate: synthetic IoUT series, benchmark
loaders/surrogates, partitioning, batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import benchmarks, partition, pipeline
from repro.data.synthetic import SyntheticConfig, generate, normalize


@pytest.fixture(scope="module")
def small_ds():
    cfg = SyntheticConfig(n_sensors=8, train_len=64, val_len=16, test_len=48)
    return generate(jax.random.key(0), cfg), cfg


def test_synthetic_shapes(small_ds):
    ds, cfg = small_ds
    assert ds.train.shape == (8, 64, cfg.feature_dim)
    assert ds.val.shape == (8, 16, cfg.feature_dim)
    assert ds.test.shape == (8, 48, cfg.feature_dim)
    assert ds.test_label.shape == (8, 48)
    assert ds.test_label.dtype == jnp.bool_


def test_synthetic_anomaly_rate(small_ds):
    ds, cfg = small_ds
    rate = float(jnp.mean(ds.test_label))
    assert 0.3 * cfg.anomaly_rate < rate < 3.0 * cfg.anomaly_rate


def test_anomalous_points_differ_from_normal(small_ds):
    ds, _ = small_ds
    # Anomalies are injected, so labeled points deviate more from the mean.
    mean = jnp.mean(ds.train, axis=(1,), keepdims=True)
    dev = jnp.linalg.norm(ds.test - mean, axis=-1)
    anom = float(jnp.mean(jnp.where(ds.test_label, dev, jnp.nan), where=ds.test_label))
    norm = float(jnp.mean(jnp.where(~ds.test_label, dev, jnp.nan), where=~ds.test_label))
    assert anom > norm


def test_normalize_zero_mean_unit_std(small_ds):
    ds, _ = small_ds
    nds = normalize(ds)
    mu = np.asarray(jnp.mean(nds.train, axis=1))
    sd = np.asarray(jnp.std(nds.train, axis=1))
    np.testing.assert_allclose(mu, 0.0, atol=1e-4)
    np.testing.assert_allclose(sd, 1.0, atol=1e-2)


def test_dirichlet_alpha_controls_heterogeneity():
    key = jax.random.key(1)
    p_noniid = partition.dirichlet_proportions(key, 100, 5, 0.1)
    p_iid = partition.dirichlet_proportions(key, 100, 5, 1e4)
    # strongly non-IID rows are peaky; near-IID rows are uniform
    assert float(jnp.mean(jnp.max(p_noniid, 1))) > 0.6
    assert float(jnp.mean(jnp.max(p_iid, 1))) < 0.35


def test_contiguous_split():
    x = jnp.arange(20.0).reshape(10, 2)
    parts = partition.contiguous_split(x, 3)
    assert parts.shape == (3, 3, 2)
    np.testing.assert_array_equal(np.asarray(parts[0]), np.asarray(x[:3]))


def test_entity_replication():
    key = jax.random.key(2)
    assign = partition.entities_to_sensors(key, 4, 10)
    assert assign.shape == (10,)
    assert int(jnp.max(assign)) <= 3
    data = jnp.arange(8.0).reshape(4, 2)
    rep = partition.replicate_entities(data, assign)
    assert rep.shape == (10, 2)


@pytest.mark.parametrize("name", ["smd", "smap", "msl"])
def test_benchmark_surrogate_shapes(name):
    bd = benchmarks.load(name, data_dir="/nonexistent", length=128)
    spec = benchmarks.SPECS[name]
    assert bd.source == "surrogate"
    assert bd.dataset.train.shape[0] == spec.n_entities
    assert bd.dataset.train.shape[-1] == spec.feature_dim
    rate = float(jnp.mean(bd.dataset.test_label))
    assert 0.2 * spec.anomaly_rate < rate < 4.0 * spec.anomaly_rate


def test_epoch_batches_cover_data_once():
    data = jnp.arange(32.0).reshape(16, 2)
    b = pipeline.epoch_batches(jax.random.key(0), data, 4)
    assert b.shape == (4, 4, 2)
    seen = np.sort(np.asarray(b[..., 0]).reshape(-1))
    np.testing.assert_array_equal(seen, np.asarray(data[:, 0]))


def test_multi_epoch_batches():
    data = jnp.arange(32.0).reshape(16, 2)
    b = pipeline.multi_epoch_batches(jax.random.key(0), data, 4, 3)
    assert b.shape == (12, 4, 2)


def test_lm_batches():
    toks = jnp.arange(1000, dtype=jnp.int32)
    b = pipeline.lm_batches(jax.random.key(0), toks, 4, 16)
    assert b.shape == (4, 17)
    # windows are contiguous
    np.testing.assert_array_equal(
        np.diff(np.asarray(b), axis=1), 1
    )
