"""Tests for hierarchical aggregation operators (Eqs. 13, 15, 16)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core import cooperation as coop
from repro.launch.mesh import shard_map_compat


def test_fog_aggregate_matches_manual():
    updates = jnp.arange(12.0).reshape(6, 2)
    fog_id = jnp.array([0, 0, 1, 1, 1, 2], jnp.int32)
    weights = jnp.array([1.0, 3.0, 2.0, 2.0, 0.0, 5.0])
    out, fog_w = agg.fog_aggregate(updates, fog_id, weights, n_fog=4)
    np.testing.assert_allclose(np.asarray(fog_w), [4.0, 4.0, 5.0, 0.0])
    m0 = (1 * updates[0] + 3 * updates[1]) / 4
    m1 = (2 * updates[2] + 2 * updates[3]) / 4
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(m0), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(m1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[3]), 0.0)  # empty cluster


def test_cooperative_mix_identity_for_noncooperating():
    models = jnp.arange(8.0).reshape(4, 2)
    d = coop.no_cooperation(jnp.zeros((4, 3)))
    mixed = agg.cooperative_mix(models, d)
    np.testing.assert_array_equal(np.asarray(mixed), np.asarray(models))


def test_cooperative_mix_convex_combination():
    models = jnp.array([[0.0], [10.0]])
    d = coop.CoopDecision(
        partner=jnp.array([1, 1], jnp.int32),
        self_weight=jnp.array([0.8, 1.0]),
        partner_weight=jnp.array([0.2, 0.0]),
        cooperates=jnp.array([True, False]),
        dist_m=jnp.zeros((2,)),
    )
    mixed = agg.cooperative_mix(models, d)
    np.testing.assert_allclose(np.asarray(mixed), [[2.0], [10.0]])


def test_global_aggregate_weighted():
    models = jnp.array([[1.0], [2.0], [3.0]])
    w = jnp.array([1.0, 1.0, 2.0])
    out = agg.global_aggregate(models, w)
    np.testing.assert_allclose(np.asarray(out), [(1 + 2 + 6) / 4.0])


def test_hierarchy_equals_flat_when_weights_consistent():
    """Two-level weighted mean == one-level weighted mean (associativity of
    weighted averages) — the algebraic fact the paper's Eq. 13+16 rely on."""
    key = jax.random.key(5)
    updates = jax.random.normal(key, (10, 3))
    weights = jax.random.uniform(jax.random.fold_in(key, 1), (10,)) + 0.1
    fog_id = jnp.array([0, 1, 2, 0, 1, 2, 0, 1, 2, 0], jnp.int32)
    fog_up, fog_w = agg.fog_aggregate(updates, fog_id, weights, 3)
    two_level = agg.global_aggregate(fog_up, fog_w)
    flat = agg.weighted_mean(updates, weights)
    np.testing.assert_allclose(np.asarray(two_level), np.asarray(flat), rtol=1e-5)


def test_hierarchical_mean_shard_map_matches_flat():
    """Mesh two-level reduction == flat weighted mean on a 1x1 mesh."""
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    update = jnp.arange(4.0)
    weight = jnp.float32(2.0)

    def f(u, w):
        return agg.hierarchical_mean(u, w, intra_axis="data", inter_axis="pod")

    out = shard_map_compat(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=P()
    )(update, weight)
    np.testing.assert_allclose(np.asarray(out), np.asarray(update))


def test_ring_mix_single_device_identity():
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("pod",))
    x = jnp.arange(3.0)
    out = shard_map_compat(
        lambda u: agg.ring_mix(u, 0.3, axis="pod"),
        mesh=mesh, in_specs=(P(),), out_specs=P(),
    )(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x))


# ---------------------------------------------------------------------------
# Zero-total-weight rounds (dead network) — PR 5 bugfix.
# ---------------------------------------------------------------------------

def test_global_aggregate_zero_weights_holds_prev():
    """A dead-network round must hold the model, not wipe it to zeros."""
    models = jnp.arange(6.0).reshape(3, 2) + 1.0
    prev = jnp.array([7.0, -3.0])
    dead = jnp.zeros((3,))
    held = agg.global_aggregate(models, dead, prev=prev)
    np.testing.assert_array_equal(np.asarray(held), np.asarray(prev))
    # without a carry the legacy zero default is preserved
    np.testing.assert_allclose(np.asarray(agg.global_aggregate(models, dead)), 0.0)
    # live rounds are untouched by the fallback
    live_w = jnp.array([1.0, 0.0, 3.0])
    np.testing.assert_allclose(
        np.asarray(agg.global_aggregate(models, live_w, prev=prev)),
        np.asarray(agg.global_aggregate(models, live_w)),
        rtol=1e-6,
    )


def test_weighted_mean_zero_weights_holds_prev():
    updates = jnp.arange(4.0).reshape(2, 2)
    prev = jnp.array([5.0, 5.0])
    held = agg.weighted_mean(updates, jnp.zeros((2,)), prev=prev)
    np.testing.assert_array_equal(np.asarray(held), np.asarray(prev))


# ---------------------------------------------------------------------------
# Non-finite client updates (graceful degradation guard) — ISSUE 7.
# ---------------------------------------------------------------------------

def test_compress_and_accumulate_zeroes_nonfinite_rows():
    """A client delta carrying Inf/NaN must be zeroed — delta, EF buffer
    AND weight — before it touches the fog sums, independent of the fault
    layer; finite clients are bit-identical with or without the poisoned
    neighbour."""
    from repro.core import compression as comp

    key = jax.random.key(7)
    n, d = 8, 24
    deltas = jax.random.normal(key, (n, d))
    err = jax.random.normal(jax.random.fold_in(key, 1), (n, d)) * 0.1
    fog_id = jnp.arange(n, dtype=jnp.int32) % 2
    weights = jnp.ones((n,))
    cfg = comp.CompressorConfig(rho_s=0.25, quant_bits=8, mode="blockwise")

    poisoned = deltas.at[2, 3].set(jnp.inf).at[5, 0].set(jnp.nan)
    fog_sum, fog_w, new_err = agg.compress_and_accumulate(
        poisoned, err, fog_id, weights, 2, cfg
    )
    assert bool(jnp.all(jnp.isfinite(fog_sum)))
    assert bool(jnp.all(jnp.isfinite(new_err)))
    # The poisoned clients' weight is gone from their fogs.
    np.testing.assert_allclose(np.asarray(fog_w), [3.0, 3.0])

    # Equivalent to excluding them up front (weight 0, zero delta/err).
    excl = jnp.where(jnp.asarray([i in (2, 5) for i in range(n)]))[0]
    w_ref = weights.at[excl].set(0.0)
    d_ref = deltas.at[excl].set(0.0)
    e_ref = err.at[excl].set(0.0)
    ref_sum, ref_w, ref_err = agg.compress_and_accumulate(
        d_ref, e_ref, fog_id, w_ref, 2, cfg
    )
    np.testing.assert_array_equal(np.asarray(fog_sum), np.asarray(ref_sum))
    np.testing.assert_array_equal(np.asarray(fog_w), np.asarray(ref_w))
    np.testing.assert_array_equal(np.asarray(new_err), np.asarray(ref_err))

    # Finite inputs: the guard is an exact no-op.
    g_sum, g_w, g_err = agg.compress_and_accumulate(
        deltas, err, fog_id, weights, 2, cfg
    )
    assert bool(jnp.all(jnp.isfinite(g_sum))) and float(g_w.sum()) == n


def test_battery_exhaustion_holds_model_through_hfl_train():
    """Regression: with every sensor battery-dead, fog weights are all zero
    and hfl.train used to collapse the global model to zeros on round 1;
    now each dead round is an explicit no-op on the params."""
    from repro.core import energy as en
    from repro.core import hfl
    from repro.data.synthetic import SyntheticConfig, generate, normalize
    from repro.launch import experiment as exp
    from repro.models import autoencoder as ae

    ds = normalize(generate(
        jax.random.key(0),
        SyntheticConfig(n_sensors=8, train_len=32, val_len=16, test_len=32),
    ))
    cfg = exp.make_config(
        n_sensors=8, n_fog=2, rounds=3, local_epochs=1,
        energy=en.EnergyParams(e_init_j=0.0, e_min_j=0.0),
    )
    key = jax.random.key(1)
    params0 = ae.init(jax.random.key(2), ds.train.shape[-1], (16, 8, 16))
    # NEAREST would happily pair stale association clusters; the round now
    # feeds battery-aware active cluster sizes into the decision, so a
    # fully dead network also reports zero cooperation links.
    params, metrics = hfl.train(
        key, params0, ae.loss, ds, cfg.replace(rule=hfl.coop.CoopRule.NEAREST)
    )
    assert float(jnp.max(metrics.participation)) == 0.0
    assert float(jnp.max(metrics.coop_links)) == 0.0
    assert float(jnp.max(metrics.e_f2f)) == 0.0
    for p, p0 in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(params0)
    ):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(p0))
    assert not bool(jnp.any(jnp.isnan(metrics.loss)))
