"""Tests for config-axis sweep batching (Engine.sweep, PR 5).

Covers the acceptance pins: a >=8-cell quick-tier ablation grid runs as
<=3 compiled programs matching per-cell ``Engine.run`` to float tolerance;
shape-class grouping never co-batches mixed enums/static shapes; a swept
``rho_s`` row reproduces ``Engine(compressor="keep")`` sequential runs;
and ``Engine.sweep(family="audit")`` over a swept ``ChannelParams`` grid
matches the sequential audit path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as eng_mod
from repro.core import channel as ch
from repro.core import compression as comp
from repro.core import energy as en
from repro.data.synthetic import SyntheticConfig, generate, normalize
from repro.launch import experiment as exp


def _make_ds(seed: int):
    cfg = SyntheticConfig(n_sensors=12, train_len=48, val_len=24, test_len=48)
    return normalize(generate(jax.random.key(seed), cfg))


def _small_cfg(**kw):
    kw.setdefault("rounds", 2)
    kw.setdefault("local_epochs", 1)
    return exp.make_config(n_sensors=12, n_fog=3, **kw)


def test_ablation_grid_compiles_at_most_3_programs():
    """The acceptance pin: an 8-cell rho x lr quick-tier ablation grid is
    ONE shape-class -> one compiled program (<= 3), and every cell matches
    its per-cell Engine.run to float tolerance."""
    eng = eng_mod.Engine()
    base = _small_cfg()
    cfgs = [
        base.replace(
            lr=lr, compressor=comp.CompressorConfig(rho_s=rho, quant_bits=8)
        )
        for rho in (0.01, 0.05, 0.1, 0.2)
        for lr in (0.005, 0.01)
    ]
    assert len(cfgs) >= 8
    sw = eng.sweep("hfl-selective", cfgs, (0, 1), _make_ds)
    assert sw.compiled_programs <= 3
    assert sw.n_classes == 1
    assert np.asarray(sw["f1"]).shape == (8, 2, 1)

    for i in (0, 3, 7):
        r = eng.run("hfl-selective", cfgs[i], (0, 1), _make_ds)
        np.testing.assert_allclose(
            np.asarray(sw["e_total"][i]), np.asarray(r["e_total"]), rtol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(sw["losses"][i]), np.asarray(r.losses),
            rtol=1e-4, atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(sw["f1"][i]), np.asarray(r.f1), atol=1e-3
        )


def test_mixed_statics_never_cobatched():
    """Cells differing in enums / static structure (compressor mode,
    bit-width, server optimiser, round count) land in separate
    shape-classes — only true knob sweeps share a program."""
    eng = eng_mod.Engine(compressor="keep")
    base = _small_cfg()
    cfgs = [
        base,                                                    # class A
        base.replace(compressor=comp.CompressorConfig(
            rho_s=0.1, quant_bits=8)),                           # A (rho swept)
        base.replace(compressor=comp.CompressorConfig(
            rho_s=1.0, quant_bits=32)),                          # B: dense
        base.replace(compressor=comp.CompressorConfig(
            rho_s=0.05, quant_bits=8, mode="blockwise")),        # C: mode enum
        base.replace(server_opt="adam"),                         # D: enum
        base.replace(rounds=3),                                  # E: shape
    ]
    sw = eng.sweep("hfl-nocoop", cfgs, (0,), _make_ds)
    assert sw.n_classes == 5
    grouped = {c["indices"] for c in sw.classes}
    assert (0, 1) in grouped  # the one genuine knob sweep co-batched
    # ... and the grid still matches the per-cell path.
    for i in (2, 4):
        r = eng.run("hfl-nocoop", cfgs[i], (0,), _make_ds)
        np.testing.assert_allclose(
            np.asarray(sw["losses"][i]), np.asarray(r.losses),
            rtol=1e-4, atol=1e-6,
        )


def test_swept_rho_matches_keep_sequential():
    """A swept rho_s row under Engine(compressor="keep") — the paper's
    exact global Top-K semantics, traced k via a dynamic sort index —
    reproduces sequential experiment.run_method per cell."""
    eng = eng_mod.Engine(compressor="keep")
    base = _small_cfg(rounds=3)
    rhos = (0.02, 0.05, 0.3)
    cfgs = [
        base.replace(compressor=comp.CompressorConfig(
            rho_s=r, quant_bits=8, mode="global"))
        for r in rhos
    ]
    sw = eng.sweep("hfl-selective", cfgs, (0, 1), _make_ds)
    assert sw.n_classes == 1
    for i, c in enumerate(cfgs):
        for j, s in enumerate((0, 1)):
            ref = exp.run_method(
                "hfl-selective", _make_ds(s), eng.resolve_config(c), seed=s
            )
            np.testing.assert_allclose(
                float(sw["e_total"][i, j, 0]), ref.e_total, rtol=1e-5
            )
            np.testing.assert_allclose(
                np.asarray(sw["losses"][i, j, 0]), np.asarray(ref.losses),
                rtol=1e-4,
            )
            np.testing.assert_allclose(
                float(sw["f1"][i, j, 0]), ref.f1, atol=1e-3
            )


def test_audit_sweep_channel_grid_matches_sequential():
    """Audit sweep over a ChannelParams x EnergyParams x compressor grid:
    everything lands in ONE program (the compressor enters only through
    the payload-bits operand) and each cell matches the sequential
    audit_method on the resolved config."""
    eng = eng_mod.Engine()
    grid = [
        exp.make_config(
            n_sensors=30, n_fog=5, rounds=4,
            channel=ch.ChannelParams(wind_m_s=w, shipping=s),
            energy=en.EnergyParams(eta_ea=eta),
            compressor=cc,
        )
        for (w, s, eta) in ((3.0, 0.2, 0.25), (8.0, 0.7, 0.4))
        for cc in (
            comp.CompressorConfig(rho_s=0.05, quant_bits=8),
            comp.CompressorConfig(rho_s=1.0, quant_bits=32),
        )
    ]
    sw = eng.sweep("hfl-selective", grid, (0, 1), family="audit")
    assert sw.n_classes == 1
    assert sw.compiled_programs == 1
    for i, c in enumerate(grid):
        rcfg = eng.resolve_config(c)
        for j, s in enumerate((0, 1)):
            ref = exp.audit_method("hfl-selective", rcfg, seed=s)
            for k in ("e_s2f", "e_f2f", "e_f2g", "e_total", "participation"):
                np.testing.assert_allclose(
                    float(sw[k][i, j, 0]), ref[k], rtol=1e-5, atol=1e-7
                )


def test_sweep_program_cache_reuse():
    """Re-running the same grid hits the program cache: zero fresh
    compiles, identical results — the CI compile-count gate relies on
    this accounting."""
    eng = eng_mod.Engine()
    cfgs = [
        _small_cfg(channel=ch.ChannelParams(wind_m_s=w)) for w in (3.0, 7.0)
    ]
    s1 = eng.sweep("hfl-nocoop", cfgs, (0,), family="audit")
    before = eng.compile_count
    s2 = eng.sweep("hfl-nocoop", cfgs, (0,), family="audit")
    assert s1.compiled_programs == 1
    assert s2.compiled_programs == 0
    assert eng.compile_count == before
    np.testing.assert_array_equal(
        np.asarray(s1["e_total"]), np.asarray(s2["e_total"])
    )
    log = eng.take_log()
    assert [e["fresh_compile"] for e in log] == [True, False]
    assert all(e["kind"] == "sweep-audit" and e["n_cells"] == 2 for e in log)


def test_sweep_per_cell_datasets():
    """The config axis can carry per-cell datasets (the fig7 non-IID
    sweep): same config, different data, one program."""
    eng = eng_mod.Engine()
    cfg = _small_cfg()
    ds_list = [_make_ds(100), _make_ds(200)]
    sw = eng.sweep("fedprox", [cfg, cfg], (0,), ds_list)
    assert sw.n_classes == 1
    for i, one in enumerate(ds_list):
        r = eng.run("fedprox", cfg, (0,), one)
        np.testing.assert_allclose(
            np.asarray(sw["losses"][i]), np.asarray(r.losses),
            rtol=1e-4, atol=1e-6,
        )


def test_sweep_rejects_bad_inputs():
    eng = eng_mod.Engine()
    cfg = _small_cfg()
    with pytest.raises(ValueError, match="family"):
        eng.sweep("hfl-nocoop", [cfg], (0,), _make_ds, family="pod")
    with pytest.raises(ValueError, match="at least one"):
        eng.sweep("hfl-nocoop", [], (0,), _make_ds)
    with pytest.raises(ValueError, match="dataset"):
        eng.sweep("hfl-nocoop", [cfg], (0,))
    with pytest.raises(ValueError, match="datasets for"):
        eng.sweep("hfl-nocoop", [cfg], (0,), [_make_ds(0), _make_ds(1)])


def test_traced_payload_and_k_frac_match_concrete():
    """The traced payload/keep-count formulas agree with the concrete
    Python ones across a (d, rho) grid — the sweep's numerics contract."""
    for d in (137, 1352, 9000, 20000):
        for rho in (0.01, 0.05, 0.2, 0.9):
            cc = comp.CompressorConfig(rho_s=rho, quant_bits=8)
            cc_t = cc.replace(rho_s=jnp.float32(rho), sparse=True)
            np.testing.assert_allclose(
                float(jax.jit(lambda c: comp.payload_bits(d, c))(cc_t)),
                comp.payload_bits(d, cc), rtol=1e-6,
            )
            np.testing.assert_allclose(
                float(jax.jit(
                    lambda r: comp.blockwise_k_frac(d, r)
                )(jnp.float32(rho))),
                comp.blockwise_k_frac(d, rho), rtol=1e-6,
            )


def test_config_pytree_roundtrip_preserves_statics():
    """Flatten/unflatten keeps enums, counts, and the static sparsity
    predicate intact while leaves may be replaced by tracers."""
    cfg = _small_cfg(
        compressor=comp.CompressorConfig(rho_s=0.05, quant_bits=8),
        server_opt="adam",
    )
    leaves, treedef = jax.tree_util.tree_flatten(cfg)
    assert all(isinstance(x, (int, float)) for x in leaves)
    cfg2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert cfg2.rule is cfg.rule
    assert cfg2.rounds == cfg.rounds
    assert cfg2.compressor.is_sparse and cfg2.compressor.enabled
    # a stacked config still answers the static predicates
    stacked = eng_mod.Engine.stack_configs([cfg, cfg.replace(lr=0.02)])
    assert stacked.compressor.is_sparse
    assert stacked.compressor.enabled
    assert np.asarray(stacked.lr).shape == (2,)
    # replace(rho_s=...) across the sparsity boundary re-derives the
    # pinned predicate instead of keeping it stale
    pinned = cfg2.compressor
    assert pinned.sparse is True
    dense = pinned.replace(rho_s=1.0, quant_bits=32)
    assert dense.sparse is None and not dense.is_sparse and not dense.enabled
    assert comp.payload_bits(1352, dense) == 32.0 * 1352
