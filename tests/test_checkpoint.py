"""Tests for the npz pytree checkpoint store."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore, load_pytree, save_pytree


@pytest.fixture
def tree():
    return {
        "layer": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,))},
        "scale": jnp.float32(2.5),
    }


def test_roundtrip(tmp_path, tree):
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    restored = load_pytree(path, jax.tree_util.tree_map(jnp.zeros_like, tree))
    for a, b in zip(
        jax.tree_util.tree_leaves(restored), jax.tree_util.tree_leaves(tree)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(tmp_path, tree):
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    bad = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape + (1,)), tree)
    with pytest.raises(ValueError):
        load_pytree(path, bad)


def test_store_retention_and_latest(tmp_path, tree):
    store = CheckpointStore(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        store.save(step, tree)
    assert store.steps() == [3, 4]
    assert store.latest_step() == 4
    restored, step = store.restore(tree)
    assert step == 4
    restored, step = store.restore(tree, step=3)
    assert step == 3


def test_store_empty_raises(tmp_path, tree):
    store = CheckpointStore(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        store.restore(tree)
