"""Tests for the npz pytree checkpoint store."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore, load_pytree, save_pytree


@pytest.fixture
def tree():
    return {
        "layer": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,))},
        "scale": jnp.float32(2.5),
    }


def test_roundtrip(tmp_path, tree):
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    restored = load_pytree(path, jax.tree_util.tree_map(jnp.zeros_like, tree))
    for a, b in zip(
        jax.tree_util.tree_leaves(restored), jax.tree_util.tree_leaves(tree)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_raises(tmp_path, tree):
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    bad = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape + (1,)), tree)
    with pytest.raises(ValueError):
        load_pytree(path, bad)


def test_store_retention_and_latest(tmp_path, tree):
    store = CheckpointStore(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        store.save(step, tree)
    assert store.steps() == [3, 4]
    assert store.latest_step() == 4
    restored, step = store.restore(tree)
    assert step == 4
    restored, step = store.restore(tree, step=3)
    assert step == 3


def test_store_empty_raises(tmp_path, tree):
    store = CheckpointStore(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        store.restore(tree)


def test_save_is_atomic_no_stray_tmp_files(tmp_path, tree):
    """Saves stage through unique temp files and os.replace: after any
    number of saves (overwrites included) the directory holds only final
    step files, and every one of them is fully loadable."""
    store = CheckpointStore(str(tmp_path), keep=10)
    for step in (1, 2, 2, 3):                  # step 2 saved twice
        store.save(step, tree)
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["step_00000001.npz", "step_00000002.npz",
                     "step_00000003.npz"]
    for step in (1, 2, 3):
        restored, _ = store.restore(tree, step=step)
        for a, b in zip(jax.tree_util.tree_leaves(restored),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_publish_latest_roundtrip_from_real_train(tmp_path):
    """The serving hand-off: a real ``hfl.train`` run publishes every
    round; ``latest`` must hand back EXACTLY the final trained params (and
    the publishing Python loop must match the lax.scan path)."""
    from repro.core import hfl
    from repro.data.synthetic import SyntheticConfig, generate, normalize
    from repro.launch import experiment as exp
    from repro.models import autoencoder as ae

    dcfg = SyntheticConfig(n_sensors=8, train_len=48, val_len=24, test_len=48)
    ds = normalize(generate(jax.random.key(0), dcfg))
    p0 = ae.init(jax.random.key(1), ds.train.shape[-1], (16, 8, 16))
    cfg = exp.make_config(n_sensors=8, n_fog=3, rounds=3, local_epochs=1)

    store = CheckpointStore(str(tmp_path), keep=5)
    trained, _ = hfl.train(jax.random.key(2), p0, ae.loss, ds, cfg,
                           store=store)
    assert store.steps() == [1, 2, 3]
    latest, step = store.latest(p0)
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(latest),
                    jax.tree_util.tree_leaves(trained)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # Publishing loop == scan path (identical numerics, same round fn).
    scan_params, _ = hfl.train(jax.random.key(2), p0, ae.loss, ds, cfg)
    for a, b in zip(jax.tree_util.tree_leaves(trained),
                    jax.tree_util.tree_leaves(scan_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    # publish_every thins the stream; the final round always publishes.
    store2 = CheckpointStore(str(tmp_path / "thin"), keep=5)
    hfl.train(jax.random.key(2), p0, ae.loss, ds, cfg, store=store2,
              publish_every=2)
    assert store2.steps() == [2, 3]
    # publish_offset continues a stream without colliding steps.
    hfl.train(jax.random.key(3), p0, ae.loss, ds, cfg, store=store2,
              publish_every=2, publish_offset=3)
    assert store2.steps() == [2, 3, 5, 6]

    # rounds=0 with a store degenerates to the scan path: no publish, no
    # crash on the empty metrics stack.
    zp, zm = hfl.train(jax.random.key(4), p0, ae.loss, ds,
                       cfg.replace(rounds=0), store=store2)
    assert store2.steps() == [2, 3, 5, 6]
    assert zm.loss.shape == (0,)
    for a, b in zip(jax.tree_util.tree_leaves(zp),
                    jax.tree_util.tree_leaves(p0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
