"""Run plain unit tests even without hypothesis installed.

The CPU container this repo targets does not ship hypothesis (CI installs
it from requirements-dev.txt).  Importing ``given``/``settings``/``st``
from here instead of hypothesis keeps the ordinary unit tests in the
channel/compression/energy modules collecting and running everywhere;
only the ``@given`` property tests skip when hypothesis is missing.
"""
import pytest

try:
    from hypothesis import given, settings  # noqa: F401  (re-exported to tests)
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in hypothesis-less envs
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for hypothesis.strategies: every strategy-builder
        call site evaluates to an inert placeholder."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        del a, k
        return lambda f: f

    def given(*a, **k):
        del a, k

        def deco(f):
            return pytest.mark.skip(reason="property test needs hypothesis")(
                f
            )

        return deco
