"""Tests for the fog cooperation rules (Eqs. 14, 28-29)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel as ch
from repro.core import cooperation as coop


@pytest.fixture(scope="module")
def fog_setup(cparams):
    key = jax.random.key(11)
    pos = jax.random.uniform(key, (8, 3), minval=0.0, maxval=1200.0)
    sizes = jnp.array([12, 1, 9, 2, 15, 3, 8, 0], jnp.int32)
    return pos, sizes


def test_nocoop_is_identity(fog_setup):
    pos, _ = fog_setup
    d = coop.no_cooperation(pos)
    assert not bool(jnp.any(d.cooperates))
    np.testing.assert_array_equal(np.asarray(d.partner), np.arange(8))
    np.testing.assert_allclose(np.asarray(d.self_weight), 1.0)
    np.testing.assert_allclose(np.asarray(d.partner_weight), 0.0)


def test_mixing_rows_are_stochastic(fog_setup, cparams):
    pos, sizes = fog_setup
    for rule in coop.CoopRule:
        d = coop.decide(rule, pos, sizes, cparams)
        np.testing.assert_allclose(
            np.asarray(d.self_weight + d.partner_weight), 1.0, rtol=1e-6
        )
        assert bool(jnp.all(d.self_weight >= 0))
        assert bool(jnp.all(d.partner_weight >= 0))


def test_nearest_uses_paper_weights(fog_setup, cparams):
    pos, sizes = fog_setup
    d = coop.nearest_cooperation(pos, sizes, cparams)
    coop_mask = np.asarray(d.cooperates)
    assert coop_mask.any()
    np.testing.assert_allclose(
        np.asarray(d.self_weight)[coop_mask], 0.7, rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(d.partner_weight)[coop_mask], 0.3, rtol=1e-6
    )


def test_nearest_picks_nearest_feasible_nonempty(fog_setup, cparams):
    pos, sizes = fog_setup
    d = coop.nearest_cooperation(pos, sizes, cparams)
    dm = np.array(ch.pairwise_distances(pos, pos))
    np.fill_diagonal(dm, np.inf)
    feas = np.asarray(ch.feasible(jnp.asarray(dm), cparams))
    eligible = feas & (np.asarray(sizes) > 0)[None, :]
    for m in range(pos.shape[0]):
        if int(sizes[m]) > 0 and eligible[m].any():
            masked = np.where(eligible[m], dm[m], np.inf)
            assert bool(d.cooperates[m])
            assert int(d.partner[m]) == int(np.argmin(masked))


def test_empty_fog_never_selected_as_partner(fog_setup, cparams):
    """Bugfix: an empty fog has no local aggregate to exchange — pairing
    with it would mix stale globals into a real fog (Eq. 15) while the
    ``cooperates & fog_active`` energy/latency masks (Eqs. 18/21) count no
    exchange.  Partner eligibility is gated on cluster_size > 0."""
    pos, sizes = fog_setup
    empty = np.flatnonzero(np.asarray(sizes) == 0)
    assert empty.size > 0  # fixture includes an empty fog
    for rule in coop.CoopRule:
        d = coop.decide(rule, pos, sizes, cparams)
        partners = np.asarray(d.partner)[np.asarray(d.cooperates)]
        assert not np.isin(partners, empty).any(), rule


def test_empty_fog_never_cooperates(cparams):
    """The empty fog itself must not cooperate either: its mixing row
    would update a model no cluster owns while energy says nothing moved."""
    key = jax.random.key(3)
    pos = jax.random.uniform(key, (6, 3), minval=0.0, maxval=800.0)
    sizes = jnp.array([0, 5, 7, 0, 3, 9], jnp.int32)
    for rule in (coop.CoopRule.NEAREST, coop.CoopRule.SELECTIVE):
        d = coop.decide(rule, pos, sizes, cparams)
        cooperating = np.flatnonzero(np.asarray(d.cooperates))
        assert (np.asarray(sizes)[cooperating] > 0).all(), rule


def test_coop_decision_consistent_with_energy_masks(fog_setup, cparams):
    """With every sensor alive, mixing/energy/latency agree: a fog whose
    mixing row actually blends a partner (partner_weight > 0) is exactly a
    fog the ``cooperates & fog_active`` masks count as exchanging."""
    pos, sizes = fog_setup
    fog_active = np.asarray(sizes) > 0  # full-battery round: weight > 0
    for rule in coop.CoopRule:
        d = coop.decide(rule, pos, sizes, cparams)
        mixes = np.asarray(d.partner_weight) > 0
        counted = np.asarray(d.cooperates) & fog_active
        np.testing.assert_array_equal(mixes, np.asarray(d.cooperates))
        np.testing.assert_array_equal(mixes, counted)


def test_selective_eligibility_rule(fog_setup, cparams):
    """Eq. 28: only clusters with c_m <= max(2, 0.75 mean) may cooperate."""
    pos, sizes = fog_setup
    d = coop.selective_cooperation(pos, sizes, cparams)
    c = np.asarray(sizes, np.float32)
    mean_c = c[c > 0].mean()
    threshold = max(2.0, 0.75 * mean_c)
    coop_mask = np.asarray(d.cooperates)
    # every cooperating fog is eligible and nonempty
    assert (c[coop_mask] <= threshold).all()
    assert (c[coop_mask] > 0).all()


def test_selective_partner_is_larger_and_close(fog_setup, cparams):
    pos, sizes = fog_setup
    d = coop.selective_cooperation(pos, sizes, cparams)
    c = np.asarray(sizes)
    dm = np.array(ch.pairwise_distances(pos, pos))
    np.fill_diagonal(dm, np.inf)
    feas = np.asarray(ch.feasible(jnp.asarray(dm), cparams))
    q1 = np.nanquantile(np.where(feas, dm, np.nan), 0.25)
    for m in np.flatnonzero(np.asarray(d.cooperates)):
        j = int(d.partner[m])
        assert c[j] > c[m]
        assert dm[m, j] < q1
        assert feas[m, j]
        # weights are the paper's (0.8, 0.2)
        assert float(d.self_weight[m]) == pytest.approx(0.8)
        assert float(d.partner_weight[m]) == pytest.approx(0.2)


def test_selective_subset_of_nearest_energy(fog_setup, cparams):
    """Selective must activate at most as many links as always-on."""
    pos, sizes = fog_setup
    ds = coop.selective_cooperation(pos, sizes, cparams)
    dn = coop.nearest_cooperation(pos, sizes, cparams)
    assert int(jnp.sum(ds.cooperates)) <= int(jnp.sum(dn.cooperates))


def test_selective_no_feasible_pairs_degrades_cleanly(cparams):
    """Bugfix: with ZERO feasible fog-fog links the q1 quantile used to run
    nanquantile over an all-NaN matrix — NaN result plus a RuntimeWarning
    under vmap on CPU.  The guard makes the no-coop degradation explicit
    and warning-free."""
    import warnings

    # Pairwise distances ~>= 5 km: far beyond the 140 dB SL cap's reach.
    pos = jnp.array(
        [[0.0, 0.0, 100.0], [5000.0, 0.0, 150.0],
         [0.0, 5000.0, 200.0], [5000.0, 5000.0, 250.0]]
    )
    sizes = jnp.array([1, 9, 2, 7], jnp.int32)
    feas = ch.feasible(
        ch.pairwise_distances(pos, pos)
        + jnp.diag(jnp.full((4,), jnp.inf)), cparams
    )
    assert not bool(jnp.any(feas))  # scenario really has no feasible pair
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        d = coop.selective_cooperation(pos, sizes, cparams)
        assert not bool(jnp.any(d.cooperates))
        # weights stay a clean identity row, not NaN
        np.testing.assert_allclose(np.asarray(d.self_weight), 1.0)
        np.testing.assert_allclose(np.asarray(d.dist_m), 0.0)

        # and under vmap (the engine's trial axes) it stays warning-free
        batched = jax.vmap(
            lambda p: coop.selective_cooperation(p, sizes, cparams)
        )(jnp.stack([pos, pos + 10.0]))
        assert not bool(jnp.any(batched.cooperates))


def test_selective_eligibility_factor_monotone(fog_setup, cparams):
    """The Eq. 28 factor sweep (ablations) reuses the production rule: a
    larger eligibility factor can only admit more cooperating fogs."""
    pos, sizes = fog_setup
    links = [
        int(jnp.sum(coop.selective_cooperation(
            pos, sizes, cparams, eligibility_factor=f).cooperates))
        for f in (0.25, 0.75, 1.5)
    ]
    assert links == sorted(links)


def test_selective_all_equal_clusters_no_coop(cparams):
    """With perfectly balanced clusters nobody passes Eq. 28 (c > 0.75 mean
    and c > 2)."""
    key = jax.random.key(1)
    pos = jax.random.uniform(key, (6, 3), minval=0.0, maxval=500.0)
    sizes = jnp.full((6,), 10, jnp.int32)
    d = coop.selective_cooperation(pos, sizes, cparams)
    assert not bool(jnp.any(d.cooperates))


def test_selective_needs_larger_neighbour(cparams):
    """A small cluster with only equal-size neighbours cannot cooperate."""
    pos = jnp.array([[0.0, 0.0, 100.0], [100.0, 0.0, 100.0]])
    sizes = jnp.array([1, 1], jnp.int32)
    d = coop.selective_cooperation(pos, sizes, cparams)
    assert not bool(jnp.any(d.cooperates))


def test_selective_small_joins_nearby_large(cparams):
    # Three isolated fog pairs with distinct intra-pair distances 30/50/100 m
    # (inter-pair links are infeasible at ~2.6 km under the 140 dB cap), so
    # the first quartile of feasible distances is 35 m and only the small
    # fog 0 has a larger neighbour strictly inside it.
    pos = jnp.array(
        [[0.0, 0.0, 100.0], [30.0, 0.0, 100.0],
         [1900.0, 0.0, 100.0], [1950.0, 0.0, 100.0],
         [0.0, 1900.0, 100.0], [100.0, 1900.0, 100.0]]
    )
    sizes = jnp.array([1, 20, 10, 10, 10, 10], jnp.int32)
    d = coop.selective_cooperation(pos, sizes, cparams)
    assert bool(d.cooperates[0])
    assert int(d.partner[0]) == 1
