"""Tests for the fog cooperation rules (Eqs. 14, 28-29)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel as ch
from repro.core import cooperation as coop


@pytest.fixture(scope="module")
def fog_setup(cparams):
    key = jax.random.key(11)
    pos = jax.random.uniform(key, (8, 3), minval=0.0, maxval=1200.0)
    sizes = jnp.array([12, 1, 9, 2, 15, 3, 8, 0], jnp.int32)
    return pos, sizes


def test_nocoop_is_identity(fog_setup):
    pos, _ = fog_setup
    d = coop.no_cooperation(pos)
    assert not bool(jnp.any(d.cooperates))
    np.testing.assert_array_equal(np.asarray(d.partner), np.arange(8))
    np.testing.assert_allclose(np.asarray(d.self_weight), 1.0)
    np.testing.assert_allclose(np.asarray(d.partner_weight), 0.0)


def test_mixing_rows_are_stochastic(fog_setup, cparams):
    pos, sizes = fog_setup
    for rule in coop.CoopRule:
        d = coop.decide(rule, pos, sizes, cparams)
        np.testing.assert_allclose(
            np.asarray(d.self_weight + d.partner_weight), 1.0, rtol=1e-6
        )
        assert bool(jnp.all(d.self_weight >= 0))
        assert bool(jnp.all(d.partner_weight >= 0))


def test_nearest_uses_paper_weights(fog_setup, cparams):
    pos, sizes = fog_setup
    d = coop.nearest_cooperation(pos, cparams)
    coop_mask = np.asarray(d.cooperates)
    assert coop_mask.any()
    np.testing.assert_allclose(
        np.asarray(d.self_weight)[coop_mask], 0.7, rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(d.partner_weight)[coop_mask], 0.3, rtol=1e-6
    )


def test_nearest_picks_nearest_feasible(fog_setup, cparams):
    pos, _ = fog_setup
    d = coop.nearest_cooperation(pos, cparams)
    dm = np.array(ch.pairwise_distances(pos, pos))
    np.fill_diagonal(dm, np.inf)
    feas = np.asarray(ch.feasible(jnp.asarray(dm), cparams))
    for m in range(pos.shape[0]):
        if feas[m].any():
            masked = np.where(feas[m], dm[m], np.inf)
            assert int(d.partner[m]) == int(np.argmin(masked))


def test_selective_eligibility_rule(fog_setup, cparams):
    """Eq. 28: only clusters with c_m <= max(2, 0.75 mean) may cooperate."""
    pos, sizes = fog_setup
    d = coop.selective_cooperation(pos, sizes, cparams)
    c = np.asarray(sizes, np.float32)
    mean_c = c[c > 0].mean()
    threshold = max(2.0, 0.75 * mean_c)
    coop_mask = np.asarray(d.cooperates)
    # every cooperating fog is eligible and nonempty
    assert (c[coop_mask] <= threshold).all()
    assert (c[coop_mask] > 0).all()


def test_selective_partner_is_larger_and_close(fog_setup, cparams):
    pos, sizes = fog_setup
    d = coop.selective_cooperation(pos, sizes, cparams)
    c = np.asarray(sizes)
    dm = np.array(ch.pairwise_distances(pos, pos))
    np.fill_diagonal(dm, np.inf)
    feas = np.asarray(ch.feasible(jnp.asarray(dm), cparams))
    q1 = np.nanquantile(np.where(feas, dm, np.nan), 0.25)
    for m in np.flatnonzero(np.asarray(d.cooperates)):
        j = int(d.partner[m])
        assert c[j] > c[m]
        assert dm[m, j] < q1
        assert feas[m, j]
        # weights are the paper's (0.8, 0.2)
        assert float(d.self_weight[m]) == pytest.approx(0.8)
        assert float(d.partner_weight[m]) == pytest.approx(0.2)


def test_selective_subset_of_nearest_energy(fog_setup, cparams):
    """Selective must activate at most as many links as always-on."""
    pos, sizes = fog_setup
    ds = coop.selective_cooperation(pos, sizes, cparams)
    dn = coop.nearest_cooperation(pos, cparams)
    assert int(jnp.sum(ds.cooperates)) <= int(jnp.sum(dn.cooperates))


def test_selective_all_equal_clusters_no_coop(cparams):
    """With perfectly balanced clusters nobody passes Eq. 28 (c > 0.75 mean
    and c > 2)."""
    key = jax.random.key(1)
    pos = jax.random.uniform(key, (6, 3), minval=0.0, maxval=500.0)
    sizes = jnp.full((6,), 10, jnp.int32)
    d = coop.selective_cooperation(pos, sizes, cparams)
    assert not bool(jnp.any(d.cooperates))


def test_selective_needs_larger_neighbour(cparams):
    """A small cluster with only equal-size neighbours cannot cooperate."""
    pos = jnp.array([[0.0, 0.0, 100.0], [100.0, 0.0, 100.0]])
    sizes = jnp.array([1, 1], jnp.int32)
    d = coop.selective_cooperation(pos, sizes, cparams)
    assert not bool(jnp.any(d.cooperates))


def test_selective_small_joins_nearby_large(cparams):
    # Three isolated fog pairs with distinct intra-pair distances 30/50/100 m
    # (inter-pair links are infeasible at ~2.6 km under the 140 dB cap), so
    # the first quartile of feasible distances is 35 m and only the small
    # fog 0 has a larger neighbour strictly inside it.
    pos = jnp.array(
        [[0.0, 0.0, 100.0], [30.0, 0.0, 100.0],
         [1900.0, 0.0, 100.0], [1950.0, 0.0, 100.0],
         [0.0, 1900.0, 100.0], [100.0, 1900.0, 100.0]]
    )
    sizes = jnp.array([1, 20, 10, 10, 10, 10], jnp.int32)
    d = coop.selective_cooperation(pos, sizes, cparams)
    assert bool(d.cooperates[0])
    assert int(d.partner[0]) == 1
