"""Tests for the fused local-training path (the client phase in one
VMEM-resident operator).

Covers the ISSUE-4 acceptance points: fused-vs-``local_sgd``-scan parity
to float tolerance (plain SGD and FedProx ``mu > 0``, window sizes that do
not divide the batch size, E = 1 and E = 5), Pallas-interpret vs
jnp-oracle parity, the auto-fallback rule for non-AE models, end-to-end
``hfl.train`` / ``flat_fl.train_flat`` fused-vs-unfused equivalence, the
engine's local-solver resolution, and the Eq. 21 empty-fog latency fix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import cooperation as coop
from repro.core import hfl
from repro.data.pipeline import multi_epoch_indices
from repro.kernels import ops
from repro.models import autoencoder as ae
from repro.optim.sgd import (
    LocalTrainConfig,
    fusable_params,
    make_client_solver,
)

D = 32
HIDDEN = (16, 8, 16)


def _params(seed=1, dim=D, hidden=HIDDEN):
    return ae.init(jax.random.key(seed), dim, hidden)


def _clients(n, window, seed=0):
    return jax.random.normal(jax.random.key(seed), (n, window, D))


def _legacy(params, data, keys, batch_size, epochs, lr, mu):
    """The pre-fusion client phase: per-client scan over a gathered
    (E * nb, bs, D) batch stream."""
    solver = make_client_solver(
        ae.loss, batch_size=batch_size, epochs=epochs, lr=lr, prox_mu=mu,
        solver=LocalTrainConfig(fused=False),
    )
    return solver(params, data, keys)


@pytest.mark.parametrize(
    "window,batch_size,epochs",
    [
        (64, 32, 1),      # E = 1
        (64, 32, 5),      # E = 5
        (70, 32, 3),      # window does not divide the batch size
        (40, 16, 2),      # small batches, partial window use
    ],
)
@pytest.mark.parametrize("mu", [0.0, 0.01])
def test_fused_ref_matches_scan(window, batch_size, epochs, mu):
    """ops.local_train (jnp oracle path) == vmapped local_sgd /
    proximal_local_sgd over multi_epoch_batches, batch for batch."""
    params = _params()
    data = _clients(4, window)
    keys = jax.random.split(jax.random.key(3), 4)
    d_leg, l_leg = _legacy(params, data, keys, batch_size, epochs, 0.05, mu)
    idx = jax.vmap(
        lambda k: multi_epoch_indices(k, window, batch_size, epochs)
    )(keys)
    d_ref, l_ref = ops.local_train(
        params, data, idx, 0.05, mu, use_pallas=False
    )
    np.testing.assert_allclose(
        np.asarray(d_ref), np.asarray(d_leg), rtol=1e-5, atol=1e-7
    )
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_leg), rtol=1e-6)


@pytest.mark.parametrize(
    "window,batch_size,epochs,mu",
    [
        (64, 32, 1, 0.0),
        (64, 32, 5, 0.0),
        (70, 32, 3, 0.01),
        (40, 16, 2, 0.0),
    ],
)
def test_pallas_interpret_matches_oracle(window, batch_size, epochs, mu):
    """The kernel body (interpret mode) must agree with the jnp oracle:
    identical batch assembly from the resident window, manual backward ==
    autodiff to float tolerance."""
    params = _params()
    data = _clients(3, window, seed=window)
    keys = jax.random.split(jax.random.key(4), 3)
    idx = jax.vmap(
        lambda k: multi_epoch_indices(k, window, batch_size, epochs)
    )(keys)
    d_ref, l_ref = ops.local_train(
        params, data, idx, 0.05, mu, use_pallas=False
    )
    d_pl, l_pl = ops.local_train(
        params, data, idx, 0.05, mu, use_pallas=True, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(d_pl), np.asarray(d_ref), rtol=1e-4, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(l_pl), np.asarray(l_ref), rtol=1e-5, atol=1e-7
    )


def test_solver_dispatches_fused_and_matches_scan():
    """make_client_solver with the default config routes the paper AE
    through the fused operator and reproduces the scan path."""
    params = _params()
    data = _clients(5, 64)
    keys = jax.random.split(jax.random.key(5), 5)
    fused = make_client_solver(
        ae.loss, batch_size=32, epochs=2, lr=0.05
    )
    d_f, l_f = fused(params, data, keys)
    d_s, l_s = _legacy(params, data, keys, 32, 2, 0.05, 0.0)
    assert d_f.shape == (5, ravel_pytree(params)[0].shape[0])
    np.testing.assert_allclose(
        np.asarray(d_f), np.asarray(d_s), rtol=1e-5, atol=1e-7
    )
    np.testing.assert_allclose(np.asarray(l_f), np.asarray(l_s), rtol=1e-6)


def test_non_ae_models_fall_back():
    """Anything the kernel cannot express must silently take the scan
    path: non-AE param structures and non-AE losses."""
    assert fusable_params(_params())
    # dict-of-arrays params (LLM-style) are not fusable
    assert not fusable_params({"w": jnp.zeros((4, 4))})
    # broken layer chaining is not fusable
    bad = [{"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))},
           {"w": jnp.zeros((5, 8)), "b": jnp.zeros((8,))}]
    assert not fusable_params(bad)
    # encoder-only stacks (out dim != in dim) are not a reconstruction
    enc = [{"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}]
    assert not fusable_params(enc)

    # a custom loss over AE-shaped params must NOT hit the AE kernel:
    # the solver with a quadratic loss equals the legacy scan of that loss
    def quad_loss(params, batch):
        flat, _ = ravel_pytree(params)
        return jnp.sum(flat**2) + 0.0 * jnp.sum(batch)

    params = _params()
    data = _clients(3, 64)
    keys = jax.random.split(jax.random.key(6), 3)
    solver = make_client_solver(
        quad_loss, batch_size=32, epochs=1, lr=0.05
    )
    d_c, _ = solver(params, data, keys)
    legacy = make_client_solver(
        quad_loss, batch_size=32, epochs=1, lr=0.05,
        solver=LocalTrainConfig(fused=False),
    )
    d_l, _ = legacy(params, data, keys)
    np.testing.assert_array_equal(np.asarray(d_c), np.asarray(d_l))


def _tiny_setup(prox_mu=0.0):
    from repro.data.synthetic import SyntheticConfig, generate, normalize
    from repro.launch import experiment as exp

    dcfg = SyntheticConfig(n_sensors=10, train_len=48, val_len=24, test_len=48)
    ds = normalize(generate(jax.random.key(0), dcfg))
    params0 = ae.init(jax.random.key(1), ds.train.shape[-1], HIDDEN)
    cfg = exp.make_config(
        n_sensors=10, n_fog=3, rounds=2, local_epochs=2, prox_mu=prox_mu,
    )
    return ds, params0, cfg


@pytest.mark.parametrize("prox_mu", [0.0, 0.01])
def test_hfl_train_fused_matches_unfused(prox_mu):
    """End to end: hfl.train with the fused default == the legacy scan
    path (LocalTrainConfig(fused=False)) to float tolerance."""
    ds, params0, cfg = _tiny_setup(prox_mu)
    p1, m1 = hfl.train(jax.random.key(2), params0, ae.loss, ds, cfg)
    p2, m2 = hfl.train(
        jax.random.key(2), params0, ae.loss, ds,
        cfg.replace(local_solver=LocalTrainConfig(fused=False)),
    )
    np.testing.assert_allclose(
        np.asarray(m1.loss), np.asarray(m2.loss), rtol=1e-5
    )
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_flat_train_fused_matches_unfused():
    from repro.core import flat_fl

    ds, params0, cfg = _tiny_setup(prox_mu=0.01)   # FedProx in-kernel
    p1, m1 = flat_fl.train_flat(jax.random.key(2), params0, ae.loss, ds, cfg)
    p2, m2 = flat_fl.train_flat(
        jax.random.key(2), params0, ae.loss, ds,
        cfg.replace(local_solver=LocalTrainConfig(fused=False)),
    )
    np.testing.assert_allclose(
        np.asarray(m1.loss), np.asarray(m2.loss), rtol=1e-5
    )
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_engine_resolves_local_solver():
    from repro import engine as eng_mod

    eng = eng_mod.Engine()
    ls = eng.resolve_local_solver(LocalTrainConfig())
    assert ls.fused
    assert ls.use_pallas == eng_mod.default_use_pallas()
    # the explicit opt-out is respected
    off = LocalTrainConfig(fused=False)
    assert eng.resolve_local_solver(off) == off
    assert eng.resolve_config(hfl.HFLConfig()).local_solver == ls


def test_mesh_pod_local_epochs_runs_and_degenerates():
    """core/mesh_fl routes through optim/sgd: E=1 keeps the historical
    gradient-exchange numerics; E>1 (delta exchange) still learns."""
    from repro import configs
    from repro.core import mesh_fl
    from repro.models import api

    cfg = configs.get("llama3_8b", reduced=True).replace(learning_rate=1e-2)
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    key = jax.random.key(0)
    params = api.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}

    step1 = mesh_fl.make_pod_hfl_train_step(cfg, mesh, local_epochs=1)
    step2 = mesh_fl.make_pod_hfl_train_step(cfg, mesh, local_epochs=2)
    # production-scale lr: the f32-upcast local steps must still produce
    # nonzero exchanged deltas (raw-bf16 steps would round |lr*g| << |p|
    # to zero and leave the EF residual exactly zero)
    step_small = mesh_fl.make_pod_hfl_train_step(
        cfg.replace(learning_rate=1e-4), mesh, local_epochs=2
    )
    with mesh:
        err = mesh_fl.init_err(params, n_pods=1)
        p1, _, l1 = jax.jit(step1)(params, err, batch)
        p2, _, l2 = jax.jit(step2)(params, err, batch)
        _, err_small, _ = jax.jit(step_small)(params, err, batch)
    moved = sum(float(jnp.sum(jnp.abs(e)))
                for e in jax.tree_util.tree_leaves(err_small))
    assert moved > 0.0
    # E=2 reports the mean over both local passes; the second pass re-visits
    # the same batch after a step, so the mean must not exceed the E=1 loss.
    assert float(l2) <= float(l1) + 1e-6
    # E=2 moves further than E=1 from the same start
    d1 = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
             for a, b in zip(jax.tree_util.tree_leaves(p1),
                             jax.tree_util.tree_leaves(params)))
    d2 = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
             for a, b in zip(jax.tree_util.tree_leaves(p2),
                             jax.tree_util.tree_leaves(params)))
    assert d2 > d1 > 0.0


def test_empty_fog_phantom_exchange_does_not_set_latency():
    """Eq. 21 regression pin: an empty fog paired with a distant partner
    (cooperates=True but fog_active=False) must not contribute a
    fog-to-fog latency term — same mask as the Eq. 18 energy."""
    cfg = hfl.HFLConfig()
    l_u, l_full = 1000.0, 43264.0
    active = jnp.array([True, True])
    sensor_dist = jnp.array([200.0, 300.0])
    fog_active = jnp.array([True, False])       # fog 1 is EMPTY
    fg_dist = jnp.array([400.0, 500.0])
    # both fogs nominally cooperate; the empty one with a huge link
    def _decision(coop_mask):
        return coop.CoopDecision(
            partner=jnp.array([1, 0], jnp.int32),
            self_weight=jnp.array([0.8, 0.8]),
            partner_weight=jnp.array([0.2, 0.2]),
            cooperates=jnp.array(coop_mask),
            dist_m=jnp.array([350.0, 4000.0]),
        )

    decision = _decision([True, True])
    lat = hfl.comm_latency_s(
        l_u, l_full, active, sensor_dist, decision, fog_active, fg_dist,
        cfg.channel,
    )
    # dropping the phantom pair entirely must give the same latency
    no_phantom = _decision([True, False])
    lat_ref = hfl.comm_latency_s(
        l_u, l_full, active, sensor_dist, no_phantom, fog_active, fg_dist,
        cfg.channel,
    )
    np.testing.assert_allclose(float(lat), float(lat_ref))
    # sanity: with members in fog 1 the 4 km exchange WOULD dominate
    lat_full = hfl.comm_latency_s(
        l_u, l_full, active, sensor_dist, decision,
        jnp.array([True, True]), fg_dist, cfg.channel,
    )
    assert float(lat_full) > float(lat)


def test_publish_path_donation_keeps_scan_numerics():
    """The publish-path step_fn donates its carry; numerics must stay
    identical to the scan path and the caller's init params must remain
    usable afterwards."""
    from repro.checkpoint import CheckpointStore
    import tempfile

    ds, params0, cfg = _tiny_setup()
    with tempfile.TemporaryDirectory() as tmp:
        store = CheckpointStore(tmp, keep=5)
        p_pub, m_pub = hfl.train(
            jax.random.key(2), params0, ae.loss, ds, cfg, store=store
        )
        # init params were NOT donated away
        _ = jax.block_until_ready(ravel_pytree(params0)[0] + 0.0)
        p_scan, m_scan = hfl.train(jax.random.key(2), params0, ae.loss, ds, cfg)
        np.testing.assert_allclose(
            np.asarray(m_pub.loss), np.asarray(m_scan.loss), rtol=1e-6
        )
        for a, b in zip(jax.tree_util.tree_leaves(p_pub),
                        jax.tree_util.tree_leaves(p_scan)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
