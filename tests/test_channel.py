"""Unit + property tests for the underwater acoustic channel (Sec. III-B/C)."""
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401
from _hypothesis_compat import given, settings, st  # noqa: F401

from repro.core import channel as ch


def test_thorp_at_12khz_matches_closed_form():
    # Eq. 2 evaluated by hand at f = 12 kHz.
    f = 12.0
    f2 = f * f
    expected = 0.11 * f2 / (1 + f2) + 44 * f2 / (4100 + f2) + 2.75e-4 * f2 + 0.003
    got = float(ch.thorp_absorption_db_per_km(f))
    np.testing.assert_allclose(got, expected, rtol=1e-6)


def test_thorp_increases_with_frequency():
    f = jnp.linspace(1.0, 100.0, 64)
    a = ch.thorp_absorption_db_per_km(f)
    assert bool(jnp.all(jnp.diff(a) > 0))


def test_transmission_loss_monotone_in_distance():
    d = jnp.linspace(1.0, 10_000.0, 256)
    tl = ch.transmission_loss_db(d, 12.0)
    assert bool(jnp.all(jnp.diff(tl) > 0))


def test_transmission_loss_at_reference_distance_is_zero_spreading():
    # At d = 1 m the spreading term vanishes; only absorption d/1000 remains.
    tl = float(ch.transmission_loss_db(1.0, 12.0))
    alpha = float(ch.thorp_absorption_db_per_km(12.0))
    np.testing.assert_allclose(tl, alpha / 1000.0, atol=1e-6)


def test_sub_metre_distances_clipped():
    assert float(ch.transmission_loss_db(0.01, 12.0)) == pytest.approx(
        float(ch.transmission_loss_db(1.0, 12.0))
    )


def test_wenz_noise_all_components_positive_contribution():
    # Total PSD must exceed each individual component (linear-scale sum).
    f = 12.0
    total = float(ch.wenz_noise_psd_db(f))
    logf = np.log10(f)
    n_wind = 50 + 7.5 * np.sqrt(5.0) + 20 * logf - 40 * np.log10(f + 0.4)
    assert total > n_wind  # wind dominates at 12 kHz but total is larger


def test_wenz_wind_increases_noise():
    lo = float(ch.wenz_noise_psd_db(12.0, wind_m_s=0.0))
    hi = float(ch.wenz_noise_psd_db(12.0, wind_m_s=15.0))
    assert hi > lo


def test_snr_at_min_source_level_equals_target(cparams):
    """Eq. 5 must invert Eq. 4: SNR(SL_min, d) == gamma_tgt exactly."""
    d = jnp.array([10.0, 100.0, 1000.0, 3000.0])
    sl_min = ch.min_source_level_db(d, cparams)
    snr = ch.snr_db(sl_min, d, cparams)
    np.testing.assert_allclose(
        np.asarray(snr), cparams.gamma_tgt_db, rtol=1e-5
    )


def test_feasibility_is_distance_threshold(cparams):
    """Feasibility must be monotone: feasible at d implies feasible closer."""
    rmax = float(ch.max_feasible_range_m(cparams))
    assert 100.0 < rmax < 50_000.0
    assert bool(ch.feasible(rmax * 0.999, cparams))
    assert not bool(ch.feasible(rmax * 1.001, cparams))


def test_higher_sl_cap_extends_range(cparams):
    r1 = float(ch.max_feasible_range_m(cparams))
    r2 = float(ch.max_feasible_range_m(cparams.replace(sl_max_db=160.0)))
    assert r2 > r1


def test_shannon_rate_matches_formula(cparams):
    expected = 4000.0 * np.log2(1.0 + 10.0)  # B log2(1 + 10^(10/10))
    np.testing.assert_allclose(
        float(ch.shannon_rate_bps(cparams)), expected, rtol=1e-6
    )


def test_propagation_delay():
    np.testing.assert_allclose(
        float(ch.propagation_delay_s(1500.0)), 1.0, rtol=1e-6
    )


def test_pairwise_distances_against_numpy():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(7, 3)).astype(np.float32)
    b = rng.normal(size=(5, 3)).astype(np.float32)
    got = np.asarray(ch.pairwise_distances(jnp.asarray(a), jnp.asarray(b)))
    want = np.linalg.norm(a[:, None] - b[None, :], axis=-1)
    np.testing.assert_allclose(got, want, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(
    d=st.floats(min_value=1.0, max_value=20_000.0),
    f=st.floats(min_value=1.0, max_value=60.0),
    gamma=st.floats(min_value=0.0, max_value=20.0),
)
def test_property_sl_min_inverts_snr(d, f, gamma):
    p = ch.ChannelParams(freq_khz=f, gamma_tgt_db=gamma)
    sl = float(ch.min_source_level_db(jnp.float32(d), p))
    snr = float(ch.snr_db(jnp.float32(sl), jnp.float32(d), p))
    assert snr == pytest.approx(gamma, abs=1e-3)


@settings(max_examples=30, deadline=None)
@given(
    d1=st.floats(min_value=1.0, max_value=10_000.0),
    d2=st.floats(min_value=1.0, max_value=10_000.0),
)
def test_property_tl_monotone(d1, d2):
    lo, hi = sorted((d1, d2))
    tl_lo = float(ch.transmission_loss_db(jnp.float32(lo), 12.0))
    tl_hi = float(ch.transmission_loss_db(jnp.float32(hi), 12.0))
    assert tl_lo <= tl_hi + 1e-6
